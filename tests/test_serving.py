"""OnlineGraphService: microbatching, deadline shedding, EdgeBank
degradation + circuit-breaker recovery, ingest hygiene, crash-safe
snapshot/restore bit-parity, and the deterministic chaos test driven by
serve.faults.FaultInjector."""

import time

import numpy as np
import pytest

from repro.models.tg.edgebank import EdgeBank
from repro.serve import FaultInjector, ModelFault, OnlineGraphService, Status


def _events(n, num_nodes=40, seed=0, t0=100):
    rng = np.random.default_rng(seed)
    return [(int(rng.integers(num_nodes)), int(rng.integers(num_nodes)),
             t0 + i, i) for i in range(n)]


def _mk(num_nodes=40, **kw):
    kw.setdefault("k", 4)
    kw.setdefault("flush_interval", 0.002)
    return OnlineGraphService(num_nodes, **kw)


# ---------------------------------------------------------------- batching

def test_flush_on_timeout_single_request():
    with _mk() as svc:
        svc.ingest_many(_events(50))
        svc.drain()
        r = svc.predict_link(1, 2, 500)
        assert r.status is Status.OK and r.tier == "model"
        assert 0.0 <= r.score <= 1.0


def test_flush_on_size():
    with _mk(max_batch=4, flush_interval=5.0) as svc:  # size-only flush
        svc.ingest_many(_events(50))
        svc.drain()
        pend = [svc.submit_link(i, i + 1, 500) for i in range(4)]
        rs = [p.result(timeout=10) for p in pend]
        assert all(r.status is Status.OK for r in rs)


def test_deadline_shedding_is_explicit():
    with _mk() as svc:
        r = svc.submit_link(1, 2, 500, timeout=0.0).result(timeout=10)
        assert r.status is Status.REJECTED
        assert "deadline" in r.detail
        assert svc.stats["rejected"] == 1


# -------------------------------------------------------------- degradation

def test_degrades_to_edgebank_and_probe_recovers():
    broken = {"on": True}

    def model(seeds, t, ids, times, mask):
        if broken["on"]:
            raise ModelFault("boom")
        return np.full(len(seeds) // 2, 0.5, np.float32)

    with _mk(model_fn=model, fail_threshold=2, probe_every=2) as svc:
        svc.ingest(3, 4, 100, 0)
        svc.drain()
        # two failing flushes open the breaker; every answer still arrives
        # via the EdgeBank fallback with an explicit DEGRADED status
        for _ in range(2):
            r = svc.predict_link(3, 4, 500)
            assert r.status is Status.DEGRADED and r.tier == "edgebank"
        assert svc.stats["model_errors"] == 2
        # breaker open: EdgeBank answers warm from the same event stream
        r = svc.predict_link(3, 4, 500)
        assert r.status is Status.DEGRADED and r.score == 1.0
        r = svc.predict_link(7, 8, 500)  # unseen pair
        assert r.status is Status.DEGRADED and r.score == 0.0
        # heal the model: the next probe flush closes the breaker
        broken["on"] = False
        statuses = [svc.predict_link(3, 4, 500).status for _ in range(4)]
        assert Status.OK in statuses
        assert statuses[-1] is Status.OK  # healthy again, stays healthy
        assert svc.stats["probes"] >= 1


def test_embed_has_no_fallback_tier():
    def model(*a):
        raise ModelFault("boom")

    with _mk(model_fn=model, embed_fn=model, fail_threshold=1) as svc:
        svc.predict_link(1, 2, 100)  # opens the breaker
        r = svc.embed(1, 100)
        assert r.status is Status.FAILED
        assert "no fallback" in r.detail


def test_latency_budget_degrades():
    def slow(seeds, t, ids, times, mask):
        time.sleep(0.05)
        return np.zeros(len(seeds) // 2, np.float32)

    with _mk(model_fn=slow, latency_budget=0.01, probe_every=100) as svc:
        first = svc.predict_link(1, 2, 100)
        assert first.status is Status.OK  # no EWMA yet: model runs, is slow
        second = svc.predict_link(1, 2, 100)
        assert second.status is Status.DEGRADED and second.tier == "edgebank"


# ------------------------------------------------------------------ ingest

def test_ingest_dedup_and_out_of_order_counting():
    with _mk() as svc:
        svc.ingest(1, 2, 100, 7)
        svc.ingest(1, 2, 100, 7)   # duplicate eid: dropped
        svc.ingest(3, 4, 50, 8)    # out of order: applied + counted
        svc.drain()
        assert svc.stats["events_applied"] == 2
        assert svc.stats["events_deduped"] == 1
        assert svc.stats["events_out_of_order"] == 1
        assert svc.predict_link(3, 4, 500).status is Status.OK


def test_stop_fails_outstanding_requests_no_deadlock():
    def hang(seeds, t, ids, times, mask):
        time.sleep(0.2)
        return np.zeros(len(seeds) // 2, np.float32)

    svc = _mk(model_fn=hang)
    pend = [svc.submit_link(i, i + 1, 100) for i in range(3)]
    svc.stop()
    for p in pend:
        r = p.result(timeout=10)  # resolved, not deadlocked
        assert r.status in (Status.OK, Status.FAILED)
    with pytest.raises(RuntimeError):
        svc.ingest(1, 2, 3)


# -------------------------------------------------------------- durability

def test_snapshot_restore_bit_parity(tmp_path):
    """Kill-then-restore == uninterrupted: a service snapshotted mid-stream
    and restored into a fresh process answers bit-identically to one that
    never died."""
    ev = _events(120, seed=3)
    queries = [(s, d, 1000) for s, d, _, _ in _events(20, seed=9)]

    with _mk(seed=5) as clean:
        clean.ingest_many(ev)
        clean.drain()
        want = [clean.predict_link(*q).score for q in queries]

    with _mk(seed=5) as victim:
        victim.ingest_many(ev[:60])
        victim.snapshot(str(tmp_path), step=60)
    # "crash": victim is gone; a fresh service restores and replays the
    # rest of the stream (duplicates straddling the snapshot are deduped)
    with _mk(seed=5) as revived:
        assert revived.restore(str(tmp_path)) == 60
        revived.ingest_many(ev[55:])  # overlap: eids 55-59 already applied
        revived.drain()
        assert revived.stats["events_deduped"] == 5
        got = [revived.predict_link(*q).score for q in queries]
    assert got == want  # bit-identical, not approximately equal


def test_edgebank_state_roundtrip():
    bank = EdgeBank(30, window=50)
    rng = np.random.default_rng(0)
    bank.update_memory(rng.integers(0, 30, 40), rng.integers(0, 30, 40),
                       rng.integers(0, 200, 40))
    clone = EdgeBank(30, window=50)
    clone.load_state_dict(bank.state_dict())
    src, dst, t = rng.integers(0, 30, 50), rng.integers(0, 30, 50), \
        rng.integers(0, 300, 50)
    np.testing.assert_array_equal(bank.predict_link(src, dst, t),
                                  clone.predict_link(src, dst, t))
    # canonical serialization: same memory -> identical bytes
    a, b = bank.state_dict(), clone.state_dict()
    np.testing.assert_array_equal(a["keys"], b["keys"])
    np.testing.assert_array_equal(a["times"], b["times"])


# ------------------------------------------------------------------- chaos

def test_chaos_never_deadlocks_and_degrades_gracefully():
    """The acceptance chaos test: slow + failing model steps and a dropped/
    duplicated/reordered event stream. The service must resolve every
    request with an explicit status, shed over-deadline requests, and keep
    serving EdgeBank answers while the model tier is down."""
    from repro.obs import MemorySink, Telemetry, validate

    inj = FaultInjector(seed=0, drop_p=0.05, dup_p=0.05, reorder_p=0.15,
                        reorder_span=3, slow_p=0.5, slow_s=0.02,
                        fail_p=0.6)
    sink = MemorySink()
    tel = Telemetry(sink)
    svc = _mk(num_nodes=60, fault_injector=inj, fail_threshold=2,
              probe_every=3, latency_budget=0.05, telemetry=tel)
    try:
        stream = inj.perturb_events(_events(150, num_nodes=60, seed=1))
        svc.ingest_many(stream)
        svc.drain()
        assert inj.stats["dropped"] > 0 and inj.stats["duplicated"] > 0
        assert inj.stats["reordered"] > 0
        assert svc.stats["events_deduped"] >= inj.stats["duplicated"]

        pend = [svc.submit_link(int(i % 60), int((i * 7 + 1) % 60), 1000,
                                timeout=5.0) for i in range(30)]
        pend += [svc.submit_link(1, 2, 1000, timeout=0.0)
                 for _ in range(3)]  # guaranteed over-deadline
        results = [p.result(timeout=30) for p in pend]  # never deadlocks

        statuses = {r.status for r in results}
        assert all(isinstance(r.status, Status) for r in results)
        assert Status.REJECTED in statuses  # explicit shedding
        assert Status.DEGRADED in statuses  # EdgeBank served while degraded
        for r in results:
            if r.status in (Status.OK, Status.DEGRADED):
                assert r.score is not None and 0.0 <= r.score <= 1.0
        assert inj.stats["model_faults"] > 0
        # every request is accounted for in the service counters
        tallied = sum(svc.stats[s] for s in
                      ("ok", "degraded", "rejected", "failed"))
        assert tallied == len(results)

        # telemetry mirrors the stats dict and records schema-valid output
        assert tel.counter_value("serve/events_deduped") == \
            svc.stats["events_deduped"]
        assert tel.counter_value("serve/model_errors") == \
            svc.stats["model_errors"]
        by_status = sum(tel.counter_value(f"serve/requests_{s}")
                        for s in ("ok", "degraded", "rejected", "failed"))
        assert by_status == len(results)
        # per-tier latency histograms saw every tiered (ok/degraded) answer
        answered = sum(
            tel.histogram(f"serve/latency/{tier}").count
            for tier in ("model", "edgebank")
            if tel.histogram(f"serve/latency/{tier}") is not None)
        assert answered == svc.stats["ok"] + svc.stats["degraded"]
        tel.flush()
        for rec in sink.records:
            validate(rec)
    finally:
        svc.stop()
