import numpy as np

from repro.train import auc, mrr, ndcg_at_k


def test_mrr_perfect():
    pos = np.array([5.0, 5.0])
    neg = np.zeros((2, 10))
    assert mrr(pos, neg) == 1.0


def test_mrr_worst():
    pos = np.array([0.0])
    neg = np.ones((1, 9))
    assert abs(mrr(pos, neg) - 0.1) < 1e-6


def test_mrr_ties_midrank():
    pos = np.array([1.0])
    neg = np.array([[1.0, 0.0]])  # one tie -> rank 1.5
    assert abs(mrr(pos, neg) - 1 / 1.5) < 1e-6


def test_mrr_mask():
    pos = np.array([5.0, 0.0])
    neg = np.stack([np.zeros(5), np.ones(5)])
    assert mrr(pos, neg, mask=np.array([True, False])) == 1.0


def test_auc():
    assert auc([0.9, 0.8, 0.2, 0.1], [1, 1, 0, 0]) == 1.0
    assert auc([0.1, 0.2, 0.8, 0.9], [1, 1, 0, 0]) == 0.0
    assert abs(auc([0.5, 0.5, 0.5, 0.5], [1, 1, 0, 0]) - 0.5) < 1e-9


def test_auc_degenerate():
    assert auc([0.5, 0.2], [1, 1]) == 0.5


def test_ndcg():
    pred = np.array([[3.0, 2.0, 1.0]])
    target = np.array([[3.0, 2.0, 1.0]])
    assert abs(ndcg_at_k(pred, target, k=3) - 1.0) < 1e-9
    worst = np.array([[1.0, 2.0, 3.0]])
    assert ndcg_at_k(worst, target, k=3) < 1.0
