"""Scan-compiled DTDG pipeline: SnapshotTensor tensorization, scan-vs-loop
parity (the compiled epoch must be bit-identical to the per-snapshot jitted
loop), checkpointing through the shared state_dict contract, the
segment_reduce routing in the GCN layer, the uniform sampler's hop-2
frontier, and counter-only uniform checkpoints."""

import numpy as np
import pytest

import jax

from repro.core import (
    DGData,
    DGraph,
    DGDataLoader,
    RECIPE_DTDG_SNAPSHOT,
    RecipeRegistry,
    TRAIN_KEY,
    snapshot_negatives,
    snapshot_tensor,
)
from repro.train import LinkPredictionTrainer, SnapshotLinkTrainer

DTDG_MODELS = ["gcn", "gclstm", "tgcn"]


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


# ----------------------------------------------------------------------
# SnapshotTensor tensorization
# ----------------------------------------------------------------------
def test_snapshot_tensor_matches_time_iteration(small_stream):
    """Rows of the device tensor == iterate-by-time over the discretized
    stream (same windows, counts, masks, and edge sets)."""
    st = snapshot_tensor(small_stream, "h")
    disc = small_stream.discretize("h", reduce="first")
    loader = DGDataLoader(DGraph(disc), None, batch_size=None,
                          batch_unit="h", emit_empty=True)
    rows = list(loader)
    assert len(rows) == st.num_snapshots
    counts = np.asarray(st.counts)
    for i, b in enumerate(rows):
        assert counts[i] == b.num_events
        m = np.asarray(st.mask[i])
        assert m[: counts[i]].all() and not m[counts[i]:].any()
        got = set(zip(np.asarray(st.src[i])[: counts[i]].tolist(),
                      np.asarray(st.dst[i])[: counts[i]].tolist()))
        want = set(zip(b["src"].tolist(), b["dst"].tolist()))
        assert got == want


def test_snapshot_tensor_capacity_and_device_arrays(small_stream):
    st = snapshot_tensor(small_stream, "h")
    assert st.capacity >= int(np.asarray(st.counts).max())
    assert st.capacity & (st.capacity - 1) == 0  # power of two
    assert isinstance(st.src, jax.Array) and isinstance(st.mask, jax.Array)
    # explicit capacity is honored (tail dropped deterministically)
    st2 = snapshot_tensor(small_stream, "h", capacity=4)
    assert st2.capacity == 4
    assert int(np.asarray(st2.counts).max()) <= 4


def test_snapshot_tensor_huge_ticks_fallback():
    """Graphs whose coarse ticks exceed int32 (ns/us-scale epochs) route
    through the numpy fallback and tensorize correctly — ticks are staged
    zero-based, never wrapped (regression)."""
    rng = np.random.default_rng(0)
    t = np.sort(rng.integers(2**45, 2**45 + 50 * 3600, 50))
    d = DGData.from_arrays(rng.integers(0, 10, 50), rng.integers(0, 10, 50),
                           t, granularity="s")
    st = snapshot_tensor(d, "h")
    disc = d.discretize("h", reduce="first")
    assert int(np.asarray(st.counts).sum()) == disc.num_edge_events
    assert st.row_of_time(int(t[0])) == 0
    assert st.num_snapshots == int(t.max() // 3600 - t.min() // 3600) + 1


def test_snapshot_negatives_row_pure():
    """Bulk draws == per-row draws (the scan-vs-loop negatives invariant)."""
    bulk = np.asarray(snapshot_negatives(3, 100, 8, 5, np.arange(20)))
    for row in (0, 7, 19):
        one = np.asarray(snapshot_negatives(3, 100, 8, 5, [row]))[0]
        np.testing.assert_array_equal(bulk[row], one)
    # different negative widths get independent streams
    other = np.asarray(snapshot_negatives(3, 100, 8, 4, [0]))[0]
    assert other.shape == (8, 4)


# ----------------------------------------------------------------------
# Scan-vs-loop parity (the tentpole invariant)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("model", DTDG_MODELS)
def test_scan_vs_loop_parity(model, small_stream):
    """One scanned jitted epoch == per-snapshot jitted loop, bit-for-bit:
    losses, trained params, and val/test MRR."""
    kw = dict(snapshot_unit="h", d_embed=16, seed=3)
    scan = SnapshotLinkTrainer(model, small_stream, compiled=True, **kw)
    loop = SnapshotLinkTrainer(model, small_stream, compiled=False, **kw)

    loss_s, _ = scan.train_epoch()
    loss_l, _ = loop.train_epoch()
    assert loss_s == loss_l
    assert _tree_equal(scan.params, loop.params)
    assert _tree_equal(scan.opt_state, loop.opt_state)

    mrr_s, _ = scan.evaluate("val")
    mrr_l, _ = loop.evaluate("val")
    assert mrr_s == mrr_l
    assert scan.evaluate("test")[0] == loop.evaluate("test")[0]


def test_scan_chunked_matches_whole_epoch(small_stream):
    whole = SnapshotLinkTrainer("tgcn", small_stream, snapshot_unit="h",
                                d_embed=16)
    chunked = SnapshotLinkTrainer("tgcn", small_stream, snapshot_unit="h",
                                  d_embed=16, chunk_size=5)
    l1, _ = whole.train_epoch()
    l2, _ = chunked.train_epoch()
    assert l1 == l2
    assert _tree_equal(whole.params, chunked.params)
    assert whole.evaluate("val")[0] == chunked.evaluate("val")[0]


def test_empty_val_split_keeps_test_pairs(small_stream):
    """val_ratio=0 collapses val onto the test boundary instead of
    silently swallowing the test split (regression)."""
    tr = SnapshotLinkTrainer("gcn", small_stream, snapshot_unit="h",
                             d_embed=16, val_ratio=0.0, test_ratio=0.3)
    vlo, vhi = tr._split_pairs("val")
    tlo, thi = tr._split_pairs("test")
    assert vlo == vhi  # no val pairs
    assert thi > tlo  # test split intact
    assert tr.evaluate("test")[0] > 0.0


def test_pair_xs_cache_is_bounded(small_stream):
    """Scan-input caching must not grow without bound across epochs,
    chunk sizes, and splits (it duplicates device slices + negatives)."""
    tr = SnapshotLinkTrainer("gcn", small_stream, snapshot_unit="h",
                             d_embed=16, chunk_size=3)
    tr.train_epoch()
    tr.evaluate("val")
    tr.evaluate("test")
    tr.chunk_size = 5
    tr.train_epoch()
    assert len(tr._xs_cache) <= tr._XS_CACHE_MAX


def test_split_pairs_partition(small_stream):
    """Every prediction pair lands in exactly one split, in order."""
    tr = SnapshotLinkTrainer("gcn", small_stream, snapshot_unit="h",
                             d_embed=16)
    t_lo, t_hi = tr._split_pairs("train")
    v_lo, v_hi = tr._split_pairs("val")
    s_lo, s_hi = tr._split_pairs("test")
    assert 0 == t_lo <= t_hi == v_lo <= v_hi == s_lo <= s_hi
    assert s_hi == tr.snapshots.num_snapshots - 1
    assert t_hi > 0  # non-degenerate train split on the fixture


# ----------------------------------------------------------------------
# Checkpointing: shared state_dict contract + snapshot cursor
# ----------------------------------------------------------------------
def test_snapshot_trainer_checkpoint_roundtrip(small_stream, tmp_path):
    a = SnapshotLinkTrainer("gclstm", small_stream, snapshot_unit="h",
                            d_embed=16)
    a.train_epoch()
    a.save_checkpoint(str(tmp_path), 1)
    b = SnapshotLinkTrainer("gclstm", small_stream, snapshot_unit="h",
                            d_embed=16)
    b.restore_checkpoint(str(tmp_path))
    assert _tree_equal(a.params, b.params)
    assert a.evaluate("val")[0] == b.evaluate("val")[0]
    assert a.train_epoch()[0] == b.train_epoch()[0]


def test_snapshot_trainer_mid_epoch_cursor_resume(small_stream, tmp_path):
    """A restored mid-epoch snapshot cursor resumes the same stream: chunked
    epoch halves stitched across a checkpoint == one uninterrupted epoch."""
    full = SnapshotLinkTrainer("tgcn", small_stream, snapshot_unit="h",
                               d_embed=16, seed=1)
    half = SnapshotLinkTrainer("tgcn", small_stream, snapshot_unit="h",
                               d_embed=16, seed=1, chunk_size=4)
    loss_full, _ = full.train_epoch()

    # run the first chunks manually by aborting mid-epoch via chunk loop
    lo, hi = half._split_pairs("train")
    mid = lo + (hi - lo) // 2
    half.chunk_size = mid - lo
    half.reset_epoch_state()
    xs = half._pair_xs(lo, mid, half.num_negatives)
    (half.params, half.opt_state, half.model_state), ls1 = half._train_scan(
        half.params, half.opt_state, half.model_state, xs)
    half._cursor = mid
    half.save_checkpoint(str(tmp_path), 7)

    resumed = SnapshotLinkTrainer("tgcn", small_stream, snapshot_unit="h",
                                  d_embed=16, seed=1)
    step = resumed.restore_checkpoint(str(tmp_path))
    assert step == 7 and resumed._cursor == mid
    loss_resumed, _ = resumed.train_epoch()  # finishes pairs [mid, hi)
    assert _tree_equal(full.params, resumed.params)
    assert resumed._cursor == 0  # epoch completed, cursor rewound
    # the two halves reconstruct the uninterrupted epoch's mean loss
    first = [float(l) for l in np.asarray(ls1)]
    n_rest = hi - mid
    combined = (np.sum(first) + loss_resumed * n_rest) / (len(first) + n_rest)
    np.testing.assert_allclose(combined, loss_full, rtol=1e-6)


def test_legacy_run_epoch_shim(small_stream):
    tr = SnapshotLinkTrainer("gcn", small_stream, snapshot_unit="h",
                             d_embed=16)
    loss, _ = tr.run_epoch(train=True)
    assert np.isfinite(loss)
    mrr, _ = tr.run_epoch(train=False)
    assert 0.0 <= mrr <= 1.0


def test_dtdg_recipe_negative_hooks(small_stream):
    """The DTDG recipe's hook draws match the bulk scan draws per row."""
    from repro.core.batch import Batch

    m = RecipeRegistry.build(RECIPE_DTDG_SNAPSHOT, num_nodes=50, capacity=8,
                             num_negatives=3, eval_negatives=5, seed=9)
    bulk = np.asarray(snapshot_negatives(9, 50, 8, 3, np.arange(6)))
    with m.activate(TRAIN_KEY):
        for row in range(6):
            b = Batch({"src": np.zeros(8, np.int64),
                       "dst": np.zeros(8, np.int64),
                       "time": np.zeros(8, np.int64)},
                      meta={"snapshot_row": row})
            out = m.execute(b)
            np.testing.assert_array_equal(np.asarray(out["neg"]), bulk[row])
    # cursor state is checkpointable
    sd = m.state_dict()
    assert any("SnapshotNegativeHook" in k for k in sd)


# ----------------------------------------------------------------------
# segment_reduce routing in the GCN layer
# ----------------------------------------------------------------------
def test_gcn_layer_segment_reduce_parity():
    """gcn_layer routed through kernels/segment_reduce == direct jnp math
    (the CPU reference path), and the Pallas kernel agrees in interpret
    mode on the same shapes."""
    import jax.numpy as jnp

    from repro.kernels.segment_reduce import segment_sum_kernel, segment_sum_ref
    from repro.nn.graph_conv import gcn_layer, gcn_layer_init
    from repro.nn.linear import dense

    key = jax.random.PRNGKey(0)
    n, e, d_in, d_out = 24, 64, 8, 4
    p = gcn_layer_init(key, d_in, d_out)
    x = jax.random.normal(key, (n, d_in))
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    mask = jnp.asarray(rng.random(e) > 0.25)

    out = gcn_layer(p, x, src, dst, mask, n)

    w = mask.astype(x.dtype)
    deg = (jax.ops.segment_sum(w, src, n)
           + jax.ops.segment_sum(w, dst, n) + 1.0)
    dinv = jax.lax.rsqrt(deg)
    h = dense(p["lin"], x)
    coeff = (dinv[src] * dinv[dst] * w)[:, None]
    agg = (jax.ops.segment_sum(coeff * h[dst], src, n)
           + jax.ops.segment_sum(coeff * h[src], dst, n))
    ref = agg + dinv[:, None] ** 2 * h
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    data = coeff * h[dst]
    kern = segment_sum_kernel(data, src, n, block_e=32, interpret=True)
    np.testing.assert_allclose(np.asarray(kern),
                               np.asarray(segment_sum_ref(data, src, n)),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# Satellite: uniform sampler hop-2 recursive frontier
# ----------------------------------------------------------------------
@pytest.mark.parametrize("device_sampling", [False, True])
def test_uniform_hop2_contract(device_sampling):
    """Hop-2 uniform draws are strictly before their hop-1 seed's time, and
    padded hop-1 slots come back fully masked."""
    from repro.core.batch import Batch
    from repro.core.tg_hooks import (
        DeviceUniformNeighborHook,
        UniformNeighborHook,
    )

    rng = np.random.default_rng(0)
    n_nodes, E = 30, 400
    src = rng.integers(0, n_nodes, E)
    dst = rng.integers(0, n_nodes, E)
    t = np.sort(rng.integers(0, 1000, E))
    cls = DeviceUniformNeighborHook if device_sampling else UniformNeighborHook
    hook = cls(n_nodes, k=4, include_negatives=False, seed=0, num_hops=2)
    hook.build(src, dst, t, np.arange(E, dtype=np.int64))

    b = Batch({"src": src[300:320], "dst": dst[300:320],
               "time": t[300:320]})
    out = hook(b)
    for attr in ("nbr2_ids", "nbr2_times", "nbr2_eids", "nbr2_mask"):
        assert attr in out
    ids1 = np.asarray(out["nbr_ids"]).reshape(-1)
    t1 = np.asarray(out["nbr_times"]).reshape(-1)
    ids2 = np.asarray(out["nbr2_ids"])
    t2 = np.asarray(out["nbr2_times"])
    m2 = np.asarray(out["nbr2_mask"])
    assert ids2.shape == (len(ids1), 4)
    # padded hop-1 rows are fully masked at hop 2
    assert not m2[ids1 < 0].any()
    # strict temporal causality: hop-2 times < hop-1 interaction time
    rows = np.flatnonzero((ids1 >= 0))
    for r in rows:
        assert (t2[r][m2[r]] < t1[r]).all()
        assert (ids2[r][m2[r]] >= 0).all()


def test_uniform_hop2_tgat_end_to_end(small_stream):
    """2-layer TGAT + sampler='uniform' trains (used to raise)."""
    tr = LinkPredictionTrainer("tgat", small_stream, batch_size=48, k=3,
                               eval_negatives=5, sampler="uniform",
                               model_kwargs={"num_layers": 2})
    loss, _ = tr.train_epoch()
    assert np.isfinite(loss)
    mrr, _ = tr.evaluate("val")
    assert 0.0 <= mrr <= 1.0


# ----------------------------------------------------------------------
# Satellite: counter-only uniform checkpoints
# ----------------------------------------------------------------------
def test_uniform_counter_only_checkpoint():
    """checkpoint_adjacency=False drops the O(E) CSR; rebuilding from
    storage on load reproduces the exact draw stream."""
    from repro.core.device_uniform import DeviceUniformSampler
    from repro.core.sampler import UniformSampler

    rng = np.random.default_rng(0)
    src, dst = rng.integers(0, 40, 200), rng.integers(0, 40, 200)
    t = np.sort(rng.integers(0, 500, 200))
    seeds, qt = np.arange(10), np.full(10, 400)

    for cls in (UniformSampler, DeviceUniformSampler):
        full = cls(40, 4, seed=5)
        lean = cls(40, 4, seed=5, checkpoint_adjacency=False)
        for s in (full, lean):
            s.build(src, dst, t)
            s.sample(seeds, qt)
        assert set(lean.state_dict()) == {"counter"}
        assert {"adj_nbr", "indptr"} <= set(full.state_dict())
        # rebuild-from-storage restore: next draws match the full sampler
        restored = cls(40, 4, seed=5)
        restored.build(src, dst, t)
        restored.load_state_dict(lean.state_dict())
        a, b = full.sample(seeds, qt), restored.sample(seeds, qt)
        np.testing.assert_array_equal(np.asarray(a.nbr_ids),
                                      np.asarray(b.nbr_ids))


def test_uniform_counter_only_trainer_checkpoint(small_stream, tmp_path):
    """Trainer-level: counter-only uniform checkpoints restore into a fresh
    trainer (which rebuilds the adjacency from storage) bit-identically."""
    kw = dict(batch_size=48, k=4, eval_negatives=5, sampler="uniform",
              model_kwargs={"num_layers": 1},
              uniform_checkpoint_adjacency=False)
    a = LinkPredictionTrainer("tgat", small_stream, **kw)
    a.train_epoch()
    path = a.save_checkpoint(str(tmp_path), 2)
    # the checkpoint carries no adjacency leaves
    import os
    leaf_names = os.listdir(path)
    assert not any("adj_nbr" in n for n in leaf_names)
    b = LinkPredictionTrainer("tgat", small_stream, **kw)
    b.restore_checkpoint(str(tmp_path))
    assert a.evaluate("val")[0] == b.evaluate("val")[0]
    # cross-flag interchange: a counter-only checkpoint restores into a
    # trainer built with the default full-adjacency checkpointing too
    kw_full = dict(kw, uniform_checkpoint_adjacency=True)
    c = LinkPredictionTrainer("tgat", small_stream, **kw_full)
    c.restore_checkpoint(str(tmp_path))
    assert a.evaluate("val")[0] == c.evaluate("val")[0]
