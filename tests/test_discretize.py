import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    DGData,
    TimeDelta,
    discretize,
    discretize_edges_padded,
    discretize_jax,
    discretize_naive,
)

REDUCTIONS = ["first", "last", "sum", "mean", "max", "count"]


def _mk(n, n_nodes, t_hi, seed=0, feat_dim=3):
    rng = np.random.default_rng(seed)
    return DGData.from_arrays(
        rng.integers(0, n_nodes, n),
        rng.integers(0, n_nodes, n),
        rng.integers(0, t_hi, n),
        edge_feats=rng.standard_normal((n, feat_dim)).astype(np.float32),
        granularity="s",
    )


def _key_set(d):
    return set(zip(d.edge_t.tolist(), d.src.tolist(), d.dst.tolist()))


def _aligned(a, b):
    oa = np.lexsort((a.dst, a.src, a.edge_t))
    ob = np.lexsort((b.dst, b.src, b.edge_t))
    return a.edge_feats[oa], b.edge_feats[ob]


@pytest.mark.parametrize("reduce", REDUCTIONS)
def test_vectorized_matches_naive(reduce):
    d = _mk(500, 15, 10_000)
    a = discretize(d, TimeDelta("h"), reduce=reduce)
    b = discretize_naive(d, TimeDelta("h"), reduce=reduce)
    assert _key_set(a) == _key_set(b)
    fa, fb = _aligned(a, b)
    np.testing.assert_allclose(fa, fb, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("reduce", ["first", "sum", "count"])
def test_jax_backend_matches_naive(reduce):
    d = _mk(300, 10, 5000)
    a = discretize_jax(d, TimeDelta("h"), reduce=reduce)
    b = discretize_naive(d, TimeDelta("h"), reduce=reduce)
    assert _key_set(a) == _key_set(b)
    fa, fb = _aligned(a, b)
    np.testing.assert_allclose(fa, fb, rtol=1e-4, atol=1e-4)


def test_coarser_granularity_fewer_events():
    d = _mk(2000, 10, 100_000)
    hourly = discretize(d, TimeDelta("h"))
    daily = discretize(d, TimeDelta("d"))
    assert daily.num_edge_events <= hourly.num_edge_events <= d.num_edge_events
    assert daily.granularity == TimeDelta("d")


def test_timestamps_are_coarse_ticks():
    d = _mk(200, 8, 7200)
    h = discretize(d, TimeDelta("h"))
    assert h.edge_t.max() <= 2  # 7200s -> at most 3 hourly buckets


def test_count_appends_multiplicity():
    d = _mk(400, 5, 1000, feat_dim=2)
    c = discretize(d, TimeDelta("h"), reduce="count")
    assert c.edge_feat_dim == 3  # 2 features + count
    assert c.edge_feats[:, -1].sum() == d.num_edge_events


def test_event_ordered_rejected():
    d = DGData.from_arrays([0], [1], [0], granularity=TimeDelta.event())
    with pytest.raises(TypeError):
        discretize(d, TimeDelta("h"))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 120),
    n_nodes=st.integers(1, 12),
    t_hi=st.integers(1, 20_000),
    seed=st.integers(0, 10_000),
    reduce=st.sampled_from(REDUCTIONS),
)
def test_property_vectorized_equals_naive(n, n_nodes, t_hi, seed, reduce):
    """System invariant: psi_r vectorized == dict-based oracle, any input."""
    d = _mk(n, n_nodes, t_hi, seed=seed)
    a = discretize(d, TimeDelta("m"), reduce=reduce)
    b = discretize_naive(d, TimeDelta("m"), reduce=reduce)
    assert _key_set(a) == _key_set(b)
    fa, fb = _aligned(a, b)
    np.testing.assert_allclose(fa, fb, rtol=1e-4, atol=1e-4)


def _run_padded(d, k, reduce, capacity=None):
    """Invoke the jitted padded core on a DGData's edge arrays."""
    import jax.numpy as jnp

    e = d.num_edge_events
    cap = capacity or e
    feats = (jnp.zeros((e, 0), jnp.float32) if d.edge_feats is None
             else jnp.asarray(d.edge_feats))
    return discretize_edges_padded(
        jnp.asarray(d.src), jnp.asarray(d.dst), jnp.asarray(d.edge_t), feats,
        k=k, reduce=reduce, capacity=cap, feat_dim=d.edge_feat_dim,
    )


@pytest.mark.parametrize("reduce", REDUCTIONS)
def test_jit_padded_core_matches_host(reduce):
    """The jittable fixed-capacity core == host numpy discretize: same
    classes (tick-major sorted), same reduced features, correct valid
    count, zero/sentinel padding beyond it."""
    d = _mk(400, 12, 8000, seed=4)
    k = 3600
    usrc, udst, uct, feats, count = _run_padded(d, k, reduce)
    ref = discretize(d, TimeDelta("h"), reduce=reduce)
    g = int(count)
    assert g == ref.num_edge_events
    order = np.lexsort((ref.dst, ref.src, ref.edge_t))
    np.testing.assert_array_equal(np.asarray(usrc)[:g], ref.src[order])
    np.testing.assert_array_equal(np.asarray(udst)[:g], ref.dst[order])
    np.testing.assert_array_equal(np.asarray(uct)[:g], ref.edge_t[order])
    np.testing.assert_allclose(np.asarray(feats)[:g], ref.edge_feats[order],
                               rtol=1e-5, atol=1e-5)
    # padding invariants: zeros / int32-max sentinel beyond the valid count
    assert (np.asarray(usrc)[g:] == 0).all()
    assert (np.asarray(uct)[g:] == 2**31 - 1).all()
    assert (np.asarray(feats)[g:] == 0).all()


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 100),
    n_nodes=st.integers(1, 10),
    t_hi=st.integers(1, 15_000),
    seed=st.integers(0, 5_000),
    reduce=st.sampled_from(REDUCTIONS),
)
def test_property_jit_padded_equals_host(n, n_nodes, t_hi, seed, reduce):
    """System invariant: jitted padded psi_r == host numpy psi_r, any
    input (the device/host parity behind SnapshotTensor)."""
    d = _mk(n, n_nodes, t_hi, seed=seed)
    usrc, udst, uct, feats, count = _run_padded(d, 60, reduce)
    ref = discretize(d, TimeDelta("m"), reduce=reduce)
    g = int(count)
    assert g == ref.num_edge_events
    order = np.lexsort((ref.dst, ref.src, ref.edge_t))
    np.testing.assert_array_equal(np.asarray(usrc)[:g], ref.src[order])
    np.testing.assert_array_equal(np.asarray(udst)[:g], ref.dst[order])
    np.testing.assert_array_equal(np.asarray(uct)[:g], ref.edge_t[order])
    np.testing.assert_allclose(np.asarray(feats)[:g], ref.edge_feats[order],
                               rtol=1e-4, atol=1e-4)


def test_jax_path_handles_large_node_counts():
    """Graphs with num_nodes > 2**15.5 (where a dense src*n+dst pair key
    would overflow int32) stay on the device path via the three-level
    stable argsort (regression: 46k-node cliff)."""
    rng = np.random.default_rng(1)
    d = DGData.from_arrays(
        rng.integers(0, 100_000, 800), rng.integers(0, 100_000, 800),
        rng.integers(0, 20_000, 800),
        edge_feats=rng.standard_normal((800, 2)).astype(np.float32),
        granularity="s", num_nodes=100_000,
    )
    from repro.core.discretize import jax_discretize_supported

    assert jax_discretize_supported(d, 3600, edges_only=True)
    a = discretize_jax(d, TimeDelta("h"), reduce="sum")
    b = discretize(d, TimeDelta("h"), reduce="sum")
    assert _key_set(a) == _key_set(b)
    fa, fb = _aligned(a, b)
    np.testing.assert_allclose(fa, fb, rtol=1e-5, atol=1e-5)


def test_jax_path_handles_timestamps_beyond_int32():
    """Raw timestamps >= 2**31 must not wrap on the device path: ticks are
    pre-divided on the host when needed (regression: silent int32 wrap)."""
    rng = np.random.default_rng(0)
    t = np.sort(rng.integers(2**31 + 1000, 2**31 + 7_200_000, 200))
    d = DGData.from_arrays(rng.integers(0, 20, 200), rng.integers(0, 20, 200),
                           t, granularity="s")
    a = discretize_jax(d, TimeDelta("h"), reduce="count")
    b = discretize(d, TimeDelta("h"), reduce="count")
    assert _key_set(a) == _key_set(b)
    assert a.edge_t.min() > 0  # no negative wrapped ticks


def test_jax_wrapper_still_matches_naive_all_reductions():
    """discretize_jax (now routed through the jitted core) keeps full
    semantic parity with the dict oracle for every reduction."""
    d = _mk(300, 10, 5000, seed=2)
    for reduce in REDUCTIONS:
        a = discretize_jax(d, TimeDelta("h"), reduce=reduce)
        b = discretize_naive(d, TimeDelta("h"), reduce=reduce)
        assert _key_set(a) == _key_set(b)
        fa, fb = _aligned(a, b)
        np.testing.assert_allclose(fa, fb, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_idempotent_at_same_granularity(seed):
    """Discretizing twice at the same granularity is idempotent."""
    d = _mk(150, 8, 5000, seed=seed)
    once = discretize(d, TimeDelta("h"), reduce="sum")
    twice = discretize(once, TimeDelta("h"), reduce="sum")
    assert _key_set(once) == _key_set(twice)
    fa, fb = _aligned(once, twice)
    np.testing.assert_allclose(fa, fb, rtol=1e-5, atol=1e-5)
