import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DGData, TimeDelta, discretize, discretize_jax, discretize_naive

REDUCTIONS = ["first", "last", "sum", "mean", "max", "count"]


def _mk(n, n_nodes, t_hi, seed=0, feat_dim=3):
    rng = np.random.default_rng(seed)
    return DGData.from_arrays(
        rng.integers(0, n_nodes, n),
        rng.integers(0, n_nodes, n),
        rng.integers(0, t_hi, n),
        edge_feats=rng.standard_normal((n, feat_dim)).astype(np.float32),
        granularity="s",
    )


def _key_set(d):
    return set(zip(d.edge_t.tolist(), d.src.tolist(), d.dst.tolist()))


def _aligned(a, b):
    oa = np.lexsort((a.dst, a.src, a.edge_t))
    ob = np.lexsort((b.dst, b.src, b.edge_t))
    return a.edge_feats[oa], b.edge_feats[ob]


@pytest.mark.parametrize("reduce", REDUCTIONS)
def test_vectorized_matches_naive(reduce):
    d = _mk(500, 15, 10_000)
    a = discretize(d, TimeDelta("h"), reduce=reduce)
    b = discretize_naive(d, TimeDelta("h"), reduce=reduce)
    assert _key_set(a) == _key_set(b)
    fa, fb = _aligned(a, b)
    np.testing.assert_allclose(fa, fb, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("reduce", ["first", "sum", "count"])
def test_jax_backend_matches_naive(reduce):
    d = _mk(300, 10, 5000)
    a = discretize_jax(d, TimeDelta("h"), reduce=reduce)
    b = discretize_naive(d, TimeDelta("h"), reduce=reduce)
    assert _key_set(a) == _key_set(b)
    fa, fb = _aligned(a, b)
    np.testing.assert_allclose(fa, fb, rtol=1e-4, atol=1e-4)


def test_coarser_granularity_fewer_events():
    d = _mk(2000, 10, 100_000)
    hourly = discretize(d, TimeDelta("h"))
    daily = discretize(d, TimeDelta("d"))
    assert daily.num_edge_events <= hourly.num_edge_events <= d.num_edge_events
    assert daily.granularity == TimeDelta("d")


def test_timestamps_are_coarse_ticks():
    d = _mk(200, 8, 7200)
    h = discretize(d, TimeDelta("h"))
    assert h.edge_t.max() <= 2  # 7200s -> at most 3 hourly buckets


def test_count_appends_multiplicity():
    d = _mk(400, 5, 1000, feat_dim=2)
    c = discretize(d, TimeDelta("h"), reduce="count")
    assert c.edge_feat_dim == 3  # 2 features + count
    assert c.edge_feats[:, -1].sum() == d.num_edge_events


def test_event_ordered_rejected():
    d = DGData.from_arrays([0], [1], [0], granularity=TimeDelta.event())
    with pytest.raises(TypeError):
        discretize(d, TimeDelta("h"))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 120),
    n_nodes=st.integers(1, 12),
    t_hi=st.integers(1, 20_000),
    seed=st.integers(0, 10_000),
    reduce=st.sampled_from(REDUCTIONS),
)
def test_property_vectorized_equals_naive(n, n_nodes, t_hi, seed, reduce):
    """System invariant: psi_r vectorized == dict-based oracle, any input."""
    d = _mk(n, n_nodes, t_hi, seed=seed)
    a = discretize(d, TimeDelta("m"), reduce=reduce)
    b = discretize_naive(d, TimeDelta("m"), reduce=reduce)
    assert _key_set(a) == _key_set(b)
    fa, fb = _aligned(a, b)
    np.testing.assert_allclose(fa, fb, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_idempotent_at_same_granularity(seed):
    """Discretizing twice at the same granularity is idempotent."""
    d = _mk(150, 8, 5000, seed=seed)
    once = discretize(d, TimeDelta("h"), reduce="sum")
    twice = discretize(once, TimeDelta("h"), reduce="sum")
    assert _key_set(once) == _key_set(twice)
    fa, fb = _aligned(once, twice)
    np.testing.assert_allclose(fa, fb, rtol=1e-5, atol=1e-5)
