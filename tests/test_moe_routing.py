"""MoE routing correctness: the group-local gather dispatch must equal a
naive per-token dense reference when capacity is dropless."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.lm.layers import moe_block, moe_specs
from repro.models.lm.params import materialize


def _naive_moe(p, cfg, x):
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, ids = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gate = gate / gate.sum(-1, keepdims=True)
    # dense: compute every expert for every token, select
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["wg"]))
    h = h * jnp.einsum("td,edf->tef", xt, p["wi"])
    out_all = jnp.einsum("tef,efd->ted", h, p["wo"])  # (T, E, d)
    sel = jnp.take_along_axis(out_all, ids[..., None], axis=1)  # (T, K, d)
    y = (sel * gate[..., None]).sum(1)
    if cfg.num_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(xt @ sp["wg"]) * (xt @ sp["wi"])) @ sp["wo"]
    return y.reshape(B, S, d)


@pytest.mark.parametrize("arch", ["dbrx-132b", "qwen2-moe-a2.7b"])
def test_group_local_dispatch_matches_dense(arch):
    cfg = dataclasses.replace(get_arch(arch).reduced(), capacity_factor=1e3)
    p = materialize(moe_specs(cfg), jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model)) * 0.5
    got, aux = moe_block(p, cfg, x)
    want = _naive_moe(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


def test_capacity_drops_tokens():
    cfg = dataclasses.replace(get_arch("dbrx-132b").reduced(),
                              capacity_factor=0.1)
    p = materialize(moe_specs(cfg), jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model))
    got, _ = moe_block(p, cfg, x)
    want = _naive_moe(p, cfg, x)
    # with tight capacity, outputs differ (tokens were dropped) but stay finite
    assert np.isfinite(np.asarray(got, np.float32)).all()
    assert float(jnp.abs(got - want).max()) > 1e-4
