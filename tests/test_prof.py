import time

import pytest

from repro.utils import Profiler


def _make_profiler():
    with pytest.warns(DeprecationWarning, match="repro.obs.Telemetry"):
        return Profiler()


def test_profiler_accumulates_and_reports():
    p = _make_profiler()
    for _ in range(3):
        with p("outer"):
            with p("inner"):
                time.sleep(0.002)
    assert p.counts["outer"] == 3
    assert p.counts["outer.inner"] == 3
    assert p.times["outer"] >= p.times["outer.inner"] > 0
    rep = p.report(min_pct=0.0)
    assert "outer" in rep and "inner" in rep
    p.reset()
    assert p.total() == 0.0


def test_profiler_shim_emits_span_records():
    # The shim is a Telemetry front: sections land as span records with
    # dotted paths in its private sink.
    p = _make_profiler()
    with p("a"):
        with p("b"):
            pass
    paths = [r["path"] for r in p._sink.records if r["kind"] == "span"]
    assert paths == ["a.b", "a"]
