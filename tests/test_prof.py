import time

from repro.utils import Profiler


def test_profiler_accumulates_and_reports():
    p = Profiler()
    for _ in range(3):
        with p("outer"):
            with p("inner"):
                time.sleep(0.002)
    assert p.counts["outer"] == 3
    assert p.counts["outer.inner"] == 3
    assert p.times["outer"] >= p.times["outer.inner"] > 0
    rep = p.report(min_pct=0.0)
    assert "outer" in rep and "inner" in rep
    p.reset()
    assert p.total() == 0.0
