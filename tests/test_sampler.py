import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import RecencySampler, SequentialRecencySampler, UniformSampler


def _assert_same(a, b):
    np.testing.assert_array_equal(a.nbr_ids, b.nbr_ids)
    np.testing.assert_array_equal(a.nbr_times, b.nbr_times)
    np.testing.assert_array_equal(a.nbr_eids, b.nbr_eids)
    np.testing.assert_array_equal(a.mask, b.mask)


def test_recency_most_recent_first():
    s = RecencySampler(10, k=3)
    s.update(np.array([0, 0, 0]), np.array([1, 2, 3]), np.array([1, 2, 3]))
    blk = s.sample(np.array([0]))
    np.testing.assert_array_equal(blk.nbr_ids[0], [3, 2, 1])
    np.testing.assert_array_equal(blk.nbr_times[0], [3, 2, 1])


def test_recency_wraparound():
    s = RecencySampler(10, k=2)
    s.update(np.array([0] * 5), np.arange(1, 6), np.arange(5))
    blk = s.sample(np.array([0]))
    np.testing.assert_array_equal(blk.nbr_ids[0], [5, 4])  # only last K kept


def test_undirected_insertion():
    s = RecencySampler(10, k=4)
    s.update(np.array([0]), np.array([1]), np.array([7]))
    blk = s.sample(np.array([1]))
    assert blk.nbr_ids[0, 0] == 0  # dst got src as neighbor


def test_state_dict_roundtrip():
    s = RecencySampler(10, k=3)
    s.update(np.array([0, 1]), np.array([2, 3]), np.array([1, 2]))
    state = s.state_dict()
    s2 = RecencySampler(10, k=3)
    s2.load_state_dict(state)
    _assert_same(s.sample(np.arange(10)), s2.sample(np.arange(10)))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    k=st.integers(1, 7),
    n_nodes=st.integers(2, 30),
    n_batches=st.integers(1, 8),
)
def test_property_vectorized_equals_sequential(seed, k, n_nodes, n_batches):
    """The paper's vectorized circular-buffer updates must be
    indistinguishable from sequential event insertion."""
    rng = np.random.default_rng(seed)
    fast = RecencySampler(n_nodes, k)
    slow = SequentialRecencySampler(n_nodes, k)
    t0 = 0
    for _ in range(n_batches):
        B = int(rng.integers(1, 20))
        src = rng.integers(0, n_nodes, B)
        dst = rng.integers(0, n_nodes, B)
        t = np.sort(rng.integers(t0, t0 + 50, B))
        t0 += 50
        eids = rng.integers(0, 10_000, B)
        fast.update(src, dst, t, eids)
        slow.update(src, dst, t, eids)
        seeds = rng.integers(0, n_nodes, 13)
        _assert_same(fast.sample(seeds), slow.sample(seeds))


def test_uniform_sampler_temporal_constraint():
    s = UniformSampler(10, k=8, seed=0)
    src = np.array([0, 0, 0])
    dst = np.array([1, 2, 3])
    t = np.array([10, 20, 30])
    s.build(src, dst, t)
    blk = s.sample(np.array([0]), np.array([25]))
    valid = blk.nbr_ids[0][blk.mask[0]]
    assert set(valid.tolist()) <= {1, 2}  # node 3 is in the future
    assert (blk.nbr_times[0][blk.mask[0]] < 25).all()


def test_uniform_sampler_no_history():
    s = UniformSampler(10, k=4, seed=0)
    s.build(np.array([0]), np.array([1]), np.array([100]))
    blk = s.sample(np.array([5]), np.array([50]))
    assert not blk.mask.any()
