import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    DeviceRecencySampler,
    DeviceUniformSampler,
    RecencySampler,
    SequentialRecencySampler,
    UniformSampler,
)


def _assert_same(a, b):
    np.testing.assert_array_equal(a.nbr_ids, b.nbr_ids)
    np.testing.assert_array_equal(a.nbr_times, b.nbr_times)
    np.testing.assert_array_equal(a.nbr_eids, b.nbr_eids)
    np.testing.assert_array_equal(a.mask, b.mask)


def _assert_same_np(a, b):
    """Like _assert_same but coerces device arrays to host first."""
    np.testing.assert_array_equal(np.asarray(a.nbr_ids), np.asarray(b.nbr_ids))
    np.testing.assert_array_equal(np.asarray(a.nbr_times), np.asarray(b.nbr_times))
    np.testing.assert_array_equal(np.asarray(a.nbr_eids), np.asarray(b.nbr_eids))
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))


def test_recency_most_recent_first():
    s = RecencySampler(10, k=3)
    s.update(np.array([0, 0, 0]), np.array([1, 2, 3]), np.array([1, 2, 3]))
    blk = s.sample(np.array([0]))
    np.testing.assert_array_equal(blk.nbr_ids[0], [3, 2, 1])
    np.testing.assert_array_equal(blk.nbr_times[0], [3, 2, 1])


def test_recency_wraparound():
    s = RecencySampler(10, k=2)
    s.update(np.array([0] * 5), np.arange(1, 6), np.arange(5))
    blk = s.sample(np.array([0]))
    np.testing.assert_array_equal(blk.nbr_ids[0], [5, 4])  # only last K kept


def test_undirected_insertion():
    s = RecencySampler(10, k=4)
    s.update(np.array([0]), np.array([1]), np.array([7]))
    blk = s.sample(np.array([1]))
    assert blk.nbr_ids[0, 0] == 0  # dst got src as neighbor


def test_state_dict_roundtrip():
    s = RecencySampler(10, k=3)
    s.update(np.array([0, 1]), np.array([2, 3]), np.array([1, 2]))
    state = s.state_dict()
    s2 = RecencySampler(10, k=3)
    s2.load_state_dict(state)
    _assert_same(s.sample(np.arange(10)), s2.sample(np.arange(10)))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    k=st.integers(1, 7),
    n_nodes=st.integers(2, 30),
    n_batches=st.integers(1, 8),
)
def test_property_vectorized_equals_sequential(seed, k, n_nodes, n_batches):
    """The paper's vectorized circular-buffer updates must be
    indistinguishable from sequential event insertion."""
    rng = np.random.default_rng(seed)
    fast = RecencySampler(n_nodes, k)
    slow = SequentialRecencySampler(n_nodes, k)
    t0 = 0
    for _ in range(n_batches):
        B = int(rng.integers(1, 20))
        src = rng.integers(0, n_nodes, B)
        dst = rng.integers(0, n_nodes, B)
        t = np.sort(rng.integers(t0, t0 + 50, B))
        t0 += 50
        eids = rng.integers(0, 10_000, B)
        fast.update(src, dst, t, eids)
        slow.update(src, dst, t, eids)
        seeds = rng.integers(0, n_nodes, 13)
        _assert_same(fast.sample(seeds), slow.sample(seeds))


@pytest.mark.parametrize("cls", [RecencySampler, DeviceRecencySampler])
def test_recency_wraparound_single_batch_overflow(cls):
    """One batch carrying more than K events for a node must leave exactly
    the last K visible, with the cursor advanced by the full multiplicity
    (sequential semantics)."""
    k = 3
    fast, slow = cls(6, k), SequentialRecencySampler(6, k)
    # node 0 gets 8 events in ONE update call (8 > 2*k)
    src = np.zeros(8, dtype=np.int64)
    dst = np.array([1, 2, 3, 4, 5, 1, 2, 3], dtype=np.int64)
    t = np.arange(8, dtype=np.int64)
    eids = np.arange(100, 108, dtype=np.int64)
    fast.update(src, dst, t, eids)
    slow.update(src, dst, t, eids)
    a, b = fast.sample(np.arange(6)), slow.sample(np.arange(6))
    _assert_same_np(a, b)
    # subsequent inserts must continue from the advanced cursor
    fast.update(np.array([0]), np.array([5]), np.array([9]))
    slow.update(np.array([0]), np.array([5]), np.array([9]))
    _assert_same_np(fast.sample(np.arange(6)), slow.sample(np.arange(6)))


@pytest.mark.parametrize("cls", [RecencySampler, DeviceRecencySampler])
def test_recency_duplicate_timestamps_batch_equivalence(cls):
    """Equal timestamps within a batch must not reorder insertions: batch
    updates are indistinguishable from sequential insertion."""
    rng = np.random.default_rng(7)
    k = 4
    fast, slow = cls(10, k), SequentialRecencySampler(10, k)
    for _ in range(6):
        B = 15
        src = rng.integers(0, 10, B)
        dst = rng.integers(0, 10, B)
        t = np.full(B, 42)  # all duplicates
        eids = rng.integers(0, 1000, B)
        fast.update(src, dst, t, eids)
        slow.update(src, dst, t, eids)
        _assert_same_np(fast.sample(np.arange(10)), slow.sample(np.arange(10)))


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    k=st.integers(1, 7),
    n_nodes=st.integers(2, 30),
    n_batches=st.integers(1, 6),
)
def test_property_device_equals_sequential(seed, k, n_nodes, n_batches):
    """DeviceRecencySampler must be bit-identical to sequential insertion on
    randomized event streams (wraparound + duplicate timestamps included)."""
    rng = np.random.default_rng(seed)
    fast = DeviceRecencySampler(n_nodes, k)
    slow = SequentialRecencySampler(n_nodes, k)
    t0 = 0
    for _ in range(n_batches):
        B = int(rng.integers(1, 20))
        src = rng.integers(0, n_nodes, B)
        dst = rng.integers(0, n_nodes, B)
        t = np.sort(rng.integers(t0, t0 + 10, B))  # duplicates likely
        t0 += 10
        eids = rng.integers(0, 10_000, B)
        fast.update(src, dst, t, eids)
        slow.update(src, dst, t, eids)
        seeds = rng.integers(0, n_nodes, 13)
        _assert_same_np(fast.sample(seeds), slow.sample(seeds))


def test_device_padded_update_matches_unpadded():
    """Fixed-shape padded updates (valid mask) must equal exact-size ones."""
    rng = np.random.default_rng(5)
    a, b = DeviceRecencySampler(8, 3), DeviceRecencySampler(8, 3)
    src = rng.integers(0, 8, 10)
    dst = rng.integers(0, 8, 10)
    t = np.sort(rng.integers(0, 50, 10))
    a.update(src, dst, t)
    pad = 6
    b.update(np.concatenate([src, np.zeros(pad, np.int64)]),
             np.concatenate([dst, np.zeros(pad, np.int64)]),
             np.concatenate([t, np.zeros(pad, np.int64)]),
             valid=np.concatenate([np.ones(10, bool), np.zeros(pad, bool)]))
    _assert_same_np(a.sample(np.arange(8)), b.sample(np.arange(8)))


def test_device_state_dict_interchangeable_with_host():
    """Checkpoint contract: device state restores into the host sampler and
    vice versa, preserving sample outputs exactly."""
    rng = np.random.default_rng(11)
    dev = DeviceRecencySampler(12, 4)
    src = rng.integers(0, 12, 30)
    dst = rng.integers(0, 12, 30)
    t = np.sort(rng.integers(0, 90, 30))
    dev.update(src, dst, t, rng.integers(0, 100, 30))
    state = dev.state_dict()

    host = RecencySampler(12, 4)
    host.load_state_dict(state)
    _assert_same_np(dev.sample(np.arange(12)), host.sample(np.arange(12)))

    dev2 = DeviceRecencySampler(12, 4)
    dev2.load_state_dict(host.state_dict())
    _assert_same_np(dev.sample(np.arange(12)), dev2.sample(np.arange(12)))


def test_uniform_sampler_temporal_constraint():
    s = UniformSampler(10, k=8, seed=0)
    src = np.array([0, 0, 0])
    dst = np.array([1, 2, 3])
    t = np.array([10, 20, 30])
    s.build(src, dst, t)
    blk = s.sample(np.array([0]), np.array([25]))
    valid = blk.nbr_ids[0][blk.mask[0]]
    assert set(valid.tolist()) <= {1, 2}  # node 3 is in the future
    assert (blk.nbr_times[0][blk.mask[0]] < 25).all()


def test_uniform_sampler_no_history():
    s = UniformSampler(10, k=4, seed=0)
    s.build(np.array([0]), np.array([1]), np.array([100]))
    blk = s.sample(np.array([5]), np.array([50]))
    assert not blk.mask.any()


def _uniform_candidates(s: UniformSampler, seed: int, qt: int):
    """The host sampler's ground-truth candidate multiset for one query:
    all (id, time, eid) adjacency entries of ``seed`` with t < qt."""
    lo, hi = s._indptr[seed], s._indptr[seed + 1]
    sel = slice(lo, hi)
    keep = s._adj_t[sel] < qt
    return set(zip(s._adj_nbr[sel][keep].tolist(),
                   s._adj_t[sel][keep].tolist(),
                   s._adj_e[sel][keep].tolist()))


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_nodes=st.integers(2, 25),
    n_events=st.integers(1, 120),
    k=st.integers(1, 6),
)
def test_property_device_uniform_parity_with_host(seed, n_nodes, n_events, k):
    """Device CSR + composite-key search must agree with the host path on
    randomized streams: identical valid-prefix masks, and every drawn
    neighbor a member of the host's strict-past candidate set — including
    duplicate timestamps, nodes with < K past neighbors, empty prefixes."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_events)
    dst = rng.integers(0, n_nodes, n_events)
    t = np.sort(rng.integers(0, 30, n_events))  # duplicate timestamps likely
    eids = np.arange(n_events, dtype=np.int64)

    host = UniformSampler(n_nodes, k, seed=1)
    host.build(src, dst, t, eids)
    dev = DeviceUniformSampler(n_nodes, k, seed=1)
    dev.build(src, dst, t, eids)

    seeds = rng.integers(0, n_nodes, 17)
    qt = rng.integers(0, 40, 17)
    hb = host.sample(seeds, qt)
    db = dev.sample(seeds, qt)
    np.testing.assert_array_equal(np.asarray(db.mask), hb.mask)
    for i in range(len(seeds)):
        cands = _uniform_candidates(host, int(seeds[i]), int(qt[i]))
        if not cands:
            assert not np.asarray(db.mask)[i].any()
            continue
        got = set(zip(np.asarray(db.nbr_ids)[i].tolist(),
                      np.asarray(db.nbr_times)[i].tolist(),
                      np.asarray(db.nbr_eids)[i].tolist()))
        assert got <= cands
        assert (np.asarray(db.nbr_times)[i] < qt[i]).all()


def test_device_uniform_adjacency_matches_host_csr():
    """The segment-op CSR build must produce exactly the host lexsort CSR
    (same node-major/time-ascending layout, same indptr)."""
    rng = np.random.default_rng(3)
    N, E = 20, 200
    src = rng.integers(0, N, E)
    dst = rng.integers(0, N, E)
    t = np.sort(rng.integers(0, 50, E))
    host = UniformSampler(N, 4)
    host.build(src, dst, t)
    dev = DeviceUniformSampler(N, 4)
    dev.build(src, dst, t)
    adj = {k2: np.asarray(v) for k2, v in dev._adj.items()}
    np.testing.assert_array_equal(adj["indptr"], host._indptr)
    np.testing.assert_array_equal(adj["adj_t"], host._adj_t)
    # Within exact (node, time) ties host lexsort and the device stable
    # argsort both keep stream order, so ids/eids must match exactly too.
    np.testing.assert_array_equal(adj["adj_nbr"], host._adj_nbr)
    np.testing.assert_array_equal(adj["adj_e"], host._adj_e)


def test_uniform_state_dict_roundtrip_and_interchange():
    """Checkpoint contract: device state restores into the host uniform
    sampler and vice versa; the draw counter round-trips so a restored run
    continues the same reproducible draw sequence."""
    rng = np.random.default_rng(9)
    N, E, k = 15, 80, 3
    src = rng.integers(0, N, E)
    dst = rng.integers(0, N, E)
    t = np.sort(rng.integers(0, 40, E))

    dev = DeviceUniformSampler(N, k, seed=5)
    dev.build(src, dst, t)
    seeds = rng.integers(0, N, 9)
    qt = rng.integers(10, 50, 9)
    dev.sample(seeds, qt)  # advance the counter
    state = dev.state_dict()
    assert int(state["counter"]) == 1

    # device -> device: identical continuation
    dev2 = DeviceUniformSampler(N, k, seed=5)
    dev2.load_state_dict(state)
    a, b = dev.sample(seeds, qt), dev2.sample(seeds, qt)
    _assert_same_np(a, b)

    # device -> host: same adjacency, valid draws, same counter
    host = UniformSampler(N, k, seed=5)
    host.load_state_dict(state)
    np.testing.assert_array_equal(host._indptr, np.asarray(dev._adj["indptr"]))
    hb = host.sample(seeds, qt)
    np.testing.assert_array_equal(hb.mask, np.asarray(a.mask))

    # host -> device round-trip preserves the adjacency bit-for-bit
    dev3 = DeviceUniformSampler(N, k, seed=5)
    dev3.load_state_dict(host.state_dict())
    np.testing.assert_array_equal(np.asarray(dev3._adj["adj_key"]),
                                  np.asarray(dev._adj["adj_key"]))


def test_uniform_reset_state_replays_draws():
    """Counter-derived RNG: reset_state must replay the epoch exactly, for
    both the host and device samplers."""
    rng = np.random.default_rng(2)
    N, E, k = 12, 60, 4
    src, dst = rng.integers(0, N, E), rng.integers(0, N, E)
    t = np.sort(rng.integers(0, 30, E))
    for cls in (UniformSampler, DeviceUniformSampler):
        s = cls(N, k, seed=3)
        s.build(src, dst, t)
        seeds, qt = rng.integers(0, N, 8), rng.integers(5, 35, 8)
        first = [s.sample(seeds, qt) for _ in range(3)]
        s.reset_state()
        second = [s.sample(seeds, qt) for _ in range(3)]
        for a, b in zip(first, second):
            _assert_same_np(a, b)


def test_device_uniform_requires_build():
    s = DeviceUniformSampler(5, 2)
    with pytest.raises(RuntimeError, match="build"):
        s.sample(np.array([0]), np.array([10]))


def test_uniform_sampler_global_searchsorted_matches_per_seed_loop():
    """The vectorized (node, time-rank) composite-key search must count
    exactly the neighbors a per-seed binary search would."""
    rng = np.random.default_rng(3)
    N, E, B = 40, 500, 64
    src = rng.integers(0, N, E)
    dst = rng.integers(0, N, E)
    t = np.sort(rng.integers(0, 100, E))  # duplicate timestamps guaranteed
    s = UniformSampler(N, k=8, seed=1)
    s.build(src, dst, t)
    seeds = rng.integers(0, N, B)
    query_t = rng.integers(0, 120, B)

    starts, ends = s._indptr[seeds], s._indptr[seeds + 1]
    want = np.array([
        starts[i] + np.searchsorted(s._adj_t[starts[i]:ends[i]],
                                    query_t[i], side="left")
        for i in range(B)
    ])
    qranks = np.searchsorted(s._tvals, query_t, side="left")
    got = np.searchsorted(s._adj_key, seeds * s._key_base + qranks,
                          side="left")
    np.testing.assert_array_equal(got, want)

    blk = s.sample(seeds, query_t)
    for i in range(B):
        if blk.mask[i].any():
            assert (blk.nbr_times[i][blk.mask[i]] < query_t[i]).all()


def test_uniform_sample_dedups_duplicate_query_keys():
    """Batch-level dedup of duplicate (seed, query_t) pairs — the hop-2
    frontier / one-vs-many shape — is bit-identical to the direct search:
    valid counts match the per-seed loop, and duplicated rows keep
    independent (per-row) draws."""
    rng = np.random.default_rng(7)
    N, E = 30, 400
    src = rng.integers(0, N, E)
    dst = rng.integers(0, N, E)
    t = np.sort(rng.integers(0, 80, E))
    s = UniformSampler(N, k=6, seed=2)
    s.build(src, dst, t)

    # Heavily duplicated batch: every (seed, t) pair appears many times.
    base_seeds = rng.integers(0, N, 8)
    base_t = rng.integers(1, 90, 8)
    seeds = np.repeat(base_seeds, 16)
    query_t = np.repeat(base_t, 16)

    blk = s.sample(seeds, query_t)

    # Valid-candidate sets match a per-seed binary search exactly.
    starts, ends = s._indptr[seeds], s._indptr[seeds + 1]
    for i in range(len(seeds)):
        n_valid = int(np.searchsorted(s._adj_t[starts[i]:ends[i]],
                                      query_t[i], side="left"))
        assert blk.mask[i].all() == (n_valid > 0) and blk.mask[i].any() == (n_valid > 0)
        if n_valid:
            assert (blk.nbr_times[i][blk.mask[i]] < query_t[i]).all()

    # Draws are per-row (duplicates are NOT forced to share neighbors):
    # with 6 draws from a multi-candidate past, 16 duplicate rows almost
    # surely differ somewhere.
    s2 = UniformSampler(N, k=6, seed=2)
    s2.build(src, dst, t)
    blk2 = s2.sample(seeds, query_t)
    _assert_same_np(blk, blk2)  # deterministic per (seed, counter)
    rich = [i for i in range(0, len(seeds), 16)
            if (s._indptr[seeds[i] + 1] - s._indptr[seeds[i]]) > 4
            and blk.mask[i].any()]
    if rich:
        i = rich[0]
        rows = blk.nbr_eids[i:i + 16]
        assert not (rows == rows[0]).all()
