import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    DeviceRecencySampler,
    RecencySampler,
    SequentialRecencySampler,
    UniformSampler,
)


def _assert_same(a, b):
    np.testing.assert_array_equal(a.nbr_ids, b.nbr_ids)
    np.testing.assert_array_equal(a.nbr_times, b.nbr_times)
    np.testing.assert_array_equal(a.nbr_eids, b.nbr_eids)
    np.testing.assert_array_equal(a.mask, b.mask)


def _assert_same_np(a, b):
    """Like _assert_same but coerces device arrays to host first."""
    np.testing.assert_array_equal(np.asarray(a.nbr_ids), np.asarray(b.nbr_ids))
    np.testing.assert_array_equal(np.asarray(a.nbr_times), np.asarray(b.nbr_times))
    np.testing.assert_array_equal(np.asarray(a.nbr_eids), np.asarray(b.nbr_eids))
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))


def test_recency_most_recent_first():
    s = RecencySampler(10, k=3)
    s.update(np.array([0, 0, 0]), np.array([1, 2, 3]), np.array([1, 2, 3]))
    blk = s.sample(np.array([0]))
    np.testing.assert_array_equal(blk.nbr_ids[0], [3, 2, 1])
    np.testing.assert_array_equal(blk.nbr_times[0], [3, 2, 1])


def test_recency_wraparound():
    s = RecencySampler(10, k=2)
    s.update(np.array([0] * 5), np.arange(1, 6), np.arange(5))
    blk = s.sample(np.array([0]))
    np.testing.assert_array_equal(blk.nbr_ids[0], [5, 4])  # only last K kept


def test_undirected_insertion():
    s = RecencySampler(10, k=4)
    s.update(np.array([0]), np.array([1]), np.array([7]))
    blk = s.sample(np.array([1]))
    assert blk.nbr_ids[0, 0] == 0  # dst got src as neighbor


def test_state_dict_roundtrip():
    s = RecencySampler(10, k=3)
    s.update(np.array([0, 1]), np.array([2, 3]), np.array([1, 2]))
    state = s.state_dict()
    s2 = RecencySampler(10, k=3)
    s2.load_state_dict(state)
    _assert_same(s.sample(np.arange(10)), s2.sample(np.arange(10)))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    k=st.integers(1, 7),
    n_nodes=st.integers(2, 30),
    n_batches=st.integers(1, 8),
)
def test_property_vectorized_equals_sequential(seed, k, n_nodes, n_batches):
    """The paper's vectorized circular-buffer updates must be
    indistinguishable from sequential event insertion."""
    rng = np.random.default_rng(seed)
    fast = RecencySampler(n_nodes, k)
    slow = SequentialRecencySampler(n_nodes, k)
    t0 = 0
    for _ in range(n_batches):
        B = int(rng.integers(1, 20))
        src = rng.integers(0, n_nodes, B)
        dst = rng.integers(0, n_nodes, B)
        t = np.sort(rng.integers(t0, t0 + 50, B))
        t0 += 50
        eids = rng.integers(0, 10_000, B)
        fast.update(src, dst, t, eids)
        slow.update(src, dst, t, eids)
        seeds = rng.integers(0, n_nodes, 13)
        _assert_same(fast.sample(seeds), slow.sample(seeds))


@pytest.mark.parametrize("cls", [RecencySampler, DeviceRecencySampler])
def test_recency_wraparound_single_batch_overflow(cls):
    """One batch carrying more than K events for a node must leave exactly
    the last K visible, with the cursor advanced by the full multiplicity
    (sequential semantics)."""
    k = 3
    fast, slow = cls(6, k), SequentialRecencySampler(6, k)
    # node 0 gets 8 events in ONE update call (8 > 2*k)
    src = np.zeros(8, dtype=np.int64)
    dst = np.array([1, 2, 3, 4, 5, 1, 2, 3], dtype=np.int64)
    t = np.arange(8, dtype=np.int64)
    eids = np.arange(100, 108, dtype=np.int64)
    fast.update(src, dst, t, eids)
    slow.update(src, dst, t, eids)
    a, b = fast.sample(np.arange(6)), slow.sample(np.arange(6))
    _assert_same_np(a, b)
    # subsequent inserts must continue from the advanced cursor
    fast.update(np.array([0]), np.array([5]), np.array([9]))
    slow.update(np.array([0]), np.array([5]), np.array([9]))
    _assert_same_np(fast.sample(np.arange(6)), slow.sample(np.arange(6)))


@pytest.mark.parametrize("cls", [RecencySampler, DeviceRecencySampler])
def test_recency_duplicate_timestamps_batch_equivalence(cls):
    """Equal timestamps within a batch must not reorder insertions: batch
    updates are indistinguishable from sequential insertion."""
    rng = np.random.default_rng(7)
    k = 4
    fast, slow = cls(10, k), SequentialRecencySampler(10, k)
    for _ in range(6):
        B = 15
        src = rng.integers(0, 10, B)
        dst = rng.integers(0, 10, B)
        t = np.full(B, 42)  # all duplicates
        eids = rng.integers(0, 1000, B)
        fast.update(src, dst, t, eids)
        slow.update(src, dst, t, eids)
        _assert_same_np(fast.sample(np.arange(10)), slow.sample(np.arange(10)))


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    k=st.integers(1, 7),
    n_nodes=st.integers(2, 30),
    n_batches=st.integers(1, 6),
)
def test_property_device_equals_sequential(seed, k, n_nodes, n_batches):
    """DeviceRecencySampler must be bit-identical to sequential insertion on
    randomized event streams (wraparound + duplicate timestamps included)."""
    rng = np.random.default_rng(seed)
    fast = DeviceRecencySampler(n_nodes, k)
    slow = SequentialRecencySampler(n_nodes, k)
    t0 = 0
    for _ in range(n_batches):
        B = int(rng.integers(1, 20))
        src = rng.integers(0, n_nodes, B)
        dst = rng.integers(0, n_nodes, B)
        t = np.sort(rng.integers(t0, t0 + 10, B))  # duplicates likely
        t0 += 10
        eids = rng.integers(0, 10_000, B)
        fast.update(src, dst, t, eids)
        slow.update(src, dst, t, eids)
        seeds = rng.integers(0, n_nodes, 13)
        _assert_same_np(fast.sample(seeds), slow.sample(seeds))


def test_device_padded_update_matches_unpadded():
    """Fixed-shape padded updates (valid mask) must equal exact-size ones."""
    rng = np.random.default_rng(5)
    a, b = DeviceRecencySampler(8, 3), DeviceRecencySampler(8, 3)
    src = rng.integers(0, 8, 10)
    dst = rng.integers(0, 8, 10)
    t = np.sort(rng.integers(0, 50, 10))
    a.update(src, dst, t)
    pad = 6
    b.update(np.concatenate([src, np.zeros(pad, np.int64)]),
             np.concatenate([dst, np.zeros(pad, np.int64)]),
             np.concatenate([t, np.zeros(pad, np.int64)]),
             valid=np.concatenate([np.ones(10, bool), np.zeros(pad, bool)]))
    _assert_same_np(a.sample(np.arange(8)), b.sample(np.arange(8)))


def test_device_state_dict_interchangeable_with_host():
    """Checkpoint contract: device state restores into the host sampler and
    vice versa, preserving sample outputs exactly."""
    rng = np.random.default_rng(11)
    dev = DeviceRecencySampler(12, 4)
    src = rng.integers(0, 12, 30)
    dst = rng.integers(0, 12, 30)
    t = np.sort(rng.integers(0, 90, 30))
    dev.update(src, dst, t, rng.integers(0, 100, 30))
    state = dev.state_dict()

    host = RecencySampler(12, 4)
    host.load_state_dict(state)
    _assert_same_np(dev.sample(np.arange(12)), host.sample(np.arange(12)))

    dev2 = DeviceRecencySampler(12, 4)
    dev2.load_state_dict(host.state_dict())
    _assert_same_np(dev.sample(np.arange(12)), dev2.sample(np.arange(12)))


def test_uniform_sampler_temporal_constraint():
    s = UniformSampler(10, k=8, seed=0)
    src = np.array([0, 0, 0])
    dst = np.array([1, 2, 3])
    t = np.array([10, 20, 30])
    s.build(src, dst, t)
    blk = s.sample(np.array([0]), np.array([25]))
    valid = blk.nbr_ids[0][blk.mask[0]]
    assert set(valid.tolist()) <= {1, 2}  # node 3 is in the future
    assert (blk.nbr_times[0][blk.mask[0]] < 25).all()


def test_uniform_sampler_no_history():
    s = UniformSampler(10, k=4, seed=0)
    s.build(np.array([0]), np.array([1]), np.array([100]))
    blk = s.sample(np.array([5]), np.array([50]))
    assert not blk.mask.any()


def test_uniform_sampler_global_searchsorted_matches_per_seed_loop():
    """The vectorized (node, time-rank) composite-key search must count
    exactly the neighbors a per-seed binary search would."""
    rng = np.random.default_rng(3)
    N, E, B = 40, 500, 64
    src = rng.integers(0, N, E)
    dst = rng.integers(0, N, E)
    t = np.sort(rng.integers(0, 100, E))  # duplicate timestamps guaranteed
    s = UniformSampler(N, k=8, seed=1)
    s.build(src, dst, t)
    seeds = rng.integers(0, N, B)
    query_t = rng.integers(0, 120, B)

    starts, ends = s._indptr[seeds], s._indptr[seeds + 1]
    want = np.array([
        starts[i] + np.searchsorted(s._adj_t[starts[i]:ends[i]],
                                    query_t[i], side="left")
        for i in range(B)
    ])
    qranks = np.searchsorted(s._tvals, query_t, side="left")
    got = np.searchsorted(s._adj_key, seeds * s._key_base + qranks,
                          side="left")
    np.testing.assert_array_equal(got, want)

    blk = s.sample(seeds, query_t)
    for i in range(B):
        if blk.mask[i].any():
            assert (blk.nbr_times[i][blk.mask[i]] < query_t[i]).all()
