"""Out-of-core event storage (``repro.storage``): backend parity between
``InMemoryStore`` and ``MmapStore`` (range queries, windowed iteration,
end-to-end CTDG training), the streaming two-pass CSR build against the
in-RAM oracle, the converters' torn-store/unsorted-stream guards, and the
``iter_windows`` resume cursor round-tripping through the checkpoint
layer."""

import os

import numpy as np
import pytest

from repro.core import DGData
from repro.core.sampler import UniformSampler
from repro.storage import (
    EventStore,
    InMemoryStore,
    MmapStore,
    StoreEventLoader,
    streaming_csr,
)


def _mk_data(n=500, num_nodes=60, d_edge=4, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_nodes, n)
    dst = rng.integers(0, num_nodes, n)
    t = np.sort(rng.integers(0, 10_000, n))
    feats = rng.standard_normal((n, d_edge)).astype(np.float32)
    return DGData.from_arrays(src, dst, t, edge_feats=feats, granularity="s")


@pytest.fixture()
def both_stores(tmp_path):
    data = _mk_data()
    mem = InMemoryStore.from_data(data)
    mm = MmapStore.from_data(str(tmp_path / "store"), data, chunk_rows=97)
    return data, mem, mm


# -- backend parity ----------------------------------------------------


def test_backend_columns_bit_identical(both_stores):
    data, mem, mm = both_stores
    for col in ("src", "dst", "edge_t"):
        np.testing.assert_array_equal(getattr(mem, col), getattr(mm, col))
        assert getattr(mm, col).dtype == getattr(mem, col).dtype
    np.testing.assert_array_equal(mem.edge_feats, mm.edge_feats)
    assert mem.num_nodes == mm.num_nodes == data.num_nodes
    assert mem.edge_feat_dim == mm.edge_feat_dim == 4
    assert mem.time_span == mm.time_span


def test_backend_range_queries_identical(both_stores):
    data, mem, mm = both_stores
    t_lo, t_hi = mem.time_span
    probes = [(None, None), (t_lo, t_hi), (t_lo + 7, t_hi - 7),
              (t_hi + 1, t_hi + 2), (None, (t_lo + t_hi) // 2)]
    for a, b in probes:
        assert mem.edge_range(a, b) == mm.edge_range(a, b)
        assert mem.edge_range(a, b) == data.edge_range(a, b)
        assert mem.node_event_range(a, b) == mm.node_event_range(a, b)


def test_windowed_iteration_identical(both_stores):
    _, mem, mm = both_stores
    for kw in ({"batch_size": 123}, {"time_window": 1777}):
        w1 = list(mem.iter_windows(**kw))
        w2 = list(mm.iter_windows(**kw))
        assert len(w1) == len(w2) > 1
        for a, b in zip(w1, w2):
            assert (a.lo, a.hi, a.window) == (b.lo, b.hi, b.window)
            np.testing.assert_array_equal(a.src, b.src)
            np.testing.assert_array_equal(a.t, b.t)
            np.testing.assert_array_equal(a.eids, b.eids)


def test_mmap_release_keeps_columns_readable(both_stores):
    _, mem, mm = both_stores
    before = mm.src[:10].copy()
    mm.release()  # MADV_DONTNEED; pages fault back in on next touch
    np.testing.assert_array_equal(mm.src[:10], before)
    np.testing.assert_array_equal(np.asarray(mm.dst), np.asarray(mem.dst))


# -- windows: bounds, resume, checkpoint round-trip --------------------


def test_edge_window_bounds_raise(both_stores):
    _, mem, mm = both_stores
    for store in (mem, mm):
        with pytest.raises(ValueError):
            store.edge_window(10, 5)
        with pytest.raises(ValueError):
            store.edge_window(-1, 5)
        with pytest.raises(ValueError):
            store.edge_window(0, store.num_edge_events + 1)
        empty = store.edge_window(7, 7)
        assert len(empty) == 0 and empty.eids.dtype == np.int64


def test_iter_windows_argument_validation(both_stores):
    _, mem, _ = both_stores
    with pytest.raises(ValueError):
        mem.iter_windows()
    with pytest.raises(ValueError):
        mem.iter_windows(batch_size=10, time_window=10)
    with pytest.raises(ValueError):
        mem.iter_windows(batch_size=0)


def test_resume_cursor_roundtrips_through_checkpoint(both_stores, tmp_path):
    """Stop mid-epoch, checkpoint the cursor with the distributed
    checkpoint layer, restore into a fresh iterator: the replayed windows
    match an uninterrupted epoch's tail bit-for-bit."""
    from repro.distributed import checkpoint as ckpt

    _, _, mm = both_stores
    full = list(mm.iter_windows(batch_size=77))

    it = mm.iter_windows(batch_size=77)
    seen = []
    for w in it:
        seen.append(w)
        if len(seen) == 3:
            break
    ckpt.save(str(tmp_path / "ck"), 0, it.state_dict())

    state, _, _ = ckpt.restore(str(tmp_path / "ck"))
    resumed = list(mm.iter_windows(
        batch_size=77, start={k: int(v) for k, v in state.items()}))
    tail = full[3:]
    assert len(resumed) == len(tail)
    for a, b in zip(resumed, tail):
        assert (a.lo, a.hi) == (b.lo, b.hi)
        np.testing.assert_array_equal(a.src, b.src)
        np.testing.assert_array_equal(a.eids, b.eids)


def test_time_window_resume(both_stores):
    _, mem, _ = both_stores
    full = list(mem.iter_windows(time_window=911))
    wi = mem.iter_windows(time_window=911)
    gen = iter(wi)
    next(gen)
    next(gen)
    # cursor state reflects what the generator has already yielded
    state = wi.state_dict()
    resumed = list(mem.iter_windows(time_window=911, start=state))
    assert [(w.lo, w.hi) for w in resumed] == [(w.lo, w.hi) for w in full[2:]]


# -- converters: guards and CSV ----------------------------------------


def test_from_chunks_rejects_unsorted(tmp_path):
    chunks = [
        {"src": np.array([1, 2]), "dst": np.array([3, 4]),
         "t": np.array([10, 5])},
    ]
    with pytest.raises(ValueError, match="time-sorted"):
        MmapStore.from_chunks(str(tmp_path / "bad"), iter(chunks))
    assert not os.path.exists(str(tmp_path / "bad"))  # no torn publish

    across = [
        {"src": np.array([1]), "dst": np.array([2]), "t": np.array([10])},
        {"src": np.array([3]), "dst": np.array([4]), "t": np.array([5])},
    ]
    with pytest.raises(ValueError, match="time-sorted"):
        MmapStore.from_chunks(str(tmp_path / "bad2"), iter(across))


def test_torn_store_detected(tmp_path, both_stores):
    data, _, _ = both_stores
    path = str(tmp_path / "torn")
    MmapStore.from_data(path, data)
    assert MmapStore.is_intact(path)
    with open(os.path.join(path, "src.npy"), "r+b") as f:
        f.truncate(os.path.getsize(os.path.join(path, "src.npy")) - 8)
    assert not MmapStore.is_intact(path)
    with pytest.raises(ValueError):
        MmapStore(path)


def test_from_csv_matches_dgdata_from_csv(tmp_path):
    rng = np.random.default_rng(3)
    n = 257
    src = rng.integers(0, 40, n)
    dst = rng.integers(0, 40, n)
    t = np.sort(rng.integers(0, 5000, n))
    lines = ["src,dst,t,f0,f1"]
    for i in range(n):
        lines.append(f"{src[i]},{dst[i]},{t[i]},{i * 0.5},{-i * 0.25}")
    p = tmp_path / "edges.csv"
    p.write_text("\n".join(lines) + "\n")

    d = DGData.from_csv(str(p), feat_cols=[3, 4], chunk_rows=61)
    store = MmapStore.from_csv(str(tmp_path / "csvstore"), str(p),
                               feat_cols=[3, 4], chunk_rows=61)
    np.testing.assert_array_equal(d.src, store.src)
    np.testing.assert_array_equal(d.dst, store.dst)
    np.testing.assert_array_equal(d.edge_t, store.edge_t)
    np.testing.assert_array_equal(d.edge_feats, store.edge_feats)
    assert store.src.dtype == np.int64  # exact ids end-to-end


def test_csv_int64_exactness(tmp_path):
    """Ids/timestamps above 2**53 survive the chunked parse exactly (the
    old float64 genfromtxt path would round them)."""
    big = 2**60 + 1
    p = tmp_path / "big.csv"
    p.write_text(f"src,dst,t\n{big},1,{big}\n{big + 2},1,{big + 2}\n")
    d = DGData.from_csv(str(p))
    assert int(d.src[0]) == big and int(d.edge_t[1]) == big + 2


# -- DGData <-> store --------------------------------------------------


def test_dgdata_from_store_zero_copy(both_stores):
    data, mem, mm = both_stores
    d1, d2 = DGData.from_store(mem), mm.to_data()
    assert d1.src is mem.src  # alias, not a copy
    assert isinstance(d2.src, np.memmap)
    np.testing.assert_array_equal(d1.src, d2.src)
    np.testing.assert_array_equal(d1.edge_feats, d2.edge_feats)
    assert d1.num_nodes == d2.num_nodes == data.num_nodes
    assert d1.granularity == d2.granularity == data.granularity
    # views/splits work off the memmap-backed columns
    tr, va, te = d2.split(0.15, 0.15)
    assert tr.num_edge_events + va.num_edge_events + te.num_edge_events \
        == data.num_edge_events
    assert te.eid_offset == tr.num_edge_events + va.num_edge_events


def test_to_store_roundtrip(both_stores):
    data, _, _ = both_stores
    store = data.to_store()
    assert isinstance(store, EventStore)
    assert store.src is data.src


# -- slice_events hardening --------------------------------------------


def test_slice_events_bounds(both_stores):
    data, _, _ = both_stores
    with pytest.raises(ValueError):
        data.slice_events(5, 4)
    with pytest.raises(ValueError):
        data.slice_events(-1, 4)
    with pytest.raises(ValueError):
        data.slice_events(0, data.num_edge_events + 1)
    empty = data.slice_events(7, 7)  # empty window is legal
    assert empty.num_edge_events == 0
    assert empty.eid_offset == 7


# -- streaming CSR vs in-RAM build -------------------------------------


def test_streaming_csr_matches_host_build(both_stores):
    data, mem, mm = both_stores
    eids = np.arange(data.num_edge_events, dtype=np.int64)
    ref = UniformSampler(data.num_nodes, k=4, seed=0)
    ref.build(data.src, data.dst, data.edge_t, eids)
    for store in (mem, mm):
        s = UniformSampler(data.num_nodes, k=4, seed=0)
        s.build_from_store(store, chunk_size=89)
        a, b = ref.state_dict(), s.state_dict()
        assert a.keys() == b.keys()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


def test_streaming_csr_scratch_dir(both_stores, tmp_path):
    _, _, mm = both_stores
    in_ram = streaming_csr(mm, chunk_size=101)
    on_disk = streaming_csr(mm, chunk_size=101,
                            scratch_dir=str(tmp_path / "scratch"))
    for k in in_ram:
        np.testing.assert_array_equal(np.asarray(in_ram[k]),
                                      np.asarray(on_disk[k]))


def test_device_uniform_build_from_store(both_stores):
    from repro.core.device_uniform import DeviceUniformSampler

    data, _, mm = both_stores
    eids = np.arange(data.num_edge_events, dtype=np.int64)
    ref = DeviceUniformSampler(data.num_nodes, k=4, seed=0)
    ref.build(data.src, data.dst, data.edge_t, eids)
    s = DeviceUniformSampler(data.num_nodes, k=4, seed=0)
    s.build_from_store(mm, chunk_size=73)
    q = np.array([1, 5, 9], dtype=np.int32)
    qt = np.array([8000, 9000, 9999], dtype=np.int32)
    b1, b2 = ref.sample(q, qt), s.sample(q, qt)
    np.testing.assert_array_equal(np.asarray(b1.nbr_ids),
                                  np.asarray(b2.nbr_ids))
    np.testing.assert_array_equal(np.asarray(b1.nbr_times),
                                  np.asarray(b2.nbr_times))
    np.testing.assert_array_equal(np.asarray(b1.mask), np.asarray(b2.mask))


# -- loader integration ------------------------------------------------


def test_store_event_loader_feeds_prefetch(both_stores):
    from repro.core.loader import PrefetchLoader

    _, mem, mm = both_stores
    plain = [(b["src"], b.meta["eids"]) for b in
             StoreEventLoader(mem, batch_size=150)]
    pref = PrefetchLoader(StoreEventLoader(mm, batch_size=150, release=True))
    fetched = [(b["src"], b.meta["eids"]) for b in pref]
    assert len(plain) == len(fetched) == 4
    for (s1, e1), (s2, e2) in zip(plain, fetched):
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


def test_dgdataloader_on_batch_called(both_stores):
    from repro.core import DGraph
    from repro.core.loader import DGDataLoader

    data, _, _ = both_stores
    calls = []
    loader = DGDataLoader(DGraph(data), batch_size=100,
                          on_batch=lambda: calls.append(1))
    n = sum(1 for _ in loader)
    assert len(calls) == n > 0


# -- end-to-end CTDG parity --------------------------------------------


@pytest.mark.parametrize("kind", ["uniform", "recency"])
def test_e2e_ctdg_link_backend_parity(kind, small_stream, tmp_path):
    """One CTDG link epoch + eval off each backend: loss and MRR are
    bit-identical between ``InMemoryStore`` and ``MmapStore``."""
    from repro.tg import (
        DataSpec, Experiment, ModelSpec, SamplerSpec, TrainSpec,
    )

    path = str(tmp_path / "store")
    MmapStore.from_data(path, small_stream)
    exp = Experiment(
        data=DataSpec("tiny", storage=None),
        model=ModelSpec("graphmixer"),
        sampler=SamplerSpec(kind=kind, k=4),
        train=TrainSpec(batch_size=150, eval_negatives=5, seed=0),
    )

    def run(store):
        pipe = exp.compile(store)
        assert isinstance(pipe.data.src, np.ndarray)
        loss, _ = pipe.train_epoch()
        mrr, _ = pipe.evaluate("val")
        return loss, mrr

    l_mem, m_mem = run(small_stream.to_store())
    l_mm, m_mm = run(MmapStore(path))
    assert l_mem == l_mm
    assert m_mem == m_mm


def test_experiment_dataspec_storage_roundtrip(small_stream, tmp_path):
    from repro.tg import DataSpec, Experiment

    path = str(tmp_path / "store")
    MmapStore.from_data(path, small_stream)
    exp = Experiment(data=DataSpec(storage=path))
    again = Experiment.from_json(exp.to_json())
    assert again.data.storage == path
    stream = again._dataset()
    assert isinstance(stream.src, np.memmap)
    assert stream.num_edge_events == small_stream.num_edge_events


def test_dtdg_discretize_off_memmap(small_stream, tmp_path):
    """The DTDG discretization path runs off memmap-backed columns and
    matches the in-RAM stream exactly."""
    from repro.core import TimeDelta
    from repro.core.discretize import discretize

    path = str(tmp_path / "store")
    store = MmapStore.from_data(path, small_stream)
    a = discretize(small_stream, TimeDelta("h"))
    b = discretize(store.to_data(), TimeDelta("h"))
    np.testing.assert_array_equal(np.asarray(a.src), np.asarray(b.src))
    np.testing.assert_array_equal(np.asarray(a.edge_t), np.asarray(b.edge_t))
    if a.edge_feats is not None:
        np.testing.assert_array_equal(np.asarray(a.edge_feats),
                                      np.asarray(b.edge_feats))
