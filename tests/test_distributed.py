"""Multi-device tests (shard_map DP trainer, sharding rules, mini dry-run,
elastic restore, sampler checkpoint resharding). These need >1 device, so
each runs in a subprocess with ``--xla_force_host_platform_device_count``
set before jax initializes (``tests/_forced_topology.py``).
"""

from tests._forced_topology import run_forced as _run


def test_sharding_rules_divisibility():
    out = _run("""
    import jax
    from repro.distributed.sharding import logical_spec
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    # divisible -> sharded; non-divisible -> dropped; missing axis -> dropped
    s1 = logical_spec(("batch", "mlp"), mesh=mesh, shape=(8, 16))
    s2 = logical_spec(("batch", "mlp"), mesh=mesh, shape=(8, 5))
    s3 = logical_spec(("batch", None), mesh=mesh, shape=(3, 5))
    print(s1, "|", s2, "|", s3)
    """)
    assert "'data', 'model'" in out.replace('"', "'") or "data" in out
    parts = out.strip().split("|")
    assert "model" not in parts[1]
    assert "data" not in parts[2]


def test_dp_trainer_matches_single_device():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.dp_trainer import DataParallelTrainer
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    mesh = jax.make_mesh((4,), ("data",))
    D = 8
    def loss_fn(params, state, batch):
        h = batch["x"] @ params["w"]
        return ((h - 1.0) ** 2).mean(), (state, None)

    params = {"w": jnp.eye(D)}
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 16, D)), jnp.float32)

    tr = DataParallelTrainer(loss_fn, mesh, AdamWConfig(lr=1e-2))
    opt, err = tr.init(params)
    tr.build_step(stateful=False)
    err = {} if err is None else err
    p_dp, *_rest = tr._step(params, opt, err, {}, {"x": x})

    # single-device reference: same global batch, plain AdamW
    opt_ref = adamw_init(params)
    g = jax.grad(lambda p: ((x[0] @ p["w"] - 1.0) ** 2).mean())(params)
    p_ref, _ = adamw_update(params, g, opt_ref, AdamWConfig(lr=1e-2))
    np.testing.assert_allclose(np.asarray(p_dp["w"]), np.asarray(p_ref["w"]),
                               rtol=1e-5, atol=1e-5)
    print("MATCH")
    """, devices=4)
    assert "MATCH" in out


def test_int8_error_feedback_tracks_uncompressed():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.dp_trainer import DataParallelTrainer
    from repro.optim import AdamWConfig
    mesh = jax.make_mesh((4,), ("data",))
    D = 8
    def loss_fn(params, state, batch):
        return ((batch["x"] @ params["w"] - 1.0) ** 2).mean(), (state, None)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 16, D)), jnp.float32)
    finals = {}
    for scheme in ("none", "int8_ef"):
        params = {"w": jnp.eye(D)}
        tr = DataParallelTrainer(loss_fn, mesh, AdamWConfig(lr=1e-2),
                                 compression=scheme)
        opt, err = tr.init(params)
        tr.build_step(stateful=False)
        err = {} if err is None else err
        loss = None
        for _ in range(30):
            params, opt, err, _st, loss = tr._step(params, opt, err, {}, {"x": x})
        finals[scheme] = float(loss)
    print("LOSSES", finals)
    assert finals["int8_ef"] < 1.2 * finals["none"] + 1e-3
    """, devices=4)
    assert "LOSSES" in out


def test_mini_dryrun_on_debug_mesh():
    """End-to-end dry-run machinery on an 8-device mesh with a reduced arch."""
    out = _run("""
    import dataclasses, jax
    from repro.configs import get_arch
    from repro.configs.base import ShapeConfig
    from repro.distributed.sharding import sharding_context, DEFAULT_RULES
    from repro.launch.specs import step_and_args
    from repro.launch import hlo_analysis

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                              scan_layers=True, remat=True,
                              param_dtype="bfloat16", compute_dtype="bfloat16")
    for shape in [ShapeConfig("t", 64, 8, "train"),
                  ShapeConfig("p", 64, 8, "prefill"),
                  ShapeConfig("d", 64, 8, "decode")]:
        with sharding_context(mesh, DEFAULT_RULES):
            step, args, _ = step_and_args(cfg, shape, mesh, kv_block=32)
            with mesh:
                compiled = jax.jit(step).lower(*args).compile()
        r = hlo_analysis.analyze(compiled, mesh.size)
        assert r.flops_per_device > 0
        print(shape.kind, "ok", r.dominant)
    """, devices=8)
    assert out.count("ok") == 3


def test_sampler_checkpoint_reshard_1_to_8_and_back(tmp_path):
    """Sampler/hook state saved on a 1-device mesh must restore onto an
    8-device mesh (and the reverse) through the real checkpoint machinery,
    with bit-identical subsequent sample draws (docs/sharding.md)."""
    out = _run(f"""
    import numpy as np
    from repro.core import DeviceRecencySampler, DeviceUniformSampler
    from repro.distributed import checkpoint as ckpt
    from repro.distributed.sharding import make_node_mesh

    rng = np.random.default_rng(0)
    N, k, E = 29, 4, 250
    src, dst = rng.integers(0, N, E), rng.integers(0, N, E)
    t = np.sort(rng.integers(0, 70, E))

    def warm_recency(s):
        for i in range(4):
            sl = slice(i * 40, (i + 1) * 40)
            s.update(src[sl], dst[sl], t[sl])

    for save_shards, load_shards in ((1, 8), (8, 1)):
        a = DeviceRecencySampler(N, k, mesh=make_node_mesh(save_shards))
        warm_recency(a)
        u = DeviceUniformSampler(N, k, seed=3,
                                 mesh=make_node_mesh(save_shards))
        u.build(src, dst, t)
        u.sample(rng.integers(0, N, 9), rng.integers(5, 80, 9))
        d = r"{tmp_path}" + f"/re_{{save_shards}}to{{load_shards}}"
        ckpt.save(d, 0, {{"recency": a.state_dict(),
                          "uniform": u.state_dict()}})

        b = DeviceRecencySampler(N, k, mesh=make_node_mesh(load_shards))
        v = DeviceUniformSampler(N, k, seed=3,
                                 mesh=make_node_mesh(load_shards))
        tree, _, _ = ckpt.restore(d, target=None)
        rec = {{kk.split("/", 1)[1]: vv for kk, vv in tree.items()
               if kk.startswith("recency/")}}
        uni = {{kk.split("/", 1)[1]: vv for kk, vv in tree.items()
               if kk.startswith("uniform/")}}
        b.load_state_dict(rec)
        v.load_state_dict(uni)

        seeds = rng.integers(0, N, 13)
        qa, qb = a.sample(seeds), b.sample(seeds)
        qt = rng.integers(10, 90, 13)
        # the restored uniform sampler continues the SAME draw counter
        ua, ub = u.sample(seeds, qt), v.sample(seeds, qt)
        for x, y in ((qa, qb), (ua, ub)):
            for f in ("nbr_ids", "nbr_times", "nbr_eids", "mask"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(x, f)), np.asarray(getattr(y, f)))
        print(f"RESHARD {{save_shards}}->{{load_shards}} OK")
    """)
    assert "RESHARD 1->8 OK" in out and "RESHARD 8->1 OK" in out


def test_sharded_pipeline_matches_single_device():
    """CTDGLinkPipeline with SamplerSpec.shards=4 must produce the exact
    same train losses as the unsharded device pipeline (the whole stack:
    recipe mesh plumbing, replicated batch staging, shard_map samplers,
    replicated jitted steps)."""
    out = _run("""
    import numpy as np
    from repro.data import generate
    from repro.tg.specs import SamplerSpec
    from repro.train.loop import CTDGLinkPipeline

    data = generate("tiny").slice_events(0, 300)

    def run(spec):
        p = CTDGLinkPipeline("tgat", data, batch_size=100, seed=0,
                             sampler_spec=spec)
        loss, _ = p.train_epoch()
        return loss

    l0 = run(SamplerSpec(device=True))
    l1 = run(SamplerSpec(device=True, shards=4))
    assert l0 == l1, (l0, l1)
    print("PIPELINE SHARDED OK", l0)
    """, devices=4)
    assert "PIPELINE SHARDED OK" in out


def test_elastic_restore_across_meshes(tmp_path):
    out = _run(f"""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed import checkpoint as ckpt
    from repro.distributed.sharding import logical_sharding

    # save params sharded on a (4, 2) mesh
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    w = jax.device_put(w, logical_sharding(("batch", "mlp"), mesh=mesh_a, shape=w.shape))
    ckpt.save(r"{tmp_path}", 0, {{"w": w}}, logical_axes={{"w": ("batch", "mlp")}})

    # restore onto a DIFFERENT mesh (2, 4): elastic re-shard
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    tree, step, _ = ckpt.restore(r"{tmp_path}", target={{"w": w}}, mesh=mesh_b)
    got = tree["w"]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(w))
    assert got.sharding.mesh.shape["model"] == 4
    print("ELASTIC OK")
    """, devices=8)
    assert "ELASTIC OK" in out
