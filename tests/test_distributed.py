"""Multi-device tests (shard_map DP trainer, sharding rules, mini dry-run,
elastic restore). These need >1 device, so each runs in a subprocess with
``--xla_force_host_platform_device_count`` set before jax initializes.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str, devices: int = 8, timeout: int = 520) -> str:
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(snippet)
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=REPO)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_sharding_rules_divisibility():
    out = _run("""
    import jax
    from repro.distributed.sharding import logical_spec
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    # divisible -> sharded; non-divisible -> dropped; missing axis -> dropped
    s1 = logical_spec(("batch", "mlp"), mesh=mesh, shape=(8, 16))
    s2 = logical_spec(("batch", "mlp"), mesh=mesh, shape=(8, 5))
    s3 = logical_spec(("batch", None), mesh=mesh, shape=(3, 5))
    print(s1, "|", s2, "|", s3)
    """)
    assert "'data', 'model'" in out.replace('"', "'") or "data" in out
    parts = out.strip().split("|")
    assert "model" not in parts[1]
    assert "data" not in parts[2]


def test_dp_trainer_matches_single_device():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.dp_trainer import DataParallelTrainer
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    mesh = jax.make_mesh((4,), ("data",))
    D = 8
    def loss_fn(params, state, batch):
        h = batch["x"] @ params["w"]
        return ((h - 1.0) ** 2).mean(), (state, None)

    params = {"w": jnp.eye(D)}
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 16, D)), jnp.float32)

    tr = DataParallelTrainer(loss_fn, mesh, AdamWConfig(lr=1e-2))
    opt, err = tr.init(params)
    tr.build_step(stateful=False)
    err = {} if err is None else err
    p_dp, *_rest = tr._step(params, opt, err, {}, {"x": x})

    # single-device reference: same global batch, plain AdamW
    opt_ref = adamw_init(params)
    g = jax.grad(lambda p: ((x[0] @ p["w"] - 1.0) ** 2).mean())(params)
    p_ref, _ = adamw_update(params, g, opt_ref, AdamWConfig(lr=1e-2))
    np.testing.assert_allclose(np.asarray(p_dp["w"]), np.asarray(p_ref["w"]),
                               rtol=1e-5, atol=1e-5)
    print("MATCH")
    """, devices=4)
    assert "MATCH" in out


def test_int8_error_feedback_tracks_uncompressed():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.dp_trainer import DataParallelTrainer
    from repro.optim import AdamWConfig
    mesh = jax.make_mesh((4,), ("data",))
    D = 8
    def loss_fn(params, state, batch):
        return ((batch["x"] @ params["w"] - 1.0) ** 2).mean(), (state, None)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 16, D)), jnp.float32)
    finals = {}
    for scheme in ("none", "int8_ef"):
        params = {"w": jnp.eye(D)}
        tr = DataParallelTrainer(loss_fn, mesh, AdamWConfig(lr=1e-2),
                                 compression=scheme)
        opt, err = tr.init(params)
        tr.build_step(stateful=False)
        err = {} if err is None else err
        loss = None
        for _ in range(30):
            params, opt, err, _st, loss = tr._step(params, opt, err, {}, {"x": x})
        finals[scheme] = float(loss)
    print("LOSSES", finals)
    assert finals["int8_ef"] < 1.2 * finals["none"] + 1e-3
    """, devices=4)
    assert "LOSSES" in out


def test_mini_dryrun_on_debug_mesh():
    """End-to-end dry-run machinery on an 8-device mesh with a reduced arch."""
    out = _run("""
    import dataclasses, jax
    from repro.configs import get_arch
    from repro.configs.base import ShapeConfig
    from repro.distributed.sharding import sharding_context, DEFAULT_RULES
    from repro.launch.specs import step_and_args
    from repro.launch import hlo_analysis

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                              scan_layers=True, remat=True,
                              param_dtype="bfloat16", compute_dtype="bfloat16")
    for shape in [ShapeConfig("t", 64, 8, "train"),
                  ShapeConfig("p", 64, 8, "prefill"),
                  ShapeConfig("d", 64, 8, "decode")]:
        with sharding_context(mesh, DEFAULT_RULES):
            step, args, _ = step_and_args(cfg, shape, mesh, kv_block=32)
            with mesh:
                compiled = jax.jit(step).lower(*args).compile()
        r = hlo_analysis.analyze(compiled, mesh.size)
        assert r.flops_per_device > 0
        print(shape.kind, "ok", r.dominant)
    """, devices=8)
    assert out.count("ok") == 3


def test_elastic_restore_across_meshes(tmp_path):
    out = _run(f"""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed import checkpoint as ckpt
    from repro.distributed.sharding import logical_sharding

    # save params sharded on a (4, 2) mesh
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    w = jax.device_put(w, logical_sharding(("batch", "mlp"), mesh=mesh_a, shape=w.shape))
    ckpt.save(r"{tmp_path}", 0, {{"w": w}}, logical_axes={{"w": ("batch", "mlp")}})

    # restore onto a DIFFERENT mesh (2, 4): elastic re-shard
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    tree, step, _ = ckpt.restore(r"{tmp_path}", target={{"w": w}}, mesh=mesh_b)
    got = tree["w"]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(w))
    assert got.sharding.mesh.shape["model"] == 4
    print("ELASTIC OK")
    """, devices=8)
    assert "ELASTIC OK" in out
