"""Multi-device tests (shard_map DP trainer, sharding rules, mini dry-run,
elastic restore, sampler checkpoint resharding). These need >1 device, so
each runs in a subprocess with ``--xla_force_host_platform_device_count``
set before jax initializes (``tests/_forced_topology.py``).
"""

from tests._forced_topology import run_forced as _run


def test_sharding_rules_divisibility():
    out = _run("""
    import jax
    from repro.distributed.sharding import logical_spec
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    # divisible -> sharded; non-divisible -> dropped; missing axis -> dropped
    s1 = logical_spec(("batch", "mlp"), mesh=mesh, shape=(8, 16))
    s2 = logical_spec(("batch", "mlp"), mesh=mesh, shape=(8, 5))
    s3 = logical_spec(("batch", None), mesh=mesh, shape=(3, 5))
    print(s1, "|", s2, "|", s3)
    """)
    assert "'data', 'model'" in out.replace('"', "'") or "data" in out
    parts = out.strip().split("|")
    assert "model" not in parts[1]
    assert "data" not in parts[2]


def test_dp_trainer_matches_single_device():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.dp_trainer import DataParallelTrainer
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    mesh = jax.make_mesh((4,), ("data",))
    D = 8
    def loss_fn(params, state, batch):
        h = batch["x"] @ params["w"]
        return ((h - 1.0) ** 2).mean(), (state, None)

    params = {"w": jnp.eye(D)}
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 16, D)), jnp.float32)

    tr = DataParallelTrainer(loss_fn, mesh, AdamWConfig(lr=1e-2))
    opt, err = tr.init(params)
    tr.build_step(stateful=False)
    err = {} if err is None else err
    p_dp, *_rest = tr._step(params, opt, err, {}, {"x": x})

    # single-device reference: same global batch, plain AdamW
    opt_ref = adamw_init(params)
    g = jax.grad(lambda p: ((x[0] @ p["w"] - 1.0) ** 2).mean())(params)
    p_ref, _ = adamw_update(params, g, opt_ref, AdamWConfig(lr=1e-2))
    np.testing.assert_allclose(np.asarray(p_dp["w"]), np.asarray(p_ref["w"]),
                               rtol=1e-5, atol=1e-5)
    print("MATCH")
    """, devices=4)
    assert "MATCH" in out


def test_int8_error_feedback_tracks_uncompressed():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.dp_trainer import DataParallelTrainer
    from repro.optim import AdamWConfig
    mesh = jax.make_mesh((4,), ("data",))
    D = 8
    def loss_fn(params, state, batch):
        return ((batch["x"] @ params["w"] - 1.0) ** 2).mean(), (state, None)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 16, D)), jnp.float32)
    finals = {}
    for scheme in ("none", "int8_ef"):
        params = {"w": jnp.eye(D)}
        tr = DataParallelTrainer(loss_fn, mesh, AdamWConfig(lr=1e-2),
                                 compression=scheme)
        opt, err = tr.init(params)
        tr.build_step(stateful=False)
        err = {} if err is None else err
        loss = None
        for _ in range(30):
            params, opt, err, _st, loss = tr._step(params, opt, err, {}, {"x": x})
        finals[scheme] = float(loss)
    print("LOSSES", finals)
    assert finals["int8_ef"] < 1.2 * finals["none"] + 1e-3
    """, devices=4)
    assert "LOSSES" in out


def test_mini_dryrun_on_debug_mesh():
    """End-to-end dry-run machinery on an 8-device mesh with a reduced arch."""
    out = _run("""
    import dataclasses, jax
    from repro.configs import get_arch
    from repro.configs.base import ShapeConfig
    from repro.distributed.sharding import sharding_context, DEFAULT_RULES
    from repro.launch.specs import step_and_args
    from repro.launch import hlo_analysis

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                              scan_layers=True, remat=True,
                              param_dtype="bfloat16", compute_dtype="bfloat16")
    for shape in [ShapeConfig("t", 64, 8, "train"),
                  ShapeConfig("p", 64, 8, "prefill"),
                  ShapeConfig("d", 64, 8, "decode")]:
        with sharding_context(mesh, DEFAULT_RULES):
            step, args, _ = step_and_args(cfg, shape, mesh, kv_block=32)
            with mesh:
                compiled = jax.jit(step).lower(*args).compile()
        r = hlo_analysis.analyze(compiled, mesh.size)
        assert r.flops_per_device > 0
        print(shape.kind, "ok", r.dominant)
    """, devices=8)
    assert out.count("ok") == 3


def test_sampler_checkpoint_reshard_1_to_8_and_back(tmp_path):
    """Sampler/hook state saved on a 1-device mesh must restore onto an
    8-device mesh (and the reverse) through the real checkpoint machinery,
    with bit-identical subsequent sample draws (docs/sharding.md)."""
    out = _run(f"""
    import numpy as np
    from repro.core import DeviceRecencySampler, DeviceUniformSampler
    from repro.distributed import checkpoint as ckpt
    from repro.distributed.sharding import make_node_mesh

    rng = np.random.default_rng(0)
    N, k, E = 29, 4, 250
    src, dst = rng.integers(0, N, E), rng.integers(0, N, E)
    t = np.sort(rng.integers(0, 70, E))

    def warm_recency(s):
        for i in range(4):
            sl = slice(i * 40, (i + 1) * 40)
            s.update(src[sl], dst[sl], t[sl])

    for save_shards, load_shards in ((1, 8), (8, 1)):
        a = DeviceRecencySampler(N, k, mesh=make_node_mesh(save_shards))
        warm_recency(a)
        u = DeviceUniformSampler(N, k, seed=3,
                                 mesh=make_node_mesh(save_shards))
        u.build(src, dst, t)
        u.sample(rng.integers(0, N, 9), rng.integers(5, 80, 9))
        d = r"{tmp_path}" + f"/re_{{save_shards}}to{{load_shards}}"
        ckpt.save(d, 0, {{"recency": a.state_dict(),
                          "uniform": u.state_dict()}})

        b = DeviceRecencySampler(N, k, mesh=make_node_mesh(load_shards))
        v = DeviceUniformSampler(N, k, seed=3,
                                 mesh=make_node_mesh(load_shards))
        tree, _, _ = ckpt.restore(d, target=None)
        rec = {{kk.split("/", 1)[1]: vv for kk, vv in tree.items()
               if kk.startswith("recency/")}}
        uni = {{kk.split("/", 1)[1]: vv for kk, vv in tree.items()
               if kk.startswith("uniform/")}}
        b.load_state_dict(rec)
        v.load_state_dict(uni)

        seeds = rng.integers(0, N, 13)
        qa, qb = a.sample(seeds), b.sample(seeds)
        qt = rng.integers(10, 90, 13)
        # the restored uniform sampler continues the SAME draw counter
        ua, ub = u.sample(seeds, qt), v.sample(seeds, qt)
        for x, y in ((qa, qb), (ua, ub)):
            for f in ("nbr_ids", "nbr_times", "nbr_eids", "mask"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(x, f)), np.asarray(getattr(y, f)))
        print(f"RESHARD {{save_shards}}->{{load_shards}} OK")
    """)
    assert "RESHARD 1->8 OK" in out and "RESHARD 8->1 OK" in out


def test_sharded_pipeline_matches_single_device():
    """CTDGLinkPipeline with SamplerSpec.shards=4 must produce the exact
    same train losses as the unsharded device pipeline (the whole stack:
    recipe mesh plumbing, replicated batch staging, shard_map samplers,
    replicated jitted steps)."""
    out = _run("""
    import numpy as np
    from repro.data import generate
    from repro.tg.specs import SamplerSpec
    from repro.train.loop import CTDGLinkPipeline

    data = generate("tiny").slice_events(0, 300)

    def run(spec):
        p = CTDGLinkPipeline("tgat", data, batch_size=100, seed=0,
                             sampler_spec=spec)
        loss, _ = p.train_epoch()
        return loss

    l0 = run(SamplerSpec(device=True))
    l1 = run(SamplerSpec(device=True, shards=4))
    assert l0 == l1, (l0, l1)
    print("PIPELINE SHARDED OK", l0)
    """, devices=4)
    assert "PIPELINE SHARDED OK" in out


def test_sharded_fused_layer_bit_parity():
    """``fused_temporal_layer_sharded`` inside a shard_map over the node
    axis must be BIT-identical to the single-device layer: one owner per
    seed contributes its value, every other shard contributes exact zeros,
    and the psum of one value with zeros is exact. Gradients likewise."""
    out = _run("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import DeviceRecencySampler
    from repro.distributed.sharding import (SHARD_MAP_KW, make_node_mesh,
                                            shard_map)
    from repro.kernels.temporal_attention import (
        fused_temporal_layer, fused_temporal_layer_sharded)

    rng = np.random.default_rng(0)
    N, K, H, D, S = 23, 4, 2, 8, 16
    plain = DeviceRecencySampler(N, K, retain_state=True)
    for _ in range(3):
        src, dst = rng.integers(0, N, 20), rng.integers(0, N, 20)
        t = np.sort(rng.integers(0, 50, 20))
        plain.update(src, dst, t)
    sd = plain.state_dict()

    q = jnp.asarray(rng.standard_normal((S, H, D)) * .25, jnp.float32)
    kt = jnp.asarray(rng.standard_normal((N, H, D)) * .25, jnp.float32)
    vt = jnp.asarray(rng.standard_normal((N, H, D)) * .25, jnp.float32)
    seeds = jnp.asarray(rng.integers(0, N, S), jnp.int32)
    seed_t = jnp.asarray(np.full(S, 60), jnp.int32)

    def ref_loss(q, kt):
        o = fused_temporal_layer(q, kt, vt, seeds, seed_t,
                                 plain.packed_buffer, mode="ref")
        return jnp.sum(jnp.sin(o)), o
    (_, out_ref), g_ref = jax.value_and_grad(
        ref_loss, (0, 1), has_aux=True)(q, kt)

    for shards in (2, 5, 8):
        mesh = make_node_mesh(shards, "nodes")
        sh = DeviceRecencySampler(N, K, mesh=mesh, mesh_axis="nodes",
                                  retain_state=True)
        sh.load_state_dict(sd)
        per = sh.rows_per_shard

        def body(q, kt, buf):
            def loss(q, kt):
                o = fused_temporal_layer_sharded(
                    q, kt, vt, seeds, seed_t, buf, axis="nodes",
                    rows_per_shard=per, mode="ref")
                return jnp.sum(jnp.sin(o)), o
            (_, o), g = jax.value_and_grad(loss, (0, 1),
                                           has_aux=True)(q, kt)
            return o, g

        smapped = shard_map(body, mesh=mesh,
                            in_specs=(P(), P(), P("nodes")),
                            out_specs=(P(), (P(), P())), **SHARD_MAP_KW)
        o, g = jax.jit(smapped)(q, kt, sh.packed_buffer)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(out_ref))
        for a, b in zip(g, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)
        print(f"SHARDED LAYER {shards} OK")
    """)
    for shards in (2, 5, 8):
        assert f"SHARDED LAYER {shards} OK" in out


def test_2d_pipeline_matches_single_device():
    """A jitted 2-D-mesh train epoch (data >= 2, nodes >= 2, fused path
    enabled) must match the single-device fused pipeline within the
    documented 1e-4 kernel grad bound — both 2x4 and 4x2 mesh shapes
    (docs/sharding.md)."""
    out = _run("""
    import numpy as np, jax
    from repro.data import generate
    from repro.tg.specs import SamplerSpec
    from repro.train.loop import CTDGLinkPipeline

    data = generate("tiny").slice_events(0, 300)

    def build(ds, ns):
        spec = SamplerSpec(kind="recency", device=True, shards=ns,
                           expose_buffer=True if ns else None)
        return CTDGLinkPipeline("tgat", data, batch_size=100, seed=0,
                                sampler_spec=spec, data_shards=ds,
                                fused="ref")

    ref = build(1, None)
    l0, _ = ref.train_epoch()
    leaves0 = jax.tree.leaves(ref.params)
    for ds, ns in ((2, 4), (4, 2)):
        p = build(ds, ns)
        assert p._mesh is not None and dict(p._mesh.shape) == {
            "data": ds, "nodes": ns}
        l1, _ = p.train_epoch()
        assert abs(l0 - l1) < 1e-4, (ds, ns, l0, l1)
        d = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                for a, b in zip(leaves0, jax.tree.leaves(p.params)))
        assert d < 1e-4, (ds, ns, d)
        print(f"2D {ds}x{ns} OK", l1, d)
    """)
    assert "2D 2x4 OK" in out and "2D 4x2 OK" in out


def test_2d_checkpoint_reshard_across_mesh_shapes(tmp_path):
    """A pipeline checkpoint written under one 2-D mesh shape must restore
    under any other (1x1 <-> 2x4 <-> 4x2) and continue training to the
    same losses — canonical sampler state + replicated params make
    checkpoints mesh-agnostic."""
    out = _run(f"""
    import numpy as np, jax
    from repro.data import generate
    from repro.tg.specs import SamplerSpec
    from repro.train.loop import CTDGLinkPipeline

    data = generate("tiny").slice_events(0, 300)

    def build(ds, ns):
        spec = SamplerSpec(kind="recency", device=True, shards=ns,
                           expose_buffer=True if ns else None)
        return CTDGLinkPipeline("tgat", data, batch_size=100, seed=0,
                                sampler_spec=spec, data_shards=ds,
                                fused="ref")

    # epoch 0 under 2x4, checkpoint, then epoch 1 under 1x1 / 2x4 / 4x2
    a = build(2, 4)
    a.train_epoch()
    d = r"{tmp_path}" + "/2d"
    a.save_checkpoint(d, 0)

    losses, params = [], []
    for ds, ns in ((1, None), (2, 4), (4, 2)):
        p = build(ds, ns)
        p.restore_checkpoint(d)
        l, _ = p.train_epoch()
        losses.append(l)
        params.append(jax.tree.leaves(p.params))
    for l, ps in zip(losses[1:], params[1:]):
        assert abs(l - losses[0]) < 1e-4, losses
        dmax = max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
                   for x, y in zip(params[0], ps))
        assert dmax < 1e-4, dmax
    print("2D RESHARD OK", losses)
    """)
    assert "2D RESHARD OK" in out


def test_elastic_restore_across_meshes(tmp_path):
    out = _run(f"""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed import checkpoint as ckpt
    from repro.distributed.sharding import logical_sharding

    # save params sharded on a (4, 2) mesh
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    w = jax.device_put(w, logical_sharding(("batch", "mlp"), mesh=mesh_a, shape=w.shape))
    ckpt.save(r"{tmp_path}", 0, {{"w": w}}, logical_axes={{"w": ("batch", "mlp")}})

    # restore onto a DIFFERENT mesh (2, 4): elastic re-shard
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    tree, step, _ = ckpt.restore(r"{tmp_path}", target={{"w": w}}, mesh=mesh_b)
    got = tree["w"]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(w))
    assert got.sharding.mesh.shape["model"] == 4
    print("ELASTIC OK")
    """, devices=8)
    assert "ELASTIC OK" in out
