import numpy as np

from repro.launch import hlo_analysis as H


SAMPLE = """
  %all-gather = f32[512,1024]{0,1} all-gather(%copy), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={1}
  %x = bf16[16,128]{1,0} add(%a, %b)
  %all-reduce.1 = bf16[32,256]{1,0} all-reduce(%dot), channel_id=2, replica_groups=[4,2]<=[2,4]T(1,0)
  %rs = f32[8,8]{1,0} reduce-scatter(%big), channel_id=3, replica_groups={{0,1,2,3}}
  %cp = bf16[4,4]{1,0} collective-permute(%y), channel_id=4
"""


def test_parse_collective_kinds_and_sizes():
    stats = H.parse_collectives(SAMPLE, bf16_model=False)
    assert stats.count == 4
    assert stats.op_bytes["all-gather"] == 512 * 1024 * 4
    assert stats.op_bytes["all-reduce"] == 32 * 256 * 2
    assert stats.op_bytes["reduce-scatter"] == 64 * 4
    assert stats.op_bytes["collective-permute"] == 16 * 2


def test_group_size_formats():
    assert H._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert H._group_size("replica_groups=[4,2]<=[2,4]T(1,0)") == 2
    assert H._group_size("no groups here") == 1


def test_bf16_correction_halves_large_f32():
    raw = H.parse_collectives(SAMPLE, bf16_model=False)
    corr = H.parse_collectives(SAMPLE, bf16_model=True)
    # the big f32 all-gather gets halved; small/bf16 ops unchanged
    assert corr.op_bytes["all-gather"] == raw.op_bytes["all-gather"] // 2
    assert corr.op_bytes["all-reduce"] == raw.op_bytes["all-reduce"]
    assert corr.wire_bytes < raw.wire_bytes == corr.wire_bytes_raw


def test_roofline_terms_and_dominance():
    coll = H.CollectiveStats({"all-reduce": 10}, 10, int(50e9), 1)
    r = H.Roofline(
        flops_per_device=197e12,  # exactly 1s of compute
        bytes_per_device=819e9,  # 0.5s corrected memory
        collective=coll,  # 1s of wire
        num_devices=4,
    )
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 0.5) < 1e-9
    assert abs(r.collective_s - 1.0) < 1e-9
    assert r.dominant in ("compute", "collective")
    assert r.step_time_s == 1.0
    assert r.flops_global == 197e12 * 4


def test_extrapolate_depth():
    c1 = H.CollectiveStats({"all-reduce": 100}, 100, 1000, 2, 2000)
    c2 = H.CollectiveStats({"all-reduce": 160}, 160, 1600, 3, 3200)
    r1 = H.Roofline(10.0, 100.0, c1, 4)
    r2 = H.Roofline(16.0, 160.0, c2, 4)
    out = H.extrapolate(r1, r2, n_units=10)
    assert out.flops_per_device == 10.0 + 9 * 6.0
    assert out.bytes_per_device == 100.0 + 9 * 60.0
    assert out.collective.wire_bytes == 1000 + 9 * 600
    assert out.collective.wire_bytes_raw == 2000 + 9 * 1200


def test_model_flops():
    from repro.configs import SHAPES, get_arch

    cfg = get_arch("yi-9b")
    mf = H.model_flops(cfg, SHAPES["train_4k"])
    assert abs(mf - 6 * cfg.param_count() * 256 * 4096) / mf < 1e-9
    dec = H.model_flops(cfg, SHAPES["decode_32k"])
    assert dec == 2 * cfg.active_param_count() * 128
