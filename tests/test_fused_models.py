"""Fused device-sampling model path (TGAT/TGN layer-1 over the resident
packed buffer): numerical parity with the classic pre-gathered path, the
no-HBM-materialization guarantee (jaxpr inspection), and end-to-end trainer
bit-parity between ``device_sampling=True`` and the host numpy oracle."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    DGData,
    DGraph,
    DGDataLoader,
    RECIPE_TGB_LINK,
    RecipeRegistry,
    TRAIN_KEY,
)
from repro.models.tg import tgat, tgn


def _stream(n=400, num_nodes=40, d_edge=6, seed=0):
    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((n, d_edge)).astype(np.float32)
    return DGData.from_arrays(
        rng.integers(0, num_nodes, n), rng.integers(0, num_nodes, n),
        np.sort(rng.integers(0, 5000, n)), edge_feats=feats, granularity="s",
    ), feats


def _device_batches(data, feats, num_nodes=40, k=6, B=50, num_hops=1,
                    eval_negatives=3):
    """Run the device-sampling TGB-link recipe and return staged batches
    (each carries consistent hook tensors + the pre-update ``nbr_buf``)."""
    from repro.core.tg_hooks import stage_batch

    m = RecipeRegistry.build(
        RECIPE_TGB_LINK, num_nodes=num_nodes, k=k, batch_size=B,
        num_hops=num_hops, eval_negatives=eval_negatives,
        edge_feats=feats, edge_feat_dim=feats.shape[1],
        device_sampling=True, seed=0,
    )
    loader = DGDataLoader(DGraph(data), m, batch_size=B)
    with m.activate(TRAIN_KEY):
        batches = [stage_batch(b) for b in loader]
    # Later batches have warm buffers (wraparound, partial rows, padding).
    return [{k2: b[k2] for k2 in b.keys()} for b in batches]


@pytest.mark.parametrize("num_layers", [1, 2])
def test_tgat_fused_matches_classic(num_layers):
    """Fused TGAT embeddings (ref and interpret-mode kernel) must agree
    with the classic pre-gathered oracle path on real pipeline batches."""
    data, feats = _stream()
    batches = _device_batches(data, feats, num_hops=num_layers)
    cfg = tgat.TGATConfig(num_nodes=40, d_edge=feats.shape[1], d_model=32,
                          d_time=16, num_heads=2, num_layers=num_layers, k=6)
    params = tgat.init(jax.random.PRNGKey(0), cfg)
    for batch in batches[-3:]:
        classic = tgat.embed(params, cfg, batch, fused=False)
        for mode in ("ref", "interpret"):
            got = tgat.embed(params, cfg, batch, fused=mode)
            np.testing.assert_allclose(got, classic, rtol=2e-4, atol=2e-4,
                                       err_msg=f"mode={mode}")


def test_tgat_fused_grads_flow():
    """The fused path must be trainable: link-loss grads exist for every
    parameter and match the classic path's grads."""
    from repro.models.tg.common import bce_link_loss

    data, feats = _stream()
    batch = _device_batches(data, feats)[-1]
    cfg = tgat.TGATConfig(num_nodes=40, d_edge=feats.shape[1], d_model=32,
                          d_time=16, num_layers=1, k=6)
    params = tgat.init(jax.random.PRNGKey(1), cfg)

    def loss(params, fused):
        pos, neg = tgat.link_scores(params, cfg, batch, 50, fused=fused)
        return bce_link_loss(pos, neg, batch["batch_mask"])

    g_fused = jax.grad(lambda p: loss(p, "interpret"))(params)
    g_classic = jax.grad(lambda p: loss(p, False))(params)
    flat_f = jax.tree_util.tree_leaves_with_path(g_fused)
    flat_c = dict(jax.tree_util.tree_leaves_with_path(g_classic))
    assert flat_f
    for path, leaf in flat_f:
        np.testing.assert_allclose(
            leaf, flat_c[path], rtol=5e-3, atol=1e-4,
            err_msg=jax.tree_util.keystr(path))


def test_tgn_fused_matches_classic():
    data, feats = _stream()
    batches = _device_batches(data, feats)
    cfg = tgn.TGNConfig(num_nodes=40, d_edge=feats.shape[1], d_model=32,
                        d_time=16, d_memory=24, k=6)
    params = tgn.init(jax.random.PRNGKey(0), cfg)
    state = tgn.init_state(cfg)
    # Non-trivial memory: evolve it through a few batches first.
    for b in batches[:3]:
        state = tgn.update_memory(params, cfg, state, b)
    batch = batches[3]
    classic = tgn.embed(params, cfg, state, batch, fused=False)
    for mode in ("ref", "interpret"):
        got = tgn.embed(params, cfg, state, batch, fused=mode)
        np.testing.assert_allclose(got, classic, rtol=2e-4, atol=2e-4,
                                   err_msg=f"mode={mode}")


def test_fused_requires_device_sampling_batch():
    cfg = tgat.TGATConfig(num_nodes=10, d_model=16, d_time=8, num_layers=1)
    params = tgat.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="nbr_buf"):
        tgat.embed(params, cfg, {"seed_nodes": jnp.zeros(4, jnp.int32)},
                   fused="ref")


def _float_intermediates(jaxpr, S, K):
    """All float intermediate shapes in ``jaxpr`` whose leading dims are
    (S, K) with a feature tail — the pre-gathered neighbor kv tensors."""
    hits = []
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "shape"):
                continue
            if (np.issubdtype(aval.dtype, np.floating) and len(aval.shape) >= 3
                    and aval.shape[0] == S and aval.shape[1] == K):
                hits.append(tuple(aval.shape))
    return hits


def test_fused_tgat_never_materializes_pregathered_kv():
    """Acceptance: with the fused kernel active, the (S, K, H, Dh) / (S, K,
    d_kv) neighbor tensors must not appear anywhere in the forward jaxpr —
    they exist only as VMEM scratch inside the pallas_call. The classic path
    is the positive control (it *does* materialize them)."""
    data, feats = _stream()
    batch = _device_batches(data, feats)[-1]
    cfg = tgat.TGATConfig(num_nodes=40, d_edge=feats.shape[1], d_model=32,
                          d_time=16, num_layers=1, k=6)
    params = tgat.init(jax.random.PRNGKey(0), cfg)
    S, K = batch["nbr_ids"].shape

    fused_jaxpr = jax.make_jaxpr(
        lambda p, b: tgat.embed(p, cfg, b, fused="interpret"))(params, batch)
    assert _float_intermediates(fused_jaxpr.jaxpr, S, K) == []

    classic_jaxpr = jax.make_jaxpr(
        lambda p, b: tgat.embed(p, cfg, b, fused=False))(params, batch)
    assert _float_intermediates(classic_jaxpr.jaxpr, S, K) != []


def test_trainer_device_sampling_bitwise_parity(small_stream):
    """End-to-end acceptance: with ``device_sampling=True`` the TGAT
    link-prediction losses and MRR are bit-identical to the host numpy
    oracle pipeline (on this CPU backend the fused dispatch resolves to the
    oracle math, and the device sampler is bit-identical to the host one)."""
    from repro.train import LinkPredictionTrainer

    losses, mrrs = {}, {}
    for dev in (False, True):
        tr = LinkPredictionTrainer(
            "tgat", small_stream, batch_size=48, k=4, eval_negatives=5,
            model_kwargs={"num_layers": 1}, device_sampling=dev, seed=0,
        )
        losses[dev], _ = tr.train_epoch()
        mrrs[dev], _ = tr.evaluate("val")
    assert losses[True] == losses[False]
    assert mrrs[True] == mrrs[False]
