"""Fused device-sampling model path (TGAT/TGN layer-1 over the resident
packed buffer): numerical parity with the classic pre-gathered path, the
no-HBM-materialization guarantee (jaxpr inspection), and end-to-end trainer
bit-parity between ``device_sampling=True`` and the host numpy oracle."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    DGData,
    DGraph,
    DGDataLoader,
    RECIPE_TGB_LINK,
    RecipeRegistry,
    TRAIN_KEY,
)
from repro.models.tg import tgat, tgn
from tests.utils import assert_no_intermediate, float_intermediates


def _stream(n=400, num_nodes=40, d_edge=6, seed=0):
    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((n, d_edge)).astype(np.float32)
    return DGData.from_arrays(
        rng.integers(0, num_nodes, n), rng.integers(0, num_nodes, n),
        np.sort(rng.integers(0, 5000, n)), edge_feats=feats, granularity="s",
    ), feats


def _device_batches(data, feats, num_nodes=40, k=6, B=50, num_hops=1,
                    eval_negatives=3):
    """Run the device-sampling TGB-link recipe and return staged batches
    (each carries consistent hook tensors + the pre-update ``nbr_buf``)."""
    from repro.core.tg_hooks import stage_batch

    m = RecipeRegistry.build(
        RECIPE_TGB_LINK, num_nodes=num_nodes, k=k, batch_size=B,
        num_hops=num_hops, eval_negatives=eval_negatives,
        edge_feats=feats, edge_feat_dim=feats.shape[1],
        device_sampling=True, seed=0,
    )
    loader = DGDataLoader(DGraph(data), m, batch_size=B)
    with m.activate(TRAIN_KEY):
        batches = [stage_batch(b) for b in loader]
    # Later batches have warm buffers (wraparound, partial rows, padding).
    return [{k2: b[k2] for k2 in b.keys()} for b in batches]


@pytest.mark.parametrize("num_layers", [1, 2])
def test_tgat_fused_matches_classic(num_layers):
    """Fused TGAT embeddings (ref and interpret-mode kernel) must agree
    with the classic pre-gathered oracle path on real pipeline batches."""
    data, feats = _stream()
    batches = _device_batches(data, feats, num_hops=num_layers)
    cfg = tgat.TGATConfig(num_nodes=40, d_edge=feats.shape[1], d_model=32,
                          d_time=16, num_heads=2, num_layers=num_layers, k=6)
    params = tgat.init(jax.random.PRNGKey(0), cfg)
    for batch in batches[-3:]:
        classic = tgat.embed(params, cfg, batch, fused=False)
        for mode in ("ref", "interpret"):
            got = tgat.embed(params, cfg, batch, fused=mode)
            np.testing.assert_allclose(got, classic, rtol=2e-4, atol=2e-4,
                                       err_msg=f"mode={mode}")


def test_tgat_fused_grads_flow():
    """The fused path must be trainable: link-loss grads exist for every
    parameter and match the classic path's grads."""
    from repro.models.tg.common import bce_link_loss

    data, feats = _stream()
    batch = _device_batches(data, feats)[-1]
    cfg = tgat.TGATConfig(num_nodes=40, d_edge=feats.shape[1], d_model=32,
                          d_time=16, num_layers=1, k=6)
    params = tgat.init(jax.random.PRNGKey(1), cfg)

    def loss(params, fused):
        pos, neg = tgat.link_scores(params, cfg, batch, 50, fused=fused)
        return bce_link_loss(pos, neg, batch["batch_mask"])

    g_fused = jax.grad(lambda p: loss(p, "interpret"))(params)
    g_classic = jax.grad(lambda p: loss(p, False))(params)
    flat_f = jax.tree_util.tree_leaves_with_path(g_fused)
    flat_c = dict(jax.tree_util.tree_leaves_with_path(g_classic))
    assert flat_f
    for path, leaf in flat_f:
        np.testing.assert_allclose(
            leaf, flat_c[path], rtol=5e-3, atol=1e-4,
            err_msg=jax.tree_util.keystr(path))


def test_tgn_fused_matches_classic():
    data, feats = _stream()
    batches = _device_batches(data, feats)
    cfg = tgn.TGNConfig(num_nodes=40, d_edge=feats.shape[1], d_model=32,
                        d_time=16, d_memory=24, k=6)
    params = tgn.init(jax.random.PRNGKey(0), cfg)
    state = tgn.init_state(cfg)
    # Non-trivial memory: evolve it through a few batches first.
    for b in batches[:3]:
        state = tgn.update_memory(params, cfg, state, b)
    batch = batches[3]
    classic = tgn.embed(params, cfg, state, batch, fused=False)
    for mode in ("ref", "interpret"):
        got = tgn.embed(params, cfg, state, batch, fused=mode)
        np.testing.assert_allclose(got, classic, rtol=2e-4, atol=2e-4,
                                   err_msg=f"mode={mode}")


def test_fused_requires_device_sampling_batch():
    cfg = tgat.TGATConfig(num_nodes=10, d_model=16, d_time=8, num_layers=1)
    params = tgat.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="nbr_buf"):
        tgat.embed(params, cfg, {"seed_nodes": jnp.zeros(4, jnp.int32)},
                   fused="ref")


def test_fused_tgat_never_materializes_pregathered_kv():
    """Acceptance: with the fused kernel active, the (S, K, H, Dh) / (S, K,
    d_kv) neighbor tensors must not appear anywhere in the forward jaxpr —
    they exist only as VMEM scratch inside the pallas_call. The classic path
    is the positive control (it *does* materialize them)."""
    data, feats = _stream()
    batch = _device_batches(data, feats)[-1]
    cfg = tgat.TGATConfig(num_nodes=40, d_edge=feats.shape[1], d_model=32,
                          d_time=16, num_layers=1, k=6)
    params = tgat.init(jax.random.PRNGKey(0), cfg)
    S, K = batch["nbr_ids"].shape

    fused_jaxpr = jax.make_jaxpr(
        lambda p, b: tgat.embed(p, cfg, b, fused="interpret"))(params, batch)
    assert_no_intermediate(fused_jaxpr, (S, K))

    classic_jaxpr = jax.make_jaxpr(
        lambda p, b: tgat.embed(p, cfg, b, fused=False))(params, batch)
    assert float_intermediates(classic_jaxpr, (S, K)) != []


def _train_step_jaxpr(loss_fn, params, batch):
    """Trace a full train step (loss + grads + AdamW update) to a jaxpr."""
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    opt_cfg = AdamWConfig(lr=1e-4)
    opt0 = adamw_init(params)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss

    return jax.make_jaxpr(step)(params, opt0, batch)


@pytest.mark.parametrize("num_layers", [1, 2])
def test_fused_tgat_train_step_is_gather_free(num_layers):
    """Tentpole acceptance: the *train* step — forward AND the flash-style
    backward — never materializes an (S, K, ·) or (S*K, K, ·) float tensor
    for fused TGAT. With the backward now a Pallas kernel (not the oracle
    recompute), the whole jitted value_and_grad + AdamW step is gather-free;
    the classic path is the positive control."""
    from repro.models.tg.common import bce_link_loss

    data, feats = _stream()
    batch = _device_batches(data, feats, num_hops=num_layers)[-1]
    cfg = tgat.TGATConfig(num_nodes=40, d_edge=feats.shape[1], d_model=32,
                          d_time=16, num_heads=2, num_layers=num_layers, k=6)
    params = tgat.init(jax.random.PRNGKey(0), cfg)
    S, K = batch["nbr_ids"].shape

    def loss(fused):
        def f(params, batch):
            pos, neg = tgat.link_scores(params, cfg, batch, 50, fused=fused)
            return bce_link_loss(pos, neg, batch["batch_mask"])
        return f

    jaxpr = _train_step_jaxpr(loss("interpret"), params, batch)
    assert_no_intermediate(jaxpr, (S, K))
    assert_no_intermediate(jaxpr, (S * K, K))

    classic = _train_step_jaxpr(loss(False), params, batch)
    assert float_intermediates(classic, (S, K)) != []


def test_fused_tgn_train_step_is_gather_free():
    """Same train-step acceptance for fused TGN (memory ‖ features kv
    tables): no (S, K, ·) float intermediate in forward or backward."""
    from repro.models.tg.common import bce_link_loss

    data, feats = _stream()
    batches = _device_batches(data, feats)
    cfg = tgn.TGNConfig(num_nodes=40, d_edge=feats.shape[1], d_model=32,
                        d_time=16, d_memory=24, k=6)
    params = tgn.init(jax.random.PRNGKey(0), cfg)
    state = tgn.init_state(cfg)
    for b in batches[:3]:
        state = tgn.update_memory(params, cfg, state, b)
    batch = batches[3]
    S, K = batch["nbr_ids"].shape

    def loss(fused):
        def f(params, batch):
            (pos, neg), _ = tgn.link_scores(params, cfg, state, batch, 50,
                                            fused=fused)
            return bce_link_loss(pos, neg, batch["batch_mask"])
        return f

    jaxpr = _train_step_jaxpr(loss("interpret"), params, batch)
    assert_no_intermediate(jaxpr, (S, K))

    classic = _train_step_jaxpr(loss(False), params, batch)
    assert float_intermediates(classic, (S, K)) != []


def test_trainer_device_sampling_bitwise_parity(small_stream):
    """End-to-end acceptance: with ``device_sampling=True`` the TGAT
    link-prediction losses and MRR are bit-identical to the host numpy
    oracle pipeline (on this CPU backend the fused dispatch resolves to the
    oracle math, and the device sampler is bit-identical to the host one)."""
    from repro.train import LinkPredictionTrainer

    losses, mrrs = {}, {}
    for dev in (False, True):
        tr = LinkPredictionTrainer(
            "tgat", small_stream, batch_size=48, k=4, eval_negatives=5,
            model_kwargs={"num_layers": 1}, device_sampling=dev, seed=0,
        )
        losses[dev], _ = tr.train_epoch()
        mrrs[dev], _ = tr.evaluate("val")
    assert losses[True] == losses[False]
    assert mrrs[True] == mrrs[False]
