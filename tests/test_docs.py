"""Docs quality gates: relative links in README/docs must resolve, and the
device-sampling-pipeline modules must keep full public-API docstring
coverage (the PR-1 additions originally shipped thin — this stops that from
regressing)."""

import importlib
import inspect
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def test_readme_and_docs_exist():
    assert (ROOT / "README.md").exists()
    assert (ROOT / "docs" / "architecture.md").exists()
    assert (ROOT / "docs" / "kernels.md").exists()
    assert (ROOT / "docs" / "dtdg.md").exists()
    assert (ROOT / "docs" / "experiment.md").exists()
    assert (ROOT / "docs" / "sharding.md").exists()
    assert (ROOT / "docs" / "serving.md").exists()
    assert (ROOT / "docs" / "storage.md").exists()
    assert (ROOT / "docs" / "observability.md").exists()
    assert (ROOT / "docs" / "benchmarks.md").exists()


def test_relative_doc_links_resolve():
    """Same rule as the CI link-check step (scripts/check_doc_links.py)."""
    sys.path.insert(0, str(ROOT / "scripts"))
    try:
        from check_doc_links import broken_links, doc_files
    finally:
        sys.path.pop(0)
    assert len(doc_files(ROOT)) >= 3
    assert broken_links(ROOT) == []


# Modules whose public surface must stay documented (the device-resident
# sampling pipeline: PR-1 additions + the fused-attention layer + the
# scan-compiled DTDG pipeline + the mesh-sharded sampler layer).
DOCUMENTED_MODULES = [
    "repro.core.device_sampler",
    "repro.core.device_uniform",
    "repro.distributed.sharding",
    "repro.core.discretize",
    "repro.core.graph",
    "repro.core.loader",
    "repro.core.negatives",
    "repro.core.tg_hooks",
    "repro.core.sampler",
    "repro.core.recipes",
    "repro.kernels.temporal_attention.kernel",
    "repro.kernels.temporal_attention.ops",
    "repro.kernels.temporal_attention.ref",
    "repro.nn.attention",
    "repro.nn.graph_conv",
    "repro.models.tg.common",
    "repro.models.tg.snapshot",
    "repro.train.tg_trainer",
    "repro.train.loop",
    "repro.train.nodeprop",
    "repro.tg.specs",
    "repro.tg.experiment",
    "repro.serve.graph_service",
    "repro.serve.faults",
    "repro.storage.base",
    "repro.storage.memory",
    "repro.storage.mmap",
    "repro.storage.csr",
    "repro.storage.windows",
    "repro.obs.records",
    "repro.obs.sinks",
    "repro.obs.telemetry",
    "repro.obs.profiler",
    "repro.utils.prof",
    # Test infrastructure is public surface too: the shared kernel-parity
    # harness and the jaxpr-inspection helpers are how new kernel families
    # get their acceptance coverage.
    "tests.utils",
    "tests.kernels.harness",
]


def _undocumented(module_name):
    m = importlib.import_module(module_name)
    missing = []
    if not inspect.getdoc(m):
        missing.append(module_name)
    for name, obj in vars(m).items():
        if name.startswith("_") or getattr(obj, "__module__", None) != module_name:
            continue
        if inspect.isfunction(obj):
            if not inspect.getdoc(obj):
                missing.append(f"{module_name}.{name}")
        elif inspect.isclass(obj):
            if not inspect.getdoc(obj):
                missing.append(f"{module_name}.{name}")
            for mname, meth in vars(obj).items():
                if mname.startswith("_"):
                    continue
                if inspect.isfunction(meth) and not inspect.getdoc(meth):
                    missing.append(f"{module_name}.{name}.{mname}")
    return missing


def test_public_api_docstrings():
    missing = []
    for mod in DOCUMENTED_MODULES:
        missing += _undocumented(mod)
    assert missing == [], f"undocumented public symbols: {missing}"
