import pytest

from repro.core import EventOrderedError, TimeDelta


def test_ordering():
    assert TimeDelta("s") <= TimeDelta("h")
    assert TimeDelta("h") <= TimeDelta("d")
    assert TimeDelta("d") <= TimeDelta("w")
    assert not (TimeDelta("d") <= TimeDelta("h"))
    assert TimeDelta("s", 30) <= TimeDelta("m")
    assert TimeDelta("h") <= TimeDelta("h")


def test_ticks_per():
    assert TimeDelta("h").ticks_per(TimeDelta("s")) == 3600
    assert TimeDelta("d").ticks_per(TimeDelta("h")) == 24
    assert TimeDelta("m", 5).ticks_per(TimeDelta("s")) == 300
    with pytest.raises(ValueError):
        TimeDelta("s", 7).ticks_per(TimeDelta("s", 2))


def test_event_ordered_excluded_from_time_ops():
    ev = TimeDelta.event()
    assert ev.is_event_ordered
    with pytest.raises(EventOrderedError):
        _ = ev.seconds
    with pytest.raises(EventOrderedError):
        ev.is_coarser_or_equal(TimeDelta("s"))


def test_validation():
    with pytest.raises(ValueError):
        TimeDelta("fortnight")
    with pytest.raises(ValueError):
        TimeDelta("s", 0)
    with pytest.raises(ValueError):
        TimeDelta("r", 2)


def test_coerce():
    assert TimeDelta.coerce("h") == TimeDelta("h")
    td = TimeDelta("m", 5)
    assert TimeDelta.coerce(td) is td
