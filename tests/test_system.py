"""End-to-end behaviour tests for the whole system (paper workflow of
Fig. 5): load data -> build recipe -> train -> evaluate with one-vs-many
negatives; plus the RQ1-RQ3 research paths (granularity sweep, time-driven
batching, graph property prediction) exercised end to end."""

import numpy as np
import pytest

from repro.core import (
    DGraph,
    DGDataLoader,
    RecipeRegistry,
    TimeDelta,
    RECIPE_ANALYTICS_DOS,
)
from repro.data import generate
from repro.train import LinkPredictionTrainer, SnapshotLinkTrainer


def test_paper_fig5_workflow(small_stream):
    """The canonical TGM workflow: recipe + loader + train + TGB eval."""
    tr = LinkPredictionTrainer("tgat", small_stream, batch_size=48, k=4,
                               eval_negatives=10,
                               model_kwargs={"num_layers": 1})
    l0, _ = tr.train_epoch()
    l1, _ = tr.train_epoch()
    assert np.isfinite(l1)
    mrr, _ = tr.evaluate("val")
    assert 0 <= mrr <= 1


@pytest.mark.parametrize("device_sampling", [False, True])
def test_uniform_sampler_trainer_end_to_end(small_stream, device_sampling):
    """The uniform temporal sampler (host and device-CSR twins) is
    interchangeable with recency inside the TGB link recipe."""
    tr = LinkPredictionTrainer("tgat", small_stream, batch_size=48, k=4,
                               eval_negatives=5, sampler="uniform",
                               device_sampling=device_sampling,
                               model_kwargs={"num_layers": 1})
    loss, _ = tr.train_epoch()
    assert np.isfinite(loss)
    mrr, _ = tr.evaluate("val")
    assert 0 <= mrr <= 1


def test_rq2_granularity_is_a_hyperparameter(small_stream):
    """Snapshot granularity changes DTDG behaviour with one-line changes."""
    mrrs = {}
    for unit in ["h", "d"]:
        tr = SnapshotLinkTrainer("gcn", small_stream, snapshot_unit=unit,
                                 d_embed=16)
        tr.run_epoch(train=True)
        mrrs[unit], _ = tr.run_epoch(train=False)
    assert set(mrrs) == {"h", "d"}  # both granularities run end-to-end


def test_rq3_iterate_by_time_vs_events(small_stream):
    """CTDG stream consumed by fixed-size and by fixed-time batching."""
    g = DGraph(small_stream)
    by_events = list(DGDataLoader(g, None, batch_size=100))
    by_time = list(DGDataLoader(g, None, batch_size=None, batch_unit="h"))
    assert sum(b.num_events for b in by_events) == sum(
        b.num_events for b in by_time) == small_stream.num_edge_events
    sizes = {b.num_events for b in by_time}
    assert len(sizes) > 1  # time windows have variable event counts


def test_analytics_recipe_dos(small_stream):
    m = RecipeRegistry.build(RECIPE_ANALYTICS_DOS,
                             num_nodes=small_stream.num_nodes, num_moments=8)
    loader = DGDataLoader(DGraph(small_stream), m, batch_size=None,
                          batch_unit="h")
    moments = [b["dos"] for b in loader]
    assert all(mm.shape == (8,) for mm in moments)


def test_synthetic_datasets_match_table13_shape():
    """Generators expose the Table 13 datasets at configurable scale."""
    from repro.data.synthetic import DATASET_SPECS

    assert set(DATASET_SPECS) >= {"wikipedia", "reddit", "lastfm", "trade", "genre"}
    d = generate("wikipedia", scale=0.02)
    assert d.edge_feat_dim == 172  # LIWC-like features
    assert d.num_edge_events >= 1000
    tr, va, te = d.split()
    assert tr.num_edge_events > va.num_edge_events
