"""Fault tolerance: kill a training run mid-flight, resume, and verify the
final state is bit-identical to an uninterrupted run (deterministic data
order keyed by step)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _train(ckpt_dir, extra, timeout=520):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    cmd = [sys.executable, "-m", "repro.launch.train", "--workload", "lm",
           "--arch", "qwen3-0.6b", "--reduced", "--steps", "12",
           "--batch-size", "2", "--seq-len", "16", "--ckpt-every", "4",
           "--log-every", "4", "--ckpt-dir", str(ckpt_dir)] + extra
    return subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                          env=env, cwd=REPO)


def test_kill_and_resume_is_deterministic(tmp_path):
    clean_dir = tmp_path / "clean"
    crash_dir = tmp_path / "crash"

    # uninterrupted run
    out = _train(clean_dir, [])
    assert out.returncode == 0, out.stderr[-2000:]
    final_clean = [l for l in out.stdout.splitlines() if "done" in l][-1]

    # crashed at step 7, then resumed
    out = _train(crash_dir, ["--simulate-failure", "7"])
    assert out.returncode == 42  # injected failure
    assert "failure-injection" in out.stdout
    out = _train(crash_dir, ["--resume"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[resume] restored step" in out.stdout
    final_crash = [l for l in out.stdout.splitlines() if "done" in l][-1]

    assert final_clean == final_crash  # bit-identical final loss


def test_tg_workload_resume(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    cmd = [sys.executable, "-m", "repro.launch.train", "--workload", "tg",
           "--model", "tpnet", "--dataset", "tiny", "--data-scale", "0.2",
           "--epochs", "2", "--batch-size", "64",
           "--ckpt-dir", str(tmp_path)]
    out = subprocess.run(cmd + ["--simulate-failure", "0"], capture_output=True,
                         text=True, timeout=520, env=env, cwd=REPO)
    assert out.returncode == 42
    out = subprocess.run(cmd + ["--resume"], capture_output=True, text=True,
                         timeout=520, env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[resume]" in out.stdout
    assert "final test MRR" in out.stdout


def test_dtdg_mid_epoch_resume_is_deterministic(tmp_path):
    """DTDG quadrant of the kill/resume story: the scan-compiled snapshot
    pipeline checkpoints its mid-epoch snapshot_cursor after every chunk;
    killing after N chunks and resuming must land on the exact chunk
    boundary and produce a bit-identical final test MRR."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    base = [sys.executable, "-m", "repro.launch.train", "--workload", "dtdg",
            "--model", "gclstm", "--dataset", "tiny", "--data-scale", "0.3",
            "--epochs", "2", "--chunk-size", "4", "--discretization", "h"]

    def run(ckpt_dir, extra):
        return subprocess.run(base + ["--ckpt-dir", str(ckpt_dir)] + extra,
                              capture_output=True, text=True, timeout=520,
                              env=env, cwd=REPO)

    out = run(tmp_path / "clean", [])
    assert out.returncode == 0, out.stderr[-2000:]
    final_clean = [l for l in out.stdout.splitlines()
                   if "final test MRR" in l][-1]

    # kill after 3 chunks (mid-epoch: each epoch has >3 chunks), resume
    out = run(tmp_path / "crash", ["--simulate-failure", "3"])
    assert out.returncode == 42
    assert "failure-injection" in out.stdout
    out = run(tmp_path / "crash", ["--resume"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[resume] restored step" in out.stdout
    assert "cursor" in out.stdout  # resumed mid-epoch, not at a boundary
    final_crash = [l for l in out.stdout.splitlines()
                   if "final test MRR" in l][-1]
    assert final_clean == final_crash  # bit-identical
