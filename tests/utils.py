"""Shared test utilities: jaxpr-inspection helpers.

The fused-kernel acceptance story ("no pre-gathered neighbor tensor ever
lands in HBM") is asserted structurally: trace the jitted computation,
walk every equation — recursing into sub-jaxprs so ``custom_vjp`` branches,
``scan`` bodies and jitted sub-calls are covered, but *not* into
``pallas_call`` bodies, whose internal scratch is VMEM by construction —
and require that no floating-point intermediate matches the banned shape
prefix. ``tests/test_fused_models.py`` uses this to prove the full train
step (forward *and* backward) of fused TGAT/TGN is gather-free.
"""

from __future__ import annotations

import numpy as np


def _iter_jaxprs(params):
    """Yield every (Closed)Jaxpr reachable from an eqn's params dict."""
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for item in vs:
            if hasattr(item, "eqns"):  # raw Jaxpr
                yield item
            elif hasattr(item, "jaxpr") and hasattr(
                    getattr(item, "jaxpr"), "eqns"):  # ClosedJaxpr
                yield item.jaxpr


def float_intermediates(jaxpr, shape_prefix):
    """All float intermediate shapes in ``jaxpr`` (recursively) whose
    leading dims equal ``shape_prefix`` and that carry at least one more
    (feature) axis.

    ``jaxpr`` may be a ``ClosedJaxpr`` or a raw ``Jaxpr``; ``shape_prefix``
    is a tuple of leading dimensions, e.g. ``(S, K)`` for the pre-gathered
    neighbor kv tensors. Equations inside ``pallas_call`` bodies are not
    visited (kernel-internal values live in VMEM scratch, which is exactly
    the memory win being asserted). Returns a list of offending shapes —
    empty means the computation never materializes such a tensor.
    """
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    prefix = tuple(shape_prefix)
    n = len(prefix)
    hits = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            shape = getattr(aval, "shape", None)
            if shape is None or getattr(aval, "dtype", None) is None:
                continue
            if (np.issubdtype(aval.dtype, np.floating)
                    and len(shape) > n and tuple(shape[:n]) == prefix):
                hits.append(tuple(shape))
        for sub in _iter_jaxprs(eqn.params):
            hits.extend(float_intermediates(sub, prefix))
    return hits


def assert_no_intermediate(jaxpr, shape_prefix):
    """Assert ``jaxpr`` contains no float intermediate whose shape starts
    with ``shape_prefix`` (see ``float_intermediates``); raises with the
    offending shapes otherwise."""
    hits = float_intermediates(jaxpr, shape_prefix)
    assert not hits, (
        f"found float intermediates with banned shape prefix "
        f"{tuple(shape_prefix)}: {sorted(set(hits))}")
