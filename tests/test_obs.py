"""Structured telemetry (``repro.obs``): span nesting + monotonic timing,
histogram quantiles against numpy, JSONL schema round-trip through
``FileSink``, the near-zero disabled fast path, ``TrainLoop`` history
parity with the records it emits, and the end-to-end acceptance run — one
sink observing a CTDG epoch, a serving chaos burst, and a windowed
out-of-core storage epoch + streaming-CSR build, every record
schema-valid."""

import json
import time

import numpy as np
import pytest

from repro.obs import (
    FileSink,
    MemorySink,
    NullSink,
    Telemetry,
    bench_record,
    span_report,
    validate,
)
from repro.obs.telemetry import _H_GROWTH


# ------------------------------------------------------------------ spans

def test_span_nesting_and_monotonicity():
    tel = Telemetry()
    sink = tel.attach(MemorySink())
    with tel.span("outer", tag="x") as sp:
        with tel.span("inner"):
            time.sleep(0.002)
        sp["result"] = 7
    spans = [r for r in sink.records if r["kind"] == "span"]
    assert [s["path"] for s in spans] == ["outer.inner", "outer"]
    inner, outer = spans
    assert outer["name"] == "outer" and inner["name"] == "inner"
    # monotonic clock: inner starts after outer, outer spans inner
    assert inner["t0"] >= outer["t0"]
    assert outer["dur_s"] >= inner["dur_s"] > 0
    assert outer["attrs"] == {"tag": "x", "result": 7}
    for s in spans:
        validate(s)


def test_span_attrs_survive_exceptions():
    tel = Telemetry()
    sink = tel.attach(MemorySink())
    with pytest.raises(RuntimeError):
        with tel.span("boom") as sp:
            sp["partial"] = 1
            raise RuntimeError("x")
    (rec,) = sink.records
    assert rec["attrs"] == {"partial": 1}


def test_disabled_span_yields_writable_scratch():
    tel = Telemetry()  # no sinks: disabled
    assert not tel.enabled
    with tel.span("anything") as sp:
        sp["loss"] = 1.0  # must not raise
    tel.count("c")
    tel.gauge("g", 1.0)
    tel.observe("h", 0.1)
    assert tel.counter_value("c") == 0  # nothing recorded


def test_null_sink_keeps_telemetry_disabled():
    tel = Telemetry(NullSink())
    assert not tel.enabled


# -------------------------------------------------------------- histogram

def test_histogram_quantiles_vs_numpy():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-6.0, sigma=1.5, size=5000)
    tel = Telemetry(MemorySink())
    for s in samples:
        tel.observe("lat", float(s))
    h = tel.histogram("lat")
    assert h.count == len(samples)
    assert h.sum == pytest.approx(samples.sum())
    for q in (0.5, 0.9, 0.99):
        true = float(np.quantile(samples, q))
        est = h.quantile(q)
        # upper-edge estimate: >= truth (up to rank rounding), within one
        # bucket ratio (~1.33) of it
        assert est >= true * 0.999
        assert est <= true * _H_GROWTH * 1.05
    assert h.quantile(1.0) == pytest.approx(samples.max())


def test_histogram_snapshot_record():
    tel = Telemetry(MemorySink())
    for v in (1e-5, 1e-5, 3.0):
        tel.observe("x", v)
    rec = tel.histogram("x").snapshot("x")
    validate(rec)
    assert rec["count"] == 3
    assert sum(c for _, c in rec["buckets"]) == 3
    # bucket upper edges bound their contents
    assert any(edge >= 3.0 for edge, _ in rec["buckets"])


# ------------------------------------------------------- sinks and schema

def test_filesink_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "tel.jsonl")
    tel = Telemetry(FileSink(path))
    with tel.span("work", n=3):
        tel.count("things", 2)
        tel.observe("lat", 0.01)
        tel.gauge("depth", 4)
    tel.flush()
    lines = open(path).read().splitlines()
    records = [json.loads(ln) for ln in lines]
    kinds = [r["kind"] for r in records]
    assert kinds == ["span", "counter", "gauge", "hist"]
    for r in records:
        validate(r)
    assert records[0]["attrs"] == {"n": 3}


def test_validate_rejects_malformed():
    with pytest.raises(ValueError):
        validate({"kind": "nope"})
    with pytest.raises(ValueError):
        validate({"kind": "span", "name": "a"})  # missing fields
    with pytest.raises(ValueError):
        validate({"kind": "counter", "name": "a", "value": "high"})
    with pytest.raises(ValueError):
        validate([])  # not a dict


def test_bench_record_matches_legacy_fields():
    rec = bench_record("bench/x", 12.345, "pct=1", ts=1700000000.123456,
                       rev="abc1234", backend="cpu", device_count=1)
    validate(rec)
    assert rec["kind"] == "bench"
    assert rec["us"] == 12.3  # round(value, 1), as the legacy writer did
    assert rec["ts"] == 1700000000.123
    assert rec["name"] == "bench/x" and rec["derived"] == "pct=1"
    assert rec["backend"] == "cpu" and rec["device_count"] == 1


def test_attach_detach_tee():
    tel = Telemetry(MemorySink())
    extra = tel.attach(MemorySink())
    tel.count("a")
    tel.detach(extra)
    tel.flush()
    # the detached sink saw nothing (flush came after detach)
    assert extra.records == []
    assert tel.enabled


# ------------------------------------------------------- disabled overhead

def test_disabled_overhead_is_negligible():
    tel = Telemetry()
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        with tel.span("hot"):
            pass
        tel.count("c")
        tel.observe("h", 0.1)
    per_iter = (time.perf_counter() - t0) / n
    # one nullcontext + two early returns; generous bound for slow CI
    assert per_iter < 20e-6, f"disabled telemetry costs {per_iter:.2e}s/iter"


# --------------------------------------------------- train-history parity

class _ScriptedPipeline:
    """Duck-typed pipeline returning scripted values (no JAX involved)."""

    def __init__(self):
        self.losses = [(0.9, 1.5), (0.7, 1.4), (0.5, 1.3)]
        self.metrics = iter([0.11, 0.22])
        self.saved = 0

    def train_epoch(self):
        return self.losses.pop(0)

    def evaluate(self, split):
        return next(self.metrics), 0.01

    def save_checkpoint(self, ckpt_dir, step):
        self.saved += 1
        return f"{ckpt_dir}/ckpt_{step}"


def test_trainloop_history_from_records_parity(tmp_path):
    from repro.train.loop import TrainLoop, history_from_records

    tel = Telemetry()
    sink = tel.attach(MemorySink())
    loop = TrainLoop(_ScriptedPipeline(), telemetry=tel)
    history = loop.fit(epochs=3, eval_every=2, eval_split="val",
                       ckpt_dir=str(tmp_path), ckpt_every=3)
    expected = {
        "loss": [0.9, 0.7, 0.5],
        "train_secs": [1.5, 1.4, 1.3],
        "eval": [(1, 0.11)],
        "ckpts": [f"{tmp_path}/ckpt_2"],
    }
    assert history == expected  # identical keys AND values
    # and the records alone rebuild the same history
    assert history_from_records(sink.records) == expected
    for r in sink.records:
        validate(r)


# -------------------------------------------------- end-to-end acceptance

def test_single_sink_observes_train_serve_and_storage(tmp_path):
    """ISSUE acceptance: one ``repro.obs`` sink sees a CTDG link epoch, a
    serving chaos run, and a windowed out-of-core storage epoch (plus a
    streaming-CSR build), and every emitted record validates."""
    from repro.core import DGData
    from repro.core.loader import PrefetchLoader
    from repro.serve import FaultInjector, OnlineGraphService
    from repro.storage import InMemoryStore, StoreEventLoader, streaming_csr
    from repro.train.loop import CTDGLinkPipeline, TrainLoop

    path = str(tmp_path / "run.jsonl")
    tel = Telemetry(FileSink(path))
    mem = tel.attach(MemorySink())

    # -- one CTDG link epoch through TrainLoop --------------------------
    from repro.data import generate

    data = generate("tiny").slice_events(0, 300)
    pipe = CTDGLinkPipeline("tgat", data, batch_size=100, seed=0,
                            telemetry=tel)
    TrainLoop(pipe).fit(epochs=1)
    assert any(r["kind"] == "span" and r["name"] == "ctdg/epoch"
               for r in mem.records)
    assert any(r["kind"] == "span" and r["name"] == "ctdg/step"
               for r in mem.records)

    # -- one serving chaos burst ----------------------------------------
    inj = FaultInjector(seed=0, dup_p=0.1, fail_p=0.3)
    rng = np.random.default_rng(1)
    events = [(int(rng.integers(40)), int(rng.integers(40)), 100 + i, i)
              for i in range(80)]
    with OnlineGraphService(40, k=4, flush_interval=0.002,
                            fault_injector=inj, telemetry=tel) as svc:
        svc.ingest_many(inj.perturb_events(events))
        svc.drain()
        rs = [svc.submit_link(i % 40, (i * 3 + 1) % 40, 500).result(30)
              for i in range(10)]
    assert all(r.status is not None for r in rs)
    assert tel.counter_value("serve/events_applied") > 0

    # -- one windowed storage epoch + streaming CSR ---------------------
    src = rng.integers(0, 40, 400)
    dst = rng.integers(0, 40, 400)
    t = np.sort(rng.integers(0, 5000, 400))
    store = InMemoryStore.from_data(
        DGData.from_arrays(src, dst, t, granularity="s"))
    loader = PrefetchLoader(
        StoreEventLoader(store, batch_size=100, telemetry=tel),
        telemetry=tel)
    assert len(list(loader)) == 4
    streaming_csr(store, chunk_size=150, telemetry=tel)
    assert tel.counter_value("storage/windows_read") > 0
    assert tel.counter_value("storage/csr_windows") > 0
    assert tel.counter_value("loader/batches") == 4

    # -- every record in the shared JSONL file validates ----------------
    tel.flush()
    records = [json.loads(ln) for ln in open(path).read().splitlines()]
    assert len(records) == len(mem.records)
    for r in records:
        validate(r)
    names = {r["name"] for r in records}
    # all three subsystems landed in ONE file
    assert "ctdg/epoch" in names
    assert "storage/csr_pass1" in names
    assert any(n.startswith("serve/") for n in names)
    # and the report renders without blowing up
    assert "section" in span_report(records, min_pct=0.0)
    assert "|" in span_report(records, min_pct=0.0, markdown=True)
