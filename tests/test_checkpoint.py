import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32),
                   "b": jnp.asarray(rng.standard_normal(4), jnp.bfloat16)},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 3, tree, extra_meta={"loss": 1.5})
    restored, step, extra = ckpt.restore(str(tmp_path), target=tree)
    assert step == 3 and extra["loss"] == 1.5
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert np.asarray(restored["opt"]["step"]) == 7


def test_latest_and_retention(tmp_path):
    tree = _tree()
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    assert sorted(ckpt.all_steps(str(tmp_path))) == [3, 4, 5]


def test_atomic_publish_no_tmp_left(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    assert not [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path))


def test_async_checkpointer(tmp_path):
    w = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = _tree()
    for s in range(4):
        w.save(s, tree, extra_meta={"s": s})
    w.close()
    assert ckpt.latest_step(str(tmp_path)) == 3
    restored, step, extra = ckpt.restore(str(tmp_path), target=tree)
    assert extra["s"] == 3


def test_dtype_preserved(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 0, tree)
    restored, _, _ = ckpt.restore(str(tmp_path), target=tree)
    assert restored["params"]["b"].dtype == np.dtype(jnp.bfloat16)


def test_sharded_sampler_bundle_is_canonical_and_reshards(tmp_path):
    """Checkpoint bundles carry the samplers' canonical host layout, so a
    bundle written by a mesh-sharded sampler restores into an unsharded
    one (and back) with bit-identical draws — the single-device half of
    the 1<->8 resharding story (the 8-device half runs in
    tests/test_distributed.py)."""
    from repro.core import DeviceRecencySampler
    from repro.distributed.sharding import make_node_mesh

    rng = np.random.default_rng(8)
    N, k = 17, 3
    sharded = DeviceRecencySampler(N, k, mesh=make_node_mesh(1))
    for i in range(3):
        src, dst = rng.integers(0, N, 12), rng.integers(0, N, 12)
        t = np.sort(rng.integers(i * 30, (i + 1) * 30, 12))
        sharded.update(src, dst, t)
    ckpt.save(str(tmp_path), 0, {"sampler": sharded.state_dict()})

    flat, _, _ = ckpt.restore(str(tmp_path), target=None)
    state = {kk.split("/", 1)[1]: v for kk, v in flat.items()}
    assert state["ids"].shape == (N, k)  # canonical: no sink, no padding

    plain = DeviceRecencySampler(N, k)
    plain.load_state_dict(state)
    back = DeviceRecencySampler(N, k, mesh=make_node_mesh(1))
    back.load_state_dict(plain.state_dict())
    a, b = plain.sample(np.arange(N)), back.sample(np.arange(N))
    np.testing.assert_array_equal(np.asarray(a.nbr_ids), np.asarray(b.nbr_ids))
    np.testing.assert_array_equal(np.asarray(a.nbr_eids), np.asarray(b.nbr_eids))
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))


def test_truncated_checkpoint_falls_back_to_newest_intact(tmp_path):
    """A torn checkpoint (truncated leaf file) must be skipped by
    latest_step/restore, falling back to the newest intact step; asking
    for the torn step explicitly raises a clear error."""
    tree = _tree()
    ckpt.save(str(tmp_path), 1, tree, extra_meta={"s": 1})
    ckpt.save(str(tmp_path), 2, tree, extra_meta={"s": 2})
    # Truncate a leaf of step 2 (crash mid-write / bitrot post-publish).
    leaf = os.path.join(tmp_path, "ckpt_2", "leaf_0.npy")
    with open(leaf, "r+b") as f:
        f.truncate(os.path.getsize(leaf) // 2)
    assert not ckpt.is_intact(os.path.join(tmp_path, "ckpt_2"))
    assert ckpt.latest_step(str(tmp_path)) == 1
    restored, step, extra = ckpt.restore(str(tmp_path), target=tree)
    assert step == 1 and extra["s"] == 1
    with pytest.raises(RuntimeError, match="torn"):
        ckpt.restore(str(tmp_path), step=2, target=tree)


def test_missing_leaf_detected_as_torn(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 0, tree)
    ckpt.save(str(tmp_path), 5, tree)
    os.remove(os.path.join(tmp_path, "ckpt_5", "leaf_1.npy"))
    assert ckpt.latest_step(str(tmp_path)) == 0
    assert sorted(ckpt.all_steps(str(tmp_path))) == [0, 5]  # raw listing
    assert ckpt.all_steps(str(tmp_path), intact_only=True) == [0]


def test_async_checkpointer_surfaces_worker_failure(tmp_path):
    """A failed background write must raise on the NEXT save()/wait(),
    never be dropped."""
    w = ckpt.AsyncCheckpointer(str(tmp_path / "sub"), keep=2)
    w.save(0, _tree())
    w.wait()
    # Make the next write fail: the ckpt root becomes a regular file.
    import shutil
    shutil.rmtree(tmp_path / "sub")
    (tmp_path / "sub").write_text("not a directory")
    w.save(1, _tree())
    with pytest.raises(RuntimeError, match="async checkpoint write failed"):
        w.wait()
    with pytest.raises(RuntimeError, match="async checkpoint write failed"):
        w.save(2, _tree())


def test_async_checkpointer_dead_worker_raises_not_hangs(tmp_path):
    """wait() must not block forever when the worker thread has died hard
    (the old bare q.join() would)."""
    w = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    w.save(0, _tree())
    w.wait()
    w._thread.join(timeout=0.1)  # ensure no task in flight
    # Simulate a hard worker death with an item still queued.
    w._q.put((1, {"x": np.zeros(2)}, None, None))
    orig = w._thread
    class Dead:
        @staticmethod
        def is_alive():
            return False
    w._thread = Dead()
    with pytest.raises(RuntimeError, match="worker thread died"):
        w.wait()
    w._thread = orig
