import numpy as np
import pytest

from repro.core import DGData, DGraph, TimeDelta


def _mk(n=100, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, 20, n)
    dst = rng.integers(0, 20, n)
    t = rng.integers(0, 1000, n)
    feats = rng.standard_normal((n, 4)).astype(np.float32)
    return DGData.from_arrays(src, dst, t, edge_feats=feats, granularity="s")


def test_time_sorted_storage():
    d = _mk()
    assert (np.diff(d.edge_t) >= 0).all()
    assert d.num_edge_events == 100
    assert d.edge_feat_dim == 4


def test_stable_sort_preserves_feature_alignment():
    src = [1, 2, 3]
    dst = [4, 5, 6]
    t = [30, 10, 20]
    feats = np.asarray([[30.0], [10.0], [20.0]], np.float32)
    d = DGData.from_arrays(src, dst, t, edge_feats=feats)
    np.testing.assert_array_equal(d.edge_t.astype(np.float32), d.edge_feats[:, 0])


def test_edge_range_binary_search():
    d = _mk()
    lo, hi = d.edge_range(100, 500)
    assert (d.edge_t[lo:hi] >= 100).all()
    assert (d.edge_t[lo:hi] < 500).all()
    if lo > 0:
        assert d.edge_t[lo - 1] < 100
    if hi < d.num_edge_events:
        assert d.edge_t[hi] >= 500


def test_split_chronological():
    d = _mk(1000)
    tr, va, te = d.split(0.15, 0.15)
    assert tr.num_edge_events + va.num_edge_events + te.num_edge_events == 1000
    if va.num_edge_events and tr.num_edge_events:
        assert tr.edge_t[-1] <= va.edge_t[0]
    if te.num_edge_events and va.num_edge_events:
        assert va.edge_t[-1] <= te.edge_t[0]


def test_view_is_o1_and_immutable():
    d = _mk()
    g = DGraph(d)
    sub = g.slice_time(100, 500)
    assert sub.data is d  # no copy
    lo, hi = d.edge_range(100, 500)
    assert sub.num_edge_events == hi - lo


def test_view_granularity_must_be_coarser():
    d = _mk()
    DGraph(d, granularity="h")  # ok: coarser
    with pytest.raises(ValueError):
        DGraph(d, granularity=TimeDelta("ms"))


def test_materialize_window():
    d = _mk()
    g = DGraph(d, t_lo=0, t_hi=500)
    out = g.materialize()
    assert (out["time"] < 500).all()
    assert out["src"].shape == out["dst"].shape == out["time"].shape


def test_csv_adapter(tmp_path):
    p = tmp_path / "edges.csv"
    p.write_text("src,dst,t\n1,2,10\n3,4,5\n")
    d = DGData.from_csv(str(p))
    assert d.num_edge_events == 2
    assert d.edge_t[0] == 5  # sorted
