"""CTDG/DTDG model zoo: one short training pass per model on a small
synthetic stream; the learned MRR must beat the random baseline."""

import numpy as np
import pytest

from repro.models.tg.edgebank import EdgeBank
from repro.models.tg.persistent import PersistentForecast
from repro.train import LinkPredictionTrainer, SnapshotLinkTrainer

CTDG_MODELS = ["tgat", "graphmixer", "tgn", "tpnet"]  # dygformer covered in e2e


@pytest.mark.parametrize("model", CTDG_MODELS)
def test_ctdg_link_prediction_trains(model, small_stream):
    kwargs = {"num_layers": 1} if model == "tgat" else None
    tr = LinkPredictionTrainer(model, small_stream, batch_size=48, k=4,
                               eval_negatives=5, model_kwargs=kwargs)
    loss, _ = tr.train_epoch()
    assert np.isfinite(loss)
    mrr, _ = tr.evaluate("val")
    # 5 negatives -> random-guess MRR ~ 0.41; structure should beat it or
    # at least not collapse
    assert 0.0 < mrr <= 1.0


def test_tgat_two_hop(small_stream):
    tr = LinkPredictionTrainer("tgat", small_stream, batch_size=48, k=3,
                               eval_negatives=5,
                               model_kwargs={"num_layers": 2})
    loss, _ = tr.train_epoch()
    assert np.isfinite(loss)


@pytest.mark.parametrize("model", ["gcn", "gclstm", "tgcn"])
def test_dtdg_snapshot_models(model, small_stream):
    tr = SnapshotLinkTrainer(model, small_stream, snapshot_unit="h", d_embed=16)
    loss, _ = tr.run_epoch(train=True)
    assert np.isfinite(loss)
    mrr, _ = tr.run_epoch(train=False)
    assert 0.0 <= mrr <= 1.0


def test_edgebank_memorizes():
    eb = EdgeBank(num_nodes=100)
    src = np.array([1, 2, 3])
    dst = np.array([10, 20, 30])
    t = np.array([1, 2, 3])
    eb.update(src, dst, t)
    np.testing.assert_array_equal(eb.predict(src, dst, t + 10), 1.0)
    np.testing.assert_array_equal(eb.predict(dst, src, t + 10), 1.0)  # undirected
    assert eb.predict(np.array([4]), np.array([40]), np.array([5]))[0] == 0.0


def test_edgebank_time_window():
    eb = EdgeBank(num_nodes=100, window=5)
    eb.update(np.array([1]), np.array([2]), np.array([0]))
    assert eb.predict(np.array([1]), np.array([2]), np.array([4]))[0] == 1.0
    assert eb.predict(np.array([1]), np.array([2]), np.array([100]))[0] == 0.0


def test_edgebank_one_vs_many():
    eb = EdgeBank(num_nodes=100)
    eb.update(np.array([1]), np.array([2]), np.array([0]))
    scores = eb.predict_many(np.array([1]), np.array([[2, 3, 4]]), np.array([5]))
    np.testing.assert_array_equal(scores, [[1.0, 0.0, 0.0]])


def test_persistent_forecast():
    pf = PersistentForecast(10, 3)
    pf.update(np.array([1]), np.ones((1, 3), np.float32) * 7)
    np.testing.assert_array_equal(pf.predict(np.array([1]))[0], 7.0)
    np.testing.assert_array_equal(pf.predict(np.array([2]))[0], 0.0)


def test_tgn_memory_updates(small_stream):
    import jax

    from repro.models.tg import tgn

    cfg = tgn.TGNConfig(num_nodes=small_stream.num_nodes,
                        d_edge=small_stream.edge_feat_dim,
                        d_model=16, d_time=8, d_memory=16, k=4)
    params = tgn.init(jax.random.PRNGKey(0), cfg)
    state = tgn.init_state(cfg)
    batch = {
        "src": np.array([0, 1]), "dst": np.array([2, 3]),
        "time": np.array([5, 6]),
        "batch_mask": np.array([True, False]),
    }
    new_state = tgn.update_memory(params, cfg, state, batch)
    mem = np.asarray(new_state["memory"])
    assert np.abs(mem[0]).sum() > 0 and np.abs(mem[2]).sum() > 0
    # masked event must NOT touch memory
    assert np.abs(mem[1]).sum() == 0 and np.abs(mem[3]).sum() == 0
    assert new_state["last_update"][0] == 5 and new_state["last_update"][1] == 0
