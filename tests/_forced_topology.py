"""Shared launcher for tests that need a forced multi-device CPU topology.

``XLA_FLAGS=--xla_force_host_platform_device_count=N`` must be set before
jax initializes, so these tests run their snippets in a subprocess with the
flag injected first. Used by ``tests/test_distributed.py`` and
``tests/test_sharded_sampler.py``.
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_forced(snippet: str, devices: int = 8, timeout: int = 520) -> str:
    """Run ``snippet`` in a fresh interpreter with ``devices`` emulated CPU
    devices and ``PYTHONPATH=src``; assert success and return stdout."""
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(snippet)
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=REPO)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout
