import numpy as np
import pytest

from repro.core import (
    Batch,
    Hook,
    HookManager,
    LambdaHook,
    RecipeError,
    RecipeRegistry,
    resolve_order,
    RECIPE_TGB_LINK,
    RECIPE_ANALYTICS_DOS,
)
from repro.core.tg_hooks import DOSEstimateHook, NegativeEdgeHook, PadBatchHook


def _hook(name, requires, produces):
    def fn(b):
        for p in produces:
            b[p] = np.zeros(1)
        return b

    return LambdaHook(fn, requires, produces, name)


def test_topological_order_respects_contracts():
    a = _hook("a", {"src"}, {"x"})
    b = _hook("b", {"x"}, {"y"})
    c = _hook("c", {"y", "x"}, {"z"})
    order = resolve_order([c, b, a])
    assert [h.name for h in order] == ["a", "b", "c"]


def test_registration_order_breaks_ties():
    a = _hook("a", {"src"}, {"x"})
    b = _hook("b", {"src"}, {"y"})
    order = resolve_order([a, b])
    assert [h.name for h in order] == ["a", "b"]


def test_unsatisfied_requirement_fails_fast():
    with pytest.raises(RecipeError, match="requires"):
        resolve_order([_hook("a", {"nonexistent"}, {"x"})])


def test_cycle_detection():
    a = _hook("a", {"y"}, {"x"})
    b = _hook("b", {"x"}, {"y"})
    with pytest.raises(RecipeError, match="cycle"):
        resolve_order([a, b])


def test_hook_must_produce_declared_attrs():
    bad = LambdaHook(lambda b: b, {"src"}, {"never_produced"}, "bad")
    m = HookManager()
    m.register(bad)
    batch = Batch({"src": np.zeros(3), "dst": np.zeros(3), "time": np.zeros(3)})
    with pytest.raises(RecipeError, match="did not produce"):
        m.execute(batch)


def test_keyed_activation_groups():
    m = HookManager()
    m.register(_hook("shared", {"src"}, {"s"}))
    m.register(_hook("train_only", {"src"}, {"t"}), key="train")
    batch = Batch({"src": np.zeros(2), "dst": np.zeros(2), "time": np.zeros(2)})
    with m.activate("train"):
        out = m.execute(Batch(batch.as_dict()))
        assert "t" in out and "s" in out
    with m.activate("eval"):
        out = m.execute(Batch(batch.as_dict()))
        assert "t" not in out and "s" in out


def test_reset_state_resets_all_groups():
    m = HookManager()
    h = NegativeEdgeHook(10, strategy="historical")
    m.register(h, key="train")
    h._sampler._hist.add((1, 2))
    m.reset_state()
    assert not h._sampler._hist


def test_recipe_registry():
    assert RECIPE_TGB_LINK in RecipeRegistry.available()
    m = RecipeRegistry.build(RECIPE_TGB_LINK, num_nodes=10, k=2, batch_size=8)
    assert m.hooks("train")
    with pytest.raises(KeyError):
        RecipeRegistry.build("nope")


def test_pad_hook_fixed_shapes():
    h = PadBatchHook(16)
    b = Batch({"src": np.arange(5), "dst": np.arange(5), "time": np.arange(5)})
    out = h(b)
    assert out["src"].shape == (16,)
    assert out["batch_mask"].sum() == 5


def test_dos_hook_moments():
    h = DOSEstimateHook(num_nodes=50, num_moments=6)
    rng = np.random.default_rng(0)
    b = Batch({"src": rng.integers(0, 50, 100), "dst": rng.integers(0, 50, 100),
               "time": np.arange(100)})
    out = h(b)
    assert out["dos"].shape == (6,)
    # moment 0 of the Chebyshev expansion is ~1 (normalized trace)
    assert abs(out["dos"][0] - 1.0) < 0.2


def test_hook_manager_state_dict_roundtrip():
    """Sampler buffers collected via HookManager.state_dict must restore
    into a freshly built manager (the trainer checkpoint path)."""
    from repro.core.tg_hooks import DeviceRecencyNeighborHook, RecencyNeighborHook

    rng = np.random.default_rng(0)
    for hook_cls in (RecencyNeighborHook, DeviceRecencyNeighborHook):
        m = HookManager()
        m.register(hook_cls(20, 3, include_negatives=False))
        b = Batch({"src": rng.integers(0, 20, 30), "dst": rng.integers(0, 20, 30),
                   "time": np.sort(rng.integers(0, 100, 30))})
        with m.activate("train"):
            m.execute(b)
        state = m.state_dict()
        assert len(state) == 1

        m2 = HookManager()
        m2.register(hook_cls(20, 3, include_negatives=False))
        m2.load_state_dict(state)
        h1 = m.hooks()[0].sampler
        h2 = m2.hooks()[0].sampler
        blk1, blk2 = h1.sample(np.arange(20)), h2.sample(np.arange(20))
        np.testing.assert_array_equal(np.asarray(blk1.nbr_ids), np.asarray(blk2.nbr_ids))

    with pytest.raises(KeyError):
        m2.load_state_dict({"shared/9/Nope": {}})
