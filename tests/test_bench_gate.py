"""Regression-gate semantics: direction-aware comparison (lower-better
latencies vs higher-better rates) and the graceful skip for bench names
with no baseline entry."""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _compare(current, baseline, tolerance=2.0):
    sys.path.insert(0, str(ROOT / "scripts"))
    try:
        from check_bench_regression import compare
    finally:
        sys.path.pop(0)
    return compare(current, baseline, tolerance)


def _cur(name, us):
    return {name: [{"us": us, "runs": 1, "backend": "cpu",
                    "device_count": 1}]}


def _base(us, **kw):
    return {"us": us, "backend": "cpu", "device_count": 1, **kw}


def test_lower_is_better_default():
    _, reg = _compare(_cur("lat", 30.0), {"lat": _base(10.0)})
    assert reg and reg[0][0] == "lat"
    _, reg = _compare(_cur("lat", 15.0), {"lat": _base(10.0)})
    assert reg == []


def test_higher_is_better_direction():
    # rate collapsing below baseline/tolerance = regression
    _, reg = _compare(_cur("qps", 4.0), {"qps": _base(10.0,
                                                     direction="higher")})
    assert reg and reg[0][0] == "qps"
    # a *slower* latency-style ratio that would fail lower-better passes
    _, reg = _compare(_cur("qps", 30.0), {"qps": _base(10.0,
                                                       direction="higher")})
    assert reg == []


def test_unknown_bench_name_skips_gracefully():
    rows, reg = _compare(_cur("brand_new_bench", 5.0), {})
    assert reg == []
    assert rows[0][4] == "new (no baseline)"
