"""Mesh-sharded device sampler tests (docs/sharding.md).

Two layers:

  * in-process tests build a 1-D mesh over *all currently visible* devices
    (1 on the plain tier-1 run; 8 in the ``tier1-multidevice`` CI job,
    which sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and
    assert the shard_map paths are bit-identical to the single-device
    samplers / sequential oracle;
  * subprocess tests force an 8-device CPU topology regardless of the
    parent's XLA flags (the flag must be set before jax initializes), so
    the genuinely multi-device property tests and the 1<->8 checkpoint
    resharding runs are exercised on every environment.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

from repro.core import (
    DeviceRecencySampler,
    DeviceUniformSampler,
    SequentialRecencySampler,
)
from repro.distributed.sharding import make_node_mesh
from repro.tg.specs import SamplerSpec
from tests._forced_topology import run_forced as _run


def _mesh_all():
    return make_node_mesh(jax.device_count())


def _assert_same_np(a, b):
    np.testing.assert_array_equal(np.asarray(a.nbr_ids), np.asarray(b.nbr_ids))
    np.testing.assert_array_equal(np.asarray(a.nbr_times), np.asarray(b.nbr_times))
    np.testing.assert_array_equal(np.asarray(a.nbr_eids), np.asarray(b.nbr_eids))
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))


# ----------------------------------------------------------------------
# In-process: mesh over whatever devices this run has
# ----------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    k=st.integers(1, 6),
    n_nodes=st.integers(2, 30),
    n_batches=st.integers(1, 5),
)
def test_property_sharded_recency_equals_sequential(seed, k, n_nodes,
                                                    n_batches):
    """The shard_map recency path must stay indistinguishable from
    sequential insertion (wraparound + duplicate timestamps included)."""
    rng = np.random.default_rng(seed)
    fast = DeviceRecencySampler(n_nodes, k, mesh=_mesh_all())
    slow = SequentialRecencySampler(n_nodes, k)
    t0 = 0
    for _ in range(n_batches):
        B = int(rng.integers(1, 20))
        src = rng.integers(0, n_nodes, B)
        dst = rng.integers(0, n_nodes, B)
        t = np.sort(rng.integers(t0, t0 + 10, B))
        t0 += 10
        eids = rng.integers(0, 10_000, B)
        fast.update(src, dst, t, eids)
        slow.update(src, dst, t, eids)
        seeds = rng.integers(0, n_nodes, 13)
        _assert_same_np(fast.sample(seeds), slow.sample(seeds))


def test_sharded_uniform_draws_match_unsharded():
    """Sharded uniform sampling must be bit-identical to the single-device
    device sampler: same counter-derived draws, same masks."""
    rng = np.random.default_rng(5)
    N, E, k = 25, 300, 5
    src, dst = rng.integers(0, N, E), rng.integers(0, N, E)
    t = np.sort(rng.integers(0, 60, E))
    eids = np.arange(E, dtype=np.int64)

    ref = DeviceUniformSampler(N, k, seed=7)
    ref.build(src, dst, t, eids)
    dev = DeviceUniformSampler(N, k, seed=7, mesh=_mesh_all())
    dev.build(src, dst, t, eids)
    for _ in range(4):
        seeds = rng.integers(0, N, 17)
        qt = rng.integers(0, 70, 17)
        _assert_same_np(ref.sample(seeds, qt), dev.sample(seeds, qt))


def test_sharded_recency_state_dict_is_canonical():
    """A sharded sampler's state_dict must strip sinks/padding and load
    into an unsharded sampler (and back) with identical draws."""
    rng = np.random.default_rng(1)
    N, k = 23, 4
    sharded = DeviceRecencySampler(N, k, mesh=_mesh_all())
    plain = DeviceRecencySampler(N, k)
    for _ in range(3):
        src, dst = rng.integers(0, N, 15), rng.integers(0, N, 15)
        t = np.sort(rng.integers(0, 50, 15))
        sharded.update(src, dst, t)
        plain.update(src, dst, t)
    sd = sharded.state_dict()
    for key in ("ids", "times", "eids", "cursor", "count"):
        assert sd[key].shape[0] == N  # canonical: no sinks, no padding
        np.testing.assert_array_equal(sd[key], plain.state_dict()[key])
    # round-trip: canonical -> sharded -> canonical
    back = DeviceRecencySampler(N, k, mesh=_mesh_all())
    back.load_state_dict(sd)
    _assert_same_np(back.sample(np.arange(N)), plain.sample(np.arange(N)))


def test_sharded_uniform_state_dict_reassembles_csr():
    """The sharded uniform state_dict must reassemble the canonical
    node-major CSR (padding stripped, global indptr) and reshard on load."""
    rng = np.random.default_rng(2)
    N, E, k = 19, 200, 3
    src, dst = rng.integers(0, N, E), rng.integers(0, N, E)
    t = np.sort(rng.integers(0, 40, E))
    ref = DeviceUniformSampler(N, k, seed=1)
    ref.build(src, dst, t)
    dev = DeviceUniformSampler(N, k, seed=1, mesh=_mesh_all())
    dev.build(src, dst, t)
    a, b = ref.state_dict(), dev.state_dict()
    for key in ("adj_nbr", "adj_t", "adj_e", "indptr"):
        np.testing.assert_array_equal(a[key], b[key])
    # canonical -> sharded load continues the identical draw stream
    dev2 = DeviceUniformSampler(N, k, seed=1, mesh=_mesh_all())
    dev2.load_state_dict(a)
    seeds, qt = rng.integers(0, N, 9), rng.integers(5, 50, 9)
    _assert_same_np(ref.sample(seeds, qt), dev2.sample(seeds, qt))


def test_sharded_sampler_exposes_fused_buffer_surface():
    """The sharded packed buffer is a first-class surface now (the
    shard-aware fused path consumes it): ``packed_buffer`` returns the
    node-sharded packed layout, ``rows_per_shard`` reports the per-shard
    node row count, and the hook accepts ``expose_buffer=True`` with a
    mesh (defaulting to off there)."""
    from repro.core.tg_hooks import DeviceRecencyNeighborHook
    from repro.distributed.sharding import node_rows_per_shard

    mesh = _mesh_all()
    shards = jax.device_count()
    N, k = 10, 3
    s = DeviceRecencySampler(N, k, mesh=mesh)
    per = node_rows_per_shard(N, shards)
    assert s.rows_per_shard == per
    buf = s.packed_buffer
    assert buf.shape == (shards * (per + 1), k, 3)
    # default under a mesh keeps the buffer private; opting in exposes it
    hook = DeviceRecencyNeighborHook(N, k, mesh=mesh)
    assert hook.expose_buffer is False
    hook = DeviceRecencyNeighborHook(N, k, mesh=mesh, expose_buffer=True)
    assert hook.expose_buffer is True
    from repro.core.batch import Batch

    out = hook(Batch({"src": np.array([1, 2]), "dst": np.array([3, 4]),
                      "time": np.array([5, 6]),
                      "neg": np.array([[0], [7]])}))
    assert out["nbr_buf"].shape == (shards * (per + 1), k, 3)
    # unsharded sampler: rows_per_shard is None (no shard axis)
    assert DeviceRecencySampler(N, k).rows_per_shard is None


def test_sampler_spec_shards_validation():
    """SamplerSpec.shards: device-only, positive, JSON round-trips; the
    expose_buffer+shards combination is legal (shard-aware fused path)."""
    spec = SamplerSpec(device=True, shards=2)
    assert SamplerSpec.from_dict(spec.to_dict()) == spec
    spec = SamplerSpec(device=True, shards=2, expose_buffer=True,
                       partition="degree")
    assert SamplerSpec.from_dict(spec.to_dict()) == spec
    with pytest.raises(ValueError, match="device=True"):
        SamplerSpec(shards=2)
    with pytest.raises(ValueError, match="positive"):
        SamplerSpec(device=True, shards=0)
    with pytest.raises(ValueError, match="partition"):
        SamplerSpec(partition="hash")
    with pytest.raises(ValueError, match="shards must be >= 1"):
        make_node_mesh(0)
    with pytest.raises(ValueError, match="devices are visible"):
        make_node_mesh(jax.device_count() + 1)


def test_degree_partition_matches_rows_partition():
    """Degree-balanced CSR boundaries must not change a single draw — the
    partition only moves node boundaries between shards."""
    rng = np.random.default_rng(11)
    N, E, k = 29, 350, 4
    # Skewed degrees: a few hub nodes absorb most edges.
    hub = rng.integers(0, 3, E)
    src = np.where(rng.random(E) < 0.7, hub, rng.integers(0, N, E))
    dst = rng.integers(0, N, E)
    t = np.sort(rng.integers(0, 70, E))
    eids = np.arange(E, dtype=np.int64)

    rows = DeviceUniformSampler(N, k, seed=3, mesh=_mesh_all())
    rows.build(src, dst, t, eids)
    deg = DeviceUniformSampler(N, k, seed=3, mesh=_mesh_all(),
                               partition="degree")
    deg.build(src, dst, t, eids)
    for _ in range(4):
        seeds = rng.integers(0, N, 15)
        qt = rng.integers(0, 80, 15)
        _assert_same_np(rows.sample(seeds, qt), deg.sample(seeds, qt))
    # and the canonical checkpoint is partition-independent
    a, b = rows.state_dict(), deg.state_dict()
    for key in ("adj_nbr", "adj_t", "adj_e", "indptr"):
        np.testing.assert_array_equal(a[key], b[key])


# ----------------------------------------------------------------------
# Subprocess: forced 8-device topology (tests/_forced_topology.py)
# ----------------------------------------------------------------------
def test_property_sharded_recency_8dev():
    """Randomized recency streams on real 2/5/8-way meshes must match the
    sequential oracle bit-for-bit (uneven last shard included: N=23)."""
    out = _run("""
    import numpy as np
    from repro.core import DeviceRecencySampler, SequentialRecencySampler
    from repro.distributed.sharding import make_node_mesh

    rng = np.random.default_rng(0)
    N, k = 23, 4
    for shards in (2, 5, 8):
        fast = DeviceRecencySampler(N, k, mesh=make_node_mesh(shards))
        slow = SequentialRecencySampler(N, k)
        t0 = 0
        for _ in range(6):
            B = int(rng.integers(1, 25))
            src, dst = rng.integers(0, N, B), rng.integers(0, N, B)
            t = np.sort(rng.integers(t0, t0 + 10, B)); t0 += 10
            eids = rng.integers(0, 10_000, B)
            fast.update(src, dst, t, eids)
            slow.update(src, dst, t, eids)
            seeds = rng.integers(0, N, 13)
            a, b = fast.sample(seeds), slow.sample(seeds)
            for f in ("nbr_ids", "nbr_times", "nbr_eids", "mask"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(a, f)), np.asarray(getattr(b, f)))
    print("RECENCY8 OK")
    """)
    assert "RECENCY8 OK" in out


def test_property_sharded_uniform_8dev():
    """Randomized uniform sampling on real 3/8-way meshes must match the
    single-device device sampler draws bit-for-bit."""
    out = _run("""
    import numpy as np
    from repro.core import DeviceUniformSampler
    from repro.distributed.sharding import make_node_mesh

    rng = np.random.default_rng(4)
    N, E, k = 31, 400, 6
    src, dst = rng.integers(0, N, E), rng.integers(0, N, E)
    t = np.sort(rng.integers(0, 80, E))
    eids = np.arange(E, dtype=np.int64)
    for shards in (3, 8):
        ref = DeviceUniformSampler(N, k, seed=9)
        ref.build(src, dst, t, eids)
        dev = DeviceUniformSampler(N, k, seed=9,
                                   mesh=make_node_mesh(shards))
        dev.build(src, dst, t, eids)
        for _ in range(4):
            seeds = rng.integers(0, N, 21)
            qt = rng.integers(0, 90, 21)
            a, b = ref.sample(seeds, qt), dev.sample(seeds, qt)
            for f in ("nbr_ids", "nbr_times", "nbr_eids", "mask"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(a, f)), np.asarray(getattr(b, f)))
    print("UNIFORM8 OK")
    """)
    assert "UNIFORM8 OK" in out
