"""Per-kernel shape/dtype sweeps, assert_allclose vs the ref.py oracles
(interpret=True executes the Pallas kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.segment_reduce.kernel import segment_sum_kernel
from repro.kernels.segment_reduce.ref import segment_sum_ref
from repro.kernels.ssd_chunk.kernel import ssd_chunk_kernel
from repro.kernels.ssd_chunk.ref import ssd_ref
from repro.kernels.temporal_attention.kernel import (
    fused_recency_attention_kernel,
    fused_temporal_layer_kernel,
    temporal_attention_kernel,
)
from repro.kernels.temporal_attention.ops import fused_temporal_layer
from repro.kernels.temporal_attention.ref import (
    fused_recency_attention_ref,
    fused_temporal_layer_ref,
    temporal_attention_ref,
)

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hk,Sq,Skv,D,causal,window",
    [
        (2, 4, 2, 64, 64, 32, True, 0),
        (1, 4, 4, 60, 60, 64, True, 0),  # unaligned seq
        (2, 8, 2, 128, 128, 64, True, 32),  # sliding window
        (1, 2, 1, 32, 96, 32, True, 0),  # Sq != Skv (chunked decode)
        (2, 4, 2, 64, 64, 32, False, 0),  # bidirectional (encoder)
        (1, 16, 4, 128, 128, 128, True, 0),  # GQA 4:1, head_dim 128
    ],
)
def test_flash_attention_sweep(B, H, Hk, Sq, Skv, D, causal, window, dtype):
    q = jnp.asarray(RNG.standard_normal((B, H, Sq, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Hk, Skv, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Hk, Skv, D)), dtype)
    got = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                 block_q=32, block_k=32, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,K,H,D", [(100, 16, 2, 32), (256, 32, 4, 64),
                                     (33, 8, 1, 16), (128, 20, 2, 100)])
def test_temporal_attention_sweep(S, K, H, D, dtype):
    q = jnp.asarray(RNG.standard_normal((S, H, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((S, K, H, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((S, K, H, D)), dtype)
    mask = jnp.asarray(RNG.random((S, K)) > 0.4)
    got = temporal_attention_kernel(q, k, v, mask, block_s=32, interpret=True)
    want = temporal_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype))


def test_temporal_attention_empty_neighborhood_is_zero():
    S, K, H, D = 8, 4, 2, 16
    q = jnp.asarray(RNG.standard_normal((S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((S, K, H, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((S, K, H, D)), jnp.float32)
    mask = jnp.zeros((S, K), bool)
    out = temporal_attention_kernel(q, k, v, mask, block_s=8, interpret=True)
    np.testing.assert_allclose(out, 0.0)


@pytest.mark.parametrize("S,K,H,D,N", [(64, 8, 2, 32, 100), (37, 20, 1, 16, 50),
                                       (128, 16, 2, 64, 300)])
def test_fused_recency_attention_sweep(S, K, H, D, N):
    """In-kernel neighbor gather (DMA from the resident buffer + node k/v
    tables) must match the materialize-then-attend oracle to <=1e-5."""
    q = jnp.asarray(RNG.standard_normal((S, H, D)), jnp.float32)
    k_table = jnp.asarray(RNG.standard_normal((N, H, D)), jnp.float32)
    v_table = jnp.asarray(RNG.standard_normal((N, H, D)), jnp.float32)
    seeds = jnp.asarray(RNG.integers(0, N, S), jnp.int32)
    buf = RNG.integers(-1, N, (N, K)).astype(np.int32)
    buf[N // 3] = -1  # one node with a fully empty buffer
    buf_ids = jnp.asarray(buf)
    got = fused_recency_attention_kernel(q, k_table, v_table, seeds, buf_ids,
                                         block_s=32, interpret=True)
    want = fused_recency_attention_ref(q, k_table, v_table, seeds, buf_ids)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_recency_attention_empty_buffer_rows_are_zero():
    S, K, H, D, N = 8, 4, 2, 16, 20
    q = jnp.asarray(RNG.standard_normal((S, H, D)), jnp.float32)
    tbl = jnp.asarray(RNG.standard_normal((N, H, D)), jnp.float32)
    seeds = jnp.asarray(RNG.integers(0, N, S), jnp.int32)
    buf_ids = jnp.full((N, K), -1, jnp.int32)  # nothing inserted yet
    out = fused_recency_attention_kernel(q, tbl, tbl, seeds, buf_ids,
                                         block_s=8, interpret=True)
    np.testing.assert_allclose(out, 0.0)


def test_fused_recency_attention_consumes_device_sampler_state():
    """End-to-end: DeviceRecencySampler buffers feed the fused kernel and
    agree with sampling + explicit gather + the plain oracle."""
    from repro.core.device_sampler import DeviceRecencySampler

    rng = np.random.default_rng(0)
    N, K, H, D, B = 30, 5, 2, 16, 40
    s = DeviceRecencySampler(N, K)
    src = rng.integers(0, N, B)
    dst = rng.integers(0, N, B)
    t = np.sort(rng.integers(0, 100, B))
    s.update(src, dst, t)

    seeds = jnp.asarray(rng.integers(0, N, 16), jnp.int32)
    q = jnp.asarray(rng.standard_normal((16, H, D)), jnp.float32)
    tbl = jnp.asarray(rng.standard_normal((N + 1, H, D)), jnp.float32)
    buf_ids = s.buffer_ids
    got = fused_recency_attention_kernel(q, tbl, tbl, seeds, buf_ids,
                                         block_s=16, interpret=True)

    blk = s.sample(seeds)
    safe = jnp.maximum(blk.nbr_ids, 0)
    want = temporal_attention_ref(q, tbl[safe], tbl[safe], blk.mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def _fused_layer_inputs(S, K, H, D, N, d_time, d_edge, E=300, rng=None,
                        w_scale=1.0):
    rng = RNG if rng is None else rng
    q = jnp.asarray(rng.standard_normal((S, H, D)), jnp.float32)
    kt = jnp.asarray(rng.standard_normal((N, H, D)), jnp.float32)
    vt = jnp.asarray(rng.standard_normal((N, H, D)), jnp.float32)
    seeds = jnp.asarray(rng.integers(0, N, S), jnp.int32)
    seed_t = jnp.asarray(rng.integers(50, 120, S), jnp.int32)
    buf = np.stack([
        rng.integers(-1, N, (N, K)),       # neighbor ids (-1 = empty)
        rng.integers(0, 50, (N, K)),       # times
        rng.integers(-1, E, (N, K)),       # edge ids (-1 = featureless)
    ], axis=-1).astype(np.int32)
    buf[N // 4] = -1                        # a fully empty row
    w = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32) * w_scale  # noqa: E731
    kw = {}
    if d_time:
        kw.update(
            time_w=jnp.asarray(rng.standard_normal(d_time), jnp.float32) * .1,
            time_b=jnp.asarray(rng.standard_normal(d_time), jnp.float32) * .1,
            wt_k=w(d_time, H * D), wt_v=w(d_time, H * D),
        )
    if d_edge:
        kw.update(
            edge_feats=jnp.asarray(rng.standard_normal((E, d_edge)), jnp.float32),
            we_k=w(d_edge, H * D), we_v=w(d_edge, H * D),
        )
    return (q, kt, vt, seeds, seed_t, jnp.asarray(buf)), kw


@pytest.mark.parametrize("S,K,H,D,N,d_time,d_edge", [
    (64, 8, 2, 32, 100, 24, 12),   # both bias folds
    (37, 20, 1, 16, 50, 100, 0),   # time only, unaligned S
    (48, 16, 2, 50, 80, 0, 8),     # edge only, d_model = 100-style head dim
    (33, 4, 2, 16, 40, 0, 0),      # plain gather (wrapper semantics)
])
def test_fused_temporal_layer_sweep(S, K, H, D, N, d_time, d_edge):
    """Double-buffered in-kernel gather + time/edge bias folds must match
    the materialize-then-attend oracle to <=2e-5."""
    args, kw = _fused_layer_inputs(S, K, H, D, N, d_time, d_edge)
    got = fused_temporal_layer_kernel(*args, block_s=16, interpret=True, **kw)
    want = fused_temporal_layer_ref(*args, **kw)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_fused_temporal_layer_grads_match_ref():
    """The custom VJP (kernel forward, oracle backward) must produce the
    same parameter gradients as differentiating the oracle directly.

    Glorot-magnitude (~0.2) projections keep the softmax un-saturated — the
    training regime; unit-scale weights would amplify the kernel's ~1e-5
    forward rounding through near-one-hot attention."""
    args, kw = _fused_layer_inputs(24, 6, 2, 16, 30, 12, 5,
                                   rng=np.random.default_rng(7), w_scale=0.2)
    q, kt, vt, seeds, seed_t, buf = args

    def loss(mode):
        def f(q, kt, vt, wt_k, we_k):
            out = fused_temporal_layer(
                q, kt, vt, seeds, seed_t, buf,
                time_w=kw["time_w"], time_b=kw["time_b"],
                wt_k=wt_k, wt_v=kw["wt_v"],
                edge_feats=kw["edge_feats"], we_k=we_k, we_v=kw["we_v"],
                block_s=8, mode=mode)
            return (out ** 2).sum()
        return jax.grad(f, argnums=(0, 1, 2, 3, 4))(
            q, kt, vt, kw["wt_k"], kw["we_k"])

    for g_kernel, g_ref in zip(loss("interpret"), loss("ref")):
        np.testing.assert_allclose(g_kernel, g_ref, rtol=1e-4, atol=1e-4)


def test_fused_temporal_layer_empty_rows_are_zero():
    (q, kt, vt, seeds, seed_t, _), kw = _fused_layer_inputs(16, 4, 2, 16, 20,
                                                            8, 0)
    buf = jnp.asarray(np.stack([np.full((20, 4), -1), np.zeros((20, 4)),
                                np.full((20, 4), -1)], -1), jnp.int32)
    out = fused_temporal_layer_kernel(q, kt, vt, seeds, seed_t, buf,
                                      block_s=8, interpret=True, **kw)
    np.testing.assert_allclose(out, 0.0)


@pytest.mark.parametrize("E,D,G,block_e", [(500, 16, 64, 128), (1000, 64, 128, 256),
                                           (77, 8, 16, 32), (512, 128, 256, 128)])
def test_segment_sum_sweep(E, D, G, block_e):
    data = jnp.asarray(RNG.standard_normal((E, D)), jnp.float32)
    seg = jnp.sort(jnp.asarray(RNG.integers(0, G, E), jnp.int32))
    got = segment_sum_kernel(data, seg, G, block_e=block_e, interpret=True)
    want = segment_sum_ref(data, seg, G)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_segment_sum_padding_ids_ignored():
    data = jnp.ones((10, 4), jnp.float32)
    seg = jnp.asarray([0, 0, 1, -1, -1, 2, 2, 2, -1, 3], jnp.int32)
    got = segment_sum_kernel(data, seg, 4, block_e=8, interpret=True)
    np.testing.assert_allclose(got[:, 0], [2, 1, 3, 1])


@pytest.mark.parametrize("S,H,P,N,chunk", [(64, 2, 16, 32, 16),
                                           (100, 4, 32, 64, 32),
                                           (96, 1, 8, 16, 96),
                                           (128, 2, 64, 128, 128)])
def test_ssd_chunk_sweep(S, H, P, N, chunk):
    x = jnp.asarray(RNG.standard_normal((S, H, P)), jnp.float32) * 0.5
    dt = jax.nn.softplus(jnp.asarray(RNG.standard_normal((S, H)), jnp.float32))
    a = -jnp.exp(jnp.asarray(RNG.standard_normal(H), jnp.float32) * 0.3)
    B = jnp.asarray(RNG.standard_normal((S, H, N)), jnp.float32) * 0.5
    C = jnp.asarray(RNG.standard_normal((S, H, N)), jnp.float32) * 0.5
    got = ssd_chunk_kernel(x, dt, a, B, C, chunk=chunk, interpret=True)
    want, _ = ssd_ref(x, dt, a, B, C)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_ssd_chunk_matches_model_layer():
    """The kernel must agree with the model's jnp ssd_mix path too."""
    from repro.configs import get_arch
    from repro.models.lm.layers import ssd_mix

    cfg = get_arch("mamba2-780m").reduced()
    S, H, P, N = 48, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x = jnp.asarray(RNG.standard_normal((1, S, H, P)), jnp.float32) * 0.5
    dt = jax.nn.softplus(jnp.asarray(RNG.standard_normal((1, S, H)), jnp.float32))
    a = -jnp.exp(jnp.asarray(RNG.standard_normal(H), jnp.float32) * 0.3)
    B = jnp.asarray(RNG.standard_normal((1, S, 1, N)), jnp.float32) * 0.5
    C = jnp.asarray(RNG.standard_normal((1, S, 1, N)), jnp.float32) * 0.5
    y_model = ssd_mix(cfg, x, dt, a, B, C, chunk=16)
    rep = H  # groups=1 -> repeat to heads
    y_kernel = ssd_chunk_kernel(
        x[0], dt[0], a,
        jnp.repeat(B[0], rep, axis=1), jnp.repeat(C[0], rep, axis=1),
        chunk=16, interpret=True)
    np.testing.assert_allclose(y_model[0], y_kernel, rtol=1e-3, atol=1e-3)
