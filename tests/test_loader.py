import numpy as np
import pytest

from repro.core import (
    DGData,
    DGraph,
    DGDataLoader,
    RecipeRegistry,
    TimeDelta,
    RECIPE_TGB_LINK,
    TRAIN_KEY,
    EVAL_KEY,
)


def _graph(n=300, t_hi=7200):
    rng = np.random.default_rng(0)
    return DGData.from_arrays(
        rng.integers(0, 30, n), rng.integers(0, 30, n),
        np.sort(rng.integers(0, t_hi, n)), granularity="s",
    )


def test_iterate_by_events():
    g = DGraph(_graph(250))
    loader = DGDataLoader(g, None, batch_size=64)
    sizes = [b.num_events for b in loader]
    assert sizes[:-1] == [64] * (len(sizes) - 1)
    assert sum(sizes) == 250
    assert len(loader) == len(sizes)


def test_iterate_by_events_drop_last():
    g = DGraph(_graph(250))
    loader = DGDataLoader(g, None, batch_size=64, drop_last=True)
    assert all(b.num_events == 64 for b in loader)


def test_iterate_by_time_windows():
    g = DGraph(_graph(300, t_hi=7200))
    loader = DGDataLoader(g, None, batch_size=None, batch_unit="h")
    batches = list(loader)
    assert len(batches) <= len(loader)
    for b in batches:
        lo, hi = b.meta["window"]
        assert hi - lo <= 3600
        assert (b["time"] >= lo).all() and (b["time"] < hi).all()
    assert sum(b.num_events for b in batches) == 300


def test_iterate_by_time_requires_real_granularity():
    d = DGData.from_arrays([0], [1], [5], granularity=TimeDelta.event())
    with pytest.raises(ValueError):
        DGDataLoader(DGraph(d), None, batch_size=None, batch_unit="h")


def test_batch_unit_must_be_coarser():
    d = _graph()
    with pytest.raises(ValueError):
        DGDataLoader(DGraph(d), None, batch_size=None, batch_unit=TimeDelta("ms"))


def test_exactly_one_iteration_mode():
    g = DGraph(_graph())
    with pytest.raises(ValueError):
        DGDataLoader(g, None, batch_size=None, batch_unit=None)
    with pytest.raises(ValueError):
        DGDataLoader(g, None, batch_size=10, batch_unit="h")


def test_full_recipe_pipeline_shapes():
    data = _graph(200)
    m = RecipeRegistry.build(RECIPE_TGB_LINK, num_nodes=30, k=4, batch_size=32,
                             eval_negatives=7)
    loader = DGDataLoader(DGraph(data), m, batch_size=32)
    with m.activate(TRAIN_KEY):
        for b in loader:
            assert b["src"].shape == (32,)
            assert b["neg"].shape == (32, 1)
            assert b["nbr_ids"].shape == (32 * 3, 4)
            assert b["batch_mask"].shape == (32,)
    m.reset_state()
    with m.activate(EVAL_KEY):
        b = next(iter(loader))
        assert b["neg"].shape == (32, 7)
        assert b["nbr_ids"].shape == (32 * (2 + 7), 4)


def test_eval_negatives_deterministic_per_epoch():
    data = _graph(100)
    m = RecipeRegistry.build(RECIPE_TGB_LINK, num_nodes=30, k=2, batch_size=32,
                             eval_negatives=5)
    loader = DGDataLoader(DGraph(data), m, batch_size=32)
    with m.activate(EVAL_KEY):
        first = [np.asarray(b["neg"]) for b in loader]
    m.reset_state()
    with m.activate(EVAL_KEY):
        second = [np.asarray(b["neg"]) for b in loader]
    for a, b in zip(first, second):
        np.testing.assert_array_equal(a, b)


# -- PrefetchLoader (device-sampling pipeline) ---------------------------


def test_prefetch_loader_yields_same_batches_on_device():
    from repro.core import PrefetchLoader

    g = DGraph(_graph(250))
    plain = list(DGDataLoader(g, None, batch_size=64))
    pre = list(PrefetchLoader(DGDataLoader(g, None, batch_size=64)))
    assert len(pre) == len(plain)
    for a, b in zip(pre, plain):
        # staged arrays live on device as int32; values must be unchanged
        np.testing.assert_array_equal(np.asarray(a["src"]), b["src"])
        np.testing.assert_array_equal(np.asarray(a["time"]), b["time"])
        assert not isinstance(a["src"], np.ndarray)


def test_prefetch_loader_propagates_producer_exception():
    from repro.core import PrefetchLoader

    def gen():
        from repro.core.batch import Batch

        yield Batch({"src": np.arange(3)})
        raise RuntimeError("producer died")

    class G:
        def __iter__(self):
            return gen()

    out = []
    with pytest.raises(RuntimeError, match="producer died"):
        for b in PrefetchLoader(G()):
            out.append(b)
    assert len(out) == 1  # the good batch arrived before the error


def test_prefetch_loader_respects_depth_and_len():
    from repro.core import PrefetchLoader

    g = DGraph(_graph(250))
    inner = DGDataLoader(g, None, batch_size=64)
    pre = PrefetchLoader(inner, prefetch=1)
    assert len(pre) == len(inner)
    with pytest.raises(ValueError):
        PrefetchLoader(inner, prefetch=0)


@pytest.mark.parametrize("device_sampling", [False, True])
def test_uniform_sampler_recipe_runs_and_checkpoints(device_sampling):
    """RECIPE_TGB_LINK with sampler='uniform' (host and device twins) must
    produce the standard neighbor contract, keep neighbors in the strict
    past, and round-trip through HookManager.state_dict."""
    data = _graph(200)
    m = RecipeRegistry.build(
        RECIPE_TGB_LINK, num_nodes=30, k=4, batch_size=50, eval_negatives=5,
        seed=0, sampler="uniform", device_sampling=device_sampling,
    )
    from repro.core.tg_hooks import DeviceUniformNeighborHook, UniformNeighborHook

    hook = next(h for h in m.hooks()
                if isinstance(h, (UniformNeighborHook, DeviceUniformNeighborHook)))
    hook.build(data.src, data.dst, data.edge_t)

    with m.activate(TRAIN_KEY):
        loader = DGDataLoader(DGraph(data), m, batch_size=50)
        batches = list(loader)
    for b in batches:
        assert b["nbr_ids"].shape == (50 * 3, 4)
        nbr_t = np.asarray(b["nbr_times"])
        mask = np.asarray(b["nbr_mask"])
        qt = np.asarray(b["seed_times"])[:, None]
        assert (nbr_t[mask] < np.broadcast_to(qt, nbr_t.shape)[mask]).all()

    # Checkpoint through the manager: restored manager replays identically.
    state = m.state_dict()
    assert any("UniformNeighborHook" in k for k in state)
    m2 = RecipeRegistry.build(
        RECIPE_TGB_LINK, num_nodes=30, k=4, batch_size=50, eval_negatives=5,
        seed=0, sampler="uniform", device_sampling=device_sampling,
    )
    m2.load_state_dict(state)
    with m.activate(TRAIN_KEY), m2.activate(TRAIN_KEY):
        la = DGDataLoader(DGraph(data), m, batch_size=50)
        lb = DGDataLoader(DGraph(data), m2, batch_size=50)
        for ba, bb in zip(la, lb):
            # The (unsaved) negative-edge RNG differs between managers, so
            # compare the deterministic src/dst seed rows: same adjacency +
            # same restored draw counter => identical neighborhoods.
            np.testing.assert_array_equal(np.asarray(ba["nbr_ids"])[:100],
                                          np.asarray(bb["nbr_ids"])[:100])


def test_sliced_split_eids_are_global():
    """Loader event ids must be global storage indices on sliced splits, so
    eid-keyed edge-feature lookups during val/test iteration hit the right
    rows of the full-stream feature table."""
    rng = np.random.default_rng(0)
    n = 200
    feats = rng.standard_normal((n, 3)).astype(np.float32)
    data = DGData.from_arrays(
        rng.integers(0, 30, n), rng.integers(0, 30, n),
        np.sort(rng.integers(0, 7200, n)), edge_feats=feats, granularity="s",
    )
    train, val, test = data.split()
    offset = 0
    for split in (train, val, test):
        assert split.eid_offset == offset
        for b in DGDataLoader(DGraph(split), None, batch_size=64):
            eids = b.meta["eids"]
            # global ids: the split's features are the table rows at eids
            np.testing.assert_array_equal(split.edge_feats[eids - offset],
                                          feats[eids])
        offset += split.num_edge_events


def test_hook_manager_accepts_legacy_class_name_state_keys():
    """Checkpoints written before ``state_key`` (device hooks keyed by
    class name) must still restore."""
    common = dict(num_nodes=30, k=4, batch_size=50, eval_negatives=5, seed=0)
    m = RecipeRegistry.build(RECIPE_TGB_LINK, device_sampling=True, **common)
    state = m.state_dict()
    legacy = {k.replace("RecencyNeighborHook", "DeviceRecencyNeighborHook"): v
              for k, v in state.items()}
    assert legacy != state  # the rename actually happened
    m.load_state_dict(legacy)  # must not raise


def test_checkpoint_interchange_across_device_sampling_flavors():
    """A HookManager checkpoint saved by the device-sampling recipe must
    restore into the host recipe (and back): hook checkpoint keys share the
    logical name because the sampler state contracts are interchangeable —
    e.g. resuming a TPU device-sampling run on a host-sampling machine."""
    data = _graph(150)
    common = dict(num_nodes=30, k=4, batch_size=50, eval_negatives=5, seed=0)
    m_dev = RecipeRegistry.build(RECIPE_TGB_LINK, device_sampling=True, **common)
    with m_dev.activate(TRAIN_KEY):
        for _ in DGDataLoader(DGraph(data), m_dev, batch_size=50):
            pass

    m_host = RecipeRegistry.build(RECIPE_TGB_LINK, **common)
    m_host.load_state_dict(m_dev.state_dict())  # device -> host
    m_dev2 = RecipeRegistry.build(RECIPE_TGB_LINK, device_sampling=True, **common)
    m_dev2.load_state_dict(m_host.state_dict())  # host -> device

    def _hook(m):
        return next(h for h in m.hooks() if "Recency" in type(h).__name__)

    seeds = np.arange(30)
    a = _hook(m_dev).sampler.sample(seeds)
    b = _hook(m_host).sampler.sample(seeds)
    c = _hook(m_dev2).sampler.sample(seeds)
    np.testing.assert_array_equal(np.asarray(a.nbr_ids), np.asarray(b.nbr_ids))
    np.testing.assert_array_equal(np.asarray(a.nbr_ids), np.asarray(c.nbr_ids))

    # Same guarantee for the uniform pair.
    mu_dev = RecipeRegistry.build(RECIPE_TGB_LINK, sampler="uniform",
                                  device_sampling=True, **common)
    from repro.core.tg_hooks import DeviceUniformNeighborHook

    next(h for h in mu_dev.hooks()
         if isinstance(h, DeviceUniformNeighborHook)).build(
             data.src, data.dst, data.edge_t)
    mu_host = RecipeRegistry.build(RECIPE_TGB_LINK, sampler="uniform", **common)
    mu_host.load_state_dict(mu_dev.state_dict())  # device -> host CSR


def test_uniform_recipe_supports_hop2():
    """Hop-2 uniform sampling builds a valid recipe (recursive frontier;
    used to raise) with the nbr2 feature lookup wired in."""
    m = RecipeRegistry.build(RECIPE_TGB_LINK, num_nodes=10, k=2,
                             batch_size=8, sampler="uniform", num_hops=2)
    hook = next(h for h in m.hooks() if hasattr(h, "num_hops"))
    assert hook.num_hops == 2
    assert "nbr2_ids" in hook.produces


def test_device_sampling_recipe_parity_with_host_recipe():
    """The full TGB-link hook pipeline must produce identical neighbor
    tensors with host numpy buffers and device-resident buffers."""
    data = _graph(200)
    common = dict(num_nodes=30, k=4, batch_size=50, eval_negatives=5, seed=0)
    m_host = RecipeRegistry.build(RECIPE_TGB_LINK, **common)
    m_dev = RecipeRegistry.build(RECIPE_TGB_LINK, device_sampling=True, **common)

    for key in (TRAIN_KEY, EVAL_KEY):
        m_host.reset_state()
        m_dev.reset_state()
        with m_host.activate(key), m_dev.activate(key):
            la = DGDataLoader(DGraph(data), m_host, batch_size=50)
            lb = DGDataLoader(DGraph(data), m_dev, batch_size=50)
            for ba, bb in zip(la, lb):
                for attr in ("nbr_ids", "nbr_times", "nbr_eids", "nbr_mask"):
                    np.testing.assert_array_equal(
                        np.asarray(ba[attr]), np.asarray(bb[attr]),
                        err_msg=f"{key}:{attr}")


def test_prefetch_loader_staging_pool_parity():
    """Explicit host-staging (the reusable-buffer pool) yields bit-identical
    batches to the unstaged path, across more batches than the pool has
    slots (so every slot is reused at least once)."""
    from repro.core import PrefetchLoader

    g = DGraph(_graph(640))
    plain = list(DGDataLoader(g, None, batch_size=64))
    staged_loader = PrefetchLoader(
        DGDataLoader(g, None, batch_size=64), prefetch=2, staging=True)
    assert staged_loader._pool is not None and staged_loader._pool.depth == 4
    staged = list(staged_loader)
    assert len(staged) == len(plain)
    for a, b in zip(staged, plain):
        for key in ("src", "dst", "time"):
            np.testing.assert_array_equal(np.asarray(a[key]), b[key])
        assert str(a["src"].dtype) == "int32"  # int64 narrowed in the pool


def test_staging_pool_slot_rotation_and_dtype():
    """Slots rotate round-robin and narrow int64; reuse only overwrites a
    slot after `depth` newer generations."""
    from repro.core.loader import _HostStagingPool

    pool = _HostStagingPool(2)
    a = pool.stage("x", np.arange(4, dtype=np.int64))
    pool.advance()
    b = pool.stage("x", np.arange(4, 8, dtype=np.int64))
    assert a.dtype == np.int32 and b.dtype == np.int32
    assert a is not b  # different generation slots
    np.testing.assert_array_equal(a, [0, 1, 2, 3])  # not clobbered by b
    pool.advance()
    c = pool.stage("x", np.full(4, 9, dtype=np.int64))
    assert c is a  # wrapped around to the first slot
    with pytest.raises(ValueError):
        _HostStagingPool(1)


def test_prefetch_loader_exception_keeps_original_traceback():
    """A hook raising mid-stream surfaces within one next(), carrying the
    producer-side frames (the raise site is debuggable, not swallowed)."""
    import traceback

    from repro.core import PrefetchLoader
    from repro.core.batch import Batch

    def _hook_that_raises():
        raise ValueError("hook exploded")

    def gen():
        yield Batch({"src": np.arange(3)})
        _hook_that_raises()

    class G:
        def __iter__(self):
            return gen()

    it = iter(PrefetchLoader(G()))
    next(it)  # the staged batch arrives first (FIFO with the error)
    with pytest.raises(ValueError, match="hook exploded") as ei:
        next(it)  # the error surfaces within ONE next()
    frames = traceback.extract_tb(ei.value.__traceback__)
    assert any(f.name == "_hook_that_raises" for f in frames)


def test_prefetch_loader_dead_producer_raises_not_hangs(monkeypatch):
    """A producer thread dying without delivering the end-of-stream
    sentinel or an error must surface as a RuntimeError on the consumer
    side instead of blocking forever."""
    import threading

    from repro.core import PrefetchLoader

    class G:
        def __iter__(self):
            return iter(())

    pre = PrefetchLoader(G())
    it = iter(pre)
    # Hard death: the producer thread never runs at all, so neither the
    # END sentinel nor an exception ever reaches the queue.
    monkeypatch.setattr(threading.Thread, "start", lambda self: None)
    with pytest.raises(RuntimeError, match="producer thread died"):
        next(it)


def test_prefetch_loader_close_is_idempotent():
    from repro.core import PrefetchLoader
    from repro.core.batch import Batch

    def gen():
        for i in range(1000):
            yield Batch({"src": np.arange(3) + i})

    class G:
        def __iter__(self):
            return gen()

    pre = PrefetchLoader(G(), prefetch=2)
    it = iter(pre)
    next(it)
    pre.close()
    pre.close()  # idempotent: second call is a no-op
    assert list(it) == []  # consumer observes a clean end of iteration
    pre.close()  # and safe again after iteration finished
