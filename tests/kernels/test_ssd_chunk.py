"""SSD-chunk family extras beyond the shared parity harness: agreement
with the model-side ``ssd_mix`` path (grouped B/C broadcast to heads)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ssd_chunk.kernel import ssd_chunk_kernel

RNG = np.random.default_rng(42)


def test_ssd_chunk_matches_model_layer():
    """The kernel must agree with the model's jnp ssd_mix path too."""
    from repro.configs import get_arch
    from repro.models.lm.layers import ssd_mix

    cfg = get_arch("mamba2-780m").reduced()
    S, H, P, N = 48, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x = jnp.asarray(RNG.standard_normal((1, S, H, P)), jnp.float32) * 0.5
    dt = jax.nn.softplus(
        jnp.asarray(RNG.standard_normal((1, S, H)), jnp.float32))
    a = -jnp.exp(jnp.asarray(RNG.standard_normal(H), jnp.float32) * 0.3)
    B = jnp.asarray(RNG.standard_normal((1, S, 1, N)), jnp.float32) * 0.5
    C = jnp.asarray(RNG.standard_normal((1, S, 1, N)), jnp.float32) * 0.5
    y_model = ssd_mix(cfg, x, dt, a, B, C, chunk=16)
    rep = H  # groups=1 -> repeat to heads
    y_kernel = ssd_chunk_kernel(
        x[0], dt[0], a,
        jnp.repeat(B[0], rep, axis=1), jnp.repeat(C[0], rep, axis=1),
        chunk=16, interpret=True)
    np.testing.assert_allclose(y_model[0], y_kernel, rtol=1e-3, atol=1e-3)
