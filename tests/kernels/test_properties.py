"""Hypothesis property tests for the custom-VJP gradients.

Randomized shapes, masks and degenerate inputs (all-masked seed rows,
empty neighbor buffers, K=1, duplicate timestamps, hop-2 padding) through
``fused_temporal_layer`` — whose backward is the flash-style Pallas kernel
— and ``segment_agg`` — whose backward is the gather VJP. Each drawn
example asserts gradient parity against plain ``jax.grad`` of the jnp
oracle within the 1e-4 f32 acceptance bound; these are exactly the corner
regimes where a hand-written backward most often diverges.

Runs under real hypothesis when installed, else the deterministic in-repo
stub (``tests/_hypothesis_stub.py``) registered by ``conftest.py``. Shape
draws come from small fixed menus so the jit cache is shared across
examples (the stub has no shrinking — failure output includes the drawn
example for replay).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.temporal_attention import (
    fused_temporal_layer,
    fused_temporal_layer_hop2,
    fused_temporal_layer_per_seed,
)
from repro.nn.graph_conv import segment_agg
from tests.kernels.families import fused_layer_inputs

TOL = dict(rtol=1e-4, atol=1e-4)
_WEIGHTS = ("time_w", "time_b", "wt_k", "wt_v", "we_k", "we_v")


def _grad_parity(loss, diff):
    """Assert grads of ``loss(diff, mode)`` agree between the kernel path
    ("interpret": the Pallas backward) and the oracle path ("ref")."""
    g_kernel = jax.grad(loss)(diff, "interpret")
    g_ref = jax.grad(loss)(diff, "ref")
    for name in diff:
        np.testing.assert_allclose(g_kernel[name], g_ref[name],
                                   err_msg=name, **TOL)


@given(
    S=st.sampled_from([8, 24]),
    K=st.sampled_from([1, 4, 6]),
    d_time=st.sampled_from([0, 8]),
    d_edge=st.sampled_from([0, 5]),
    neg_seeds=st.booleans(),
    empty=st.booleans(),
    dup_times=st.booleans(),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=10, deadline=None)
def test_fused_layer_grad_property(S, K, d_time, d_edge, neg_seeds, empty,
                                   dup_times, seed):
    """Hop-1: backward-kernel gradients match the oracle for every drawn
    shape/bias-group/degeneracy combination, on every differentiable
    operand (q, k/v tables, time/edge fold weights)."""
    rng = np.random.default_rng(seed)
    args, kw = fused_layer_inputs(
        rng, S, K, 2, 16, 30, d_time, d_edge,
        neg_seeds=S // 4 if neg_seeds else 0, empty=empty,
        dup_times=dup_times)
    q, kt, vt, seeds, seed_t, buf = args
    diff = {"q": q, "k_table": kt, "v_table": vt,
            **{n: kw[n] for n in _WEIGHTS if n in kw}}
    aux = {n: v for n, v in kw.items() if n not in diff}

    def loss(diff, mode):
        out = fused_temporal_layer(
            diff["q"], diff["k_table"], diff["v_table"], seeds, seed_t, buf,
            **{n: diff[n] for n in diff
               if n not in ("q", "k_table", "v_table")},
            **aux, mode=mode)
        return jnp.sum(jnp.sin(out))

    _grad_parity(loss, diff)


@given(
    S=st.sampled_from([4, 8]),
    K=st.sampled_from([1, 4]),
    d_time=st.sampled_from([0, 8]),
    pad_frontier=st.booleans(),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=8, deadline=None)
def test_fused_layer_hop2_grad_property(S, K, d_time, pad_frontier, seed):
    """Hop-2: frontier seeds (optionally -1-padded) at hop-1 interaction
    times — gradient parity through the flattening wrapper."""
    rng = np.random.default_rng(seed)
    args, kw = fused_layer_inputs(rng, S * K, K, 2, 16, 20, d_time, 0)
    q, kt, vt, _, _, buf = args
    lo = -1 if pad_frontier else 0
    frontier = jnp.asarray(rng.integers(lo, 20, (S, K)), jnp.int32)
    f_times = jnp.asarray(rng.integers(0, 50, (S, K)), jnp.int32)
    diff = {"q": q, "k_table": kt, "v_table": vt}
    aux = {n: v for n, v in kw.items() if n not in diff}

    def loss(diff, mode):
        out = fused_temporal_layer_hop2(
            diff["q"], diff["k_table"], diff["v_table"], frontier, f_times,
            buf, **aux, mode=mode)
        return jnp.sum(jnp.sin(out))

    _grad_parity(loss, diff)


@given(
    S=st.sampled_from([4, 8]),
    K=st.sampled_from([1, 4]),
    d_time=st.sampled_from([0, 8]),
    mask_all=st.booleans(),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=8, deadline=None)
def test_fused_layer_per_seed_grad_property(S, K, d_time, mask_all, seed):
    """Per-seed-table: each seed over its own K computed rows (2-layer
    TGAT's final hop) — gradient parity including all-masked seeds."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((S, 2, 16)) * 0.25, jnp.float32)
    k_rows = jnp.asarray(rng.standard_normal((S * K, 2, 16)) * 0.25,
                         jnp.float32)
    v_rows = jnp.asarray(rng.standard_normal((S * K, 2, 16)) * 0.25,
                         jnp.float32)
    seed_t = jnp.asarray(rng.integers(50, 120, S), jnp.int32)
    nbr_t = jnp.asarray(rng.integers(0, 50, (S, K)), jnp.int32)
    mask = np.asarray(rng.integers(0, 2, (S, K)), bool)
    if mask_all:
        mask[0] = False  # a fully-masked seed row
    mask = jnp.asarray(mask)
    kw = {}
    if d_time:
        kw = dict(
            time_w=jnp.asarray(rng.standard_normal(d_time) * 0.1,
                               jnp.float32),
            time_b=jnp.asarray(rng.standard_normal(d_time) * 0.1,
                               jnp.float32),
            wt_k=jnp.asarray(rng.standard_normal((d_time, 32)) * 0.25,
                             jnp.float32),
            wt_v=jnp.asarray(rng.standard_normal((d_time, 32)) * 0.25,
                             jnp.float32),
        )
    diff = {"q": q, "k_rows": k_rows, "v_rows": v_rows}

    def loss(diff, mode):
        out = fused_temporal_layer_per_seed(
            diff["q"], diff["k_rows"], diff["v_rows"], seed_t, nbr_t, mask,
            **kw, mode=mode)
        return jnp.sum(jnp.sin(out))

    _grad_parity(loss, diff)


@given(
    E=st.sampled_from([1, 40, 300]),
    D=st.sampled_from([1, 8]),
    G=st.sampled_from([1, 16]),
    all_padding=st.booleans(),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=10, deadline=None)
def test_segment_agg_grad_property(E, D, G, all_padding, seed):
    """segment_agg's gather VJP matches jax.grad of the scatter oracle,
    including fully-padded (-1) id vectors and singleton segments."""
    rng = np.random.default_rng(seed)
    data = jnp.asarray(rng.standard_normal((E, D)), jnp.float32)
    ids = (np.full(E, -1, np.int32) if all_padding
           else rng.integers(-1, G, E).astype(np.int32))
    ids = jnp.asarray(ids)

    def loss(data, mode):
        return jnp.sum(jnp.sin(segment_agg(data, ids, G, mode=mode)))

    g_kernel = jax.grad(loss)(data, "interpret")
    g_ref = jax.grad(loss)(data, "ref")
    np.testing.assert_allclose(g_kernel, g_ref, **TOL)
