"""Temporal-attention family extras beyond the shared parity harness:
exact-zero guarantees for empty neighborhoods, end-to-end device-sampler
wiring, the full backward-kernel gradient surface (bias-fold weights
included), the hop-2-aware and per-seed-table variants, and the
duplicate-neighbor read-modify-write accumulation path."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.temporal_attention import (
    fused_recency_attention_kernel,
    fused_temporal_layer,
    fused_temporal_layer_hop2,
    fused_temporal_layer_kernel,
    fused_temporal_layer_per_seed,
    temporal_attention_kernel,
)
from repro.kernels.temporal_attention.ref import temporal_attention_ref
from tests.kernels.families import fused_layer_inputs

RNG = np.random.default_rng(42)


def test_temporal_attention_empty_neighborhood_is_zero():
    S, K, H, D = 8, 4, 2, 16
    q = jnp.asarray(RNG.standard_normal((S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((S, K, H, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((S, K, H, D)), jnp.float32)
    mask = jnp.zeros((S, K), bool)
    out = temporal_attention_kernel(q, k, v, mask, block_s=8, interpret=True)
    np.testing.assert_allclose(out, 0.0)


def test_fused_recency_attention_consumes_device_sampler_state():
    """End-to-end: DeviceRecencySampler buffers feed the fused kernel and
    agree with sampling + explicit gather + the plain oracle."""
    from repro.core.device_sampler import DeviceRecencySampler

    rng = np.random.default_rng(0)
    N, K, H, D, B = 30, 5, 2, 16, 40
    s = DeviceRecencySampler(N, K)
    src = rng.integers(0, N, B)
    dst = rng.integers(0, N, B)
    t = np.sort(rng.integers(0, 100, B))
    s.update(src, dst, t)

    seeds = jnp.asarray(rng.integers(0, N, 16), jnp.int32)
    q = jnp.asarray(rng.standard_normal((16, H, D)), jnp.float32)
    tbl = jnp.asarray(rng.standard_normal((N + 1, H, D)), jnp.float32)
    got = fused_recency_attention_kernel(q, tbl, tbl, seeds, s.buffer_ids,
                                         block_s=16, interpret=True)

    blk = s.sample(seeds)
    safe = jnp.maximum(blk.nbr_ids, 0)
    want = temporal_attention_ref(q, tbl[safe], tbl[safe], blk.mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_temporal_layer_empty_rows_are_zero():
    (q, kt, vt, seeds, seed_t, _), kw = fused_layer_inputs(
        np.random.default_rng(1), 16, 4, 2, 16, 20, 8, 0)
    buf = jnp.asarray(np.stack([np.full((20, 4), -1), np.zeros((20, 4)),
                                np.full((20, 4), -1)], -1), jnp.int32)
    kw.pop("block_s")
    out = fused_temporal_layer_kernel(q, kt, vt, seeds, seed_t, buf,
                                      block_s=8, interpret=True, **kw)
    np.testing.assert_allclose(out, 0.0)


def test_fused_temporal_layer_negative_seeds_zero_rows_and_grads():
    """Hop-2 padding contract: seeds < 0 produce exactly-zero output rows,
    and contribute exactly zero to every gradient."""
    rng = np.random.default_rng(3)
    args, kw = fused_layer_inputs(rng, 12, 4, 2, 16, 20, 8, 0)
    q, kt, vt, seeds, seed_t, buf = args
    neg = jnp.asarray(np.where(np.arange(12) % 3 == 0, -1,
                               np.asarray(seeds)), jnp.int32)
    out = fused_temporal_layer(q, kt, vt, neg, seed_t, buf,
                               mode="interpret", **kw)
    np.testing.assert_allclose(out[::3], 0.0)

    def loss(q, s):
        o = fused_temporal_layer(q, kt, vt, s, seed_t, buf,
                                 mode="interpret", **kw)
        return jnp.sum(jnp.sin(o))

    gq = jax.grad(loss)(q, neg)
    np.testing.assert_allclose(gq[::3], 0.0)


def test_fused_temporal_layer_full_gradient_surface():
    """Backward kernel parity on *every* differentiable operand, including
    the in-kernel time/edge bias-fold weights — the gradients the oracle
    backward used to produce by materializing (S, K, ·) intermediates."""
    rng = np.random.default_rng(7)
    args, kw = fused_layer_inputs(rng, 24, 6, 2, 16, 30, 12, 5, w_scale=0.2)
    q, kt, vt, seeds, seed_t, buf = args
    names = ["q", "k_table", "v_table", "time_w", "time_b", "wt_k", "wt_v",
             "we_k", "we_v"]
    diff = {"q": q, "k_table": kt, "v_table": vt,
            **{n: kw[n] for n in names[3:]}}

    def loss(diff, mode):
        out = fused_temporal_layer(
            diff["q"], diff["k_table"], diff["v_table"], seeds, seed_t, buf,
            time_w=diff["time_w"], time_b=diff["time_b"],
            wt_k=diff["wt_k"], wt_v=diff["wt_v"],
            edge_feats=kw["edge_feats"], we_k=diff["we_k"],
            we_v=diff["we_v"], block_s=8, mode=mode)
        return jnp.sum(jnp.sin(out))

    g_kernel = jax.grad(loss)(diff, "interpret")
    g_ref = jax.grad(loss)(diff, "ref")
    for n in names:
        np.testing.assert_allclose(g_kernel[n], g_ref[n], rtol=1e-4,
                                   atol=1e-4, err_msg=n)


def test_fused_temporal_layer_duplicate_neighbor_rmw():
    """A buffer row listing the *same* neighbor in several slots exercises
    the backward's sequential DMA read-modify-write into dk/dv tables —
    the accumulation must not lose updates."""
    rng = np.random.default_rng(11)
    args, kw = fused_layer_inputs(rng, 8, 6, 2, 16, 10, 8, 0)
    kw.pop("block_s")
    q, kt, vt, seeds, seed_t, buf = args
    dup = np.array(buf)
    dup[:, :4, 0] = 3  # same neighbor id in four slots of every row
    dup = jnp.asarray(dup)

    def loss(kt, vt, mode):
        o = fused_temporal_layer(q, kt, vt, seeds, seed_t, dup,
                                 block_s=8, mode=mode, **kw)
        return jnp.sum(jnp.sin(o))

    gk = jax.grad(loss, (0, 1))(kt, vt, "interpret")
    gr = jax.grad(loss, (0, 1))(kt, vt, "ref")
    for name, a, b in zip(("dk_table", "dv_table"), gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4, err_msg=name)


def test_fused_temporal_layer_hop2_variant():
    """Hop-2 wrapper: an (S, K) frontier (with -1 padding) flattens onto
    the hop-2-aware kernel; forward and gradients match the ref path."""
    rng = np.random.default_rng(5)
    S, K, H, D, N = 6, 4, 2, 16, 20
    args, kw = fused_layer_inputs(rng, S * K, K, H, D, N, 8, 0)
    kw.pop("block_s")
    q, kt, vt, _, _, buf = args
    frontier = jnp.asarray(rng.integers(-1, N, (S, K)), jnp.int32)
    f_times = jnp.asarray(rng.integers(0, 50, (S, K)), jnp.int32)

    def loss(q, kt, mode):
        o = fused_temporal_layer_hop2(q, kt, vt, frontier, f_times, buf,
                                      block_s=8, mode=mode, **kw)
        return jnp.sum(jnp.sin(o))

    out_k = fused_temporal_layer_hop2(q, kt, vt, frontier, f_times, buf,
                                      block_s=8, mode="interpret", **kw)
    out_r = fused_temporal_layer_hop2(q, kt, vt, frontier, f_times, buf,
                                      mode="ref", **kw)
    np.testing.assert_allclose(out_k, out_r, rtol=2e-5, atol=2e-5)
    pad = np.asarray(frontier.reshape(-1)) < 0
    np.testing.assert_allclose(np.asarray(out_k)[pad], 0.0)
    gk = jax.grad(loss, (0, 1))(q, kt, "interpret")
    gr = jax.grad(loss, (0, 1))(q, kt, "ref")
    for name, a, b in zip(("dq", "dk_table"), gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4, err_msg=name)


def test_fused_temporal_layer_per_seed_variant():
    """Per-seed-table wrapper: seeds attend over their own K rows; masked
    slots drop out; an all-masked seed yields a zero row; gradients flow
    into the per-seed rows and match the ref path."""
    rng = np.random.default_rng(9)
    S, K, H, D = 6, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((S, H, D)) * 0.25, jnp.float32)
    k_rows = jnp.asarray(rng.standard_normal((S * K, H, D)) * 0.25,
                         jnp.float32)
    v_rows = jnp.asarray(rng.standard_normal((S * K, H, D)) * 0.25,
                         jnp.float32)
    seed_t = jnp.asarray(rng.integers(50, 120, S), jnp.int32)
    nbr_t = jnp.asarray(rng.integers(0, 50, (S, K)), jnp.int32)
    mask = np.asarray(rng.integers(0, 2, (S, K)), bool)
    mask[2] = False  # an all-masked seed
    mask = jnp.asarray(mask)
    kw = dict(
        time_w=jnp.asarray(rng.standard_normal(8) * 0.1, jnp.float32),
        time_b=jnp.asarray(rng.standard_normal(8) * 0.1, jnp.float32),
        wt_k=jnp.asarray(rng.standard_normal((8, H * D)) * 0.25, jnp.float32),
        wt_v=jnp.asarray(rng.standard_normal((8, H * D)) * 0.25, jnp.float32),
    )

    def run(q, kr, vr, mode):
        return fused_temporal_layer_per_seed(
            q, kr, vr, seed_t, nbr_t, mask, block_s=8, mode=mode, **kw)

    out_k = run(q, k_rows, v_rows, "interpret")
    out_r = run(q, k_rows, v_rows, "ref")
    np.testing.assert_allclose(out_k, out_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(out_k[2], 0.0)

    def loss(q, kr, vr, mode):
        return jnp.sum(jnp.sin(run(q, kr, vr, mode)))

    gk = jax.grad(loss, (0, 1, 2))(q, k_rows, v_rows, "interpret")
    gr = jax.grad(loss, (0, 1, 2))(q, k_rows, v_rows, "ref")
    for name, a, b in zip(("dq", "dk_rows", "dv_rows"), gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4, err_msg=name)
    # masked rows get zero gradient (they never enter the softmax)
    flat_mask = np.asarray(mask).reshape(-1)
    np.testing.assert_allclose(np.asarray(gk[1])[~flat_mask], 0.0)
