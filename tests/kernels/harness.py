"""Shared kernel-parity harness for every Pallas kernel family.

Each family under ``src/repro/kernels/`` ships three things: a Pallas
kernel, a pure-jnp oracle (``ref.py``), and a public ``ops.py`` wrapper
with a ``mode`` dispatch argument. This module turns that contract into a
single reusable test surface — the per-family sweeps in
``tests/kernels/families.py`` are pure data, and ``test_parity.py`` runs
every (family, case) pair through the same three assertion engines:

* **forward parity** — the kernel body, executed on CPU through the Pallas
  interpreter (``mode="interpret"``), must match the oracle within the
  dtype tolerance policy;
* **dispatch** — ``mode="interpret"`` must place a ``pallas_call`` in the
  traced jaxpr and ``mode="ref"`` must not, so CI provably executes kernel
  bodies (no skips) and the oracle fallback provably avoids them;
* **gradient parity** — ``jax.grad`` through the op must match
  ``jax.grad`` of the oracle. Families with a hand-written backward
  (``fused_temporal_layer``'s flash-style backward kernel,
  ``segment_sum``'s gather VJP) are differentiated on the kernel path
  (``grad_mode="interpret"``); families without one (``pallas_call`` has
  no autodiff rule) are differentiated on the dispatch path a CPU train
  step actually takes (``grad_mode="ref"``).

Tolerances: forward parity allows 2e-5 (f32) / 2e-2 (bf16) relative+
absolute; gradient parity allows 1e-4 (f32) — the acceptance bound for the
fused-layer backward. Cases may override either bound (looser physics, e.g.
the SSD recurrence, document why at the case site).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

FWD_TOL = {jnp.bfloat16: dict(rtol=2e-2, atol=2e-2),
           "default": dict(rtol=2e-5, atol=2e-5)}
GRAD_TOL = dict(rtol=1e-4, atol=1e-4)


def forward_tol(dtype):
    """Forward-parity tolerance policy for ``dtype`` (bf16 is loose: the
    kernel accumulates in f32 but inputs/outputs round to 8-bit mantissas).
    """
    return FWD_TOL.get(dtype, FWD_TOL["default"])


@dataclasses.dataclass(frozen=True)
class Case:
    """One parametrized input for a kernel family.

    ``build(rng)`` returns ``(args, kw)`` for the family op; ``kw`` may
    include kernel-only tuning knobs (block sizes), which the harness
    strips before calling the oracle. ``dtype`` drives the tolerance
    policy; ``tol``/``grad_tol`` override it (dict of rtol/atol).
    """

    name: str
    build: Callable[[np.random.Generator], tuple]
    dtype: Any = jnp.float32
    tol: dict | None = None
    grad_tol: dict | None = None


@dataclasses.dataclass(frozen=True)
class KernelFamily:
    """A kernel family's test contract: the public op, its oracle, the
    parity/gradient case sweeps, and how to differentiate it.

    ``kernel_only``: kw names consumed by the kernel path only (stripped
    for the oracle). ``grad_argnums``: positional arg indices to
    differentiate; ``grad_mode``: the dispatch mode whose VJP is under
    test. ``grad_cases`` defaults to every case; heavy sweeps list a
    subset.
    """

    name: str
    op: Callable
    ref: Callable
    cases: tuple
    kernel_only: frozenset = frozenset()
    grad_argnums: tuple = ()
    grad_mode: str = "interpret"
    grad_cases: tuple | None = None

    def ref_kw(self, kw: dict) -> dict:
        """Strip kernel-only tuning knobs from an op kwargs dict."""
        return {k: v for k, v in kw.items() if k not in self.kernel_only}

    def rng(self, case: Case) -> np.random.Generator:
        """Deterministic per-(family, case) generator."""
        return np.random.default_rng(
            abs(hash((self.name, case.name))) % (2 ** 32))


def _has_primitive(jaxpr, name: str) -> bool:
    """Recursively search a (Closed)Jaxpr for a primitive by name."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            return True
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for item in vs:
                if hasattr(item, "eqns") or hasattr(item, "jaxpr"):
                    if _has_primitive(item, name):
                        return True
    return False


def assert_forward_parity(family: KernelFamily, case: Case):
    """Engine 1: interpret-mode kernel output == oracle output."""
    args, kw = case.build(family.rng(case))
    got = family.op(*args, mode="interpret", **kw)
    want = family.ref(*args, **family.ref_kw(kw))
    tol = case.tol or forward_tol(case.dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def assert_interpret_dispatch(family: KernelFamily, case: Case):
    """Engine 2: mode="interpret" traces a pallas_call; mode="ref" doesn't.

    This is the no-CPU-skips guarantee: tier-1 CI runs on CPU, so kernel
    bodies execute only if the interpret path actually reaches pallas_call.
    """
    args, kw = case.build(family.rng(case))
    # Close over the args (some, like segment counts, are static ints the
    # op's jit would reject as tracers).
    interp = jax.make_jaxpr(
        lambda: family.op(*args, mode="interpret", **kw))()
    assert _has_primitive(interp, "pallas_call"), (
        f"{family.name}: interpret mode never reached a pallas_call")
    ref = jax.make_jaxpr(lambda: family.op(*args, mode="ref", **kw))()
    assert not _has_primitive(ref, "pallas_call"), (
        f"{family.name}: ref mode traced a pallas_call")


def assert_grad_parity(family: KernelFamily, case: Case):
    """Engine 3: jax.grad through the op (on ``family.grad_mode``) matches
    jax.grad of the oracle, for every argnum in ``family.grad_argnums``.

    The loss is sum(sin(out)) — a non-uniform cotangent, so transposition
    bugs that a plain sum would cancel still surface.
    """
    args, kw = case.build(family.rng(case))
    argnums = family.grad_argnums

    def loss_op(*diff):
        a = list(args)
        for i, d in zip(argnums, diff):
            a[i] = d
        return jnp.sum(jnp.sin(
            family.op(*a, mode=family.grad_mode, **kw).astype(jnp.float32)))

    def loss_ref(*diff):
        a = list(args)
        for i, d in zip(argnums, diff):
            a[i] = d
        return jnp.sum(jnp.sin(
            family.ref(*a, **family.ref_kw(kw)).astype(jnp.float32)))

    diff = tuple(args[i] for i in argnums)
    got = jax.grad(loss_op, tuple(range(len(diff))))(*diff)
    want = jax.grad(loss_ref, tuple(range(len(diff))))(*diff)
    tol = case.grad_tol or GRAD_TOL
    for i, (g, w) in enumerate(zip(got, want)):
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            err_msg=f"{family.name}/{case.name} argnum {argnums[i]}", **tol)
