"""Kernel-family test declarations for the shared parity harness.

Pure data: each ``KernelFamily`` names the public op, the jnp oracle, and
a sweep of ``Case``s (shapes, dtypes, degenerate inputs). The assertion
engines live in ``tests/kernels/harness.py``; the parametrized runner in
``tests/kernels/test_parity.py``. Family-specific extras that don't fit
the shared contract (exact-zero guarantees, end-to-end sampler wiring,
model-layer parity) live in the per-family ``test_*.py`` modules.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.segment_reduce import segment_sum
from repro.kernels.segment_reduce.ref import segment_sum_ref
from repro.kernels.ssd_chunk import ssd
from repro.kernels.ssd_chunk.ref import ssd_ref
from repro.kernels.temporal_attention import (
    fused_recency_attention,
    fused_temporal_layer,
    temporal_attention,
)
from repro.kernels.temporal_attention.ref import (
    fused_recency_attention_ref,
    fused_temporal_layer_ref,
    temporal_attention_ref,
)
from tests.kernels.harness import Case, KernelFamily


def _normal(rng, shape, dtype=jnp.float32, scale=1.0):
    """Gaussian test array of ``shape`` in ``dtype``."""
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# --- temporal_attention: pre-gathered (S, K, H, D) kv + mask ---------------

def _ta_case(S, K, H, D, dtype=jnp.float32, empty=False):
    def build(rng):
        q = _normal(rng, (S, H, D), dtype)
        k = _normal(rng, (S, K, H, D), dtype)
        v = _normal(rng, (S, K, H, D), dtype)
        mask = (jnp.zeros((S, K), bool) if empty
                else jnp.asarray(rng.random((S, K)) > 0.4))
        return (q, k, v, mask), dict(block_s=32)
    return build


TEMPORAL_ATTENTION = KernelFamily(
    name="temporal_attention",
    op=temporal_attention,
    ref=temporal_attention_ref,
    kernel_only=frozenset({"block_s"}),
    grad_argnums=(0, 1, 2),
    grad_mode="ref",  # no hand-written backward (ROADMAP); ref path trains
    cases=(
        Case("s100_k16", _ta_case(100, 16, 2, 32)),
        Case("s256_k32", _ta_case(256, 32, 4, 64)),
        Case("s33_k8_h1", _ta_case(33, 8, 1, 16)),
        Case("s128_d100", _ta_case(128, 20, 2, 100)),
        Case("s100_k16_bf16", _ta_case(100, 16, 2, 32, jnp.bfloat16),
             dtype=jnp.bfloat16),
        Case("s33_k8_bf16", _ta_case(33, 8, 1, 16, jnp.bfloat16),
             dtype=jnp.bfloat16),
        Case("all_masked", _ta_case(8, 4, 2, 16, empty=True)),
    ),
    grad_cases=(Case("s33_k8_h1", _ta_case(33, 8, 1, 16)),),
)


# --- fused_recency_attention: ids-only buffer + node k/v tables ------------

def _fra_case(S, K, H, D, N, empty=False):
    def build(rng):
        q = _normal(rng, (S, H, D))
        k_table = _normal(rng, (N, H, D))
        v_table = _normal(rng, (N, H, D))
        seeds = jnp.asarray(rng.integers(0, N, S), jnp.int32)
        if empty:
            buf = np.full((N, K), -1, np.int32)  # nothing inserted yet
        else:
            buf = rng.integers(-1, N, (N, K)).astype(np.int32)
            buf[N // 3] = -1  # one node with a fully empty buffer
        return (q, k_table, v_table, seeds, jnp.asarray(buf)), dict(
            block_s=min(32, S))
    return build


FUSED_RECENCY = KernelFamily(
    name="fused_recency_attention",
    op=fused_recency_attention,
    ref=fused_recency_attention_ref,
    kernel_only=frozenset({"block_s"}),
    grad_argnums=(0, 1, 2),
    grad_mode="ref",  # in-kernel gather fwd only; ref path trains
    cases=(
        Case("s64_n100", _fra_case(64, 8, 2, 32, 100)),
        Case("s37_k20", _fra_case(37, 20, 1, 16, 50)),
        Case("s128_n300", _fra_case(128, 16, 2, 64, 300)),
        Case("empty_buffer", _fra_case(8, 4, 2, 16, 20, empty=True)),
    ),
    grad_cases=(Case("s37_k20", _fra_case(37, 20, 1, 16, 50)),),
)


# --- fused_temporal_layer: packed buffer + in-kernel time/edge folds -------

def fused_layer_inputs(rng, S, K, H, D, N, d_time, d_edge, E=300,
                       w_scale=0.25, neg_seeds=0, empty=False,
                       dup_times=False):
    """Randomized fused-layer inputs (the family's shared generator; the
    Hypothesis property tests drive the same function with drawn shapes).

    Buffer ids/eids include -1 padding and one fully-empty row;
    ``neg_seeds`` marks that many seeds as hop-2 padding (-1); ``empty``
    blanks the whole buffer; ``dup_times`` collapses all timestamps.
    Glorot-magnitude (~0.25) projections keep the softmax un-saturated —
    the training regime; unit-scale weights would amplify the kernel's
    ~1e-5 forward rounding through near-one-hot attention.
    """
    q = _normal(rng, (S, H, D), scale=w_scale)
    kt = _normal(rng, (N, H, D), scale=w_scale)
    vt = _normal(rng, (N, H, D), scale=w_scale)
    seeds = np.asarray(rng.integers(0, N, S), np.int32)
    if neg_seeds:
        seeds[rng.choice(S, size=min(neg_seeds, S), replace=False)] = -1
    seed_t = jnp.asarray(rng.integers(50, 120, S), jnp.int32)
    buf = np.stack([
        rng.integers(-1, N, (N, K)),       # neighbor ids (-1 = empty)
        rng.integers(0, 50, (N, K)),       # times
        rng.integers(-1, E, (N, K)),       # edge ids (-1 = featureless)
    ], axis=-1).astype(np.int32)
    buf[N // 4] = -1                        # a fully empty row
    if dup_times:
        buf[:, :, 1] = 17
    if empty:
        buf[:, :, 0] = -1
    kw = dict(block_s=16)
    if d_time:
        kw.update(
            time_w=_normal(rng, (d_time,), scale=0.1),
            time_b=_normal(rng, (d_time,), scale=0.1),
            wt_k=_normal(rng, (d_time, H * D), scale=w_scale),
            wt_v=_normal(rng, (d_time, H * D), scale=w_scale),
        )
    if d_edge:
        kw.update(
            edge_feats=_normal(rng, (E, d_edge)),
            we_k=_normal(rng, (d_edge, H * D), scale=w_scale),
            we_v=_normal(rng, (d_edge, H * D), scale=w_scale),
        )
    return (q, kt, vt, jnp.asarray(seeds), seed_t, jnp.asarray(buf)), kw


def _ftl_case(S, K, H, D, N, d_time, d_edge, **gen_kw):
    def build(rng):
        return fused_layer_inputs(rng, S, K, H, D, N, d_time, d_edge,
                                  **gen_kw)
    return build


FUSED_LAYER = KernelFamily(
    name="fused_temporal_layer",
    op=fused_temporal_layer,
    ref=fused_temporal_layer_ref,
    kernel_only=frozenset({"block_s"}),
    grad_argnums=(0, 1, 2),
    grad_mode="interpret",  # flash-style backward *kernel* under test
    cases=(
        Case("time_edge", _ftl_case(64, 8, 2, 32, 100, 24, 12)),
        Case("time_only_unaligned", _ftl_case(37, 20, 1, 16, 50, 100, 0)),
        Case("edge_only", _ftl_case(48, 16, 2, 50, 80, 0, 8)),
        Case("plain_gather", _ftl_case(33, 4, 2, 16, 40, 0, 0)),
        Case("hop2_neg_seeds", _ftl_case(40, 6, 2, 16, 30, 12, 5,
                                         neg_seeds=9)),
        Case("empty_buffer", _ftl_case(16, 4, 2, 16, 20, 8, 0, empty=True)),
    ),
    grad_cases=(
        Case("time_edge_grads", _ftl_case(24, 6, 2, 16, 30, 12, 5)),
        Case("hop2_neg_seed_grads", _ftl_case(24, 6, 2, 16, 30, 12, 5,
                                              neg_seeds=6)),
        Case("k1_grads", _ftl_case(16, 1, 2, 16, 20, 8, 0)),
        Case("empty_buffer_grads", _ftl_case(16, 4, 2, 16, 20, 8, 0,
                                             empty=True)),
    ),
)


# --- flash_attention: blocked online-softmax (GQA/causal/SWA) --------------

def _fa_case(B, H, Hk, Sq, Skv, D, causal, window, dtype=jnp.float32):
    def build(rng):
        q = _normal(rng, (B, H, Sq, D), dtype)
        k = _normal(rng, (B, Hk, Skv, D), dtype)
        v = _normal(rng, (B, Hk, Skv, D), dtype)
        return (q, k, v), dict(causal=causal, window=window, block_q=32,
                               block_k=32)
    return build


FLASH = KernelFamily(
    name="flash_attention",
    op=flash_attention,
    ref=flash_attention_ref,
    kernel_only=frozenset({"block_q", "block_k"}),
    grad_argnums=(0, 1, 2),
    grad_mode="ref",  # no hand-written backward (ROADMAP); ref path trains
    cases=(
        Case("base", _fa_case(2, 4, 2, 64, 64, 32, True, 0)),
        Case("unaligned_seq", _fa_case(1, 4, 4, 60, 60, 64, True, 0)),
        Case("sliding_window", _fa_case(2, 8, 2, 128, 128, 64, True, 32)),
        Case("chunked_decode", _fa_case(1, 2, 1, 32, 96, 32, True, 0)),
        Case("bidirectional", _fa_case(2, 4, 2, 64, 64, 32, False, 0)),
        Case("gqa_d128", _fa_case(1, 16, 4, 128, 128, 128, True, 0)),
        Case("base_bf16", _fa_case(2, 4, 2, 64, 64, 32, True, 0,
                                   jnp.bfloat16), dtype=jnp.bfloat16),
        Case("window_bf16", _fa_case(2, 8, 2, 128, 128, 64, True, 32,
                                     jnp.bfloat16), dtype=jnp.bfloat16),
    ),
    grad_cases=(Case("base", _fa_case(2, 4, 2, 64, 64, 32, True, 0)),),
)


# --- segment_reduce: sorted-segment sum as one-hot matmuls -----------------

def _ss_case(E, D, G, block_e, with_padding=True):
    def build(rng):
        data = _normal(rng, (E, D))
        lo = -1 if with_padding else 0
        seg = np.sort(rng.integers(lo, G, E)).astype(np.int32)
        return (data, jnp.asarray(seg), G), dict(block_e=block_e)
    return build


SEGMENT_SUM = KernelFamily(
    name="segment_sum",
    op=segment_sum,
    ref=segment_sum_ref,
    kernel_only=frozenset({"block_e"}),
    grad_argnums=(0,),
    grad_mode="interpret",  # gather-based custom VJP under test
    cases=(
        Case("e500", _ss_case(500, 16, 64, 128),
             tol=dict(rtol=1e-4, atol=1e-4)),
        Case("e1000", _ss_case(1000, 64, 128, 256),
             tol=dict(rtol=1e-4, atol=1e-4)),
        Case("e77_small", _ss_case(77, 8, 16, 32),
             tol=dict(rtol=1e-4, atol=1e-4)),
        Case("e512_d128", _ss_case(512, 128, 256, 128),
             tol=dict(rtol=1e-4, atol=1e-4)),
    ),
    grad_cases=(
        Case("e500_grads", _ss_case(500, 16, 64, 128)),
        Case("e77_grads", _ss_case(77, 8, 16, 32)),
    ),
)


# --- ssd_chunk: mamba2 SSD intra-chunk + state recurrence ------------------

def _ssd_ref_y(x, dt, a, B, C):
    """Oracle wrapper: the op returns y only; the ref also returns state."""
    y, _ = ssd_ref(x, dt, a, B, C)
    return y


def _ssd_case(S, H, P, N, chunk):
    def build(rng):
        x = _normal(rng, (S, H, P), scale=0.5)
        dt = jax.nn.softplus(_normal(rng, (S, H)))
        a = -jnp.exp(_normal(rng, (H,), scale=0.3))
        B = _normal(rng, (S, H, N), scale=0.5)
        C = _normal(rng, (S, H, N), scale=0.5)
        return (x, dt, a, B, C), dict(chunk=chunk)
    return build


# Chunked scan vs exact recurrence: associativity reordering compounds over
# the sequence, hence the documented 1e-3 bound (matches the physics, not a
# kernel bug — tightening it fails the *reference* reassociation too).
_SSD_TOL = dict(rtol=1e-3, atol=1e-3)

SSD = KernelFamily(
    name="ssd_chunk",
    op=ssd,
    ref=_ssd_ref_y,
    kernel_only=frozenset({"chunk"}),
    grad_argnums=(0, 3, 4),
    grad_mode="ref",  # no hand-written backward (ROADMAP); ref path trains
    cases=(
        Case("s64", _ssd_case(64, 2, 16, 32, 16), tol=_SSD_TOL),
        Case("s100_unaligned", _ssd_case(100, 4, 32, 64, 32), tol=_SSD_TOL),
        Case("single_chunk", _ssd_case(96, 1, 8, 16, 96), tol=_SSD_TOL),
        Case("s128_wide", _ssd_case(128, 2, 64, 128, 128), tol=_SSD_TOL),
    ),
    grad_cases=(Case("s64", _ssd_case(64, 2, 16, 32, 16)),),
)


FAMILIES = (TEMPORAL_ATTENTION, FUSED_RECENCY, FUSED_LAYER, FLASH,
            SEGMENT_SUM, SSD)
