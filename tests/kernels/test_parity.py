"""Parametrized parity runner: every kernel family × every declared case
through the three shared assertion engines (forward parity vs the jnp
oracle, interpret-mode dispatch — zero CPU skips — and gradient parity).
See ``tests/kernels/harness.py`` for the contract and tolerance policy."""

import pytest

from tests.kernels.families import FAMILIES
from tests.kernels.harness import (
    assert_forward_parity,
    assert_grad_parity,
    assert_interpret_dispatch,
)

FWD = [pytest.param(f, c, id=f"{f.name}-{c.name}")
       for f in FAMILIES for c in f.cases]
GRAD = [pytest.param(f, c, id=f"{f.name}-{c.name}")
        for f in FAMILIES for c in (f.grad_cases or f.cases)]
DISPATCH = [pytest.param(f, f.cases[0], id=f.name) for f in FAMILIES]


@pytest.mark.parametrize("family,case", FWD)
def test_forward_parity(family, case):
    assert_forward_parity(family, case)


@pytest.mark.parametrize("family,case", DISPATCH)
def test_interpret_dispatch(family, case):
    assert_interpret_dispatch(family, case)


@pytest.mark.parametrize("family,case", GRAD)
def test_grad_parity(family, case):
    assert_grad_parity(family, case)
