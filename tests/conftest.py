import sys

import numpy as np
import pytest

import jax

jax.config.update("jax_platform_name", "cpu")

try:  # property tests prefer real hypothesis when installed
    import hypothesis  # noqa: F401
except ImportError:  # fall back to the deterministic in-repo stub
    from tests import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub


@pytest.fixture(scope="session")
def tiny_graph():
    from repro.data import generate

    return generate("tiny")


@pytest.fixture(scope="session")
def small_stream(tiny_graph):
    """First ~600 events of the tiny graph (keeps model tests fast)."""
    return tiny_graph.slice_events(0, 600)
