"""Per-arch smoke tests (reduced configs, CPU): one forward/train step with
shape + finiteness asserts, and prefill/decode consistency against the
full-sequence forward (validates every cache path incl. RoPE offsets,
sliding-window rings, SSM states, cross-attention caches)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES
from repro.models.lm import model as M
from repro.optim import AdamWConfig
from repro.train.lm_train import init_opt_state, make_train_step

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, B=2, S=24, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family in ("audio", "vlm"):
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = ARCHS[arch].reduced()
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = M.forward(params, cfg, batch["tokens"],
                            frontend=batch.get("frontend"), kv_block=8)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_decreases_loss(arch):
    cfg = ARCHS[arch].reduced()
    params = M.init(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=2e-3), kv_block=8))
    batch = _batch(cfg)
    losses = []
    for _ in range(4):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]  # overfits one batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_matches_forward(arch):
    cfg = ARCHS[arch].reduced()
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    full, _ = M.forward(params, cfg, batch["tokens"],
                        frontend=batch.get("frontend"), kv_block=8)
    last, _cache = M.prefill(params, cfg, batch, max_len=32, kv_block=8)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_matches_forward(arch):
    """prefill(S-1) + decode(token S-1) == forward(S)[:, -1].

    MoE capacity is a function of the token count, so drops can differ
    between a full-sequence forward and a 1-token decode; a dropless
    capacity factor makes the comparison exact.
    """
    cfg = dataclasses.replace(ARCHS[arch].reduced(), capacity_factor=1e3)
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, S=16)
    tokens = batch["tokens"]
    full, _ = M.forward(params, cfg, tokens,
                        frontend=batch.get("frontend"), kv_block=8)
    pre_batch = dict(batch, tokens=tokens[:, :-1], labels=tokens[:, :-1])
    _, cache = M.prefill(params, cfg, pre_batch, max_len=20, kv_block=8)
    logits, cache2 = M.decode_step(params, cfg, cache, tokens[:, -1])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               rtol=3e-4, atol=3e-4)


def test_sliding_window_ring_cache_long_decode():
    """Decode far past the window: ring cache must stay consistent."""
    cfg = dataclasses.replace(ARCHS["hymba-1.5b"].reduced(), sliding_window=8)
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    S = 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S)), jnp.int32)
    full, _ = M.forward(params, cfg, tokens, kv_block=8)
    _, cache = M.prefill(params, cfg, {"tokens": tokens[:, :4]}, max_len=S + 2,
                         kv_block=8)
    logits = None
    for i in range(4, S):
        logits, cache = M.decode_step(params, cfg, cache, tokens[:, i])
        if i + 1 < S:
            np.testing.assert_allclose(
                np.asarray(logits), np.asarray(full[:, i]), rtol=3e-3, atol=3e-3)


def test_param_count_matches_specs():
    from repro.models.lm.params import n_params

    for arch, cfg in ARCHS.items():
        spec_n = n_params(M.param_specs(cfg))
        approx = cfg.param_count()
        # analytic count ignores norms/biases/pos-embeddings: within 10%
        assert abs(spec_n - approx) / approx < 0.12, (arch, spec_n, approx)


def test_shape_skip_rules():
    from repro.configs.cells import cells, skipped_cells

    assert len(cells()) + len(skipped_cells()) == 40
    skipped = {(a, s) for a, s, _ in skipped_cells()}
    assert ("mamba2-780m", "long_500k") not in skipped
    assert ("hymba-1.5b", "long_500k") not in skipped
    assert ("yi-9b", "long_500k") in skipped
