"""The declarative ``tg.Experiment`` front door: spec round-trips, pipeline
dispatch across the four quadrants, bit-parity of new-API runs against the
legacy trainers, checkpoint interchange old<->new, the node task's
scan-vs-loop parity, the TrainLoop engine, and recipe legacy-kwarg
deprecation mapping."""

import dataclasses

import numpy as np
import pytest

import jax

from repro.core import RECIPE_TGB_LINK, RecipeRegistry, TimeDelta
from repro.tg import DataSpec, Experiment, ModelSpec, SamplerSpec, TrainSpec
from repro.train import (
    CTDGLinkPipeline,
    DTDGLinkPipeline,
    DTDGNodePipeline,
    EventNodePipeline,
    LinkPredictionTrainer,
    NodePropertyTrainer,
    SnapshotLinkTrainer,
    TrainLoop,
)


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


CTDG_EXP = Experiment(
    data=DataSpec("tiny", scale=1.0),
    model=ModelSpec("tgat", {"num_layers": 1}),
    sampler=SamplerSpec(k=4),
    train=TrainSpec(batch_size=48, eval_negatives=5, seed=0),
)
DTDG_EXP = Experiment(
    data=DataSpec("tiny", discretization="h"),
    model=ModelSpec("gcn", {"d_embed": 16}),
    train=TrainSpec(seed=3),
)


# ----------------------------------------------------------------------
# Spec round-trips
# ----------------------------------------------------------------------
def test_spec_roundtrip_dict_and_json():
    """Experiment.from_dict(exp.to_dict()) and the JSON path reproduce the
    exact spec objects, including the TimeDelta axis."""
    for exp in (
        CTDG_EXP,
        DTDG_EXP,
        Experiment(task="node",
                   data=DataSpec("genre", scale=0.5, discretization=TimeDelta("m", 30)),
                   model=ModelSpec("tgcn", {"d_embed": 8}),
                   sampler=SamplerSpec(kind="uniform", device=True,
                                       checkpoint_adjacency=False, num_hops=2),
                   train=TrainSpec(lr=5e-4, epochs=3, eval_every=2,
                                   chunk_size=7, compiled=False)),
    ):
        assert Experiment.from_dict(exp.to_dict()) == exp
        assert Experiment.from_json(exp.to_json()) == exp
    # the blob is plain JSON (no repr round-trips)
    import json

    json.loads(DTDG_EXP.to_json())


def test_spec_unit_string_coercion_and_validation():
    """DataSpec coerces unit strings; bad spec fields fail fast."""
    assert DataSpec(discretization="h").discretization == TimeDelta("h")
    with pytest.raises(ValueError):
        SamplerSpec(kind="nope")
    with pytest.raises(ValueError):
        SamplerSpec(num_hops=3)
    with pytest.raises(ValueError):
        Experiment(task="graph")
    with pytest.raises(ValueError):
        DataSpec.from_dict({"datasett": "x"})


def test_compile_dispatch_and_validation(small_stream):
    """The TimeDelta axis + task select the right pipeline; mismatched
    model/axis combinations fail with a precise error."""
    assert isinstance(CTDG_EXP.compile(small_stream), CTDGLinkPipeline)
    assert isinstance(DTDG_EXP.compile(small_stream), DTDGLinkPipeline)
    node = dataclasses.replace(DTDG_EXP, task="node")
    assert isinstance(node.compile(small_stream), DTDGNodePipeline)
    pf = Experiment(task="node", data=DataSpec(discretization="h"),
                    model=ModelSpec("pf"))
    assert isinstance(pf.compile(small_stream), EventNodePipeline)
    with pytest.raises(ValueError):  # snapshot model without an axis
        Experiment(model=ModelSpec("gcn")).compile(small_stream)
    with pytest.raises(ValueError):  # CTDG model with an axis
        Experiment(data=DataSpec(discretization="h"),
                   model=ModelSpec("tgat")).compile(small_stream)
    with pytest.raises(ValueError):  # node task needs the axis
        Experiment(task="node", model=ModelSpec("gcn")).compile(small_stream)


# ----------------------------------------------------------------------
# Legacy parity: new API == legacy trainers, bit for bit
# ----------------------------------------------------------------------
def test_ctdg_experiment_matches_legacy_trainer(small_stream):
    """An Experiment-compiled CTDG pipeline reproduces the legacy
    LinkPredictionTrainer run exactly: losses, params, val MRR."""
    new = CTDG_EXP.compile(small_stream)
    legacy = LinkPredictionTrainer("tgat", small_stream, batch_size=48, k=4,
                                   eval_negatives=5, seed=0,
                                   model_kwargs={"num_layers": 1})
    l_new, _ = new.train_epoch()
    l_old, _ = legacy.train_epoch()
    assert l_new == l_old
    assert _tree_equal(new.params, legacy.params)
    assert _tree_equal(new.opt_state, legacy.opt_state)
    assert new.evaluate("val")[0] == legacy.evaluate("val")[0]


def test_dtdg_experiment_matches_legacy_trainer(small_stream):
    """Experiment-compiled DTDG pipeline == legacy SnapshotLinkTrainer."""
    new = DTDG_EXP.compile(small_stream)
    legacy = SnapshotLinkTrainer("gcn", small_stream, snapshot_unit="h",
                                 d_embed=16, seed=3)
    l_new, _ = new.train_epoch()
    l_old, _ = legacy.train_epoch()
    assert l_new == l_old
    assert _tree_equal(new.params, legacy.params)
    assert new.evaluate("val")[0] == legacy.evaluate("val")[0]
    assert new.evaluate("test")[0] == legacy.evaluate("test")[0]


def test_experiment_roundtrip_reproduces_pipeline(small_stream):
    """A round-tripped Experiment compiles to an identical pipeline: same
    trained params after an epoch."""
    a = CTDG_EXP.compile(small_stream)
    b = Experiment.from_json(CTDG_EXP.to_json()).compile(small_stream)
    a.train_epoch()
    b.train_epoch()
    assert _tree_equal(a.params, b.params)
    assert _tree_equal(a.opt_state, b.opt_state)


def test_checkpoint_interchange_legacy_and_new(small_stream, tmp_path):
    """Checkpoints interchange old<->new: a legacy trainer's checkpoint
    restores into an Experiment pipeline (and back) and continues to the
    same result as an uninterrupted run."""
    # legacy -> new (CTDG)
    legacy = LinkPredictionTrainer("tgat", small_stream, batch_size=48, k=4,
                                   eval_negatives=5, seed=0,
                                   model_kwargs={"num_layers": 1})
    legacy.train_epoch()
    legacy.save_checkpoint(str(tmp_path / "ctdg"), 0)
    new = CTDG_EXP.compile(small_stream)
    assert new.restore_checkpoint(str(tmp_path / "ctdg")) == 0
    assert _tree_equal(new.params, legacy.params)
    l_new, _ = new.train_epoch()
    l_old, _ = legacy.train_epoch()
    assert l_new == l_old
    assert _tree_equal(new.params, legacy.params)

    # new -> legacy (DTDG)
    new_d = DTDG_EXP.compile(small_stream)
    new_d.train_epoch()
    new_d.save_checkpoint(str(tmp_path / "dtdg"), 0)
    legacy_d = SnapshotLinkTrainer("gcn", small_stream, snapshot_unit="h",
                                   d_embed=16, seed=3)
    assert legacy_d.restore_checkpoint(str(tmp_path / "dtdg")) == 0
    assert _tree_equal(legacy_d.params, new_d.params)
    l_a, _ = legacy_d.train_epoch()
    l_b, _ = new_d.train_epoch()
    assert l_a == l_b


# ----------------------------------------------------------------------
# Node task: scan-vs-loop parity + checkpointing
# ----------------------------------------------------------------------
@pytest.mark.parametrize("model", ["gcn", "tgcn"])
def test_node_scan_vs_loop_parity(model, small_stream):
    """The scanned node-property epoch == the per-snapshot jitted loop,
    bit-for-bit: losses, trained params, and NDCG@10."""
    base = Experiment(
        task="node",
        data=DataSpec(discretization="h"),
        model=ModelSpec(model, {"d_embed": 8, "num_cats": 6}),
        train=TrainSpec(seed=1),
    )
    scan = base.compile(small_stream)
    loop = dataclasses.replace(
        base, train=dataclasses.replace(base.train, compiled=False)
    ).compile(small_stream)
    assert scan.compiled and not loop.compiled
    l_s, _ = scan.train_epoch()
    l_l, _ = loop.train_epoch()
    assert l_s == l_l
    assert _tree_equal(scan.params, loop.params)
    assert _tree_equal(scan.opt_state, loop.opt_state)
    assert scan.evaluate("test")[0] == loop.evaluate("test")[0]
    assert scan.evaluate("val")[0] == loop.evaluate("val")[0]


def test_node_pipeline_checkpoint_roundtrip(small_stream, tmp_path):
    """Node pipeline checkpoints restore params/opt/recurrent state."""
    exp = Experiment(task="node", data=DataSpec(discretization="h"),
                     model=ModelSpec("tgcn", {"d_embed": 8, "num_cats": 6}))
    a = exp.compile(small_stream)
    a.train_epoch()
    a.save_checkpoint(str(tmp_path / "node"), 0)
    b = exp.compile(small_stream)
    assert b.restore_checkpoint(str(tmp_path / "node")) == 0
    assert _tree_equal(a.params, b.params)
    la, _ = a.train_epoch()
    lb, _ = b.train_epoch()
    assert la == lb


def test_event_node_pipeline_checkpoints_through_trainloop(small_stream, tmp_path):
    """The event-window node pipeline honors the full pipeline surface:
    TrainLoop can checkpoint it mid-fit and a fresh pipeline restores."""
    exp = Experiment(task="node", data=DataSpec(discretization="h"),
                     model=ModelSpec("tgn", {"num_cats": 6, "d_embed": 8}),
                     train=TrainSpec(epochs=1, ckpt_dir=str(tmp_path / "en"),
                                     ckpt_every=1))
    out = exp.run(data=small_stream, splits=("test",))
    assert len(out["history"]["ckpts"]) == 1
    fresh = exp.compile(small_stream)
    assert fresh.restore_checkpoint(str(tmp_path / "en")) == 0
    assert _tree_equal(fresh.params, out["pipeline"].params)
    # pf writes a marker bundle and restores as a no-op
    pf = Experiment(task="node", data=DataSpec(discretization="h"),
                    model=ModelSpec("pf", {"num_cats": 6})).compile(small_stream)
    pf.save_checkpoint(str(tmp_path / "pf"), 3)
    assert pf.restore_checkpoint(str(tmp_path / "pf")) == 3


def test_legacy_nodeprop_trainer_shim(small_stream):
    """NodePropertyTrainer keeps the one-shot run() API; its snapshot
    models now run the scanned pipeline under the hood."""
    tr = NodePropertyTrainer("gcn", small_stream, unit="h", num_cats=6,
                             d_embed=8)
    assert isinstance(tr.pipeline, DTDGNodePipeline)
    ndcg, secs = tr.run(train_frac=0.7)
    assert 0.0 <= ndcg <= 1.0
    pf = NodePropertyTrainer("pf", small_stream, unit="h", num_cats=6)
    assert isinstance(pf.pipeline, EventNodePipeline)
    assert 0.0 <= pf.run()[0] <= 1.0


# ----------------------------------------------------------------------
# TrainLoop engine + Experiment.run
# ----------------------------------------------------------------------
def test_trainloop_cadences(small_stream, tmp_path):
    """fit() applies eval and checkpoint cadences and records history."""
    pipeline = DTDG_EXP.compile(small_stream)
    history = TrainLoop(pipeline).fit(
        epochs=2, eval_every=1, eval_split="val",
        ckpt_dir=str(tmp_path / "loop"), ckpt_every=2,
    )
    assert len(history["loss"]) == 2 == len(history["train_secs"])
    assert [e for e, _ in history["eval"]] == [0, 1]
    assert len(history["ckpts"]) == 1
    restored = DTDG_EXP.compile(small_stream)
    assert restored.restore_checkpoint(str(tmp_path / "loop")) == 1


def test_experiment_run_end_to_end(small_stream):
    """run() = compile + fit + final metrics, for the link task."""
    exp = dataclasses.replace(
        DTDG_EXP, train=dataclasses.replace(DTDG_EXP.train, epochs=2))
    out = exp.run(data=small_stream, splits=("val", "test"))
    assert len(out["history"]["loss"]) == 2
    assert set(out["metrics"]) == {"val", "test"}
    assert isinstance(out["pipeline"], DTDGLinkPipeline)


# ----------------------------------------------------------------------
# Recipe builders: spec-driven, legacy kwargs deprecated
# ----------------------------------------------------------------------
def test_recipe_spec_build_is_warning_free(recwarn):
    """Spec-driven recipe building emits no DeprecationWarning."""
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        m = RecipeRegistry.build(
            RECIPE_TGB_LINK, num_nodes=10,
            spec=SamplerSpec(kind="recency", k=2), batch_size=8,
        )
    assert m.hooks()


def test_recipe_legacy_kwargs_warn_and_map():
    """Legacy sampler kwargs still work but emit a DeprecationWarning and
    map onto the same hooks as the equivalent SamplerSpec."""
    from repro.core.tg_hooks import UniformNeighborHook

    with pytest.warns(DeprecationWarning, match="SamplerSpec"):
        m = RecipeRegistry.build(
            RECIPE_TGB_LINK, num_nodes=10, k=2, batch_size=8,
            sampler="uniform", checkpoint_adjacency=False,
        )
    hooks = [h for h in m.hooks() if isinstance(h, UniformNeighborHook)]
    assert len(hooks) == 1
    assert hooks[0].sampler.checkpoint_adjacency is False
    with pytest.raises(ValueError):  # spec and legacy kwargs are exclusive
        RecipeRegistry.build(RECIPE_TGB_LINK, num_nodes=10,
                             spec=SamplerSpec(), device_sampling=True)
