"""Minimal stand-in for the ``hypothesis`` package.

The container used for CI-less environments may lack hypothesis; rather than
skip the property tests entirely, ``conftest.py`` registers this module as
``hypothesis`` when the real package is missing. It implements just the
surface the test-suite uses — ``given``, ``settings`` and the ``integers`` /
``booleans`` / ``sampled_from`` strategies — backed by deterministic
pseudo-random example generation (seeded per test name), so the property
tests still execute many randomized examples. No shrinking, no database.
"""

from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(0, len(elements)))])


def lists(elem: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elem.draw(rng) for _ in range(n)]

    return _Strategy(draw)


class strategies:  # mirror `from hypothesis import strategies as st`
    integers = staticmethod(integers)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strategies_kw):
    def deco(fn):
        max_examples = getattr(fn, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(max_examples):
                drawn = {k: s.draw(rng) for k, s in strategies_kw.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"property falsified on example {i}: {drawn!r}"
                    ) from e

        # pytest introspects the signature to collect fixtures: hide the
        # strategy-filled parameters (and functools.wraps' __wrapped__).
        del wrapper.__wrapped__
        params = [
            p for name, p in inspect.signature(fn).parameters.items()
            if name not in strategies_kw
        ]
        wrapper.__signature__ = inspect.Signature(params)
        wrapper._stub_max_examples = max_examples
        return wrapper

    return deco
