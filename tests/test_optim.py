import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    warmup_cosine,
)


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(params, g, opt, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_moments_are_f32_for_bf16_params():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt["mu"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    new_p, new_opt = adamw_update(params, g, opt, AdamWConfig(lr=0.1))
    assert new_p["w"].dtype == jnp.bfloat16
    assert new_opt["nu"]["w"].dtype == jnp.float32


def test_weight_decay_shrinks():
    params = {"w": jnp.asarray([10.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.1)
    g = {"w": jnp.asarray([0.0])}
    p2, _ = adamw_update(params, g, opt, cfg)
    assert float(p2["w"][0]) < 10.0


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    same, _ = clip_by_global_norm(tree, 100.0)
    np.testing.assert_allclose(same["a"], tree["a"])


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0, 10, 100)) == 0.0
    assert abs(float(warmup_cosine(10, 10, 100)) - 1.0) < 1e-6
    assert float(warmup_cosine(100, 10, 100)) >= 0.1 - 1e-6
    assert float(warmup_cosine(50, 10, 100)) < 1.0
