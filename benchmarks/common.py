"""Shared benchmark utilities. Every table benchmark prints CSV rows:
``name,us_per_call,derived`` (derived = speedup / metric / note)."""

from __future__ import annotations

import time
from typing import Callable


def timeit(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
