"""Shared benchmark utilities. Every table benchmark prints CSV rows:
``name,us_per_call,derived`` (derived = speedup / metric / note).

Set ``BENCH_JSON=/path/to/bench.jsonl`` to additionally append one JSON
object per ``emit`` call (name, us, derived, unix timestamp, git revision,
JAX backend + device count). Appending keeps a trajectory across runs, so
regressions show up as a time series rather than a single stale number —
and the backend/device metadata keeps single- and multi-device trajectory
points distinguishable (``scripts/check_bench_regression.py`` gates on the
per-name medians).

The JSON record is built by ``repro.obs.records.bench_record`` — the same
typed record layer the telemetry sinks emit through — so bench lines are
schema-validated and carry ``"kind": "bench"`` alongside the legacy
fields (``docs/benchmarks.md`` documents the format).
"""

from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Callable, Optional


def timeit(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _git_rev() -> Optional[str]:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except Exception:
        return None


def _device_meta() -> dict:
    """JAX backend + visible device count (benchmarks always run under an
    initialized JAX; import is deferred so ``common`` stays import-light)."""
    try:
        import jax

        return {"backend": jax.default_backend(),
                "device_count": jax.device_count()}
    except Exception:  # pragma: no cover - jax always present in benches
        return {"backend": None, "device_count": None}


def emit(name: str, seconds: float, derived: str = "") -> None:
    emit_value(name, seconds * 1e6, derived)


def emit_value(name: str, value: float, derived: str = "") -> None:
    """Emit a raw gated value into the ``us`` field (used by rate-style
    benches — e.g. requests/s — where the gated number is not a time; the
    baseline entry's ``direction: "higher"`` tells the regression gate
    which way is better)."""
    print(f"{name},{value:.1f},{derived}", flush=True)
    path = os.environ.get("BENCH_JSON")
    if path:
        from repro.obs.records import bench_record

        record = bench_record(
            name, value, derived,
            ts=time.time(), rev=_git_rev(), **_device_meta(),
        )
        with open(path, "a") as f:
            f.write(json.dumps(record) + "\n")
