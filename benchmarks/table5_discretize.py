"""Paper Table 5: discretization latency, vectorized TGM vs UTG-style dict
baseline, on the synthetic Wikipedia/Reddit/LastFM analogues."""

from __future__ import annotations

from repro.core import TimeDelta, discretize, discretize_naive
from repro.data import generate

from benchmarks.common import emit, timeit


def run(scale: float = 0.05, datasets=("wikipedia", "reddit", "lastfm")) -> None:
    unit = TimeDelta("h")
    for name in datasets:
        data = generate(name, scale=scale)
        t_fast = timeit(lambda: discretize(data, unit, reduce="count"))
        t_naive = timeit(lambda: discretize_naive(data, unit, reduce="count"),
                         repeats=1, warmup=0)
        emit(f"table5/{name}/tgm_vectorized", t_fast,
             f"E={data.num_edge_events}")
        emit(f"table5/{name}/utg_dict_baseline", t_naive,
             f"speedup={t_naive / t_fast:.1f}x")


if __name__ == "__main__":
    run()
