"""Paper Table 5: discretization latency — vectorized TGM (host numpy) and
the jitted device path (``discretize_edges_padded``, steady-state dispatch
after one compile) vs the UTG-style dict baseline, on the synthetic
Wikipedia/Reddit/LastFM analogues."""

from __future__ import annotations

import jax

from repro.core import TimeDelta, discretize, discretize_naive
from repro.data import generate

from benchmarks.common import emit, timeit
from benchmarks.dtdg_bench import jit_discretize_call


def run(scale: float = 0.05, datasets=("wikipedia", "reddit", "lastfm")) -> None:
    unit = TimeDelta("h")
    for name in datasets:
        data = generate(name, scale=scale)
        t_fast = timeit(lambda: discretize(data, unit, reduce="count"))
        t_jit = timeit(jit_discretize_call(data, unit, reduce="count"))
        t_naive = timeit(lambda: discretize_naive(data, unit, reduce="count"),
                         repeats=1, warmup=0)
        emit(f"table5/{name}/tgm_vectorized", t_fast,
             f"E={data.num_edge_events}")
        emit(f"table5/{name}/tgm_jax_jit", t_jit,
             f"vs_numpy={t_fast / t_jit:.1f}x backend={jax.default_backend()}")
        emit(f"table5/{name}/utg_dict_baseline", t_naive,
             f"speedup={t_naive / t_fast:.1f}x jit_speedup={t_naive / t_jit:.1f}x")


if __name__ == "__main__":
    run()
