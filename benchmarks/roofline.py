"""Roofline report: reads results/dryrun.json and prints the per-cell
three-term analysis (compute / memory / collective seconds, dominant term,
useful-FLOPs ratio)."""

from __future__ import annotations

import json
import os

from benchmarks.common import emit

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun.json")


def run(path: str = DEFAULT_PATH, mesh: str = "single_pod") -> None:
    if not os.path.exists(path):
        print(f"# roofline: {path} missing — run `python -m repro.launch.dryrun`")
        return
    with open(path) as f:
        records = json.load(f)
    rows = [r for r in records if r.get("status") == "ok" and r.get("mesh") == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    for r in rows:
        emit(
            f"roofline/{r['arch']}/{r['shape']}",
            r["step_time_s"],
            f"dom={r['dominant']} comp={r['compute_s']:.3g}s "
            f"mem={r['memory_s']:.3g}s coll={r['collective_s']:.3g}s "
            f"useful={r['useful_flops_ratio']:.2f}",
        )


if __name__ == "__main__":
    run()
