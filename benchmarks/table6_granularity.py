"""Paper Table 6 / RQ2: snapshot time-granularity vs DTDG link-pred MRR."""

from __future__ import annotations

from repro.data import generate
from repro.train import SnapshotLinkTrainer

from benchmarks.common import emit


def run(scale: float = 0.01, dataset: str = "wikipedia",
        units=("h", "d", "w"), epochs: int = 2) -> None:
    data = generate(dataset, scale=scale)
    for unit in units:
        tr = SnapshotLinkTrainer("gcn", data, snapshot_unit=unit, d_embed=32)
        secs_total = 0.0
        for _ in range(epochs):
            _, secs = tr.run_epoch(train=True)
            secs_total += secs
        mrr, _ = tr.run_epoch(train=False)
        emit(f"table6/{dataset}/gcn_{unit}", secs_total / epochs,
             f"mrr={mrr:.3f}")


if __name__ == "__main__":
    run()
