"""Paper Table 6 / RQ2: snapshot time-granularity vs DTDG link-pred MRR,
measured on the scan-compiled snapshot pipeline (one jitted call per train
epoch; tensorization cost reported separately). Each granularity is one
``tg.Experiment`` differing only in ``DataSpec.discretization``."""

from __future__ import annotations

from benchmarks.common import emit, timeit

from repro.data import generate
from repro.tg import DataSpec, Experiment, ModelSpec


def run(scale: float = 0.01, dataset: str = "wikipedia",
        units=("h", "d", "w"), epochs: int = 2) -> None:
    data = generate(dataset, scale=scale)
    for unit in units:
        t_build = timeit(lambda: data.to_snapshots(unit), repeats=1, warmup=1)
        exp = Experiment(
            data=DataSpec(dataset, scale=scale, discretization=unit),
            model=ModelSpec("gcn", {"d_embed": 32}),
        )
        tr = exp.compile(data)
        secs_total = 0.0
        for _ in range(epochs):
            _, secs = tr.train_epoch()
            secs_total += secs
        mrr, _ = tr.evaluate("val")
        emit(f"table6/{dataset}/gcn_{unit}", secs_total / epochs,
             f"mrr={mrr:.3f} snapshots={tr.snapshots.num_snapshots} "
             f"cap={tr.capacity}")
        emit(f"table6/{dataset}/tensorize_{unit}", t_build,
             f"T={tr.snapshots.num_snapshots}")


if __name__ == "__main__":
    run()
