"""Benchmark harness — one function per paper table.

Prints ``name,us_per_call,derived`` CSV. ``--fast`` shrinks dataset scales
for CI; table selection via ``--only table5,table9``.

  table3  link-pred training epoch time (incl. DyGLib-style baseline)
  table4  node property prediction (PF / TGN / GCN, NDCG@10)
  table5  discretization latency (vectorized vs UTG dict)
  table6  snapshot granularity vs MRR (RQ2)
  table8  eval batch size / unit vs MRR (RQ3)
  table9  one-vs-many validation latency (batch dedup on/off)
  dtdg    scan-compiled DTDG epoch vs per-snapshot loop + jitted discretize
  kernels kernel reference-path microbenchmarks
  sharded mesh-sharded sampler scaling curve (per visible shard count)
  roofline per-cell roofline terms (reads results/dryrun.json)
  obs     telemetry span overhead, disabled and enabled (docs/observability.md)
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--fast", action="store_true", help="smaller scales")
    p.add_argument("--only", default="", help="comma-separated table list")
    args = p.parse_args()
    fast = args.fast
    only = set(filter(None, args.only.split(",")))

    from benchmarks import (
        dtdg_bench,
        kernels_bench,
        obs_bench,
        roofline,
        sharded_bench,
        table3_linkpred,
        table4_nodeprop,
        table5_discretize,
        table6_granularity,
        table8_batchsize,
        table9_validation,
        table11_profile,
    )

    jobs = [
        ("table5", lambda: table5_discretize.run(scale=0.01 if fast else 0.05)),
        ("table3", lambda: table3_linkpred.run(scale=0.005 if fast else 0.02)),
        ("table4", lambda: table4_nodeprop.run(scale=0.005 if fast else 0.02)),
        ("table6", lambda: table6_granularity.run(scale=0.005 if fast else 0.01)),
        ("table8", lambda: table8_batchsize.run(scale=0.005 if fast else 0.01)),
        ("table9", lambda: table9_validation.run(scale=0.005 if fast else 0.02)),
        ("table11", lambda: table11_profile.run(scale=0.005 if fast else 0.01)),
        ("dtdg", lambda: (
            dtdg_bench.bench_dtdg_scan_vs_loop(scale=0.005 if fast else 0.01),
            dtdg_bench.bench_discretize_jit(scale=0.01 if fast else 0.02),
        )),
        ("kernels", kernels_bench.run),
        ("obs", lambda: obs_bench.run(n=20_000 if fast else 100_000)),
        ("sharded", lambda: sharded_bench.bench_sharded_sampler(
            num_batches=10 if fast else 20)),
        ("roofline", roofline.run),
    ]

    print("name,us_per_call,derived")
    failed = 0
    for name, fn in jobs:
        if only and name not in only:
            continue
        try:
            fn()
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name}/FAILED,0,see stderr", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
