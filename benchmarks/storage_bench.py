"""Out-of-core storage benchmarks: converter and windowed-epoch throughput
for the ``repro.storage`` backends (``docs/storage.md``).

Records (all gated against ``benchmarks/baseline_cpu.json``):

  * ``storage/convert_mmap`` — chunked ``MmapStore.from_chunks`` of a
    synthetic time-sorted stream (E edges, d-dim features), wall seconds.
    The stream is produced by a generator, so the conversion itself is the
    only thing touching all E rows.
  * ``storage/epoch_inmem`` / ``storage/epoch_mmap`` — one windowed
    "epoch" per backend: iterate ``iter_windows(batch_size=B)`` over the
    full store and reduce every column (the loader-side access pattern
    without model cost). The mmap run releases pages after each window;
    its derived field reports the peak-RSS delta of the epoch
    (``resource.getrusage``) next to the in-memory run's.

``--fast`` shrinks the stream for CI.
"""

from __future__ import annotations

import argparse
import resource
import shutil
import tempfile

import numpy as np

from benchmarks.common import emit, timeit

from repro.storage import InMemoryStore, MmapStore


def _chunks(n_edges: int, d_edge: int, num_nodes: int, chunk: int = 1 << 16,
            seed: int = 0):
    """Synthetic time-sorted stream, one chunk at a time (never whole)."""
    rng = np.random.default_rng(seed)
    t0 = 0
    for lo in range(0, n_edges, chunk):
        m = min(chunk, n_edges - lo)
        yield {
            "src": rng.integers(0, num_nodes, m),
            "dst": rng.integers(0, num_nodes, m),
            "t": t0 + np.sort(rng.integers(0, 1000, m)),
            "edge_feats": rng.standard_normal((m, d_edge)).astype(np.float32),
        }
        t0 += 1000


def _rss_kb() -> int:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss


def _epoch(store, batch_size: int, release: bool) -> int:
    """Touch every column of every window; returns a checksum."""
    acc = 0
    for w in store.iter_windows(batch_size=batch_size, release=release):
        acc += int(w.src[0]) + int(w.dst[-1]) + int(w.t[-1])
        if w.edge_feats is not None:
            acc += int(w.edge_feats[0, 0] * 0)
    return acc


def bench_storage(n_edges: int = 200_000, d_edge: int = 32,
                  num_nodes: int = 20_000, batch_size: int = 10_000) -> None:
    """Converter + windowed-epoch throughput, mmap vs in-memory."""
    tmp = tempfile.mkdtemp(prefix="storage_bench_")
    try:
        path = f"{tmp}/store"
        t_conv = timeit(
            lambda: MmapStore.from_chunks(
                path, _chunks(n_edges, d_edge, num_nodes), overwrite=True),
            repeats=1, warmup=0)
        stream_mb = (n_edges * (3 * 8 + 4 * d_edge)) / 2**20
        emit("storage/convert_mmap", t_conv,
             f"E={n_edges} d={d_edge} stream={stream_mb:.0f}MB")

        mm = MmapStore(path)
        mem = InMemoryStore.from_data(mm.to_data())
        t_mem = timeit(lambda: _epoch(mem, batch_size, release=False))
        rss0 = _rss_kb()
        t_mm = timeit(lambda: _epoch(mm, batch_size, release=True))
        drss = (_rss_kb() - rss0) / 1024
        emit("storage/epoch_inmem", t_mem, f"E={n_edges} B={batch_size}")
        emit("storage/epoch_mmap", t_mm,
             f"E={n_edges} B={batch_size} rss_delta={drss:.0f}MB "
             f"vs_inmem={t_mm / t_mem:.2f}x")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small stream for CI")
    a = ap.parse_args()
    if a.fast:
        bench_storage(n_edges=60_000, d_edge=16, num_nodes=6_000,
                      batch_size=5_000)
    else:
        bench_storage()
