"""Paper Table 11: runtime breakdown of TGAT training via the telemetry
span layer (data loading / train step), rendered with ``span_report``."""

from __future__ import annotations

import jax

from repro.core import TRAIN_KEY
from repro.data import generate
from repro.obs import MemorySink, Telemetry, span_report
from repro.tg import DataSpec, Experiment, ModelSpec, SamplerSpec, TrainSpec

from benchmarks.common import emit


def run(scale: float = 0.01, dataset: str = "wikipedia") -> None:
    data = generate(dataset, scale=scale)
    tr = Experiment(
        data=DataSpec(dataset, scale=scale),
        model=ModelSpec("tgat", {"num_layers": 1}),
        sampler=SamplerSpec(k=10),
        train=TrainSpec(batch_size=200),
    ).compile(data)
    tr.train_epoch()  # warm compile

    tel = Telemetry()
    sink = tel.attach(MemorySink())
    tr.reset_epoch_state()
    with tr.manager.activate(TRAIN_KEY):
        loader = tr._loader(tr.train_data)
        it = iter(loader)
        while True:
            with tel.span("data_loading"):
                try:
                    batch = next(it)
                except StopIteration:
                    break
                bt = {k: batch[k] for k in batch.keys()}
            with tel.span("train_step"):
                tr.params, tr.opt_state, _ = tr._train_step(
                    tr.params, tr.opt_state, bt)
                # Spans time dispatch only; drain async work so the span
                # includes device time (Table 11 measures wall breakdown).
                jax.effects_barrier()

    times, counts = {}, {}
    for r in sink.records:
        if r["kind"] != "span":
            continue
        times[r["path"]] = times.get(r["path"], 0.0) + r["dur_s"]
        counts[r["path"]] = counts.get(r["path"], 0) + 1
    total = max(sum(times.values()), 1e-12)
    for path, secs in sorted(times.items()):
        emit(f"table11/{dataset}/{path}", secs / max(counts[path], 1),
             f"pct={100 * secs / total:.1f}")
    print(span_report(sink.records), flush=True)


if __name__ == "__main__":
    run()
