"""Paper Table 11: runtime breakdown of TGAT training via the built-in
profiler (data loading / hooks / sampler / forward / backward+opt)."""

from __future__ import annotations

import numpy as np

from repro.core import TRAIN_KEY
from repro.core.tg_hooks import RecencyNeighborHook
from repro.data import generate
from repro.tg import DataSpec, Experiment, ModelSpec, SamplerSpec, TrainSpec
from repro.utils import Profiler

from benchmarks.common import emit


def run(scale: float = 0.01, dataset: str = "wikipedia") -> None:
    data = generate(dataset, scale=scale)
    tr = Experiment(
        data=DataSpec(dataset, scale=scale),
        model=ModelSpec("tgat", {"num_layers": 1}),
        sampler=SamplerSpec(k=10),
        train=TrainSpec(batch_size=200),
    ).compile(data)
    tr.train_epoch()  # warm compile

    prof = Profiler(block=True)
    tr.reset_epoch_state()
    with tr.manager.activate(TRAIN_KEY):
        loader = tr._loader(tr.train_data)
        it = iter(loader)
        while True:
            with prof("data_loading"):
                try:
                    batch = next(it)
                except StopIteration:
                    break
                bt = {k: batch[k] for k in batch.keys()}
            with prof("train_step"):
                tr.params, tr.opt_state, _ = tr._train_step(
                    tr.params, tr.opt_state, bt)
    total = prof.total()
    for path, secs in sorted(prof.times.items()):
        emit(f"table11/{dataset}/{path}", secs / max(prof.counts[path], 1),
             f"pct={100 * secs / total:.1f}")
    print(prof.report(), flush=True)


if __name__ == "__main__":
    run()
