"""Kernel microbenchmarks: jnp reference path wall-time on this host (the
Pallas path needs a TPU; interpret mode is correctness-only) + oracle
agreement spot checks + the recency-sampler host-vs-device microbenchmark
(the tentpole measurement for the device-resident sampling pipeline)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_sampler import DeviceRecencySampler, _sample, _update
from repro.core.sampler import RecencySampler
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.segment_reduce.ref import segment_sum_ref
from repro.kernels.ssd_chunk.ref import ssd_ref
from repro.kernels.temporal_attention.ref import temporal_attention_ref

from benchmarks.common import emit, timeit


def bench_recency_sampler(B: int = 200, K: int = 20, N: int = 10_000,
                          num_batches: int = 50) -> None:
    """update+sample wall time per batch, host numpy vs device JAX.

    Two seed-set shapes, both at B=200/K=20 (the TGB link recipe's default):
      * train: S = 3B seeds (src + dst + 1 negative per event)
      * eval:  S = 22B seeds (src + dst + 20 one-vs-many negatives)

    The device path runs the whole batch stream inside one jitted
    ``lax.scan`` — exactly how a device-resident pipeline amortizes dispatch.
    Each iteration applies the *previous* batch's update before sampling the
    current batch's seeds; that is the same predict-then-reveal order as the
    per-batch loop (state seen by sample(i) = after batches 0..i-1), and the
    write-before-read schedule lets XLA update the buffers in place instead
    of copying them every step.
    """
    rng = np.random.default_rng(0)
    shapes = {"train": 3 * B, "eval": 22 * B}
    src = rng.integers(0, N, (num_batches, B))
    dst = rng.integers(0, N, (num_batches, B))
    t = np.sort(rng.integers(0, 100, (num_batches, B)), axis=1)
    t += np.arange(num_batches)[:, None] * 100
    eids = rng.integers(0, 10**6, (num_batches, B))
    seeds = {k: rng.integers(0, N, (num_batches, s)) for k, s in shapes.items()}

    # Shifted update stream: iteration i applies batch i-1 (first is a no-op).
    zero = np.zeros((1, B), np.int64)
    usrc = np.concatenate([zero, src[:-1]])
    udst = np.concatenate([zero, dst[:-1]])
    ut = np.concatenate([zero, t[:-1]])
    ue = np.concatenate([zero, eids[:-1]])
    uvalid = np.concatenate(
        [np.zeros((1, B), bool), np.ones((num_batches - 1, B), bool)])

    for shape_name, S in shapes.items():
        se = seeds[shape_name]

        def run_numpy():
            s = RecencySampler(N, K)
            for i in range(num_batches):
                s.sample(se[i])
                s.update(src[i], dst[i], t[i], eids[i])

        t_np = timeit(run_numpy, repeats=7) / num_batches

        dev = DeviceRecencySampler(N, K)
        xs = tuple(jnp.asarray(a, jnp.int32)
                   for a in (usrc, udst, ut, ue, se)) + (jnp.asarray(uvalid),)

        @jax.jit
        def run_stream(state, xs):
            def step(state, x):
                s_, d_, t_, e_, q_, v_ = x
                state = _update(state, s_, d_, t_, e_, v_, k=K,
                                directed=False)
                ids, *_ = _sample(state, q_, k=K)
                return state, ids
            return jax.lax.scan(step, state, xs)

        jax.block_until_ready(run_stream(dev.state, xs))  # compile
        t_dev = timeit(
            lambda: jax.block_until_ready(run_stream(dev.state, xs)),
            repeats=7,
        ) / num_batches

        emit(f"sampler/recency_numpy_{shape_name}", t_np,
             f"B{B} K{K} N{N} S{S}")
        emit(f"sampler/recency_device_{shape_name}", t_dev,
             f"B{B} K{K} N{N} S{S} speedup={t_np / t_dev:.2f}x")


def bench_fused_vs_pregathered(B: int = 200, K: int = 20, N: int = 10_000,
                               d_edge: int = 172) -> None:
    """TGAT train-step wall time: pre-gathered neighbor tensors (the classic
    hook path) vs the fused device-sampling layer, same model and batch.

    Both steps are jitted end-to-end (loss + grads + AdamW update) over a
    synthetic TGB-link train batch (S = 3B seeds). On TPU the fused column
    runs the Pallas kernel; on CPU/GPU it runs the split-projection jnp
    fallback, so the delta there reflects skipping the hook-side gather and
    concat, not the in-kernel DMA pipeline.
    """
    from repro.core import RECIPE_TGB_LINK, RecipeRegistry, TRAIN_KEY
    from repro.core.graph import DGData, DGraph
    from repro.core.loader import DGDataLoader
    from repro.core.tg_hooks import stage_batch
    from repro.models.tg import tgat
    from repro.models.tg.common import bce_link_loss
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    rng = np.random.default_rng(0)
    E = 4 * B
    feats = rng.standard_normal((E, d_edge)).astype(np.float32)
    data = DGData.from_arrays(
        rng.integers(0, N, E), rng.integers(0, N, E),
        np.sort(rng.integers(0, 10_000, E)), edge_feats=feats,
        granularity="s",
    )
    from repro.tg import SamplerSpec

    m = RecipeRegistry.build(
        RECIPE_TGB_LINK, num_nodes=N, batch_size=B, eval_negatives=20,
        edge_feats=feats, edge_feat_dim=d_edge, seed=0,
        spec=SamplerSpec(k=K, device=True),
    )
    with m.activate(TRAIN_KEY):
        *_, batch = iter(DGDataLoader(DGraph(data), m, batch_size=B))
    batch = stage_batch(batch)
    bt = {k2: batch[k2] for k2 in batch.keys()}

    cfg = tgat.TGATConfig(num_nodes=N, d_edge=d_edge, k=K, num_layers=1)
    params = tgat.init(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-4)
    opt0 = adamw_init(params)
    fused_mode = "auto" if jax.default_backend() == "tpu" else "ref"

    def make_step(fused):
        def loss_fn(params, batch):
            pos, neg = tgat.link_scores(params, cfg, batch, B, fused=fused)
            return bce_link_loss(pos, neg, batch["batch_mask"])

        @jax.jit
        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
            return params, opt_state, loss

        return step

    results = {}
    for name, fused in (("pregathered", False), ("fused", fused_mode)):
        step = make_step(fused)
        jax.block_until_ready(step(params, opt0, bt))  # compile
        results[name] = timeit(
            lambda: jax.block_until_ready(step(params, opt0, bt)), repeats=7)
        emit(f"kernels/tgat_train_step_{name}", results[name],
             f"B{B} K{K} N{N} S{3 * B} d_edge{d_edge} fused={fused}")
    delta = results["pregathered"] - results["fused"]
    emit("kernels/tgat_train_step_fused_delta", delta,
         f"speedup={results['pregathered'] / results['fused']:.2f}x "
         f"backend={jax.default_backend()}")


def bench_fused_train_step(B: int = 100, K: int = 10, N: int = 2_000,
                           d_edge: int = 32, num_layers: int = 2) -> None:
    """Gather-free 2-layer TGAT train-step wall time on the fused path.

    One jitted step — loss, the custom-VJP backward, AdamW update — over a
    device-sampling TGB-link batch, exercising all three fused-layer
    variants (hop-1 seeds, hop-2 frontier, per-seed final hop). On TPU both
    directions run Pallas kernels (flash-style backward); on CPU/GPU the
    split-projection jnp fallback runs, which is what the recorded CPU
    baseline gates — a regression here means the fused model path itself
    (projection split, synthetic-buffer assembly, VJP plumbing) got slower.
    """
    from repro.core import RECIPE_TGB_LINK, RecipeRegistry, TRAIN_KEY
    from repro.core.graph import DGData, DGraph
    from repro.core.loader import DGDataLoader
    from repro.core.tg_hooks import stage_batch
    from repro.models.tg import tgat
    from repro.models.tg.common import bce_link_loss
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    from repro.tg import SamplerSpec

    rng = np.random.default_rng(0)
    E = 4 * B
    feats = rng.standard_normal((E, d_edge)).astype(np.float32)
    data = DGData.from_arrays(
        rng.integers(0, N, E), rng.integers(0, N, E),
        np.sort(rng.integers(0, 10_000, E)), edge_feats=feats,
        granularity="s",
    )
    m = RecipeRegistry.build(
        RECIPE_TGB_LINK, num_nodes=N, batch_size=B, eval_negatives=20,
        edge_feats=feats, edge_feat_dim=d_edge, seed=0,
        spec=SamplerSpec(k=K, device=True, num_hops=num_layers),
    )
    with m.activate(TRAIN_KEY):
        *_, batch = iter(DGDataLoader(DGraph(data), m, batch_size=B))
    staged = stage_batch(batch)
    bt = {k2: staged[k2] for k2 in staged.keys()}

    cfg = tgat.TGATConfig(num_nodes=N, d_edge=d_edge, k=K,
                          num_layers=num_layers)
    params = tgat.init(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-4)
    opt0 = adamw_init(params)
    fused = "auto" if jax.default_backend() == "tpu" else "ref"

    def loss_fn(params, batch):
        pos, neg = tgat.link_scores(params, cfg, batch, B, fused=fused)
        return bce_link_loss(pos, neg, batch["batch_mask"])

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss

    jax.block_until_ready(step(params, opt0, bt))  # compile
    t = timeit(lambda: jax.block_until_ready(step(params, opt0, bt)),
               repeats=7)
    emit("kernels/fused_train_step", t,
         f"B{B} K{K} N{N} d_edge{d_edge} layers{num_layers} fused={fused}")


def run() -> None:
    rng = np.random.default_rng(0)

    bench_recency_sampler()
    bench_fused_vs_pregathered()
    bench_fused_train_step()

    q = jnp.asarray(rng.standard_normal((2, 8, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 256, 64)), jnp.float32)
    f = jax.jit(lambda q, k: flash_attention_ref(q, k, k))
    f(q, k).block_until_ready()
    emit("kernels/flash_attention_ref_fwd", timeit(
        lambda: f(q, k).block_until_ready()), "B2 H8 S256 D64")

    qs = jnp.asarray(rng.standard_normal((512, 2, 64)), jnp.float32)
    ks = jnp.asarray(rng.standard_normal((512, 16, 2, 64)), jnp.float32)
    m = jnp.asarray(rng.random((512, 16)) > 0.3)
    g = jax.jit(lambda q, k, m: temporal_attention_ref(q, k, k, m))
    g(qs, ks, m).block_until_ready()
    emit("kernels/temporal_attention_ref", timeit(
        lambda: g(qs, ks, m).block_until_ready()), "S512 K16")

    data = jnp.asarray(rng.standard_normal((8192, 64)), jnp.float32)
    seg = jnp.sort(jnp.asarray(rng.integers(0, 512, 8192), jnp.int32))
    h = jax.jit(lambda d, s: segment_sum_ref(d, s, 512))
    h(data, seg).block_until_ready()
    emit("kernels/segment_sum_ref", timeit(
        lambda: h(data, seg).block_until_ready()), "E8192 G512")

    x = jnp.asarray(rng.standard_normal((512, 4, 32)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((512, 4)), jnp.float32))
    a = -jnp.exp(jnp.asarray(rng.standard_normal(4), jnp.float32) * 0.3)
    B = jnp.asarray(rng.standard_normal((512, 4, 32)), jnp.float32)
    fn = jax.jit(lambda *args: ssd_ref(*args)[0])
    fn(x, dt, a, B, B).block_until_ready()
    emit("kernels/ssd_ref_recurrence", timeit(
        lambda: fn(x, dt, a, B, B).block_until_ready()), "S512 H4")


if __name__ == "__main__":
    run()
