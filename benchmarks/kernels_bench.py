"""Kernel microbenchmarks: jnp reference path wall-time on this host (the
Pallas path needs a TPU; interpret mode is correctness-only) + oracle
agreement spot checks."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.segment_reduce.ref import segment_sum_ref
from repro.kernels.ssd_chunk.ref import ssd_ref
from repro.kernels.temporal_attention.ref import temporal_attention_ref

from benchmarks.common import emit, timeit


def run() -> None:
    rng = np.random.default_rng(0)

    q = jnp.asarray(rng.standard_normal((2, 8, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 256, 64)), jnp.float32)
    f = jax.jit(lambda q, k: flash_attention_ref(q, k, k))
    f(q, k).block_until_ready()
    emit("kernels/flash_attention_ref_fwd", timeit(
        lambda: f(q, k).block_until_ready()), "B2 H8 S256 D64")

    qs = jnp.asarray(rng.standard_normal((512, 2, 64)), jnp.float32)
    ks = jnp.asarray(rng.standard_normal((512, 16, 2, 64)), jnp.float32)
    m = jnp.asarray(rng.random((512, 16)) > 0.3)
    g = jax.jit(lambda q, k, m: temporal_attention_ref(q, k, k, m))
    g(qs, ks, m).block_until_ready()
    emit("kernels/temporal_attention_ref", timeit(
        lambda: g(qs, ks, m).block_until_ready()), "S512 K16")

    data = jnp.asarray(rng.standard_normal((8192, 64)), jnp.float32)
    seg = jnp.sort(jnp.asarray(rng.integers(0, 512, 8192), jnp.int32))
    h = jax.jit(lambda d, s: segment_sum_ref(d, s, 512))
    h(data, seg).block_until_ready()
    emit("kernels/segment_sum_ref", timeit(
        lambda: h(data, seg).block_until_ready()), "E8192 G512")

    x = jnp.asarray(rng.standard_normal((512, 4, 32)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((512, 4)), jnp.float32))
    a = -jnp.exp(jnp.asarray(rng.standard_normal(4), jnp.float32) * 0.3)
    B = jnp.asarray(rng.standard_normal((512, 4, 32)), jnp.float32)
    fn = jax.jit(lambda *args: ssd_ref(*args)[0])
    fn(x, dt, a, B, B).block_until_ready()
    emit("kernels/ssd_ref_recurrence", timeit(
        lambda: fn(x, dt, a, B, B).block_until_ready()), "S512 H4")


if __name__ == "__main__":
    run()
