"""Paper Table 8 / RQ3: evaluation batch size & unit affect CTDG MRR.

TGAT is trained once per setting; validation runs with event-count batches
of several sizes and with time-unit batches (hour/day) — the latter is
unique to TGM's unified iteration (batches span fixed wall-clock windows,
so their event counts vary; the pad hook restores static shapes).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import DGraph, DGDataLoader, EVAL_KEY, TRAIN_KEY
from repro.data import generate
from repro.tg import DataSpec, Experiment, ModelSpec, SamplerSpec, TrainSpec
from repro.train.metrics import mrr as mrr_metric

from benchmarks.common import emit


def run(scale: float = 0.01, dataset: str = "wikipedia") -> None:
    data = generate(dataset, scale=scale)

    def tgat_exp(bs):
        return Experiment(
            data=DataSpec(dataset, scale=scale),
            model=ModelSpec("tgat", {"num_layers": 1}),
            sampler=SamplerSpec(k=10),
            train=TrainSpec(batch_size=bs, eval_negatives=20),
        )

    for bs in (50, 100, 200):
        tr = tgat_exp(bs).compile(data)
        tr.train_epoch()
        mrr, secs = tr.evaluate("val")
        emit(f"table8/{dataset}/batch_size={bs}", secs, f"mrr={mrr:.3f}")

    # iterate-by-time evaluation: the pad hook restores static shapes, so
    # the same jitted eval step serves ragged time windows (<= batch_size).
    for unit in ("h", "d"):
        tr = tgat_exp(200).compile(data)
        tr.train_epoch()
        tr.reset_epoch_state()
        with tr.manager.activate(TRAIN_KEY):
            for _ in tr._loader(tr.train_data):
                pass  # warm sampler state through the train split
        t0 = time.perf_counter()
        rrs, ws = [], []
        with tr.manager.activate(EVAL_KEY):
            loader = DGDataLoader(DGraph(tr.val_data), tr.manager,
                                  batch_size=None, batch_unit=unit)
            for batch in loader:
                bt = {k: batch[k] for k in batch.keys()}
                pos, neg = tr._eval_step(tr.params, bt)
                w = float(np.asarray(bt["batch_mask"]).sum())
                if w:
                    rrs.append(mrr_metric(pos, neg, bt["batch_mask"]) * w)
                    ws.append(w)
        secs = time.perf_counter() - t0
        mrr = float(np.sum(rrs) / max(np.sum(ws), 1.0))
        emit(f"table8/{dataset}/batch_unit={unit}", secs, f"mrr={mrr:.3f}")


if __name__ == "__main__":
    run()
