"""DTDG pipeline microbenchmarks: the scan-compiled epoch vs the
per-snapshot jitted dispatch loop (same math, bit-identical results — the
delta is pure dispatch/staging overhead), and the jitted device
discretization vs host numpy. Both emit into BENCH_JSON via
``benchmarks.common.emit`` so CI keeps a trajectory."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit

from repro.core import TimeDelta
from repro.core.discretize import (
    _host_ticks,
    discretize,
    discretize_edges_padded,
    jax_discretize_supported,
)
from repro.data import generate
from repro.tg import DataSpec, Experiment, ModelSpec, TrainSpec


def jit_discretize_call(data, unit: TimeDelta, reduce: str = "count"):
    """Steady-state jitted-discretize closure for benchmarks: stages the
    edge arrays once (with the same ``jax_discretize_supported`` guard and
    ``_host_ticks`` tick pre-division the library path applies, so huge raw
    timestamps never wrap) and returns a zero-arg callable that dispatches
    ``discretize_edges_padded`` and blocks on the result. Shared by
    ``table5_discretize`` and ``bench_discretize_jit``."""
    k = unit.ticks_per(data.granularity)
    if not jax_discretize_supported(data, k):
        raise ValueError(
            "graph exceeds the int32 device-discretize guard; benchmark the "
            "numpy path instead"
        )
    e = data.num_edge_events
    t_staged, k_dev = _host_ticks(data.edge_t, k)
    src = jnp.asarray(data.src)
    dst = jnp.asarray(data.dst)
    t = jnp.asarray(t_staged)
    feats = (jnp.zeros((e, 0), jnp.float32) if data.edge_feats is None
             else jnp.asarray(data.edge_feats))

    def call():
        out = discretize_edges_padded(src, dst, t, feats, k=k_dev,
                                      reduce=reduce, capacity=e,
                                      feat_dim=data.edge_feat_dim)
        jax.block_until_ready(out[:3])

    return call


def bench_dtdg_scan_vs_loop(model: str = "tgcn", dataset: str = "wikipedia",
                            scale: float = 0.01, unit: str = "h",
                            d_embed: int = 32) -> None:
    """Train-epoch wall time: one scanned jitted call vs T per-snapshot
    dispatches (numerical parity is asserted in tests; this measures the
    speedup the scan buys)."""
    data = generate(dataset, scale=scale)
    def build(compiled):
        return Experiment(
            data=DataSpec(dataset, scale=scale, discretization=unit),
            model=ModelSpec(model, {"d_embed": d_embed}),
            train=TrainSpec(compiled=compiled),
        ).compile(data)

    trainers = {"scan": build(True), "loop": build(False)}
    results = {}
    for name, tr in trainers.items():
        tr.train_epoch()  # compile + warm
        results[name] = timeit(lambda tr=tr: tr.train_epoch(), repeats=3,
                               warmup=0)
    scan_tr = trainers["scan"]
    emit(f"dtdg/{model}_{unit}_epoch_loop", results["loop"],
         f"T={scan_tr.snapshots.num_snapshots} cap={scan_tr.capacity} "
         f"backend={jax.default_backend()}")
    emit(f"dtdg/{model}_{unit}_epoch_scan", results["scan"],
         f"T={scan_tr.snapshots.num_snapshots} cap={scan_tr.capacity} "
         f"backend={jax.default_backend()} "
         f"speedup_vs_loop={results['loop'] / results['scan']:.2f}x")


def bench_discretize_jit(dataset: str = "wikipedia", scale: float = 0.02,
                         unit: str = "h") -> None:
    """Steady-state jitted ``discretize_edges_padded`` dispatch vs the
    vectorized host numpy path (same reduction)."""
    data = generate(dataset, scale=scale)
    gran = TimeDelta(unit)
    t_np = timeit(lambda: discretize(data, gran, reduce="count"))
    t_jit = timeit(jit_discretize_call(data, gran, reduce="count"))
    e = data.num_edge_events
    emit(f"dtdg/discretize_numpy_{unit}", t_np, f"E={e}")
    emit(f"dtdg/discretize_jit_{unit}", t_jit,
         f"E={e} vs_numpy={t_np / t_jit:.2f}x backend={jax.default_backend()}")


def run() -> None:
    bench_dtdg_scan_vs_loop()
    bench_discretize_jit()


if __name__ == "__main__":
    run()
