"""Mesh-sharded sampler + 2-D train-step scaling curves (docs/sharding.md).

``bench_sharded_sampler`` times the device-resident recency update+sample
round-trip and the device uniform sample at every shard count that fits the
visible device set (1, 2, 4, 8, ...), emitting one BENCH_JSON point per
(sampler, shards) pair — a scaling curve over the trajectory, not a single
number; the uniform sampler is timed under both CSR partitions (equal-rows
and degree-balanced boundaries — identical draws, different per-shard
padding). ``bench_2d_train_step`` times the full jitted CTDG train step
across 2-D ``(data, nodes)`` mesh shapes: each axis swept independently
((d,1) and (1,n) curves) plus the combined shapes, so the per-axis cost
composition is visible. On the CPU CI host
(``--xla_force_host_platform_device_count=8``) the curves measure
shard_map/collective *overhead* (all "devices" share the same cores, so
there is no real HBM/FLOP win to see); on real multi-chip hardware the
same curves are the scaling measurement. Records carry
``backend``/``device_count`` metadata (``benchmarks/common.py``) so the
regression gate never confuses the two regimes.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core.device_sampler import DeviceRecencySampler
from repro.core.device_uniform import DeviceUniformSampler
from repro.distributed.sharding import make_node_mesh

from benchmarks.common import emit, timeit


def _shard_counts() -> list:
    out, s = [], 1
    while s <= jax.device_count():
        out.append(s)
        s *= 2
    return out


def bench_sharded_sampler(B: int = 200, K: int = 20, N: int = 20_000,
                          num_batches: int = 20, E: int = 50_000) -> None:
    """Per-batch wall time of the sharded samplers vs shard count.

    Recency: ``num_batches`` update+sample rounds (train shape, S = 3B
    seeds). Uniform: ``num_batches`` sample calls over a pre-built E-edge
    CSR. ``shards=0`` rows are the unsharded (no-``shard_map``) baselines
    the shards=1 rows should sit close to — the gap is pure shard_map
    dispatch overhead.
    """
    rng = np.random.default_rng(0)
    S = 3 * B
    src = rng.integers(0, N, (num_batches, B))
    dst = rng.integers(0, N, (num_batches, B))
    t = np.sort(rng.integers(0, 100, (num_batches, B)), axis=1)
    t += np.arange(num_batches)[:, None] * 100
    seeds = rng.integers(0, N, (num_batches, S))

    esrc = rng.integers(0, N, E)
    edst = rng.integers(0, N, E)
    et = np.sort(rng.integers(0, 10_000, E))
    qt = rng.integers(0, 12_000, (num_batches, S))

    def run_recency(sampler):
        for i in range(num_batches):
            sampler.sample(seeds[i])
            sampler.update(src[i], dst[i], t[i])
        jax.block_until_ready(sampler.state)

    def run_uniform(sampler):
        out = None
        for i in range(num_batches):
            sampler.reset_state()  # fixed draw counter: same work per rep
            out = sampler.sample(seeds[i], qt[i])
        jax.block_until_ready(out.nbr_ids)

    for shards in [0] + _shard_counts():
        mesh = make_node_mesh(shards) if shards else None
        tag = f"s{shards}" if shards else "unsharded"

        rec = DeviceRecencySampler(N, K, mesh=mesh)
        run_recency(rec)  # compile
        rec.reset_state()
        t_rec = timeit(lambda: run_recency(rec), repeats=5) / num_batches
        emit(f"sharded/recency_update_sample_{tag}", t_rec,
             f"B{B} K{K} N{N} S{S} shards={shards}")

        uni = DeviceUniformSampler(N, K, mesh=mesh)
        uni.build(esrc, edst, et)
        run_uniform(uni)  # compile
        t_uni = timeit(lambda: run_uniform(uni), repeats=5) / num_batches
        emit(f"sharded/uniform_sample_{tag}", t_uni,
             f"K{K} N{N} E{E} S{S} shards={shards}")

        if shards:
            deg = DeviceUniformSampler(N, K, mesh=mesh, partition="degree")
            deg.build(esrc, edst, et)
            run_uniform(deg)  # compile
            t_deg = timeit(lambda: run_uniform(deg), repeats=5) / num_batches
            emit(f"sharded/uniform_sample_degree_{tag}", t_deg,
                 f"K{K} N{N} E{E} S{S} shards={shards} partition=degree")


def bench_2d_train_step(batch_size: int = 100) -> None:
    """Wall time of one jitted CTDG (TGAT, fused) train step across 2-D
    mesh shapes.

    Sweeps the data axis alone ((2,1), (4,1)), the node axis alone
    ((1,2), (1,4)), and the combined shapes ((2,2), (2,4), (4,2)),
    skipping any shape that needs more devices than are visible; (1,1) is
    the single-device fused baseline the 2-D step must parity-match. Uses
    the fused attention path (Pallas on TPU, the jnp fused oracle
    elsewhere) so the shard-aware layer and its node-axis psum are inside
    the timed step. One train batch is staged through the real hook
    pipeline, then the step itself — grads, psums, optimizer — is timed
    in isolation (the jitted step is pure in the batch: sampler updates
    happen at batch production, so replaying one batch is sound).
    """
    from repro.core import TRAIN_KEY
    from repro.data import generate
    from repro.tg.specs import SamplerSpec
    from repro.train.loop import CTDGLinkPipeline

    data = generate("tiny")
    fused = "auto" if jax.default_backend() == "tpu" else "ref"
    shapes = [(1, 1), (2, 1), (4, 1),
              (1, 2), (1, 4),
              (2, 2), (2, 4), (4, 2)]
    skipped = []
    for ds, ns in shapes:
        if ds * ns > jax.device_count():
            skipped.append((ds, ns))
            continue
        spec = SamplerSpec(kind="recency", device=True,
                           shards=ns if ns > 1 else None,
                           expose_buffer=True if ns > 1 else None)
        p = CTDGLinkPipeline("tgat", data, batch_size=batch_size, seed=0,
                             sampler_spec=spec, data_shards=ds, fused=fused)
        p.reset_epoch_state()
        with p.manager.activate(TRAIN_KEY):
            bt = p._batch_tensors(next(iter(p._loader(p.train_data))))

        def step():
            out = p._train_step(p.params, p.opt_state, bt)
            jax.block_until_ready(out[2])

        step()  # compile
        t = timeit(step, repeats=5)
        emit(f"sharded/2d_train_step_d{ds}n{ns}", t,
             f"tgat fused={fused} B{batch_size} mesh={ds}x{ns}")
    if skipped:
        print(f"# skipped (need more devices): {skipped}", flush=True)


if __name__ == "__main__":
    bench_sharded_sampler()
    bench_2d_train_step()
