"""Mesh-sharded sampler scaling curve (docs/sharding.md).

``bench_sharded_sampler`` times the device-resident recency update+sample
round-trip and the device uniform sample at every shard count that fits the
visible device set (1, 2, 4, 8, ...), emitting one BENCH_JSON point per
(sampler, shards) pair — a scaling curve over the trajectory, not a single
number. On the CPU CI host (``--xla_force_host_platform_device_count=8``)
the curve measures shard_map/collective *overhead* (all "devices" share the
same cores, so there is no real HBM win to see); on real multi-chip
hardware the same curve is the scaling measurement. Records carry
``backend``/``device_count`` metadata (``benchmarks/common.py``) so the
regression gate never confuses the two regimes.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core.device_sampler import DeviceRecencySampler
from repro.core.device_uniform import DeviceUniformSampler
from repro.distributed.sharding import make_node_mesh

from benchmarks.common import emit, timeit


def _shard_counts() -> list:
    out, s = [], 1
    while s <= jax.device_count():
        out.append(s)
        s *= 2
    return out


def bench_sharded_sampler(B: int = 200, K: int = 20, N: int = 20_000,
                          num_batches: int = 20, E: int = 50_000) -> None:
    """Per-batch wall time of the sharded samplers vs shard count.

    Recency: ``num_batches`` update+sample rounds (train shape, S = 3B
    seeds). Uniform: ``num_batches`` sample calls over a pre-built E-edge
    CSR. ``shards=0`` rows are the unsharded (no-``shard_map``) baselines
    the shards=1 rows should sit close to — the gap is pure shard_map
    dispatch overhead.
    """
    rng = np.random.default_rng(0)
    S = 3 * B
    src = rng.integers(0, N, (num_batches, B))
    dst = rng.integers(0, N, (num_batches, B))
    t = np.sort(rng.integers(0, 100, (num_batches, B)), axis=1)
    t += np.arange(num_batches)[:, None] * 100
    seeds = rng.integers(0, N, (num_batches, S))

    esrc = rng.integers(0, N, E)
    edst = rng.integers(0, N, E)
    et = np.sort(rng.integers(0, 10_000, E))
    qt = rng.integers(0, 12_000, (num_batches, S))

    def run_recency(sampler):
        for i in range(num_batches):
            sampler.sample(seeds[i])
            sampler.update(src[i], dst[i], t[i])
        jax.block_until_ready(sampler.state)

    def run_uniform(sampler):
        out = None
        for i in range(num_batches):
            sampler.reset_state()  # fixed draw counter: same work per rep
            out = sampler.sample(seeds[i], qt[i])
        jax.block_until_ready(out.nbr_ids)

    for shards in [0] + _shard_counts():
        mesh = make_node_mesh(shards) if shards else None
        tag = f"s{shards}" if shards else "unsharded"

        rec = DeviceRecencySampler(N, K, mesh=mesh)
        run_recency(rec)  # compile
        rec.reset_state()
        t_rec = timeit(lambda: run_recency(rec), repeats=5) / num_batches
        emit(f"sharded/recency_update_sample_{tag}", t_rec,
             f"B{B} K{K} N{N} S{S} shards={shards}")

        uni = DeviceUniformSampler(N, K, mesh=mesh)
        uni.build(esrc, edst, et)
        run_uniform(uni)  # compile
        t_uni = timeit(lambda: run_uniform(uni), repeats=5) / num_batches
        emit(f"sharded/uniform_sample_{tag}", t_uni,
             f"K{K} N{N} E{E} S{S} shards={shards}")


if __name__ == "__main__":
    bench_sharded_sampler()
