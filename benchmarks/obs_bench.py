"""Telemetry overhead microbenchmarks (``repro.obs``).

Gates the two costs the observability layer is allowed to have:

  obs/span_disabled   — a span + counter + histogram observe on a
                        sink-less ``Telemetry`` (the default state of
                        every instrumented hot path). This is the price
                        the whole codebase pays unconditionally, so it is
                        gated tightly; the epoch-level complement is the
                        ``storage/epoch_*`` benches, which drive full
                        instrumented training epochs with telemetry
                        disabled against the pre-instrumentation
                        baseline.
  obs/span_enabled    — the same triple into an attached ``MemorySink``:
                        what a run actually observing itself pays per
                        instrumented section.
"""

from __future__ import annotations

import time

from repro.obs import MemorySink, Telemetry

from benchmarks.common import emit


def _triple_per_call(tel: Telemetry, n: int) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        with tel.span("bench"):
            pass
        tel.count("c")
        tel.observe("h", 1e-4)
    return (time.perf_counter() - t0) / n


def run(n: int = 100_000) -> None:
    """Emit per-call span+counter+observe cost, disabled and enabled."""
    disabled = Telemetry()
    _triple_per_call(disabled, 1000)  # warm
    emit("obs/span_disabled", _triple_per_call(disabled, n))

    enabled = Telemetry()
    sink = enabled.attach(MemorySink())
    _triple_per_call(enabled, 1000)
    sink.drain()
    emit("obs/span_enabled", _triple_per_call(enabled, n),
         f"records={len(sink.records)}")


if __name__ == "__main__":
    run()
