"""Paper Table 3: training time per epoch for link property prediction.

Models: TGAT, TGN, GraphMixer, TPNet (CTDG, event-iterated) and GCN/GCLSTM
(DTDG via discretization) on the synthetic Wikipedia analogue, each
declared through ``tg.Experiment`` (the CTDG/DTDG split is one
``DataSpec.discretization`` field). A "DyGLib-style" baseline
(per-prediction neighbor re-sampling, no batch dedup, python-loop sampler)
is measured for TGAT to expose the speedup the paper reports against
DyGLib.
"""

from __future__ import annotations

import numpy as np

from repro.core import TRAIN_KEY
from repro.core.sampler import SequentialRecencySampler
from repro.core.tg_hooks import RecencyNeighborHook
from repro.data import generate
from repro.tg import DataSpec, Experiment, ModelSpec, SamplerSpec, TrainSpec

from benchmarks.common import emit


def _ctdg_exp(model: str, dataset: str, scale: float,
              k: int = 10) -> Experiment:
    kwargs = {"num_layers": 1} if model == "tgat" else {}
    return Experiment(
        data=DataSpec(dataset, scale=scale),
        model=ModelSpec(model, kwargs),
        sampler=SamplerSpec(k=k),
        train=TrainSpec(batch_size=200),
    )


def _dyglib_style_epoch(data, dataset: str, scale: float) -> float:
    """Per-prediction re-sampling with a sequential (python-loop) sampler and
    no batch-level dedup — the access pattern the paper attributes to
    DyGLib. Uses the same TGAT model; only the data path differs. k=20
    matches the baseline's historical configuration so the emitted
    trajectory metric stays comparable across PRs."""
    tr = _ctdg_exp("tgat", dataset, scale, k=20).compile(data)
    # swap the vectorized dedup sampler for the sequential, non-dedup one
    for hook in tr.manager.hooks(TRAIN_KEY):
        if isinstance(hook, RecencyNeighborHook):
            hook.dedup = False
            slow = SequentialRecencySampler(data.num_nodes, hook.k)
            hook.sampler = slow
    loss, secs = tr.train_epoch()
    assert np.isfinite(loss)
    return secs


def run(scale: float = 0.02, dataset: str = "wikipedia") -> None:
    data = generate(dataset, scale=scale)
    E = data.num_edge_events

    for model in ("tgat", "graphmixer", "tgn", "tpnet"):
        tr = _ctdg_exp(model, dataset, scale).compile(data)
        tr.train_epoch()  # warm compile
        _, secs = tr.train_epoch()
        emit(f"table3/{dataset}/{model}", secs, f"E={E}")
        if model == "tgat":
            slow = _dyglib_style_epoch(data, dataset, scale)
            emit(f"table3/{dataset}/tgat_dyglib_style", slow,
                 f"speedup={slow / secs:.1f}x")

    for model in ("gcn", "gclstm"):
        exp = Experiment(
            data=DataSpec(dataset, scale=scale, discretization="h"),
            model=ModelSpec(model, {"d_embed": 64}),
        )
        tr = exp.compile(data)
        tr.train_epoch()  # warm compile of the scanned epoch
        _, secs = tr.train_epoch()
        emit(f"table3/{dataset}/{model}", secs,
             f"E={E} (DTDG hourly, scan-compiled)")


if __name__ == "__main__":
    run()
