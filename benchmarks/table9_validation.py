"""Paper Table 9 / §5.1: one-vs-many TGB validation latency.

TGM's protocol: with N negatives per positive, the whole (positives +
negatives) candidate set is materialized ONCE per batch — de-duplicated
vectorized neighbor sampling + a single jitted scoring call.

The DyGLib access pattern the paper benchmarks against evaluates
per-candidate: for every negative column it re-samples neighborhoods and
invokes the model again (N+1 model calls and N+1 sampling passes per
batch). We reproduce both on the same TGAT model/weights. The paper
reports up to 246x on GPU, where per-call launch overheads amplify the
gap; the mechanism (calls x resampling vs one fused pass) is identical.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    DGraph,
    DGDataLoader,
    EVAL_KEY,
    TRAIN_KEY,
)
from repro.core.tg_hooks import RecencyNeighborHook
from repro.data import generate
from repro.tg import DataSpec, Experiment, ModelSpec, SamplerSpec, TrainSpec
from repro.train.metrics import mrr as mrr_metric

from benchmarks.common import emit


def _per_candidate_eval(tr, eval_negatives: int):
    """DyGLib-style: one sampling pass + one model call PER candidate
    column (positive + each negative)."""
    import jax
    import jax.numpy as jnp

    from repro.models.tg import tgat
    from repro.models.tg.common import link_decoder

    cfg = tr.cfg
    B = tr.batch_size

    @jax.jit
    def score_pairs(params, batch):
        h = tgat.embed(params, cfg, batch)  # (2B, d): [src | cand]
        return link_decoder(params["decoder"], h[:B], h[B:2 * B])

    # fresh hook state, warm through train split
    tr.reset_epoch_state()
    hook = next(h for h in tr.manager.hooks(TRAIN_KEY)
                if isinstance(h, RecencyNeighborHook))
    with tr.manager.activate(TRAIN_KEY):
        for batch in tr._loader(tr.train_data):
            pass

    t0 = time.perf_counter()
    rrs, ws = [], []
    with tr.manager.activate(EVAL_KEY):
        for batch in tr._loader(tr.val_data):
            neg = np.asarray(batch["neg"])  # (B, Nn)
            src = np.asarray(batch["src"])
            tfr = np.asarray(batch["time"])
            cols = [np.asarray(batch["dst"])] + [neg[:, j] for j in
                                                 range(neg.shape[1])]
            scores = []
            efeats = tr.train_data.edge_feats
            for cand in cols:  # per-candidate resampling + model call
                seeds = np.concatenate([src, cand])
                times_ = np.concatenate([tfr, tfr])
                blk = hook.sampler.sample(seeds)
                nbr_feats = np.zeros(blk.nbr_ids.shape + (cfg.d_edge,),
                                     np.float32)
                if efeats is not None:
                    ok = (blk.nbr_eids >= 0) & (blk.nbr_eids < len(efeats))
                    nbr_feats[ok] = efeats[blk.nbr_eids[ok]]
                bt = {
                    "seed_nodes": seeds, "seed_times": times_,
                    "nbr_ids": blk.nbr_ids, "nbr_times": blk.nbr_times,
                    "nbr_mask": blk.mask, "nbr_feats": nbr_feats,
                }
                scores.append(np.asarray(score_pairs(tr.params, bt)))
            pos, negs = scores[0], np.stack(scores[1:], 1)
            w = float(np.asarray(batch["batch_mask"]).sum())
            rrs.append(mrr_metric(pos, negs, batch["batch_mask"]) * w)
            ws.append(w)
    secs = time.perf_counter() - t0
    return float(np.sum(rrs) / max(np.sum(ws), 1.0)), secs


def run(scale: float = 0.02, dataset: str = "wikipedia",
        eval_negatives: int = 50) -> None:
    data = generate(dataset, scale=scale)

    tr = Experiment(
        data=DataSpec(dataset, scale=scale),
        model=ModelSpec("tgat", {"num_layers": 1}),
        sampler=SamplerSpec(k=10),
        train=TrainSpec(batch_size=200, eval_negatives=eval_negatives),
    ).compile(data)
    tr.train_epoch()  # train weights + warm compiles

    mrr_tgm, t_tgm = tr.evaluate("val")
    emit(f"table9/{dataset}/eval_tgm_fused", t_tgm,
         f"mrr={mrr_tgm:.3f} negs={eval_negatives}")

    mrr_dy, t_dy = _per_candidate_eval(tr, eval_negatives)
    emit(f"table9/{dataset}/eval_per_candidate", t_dy,
         f"mrr={mrr_dy:.3f} negs={eval_negatives}")
    emit(f"table9/{dataset}/speedup", t_dy - t_tgm,
         f"speedup={t_dy / t_tgm:.1f}x")


if __name__ == "__main__":
    run()
