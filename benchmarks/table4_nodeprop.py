"""Paper Table 4: dynamic node property prediction (trade/genre-like
synthetic): time per run + NDCG@10 for PF / TGN / GCN, all through the
``tg.Experiment`` node task. PF and TGN run the event-window pipeline;
GCN runs the scan-compiled ``SnapshotTensor`` pipeline (its labels count
unique next-window partners — the discretized view collapses duplicate
event classes)."""

from __future__ import annotations

import time

from repro.data import generate
from repro.tg import DataSpec, Experiment, ModelSpec, TrainSpec

from benchmarks.common import emit


def run(scale: float = 0.02, dataset: str = "genre") -> None:
    data = generate(dataset, scale=scale)
    for model in ("pf", "tgn", "gcn"):
        exp = Experiment(
            task="node",
            data=DataSpec(dataset, scale=scale, discretization="d",
                          val_ratio=0.0, test_ratio=0.3),
            model=ModelSpec(model, {"num_cats": 16}),
            train=TrainSpec(epochs=1),
        )
        t0 = time.perf_counter()
        out = exp.run(data=data, splits=("test",))
        secs = time.perf_counter() - t0
        emit(f"table4/{dataset}/{model}", secs,
             f"ndcg@10={out['metrics']['test']:.3f} E={data.num_edge_events}")


if __name__ == "__main__":
    run()
