"""Paper Table 4: dynamic node property prediction (trade/genre-like
synthetic): time per run + NDCG@10 for PF / TGN / GCN."""

from __future__ import annotations

from repro.data import generate
from repro.train.nodeprop import NodePropertyTrainer

from benchmarks.common import emit


def run(scale: float = 0.02, dataset: str = "genre") -> None:
    data = generate(dataset, scale=scale)
    for model in ("pf", "tgn", "gcn"):
        tr = NodePropertyTrainer(model, data, unit="d", num_cats=16)
        ndcg, secs = tr.run()
        emit(f"table4/{dataset}/{model}", secs,
             f"ndcg@10={ndcg:.3f} E={data.num_edge_events}")


if __name__ == "__main__":
    run()
