"""Online serving benchmark: request latency (p50/p99) and throughput vs
microbatch size for ``OnlineGraphService``.

For each batch size B the bench pre-warms a service with a synthetic event
stream, then submits closed-loop waves of B concurrent ``predict_link``
requests (``max_batch=B``, so flushes are size-triggered) and reports:

  * ``serving_link_p50_b{B}`` / ``serving_link_p99_b{B}`` — per-request
    enqueue-to-resolve latency percentiles (seconds -> us, lower-better);
  * ``serving_link_qps_b{B}`` — completed requests per second
    (higher-better: its baseline entry carries ``direction: "higher"``
    for ``scripts/check_bench_regression.py``).

``--fast`` shrinks the wave count for CI. All records land in BENCH_JSON
via ``benchmarks.common`` and are gated against
``benchmarks/baseline_cpu.json``.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, emit_value

from repro.serve import OnlineGraphService, Status


def bench_serving(batch_sizes=(1, 8, 32), *, num_nodes: int = 500,
                  n_events: int = 2000, waves: int = 30, k: int = 8) -> None:
    """Latency/throughput sweep over microbatch sizes (see module doc)."""
    rng = np.random.default_rng(0)
    events = [(int(rng.integers(num_nodes)), int(rng.integers(num_nodes)),
               100 + i, i) for i in range(n_events)]
    queries = rng.integers(0, num_nodes, size=(max(batch_sizes) * waves, 2))

    for B in batch_sizes:
        svc = OnlineGraphService(num_nodes, k=k, max_batch=B,
                                 flush_interval=0.05 if B > 1 else 0.001)
        try:
            svc.ingest_many(events)
            svc.drain()
            # warmup: trigger jit compilation for this batch shape
            warm = [svc.submit_link(1, 2, 10 ** 6) for _ in range(B)]
            for p in warm:
                assert p.result(timeout=60).status is Status.OK
            lats = []
            t0 = time.perf_counter()
            done = 0
            for w in range(waves):
                qs = queries[w * B:(w + 1) * B]
                pend = [svc.submit_link(int(s), int(d), 10 ** 6)
                        for s, d in qs]
                for p in pend:
                    r = p.result(timeout=60)
                    assert r.status is Status.OK
                    lats.append(r.latency_s)
                    done += 1
            wall = time.perf_counter() - t0
            emit(f"serving_link_p50_b{B}", float(np.percentile(lats, 50)),
                 f"n={done}")
            emit(f"serving_link_p99_b{B}", float(np.percentile(lats, 99)),
                 f"n={done}")
            emit_value(f"serving_link_qps_b{B}", done / wall,
                       "requests/s (higher is better)")
        finally:
            svc.stop()


def bench_ingest(num_nodes: int = 500, n_events: int = 3000) -> None:
    """Event-stream ingest rate (events/s through the bounded queue into
    sampler + EdgeBank; higher-better)."""
    rng = np.random.default_rng(1)
    events = [(int(rng.integers(num_nodes)), int(rng.integers(num_nodes)),
               100 + i, i) for i in range(n_events)]
    svc = OnlineGraphService(num_nodes, k=8)
    try:
        svc.ingest(0, 1, 1, -1)  # warm the jitted sampler update
        svc.drain()
        t0 = time.perf_counter()
        svc.ingest_many(events)
        svc.drain()
        wall = time.perf_counter() - t0
        emit_value("serving_ingest_eps", n_events / wall,
                   "events/s (higher is better)")
    finally:
        svc.stop()


def main(argv=None) -> int:
    """CLI entry point (``--fast`` = CI-sized run)."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized run (fewer waves/events)")
    args = ap.parse_args(argv)
    if args.fast:
        bench_serving((1, 8), n_events=500, waves=10)
        bench_ingest(n_events=1000)
    else:
        bench_serving()
        bench_ingest()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
