"""Train an assigned-architecture LM on the synthetic token stream.

Any of the 10 archs is selectable; ``--reduced`` uses the smoke config
(CPU-friendly), otherwise pass ``--layers/--d-model`` overrides to build a
~100M variant. Checkpoints + resume come from the production driver.

    PYTHONPATH=src python examples/lm_train.py --arch qwen3-0.6b --reduced \
        --steps 200 --batch-size 8 --seq-len 64
"""

import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    argv = ["--workload", "lm"] + sys.argv[1:]
    if "--arch" not in argv:
        argv += ["--arch", "qwen3-0.6b", "--reduced"]
    raise SystemExit(train_main(argv))
