"""RQ2 (paper Table 6): snapshot granularity as a hyperparameter.

One line changes the snapshot resolution; MRR shifts substantially.

    PYTHONPATH=src python examples/granularity_study.py
"""

from repro.data import generate
from repro.train import SnapshotLinkTrainer


def main():
    data = generate("wikipedia", scale=0.01)
    print(f"{data.num_edge_events} events over "
          f"{(data.time_span[1] - data.time_span[0]) / 86400:.0f} days\n")
    print(f"{'granularity':>12s} {'snapshots':>10s} {'val MRR':>8s}")
    for unit in ["h", "d", "w"]:
        tr = SnapshotLinkTrainer("gcn", data, snapshot_unit=unit, d_embed=32)
        tr.run_epoch(train=True)
        tr.run_epoch(train=True)
        mrr, _ = tr.run_epoch(train=False)
        n_snaps = len(list(tr._snapshots()))
        print(f"{unit:>12s} {n_snaps:>10d} {mrr:>8.3f}")


if __name__ == "__main__":
    main()
