"""RQ2 (paper Table 6): snapshot granularity as a hyperparameter.

One line changes the snapshot resolution; MRR shifts substantially. Runs on
the scan-compiled DTDG pipeline: the stream is tensorized once per
granularity (jitted discretize + scatter) and each train epoch is a single
scanned jitted call (see docs/dtdg.md).

    PYTHONPATH=src python examples/granularity_study.py [--fast]

``--fast`` is the CI smoke path: tiny scale, one granularity, one epoch.
"""

import sys

from repro.data import generate
from repro.train import SnapshotLinkTrainer


def main(fast: bool = False):
    scale = 0.004 if fast else 0.01
    units = ["d"] if fast else ["h", "d", "w"]
    epochs = 1 if fast else 2
    data = generate("wikipedia", scale=scale)
    print(f"{data.num_edge_events} events over "
          f"{(data.time_span[1] - data.time_span[0]) / 86400:.0f} days\n")
    print(f"{'granularity':>12s} {'snapshots':>10s} {'capacity':>9s} "
          f"{'val MRR':>8s} {'test MRR':>9s}")
    for unit in units:
        tr = SnapshotLinkTrainer("gcn", data, snapshot_unit=unit, d_embed=32)
        for _ in range(epochs):
            tr.train_epoch()
        val_mrr, _ = tr.evaluate("val")
        test_mrr, _ = tr.evaluate("test")
        print(f"{unit:>12s} {tr.snapshots.num_snapshots:>10d} "
              f"{tr.capacity:>9d} {val_mrr:>8.3f} {test_mrr:>9.3f}")


if __name__ == "__main__":
    main(fast="--fast" in sys.argv[1:])
