"""RQ2 (paper Table 6): snapshot granularity as a hyperparameter.

One spec field changes the snapshot resolution; MRR shifts substantially.
Each granularity is one declarative ``tg.Experiment`` whose
``DataSpec.discretization`` axis selects the scan-compiled DTDG pipeline:
the stream is tensorized once per granularity (jitted discretize +
scatter) and each train epoch is a single scanned jitted call (see
docs/dtdg.md and docs/experiment.md).

    PYTHONPATH=src python examples/granularity_study.py [--fast]

``--fast`` is the CI smoke path: tiny scale, one granularity, one epoch.
"""

import sys

from repro.tg import DataSpec, Experiment, ModelSpec, TrainSpec
from repro.data import generate


def main(fast: bool = False):
    scale = 0.004 if fast else 0.01
    units = ["d"] if fast else ["h", "d", "w"]
    epochs = 1 if fast else 2
    data = generate("wikipedia", scale=scale)
    print(f"{data.num_edge_events} events over "
          f"{(data.time_span[1] - data.time_span[0]) / 86400:.0f} days\n")
    print(f"{'granularity':>12s} {'snapshots':>10s} {'capacity':>9s} "
          f"{'val MRR':>8s} {'test MRR':>9s}")
    for unit in units:
        exp = Experiment(
            data=DataSpec("wikipedia", scale=scale, discretization=unit),
            model=ModelSpec("gcn", {"d_embed": 32}),
            train=TrainSpec(epochs=epochs),
        )
        out = exp.run(data=data, splits=("val", "test"))
        pipeline = out["pipeline"]
        print(f"{unit:>12s} {pipeline.snapshots.num_snapshots:>10d} "
              f"{pipeline.capacity:>9d} {out['metrics']['val']:>8.3f} "
              f"{out['metrics']['test']:>9.3f}")


if __name__ == "__main__":
    main(fast="--fast" in sys.argv[1:])
