"""Serve a small model with batched requests: prefill a batch of prompts,
then decode tokens for all requests in lockstep (static shapes).

    PYTHONPATH=src python examples/serve_batched.py --arch hymba-1.5b
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.lm import model as M
from repro.serve import generate


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="hymba-1.5b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--new-tokens", type=int, default=16)
    args = p.parse_args()

    cfg = get_arch(args.arch).reduced()
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family in ("audio", "vlm"):
        batch["frontend"] = jnp.zeros(
            (args.batch, cfg.frontend_seq, cfg.d_model), jnp.float32)

    t0 = time.perf_counter()
    out = generate(params, cfg, batch, num_tokens=args.new_tokens,
                   temperature=0.8, kv_block=16)
    dt = time.perf_counter() - t0
    tput = args.batch * args.new_tokens / dt
    print(f"{args.arch} ({cfg.name}): generated {out.shape} in {dt:.1f}s "
          f"({tput:.1f} tok/s incl. compile)")
    print("sample token ids:", np.asarray(out[0])[:12].tolist())


if __name__ == "__main__":
    main()
