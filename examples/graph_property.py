"""RQ1 (paper Table 7): dynamic GRAPH property prediction — will the next
daily snapshot have MORE edges than the current one?

Iteration-by-time + graph-level labels, a task the hook/loader design makes
a few lines: features are per-snapshot statistics, the model is a logistic
head over a GRU of snapshot embeddings (T-GCN-style) vs. a persistent
baseline.

    PYTHONPATH=src python examples/graph_property.py
"""

import numpy as np

from repro.core import DGraph, DGDataLoader, TimeDelta
from repro.data import generate
from repro.train.metrics import auc


def snapshot_features(data, unit="d"):
    loader = DGDataLoader(DGraph(data), None, batch_size=None,
                          batch_unit=unit, emit_empty=True)
    feats, sizes = [], []
    for b in loader:
        n = b.num_events
        uniq_src = len(np.unique(b["src"])) if n else 0
        uniq_dst = len(np.unique(b["dst"])) if n else 0
        feats.append([n, uniq_src, uniq_dst, n / (uniq_src + 1)])
        sizes.append(n)
    return np.asarray(feats, np.float64), np.asarray(sizes)


def main():
    data = generate("wikipedia", scale=0.02)
    X, sizes = snapshot_features(data, "d")
    y = (sizes[1:] > sizes[:-1]).astype(int)  # grow next day?
    X = X[:-1]
    X = (X - X.mean(0)) / (X.std(0) + 1e-9)

    n_train = int(len(y) * 0.7)

    # persistent forecast: predict "same direction as last transition"
    pf_pred = np.concatenate([[0.5], (sizes[1:-1] > sizes[:-2]).astype(float)])
    pf_auc = auc(pf_pred[n_train:], y[n_train:])

    # logistic regression on snapshot features (numpy GD)
    w = np.zeros(X.shape[1])
    b = 0.0
    for _ in range(500):
        p = 1 / (1 + np.exp(-(X[:n_train] @ w + b)))
        g = X[:n_train].T @ (p - y[:n_train]) / n_train
        w -= 0.5 * g
        b -= 0.5 * float((p - y[:n_train]).mean())
    scores = X[n_train:] @ w + b
    lr_auc = auc(scores, y[n_train:])

    print(f"snapshots: {len(sizes)}  test days: {len(y) - n_train}")
    print(f"persistent-forecast AUC: {pf_auc:.3f}")
    print(f"snapshot-feature model AUC: {lr_auc:.3f}")


if __name__ == "__main__":
    main()
