"""End-to-end driver: train dynamic link prediction across the CTDG *and*
DTDG halves of the model zoo through the single ``tg.Experiment`` front
door, with checkpointing, and report one-vs-many test MRR — the paper's
core task, soup to nuts.

Each model run is one declarative ``Experiment``: the CTDG models keep the
native event stream (``DataSpec.discretization=None`` -> event-iterated
pipeline), the snapshot models set a daily discretization axis (->
scan-compiled pipeline). ``--device-sampling`` only changes the
``SamplerSpec``.

    PYTHONPATH=src python examples/linkpred_end_to_end.py [--scale 0.02]
"""

import argparse

from repro.tg import DataSpec, Experiment, ModelSpec, SamplerSpec, TrainSpec
from repro.data import generate

CTDG_MODELS = ["tgat", "graphmixer", "tpnet", "tgn"]
DTDG_MODELS = ["gcn", "gclstm"]


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--scale", type=float, default=0.01)
    p.add_argument("--dataset", default="wikipedia")
    p.add_argument("--epochs", type=int, default=2)
    p.add_argument("--ckpt-dir", default="checkpoints/linkpred")
    p.add_argument("--device-sampling", action="store_true",
                   help="device-resident recency buffers + prefetching loader "
                        "(bit-identical outputs to the host numpy sampler)")
    p.add_argument("--fast", action="store_true",
                   help="CI smoke path: tiny scale, one epoch")
    args = p.parse_args()
    if args.fast:
        args.scale, args.epochs = 0.004, 1

    data = generate(args.dataset, scale=args.scale)
    print(f"{args.dataset} x{args.scale}: {data.num_edge_events} events "
          f"(~{data.num_edge_events * args.epochs // 200} train steps/model)")

    results = {}
    for model in CTDG_MODELS + DTDG_MODELS:
        if model in CTDG_MODELS:
            kwargs = {"num_layers": 1} if model == "tgat" else {}
            exp = Experiment(
                data=DataSpec(args.dataset, scale=args.scale),
                model=ModelSpec(model, kwargs),
                sampler=SamplerSpec(k=10, device=args.device_sampling),
                train=TrainSpec(epochs=args.epochs, batch_size=200,
                                eval_negatives=20,
                                ckpt_dir=f"{args.ckpt_dir}/{model}",
                                ckpt_every=args.epochs),
            )
        else:
            exp = Experiment(
                data=DataSpec(args.dataset, scale=args.scale,
                              discretization="d"),
                model=ModelSpec(model, {"d_embed": 64}),
                train=TrainSpec(epochs=args.epochs, eval_negatives=20,
                                ckpt_dir=f"{args.ckpt_dir}/{model}",
                                ckpt_every=args.epochs),
            )
        out = exp.run(data=data, splits=("test",),
                      log=lambda msg: print(f"[{model}] {msg}"))
        results[model] = out["metrics"]["test"]

    print("\ntest MRR (20 negatives):")
    for model, mrr in sorted(results.items(), key=lambda kv: -kv[1]):
        print(f"  {model:12s} {mrr:.4f}")


if __name__ == "__main__":
    main()
