"""End-to-end driver: train dynamic link prediction for a few hundred steps
across several CTDG/DTDG models and report one-vs-many test MRR, with
checkpointing — the paper's core task, soup to nuts.

    PYTHONPATH=src python examples/linkpred_end_to_end.py [--scale 0.02]
"""

import argparse

import numpy as np

from repro.data import generate
from repro.distributed import checkpoint as ckpt
from repro.train import LinkPredictionTrainer, SnapshotLinkTrainer


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--scale", type=float, default=0.01)
    p.add_argument("--dataset", default="wikipedia")
    p.add_argument("--epochs", type=int, default=2)
    p.add_argument("--ckpt-dir", default="checkpoints/linkpred")
    p.add_argument("--device-sampling", action="store_true",
                   help="device-resident recency buffers + prefetching loader "
                        "(bit-identical outputs to the host numpy sampler)")
    args = p.parse_args()

    data = generate(args.dataset, scale=args.scale)
    print(f"{args.dataset} x{args.scale}: {data.num_edge_events} events "
          f"(~{data.num_edge_events * args.epochs // 200} train steps/model)")

    results = {}
    for model in ["tgat", "graphmixer", "tpnet", "tgn"]:
        kwargs = {"num_layers": 1} if model == "tgat" else None
        tr = LinkPredictionTrainer(model, data, batch_size=200, k=10,
                                   eval_negatives=20, model_kwargs=kwargs,
                                   device_sampling=args.device_sampling)
        for epoch in range(args.epochs):
            loss, secs = tr.train_epoch()
            print(f"[{model}] epoch {epoch}: loss={loss:.4f} ({secs:.1f}s)")
        ckpt.save(f"{args.ckpt_dir}/{model}", args.epochs - 1,
                  {"params": tr.params})
        mrr, _ = tr.evaluate("test")
        results[model] = mrr

    for model in ["gcn", "gclstm"]:
        # Scan-compiled DTDG pipeline: one jitted call per train epoch.
        tr = SnapshotLinkTrainer(model, data, snapshot_unit="d", d_embed=64)
        for epoch in range(args.epochs):
            loss, secs = tr.train_epoch()
            print(f"[{model}] epoch {epoch}: loss={loss:.4f} ({secs:.1f}s, "
                  f"{tr.snapshots.num_snapshots} snapshots scanned)")
        tr.save_checkpoint(f"{args.ckpt_dir}/{model}", args.epochs - 1)
        results[model], _ = tr.evaluate("test")

    print("\ntest MRR (20 negatives):")
    for model, mrr in sorted(results.items(), key=lambda kv: -kv[1]):
        print(f"  {model:12s} {mrr:.4f}")


if __name__ == "__main__":
    main()
