"""Quickstart — the paper's Fig. 5 workflow in ~30 lines.

Load a temporal graph, build the TGB link-prediction recipe, train TGAT for
two epochs, evaluate one-vs-many MRR.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.data import generate
from repro.train import LinkPredictionTrainer

# 1. Load a temporal graph (synthetic Wikipedia analogue) and split it.
data = generate("wikipedia", scale=0.01)
print(f"graph: {data.num_edge_events} events, {data.num_nodes} nodes, "
      f"{data.edge_feat_dim}-dim edge features")

# 2. Build the model + TGB link recipe (negatives, recency neighbors,
#    padding, device transfer) — one call.
trainer = LinkPredictionTrainer(
    "tgat", data,
    batch_size=200, k=10, eval_negatives=20,
    model_kwargs={"num_layers": 1},
)

# 3. Train; hooks run transparently inside the loader.
for epoch in range(2):
    loss, secs = trainer.train_epoch()
    print(f"epoch {epoch}: loss={loss:.4f}  ({secs:.1f}s)")

# 4. One-vs-many evaluation (batch-deduplicated sampling).
mrr, secs = trainer.evaluate("val")
print(f"validation MRR: {mrr:.4f}  ({secs:.1f}s)")
