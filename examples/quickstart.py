"""Quickstart — the paper's Fig. 5 workflow through the one front door.

Declare a link-prediction experiment as specs, compile it into the TGB
link pipeline, train TGAT for two epochs, evaluate one-vs-many MRR. The
same ``Experiment`` object serializes to a JSON blob (``to_json``) that
reproduces the run bit-for-bit.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.tg import DataSpec, Experiment, ModelSpec, SamplerSpec, TrainSpec

# 1. Declare the experiment: dataset + splits, model, sampling, training.
#    DataSpec.discretization=None keeps the native event stream (CTDG);
#    setting a unit (e.g. "h") would compile the scan-based snapshot
#    pipeline instead — same entry point.
exp = Experiment(
    data=DataSpec("wikipedia", scale=0.01),
    model=ModelSpec("tgat", {"num_layers": 1}),
    sampler=SamplerSpec(kind="recency", k=10),
    train=TrainSpec(epochs=2, batch_size=200, eval_negatives=20),
    task="link",
)
print("spec:", exp.to_json())

# 2. Compile: the specs assemble the model + TGB link recipe (negatives,
#    recency neighbors, padding, device transfer) — one call.
pipeline = exp.compile()
print(f"graph: {pipeline.data.num_edge_events} events, "
      f"{pipeline.data.num_nodes} nodes, "
      f"{pipeline.data.edge_feat_dim}-dim edge features")

# 3. Train; hooks run transparently inside the loader.
for epoch in range(exp.train.epochs):
    loss, secs = pipeline.train_epoch()
    print(f"epoch {epoch}: loss={loss:.4f}  ({secs:.1f}s)")

# 4. One-vs-many evaluation (batch-deduplicated sampling).
mrr, secs = pipeline.evaluate("val")
print(f"validation MRR: {mrr:.4f}  ({secs:.1f}s)")
