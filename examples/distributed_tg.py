"""DistTGL-style data-parallel temporal-graph training with shard_map:
4 (emulated) devices, gradient compression, and synchronized TGN-style
node state. Run standalone — it forces a 4-device CPU topology.

    python examples/distributed_tg.py            # (PYTHONPATH=src)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.dp_trainer import DataParallelTrainer
from repro.optim import AdamWConfig


def main():
    mesh = jax.make_mesh((4,), ("data",))
    N, D = 64, 16  # nodes, embedding dim

    # Toy memory model: per-event, predict dst embedding from src memory.
    def loss_fn(params, state, batch):
        src, dst = batch["src"], batch["dst"]
        h = state["memory"][src] @ params["w"]
        target = params["emb"][dst]
        loss = ((h - target) ** 2).mean()
        new_mem = state["memory"].at[src].set(0.9 * state["memory"][src] + 0.1 * target)
        touched = jnp.zeros(N, bool).at[src].set(True)
        return loss, ({"memory": new_mem}, touched)

    key = jax.random.PRNGKey(0)
    params = {"w": jnp.eye(D), "emb": jax.random.normal(key, (N, D)) * 0.5}
    state = {"memory": jnp.zeros((N, D))}

    for scheme in ("none", "bf16", "int8_ef"):
        tr = DataParallelTrainer(loss_fn, mesh, AdamWConfig(lr=5e-3),
                                 compression=scheme, accum_steps=2)
        opt, err = tr.init(params)
        tr.build_step(stateful=True)
        err = {} if err is None else err
        rng = np.random.default_rng(0)
        p, st, losses = params, state, []
        for step in range(20):
            batch = {
                "src": jnp.asarray(rng.integers(0, N, (2, 32)), jnp.int32),
                "dst": jnp.asarray(rng.integers(0, N, (2, 32)), jnp.int32),
            }
            p, opt, err, st, loss = tr._step(p, opt, err, st, batch)
            losses.append(float(loss))
        print(f"compression={scheme:8s} loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"(4-way DP, grads: {scheme})")


if __name__ == "__main__":
    main()
