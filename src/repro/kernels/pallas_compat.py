"""Version compatibility shims for the Pallas TPU API.

The TPU compiler-params dataclass was renamed across JAX releases
(``TPUCompilerParams`` -> ``CompilerParams``). Kernels import the symbol
from here so they run against whichever name the installed JAX exposes.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
if CompilerParams is None:  # pragma: no cover - ancient JAX
    raise ImportError("pallas TPU compiler params class not found")
