"""Pure-jnp oracle for the temporal neighbor attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def temporal_attention_ref(q, k, v, mask, *, scale: float | None = None):
    """Seed-to-neighborhood attention (TGAT layer core).

    q: (S, H, D) seed queries; k, v: (S, K, H, D) per-seed neighbor keys /
    values (already fused with edge features + time encoding by the caller);
    mask: (S, K) neighbor validity. Returns (S, H, D); rows with no valid
    neighbor are zero.
    """
    scale = scale if scale is not None else 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("shd,skhd->shk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    any_valid = mask.any(-1)[:, None, None]
    p = jnp.where(any_valid, p, 0.0)
    o = jnp.einsum("shk,skhd->shd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def fused_recency_attention_ref(q, k_table, v_table, seeds, buf_ids, *,
                                scale: float | None = None):
    """Oracle for the fused gather+attention kernel.

    Materializes the per-seed neighbor k/v tensors explicitly (the HBM
    round-trip the fused kernel avoids) and then runs the plain oracle.

    q: (S, H, D) seed queries; k_table, v_table: (N, H, D) node-level
    projected keys/values; seeds: (S,) node ids; buf_ids: (N, K) resident
    recency buffer rows (-1 = empty slot). Returns (S, H, D).
    """
    nbr = buf_ids[seeds]  # (S, K)
    mask = nbr >= 0
    safe = jnp.maximum(nbr, 0)
    k = k_table[safe]  # (S, K, H, D) — materialized here, not in the kernel
    v = v_table[safe]
    return temporal_attention_ref(q, k, v, mask, scale=scale)
