"""Pure-jnp oracle for the temporal neighbor attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def temporal_attention_ref(q, k, v, mask, *, scale: float | None = None):
    """Seed-to-neighborhood attention (TGAT layer core).

    q: (S, H, D) seed queries; k, v: (S, K, H, D) per-seed neighbor keys /
    values (already fused with edge features + time encoding by the caller);
    mask: (S, K) neighbor validity. Returns (S, H, D); rows with no valid
    neighbor are zero.
    """
    scale = scale if scale is not None else 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("shd,skhd->shk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    any_valid = mask.any(-1)[:, None, None]
    p = jnp.where(any_valid, p, 0.0)
    o = jnp.einsum("shk,skhd->shd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def fused_recency_attention_ref(q, k_table, v_table, seeds, buf_ids, *,
                                scale: float | None = None):
    """Oracle for the fused gather+attention kernel.

    Materializes the per-seed neighbor k/v tensors explicitly (the HBM
    round-trip the fused kernel avoids) and then runs the plain oracle.

    q: (S, H, D) seed queries; k_table, v_table: (N, H, D) node-level
    projected keys/values; seeds: (S,) node ids; buf_ids: (N, K) resident
    recency buffer rows (-1 = empty slot). Returns (S, H, D).
    """
    nbr = buf_ids[seeds]  # (S, K)
    mask = nbr >= 0
    safe = jnp.maximum(nbr, 0)
    k = k_table[safe]  # (S, K, H, D) — materialized here, not in the kernel
    v = v_table[safe]
    return temporal_attention_ref(q, k, v, mask, scale=scale)


def fused_temporal_layer_ref(
    q, k_table, v_table, seeds, seed_times, buf, *,
    time_w=None, time_b=None, wt_k=None, wt_v=None,
    edge_feats=None, we_k=None, we_v=None, scale: float | None = None,
):
    """Oracle for ``fused_temporal_layer_kernel`` — and the non-TPU fallback
    of ``ops.fused_temporal_layer``.

    Materializes everything the kernel keeps in VMEM scratch: the gathered
    node-level k/v rows (S, K, H, D), the Bochner time-encoding bias
    ``phi(t_seed - t_nbr) @ wt``, and the edge-feature bias
    ``edge_feats[eid] @ we``; then runs the plain attention oracle. Same
    argument shapes/semantics as the kernel (``buf``: (Nb, K, 3) packed
    rows; bias groups optional; seeds < 0 — hop-2 frontier padding — yield
    zero rows).
    """
    S, H, D = q.shape
    K = buf.shape[1]
    safe_seeds = jnp.maximum(seeds, 0)
    ids = buf[safe_seeds, :, 0]     # (S, K)
    mask = (ids >= 0) & (seeds >= 0)[:, None]
    k = k_table[jnp.maximum(ids, 0)].reshape(S, K, H * D).astype(jnp.float32)
    v = v_table[jnp.maximum(ids, 0)].reshape(S, K, H * D).astype(jnp.float32)
    if wt_k is not None:
        dt = (seed_times[:, None] - buf[safe_seeds, :, 1]).astype(jnp.float32)
        phi = jnp.cos(dt[..., None] * time_w.reshape(-1)
                      + time_b.reshape(-1))                     # (S, K, dt)
        k = k + phi @ wt_k.reshape(wt_k.shape[0], H * D)
        v = v + phi @ wt_v.reshape(wt_v.shape[0], H * D)
    if we_k is not None:
        eids = buf[safe_seeds, :, 2]
        e = edge_feats[jnp.maximum(eids, 0)].astype(jnp.float32)
        e = e * (eids >= 0)[..., None]          # zero featureless slots
        k = k + e @ we_k.reshape(we_k.shape[0], H * D)
        v = v + e @ we_v.reshape(we_v.shape[0], H * D)
    k = k.reshape(S, K, H, D)
    v = v.reshape(S, K, H, D)

    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qs = q.astype(jnp.float32) * scale
    s = jnp.einsum("shd,skhd->shk", qs, k)
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1)[:, None, None], p, 0.0)
    return jnp.einsum("shk,skhd->shd", p, v).astype(q.dtype)
