from repro.kernels.temporal_attention.kernel import temporal_attention_kernel
from repro.kernels.temporal_attention.ops import temporal_attention
from repro.kernels.temporal_attention.ref import temporal_attention_ref

__all__ = ["temporal_attention", "temporal_attention_kernel", "temporal_attention_ref"]
