from repro.kernels.temporal_attention.kernel import (
    fused_recency_attention_kernel,
    fused_temporal_layer_bwd_kernel,
    fused_temporal_layer_kernel,
    temporal_attention_kernel,
)
from repro.kernels.temporal_attention.ops import (
    fused_recency_attention,
    fused_temporal_layer,
    fused_temporal_layer_hop2,
    fused_temporal_layer_per_seed,
    fused_temporal_layer_sharded,
    temporal_attention,
)
from repro.kernels.temporal_attention.ref import (
    fused_recency_attention_ref,
    fused_temporal_layer_ref,
    temporal_attention_ref,
)

__all__ = [
    "fused_recency_attention",
    "fused_recency_attention_kernel",
    "fused_recency_attention_ref",
    "fused_temporal_layer",
    "fused_temporal_layer_bwd_kernel",
    "fused_temporal_layer_hop2",
    "fused_temporal_layer_kernel",
    "fused_temporal_layer_per_seed",
    "fused_temporal_layer_ref",
    "fused_temporal_layer_sharded",
    "temporal_attention",
    "temporal_attention_kernel",
    "temporal_attention_ref",
]
