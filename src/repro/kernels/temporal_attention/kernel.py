"""Temporal neighbor attention Pallas TPU kernel.

The paper's profiling (Table 11) puts TGAT attention + sampling at ~28% of
epoch time. On TPU the hot loop is: for each seed node, attend its K most
recent neighbors (K = 10..32, padded). This kernel tiles seeds into VMEM
blocks and keeps the whole (block_s, K) score tile resident — one softmax
pass, no HBM round-trip for the intermediate scores.

Grid: (num_seed_blocks,) — embarrassingly parallel over seeds.
Blocks (VMEM):
  q:    (block_s, H, D)
  k/v:  (block_s, K, H, D)   — gathered neighbor features (K padded to a
                               lane multiple by ops.py)
  mask: (block_s, K)
  o:    (block_s, H, D)

With block_s=128, K=32, H=2, D=64 the working set is ~4.5 MiB f32 — well
inside the 16 MiB VMEM budget, and head_dim 64/128 keeps MXU tiles aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _temporal_attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *,
                               scale: float):
    q = q_ref[...].astype(jnp.float32) * scale  # (bs, H, D)
    k = k_ref[...].astype(jnp.float32)  # (bs, K, H, D)
    v = v_ref[...].astype(jnp.float32)
    mask = mask_ref[...]  # (bs, K)

    s = jnp.einsum("shd,skhd->shk", q, k)  # (bs, H, K)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = p.sum(axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    any_valid = mask.any(axis=-1)[:, None, None]
    p = jnp.where(any_valid, p, 0.0)
    o_ref[...] = jnp.einsum("shk,skhd->shd", p, v).astype(o_ref.dtype)


def temporal_attention_kernel(q, k, v, mask, *, block_s: int = 128,
                              scale: float | None = None,
                              interpret: bool = False):
    """q: (S, H, D); k, v: (S, K, H, D); mask: (S, K) -> (S, H, D)."""
    S, H, D = q.shape
    K = k.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)

    block_s = min(block_s, S)
    pad = (-S) % block_s
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, pad), (0, 0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    ns = (S + pad) // block_s

    out = pl.pallas_call(
        functools.partial(_temporal_attention_kernel, scale=scale),
        grid=(ns,),
        in_specs=[
            pl.BlockSpec((block_s, H, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_s, K, H, D), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((block_s, K, H, D), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((block_s, K), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, H, D), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S + pad, H, D), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)
        ),
        interpret=interpret,
    )(q, k, v, mask)
    return out[:S]
