"""Temporal neighbor attention Pallas TPU kernel.

The paper's profiling (Table 11) puts TGAT attention + sampling at ~28% of
epoch time. On TPU the hot loop is: for each seed node, attend its K most
recent neighbors (K = 10..32, padded). This kernel tiles seeds into VMEM
blocks and keeps the whole (block_s, K) score tile resident — one softmax
pass, no HBM round-trip for the intermediate scores.

Grid: (num_seed_blocks,) — embarrassingly parallel over seeds.
Blocks (VMEM):
  q:    (block_s, H, D)
  k/v:  (block_s, K, H, D)   — gathered neighbor features (K padded to a
                               lane multiple by ops.py)
  mask: (block_s, K)
  o:    (block_s, H, D)

With block_s=128, K=32, H=2, D=64 the working set is ~4.5 MiB f32 — well
inside the 16 MiB VMEM budget, and head_dim 64/128 keeps MXU tiles aligned.

``fused_recency_attention_kernel`` is the device-sampling variant: instead
of consuming pre-gathered ``(S, K, H, D)`` k/v tensors, it takes the seed
ids, the resident recency-buffer rows (``buf_ids`` from
``DeviceRecencySampler``) and node-level k/v tables, and performs the
neighbor gather *inside* the kernel — the buffer row and each neighbor's
table row are DMA'd from HBM into VMEM scratch per seed, so the fat
``(S, K, H, D)`` intermediates never exist in HBM. Seed ids arrive via
scalar prefetch (``PrefetchScalarGridSpec``) so DMA source indices are known
before the kernel body runs. The un-fused ``temporal_attention_kernel`` and
the jnp oracle remain the correctness references.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _temporal_attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *,
                               scale: float):
    q = q_ref[...].astype(jnp.float32) * scale  # (bs, H, D)
    k = k_ref[...].astype(jnp.float32)  # (bs, K, H, D)
    v = v_ref[...].astype(jnp.float32)
    mask = mask_ref[...]  # (bs, K)

    s = jnp.einsum("shd,skhd->shk", q, k)  # (bs, H, K)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = p.sum(axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    any_valid = mask.any(axis=-1)[:, None, None]
    p = jnp.where(any_valid, p, 0.0)
    o_ref[...] = jnp.einsum("shk,skhd->shd", p, v).astype(o_ref.dtype)


def temporal_attention_kernel(q, k, v, mask, *, block_s: int = 128,
                              scale: float | None = None,
                              interpret: bool = False):
    """q: (S, H, D); k, v: (S, K, H, D); mask: (S, K) -> (S, H, D)."""
    S, H, D = q.shape
    K = k.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)

    block_s = min(block_s, S)
    pad = (-S) % block_s
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, pad), (0, 0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    ns = (S + pad) // block_s

    out = pl.pallas_call(
        functools.partial(_temporal_attention_kernel, scale=scale),
        grid=(ns,),
        in_specs=[
            pl.BlockSpec((block_s, H, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_s, K, H, D), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((block_s, K, H, D), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((block_s, K), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, H, D), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S + pad, H, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)
        ),
        interpret=interpret,
    )(q, k, v, mask)
    return out[:S]


def _fused_recency_attention_kernel(
    seeds_ref,  # scalar prefetch: (S_pad,) int32 seed node ids (SMEM)
    q_ref,      # (block_s, H, D) VMEM
    k_hbm,      # (N, H, D) ANY/HBM — node-level key table
    v_hbm,      # (N, H, D) ANY/HBM — node-level value table
    buf_hbm,    # (Nb, K) ANY/HBM — resident recency buffer (neighbor ids)
    o_ref,      # (block_s, H, D) VMEM
    ids_smem,   # (K,) int32 SMEM scratch — DMA'd buffer row (for indexing)
    ids_vmem,   # (K,) int32 VMEM scratch — same row (for the vector mask)
    k_scr,      # (K, H, D) VMEM scratch
    v_scr,      # (K, H, D) VMEM scratch
    sem_ids, sem_ids2, sem_k, sem_v,
    *, scale: float, block_s: int, kbuf: int,
):
    pid = pl.program_id(0)

    def per_seed(j, carry):
        seed = seeds_ref[pid * block_s + j]
        # Buffer row -> SMEM (scalar reads drive the gather DMAs below) and
        # -> VMEM (vector mask for the softmax).
        row = pltpu.make_async_copy(buf_hbm.at[seed], ids_smem, sem_ids)
        row.start()
        row_v = pltpu.make_async_copy(buf_hbm.at[seed], ids_vmem, sem_ids2)
        row_v.start()
        row.wait()

        def gather(kk, c):
            nid = jnp.maximum(ids_smem[kk], 0)  # clamp padding (-1) to row 0
            ck = pltpu.make_async_copy(k_hbm.at[nid], k_scr.at[kk], sem_k)
            cv = pltpu.make_async_copy(v_hbm.at[nid], v_scr.at[kk], sem_v)
            ck.start()
            cv.start()
            ck.wait()
            cv.wait()
            return c

        jax.lax.fori_loop(0, kbuf, gather, 0)
        row_v.wait()

        q = q_ref[j].astype(jnp.float32) * scale  # (H, D)
        k = k_scr[...].astype(jnp.float32)  # (K, H, D)
        v = v_scr[...].astype(jnp.float32)
        mask = ids_vmem[...] >= 0  # (K,)

        s = jnp.einsum("hd,khd->hk", q, k)  # (H, K)
        s = jnp.where(mask[None, :], s, NEG_INF)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
        p = jnp.where(mask.any(), p, 0.0)
        o_ref[j] = jnp.einsum("hk,khd->hd", p, v).astype(o_ref.dtype)
        return carry

    jax.lax.fori_loop(0, block_s, per_seed, 0)


def fused_recency_attention_kernel(q, k_table, v_table, seeds, buf_ids, *,
                                   block_s: int = 128,
                                   scale: float | None = None,
                                   interpret: bool = False):
    """Fused neighbor-gather + attention over the resident recency buffer.

    q: (S, H, D) seed queries; k_table, v_table: (N, H, D) node-level
    projected keys/values (stay in HBM); seeds: (S,) int32 node ids;
    buf_ids: (Nb, K) int32 circular-buffer neighbor ids (-1 = empty, rows
    indexed by node id — ``DeviceRecencySampler.state['ids']``).
    Returns (S, H, D). The (S, K, H, D) gathered k/v exist only as a
    (K, H, D) VMEM scratch per seed, never in HBM.
    """
    S, H, D = q.shape
    K = buf_ids.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)

    seeds = seeds.astype(jnp.int32)
    buf_ids = buf_ids.astype(jnp.int32)
    block_s = min(block_s, S)
    pad = (-S) % block_s
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        seeds = jnp.pad(seeds, (0, pad))
    ns = (S + pad) // block_s

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(ns,),
        in_specs=[
            pl.BlockSpec((block_s, H, D), lambda i, *_: (i, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((block_s, H, D), lambda i, *_: (i, 0, 0)),
        scratch_shapes=[
            pltpu.SMEM((K,), jnp.int32),
            pltpu.VMEM((K,), jnp.int32),
            pltpu.VMEM((K, H, D), k_table.dtype),
            pltpu.VMEM((K, H, D), v_table.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
    )
    out = pl.pallas_call(
        functools.partial(_fused_recency_attention_kernel, scale=scale,
                          block_s=block_s, kbuf=K),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S + pad, H, D), q.dtype),
        interpret=interpret,
    )(seeds, q, k_table, v_table, buf_ids)
    return out[:S]
