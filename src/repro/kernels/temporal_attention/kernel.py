"""Temporal neighbor attention Pallas TPU kernels.

The paper's profiling (Table 11) puts TGAT attention + sampling at ~28% of
epoch time. On TPU the hot loop is: for each seed node, attend its K most
recent neighbors (K = 10..32, padded). See ``docs/kernels.md`` for the full
memory-space layout and parity-testing story.

``temporal_attention_kernel`` is the un-fused baseline: it consumes
pre-gathered ``(S, K, H, D)`` k/v tensors, tiles seeds into VMEM blocks and
keeps the whole (block_s, K) score tile resident — one softmax pass, no HBM
round-trip for the intermediate scores.

Grid: (num_seed_blocks,) — embarrassingly parallel over seeds.
Blocks (VMEM):
  q:    (block_s, H, D)
  k/v:  (block_s, K, H, D)   — gathered neighbor features (K padded to a
                               lane multiple by ops.py)
  mask: (block_s, K)
  o:    (block_s, H, D)

With block_s=128, K=32, H=2, D=64 the working set is ~4.5 MiB f32 — well
inside the 16 MiB VMEM budget, and head_dim 64/128 keeps MXU tiles aligned.

``fused_temporal_layer_kernel`` is the device-sampling variant (the layer-1
compute of TGAT/TGN when ``device_sampling=True``): instead of consuming
pre-gathered ``(S, K, H, D)`` k/v tensors, it takes the seed ids + query
times, the resident packed recency buffer (``(N+1, K, 3)`` rows of
``DeviceRecencySampler``) and *node-level* k/v tables, and performs the
neighbor gather inside the kernel. The edge-feature and Bochner
time-encoding terms of the TGAT key/value projections are folded in as
additive biases computed in VMEM:

  k[s, j] = k_table[nbr_j]                      # DMA'd node-level term
          + phi(t_s - t_j) @ Wt_k               # in-kernel time bias
          + edge_feats[eid_j] @ We_k            # DMA'd edge bias

so the fat ``(S, K, H, D)`` intermediates never exist in HBM. The buffer
row (ids, times, eids) and each neighbor's table/edge-feature row are DMA'd
from HBM into VMEM scratch per seed; seed ids and query times arrive via
scalar prefetch (``PrefetchScalarGridSpec``) so DMA source indices are known
before the kernel body runs. Seeds may be negative (hop-2 frontier padding):
the DMA index is clamped and the whole row masked out, so the 2-hop TGAT
frontier can run through the kernel unclamped.

Per-seed DMAs are double-buffered: while seed ``j``'s neighborhood is being
reduced on the VPU/MXU, seed ``j+1``'s buffer row and its K neighbor-row
copies (issued back-to-back, all in flight at once) land in the other half
of a 2-slot scratch. ``fused_recency_attention_kernel`` (the PR-1 surface:
ids-only buffer, no bias folding) is kept as a thin wrapper and runs through
the same double-buffered body.

``fused_temporal_layer_bwd_kernel`` is the flash-attention-style backward:
it re-stages every seed's neighborhood through the same double-buffered DMA
pipeline, recomputes the attention weights in VMEM, and produces all input
gradients without ever materializing an (S, K, ·) tensor in HBM — dq as a
blocked output, dk_table/dv_table by sequential per-row DMA
read-modify-write into ANY-space outputs aliased to zero-initialized
operands (the TPU has no atomics; the grid is sequential, so the
read-modify-write is race-free and handles duplicate neighbor ids exactly),
and the small weight gradients (time/edge projections, Bochner parameters)
as VMEM-resident accumulators that live across the whole grid.

The jnp oracles in ``ref.py`` remain the correctness references
(``interpret=True`` executes these kernel bodies on CPU for parity tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _temporal_attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *,
                               scale: float):
    q = q_ref[...].astype(jnp.float32) * scale  # (bs, H, D)
    k = k_ref[...].astype(jnp.float32)  # (bs, K, H, D)
    v = v_ref[...].astype(jnp.float32)
    mask = mask_ref[...]  # (bs, K)

    s = jnp.einsum("shd,skhd->shk", q, k)  # (bs, H, K)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = p.sum(axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    any_valid = mask.any(axis=-1)[:, None, None]
    p = jnp.where(any_valid, p, 0.0)
    o_ref[...] = jnp.einsum("shk,skhd->shd", p, v).astype(o_ref.dtype)


def temporal_attention_kernel(q, k, v, mask, *, block_s: int = 128,
                              scale: float | None = None,
                              interpret: bool = False):
    """q: (S, H, D); k, v: (S, K, H, D); mask: (S, K) -> (S, H, D)."""
    S, H, D = q.shape
    K = k.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)

    block_s = min(block_s, S)
    pad = (-S) % block_s
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, pad), (0, 0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    ns = (S + pad) // block_s

    out = pl.pallas_call(
        functools.partial(_temporal_attention_kernel, scale=scale),
        grid=(ns,),
        in_specs=[
            pl.BlockSpec((block_s, H, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_s, K, H, D), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((block_s, K, H, D), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((block_s, K), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, H, D), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S + pad, H, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)
        ),
        interpret=interpret,
    )(q, k, v, mask)
    return out[:S]


def _make_stager(seeds_ref, buf_hbm, k_hbm, v_hbm, ef_hbm,
                 row_smem, row_vmem, k_scr, v_scr, e_scr,
                 sem_row, sem_rowv, sem_k, sem_v, sem_e,
                 *, block_s: int, kbuf: int, has_edge: bool):
    """Build the double-buffered per-seed DMA staging closures.

    Shared by the forward and backward fused-layer kernel bodies: both walk
    the same seed blocks and need the same staged data (the packed buffer
    row in SMEM+VMEM, the K neighbor k/v table rows, and optionally the K
    edge-feature rows) in 2-slot scratch. Seed ids < 0 (hop-2 frontier
    padding) are clamped for the DMA and masked out by the caller.

    Returns ``(stage, wait)``: ``stage(j)`` issues seed j's DMAs into slot
    ``j % 2``; ``wait(j)`` blocks until they have all landed.
    """
    pid = pl.program_id(0)

    def row_copies(j):
        sl = j % 2
        seed = jnp.maximum(seeds_ref[pid * block_s + j], 0)
        return (
            pltpu.make_async_copy(buf_hbm.at[seed], row_smem.at[sl],
                                  sem_row.at[sl]),
            pltpu.make_async_copy(buf_hbm.at[seed], row_vmem.at[sl],
                                  sem_rowv.at[sl]),
        )

    def issue_nbrs(j):
        """Start all K neighbor-row copies (k, v[, edge]) back-to-back so
        they are in flight concurrently; requires row_smem[slot] landed."""
        sl = j % 2

        def one(kk, c):
            nid = jnp.maximum(row_smem[sl, kk, 0], 0)  # clamp padding (-1)
            pltpu.make_async_copy(k_hbm.at[nid], k_scr.at[sl, kk],
                                  sem_k.at[sl]).start()
            pltpu.make_async_copy(v_hbm.at[nid], v_scr.at[sl, kk],
                                  sem_v.at[sl]).start()
            if has_edge:
                eid = jnp.maximum(row_smem[sl, kk, 2], 0)
                pltpu.make_async_copy(ef_hbm.at[eid], e_scr.at[sl, kk],
                                      sem_e.at[sl]).start()
            return c

        jax.lax.fori_loop(0, kbuf, one, 0)

    def wait_nbrs(j):
        sl = j % 2

        def one(kk, c):
            nid = jnp.maximum(row_smem[sl, kk, 0], 0)
            pltpu.make_async_copy(k_hbm.at[nid], k_scr.at[sl, kk],
                                  sem_k.at[sl]).wait()
            pltpu.make_async_copy(v_hbm.at[nid], v_scr.at[sl, kk],
                                  sem_v.at[sl]).wait()
            if has_edge:
                eid = jnp.maximum(row_smem[sl, kk, 2], 0)
                pltpu.make_async_copy(ef_hbm.at[eid], e_scr.at[sl, kk],
                                      sem_e.at[sl]).wait()
            return c

        jax.lax.fori_loop(0, kbuf, one, 0)

    def stage(j):
        """Issue seed j's DMAs: buffer row, then (once the scalar copy of
        the row has landed, so neighbor indices are known) the batched
        neighbor-row copies."""
        row_s, row_v = row_copies(j)
        row_s.start()
        row_v.start()
        row_s.wait()
        issue_nbrs(j)

    def wait(j):
        _, row_v = row_copies(j)
        row_v.wait()
        wait_nbrs(j)

    return stage, wait


def _seed_kv(sl, seed_t, row_vmem, k_scr, v_scr, e_scr,
             tw_ref, tb_ref, wtk_ref, wtv_ref, wek_ref, wev_ref,
             *, kbuf: int, heads: int, hdim: int,
             has_time: bool, has_edge: bool):
    """Rebuild one seed's biased (K, H*D) keys/values from staged scratch.

    Shared by the forward (to attend) and the backward (to recompute the
    attention weights flash-style). Returns ``(k, v, phi, theta, dt, e)``
    where ``phi = cos(theta)`` is the Bochner encoding, ``dt`` the query/
    neighbor time deltas and ``e`` the zeroed edge-feature rows (the
    backward reuses all three for the weight gradients).
    """
    k = k_scr[sl].astype(jnp.float32).reshape(kbuf, heads * hdim)
    v = v_scr[sl].astype(jnp.float32).reshape(kbuf, heads * hdim)
    phi = theta = dt = e = None
    if has_time:
        # dt in int32 first (exactly like nn.time_encode's caller), then
        # the Bochner encoding phi = cos(dt * w + b) on the VPU, then the
        # (K, d_time) @ (d_time, H*D) bias matmul on the MXU.
        dt = (seed_t - row_vmem[sl, :, 1]).astype(jnp.float32)
        theta = dt[:, None] * tw_ref[0] + tb_ref[0]
        phi = jnp.cos(theta)
        k = k + phi @ wtk_ref[...]
        v = v + phi @ wtv_ref[...]
    if has_edge:
        ev = (row_vmem[sl, :, 2] >= 0).astype(jnp.float32)[:, None]
        e = e_scr[sl].astype(jnp.float32) * ev   # zero featureless slots
        k = k + e @ wek_ref[...]
        v = v + e @ wev_ref[...]
    return k, v, phi, theta, dt, e


def _masked_softmax(s, mask):
    """Row-softmax over the last axis with fully-masked rows zeroed —
    identical to the oracle's ``softmax`` + ``where(mask.any(), ·, 0)``."""
    s = jnp.where(mask[None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.where(mask.any(), p, 0.0)


def _fused_layer_kernel(
    seeds_ref,  # scalar prefetch: (S_pad,) int32 seed node ids (SMEM)
    times_ref,  # scalar prefetch: (S_pad,) int32 seed query times (SMEM)
    *refs,
    scale: float, block_s: int, kbuf: int, heads: int, hdim: int,
    has_time: bool, has_edge: bool,
):
    """Double-buffered fused gather + bias-fold + attention body.

    ``refs`` unpacks (in order) the non-prefetch inputs, the output, and the
    scratch allocated by ``fused_temporal_layer_kernel``; the exact layout
    depends on the static ``has_time`` / ``has_edge`` flags.
    """
    it = iter(refs)
    q_ref = next(it)                     # (bs, H, D) VMEM
    k_hbm = next(it)                     # (N, H, D) ANY/HBM node key table
    v_hbm = next(it)                     # (N, H, D) ANY/HBM node value table
    buf_hbm = next(it)                   # (Nb, K, 3) ANY/HBM packed buffer
    tw_ref = tb_ref = wtk_ref = wtv_ref = None
    ef_hbm = wek_ref = wev_ref = None
    if has_time:
        tw_ref = next(it)                # (1, d_time) VMEM Bochner freqs
        tb_ref = next(it)                # (1, d_time) VMEM Bochner phases
        wtk_ref = next(it)               # (d_time, H*D) VMEM key time proj
        wtv_ref = next(it)               # (d_time, H*D) VMEM value time proj
    if has_edge:
        ef_hbm = next(it)                # (E, d_edge) ANY/HBM edge features
        wek_ref = next(it)               # (d_edge, H*D) VMEM key edge proj
        wev_ref = next(it)               # (d_edge, H*D) VMEM value edge proj
    o_ref = next(it)                     # (bs, H, D) VMEM
    row_smem = next(it)                  # (2, K, 3) SMEM — scalar DMA indices
    row_vmem = next(it)                  # (2, K, 3) VMEM — vector mask/times
    k_scr = next(it)                     # (2, K, H, D) VMEM
    v_scr = next(it)                     # (2, K, H, D) VMEM
    e_scr = next(it) if has_edge else None   # (2, K, d_edge) VMEM
    sem_row = next(it)                   # DMA((2,)) — per-slot semaphores
    sem_rowv = next(it)
    sem_k = next(it)
    sem_v = next(it)
    sem_e = next(it) if has_edge else None

    pid = pl.program_id(0)
    stage, wait = _make_stager(
        seeds_ref, buf_hbm, k_hbm, v_hbm, ef_hbm,
        row_smem, row_vmem, k_scr, v_scr, e_scr,
        sem_row, sem_rowv, sem_k, sem_v, sem_e,
        block_s=block_s, kbuf=kbuf, has_edge=has_edge,
    )

    # Prologue: stage seed 0; the loop then overlaps seed j+1's copies with
    # seed j's compute (classic 2-slot software pipeline).
    stage(0)

    def per_seed(j, carry):
        @pl.when(j + 1 < block_s)
        def _():
            stage(j + 1)

        sl = j % 2
        wait(j)

        seed = seeds_ref[pid * block_s + j]
        ids = row_vmem[sl, :, 0]                      # (K,)
        mask = (ids >= 0) & (seed >= 0)               # seed < 0: hop-2 pad
        k, v, *_ = _seed_kv(
            sl, times_ref[pid * block_s + j], row_vmem, k_scr, v_scr, e_scr,
            tw_ref, tb_ref, wtk_ref, wtv_ref, wek_ref, wev_ref,
            kbuf=kbuf, heads=heads, hdim=hdim,
            has_time=has_time, has_edge=has_edge,
        )
        k = k.reshape(kbuf, heads, hdim)
        v = v.reshape(kbuf, heads, hdim)

        q = q_ref[j].astype(jnp.float32) * scale      # (H, D)
        s = jnp.einsum("hd,khd->hk", q, k)            # (H, K)
        p = _masked_softmax(s, mask)
        o_ref[j] = jnp.einsum("hk,khd->hd", p, v).astype(o_ref.dtype)
        return carry

    jax.lax.fori_loop(0, block_s, per_seed, 0)


def _layer_operands(q, k_table, v_table, buf, time_w, time_b, wt_k, wt_v,
                    edge_feats, we_k, we_v, H, D):
    """Assemble the shared (operands, in_specs, scratch) for the fused
    forward/backward pallas_calls: node tables + packed buffer in ANY/HBM,
    weight groups reshaped to (d, H*D) f32 and VMEM-resident."""
    has_time = wt_k is not None
    has_edge = we_k is not None
    K = buf.shape[1]
    full = lambda a: pl.BlockSpec(a.shape, lambda i, *_: (0,) * a.ndim)  # noqa: E731
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    operands = [k_table, v_table, buf]
    if has_time:
        tw = time_w.reshape(1, -1).astype(jnp.float32)
        tb = time_b.reshape(1, -1).astype(jnp.float32)
        wtk = wt_k.reshape(wt_k.shape[0], H * D).astype(jnp.float32)
        wtv = wt_v.reshape(wt_v.shape[0], H * D).astype(jnp.float32)
        in_specs += [full(tw), full(tb), full(wtk), full(wtv)]
        operands += [tw, tb, wtk, wtv]
    if has_edge:
        wek = we_k.reshape(we_k.shape[0], H * D).astype(jnp.float32)
        wev = we_v.reshape(we_v.shape[0], H * D).astype(jnp.float32)
        in_specs += [pl.BlockSpec(memory_space=pltpu.ANY), full(wek),
                     full(wev)]
        operands += [edge_feats, wek, wev]

    scratch = [
        pltpu.SMEM((2, K, 3), jnp.int32),
        pltpu.VMEM((2, K, 3), jnp.int32),
        pltpu.VMEM((2, K, H, D), k_table.dtype),
        pltpu.VMEM((2, K, H, D), v_table.dtype),
    ]
    if has_edge:
        scratch.append(pltpu.VMEM((2, K, edge_feats.shape[1]),
                                  edge_feats.dtype))
    scratch += [pltpu.SemaphoreType.DMA((2,))] * (5 if has_edge else 4)
    return operands, in_specs, scratch


def fused_temporal_layer_kernel(
    q, k_table, v_table, seeds, seed_times, buf, *,
    time_w=None, time_b=None, wt_k=None, wt_v=None,
    edge_feats=None, we_k=None, we_v=None,
    block_s: int = 128, scale: float | None = None,
    interpret: bool = False,
):
    """Fused neighbor-gather + bias-fold + attention over the packed buffer.

    q: (S, H, D) seed queries; k_table, v_table: (N, H, D) node-level
    projected keys/values (stay in HBM); seeds/seed_times: (S,) int32;
    buf: (Nb, K, 3) packed circular buffer (channels = neighbor id, time,
    edge id; -1 id = empty slot) — ``DeviceRecencySampler.state["buf"]``.
    Seeds may be negative (hop-2 frontier padding): those rows produce zero
    output.

    Optional bias folds (both on or both off per group):
      time_w/time_b: (d_time,) Bochner parameters, wt_k/wt_v:
        (d_time, H*D) time-encoding slices of the key/value projections;
      edge_feats: (E, d_edge) edge-feature storage (stays in HBM), we_k /
        we_v: (d_edge, H*D) edge-feature slices of the projections.

    Returns (S, H, D). The (S, K, H, D) gathered k/v exist only as 2-slot
    (K, H, D) VMEM scratch, never in HBM; per-seed DMAs are double-buffered.
    """
    S, H, D = q.shape
    K = buf.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    has_time = wt_k is not None
    has_edge = we_k is not None

    seeds = seeds.astype(jnp.int32)
    seed_times = (jnp.zeros_like(seeds) if seed_times is None
                  else seed_times.astype(jnp.int32))
    buf = buf.astype(jnp.int32)
    block_s = min(block_s, S)
    pad = (-S) % block_s
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        seeds = jnp.pad(seeds, (0, pad))
        seed_times = jnp.pad(seed_times, (0, pad))
    ns = (S + pad) // block_s

    operands, in_specs, scratch = _layer_operands(
        q, k_table, v_table, buf, time_w, time_b, wt_k, wt_v,
        edge_feats, we_k, we_v, H, D)
    in_specs = [pl.BlockSpec((block_s, H, D), lambda i, *_: (i, 0, 0))
                ] + in_specs
    operands = [q] + operands

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ns,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_s, H, D), lambda i, *_: (i, 0, 0)),
        scratch_shapes=scratch,
    )
    out = pl.pallas_call(
        functools.partial(
            _fused_layer_kernel, scale=scale, block_s=block_s, kbuf=K,
            heads=H, hdim=D, has_time=has_time, has_edge=has_edge,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S + pad, H, D), q.dtype),
        interpret=interpret,
    )(seeds, seed_times, *operands)
    return out[:S]


def _fused_layer_bwd_kernel(
    seeds_ref,  # scalar prefetch: (S_pad,) int32 seed node ids (SMEM)
    times_ref,  # scalar prefetch: (S_pad,) int32 seed query times (SMEM)
    *refs,
    scale: float, block_s: int, kbuf: int, heads: int, hdim: int,
    has_time: bool, has_edge: bool,
):
    """Flash-style backward body: restage, recompute attention, accumulate.

    Per seed, the neighborhood is re-staged through the same double-buffered
    DMA pipeline as the forward, the biased k/v and attention weights are
    recomputed in VMEM, and the chain rule is applied locally:

      dv   = p ⊗ g              ds = p * (dp - Σ_k p·dp)     dp = g · v
      dq   = (ds · k) * scale   dk = ds ⊗ (q * scale)

    dq writes to a blocked output; dk/dv rows are scattered into the
    zero-initialized ANY-space dk_table/dv_table outputs by sequential DMA
    read-modify-write (grid + fori_loop ordering makes duplicate neighbor
    ids safe without atomics); the weight gradients (time/edge projection
    slices and Bochner parameters) live in VMEM-resident accumulator outputs
    initialized at program 0.
    """
    it = iter(refs)
    q_ref = next(it)                     # (bs, H, D) VMEM
    g_ref = next(it)                     # (bs, H, D) VMEM output cotangent
    k_hbm = next(it)                     # (N, H, D) ANY node key table
    v_hbm = next(it)                     # (N, H, D) ANY node value table
    buf_hbm = next(it)                   # (Nb, K, 3) ANY packed buffer
    tw_ref = tb_ref = wtk_ref = wtv_ref = None
    ef_hbm = wek_ref = wev_ref = None
    if has_time:
        tw_ref = next(it)
        tb_ref = next(it)
        wtk_ref = next(it)
        wtv_ref = next(it)
    if has_edge:
        ef_hbm = next(it)
        wek_ref = next(it)
        wev_ref = next(it)
    next(it)                             # dk zeros operand (aliased → dk_hbm)
    next(it)                             # dv zeros operand (aliased → dv_hbm)
    dq_ref = next(it)                    # (bs, H, D) VMEM blocked output
    dk_hbm = next(it)                    # (N, H, D) f32 ANY output (aliased)
    dv_hbm = next(it)                    # (N, H, D) f32 ANY output (aliased)
    dtw_ref = dtb_ref = dwtk_ref = dwtv_ref = None
    dwek_ref = dwev_ref = None
    if has_time:
        dtw_ref = next(it)               # (1, d_time) resident accumulator
        dtb_ref = next(it)
        dwtk_ref = next(it)              # (d_time, H*D) resident accumulator
        dwtv_ref = next(it)
    if has_edge:
        dwek_ref = next(it)              # (d_edge, H*D) resident accumulator
        dwev_ref = next(it)
    row_smem = next(it)                  # (2, K, 3) SMEM
    row_vmem = next(it)                  # (2, K, 3) VMEM
    k_scr = next(it)                     # (2, K, H, D) VMEM
    v_scr = next(it)                     # (2, K, H, D) VMEM
    e_scr = next(it) if has_edge else None
    dk_rows = next(it)                   # (K, H, D) f32 — this seed's dk
    dv_rows = next(it)                   # (K, H, D) f32
    rk_row = next(it)                    # (H, D) f32 read-modify-write cell
    rv_row = next(it)                    # (H, D) f32
    sem_row = next(it)
    sem_rowv = next(it)
    sem_k = next(it)
    sem_v = next(it)
    sem_e = next(it) if has_edge else None
    sem_rk = next(it)                    # DMA — dk row read-modify-write
    sem_rv = next(it)

    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _():
        if has_time:
            dtw_ref[...] = jnp.zeros_like(dtw_ref)
            dtb_ref[...] = jnp.zeros_like(dtb_ref)
            dwtk_ref[...] = jnp.zeros_like(dwtk_ref)
            dwtv_ref[...] = jnp.zeros_like(dwtv_ref)
        if has_edge:
            dwek_ref[...] = jnp.zeros_like(dwek_ref)
            dwev_ref[...] = jnp.zeros_like(dwev_ref)

    stage, wait = _make_stager(
        seeds_ref, buf_hbm, k_hbm, v_hbm, ef_hbm,
        row_smem, row_vmem, k_scr, v_scr, e_scr,
        sem_row, sem_rowv, sem_k, sem_v, sem_e,
        block_s=block_s, kbuf=kbuf, has_edge=has_edge,
    )
    stage(0)

    def per_seed(j, carry):
        @pl.when(j + 1 < block_s)
        def _():
            stage(j + 1)

        sl = j % 2
        wait(j)

        seed = seeds_ref[pid * block_s + j]
        ids = row_vmem[sl, :, 0]
        mask = (ids >= 0) & (seed >= 0)
        k, v, phi, theta, dt, e = _seed_kv(
            sl, times_ref[pid * block_s + j], row_vmem, k_scr, v_scr, e_scr,
            tw_ref, tb_ref, wtk_ref, wtv_ref, wek_ref, wev_ref,
            kbuf=kbuf, heads=heads, hdim=hdim,
            has_time=has_time, has_edge=has_edge,
        )
        k3 = k.reshape(kbuf, heads, hdim)
        v3 = v.reshape(kbuf, heads, hdim)

        qs = q_ref[j].astype(jnp.float32) * scale     # (H, D)
        s = jnp.einsum("hd,khd->hk", qs, k3)          # (H, K)
        p = _masked_softmax(s, mask)                  # (H, K)

        g = g_ref[j].astype(jnp.float32)              # (H, D)
        dv3 = p.T[:, :, None] * g[None]               # (K, H, D) = p ⊗ g
        dp = jnp.einsum("hd,khd->hk", g, v3)          # (H, K)
        ds = p * (dp - (p * dp).sum(axis=-1, keepdims=True))
        dq_ref[j] = (jnp.einsum("hk,khd->hd", ds, k3) * scale
                     ).astype(dq_ref.dtype)
        dk3 = ds.T[:, :, None] * qs[None]             # (K, H, D) = ds ⊗ q

        # p is exactly 0 on masked slots (exp underflows at -1e30), but the
        # explicit zeroing keeps clamped padding rows provably inert.
        mf = mask.astype(jnp.float32)[:, None]
        dkf = dk3.reshape(kbuf, heads * hdim) * mf    # (K, H*D)
        dvf = dv3.reshape(kbuf, heads * hdim) * mf

        if has_time:
            dwtk_ref[...] += phi.T @ dkf
            dwtv_ref[...] += phi.T @ dvf
            dphi = (jnp.einsum("kf,tf->kt", dkf, wtk_ref[...])
                    + jnp.einsum("kf,tf->kt", dvf, wtv_ref[...]))
            dtheta = -jnp.sin(theta) * dphi           # (K, d_time)
            dtw_ref[...] += (dtheta * dt[:, None]).sum(axis=0)[None]
            dtb_ref[...] += dtheta.sum(axis=0)[None]
        if has_edge:
            dwek_ref[...] += e.T @ dkf                # e already eid-zeroed
            dwev_ref[...] += e.T @ dvf

        # Scatter this seed's dk/dv rows into the table gradients: one
        # sequential read-modify-write per slot (no TPU atomics; duplicate
        # ids within a row accumulate correctly because each RMW completes
        # before the next starts).
        dk_rows[...] = dkf.reshape(kbuf, heads, hdim)
        dv_rows[...] = dvf.reshape(kbuf, heads, hdim)

        def rmw(kk, c):
            nid = jnp.maximum(row_smem[sl, kk, 0], 0)
            in_k = pltpu.make_async_copy(dk_hbm.at[nid], rk_row, sem_rk)
            in_v = pltpu.make_async_copy(dv_hbm.at[nid], rv_row, sem_rv)
            in_k.start()
            in_v.start()
            in_k.wait()
            in_v.wait()
            rk_row[...] = rk_row[...] + dk_rows[kk]
            rv_row[...] = rv_row[...] + dv_rows[kk]
            out_k = pltpu.make_async_copy(rk_row, dk_hbm.at[nid], sem_rk)
            out_v = pltpu.make_async_copy(rv_row, dv_hbm.at[nid], sem_rv)
            out_k.start()
            out_v.start()
            out_k.wait()
            out_v.wait()
            return c

        jax.lax.fori_loop(0, kbuf, rmw, 0)
        return carry

    jax.lax.fori_loop(0, block_s, per_seed, 0)


def fused_temporal_layer_bwd_kernel(
    g, q, k_table, v_table, seeds, seed_times, buf, *,
    time_w=None, time_b=None, wt_k=None, wt_v=None,
    edge_feats=None, we_k=None, we_v=None,
    block_s: int = 128, scale: float | None = None,
    interpret: bool = False,
):
    """Backward pass of ``fused_temporal_layer_kernel``, gather-free in HBM.

    g: (S, H, D) cotangent of the forward output; remaining arguments as in
    the forward. Returns a dict of f32 gradients in the kernel's internal
    layout — ``q`` (S, H, D), ``k_table``/``v_table`` (N, H, D), and, when
    the bias groups are present, ``time_w``/``time_b`` (1, d_time) and
    ``wt_k``/``wt_v``/``we_k``/``we_v`` (d, H*D) — the caller
    (``ops._fused_layer_bwd``) reshapes/casts them back to the primal
    shapes. ``edge_feats``, ``seeds``, ``seed_times`` and ``buf`` are
    non-differentiable.

    The grid is declared sequential ("arbitrary") so the per-row DMA
    read-modify-write scatter into dk_table/dv_table is race-free.
    """
    S, H, D = q.shape
    N = k_table.shape[0]
    K = buf.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    has_time = wt_k is not None
    has_edge = we_k is not None

    seeds = seeds.astype(jnp.int32)
    seed_times = (jnp.zeros_like(seeds) if seed_times is None
                  else seed_times.astype(jnp.int32))
    buf = buf.astype(jnp.int32)
    block_s = min(block_s, S)
    pad = (-S) % block_s
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        g = jnp.pad(g, ((0, pad), (0, 0), (0, 0)))
        seeds = jnp.pad(seeds, (0, pad))
        seed_times = jnp.pad(seed_times, (0, pad))
    ns = (S + pad) // block_s

    operands, in_specs, scratch = _layer_operands(
        q, k_table, v_table, buf, time_w, time_b, wt_k, wt_v,
        edge_feats, we_k, we_v, H, D)
    blocked = pl.BlockSpec((block_s, H, D), lambda i, *_: (i, 0, 0))
    in_specs = [blocked, blocked] + in_specs
    operands = [q, g] + operands
    # Zero operands aliased to the table-gradient outputs: the kernel
    # accumulates into them by DMA read-modify-write.
    zeros = jnp.zeros((N, H, D), jnp.float32)
    alias_base = 2 + len(in_specs)  # operand index incl. 2 scalar-prefetch
    in_specs += [pl.BlockSpec(memory_space=pltpu.ANY),
                 pl.BlockSpec(memory_space=pltpu.ANY)]
    operands += [zeros, zeros]

    names = ["q", "k_table", "v_table"]
    out_shape = [
        jax.ShapeDtypeStruct((S + pad, H, D), jnp.float32),
        jax.ShapeDtypeStruct((N, H, D), jnp.float32),
        jax.ShapeDtypeStruct((N, H, D), jnp.float32),
    ]
    out_specs = [blocked, pl.BlockSpec(memory_space=pltpu.ANY),
                 pl.BlockSpec(memory_space=pltpu.ANY)]
    resident = lambda shp: pl.BlockSpec(shp, lambda i, *_: (0, 0))  # noqa: E731
    if has_time:
        d_time = time_w.size
        for name, shp in (("time_w", (1, d_time)), ("time_b", (1, d_time)),
                          ("wt_k", (d_time, H * D)), ("wt_v", (d_time, H * D))):
            names.append(name)
            out_shape.append(jax.ShapeDtypeStruct(shp, jnp.float32))
            out_specs.append(resident(shp))
    if has_edge:
        d_edge = edge_feats.shape[1]
        for name in ("we_k", "we_v"):
            names.append(name)
            out_shape.append(jax.ShapeDtypeStruct((d_edge, H * D),
                                                  jnp.float32))
            out_specs.append(resident((d_edge, H * D)))

    # The scratch list from _layer_operands ends with the staging
    # semaphores; the body unpacks buffers first, then semaphores, so the
    # read-modify-write scratch slots in between and its semaphores at the
    # end.
    n_sems = 5 if has_edge else 4
    scratch = (
        scratch[:-n_sems]
        + [
            pltpu.VMEM((K, H, D), jnp.float32),   # dk_rows
            pltpu.VMEM((K, H, D), jnp.float32),   # dv_rows
            pltpu.VMEM((H, D), jnp.float32),      # rk_row
            pltpu.VMEM((H, D), jnp.float32),      # rv_row
        ]
        + scratch[-n_sems:]
        + [pltpu.SemaphoreType.DMA,               # sem_rk
           pltpu.SemaphoreType.DMA]               # sem_rv
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ns,),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    outs = pl.pallas_call(
        functools.partial(
            _fused_layer_bwd_kernel, scale=scale, block_s=block_s, kbuf=K,
            heads=H, hdim=D, has_time=has_time, has_edge=has_edge,
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases={alias_base: 1, alias_base + 1: 2},
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(seeds, seed_times, *operands)
    grads = dict(zip(names, outs))
    grads["q"] = grads["q"][:S]
    return grads


def fused_recency_attention_kernel(q, k_table, v_table, seeds, buf_ids, *,
                                   block_s: int = 128,
                                   scale: float | None = None,
                                   interpret: bool = False):
    """Fused neighbor-gather + attention over the resident recency buffer.

    q: (S, H, D) seed queries; k_table, v_table: (N, H, D) node-level
    projected keys/values (stay in HBM); seeds: (S,) int32 node ids;
    buf_ids: (Nb, K) int32 circular-buffer neighbor ids (-1 = empty, rows
    indexed by node id — ``DeviceRecencySampler.buffer_ids``).
    Returns (S, H, D).

    Thin wrapper over ``fused_temporal_layer_kernel`` with the time/edge
    bias folds disabled (ids-only buffer): same double-buffered DMA body,
    no (S, K, H, D) HBM intermediate.
    """
    buf_ids = buf_ids.astype(jnp.int32)
    buf = jnp.stack(
        [buf_ids, jnp.zeros_like(buf_ids), jnp.full_like(buf_ids, -1)],
        axis=-1,
    )
    return fused_temporal_layer_kernel(
        q, k_table, v_table, seeds, None, buf,
        block_s=block_s, scale=scale, interpret=interpret,
    )
