"""Public jit'd wrappers: Pallas kernels on TPU, jnp references elsewhere.

``temporal_attention``       — consumes pre-gathered (S, K, H, D) k/v.
``fused_recency_attention``  — device-sampling path: consumes seed ids plus
                               the resident recency buffer and node-level
                               k/v tables; the gather happens inside the
                               kernel (TPU) or via a take in the reference
                               (other backends), never as a hook on the host.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.temporal_attention.kernel import (
    fused_recency_attention_kernel,
    temporal_attention_kernel,
)
from repro.kernels.temporal_attention.ref import (
    fused_recency_attention_ref,
    temporal_attention_ref,
)


@partial(jax.jit, static_argnames=("block_s",))
def temporal_attention(q, k, v, mask, *, block_s: int = 128):
    """q: (S, H, D); k, v: (S, K, H, D); mask: (S, K) -> (S, H, D)."""
    if jax.default_backend() == "tpu":
        return temporal_attention_kernel(q, k, v, mask, block_s=block_s)
    return temporal_attention_ref(q, k, v, mask)


@partial(jax.jit, static_argnames=("block_s",))
def fused_recency_attention(q, k_table, v_table, seeds, buf_ids, *,
                            block_s: int = 128):
    """q: (S, H, D); k_table, v_table: (N, H, D); seeds: (S,);
    buf_ids: (Nb, K) resident buffer rows -> (S, H, D)."""
    if jax.default_backend() == "tpu":
        return fused_recency_attention_kernel(
            q, k_table, v_table, seeds, buf_ids, block_s=block_s)
    return fused_recency_attention_ref(q, k_table, v_table, seeds, buf_ids)
