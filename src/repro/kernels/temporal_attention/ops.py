"""Public jit'd wrappers: Pallas kernels on TPU, jnp references elsewhere.

``temporal_attention``       — consumes pre-gathered (S, K, H, D) k/v.
``fused_recency_attention``  — device-sampling path (ids-only buffer):
                               consumes seed ids plus the resident recency
                               buffer and node-level k/v tables; the gather
                               happens inside the kernel (TPU) or via a take
                               in the reference (other backends), never as a
                               hook on the host.
``fused_temporal_layer``     — the full TGAT/TGN layer-1 compute for
                               ``device_sampling=True``: adds the in-kernel
                               time-encoding and edge-feature bias folds and
                               a custom VJP whose backward is itself a
                               Pallas kernel (flash-style recompute), so a
                               jitted, differentiated train step is
                               gather-free end to end.
``fused_temporal_layer_hop2``     — hop-2-aware variant: the (S, K) hop-1
                               frontier (padding ids = -1) queries the same
                               resident buffer at its interaction times.
``fused_temporal_layer_per_seed`` — per-seed-embedding-table variant: each
                               seed attends over its own K *computed* rows
                               (2-layer TGAT's final hop), expressed as a
                               synthetic (S, K, 3) buffer over an (S*K, H,
                               D) table so the same kernel family serves it.
``fused_temporal_layer_sharded``  — shard_map-aware variant for the 2-D
                               mesh: each node shard computes partial
                               attention from its local block of the
                               node-partitioned buffer and one psum over
                               the node axis assembles exact attention
                               (bit-parity with the single-device layer);
                               its custom VJP psums the operand cotangents
                               so sharded gradients stay exact too.

Every wrapper takes ``mode`` ∈ {"auto", "ref", "kernel", "interpret"}:
"auto" picks the Pallas kernel on TPU and the jnp reference elsewhere;
"interpret" forces the kernel body through the Pallas interpreter (the CPU
parity path used by ``tests/kernels/``).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.temporal_attention.kernel import (
    fused_recency_attention_kernel,
    fused_temporal_layer_bwd_kernel,
    fused_temporal_layer_kernel,
    temporal_attention_kernel,
)
from repro.kernels.temporal_attention.ref import (
    fused_recency_attention_ref,
    fused_temporal_layer_ref,
    temporal_attention_ref,
)


def _use_kernel(mode: str) -> bool:
    """Resolve a dispatch mode string; raises on unknown modes."""
    if mode not in ("auto", "ref", "kernel", "interpret"):
        raise ValueError(f"unknown kernel dispatch mode {mode!r}")
    return (mode in ("kernel", "interpret")
            or (mode == "auto" and jax.default_backend() == "tpu"))


@partial(jax.jit, static_argnames=("block_s", "mode"))
def temporal_attention(q, k, v, mask, *, block_s: int = 128,
                       mode: str = "auto"):
    """q: (S, H, D); k, v: (S, K, H, D); mask: (S, K) -> (S, H, D)."""
    if _use_kernel(mode):
        return temporal_attention_kernel(q, k, v, mask, block_s=block_s,
                                         interpret=mode == "interpret")
    return temporal_attention_ref(q, k, v, mask)


@partial(jax.jit, static_argnames=("block_s", "mode"))
def fused_recency_attention(q, k_table, v_table, seeds, buf_ids, *,
                            block_s: int = 128, mode: str = "auto"):
    """q: (S, H, D); k_table, v_table: (N, H, D); seeds: (S,);
    buf_ids: (Nb, K) resident buffer rows -> (S, H, D)."""
    if _use_kernel(mode):
        return fused_recency_attention_kernel(
            q, k_table, v_table, seeds, buf_ids, block_s=block_s,
            interpret=mode == "interpret")
    return fused_recency_attention_ref(q, k_table, v_table, seeds, buf_ids)


def _assemble(flt: dict, aux: dict) -> dict:
    """Merge the differentiable / auxiliary operand dicts back into the
    keyword form shared by the kernel and the reference."""
    kw = dict(aux)
    kw.update(flt)
    return kw


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _fused_layer_call(flt, aux, block_s, interpret):
    return fused_temporal_layer_kernel(
        **_assemble(flt, aux), block_s=block_s, interpret=interpret)


def _fused_layer_fwd(flt, aux, block_s, interpret):
    return _fused_layer_call(flt, aux, block_s, interpret), (flt, aux)


def _fused_layer_bwd(block_s, interpret, res, g):
    # Flash-style backward *kernel*: restage the neighborhoods through the
    # same double-buffered DMA pipeline, recompute the attention weights in
    # VMEM and accumulate every gradient in place — the (S, K, H, D)
    # intermediates the oracle-recompute backward used to materialize never
    # exist in HBM (see fused_temporal_layer_bwd_kernel).
    flt, aux = res
    grads = fused_temporal_layer_bwd_kernel(
        g, **_assemble(flt, aux), block_s=block_s, interpret=interpret)
    out = {name: grads[name].reshape(p.shape).astype(p.dtype)
           for name, p in flt.items()}
    return out, None


_fused_layer_call.defvjp(_fused_layer_fwd, _fused_layer_bwd)


def fused_temporal_layer(q, k_table, v_table, seeds, seed_times, buf, *,
                         time_w=None, time_b=None, wt_k=None, wt_v=None,
                         edge_feats=None, we_k=None, we_v=None,
                         block_s: int = 128, mode: str = "auto"):
    """Fused TGAT/TGN-style layer attention over the packed recency buffer.

    Computes, for each seed ``s`` with packed buffer row ``buf[seeds[s]]``:

      k[s, j] = k_table[id_j] + phi(t_s - t_j) @ wt_k
                + edge_feats[eid_j] @ we_k        (v analogously)
      out[s]  = softmax((q[s] * scale) . k[s]) @ v[s]   over valid slots

    q: (S, H, D); k_table/v_table: (N, H, D) node-level projected terms
    (dense bias already folded in by the caller); seeds/seed_times: (S,)
    int32 (seeds < 0 — hop-2 frontier padding — produce zero rows and zero
    gradients); buf: (Nb, K, 3). The time group (``time_w``, ``time_b``,
    ``wt_k``, ``wt_v``) and edge group (``edge_feats``, ``we_k``, ``we_v``)
    are each optional but all-or-nothing.

    ``mode`` selects the implementation:
      * ``"auto"``      — Pallas kernel on TPU, jnp reference elsewhere;
      * ``"ref"``       — force the materializing jnp oracle;
      * ``"kernel"``    — force the Pallas kernel (compiled);
      * ``"interpret"`` — force the kernel in interpret mode (CPU parity
                          tests and jaxpr inspection).

    The kernel path is differentiable via a custom VJP whose backward is
    the flash-style Pallas backward kernel — both directions of a jitted
    train step stay gather-free in HBM (``edge_feats`` is treated as
    non-differentiable storage).
    """
    flt, aux = _pack_operands(q, k_table, v_table, seeds, seed_times, buf,
                              time_w, time_b, wt_k, wt_v,
                              edge_feats, we_k, we_v)
    return _dispatch_layer(flt, aux, block_s, mode)


def _pack_operands(q, k_table, v_table, seeds, seed_times, buf, time_w,
                   time_b, wt_k, wt_v, edge_feats, we_k, we_v):
    """Split layer operands into the differentiable / auxiliary dicts the
    custom-VJP calls take (time and edge groups each all-or-nothing)."""
    flt = {"q": q, "k_table": k_table, "v_table": v_table}
    aux = {"seeds": seeds, "seed_times": seed_times, "buf": buf}
    if wt_k is not None:
        flt.update(time_w=time_w, time_b=time_b, wt_k=wt_k, wt_v=wt_v)
    if we_k is not None:
        flt.update(we_k=we_k, we_v=we_v)
        aux.update(edge_feats=edge_feats)
    return flt, aux


def _dispatch_layer(flt, aux, block_s, mode):
    """Mode dispatch shared by the plain and shard-aware layer wrappers."""
    if _use_kernel(mode):
        return _fused_layer_call(flt, aux, block_s, mode == "interpret")
    return fused_temporal_layer_ref(**_assemble(flt, aux))


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _fused_layer_sharded_call(flt, aux, axis, block_s, mode):
    return jax.lax.psum(_dispatch_layer(flt, aux, block_s, mode), axis)


def _fused_layer_sharded_fwd(flt, aux, axis, block_s, mode):
    return _fused_layer_sharded_call(flt, aux, axis, block_s, mode), (flt, aux)


def _fused_layer_sharded_bwd(axis, block_s, mode, res, g):
    # The forward is ``psum_axis(local_s)``; downstream compute is
    # node-replicated, so the incoming cotangent ``g`` is identical on
    # every node shard. Recompute the *local* call's VJP (flash-style —
    # residuals are just the operands), apply it to ``g``, then psum the
    # operand cotangents over the node axis: every shard ends up holding
    # the true Σ_s ∂local_s — the exact single-device layer gradient —
    # so no collectives are needed on the rest of the (node-replicated)
    # gradient tree.
    flt, aux = res
    _, vjp = jax.vjp(lambda f: _dispatch_layer(f, aux, block_s, mode), flt)
    (grads,) = vjp(g)
    grads = jax.tree.map(lambda x: jax.lax.psum(x, axis), grads)
    return grads, None


_fused_layer_sharded_call.defvjp(_fused_layer_sharded_fwd,
                                 _fused_layer_sharded_bwd)


def fused_temporal_layer_sharded(q, k_table, v_table, seeds, seed_times,
                                 buf, *, axis: str, rows_per_shard: int,
                                 time_w=None, time_b=None, wt_k=None,
                                 wt_v=None, edge_feats=None, we_k=None,
                                 we_v=None, block_s: int = 128,
                                 mode: str = "auto"):
    """Shard-aware ``fused_temporal_layer``: partial attention per node
    shard, assembled exactly by one psum over the mesh's node axis.

    Call this *inside* a ``shard_map`` body over a mesh with node axis
    ``axis``. ``buf`` is the shard's local ``(rows_per_shard + 1, K, 3)``
    block of the node-partitioned packed buffer (its sink at local row
    ``rows_per_shard``; see ``DeviceRecencySampler.packed_buffer``), while
    ``seeds`` carry *global* node ids and ``q``/``k_table``/``v_table``/
    weight groups are node-replicated — the buffer's id/eid channels hold
    global ids, so the in-kernel k/v/edge gathers need no remap. Each
    shard remaps the seeds it owns (``[s*per, (s+1)*per)``) to local
    buffer rows and marks the rest ``-1`` — the kernel family's existing
    zero-output / zero-gradient path — computing only its owned seeds'
    attention from rows it holds in local HBM/VMEM; the psum then sums
    exactly one owner's value with exact zeros, so the assembled output is
    bit-identical to the single-device layer at any shard count.

    Differentiation goes through a custom VJP that psums the *layer
    operand* cotangents over ``axis`` (see ``_fused_layer_sharded_bwd``),
    which keeps per-device gradients equal to the true gradients without
    collectives over the rest of the gradient tree. ``mode`` as in
    ``fused_temporal_layer``.
    """
    per = int(rows_per_shard)
    lo = jax.lax.axis_index(axis).astype(jnp.int32) * per
    seeds = seeds.astype(jnp.int32)
    owned = (seeds >= lo) & (seeds < lo + per)
    local = jnp.where(owned, seeds - lo, -1)
    flt, aux = _pack_operands(q, k_table, v_table, local,
                              seed_times.astype(jnp.int32), buf,
                              time_w, time_b, wt_k, wt_v,
                              edge_feats, we_k, we_v)
    return _fused_layer_sharded_call(flt, aux, axis, block_s, mode)


def fused_temporal_layer_hop2(q, k_table, v_table, frontier, frontier_times,
                              buf, **kw):
    """Hop-2-aware variant: embed the (S, K) hop-1 frontier over the buffer.

    ``frontier``/``frontier_times``: (S, K) int32 hop-1 neighbor ids and
    interaction times straight from the sampler hook (padding = -1); each
    frontier node queries the resident buffer *at its own interaction time*
    — the layer-0 compute of 2-layer TGAT. q: (S*K, H, D) frontier queries
    (row-major flattened). Returns (S*K, H, D) with zero rows (and zero
    gradients) for padded frontier slots; no (S, K, ·) float tensor is
    created here. Keyword arguments as in ``fused_temporal_layer``.
    """
    return fused_temporal_layer(
        q, k_table, v_table,
        frontier.reshape(-1).astype(jnp.int32),
        frontier_times.reshape(-1).astype(jnp.int32),
        buf, **kw)


def fused_temporal_layer_per_seed(q, k_rows, v_rows, seed_times, nbr_times,
                                  nbr_mask, *, nbr_eids=None, **kw):
    """Per-seed-embedding-table variant: seed ``s`` attends over *its own*
    K rows of an (S*K, H, D) table — 2-layer TGAT's final hop, where the
    keys/values come from computed hop-1 embeddings rather than a shared
    node table.

    q: (S, H, D); k_rows/v_rows: (S*K, H, D) per-seed projected rows (row
    ``s*K + j`` is seed s's j-th neighbor); seed_times: (S,); nbr_times /
    nbr_mask (and optional nbr_eids, for the edge bias group): (S, K).
    Expressed as a synthetic packed buffer (ids = row indices where valid,
    else -1) over the rows table, so the same fused kernel — and its
    backward — serves the final hop; gradients flow into ``k_rows`` /
    ``v_rows`` via the table gradient. Returns (S, H, D).
    """
    S = q.shape[0]
    K = nbr_mask.shape[1]
    rows = jnp.arange(S * K, dtype=jnp.int32).reshape(S, K)
    ids = jnp.where(nbr_mask, rows, -1)
    eids = (jnp.full((S, K), -1, jnp.int32) if nbr_eids is None
            else jnp.where(nbr_mask, nbr_eids.astype(jnp.int32), -1))
    buf = jnp.stack([ids, nbr_times.astype(jnp.int32), eids], axis=-1)
    return fused_temporal_layer(
        q, k_rows, v_rows, jnp.arange(S, dtype=jnp.int32),
        seed_times.astype(jnp.int32), buf, **kw)
