"""Public jit'd wrappers: Pallas kernels on TPU, jnp references elsewhere.

``temporal_attention``       — consumes pre-gathered (S, K, H, D) k/v.
``fused_recency_attention``  — device-sampling path (ids-only buffer):
                               consumes seed ids plus the resident recency
                               buffer and node-level k/v tables; the gather
                               happens inside the kernel (TPU) or via a take
                               in the reference (other backends), never as a
                               hook on the host.
``fused_temporal_layer``     — the full TGAT/TGN layer-1 compute for
                               ``device_sampling=True``: adds the in-kernel
                               time-encoding and edge-feature bias folds and
                               a custom VJP so the fused forward is usable
                               inside a jitted, differentiated train step.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.temporal_attention.kernel import (
    fused_recency_attention_kernel,
    fused_temporal_layer_kernel,
    temporal_attention_kernel,
)
from repro.kernels.temporal_attention.ref import (
    fused_recency_attention_ref,
    fused_temporal_layer_ref,
    temporal_attention_ref,
)


@partial(jax.jit, static_argnames=("block_s",))
def temporal_attention(q, k, v, mask, *, block_s: int = 128):
    """q: (S, H, D); k, v: (S, K, H, D); mask: (S, K) -> (S, H, D)."""
    if jax.default_backend() == "tpu":
        return temporal_attention_kernel(q, k, v, mask, block_s=block_s)
    return temporal_attention_ref(q, k, v, mask)


@partial(jax.jit, static_argnames=("block_s",))
def fused_recency_attention(q, k_table, v_table, seeds, buf_ids, *,
                            block_s: int = 128):
    """q: (S, H, D); k_table, v_table: (N, H, D); seeds: (S,);
    buf_ids: (Nb, K) resident buffer rows -> (S, H, D)."""
    if jax.default_backend() == "tpu":
        return fused_recency_attention_kernel(
            q, k_table, v_table, seeds, buf_ids, block_s=block_s)
    return fused_recency_attention_ref(q, k_table, v_table, seeds, buf_ids)


def _assemble(flt: dict, aux: dict) -> dict:
    """Merge the differentiable / auxiliary operand dicts back into the
    keyword form shared by the kernel and the reference."""
    kw = dict(aux)
    kw.update(flt)
    return kw


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _fused_layer_call(flt, aux, block_s, interpret):
    return fused_temporal_layer_kernel(
        **_assemble(flt, aux), block_s=block_s, interpret=interpret)


def _fused_layer_fwd(flt, aux, block_s, interpret):
    return _fused_layer_call(flt, aux, block_s, interpret), (flt, aux)


def _fused_layer_bwd(block_s, interpret, res, g):
    # Flash-attention-style backward: recompute from the jnp oracle. The
    # recompute materializes the (S, K, H, D) intermediates, so only the
    # forward is gather-free; a dedicated backward kernel is a ROADMAP item.
    flt, aux = res
    _, vjp = jax.vjp(lambda f: fused_temporal_layer_ref(**_assemble(f, aux)),
                     flt)
    return vjp(g)[0], None


_fused_layer_call.defvjp(_fused_layer_fwd, _fused_layer_bwd)


def fused_temporal_layer(q, k_table, v_table, seeds, seed_times, buf, *,
                         time_w=None, time_b=None, wt_k=None, wt_v=None,
                         edge_feats=None, we_k=None, we_v=None,
                         block_s: int = 128, mode: str = "auto"):
    """Fused TGAT/TGN-style layer attention over the packed recency buffer.

    Computes, for each seed ``s`` with packed buffer row ``buf[seeds[s]]``:

      k[s, j] = k_table[id_j] + phi(t_s - t_j) @ wt_k
                + edge_feats[eid_j] @ we_k        (v analogously)
      out[s]  = softmax((q[s] * scale) . k[s]) @ v[s]   over valid slots

    q: (S, H, D); k_table/v_table: (N, H, D) node-level projected terms
    (dense bias already folded in by the caller); seeds/seed_times: (S,)
    int32; buf: (Nb, K, 3). The time group (``time_w``, ``time_b``,
    ``wt_k``, ``wt_v``) and edge group (``edge_feats``, ``we_k``, ``we_v``)
    are each optional but all-or-nothing.

    ``mode`` selects the implementation:
      * ``"auto"``      — Pallas kernel on TPU, jnp reference elsewhere;
      * ``"ref"``       — force the materializing jnp oracle;
      * ``"kernel"``    — force the Pallas kernel (compiled);
      * ``"interpret"`` — force the kernel in interpret mode (CPU parity
                          tests and jaxpr inspection).

    The kernel path is differentiable via a custom VJP whose backward
    recomputes from the reference (forward stays gather-free in HBM).
    """
    if mode not in ("auto", "ref", "kernel", "interpret"):
        raise ValueError(f"unknown fused_temporal_layer mode {mode!r}")
    use_kernel = (mode in ("kernel", "interpret")
                  or (mode == "auto" and jax.default_backend() == "tpu"))
    flt = {"q": q, "k_table": k_table, "v_table": v_table}
    aux = {"seeds": seeds, "seed_times": seed_times, "buf": buf}
    if wt_k is not None:
        flt.update(time_w=time_w, time_b=time_b, wt_k=wt_k, wt_v=wt_v)
    if we_k is not None:
        flt.update(we_k=we_k, we_v=we_v)
        aux.update(edge_feats=edge_feats)
    if use_kernel:
        return _fused_layer_call(flt, aux, block_s, mode == "interpret")
    return fused_temporal_layer_ref(**_assemble(flt, aux))
