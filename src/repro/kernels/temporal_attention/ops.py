"""Public jit'd wrapper: Pallas kernel on TPU, jnp reference elsewhere."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.temporal_attention.kernel import temporal_attention_kernel
from repro.kernels.temporal_attention.ref import temporal_attention_ref


@partial(jax.jit, static_argnames=("block_s",))
def temporal_attention(q, k, v, mask, *, block_s: int = 128):
    """q: (S, H, D); k, v: (S, K, H, D); mask: (S, K) -> (S, H, D)."""
    if jax.default_backend() == "tpu":
        return temporal_attention_kernel(q, k, v, mask, block_s=block_s)
    return temporal_attention_ref(q, k, v, mask)
