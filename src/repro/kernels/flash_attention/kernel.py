"""Flash attention Pallas TPU kernel.

Grid: (B * H, num_q_blocks, num_kv_blocks); the kv axis is the innermost,
sequential ("arbitrary") dimension so the online-softmax state (running
max / denominator / accumulator) lives in VMEM scratch across kv steps.

BlockSpec tiling (all VMEM):
  q:   (1, block_q, D)   — one q block per (bh, qi)
  k/v: (1, block_k, D)   — streamed over ki; GQA maps the q head to its
                           kv head inside the index map (no kv replication
                           in HBM)
  o:   (1, block_q, D)

Default blocks 128 x 128 keep the MXU fed (D is 64/128 for all assigned
archs) and the VMEM working set at ~(2*block_k*D + 3*block_q*D + block_q *
block_k) * 4B < 0.5 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, num_kv_blocks: int, skv: int, sq: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, D)
    k = k_ref[0].astype(jnp.float32)  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    # align causality for Sq != Skv (decode chunks): offset = Skv - Sq
    qpos = qpos + (skv - sq)
    allow = kpos < skv
    if causal:
        allow &= kpos <= qpos
    if window:
        allow &= kpos > qpos - window
    s = jnp.where(allow, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           scale: float | None = None,
                           interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, Hk, Skv, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    Hk, Skv = k.shape[1], k.shape[2]
    G = H // Hk
    scale = scale if scale is not None else 1.0 / np.sqrt(D)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (Sq + pad_q) // block_q
    nk = (Skv + pad_k) // block_k

    qf = q.reshape(B * H, Sq + pad_q, D)
    kf = k.reshape(B * Hk, Skv + pad_k, D)
    vf = v.reshape(B * Hk, Skv + pad_k, D)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        # GQA: query head bh = b * H + h uses kv head b * Hk + h // G
        b = bh // H
        h = bh % H
        return (b * Hk + h // G, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk, skv=Skv, sq=Sq,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq + pad_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :Sq].reshape(B, H, Sq, D)
