"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q: (B, H, Sq, D); k, v: (B, Hk, Skv, D). Returns (B, H, Sq, D).

    GQA: H % Hk == 0 (query-head groups share a kv head).
    """
    B, H, Sq, D = q.shape
    Hk, Skv = k.shape[1], k.shape[2]
    G = H // Hk
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, Hk, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    allow = jnp.ones((Sq, Skv), bool)
    if causal:
        allow &= kpos[None, :] <= qpos[:, None] + (Skv - Sq)
    if window:
        allow &= kpos[None, :] > qpos[:, None] + (Skv - Sq) - window
    s = jnp.where(allow[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)
