"""Public jit'd wrapper: Pallas kernel on TPU, jnp reference elsewhere."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """q: (B, H, Sq, D); k, v: (B, Hk, Skv, D) -> (B, H, Sq, D)."""
    if jax.default_backend() == "tpu":
        return flash_attention_kernel(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k)
    return flash_attention_ref(q, k, v, causal=causal, window=window)
