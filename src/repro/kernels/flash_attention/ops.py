"""Public jit'd wrapper: Pallas kernel on TPU, jnp reference elsewhere."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def _use_kernel(mode: str) -> bool:
    """Resolve a dispatch mode string; raises on unknown modes."""
    if mode not in ("auto", "ref", "kernel", "interpret"):
        raise ValueError(f"unknown kernel dispatch mode {mode!r}")
    return (mode in ("kernel", "interpret")
            or (mode == "auto" and jax.default_backend() == "tpu"))


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "mode"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    mode: str = "auto"):
    """q: (B, H, Sq, D); k, v: (B, Hk, Skv, D) -> (B, H, Sq, D).

    ``mode`` ∈ {"auto", "ref", "kernel", "interpret"}: "auto" runs the
    Pallas kernel on TPU and the jnp reference elsewhere; "interpret"
    executes the kernel body through the Pallas interpreter on any backend
    (the CPU parity path used by ``tests/kernels/``).
    """
    if _use_kernel(mode):
        return flash_attention_kernel(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=mode == "interpret")
    return flash_attention_ref(q, k, v, causal=causal, window=window)
