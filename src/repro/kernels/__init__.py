"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package has:
  * ``kernel.py`` — pl.pallas_call with explicit BlockSpec VMEM tiling,
  * ``ops.py``    — jit'd public wrapper (platform dispatch: TPU runs the
                    kernel, CPU runs the reference),
  * ``ref.py``    — pure-jnp oracle used for allclose validation.

Kernels:
  flash_attention    — blocked online-softmax attention (GQA, causal, SWA)
  temporal_attention — TGAT seed->K-neighbor masked attention (the paper's
                       top-2 hot spot, Table 11)
  segment_reduce     — sorted-segment sum as MXU one-hot matmuls
                       (discretization psi_r + GCN aggregation)
  ssd_chunk          — mamba2 SSD intra-chunk + fused state recurrence

Memory layouts, the scalar-prefetch/DMA tricks, and the interpret-mode
parity-testing story are documented in ``docs/kernels.md``.
"""
