"""Public jit'd wrapper: Pallas kernel on TPU, exact recurrence elsewhere."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd_chunk.kernel import ssd_chunk_kernel
from repro.kernels.ssd_chunk.ref import ssd_ref


def _use_kernel(mode: str) -> bool:
    """Resolve a dispatch mode string; raises on unknown modes."""
    if mode not in ("auto", "ref", "kernel", "interpret"):
        raise ValueError(f"unknown kernel dispatch mode {mode!r}")
    return (mode in ("kernel", "interpret")
            or (mode == "auto" and jax.default_backend() == "tpu"))


@partial(jax.jit, static_argnames=("chunk", "mode"))
def ssd(x, dt, a, B, C, *, chunk: int = 128, mode: str = "auto"):
    """x: (S, H, P); dt: (S, H); a: (H,); B, C: (S, H, N) -> y (S, H, P).

    ``mode`` ∈ {"auto", "ref", "kernel", "interpret"}: "auto" runs the
    Pallas kernel on TPU and the exact recurrence elsewhere; "interpret"
    executes the kernel body through the Pallas interpreter on any backend
    (the CPU parity path used by ``tests/kernels/``).
    """
    if _use_kernel(mode):
        return ssd_chunk_kernel(x, dt, a, B, C, chunk=chunk,
                                interpret=mode == "interpret")
    y, _ = ssd_ref(x, dt, a, B, C)
    return y
