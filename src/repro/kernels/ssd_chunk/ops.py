"""Public jit'd wrapper: Pallas kernel on TPU, exact recurrence elsewhere."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd_chunk.kernel import ssd_chunk_kernel
from repro.kernels.ssd_chunk.ref import ssd_ref


@partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, a, B, C, *, chunk: int = 128):
    """x: (S, H, P); dt: (S, H); a: (H,); B, C: (S, H, N) -> y (S, H, P)."""
    if jax.default_backend() == "tpu":
        return ssd_chunk_kernel(x, dt, a, B, C, chunk=chunk)
    y, _ = ssd_ref(x, dt, a, B, C)
    return y
