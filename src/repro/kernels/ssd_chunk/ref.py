"""Pure-jnp oracle for the SSD chunk kernel: the naive sequential
state-space recurrence (exact, O(S) steps)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, B, C, init_state=None):
    """x: (S, H, P); dt: (S, H); a: (H,) negative; B, C: (S, H, N).

    Returns (y (S, H, P), final_state (H, P, N)).
    """
    S, H, P = x.shape
    N = B.shape[-1]
    s0 = jnp.zeros((H, P, N)) if init_state is None else init_state

    def step(s, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * a)  # (H,)
        s = s * decay[:, None, None] + jnp.einsum(
            "h,hn,hp->hpn", dtt, Bt, xt
        )
        y = jnp.einsum("hpn,hn->hp", s, Ct)
        return s, y

    final, ys = jax.lax.scan(step, s0, (x, dt, B, C))
    return ys, final
