from repro.kernels.ssd_chunk.kernel import ssd_chunk_kernel
from repro.kernels.ssd_chunk.ops import ssd
from repro.kernels.ssd_chunk.ref import ssd_ref

__all__ = ["ssd", "ssd_chunk_kernel", "ssd_ref"]
