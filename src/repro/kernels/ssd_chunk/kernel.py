"""Mamba2 SSD chunk Pallas TPU kernel.

Implements the state-space-duality chunked algorithm with the inter-chunk
recurrence FUSED into the same kernel: the grid walks chunks sequentially
per (head,) program, carrying the running state (P, N) in VMEM scratch.
This avoids materializing per-chunk states in HBM (the pure-jnp path
round-trips (B, nc, H, P, N)).

Grid: (H, num_chunks) with the chunk axis sequential ("arbitrary").
Blocks (VMEM):
  x:  (1, Q, P)    dt: (1, Q)    B, C: (1, Q, N)    y: (1, Q, P)
  scratch: state (P, N) f32, persists across the chunk walk.

Per chunk, the MXU work is (Q,N)x(N,Q) scores, (Q,Q)x(Q,P) intra-chunk
output, (N,Q)x(Q,P) state update — all 128-aligned when Q=128, N=64/128,
P=64.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _ssd_chunk_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, s_scr, *,
                      chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    a = a_ref[0]  # scalar decay rate for this head (negative)
    x = x_ref[0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)  # (Q,)
    B = b_ref[0].astype(jnp.float32)  # (Q, N)
    C = c_ref[0].astype(jnp.float32)  # (Q, N)

    adt = dt * a  # (Q,) log-decay per step
    cum = jnp.cumsum(adt)  # (Q,) inclusive
    # intra-chunk decay matrix L[i, j] = exp(cum_i - cum_j), i >= j
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    xdt = x * dt[:, None]  # (Q, P)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))  # (Q, Q)
    y_diag = jax.lax.dot(scores * L, xdt)  # (Q, P)

    # contribution of the carried state: y_off = (C * exp(cum)) @ state^T
    state = s_scr[...]  # (P, N)
    y_off = jax.lax.dot_general(C * jnp.exp(cum)[:, None], state,
                                (((1,), (1,)), ((), ())))  # (Q, P)
    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: s' = exp(sum adt) * s + sum_j exp(cum_end - cum_j) B_j (dt x)_j
    decay_end = jnp.exp(cum[-1] - cum)  # (Q,)
    wB = B * decay_end[:, None]  # (Q, N)
    s_new = jax.lax.dot_general(xdt, wB, (((0,), (0,)), ((), ())))  # (P, N)
    s_scr[...] = state * jnp.exp(cum[-1]) + s_new


def ssd_chunk_kernel(x, dt, a, B, C, *, chunk: int = 128,
                     interpret: bool = False):
    """x: (S, H, P); dt: (S, H); a: (H,); B, C: (S, H, N).

    Returns y: (S, H, P). S is padded to a chunk multiple internally
    (padded steps have dt=0 -> exp(0)=1 decay, zero input).
    """
    S, H, P = x.shape
    N = B.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, pad), (0, 0)))
        B = jnp.pad(B, ((0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    # head-major layout so each (h, chunk) block is contiguous
    xh = jnp.moveaxis(x, 1, 0)  # (H, Sp, P)
    dth = jnp.moveaxis(dt, 1, 0)  # (H, Sp)
    Bh = jnp.moveaxis(B, 1, 0)  # (H, Sp, N)
    Ch = jnp.moveaxis(C, 1, 0)

    out = pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, chunk=chunk),
        grid=(H, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda h, c: (h,)),
            pl.BlockSpec((1, chunk, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
            pl.BlockSpec((1, chunk, N), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda h, c: (h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Sp, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, xh, dth, Bh, Ch)
    return jnp.moveaxis(out, 0, 1)[:S]
