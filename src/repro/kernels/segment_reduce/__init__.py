from repro.kernels.segment_reduce.kernel import segment_sum_kernel
from repro.kernels.segment_reduce.ops import segment_sum
from repro.kernels.segment_reduce.ref import segment_sum_ref

__all__ = ["segment_sum", "segment_sum_kernel", "segment_sum_ref"]
