"""Sorted segment-sum Pallas TPU kernel (discretization psi_r / GCN
aggregation hot spot).

TPU adaptation note (DESIGN.md §2): GPU implementations scatter with atomic
adds; TPUs have no atomics, so the scatter is recast as a *one-hot matmul*
on the MXU: for each edge block, ``out += onehot(seg_ids_block) @ data_block``
where onehot is (num_segments, block_e). The whole (num_segments, D) output
tile stays resident in VMEM across the sequential edge-block walk, so each
output element is written to HBM exactly once.

Grid: (num_edge_blocks,) sequential ("arbitrary") — the output block is
revisited every step (accumulator semantics).

VMEM budget: out (G, D) + onehot (G, block_e) + data (block_e, D); with
G=2048, D=128, block_e=256 that is ~3.3 MiB f32. ops.py tiles larger
segment spaces into G-sized chunks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _segment_sum_kernel(seg_ref, data_ref, o_ref, *, num_segments: int,
                        block_e: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    seg = seg_ref[...]  # (block_e,) int32; -1 = padding
    data = data_ref[...].astype(jnp.float32)  # (block_e, D)
    # one-hot (G, block_e) on the fly; padding rows match no segment
    seg_grid = jax.lax.broadcasted_iota(jnp.int32, (num_segments, block_e), 0)
    onehot = (seg_grid == seg[None, :]).astype(jnp.float32)
    o_ref[...] += jax.lax.dot(onehot, data).astype(o_ref.dtype)


def segment_sum_kernel(data, seg_ids, num_segments: int, *,
                       block_e: int = 256, interpret: bool = False):
    """data: (E, D); seg_ids: (E,) int32 in [0, num_segments) or -1 padding.

    Returns (num_segments, D). ``num_segments * D`` must fit VMEM; the ops
    wrapper tiles bigger segment spaces.
    """
    E, D = data.shape
    pad = (-E) % block_e
    if pad:
        data = jnp.pad(data, ((0, pad), (0, 0)))
        seg_ids = jnp.pad(seg_ids, (0, pad), constant_values=-1)
    ne = (E + pad) // block_e

    out = pl.pallas_call(
        functools.partial(_segment_sum_kernel, num_segments=num_segments,
                          block_e=block_e),
        grid=(ne,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e, D), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, D), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, D), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(seg_ids.astype(jnp.int32), data)
    return out
