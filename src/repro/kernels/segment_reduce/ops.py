"""Public jit'd wrapper with segment-space tiling.

The kernel holds the whole (num_segments, D) tile in VMEM; larger segment
spaces are processed in G-sized chunks (edges are pre-sorted by segment, so
each chunk reads a contiguous edge range — ops here keeps it simple and
passes the full edge set with out-of-range ids masked to -1).

``segment_sum`` carries a custom VJP: the backward of a segment sum is a
plain gather (``g[seg_ids]`` with padding rows zeroed), so the gradient
never re-materializes scatter intermediates regardless of which dispatch
path ran the forward.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_reduce.kernel import segment_sum_kernel
from repro.kernels.segment_reduce.ref import segment_sum_ref

_VMEM_TILE = 2048


def _use_kernel(mode: str) -> bool:
    """Resolve a dispatch mode string; raises on unknown modes."""
    if mode not in ("auto", "ref", "kernel", "interpret"):
        raise ValueError(f"unknown kernel dispatch mode {mode!r}")
    return (mode in ("kernel", "interpret")
            or (mode == "auto" and jax.default_backend() == "tpu"))


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _segment_sum_call(data, seg_ids, num_segments, block_e, mode):
    if not _use_kernel(mode):
        return segment_sum_ref(data, seg_ids, num_segments)
    interpret = mode == "interpret"
    if num_segments <= _VMEM_TILE:
        return segment_sum_kernel(data, seg_ids, num_segments,
                                  block_e=block_e, interpret=interpret)
    parts = []
    for lo in range(0, num_segments, _VMEM_TILE):
        g = min(_VMEM_TILE, num_segments - lo)
        local = jnp.where((seg_ids >= lo) & (seg_ids < lo + g),
                          seg_ids - lo, -1)
        parts.append(segment_sum_kernel(data, local, g, block_e=block_e,
                                        interpret=interpret))
    return jnp.concatenate(parts, axis=0)


def _segment_sum_fwd(data, seg_ids, num_segments, block_e, mode):
    out = _segment_sum_call(data, seg_ids, num_segments, block_e, mode)
    return out, seg_ids


def _segment_sum_bwd(num_segments, block_e, mode, seg_ids, g):
    # The transpose of a masked scatter-add is a masked gather — no
    # (num_segments, E) intermediate, no scatter in the backward.
    d_data = g[jnp.maximum(seg_ids, 0)] * (seg_ids >= 0)[:, None].astype(g.dtype)
    d_ids = np.zeros(seg_ids.shape, dtype=jax.dtypes.float0)
    return d_data, d_ids


_segment_sum_call.defvjp(_segment_sum_fwd, _segment_sum_bwd)


@partial(jax.jit, static_argnames=("num_segments", "block_e", "mode"))
def segment_sum(data, seg_ids, num_segments: int, *, block_e: int = 256,
                mode: str = "auto"):
    """data: (E, D); seg_ids: (E,) int32 -> (num_segments, D).

    Rows with ``seg_ids < 0`` are dropped. ``mode`` ∈ {"auto", "ref",
    "kernel", "interpret"}: "auto" runs the Pallas kernel on TPU and the
    jnp reference elsewhere; "interpret" executes the kernel body through
    the Pallas interpreter on any backend (the CPU parity path used by
    ``tests/kernels/``). Differentiable w.r.t. ``data`` on every path via
    a gather-based custom VJP.
    """
    return _segment_sum_call(data, seg_ids, num_segments, block_e, mode)
