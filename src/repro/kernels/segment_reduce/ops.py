"""Public jit'd wrapper with segment-space tiling.

The kernel holds the whole (num_segments, D) tile in VMEM; larger segment
spaces are processed in G-sized chunks (edges are pre-sorted by segment, so
each chunk reads a contiguous edge range — ops here keeps it simple and
passes the full edge set with out-of-range ids masked to -1).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.kernel import segment_sum_kernel
from repro.kernels.segment_reduce.ref import segment_sum_ref

_VMEM_TILE = 2048


@partial(jax.jit, static_argnames=("num_segments", "block_e"))
def segment_sum(data, seg_ids, num_segments: int, *, block_e: int = 256):
    """data: (E, D); seg_ids: (E,) int32 -> (num_segments, D)."""
    if jax.default_backend() != "tpu":
        return segment_sum_ref(data, seg_ids, num_segments)
    if num_segments <= _VMEM_TILE:
        return segment_sum_kernel(data, seg_ids, num_segments, block_e=block_e)
    parts = []
    for lo in range(0, num_segments, _VMEM_TILE):
        g = min(_VMEM_TILE, num_segments - lo)
        local = jnp.where((seg_ids >= lo) & (seg_ids < lo + g), seg_ids - lo, -1)
        parts.append(segment_sum_kernel(data, local, g, block_e=block_e))
    return jnp.concatenate(parts, axis=0)
