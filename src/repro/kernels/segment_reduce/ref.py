"""Pure-jnp oracle for the sorted segment reduce kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(data, seg_ids, num_segments: int):
    """data: (E, D); seg_ids: (E,) in [0, num_segments) (need not be sorted
    for the oracle). Returns (num_segments, D)."""
    return jax.ops.segment_sum(data, seg_ids, num_segments)
