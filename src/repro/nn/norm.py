"""Normalization layers."""

from __future__ import annotations

import jax.numpy as jnp


def layer_norm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layer_norm(params, x, eps: float = 1e-5):
    mean = x.mean(-1, keepdims=True)
    var = ((x - mean) ** 2).mean(-1, keepdims=True)
    y = (x - mean) * jnp.reciprocal(jnp.sqrt(var + eps))
    return y * params["scale"] + params["bias"]


def rms_norm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rms_norm(params, x, eps: float = 1e-6):
    # Compute the statistic in f32 for bf16 activations.
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    y = xf * jnp.reciprocal(jnp.sqrt(ms + eps))
    return (y * params["scale"]).astype(x.dtype)
