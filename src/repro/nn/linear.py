"""Dense layers as (init, apply) function pairs over param dicts."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.init import glorot, zeros


def dense_init(key, d_in: int, d_out: int, bias: bool = True, dtype=jnp.float32):
    kw, kb = jax.random.split(key)
    p = {"w": glorot(kw, (d_in, d_out), dtype)}
    if bias:
        p["b"] = zeros(kb, (d_out,), dtype)
    return p


def dense(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def embedding_init(key, num: int, dim: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (num, dim), dtype) * 0.02}


def embedding(params, ids):
    return params["table"][ids]
