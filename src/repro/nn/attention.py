"""Multi-head attention primitives for the TG model zoo.

The LM stack has its own GQA attention in ``models/lm``; this module covers
the smaller, mask-heavy attention patterns of temporal graph models:
seed-to-neighborhood cross attention (TGAT/TGN) and full self-attention over
short patch sequences (DyGFormer).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import dense, dense_init

NEG_INF = -1e9


def mha_init(key, d_q: int, d_kv: int, d_model: int, num_heads: int, dtype=jnp.float32):
    """Init q/k/v/o dense params for multi-head attention with separate
    query (d_q) and key/value (d_kv) input widths."""
    if d_model % num_heads:
        raise ValueError(f"d_model {d_model} not divisible by heads {num_heads}")
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q": dense_init(kq, d_q, d_model, dtype=dtype),
        "k": dense_init(kk, d_kv, d_model, dtype=dtype),
        "v": dense_init(kv, d_kv, d_model, dtype=dtype),
        "o": dense_init(ko, d_model, d_model, dtype=dtype),
    }


def _split_heads(x, h):
    *lead, d = x.shape
    return x.reshape(*lead, h, d // h)


def mha(params, q_in, kv_in, mask=None, num_heads: int = 2):
    """q_in: (..., Lq, Dq); kv_in: (..., Lk, Dkv); mask: (..., Lq, Lk) bool.

    Returns (..., Lq, d_model).
    """
    h = num_heads
    q = _split_heads(dense(params["q"], q_in), h)  # (..., Lq, H, dh)
    k = _split_heads(dense(params["k"], kv_in), h)
    v = _split_heads(dense(params["v"], kv_in), h)
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    logits = jnp.einsum("...qhd,...khd->...hqk", q, k) * scale
    if mask is not None:
        logits = jnp.where(mask[..., None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    if mask is not None:
        # Rows with no valid key: zero output instead of uniform garbage.
        any_valid = mask[..., None, :, :].any(-1, keepdims=True)
        w = jnp.where(any_valid, w, 0.0)
    out = jnp.einsum("...hqk,...khd->...qhd", w, v)
    *lead, Lq, H, dh = out.shape
    return dense(params["o"], out.reshape(*lead, Lq, H * dh))


def seed_neighbor_attention(params, seed_feat, nbr_feat, nbr_mask, num_heads: int = 2):
    """TGAT-style: one query (the seed) attends over its K neighbors.

    seed_feat: (S, Dq); nbr_feat: (S, K, Dkv); nbr_mask: (S, K) bool.
    Returns (S, d_model).
    """
    out = mha(params, seed_feat[:, None, :], nbr_feat, nbr_mask[:, None, :],
              num_heads=num_heads)
    return out[:, 0, :]


def fused_seed_neighbor_attention(params, node_kv_in, q_in, seeds, seed_times,
                                  buf, time_params, d_edge: int = 0,
                                  edge_table=None, num_heads: int = 2,
                                  mode: str = "auto", node_axis=None,
                                  buf_rows=None):
    """Fused twin of ``seed_neighbor_attention`` over the resident recency
    buffer (the ``device_sampling=True`` layer-1 compute of TGAT/TGN).

    Instead of a pre-gathered ``(S, K, Dkv)`` neighbor tensor, this takes the
    *node-level* slice of the kv inputs (``node_kv_in``: (N, d_node), e.g.
    node features, or memory ‖ node features for TGN) and the packed buffer
    ``buf``: (Nb, K, 3). The kv projection ``concat([node, edge, time]) @ W``
    is split by input block: the node term becomes an (N, H, Dh) table
    (dense bias folded in), while the edge-feature and Bochner time-encoding
    terms are folded in as additive biases by ``fused_temporal_layer`` —
    in-kernel on TPU, so the ``(S, K, H, Dh)`` gather never lands in HBM.

    q_in: (S, Dq) query inputs (projected here); seeds/seed_times: (S,);
    time_params: ``nn.time_encode`` params; edge_table: (E, d_edge) raw
    edge-feature storage (or None). ``mode`` is forwarded to
    ``fused_temporal_layer``. Returns (S, d_model).

    With ``node_axis``/``buf_rows`` (inside a shard_map over a mesh whose
    node axis is ``node_axis``) the attention runs through
    ``fused_temporal_layer_sharded``: ``buf`` is then each shard's local
    ``(buf_rows + 1, K, 3)`` block of the node-partitioned buffer, the
    node-replicated partial outputs are psum-assembled exactly, and the
    o-projection runs on the assembled result (node-replicated like the
    rest of the model).

    Cost note: the node term is projected for *all* N nodes (O(N * d^2)
    per call) instead of the classic path's O(S*K * d^2) gathered-row
    projection — a win when S*K is comparable to or larger than N (the
    TGB one-vs-many eval regime) and on TPU where it unlocks the in-kernel
    gather, but asymptotically slower when N >> S*K. Projecting only the
    batch-reachable rows needs dynamic shapes under jit and is a ROADMAP
    item; gate with ``fused=False`` for huge-N / tiny-batch workloads.
    """
    from repro.kernels.temporal_attention import (
        fused_temporal_layer,
        fused_temporal_layer_sharded,
    )

    d_model = params["o"]["w"].shape[0]
    h = num_heads
    dh = d_model // h
    d_node = node_kv_in.shape[-1]
    wk, wv = params["k"], params["v"]
    k_tab = (node_kv_in @ wk["w"][:d_node] + wk["b"]).reshape(-1, h, dh)
    v_tab = (node_kv_in @ wv["w"][:d_node] + wv["b"]).reshape(-1, h, dh)
    use_edge = bool(d_edge) and edge_table is not None
    we_k = wk["w"][d_node:d_node + d_edge] if use_edge else None
    we_v = wv["w"][d_node:d_node + d_edge] if use_edge else None
    wt_k = wk["w"][d_node + d_edge:]
    wt_v = wv["w"][d_node + d_edge:]
    q = _split_heads(dense(params["q"], q_in), h)  # (S, H, Dh)
    kw = dict(
        time_w=time_params["w"], time_b=time_params["b"],
        wt_k=wt_k, wt_v=wt_v,
        edge_feats=edge_table if use_edge else None,
        we_k=we_k, we_v=we_v, mode=mode,
    )
    seeds = jnp.asarray(seeds, jnp.int32)
    seed_times = jnp.asarray(seed_times, jnp.int32)
    if node_axis is not None:
        att = fused_temporal_layer_sharded(
            q, k_tab, v_tab, seeds, seed_times, buf,
            axis=node_axis, rows_per_shard=buf_rows, **kw)
    else:
        att = fused_temporal_layer(q, k_tab, v_tab, seeds, seed_times, buf,
                                   **kw)
    return dense(params["o"], att.reshape(-1, d_model))


def fused_final_hop_attention(params, nbr_kv_in, q_in, seed_times, nbr_times,
                              nbr_eids, nbr_mask, time_params,
                              d_edge: int = 0, edge_table=None,
                              num_heads: int = 2, mode: str = "auto"):
    """Fused final-hop attention for 2-layer TGAT: each seed attends over
    *its own* K computed hop-1 embeddings.

    The classic path reshapes the (S*K, d_model) layer-0 frontier
    embeddings into an (S, K, d_model) tensor, concatenates edge features
    and the time encoding, and projects the result — three (S, K, ·) float
    intermediates. Here the frontier rows are projected *flat* into per-seed
    (S*K, H, Dh) k/v tables (dense bias folded in) and handed to
    ``fused_temporal_layer_per_seed``, which folds the edge/time biases
    in-kernel — the backward is the same flash-style Pallas kernel, so the
    2-layer train step stays gather-free.

    nbr_kv_in: (S*K, d_node) computed frontier embeddings (row ``s*K + j``
    is seed s's j-th neighbor); q_in: (S, Dq) query inputs (projected
    here); seed_times: (S,); nbr_times/nbr_eids/nbr_mask: (S, K);
    time_params: ``nn.time_encode`` params; edge_table: (E, d_edge) raw
    edge-feature storage (or None). Returns (S, d_model).
    """
    from repro.kernels.temporal_attention import fused_temporal_layer_per_seed

    d_model = params["o"]["w"].shape[0]
    h = num_heads
    dh = d_model // h
    d_node = nbr_kv_in.shape[-1]
    wk, wv = params["k"], params["v"]
    k_rows = (nbr_kv_in @ wk["w"][:d_node] + wk["b"]).reshape(-1, h, dh)
    v_rows = (nbr_kv_in @ wv["w"][:d_node] + wv["b"]).reshape(-1, h, dh)
    use_edge = bool(d_edge) and edge_table is not None
    we_k = wk["w"][d_node:d_node + d_edge] if use_edge else None
    we_v = wv["w"][d_node:d_node + d_edge] if use_edge else None
    wt_k = wk["w"][d_node + d_edge:]
    wt_v = wv["w"][d_node + d_edge:]
    q = _split_heads(dense(params["q"], q_in), h)  # (S, H, Dh)
    att = fused_temporal_layer_per_seed(
        q, k_rows, v_rows,
        jnp.asarray(seed_times, jnp.int32), jnp.asarray(nbr_times, jnp.int32),
        nbr_mask, nbr_eids=nbr_eids if use_edge else None,
        time_w=time_params["w"], time_b=time_params["b"],
        wt_k=wt_k, wt_v=wt_v,
        edge_feats=edge_table if use_edge else None,
        we_k=we_k, we_v=we_v, mode=mode,
    )
    return dense(params["o"], att.reshape(-1, d_model))
