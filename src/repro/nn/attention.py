"""Multi-head attention primitives for the TG model zoo.

The LM stack has its own GQA attention in ``models/lm``; this module covers
the smaller, mask-heavy attention patterns of temporal graph models:
seed-to-neighborhood cross attention (TGAT/TGN) and full self-attention over
short patch sequences (DyGFormer).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import dense, dense_init

NEG_INF = -1e9


def mha_init(key, d_q: int, d_kv: int, d_model: int, num_heads: int, dtype=jnp.float32):
    if d_model % num_heads:
        raise ValueError(f"d_model {d_model} not divisible by heads {num_heads}")
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q": dense_init(kq, d_q, d_model, dtype=dtype),
        "k": dense_init(kk, d_kv, d_model, dtype=dtype),
        "v": dense_init(kv, d_kv, d_model, dtype=dtype),
        "o": dense_init(ko, d_model, d_model, dtype=dtype),
    }


def _split_heads(x, h):
    *lead, d = x.shape
    return x.reshape(*lead, h, d // h)


def mha(params, q_in, kv_in, mask=None, num_heads: int = 2):
    """q_in: (..., Lq, Dq); kv_in: (..., Lk, Dkv); mask: (..., Lq, Lk) bool.

    Returns (..., Lq, d_model).
    """
    h = num_heads
    q = _split_heads(dense(params["q"], q_in), h)  # (..., Lq, H, dh)
    k = _split_heads(dense(params["k"], kv_in), h)
    v = _split_heads(dense(params["v"], kv_in), h)
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    logits = jnp.einsum("...qhd,...khd->...hqk", q, k) * scale
    if mask is not None:
        logits = jnp.where(mask[..., None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    if mask is not None:
        # Rows with no valid key: zero output instead of uniform garbage.
        any_valid = mask[..., None, :, :].any(-1, keepdims=True)
        w = jnp.where(any_valid, w, 0.0)
    out = jnp.einsum("...hqk,...khd->...qhd", w, v)
    *lead, Lq, H, dh = out.shape
    return dense(params["o"], out.reshape(*lead, Lq, H * dh))


def seed_neighbor_attention(params, seed_feat, nbr_feat, nbr_mask, num_heads: int = 2):
    """TGAT-style: one query (the seed) attends over its K neighbors.

    seed_feat: (S, Dq); nbr_feat: (S, K, Dkv); nbr_mask: (S, K) bool.
    Returns (S, d_model).
    """
    out = mha(params, seed_feat[:, None, :], nbr_feat, nbr_mask[:, None, :],
              num_heads=num_heads)
    return out[:, 0, :]
