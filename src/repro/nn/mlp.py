"""MLP blocks."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import dense, dense_init


def mlp_init(key, dims, bias: bool = True, dtype=jnp.float32):
    """dims = [d_in, h1, ..., d_out]."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"layer_{i}": dense_init(keys[i], dims[i], dims[i + 1], bias, dtype)
        for i in range(len(dims) - 1)
    }


def mlp(params, x, act=jax.nn.relu, final_act=None):
    n = len(params)
    for i in range(n):
        x = dense(params[f"layer_{i}"], x)
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x
