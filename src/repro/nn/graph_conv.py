"""Graph convolution over COO edge lists (snapshot/DTDG models).

Message passing is expressed as a segment reduction over a fixed-size
(padded) edge list so snapshot models compile once per snapshot capacity.
Aggregation routes through the ``kernels/segment_reduce`` op: on TPU that
is the one-hot-matmul Pallas kernel (the whole segment tile stays in VMEM);
on CPU/GPU it lowers to the ``jax.ops.segment_sum`` reference — the parity
oracle asserted in ``tests/test_dtdg_pipeline.py``. Because the op is a
plain jitted function with static segment count, it nests cleanly inside
the scan-compiled DTDG epoch (``docs/dtdg.md``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce import segment_sum as _segment_sum_op
from repro.nn.linear import dense, dense_init


def segment_agg(values, seg_ids, num_segments: int, *, mode: str = "auto"):
    """Segment-sum ``values`` (E,) or (E, D) by ``seg_ids`` via the
    ``kernels/segment_reduce`` op (``mode`` dispatch as in that op: Pallas
    kernel on TPU under "auto", jnp reference elsewhere, "interpret" forces
    the kernel body on any backend). Differentiable w.r.t. ``values`` via
    the op's gather-based custom VJP."""
    if values.ndim == 1:
        return _segment_sum_op(values[:, None], seg_ids, num_segments,
                               mode=mode)[:, 0]
    return _segment_sum_op(values, seg_ids, num_segments, mode=mode)


def gcn_layer_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    """Init one GCN layer (a dense transform)."""
    return {"lin": dense_init(key, d_in, d_out, dtype=dtype)}


def gcn_layer(params, x, src, dst, edge_mask, num_nodes: int):
    """Symmetric-normalized GCN layer.

    x: (N, d_in); src/dst: (E,) int; edge_mask: (E,) bool (padding).
    Self-loops are added implicitly via the degree normalization + identity
    term (Kipf & Welling renormalization trick).
    """
    w = edge_mask.astype(x.dtype)
    ones = w
    deg = (
        segment_agg(ones, src, num_nodes)
        + segment_agg(ones, dst, num_nodes)
        + 1.0  # self loop
    )
    dinv = jax.lax.rsqrt(deg)
    h = dense(params["lin"], x)
    coeff = (dinv[src] * dinv[dst] * w)[:, None]
    agg = segment_agg(coeff * h[dst], src, num_nodes)
    agg = agg + segment_agg(coeff * h[src], dst, num_nodes)
    return agg + dinv[:, None] ** 2 * h  # self-loop term


def gcn_init(key, dims, dtype=jnp.float32):
    """Init a GCN stack with layer widths ``dims``."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"layer_{i}": gcn_layer_init(keys[i], dims[i], dims[i + 1], dtype)
        for i in range(len(dims) - 1)
    }


def gcn(params, x, src, dst, edge_mask, num_nodes: int, act=jax.nn.relu):
    """Multi-layer GCN forward over one padded snapshot edge list."""
    n = len(params)
    for i in range(n):
        x = gcn_layer(params[f"layer_{i}"], x, src, dst, edge_mask, num_nodes)
        if i < n - 1:
            x = act(x)
    return x
