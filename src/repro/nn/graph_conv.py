"""Graph convolution over COO edge lists (snapshot/DTDG models).

Message passing is expressed with ``jax.ops.segment_sum`` over a fixed-size
(padded) edge list so snapshot models compile once per snapshot capacity.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import dense, dense_init


def gcn_layer_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    return {"lin": dense_init(key, d_in, d_out, dtype=dtype)}


def gcn_layer(params, x, src, dst, edge_mask, num_nodes: int):
    """Symmetric-normalized GCN layer.

    x: (N, d_in); src/dst: (E,) int; edge_mask: (E,) bool (padding).
    Self-loops are added implicitly via the degree normalization + identity
    term (Kipf & Welling renormalization trick).
    """
    w = edge_mask.astype(x.dtype)
    ones = w
    deg = (
        jax.ops.segment_sum(ones, src, num_nodes)
        + jax.ops.segment_sum(ones, dst, num_nodes)
        + 1.0  # self loop
    )
    dinv = jax.lax.rsqrt(deg)
    h = dense(params["lin"], x)
    coeff = (dinv[src] * dinv[dst] * w)[:, None]
    agg = jax.ops.segment_sum(coeff * h[dst], src, num_nodes)
    agg = agg + jax.ops.segment_sum(coeff * h[src], dst, num_nodes)
    return agg + dinv[:, None] ** 2 * h  # self-loop term


def gcn_init(key, dims, dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"layer_{i}": gcn_layer_init(keys[i], dims[i], dims[i + 1], dtype)
        for i in range(len(dims) - 1)
    }


def gcn(params, x, src, dst, edge_mask, num_nodes: int, act=jax.nn.relu):
    n = len(params)
    for i in range(n):
        x = gcn_layer(params[f"layer_{i}"], x, src, dst, edge_mask, num_nodes)
        if i < n - 1:
            x = act(x)
    return x
