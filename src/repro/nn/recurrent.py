"""Recurrent cells (GRU for TGN memory / T-GCN; LSTM for GCLSTM)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import dense, dense_init


def gru_init(key, d_in: int, d_hidden: int, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return {
        "wz": dense_init(ks[0], d_in, d_hidden, dtype=dtype),
        "uz": dense_init(ks[1], d_hidden, d_hidden, bias=False, dtype=dtype),
        "wr": dense_init(ks[2], d_in, d_hidden, dtype=dtype),
        "ur": dense_init(ks[3], d_hidden, d_hidden, bias=False, dtype=dtype),
        "wh": dense_init(ks[4], d_in, d_hidden, dtype=dtype),
        "uh": dense_init(ks[5], d_hidden, d_hidden, bias=False, dtype=dtype),
    }


def gru(params, x, h):
    z = jax.nn.sigmoid(dense(params["wz"], x) + dense(params["uz"], h))
    r = jax.nn.sigmoid(dense(params["wr"], x) + dense(params["ur"], h))
    hh = jnp.tanh(dense(params["wh"], x) + dense(params["uh"], r * h))
    return (1.0 - z) * h + z * hh


def lstm_init(key, d_in: int, d_hidden: int, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    names = ["wi", "ui", "wf", "uf", "wo", "uo", "wg", "ug"]
    p = {}
    for i, n in enumerate(names):
        d = d_in if n.startswith("w") else d_hidden
        p[n] = dense_init(ks[i], d, d_hidden, bias=n.startswith("w"), dtype=dtype)
    return p


def lstm(params, x, state):
    h, c = state
    i = jax.nn.sigmoid(dense(params["wi"], x) + dense(params["ui"], h))
    f = jax.nn.sigmoid(dense(params["wf"], x) + dense(params["uf"], h))
    o = jax.nn.sigmoid(dense(params["wo"], x) + dense(params["uo"], h))
    g = jnp.tanh(dense(params["wg"], x) + dense(params["ug"], h))
    c = f * c + i * g
    h = o * jnp.tanh(c)
    return h, (h, c)
