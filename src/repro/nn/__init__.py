from repro.nn import attention, graph_conv, init, linear, mlp, norm, recurrent, time_encode

__all__ = [
    "attention",
    "graph_conv",
    "init",
    "linear",
    "mlp",
    "norm",
    "recurrent",
    "time_encode",
]
