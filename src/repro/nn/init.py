"""Parameter initializers (pure functions of a PRNG key)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    scale = np.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, dtype) * scale


def lecun(key, shape, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) > 1 else shape[-1]
    return jax.random.normal(key, shape, dtype) * np.sqrt(1.0 / fan_in)


def normal(key, shape, stddev=0.02, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * stddev


def zeros(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(_key, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)
