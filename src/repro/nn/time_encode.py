"""Bochner/Time2Vec time encoding (TGAT, TGN, DyGFormer all share this).

``phi(t) = cos(t * w + b)`` with learnable (or fixed log-spaced) frequencies.
The fixed variant follows GraphMixer: w_i = 1 / alpha^(i/beta) held constant.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def time_encode_init(key, dim: int, learnable: bool = True, dtype=jnp.float32):
    if learnable:
        kw, kb = jax.random.split(key)
        w = jax.random.normal(kw, (dim,), dtype) * 0.1
        b = jax.random.normal(kb, (dim,), dtype) * 0.1
    else:
        w = jnp.asarray(1.0 / np.power(10.0, np.arange(dim) * 4.0 / dim), dtype)
        b = jnp.zeros((dim,), dtype)
    return {"w": w, "b": b}


def time_encode(params, dt):
    """dt: (...,) -> (..., dim). Accepts integer or float timestamps."""
    dt = jnp.asarray(dt, jnp.float32)
    return jnp.cos(dt[..., None] * params["w"] + params["b"])
