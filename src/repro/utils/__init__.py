from repro.utils.prof import Profiler, profile_section

__all__ = ["Profiler", "profile_section"]
