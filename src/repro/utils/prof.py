"""Performance monitoring utilities (paper §4: "Performance monitoring
utilities ... help identify bottlenecks"; Table 11 runtime breakdown).

``Profiler`` accumulates wall time per named section across a run and
prints a Table-11-style percentage breakdown. Sections nest (dotted
paths); JAX async dispatch is handled by blocking on section exit when
``block=True``.
"""

from __future__ import annotations

import contextlib
import time
from collections import defaultdict
from typing import Dict, Iterator, Optional


class Profiler:
    def __init__(self, block: bool = False):
        self.times: Dict[str, float] = defaultdict(float)
        self.counts: Dict[str, int] = defaultdict(int)
        self._stack: list = []
        self._block = block

    @contextlib.contextmanager
    def __call__(self, name: str) -> Iterator[None]:
        path = ".".join([*(s for s, _ in self._stack), name])
        t0 = time.perf_counter()
        self._stack.append((name, t0))
        try:
            yield
        finally:
            if self._block:
                import jax

                jax.effects_barrier()
            dt = time.perf_counter() - t0
            self._stack.pop()
            self.times[path] += dt
            self.counts[path] += 1

    def total(self) -> float:
        return sum(v for k, v in self.times.items() if "." not in k)

    def report(self, min_pct: float = 0.5) -> str:
        total = max(self.total(), 1e-12)
        lines = [f"{'section':<40s}{'calls':>8s}{'seconds':>10s}{'%':>7s}"]
        for path in sorted(self.times, key=lambda p: (p.count("."), -self.times[p])):
            pct = 100.0 * self.times[path] / total
            if pct < min_pct:
                continue
            depth = path.count(".")
            name = "  " * depth + path.split(".")[-1]
            lines.append(
                f"{name:<40s}{self.counts[path]:>8d}"
                f"{self.times[path]:>10.3f}{pct:>6.1f}%"
            )
        return "\n".join(lines)

    def reset(self) -> None:
        self.times.clear()
        self.counts.clear()


@contextlib.contextmanager
def profile_section(profiler: Optional[Profiler], name: str):
    if profiler is None:
        yield
    else:
        with profiler(name):
            yield
