"""Performance monitoring utilities (paper §4: "Performance monitoring
utilities ... help identify bottlenecks"; Table 11 runtime breakdown).

**Deprecated** — ``Profiler`` is now a thin shim over the structured
telemetry layer (``repro.obs.Telemetry``); constructing one raises a
``DeprecationWarning``. New code should use ``Telemetry`` spans with a
``MemorySink`` and ``repro.obs.span_report`` for the Table-11-style
breakdown (see ``docs/observability.md`` for the migration recipe). The
shim keeps the historical surface — ``times``/``counts`` per dotted
section path, ``total()``, ``report()``, ``reset()``, nesting, and
``block=True`` draining JAX async dispatch on section exit — but every
section now flows through ``Telemetry.span``, so a legacy-profiled run
can tee its sections into any sink alongside the rest of the run's
records.
"""

from __future__ import annotations

import contextlib
import warnings
from collections import defaultdict
from typing import Dict, Iterator, Optional

from repro.obs import MemorySink, Telemetry, span_report


class Profiler:
    """Deprecated span-accumulating profiler (use ``repro.obs.Telemetry``).

    Backed by a private ``Telemetry`` + ``MemorySink``: each ``with
    profiler(name)`` section is a ``Telemetry.span``, and ``times`` /
    ``counts`` aggregate the emitted span records by dotted path —
    identical keys and semantics to the historical dict-accumulating
    implementation.
    """

    def __init__(self, block: bool = False):
        warnings.warn(
            "repro.utils.Profiler is deprecated; use repro.obs.Telemetry "
            "spans with a MemorySink and repro.obs.span_report (see "
            "docs/observability.md)",
            DeprecationWarning,
            stacklevel=2,
        )
        self._telemetry = Telemetry()
        self._sink = self._telemetry.attach(MemorySink())
        self._block = block

    @contextlib.contextmanager
    def __call__(self, name: str) -> Iterator[None]:
        with self._telemetry.span(name):
            try:
                yield
            finally:
                if self._block:
                    import jax

                    # Inside the span: drain async dispatch so the span's
                    # duration includes device time, as before.
                    jax.effects_barrier()

    def _aggregate(self):
        times: Dict[str, float] = defaultdict(float)
        counts: Dict[str, int] = defaultdict(int)
        for r in self._sink.records:
            if r.get("kind") == "span":
                times[r["path"]] += r["dur_s"]
                counts[r["path"]] += 1
        return times, counts

    @property
    def times(self) -> Dict[str, float]:
        """Accumulated wall seconds per dotted section path."""
        return self._aggregate()[0]

    @property
    def counts(self) -> Dict[str, int]:
        """Section entry counts per dotted section path."""
        return self._aggregate()[1]

    def total(self) -> float:
        """Summed seconds of top-level (undotted) sections."""
        return sum(v for k, v in self.times.items() if "." not in k)

    def report(self, min_pct: float = 0.5) -> str:
        """Table-11-style percentage breakdown of the recorded sections."""
        return span_report(self._sink.records, min_pct=min_pct)

    def reset(self) -> None:
        """Drop all recorded sections."""
        self._sink.drain()


@contextlib.contextmanager
def profile_section(profiler: Optional[Profiler], name: str):
    """``with profiler(name)`` that no-ops when ``profiler`` is ``None``."""
    if profiler is None:
        yield
    else:
        with profiler(name):
            yield
