"""Parameter specs: single source of truth for shapes, logical sharding
axes, and initialization of every LM parameter.

A model module builds a pytree of ``Spec``; from it we derive
  * ``materialize``  — real initialized params (smoke tests / real training)
  * ``abstract``     — ShapeDtypeStruct pytree with NamedShardings (dry-run:
                       compile without allocating),
  * ``tree_shardings`` — in_shardings pytree for jit.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import Rules, logical_sharding


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | fan_in
    scale: float = 0.02

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} length mismatch")


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def materialize(specs, key, dtype=jnp.float32):
    """Initialize real parameters from a spec pytree."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(spec: Spec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "fan_in":
            fan = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            return jax.random.normal(k, spec.shape, dtype) / np.sqrt(fan)
        return jax.random.normal(k, spec.shape, dtype) * spec.scale

    return treedef.unflatten([one(s, k) for s, k in zip(leaves, keys)])


def abstract(specs, mesh=None, rules: Optional[Rules] = None, dtype=jnp.float32):
    """ShapeDtypeStruct pytree with shardings — no device allocation."""

    def one(spec: Spec):
        sh = logical_sharding(spec.axes, rules=rules, mesh=mesh, shape=spec.shape)
        return jax.ShapeDtypeStruct(spec.shape, dtype, sharding=sh)

    return jax.tree.map(one, specs, is_leaf=_is_spec)


def tree_shardings(specs, mesh=None, rules: Optional[Rules] = None):
    def one(spec: Spec):
        return logical_sharding(spec.axes, rules=rules, mesh=mesh, shape=spec.shape)

    return jax.tree.map(one, specs, is_leaf=_is_spec)


def n_params(specs) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs, is_leaf=_is_spec))
