"""LM building blocks: GQA attention (flash-style blocked softmax, sliding
window, KV cache), SwiGLU/GELU MLPs, top-k MoE with sort-based capacity
dispatch, and the Mamba2 SSD mixer — all pure JAX with logical sharding
annotations, targeting TPU via GSPMD.

Everything is written against the ``Spec`` param system (see params.py);
each block has ``<block>_specs(cfg)`` + ``<block>(params, cfg, ...)``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models.lm.params import Spec

NEG_INF = -2.0e38


def cdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


def cast_tree(params, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), params)


# ======================================================================
# Norms
# ======================================================================
def rms_norm_spec(dim: int) -> Spec:
    return Spec((dim,), (None,), init="ones")


def rms_norm(scale, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm_specs(dim: int):
    return {"scale": Spec((dim,), (None,), "ones"),
            "bias": Spec((dim,), (None,), "zeros")}


def layer_norm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = xf.mean(-1, keepdims=True)
    var = ((xf - mean) ** 2).mean(-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def norm_specs(cfg: ArchConfig, dim: Optional[int] = None):
    """Family-appropriate norm: LayerNorm for whisper, RMSNorm otherwise."""
    d = dim or cfg.d_model
    if cfg.family == "audio":
        return layer_norm_specs(d)
    return rms_norm_spec(d)


def norm(cfg: ArchConfig, p, x):
    if cfg.family == "audio":
        return layer_norm(p, x)
    return rms_norm(p, x, cfg.norm_eps)


# ======================================================================
# RoPE
# ======================================================================
def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D) with D even; positions: scalar, (S,) or (B, S)."""
    B, S, H, D = x.shape
    half = D // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    pos = jnp.atleast_1d(jnp.asarray(positions, jnp.float32))
    if pos.ndim == 1:
        pos = pos[None, :]
    ang = pos[..., None] * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


# ======================================================================
# Flash-style blocked attention (pure JAX; Pallas kernel is the TPU path)
# ======================================================================
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset=0, kv_len: Optional[jnp.ndarray] = None,
                    kv_block: int = 1024):
    """Online-softmax attention, O(S * kv_block) memory.

    q: (B, Sq, H, D); k, v: (B, Skv, Hk, D) with H % Hk == 0.
    ``window`` > 0 enables sliding-window masking (kvpos > qpos - window).
    ``q_offset`` is the absolute position of q[0] (decode/prefill chunks).
    ``kv_len`` optionally masks positions >= kv_len (cache fill level).
    Returns (B, Sq, H, D).
    """
    B, Sq, H, D = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    scale = 1.0 / np.sqrt(D)

    pad = (-Skv) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = (Skv + pad) // kv_block

    qg = q.reshape(B, Sq, Hk, G, D).astype(jnp.float32) * scale
    kb = k.reshape(B, nb, kv_block, Hk, D)
    vb = v.reshape(B, nb, kv_block, Hk, D)
    qpos = q_offset + jnp.arange(Sq)

    def body(carry, blk):
        m, l, acc = carry
        kj, vj, j = blk
        s = jnp.einsum("bqhgd,bthd->bqhgt", qg, kj.astype(jnp.float32))
        kvpos = j * kv_block + jnp.arange(kv_block)
        allow = jnp.ones((Sq, kv_block), bool)
        if causal:
            allow &= kvpos[None, :] <= qpos[:, None]
        if window:
            allow &= kvpos[None, :] > qpos[:, None] - window
        allow &= kvpos[None, :] < (Skv if kv_len is None else kv_len)
        s = jnp.where(allow[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgt,bthd->bqhgd", p, vj.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hk, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hk, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hk, G, D), jnp.float32)
    # Checkpoint the kv-block body: without it, scan's backward stacks the
    # per-block softmax residuals across blocks — i.e. the full (Sq, Skv)
    # attention matrix in f32 (see EXPERIMENTS.md, hymba iteration 2). With
    # it, backward recomputes each block's scores from (q, k): the
    # flash-attention-backward recompute pattern.
    body = jax.checkpoint(body, prevent_cse=False)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nb)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def swa_flash_attention(q, k, v, *, window: int, kv_block: int = 1024):
    """Sliding-window attention with block skipping.

    For q block i (size = kv_block), only kv positions in
    [(i*B - window), (i+1)*B) can be visible, i.e. at most 2 kv blocks when
    window <= kv_block. We scan q blocks and dynamic-slice exactly that kv
    span — attention work drops from O(Sq * Skv) to O(Sq * (B + window))
    (§Perf hymba iteration 3).
    """
    B, Sq, H, D = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    scale = 1.0 / np.sqrt(D)
    assert window <= kv_block and Sq == Skv

    pad = (-Sq) % kv_block
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = Sq + pad
    nq = Sp // kv_block
    qb = q.reshape(B, nq, kv_block, H, D)

    span = 2 * kv_block  # kv slice covering the window + the diagonal block

    def body(_, inp):
        qi, i = inp  # (B, kvb, H, D), scalar block index
        start = jnp.maximum(i * kv_block - kv_block, 0)
        kj = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        qg = qi.reshape(B, kv_block, Hk, G, D).astype(jnp.float32) * scale
        s = jnp.einsum("bqhgd,bthd->bqhgt", qg, kj.astype(jnp.float32))
        qpos = i * kv_block + jnp.arange(kv_block)
        kvpos = start + jnp.arange(span)
        allow = (kvpos[None, :] <= qpos[:, None]) \
            & (kvpos[None, :] > qpos[:, None] - window) \
            & (kvpos[None, :] < Skv)
        s = jnp.where(allow[None, :, None, None, :], s, NEG_INF)
        m = s.max(-1, keepdims=True)
        p = jnp.exp(s - m)
        o = jnp.einsum("bqhgt,bthd->bqhgd", p, vj.astype(jnp.float32))
        o = o / jnp.maximum(p.sum(-1)[..., None], 1e-30)
        return None, o.reshape(B, kv_block, H, D).astype(q.dtype)

    body = jax.checkpoint(body, prevent_cse=False)
    _, out = jax.lax.scan(body, None, (qb.swapaxes(0, 1), jnp.arange(nq)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sp, H, D)
    return out[:, :Sq]


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     fast: bool = True):
    """Single-position attention over a cache. q: (B, 1, H, D);
    k/v_cache: (B, Smax, Hk, D); cache_len: scalar current length.

    ``fast=True`` keeps the cache in its storage dtype and accumulates the
    dots in f32 (``preferred_element_type``) instead of materializing f32
    copies of the whole cache — decode is HBM-bound, and the f32 converts
    are 3x the useful traffic (see EXPERIMENTS.md §Perf).
    """
    B, _, H, D = q.shape
    Smax, Hk = k_cache.shape[1], k_cache.shape[2]
    G = H // Hk
    scale = 1.0 / np.sqrt(D)
    pos = jnp.arange(Smax)
    allow = pos < cache_len
    if window:
        allow &= pos > cache_len - 1 - window
    if fast:
        qg = (q.reshape(B, Hk, G, D) * jnp.asarray(scale, q.dtype))
        s = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache,
                       preferred_element_type=jnp.float32)
        s = jnp.where(allow[None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgt,bthd->bhgd", p.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, 1, H, D).astype(q.dtype)
    qg = q.reshape(B, Hk, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache.astype(jnp.float32))
    s = jnp.where(allow[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ======================================================================
# Attention block (self-attention w/ optional cache; cross-attention)
# ======================================================================
def attention_specs(cfg: ArchConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    H, Hk, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s = {
        "wq": Spec((d, H, Dh), ("embed_fsdp", "heads", "head_dim"), "fan_in"),
        "wk": Spec((d, Hk, Dh), ("embed_fsdp", "kv_heads", "head_dim"), "fan_in"),
        "wv": Spec((d, Hk, Dh), ("embed_fsdp", "kv_heads", "head_dim"), "fan_in"),
        "wo": Spec((H, Dh, d), ("heads", "head_dim", "embed_fsdp"), "fan_in"),
    }
    if cfg.attn_bias:
        s["bq"] = Spec((H, Dh), ("heads", "head_dim"), "zeros")
        s["bk"] = Spec((Hk, Dh), ("kv_heads", "head_dim"), "zeros")
        s["bv"] = Spec((Hk, Dh), ("kv_heads", "head_dim"), "zeros")
        s["bo"] = Spec((d,), (None,), "zeros")
    if cfg.qk_norm:
        s["q_norm"] = Spec((Dh,), (None,), "ones")
        s["k_norm"] = Spec((Dh,), (None,), "ones")
    return s


def _qkv(p, cfg: ArchConfig, x, positions, rope: bool):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.attn_bias:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def self_attention(p, cfg: ArchConfig, x, positions, *, causal=True,
                   rope=True, window=0, kv_block=1024):
    """Full-sequence self-attention (train / prefill). Returns (out, (k, v))."""
    q, k, v = _qkv(p, cfg, x, positions, rope)
    if (causal and window and window <= kv_block
            and q.shape[1] == k.shape[1] and q.shape[1] > 2 * kv_block):
        o = swa_flash_attention(q, k, v, window=window, kv_block=kv_block)
    else:
        o = flash_attention(q, k, v, causal=causal, window=window,
                            kv_block=kv_block)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    if cfg.attn_bias:
        out = out + p["bo"].astype(x.dtype)
    return shard(out, "batch", "seq", None), (k, v)


def cached_self_attention(p, cfg: ArchConfig, x, cache, *, window=0):
    """Single-token decode. x: (B, 1, d); cache: {k, v, idx}."""
    idx = cache["idx"]
    q, k_new, v_new = _qkv(p, cfg, x, idx, rope=True)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), idx, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), idx, axis=1)
    o = decode_attention(q, k_cache, v_cache, idx + 1, window=window)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    if cfg.attn_bias:
        out = out + p["bo"].astype(x.dtype)
    new_cache = {"k": k_cache, "v": v_cache, "idx": idx + 1}
    return out, new_cache


def cached_swa_attention(p, cfg: ArchConfig, x, cache, window: int):
    """Single-token decode with a ring-buffer sliding-window cache of size W.

    cache: {"k","v": (B, W, Hk, D), "slot_pos": (W,), "idx": scalar}. Keys
    are stored post-RoPE at absolute positions, so ring overwrites are safe.
    This is what makes hymba's long_500k decode O(W) instead of O(S).
    """
    idx = cache["idx"]
    W = cache["k"].shape[1]
    q, k_new, v_new = _qkv(p, cfg, x, idx, rope=True)
    slot = idx % W
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    slot_pos = cache["slot_pos"].at[slot].set(idx)

    B, _, H, D = q.shape
    Hk = k_cache.shape[2]
    G = H // Hk
    scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, Hk, G, D) * jnp.asarray(scale, q.dtype)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache,
                   preferred_element_type=jnp.float32)
    allow = (slot_pos >= 0) & (slot_pos <= idx) & (slot_pos > idx - window)
    s = jnp.where(allow[None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", pr.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, H, D).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    if cfg.attn_bias:
        out = out + p["bo"].astype(x.dtype)
    new_cache = {"k": k_cache, "v": v_cache, "slot_pos": slot_pos, "idx": idx + 1}
    return out, new_cache


def cross_attention(p, cfg: ArchConfig, x, enc_k, enc_v):
    """Cross-attention over precomputed encoder K/V (no rope, no mask)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if cfg.attn_bias:
        q = q + p["bq"].astype(dt)
    o = flash_attention(q, enc_k.astype(dt), enc_v.astype(dt), causal=False,
                        kv_block=min(1024, enc_k.shape[1]))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    if cfg.attn_bias:
        out = out + p["bo"].astype(dt)
    return out


def encode_kv(p, cfg: ArchConfig, enc_out):
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    if cfg.attn_bias:
        k, v = k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    return k, v


# ======================================================================
# MLP (SwiGLU / GELU)
# ======================================================================
def mlp_specs(cfg: ArchConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "silu":
        return {
            "wi": Spec((d, f), ("embed_fsdp", "mlp"), "fan_in"),
            "wg": Spec((d, f), ("embed_fsdp", "mlp"), "fan_in"),
            "wo": Spec((f, d), ("mlp", "embed_fsdp"), "fan_in"),
        }
    return {
        "wi": Spec((d, f), ("embed_fsdp", "mlp"), "fan_in"),
        "bi": Spec((f,), ("mlp",), "zeros"),
        "wo": Spec((f, d), ("mlp", "embed_fsdp"), "fan_in"),
        "bo": Spec((d,), (None,), "zeros"),
    }


def mlp_block(p, cfg: ArchConfig, x):
    dt = x.dtype
    if cfg.act == "silu":
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wi"].astype(dt))
        h = shard(h, "batch", "seq", "mlp")
        return shard(h @ p["wo"].astype(dt), "batch", "seq", None)
    h = jax.nn.gelu(x @ p["wi"].astype(dt) + p["bi"].astype(dt))
    h = shard(h, "batch", "seq", "mlp")
    return shard(h @ p["wo"].astype(dt) + p["bo"].astype(dt), "batch", "seq", None)


# ======================================================================
# MoE: top-k routing with sort-based capacity dispatch (dropless-ish)
# ======================================================================
def moe_specs(cfg: ArchConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    s = {
        "router": Spec((d, E), ("embed_fsdp", None), "fan_in"),
        "wi": Spec((E, d, f), ("experts", "embed_fsdp", "moe_mlp"), "fan_in"),
        "wg": Spec((E, d, f), ("experts", "embed_fsdp", "moe_mlp"), "fan_in"),
        "wo": Spec((E, f, d), ("experts", "moe_mlp", "embed_fsdp"), "fan_in"),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        s["shared"] = {
            "wi": Spec((d, fs), ("embed_fsdp", "mlp"), "fan_in"),
            "wg": Spec((d, fs), ("embed_fsdp", "mlp"), "fan_in"),
            "wo": Spec((fs, d), ("mlp", "embed_fsdp"), "fan_in"),
        }
    return s


def _moe_groups() -> int:
    """Number of token groups = data-parallel shard count of the active
    mesh (GShard-style per-group routing)."""
    from repro.distributed.sharding import get_mesh

    mesh = get_mesh()
    if mesh is None:
        return 1
    g = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            g *= mesh.shape[ax]
    return g


def _route_group(xt, router, E: int, K: int, C: int, dt):
    """Group-local routing: sort assignments, gather expert batches.

    xt: (Tg, d). Returns (buf (E, C, d), combine metadata, aux). Pure
    gathers — all index ops stay inside the group/shard.
    """
    Tg, d = xt.shape
    A = Tg * K
    logits = (xt @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, ids = jax.lax.top_k(probs, K)  # (Tg, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = ids.reshape(-1).astype(jnp.int32)
    sorted_e, order = jax.lax.sort_key_val(flat_e, jnp.arange(A, dtype=jnp.int32))
    _, inv = jax.lax.sort_key_val(order, jnp.arange(A, dtype=jnp.int32))
    start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=jnp.int32))
    end = jnp.concatenate([start[1:], jnp.array([A], jnp.int32)])

    slot_src = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (E, C)
    valid = slot_src < end[:, None]
    slot_src = jnp.clip(slot_src, 0, A - 1)
    buf_tok = (order // K)[slot_src]  # (E, C)
    buf = xt[buf_tok] * valid[..., None].astype(dt)

    me = probs.mean(0)
    counts = (end - start).astype(jnp.float32)
    aux = E * jnp.sum(me * counts / A)
    meta = (sorted_e, start, inv, gate)
    return buf, meta, aux


def _combine_group(out_e, meta, K: int, C: int, dt):
    """out_e: (E, C, d) -> (Tg, d), undoing the group-local sort."""
    sorted_e, start, inv, gate = meta
    A = inv.shape[0]
    pos = jnp.arange(A, dtype=jnp.int32)
    rank_sorted = pos - start[sorted_e]
    keep = (rank_sorted < C)[:, None].astype(dt)
    out_sorted = out_e[sorted_e, jnp.clip(rank_sorted, 0, C - 1)] * keep
    out_flat = out_sorted[inv]  # (A, d) in (token, k) row-major order
    Tg = A // K
    return (out_flat.reshape(Tg, K, -1) * gate[..., None].astype(dt)).sum(1)


def moe_block(p, cfg: ArchConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out, aux_loss). x: (B, S, d).

    Group-local scatter-free MoE (EXPERIMENTS.md §Perf, dbrx iterations
    2-4): tokens are split into G groups matching the data-parallel shards;
    ALL routing index ops (sort, searchsorted, gathers) are vmapped inside
    a group, so they never cross shards. Because TP replicates activations
    across the model axis anyway, placing experts on the model axis means
    every (group, expert) pair is computed exactly where both already live:
    no token all-to-all, no scatter (the scatter formulation made GSPMD
    replicate full (T*K, d)-shaped u32 index tensors — hundreds of GiB of
    wire per step). Capacity is per (group, expert), the GShard convention.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    dt = x.dtype
    G = _moe_groups()
    while T % G:
        G //= 2
    Tg = T // G

    C = int(np.ceil(Tg * K / E * cfg.capacity_factor))
    C = max(8, min(C, Tg))

    xg = shard(x.reshape(G, Tg, d), "expert_cap", None, None)
    router = p["router"].astype(dt)

    buf, meta, aux = jax.vmap(
        lambda xt: _route_group(xt, router, E, K, C, dt))(xg)
    buf = shard(buf, "expert_cap", "experts", None, None)  # (G, E, C, d)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wg"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["wi"].astype(dt))
    h = shard(h, "expert_cap", "experts", None, "moe_mlp")
    out_e = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))
    out_e = shard(out_e, "expert_cap", "experts", None, None)

    y = jax.vmap(lambda oe, m: _combine_group(oe, m, K, C, dt))(out_e, meta)
    y = y.reshape(T, d)

    if cfg.num_shared_experts:
        xt = x.reshape(T, d)
        sp = p["shared"]
        hs = jax.nn.silu(xt @ sp["wg"].astype(dt)) * (xt @ sp["wi"].astype(dt))
        y = y + hs @ sp["wo"].astype(dt)

    return shard(y.reshape(B, S, d), "batch", "seq", None), aux.mean()


# ======================================================================
# Mamba2 SSD mixer (chunked state-space duality; Dao & Gu 2024)
# ======================================================================
def ssd_specs(cfg: ArchConfig):
    d = cfg.d_model
    di = cfg.d_inner_ssm
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    conv_ch = di + 2 * G * N
    d_in_proj = 2 * di + 2 * G * N + H
    return {
        "in_proj": Spec((d, d_in_proj), ("embed_fsdp", "heads"), "fan_in"),
        "conv_w": Spec((cfg.conv_kernel, conv_ch), ("conv", "heads"), "fan_in"),
        "conv_b": Spec((conv_ch,), ("heads",), "zeros"),
        "a_log": Spec((H,), ("heads",), "ones"),
        "D": Spec((H,), ("heads",), "ones"),
        "dt_bias": Spec((H,), ("heads",), "zeros"),
        "norm": Spec((di,), (None,), "ones"),
        "out_proj": Spec((di, d), ("heads", "embed_fsdp"), "fan_in"),
    }


def _causal_conv(w, b, x):
    """Depthwise causal conv. x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _segsum(a):
    """Log-decay matrix: L[..., i, j] = sum a[j+1..i] for i >= j else -inf.

    a: (..., Q). Returns (..., Q, Q).
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum (j, i]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_mix(cfg: ArchConfig, xh, dt, A, Bm, Cm, chunk: int = 256,
            init_state=None, return_state: bool = False):
    """Chunked SSD. xh: (B, S, H, P); dt: (B, S, H); A: (H,) (negative);
    Bm, Cm: (B, S, G, N). Returns (B, S, H, P) [, final_state (B, H, P, N)].

    Matmul-heavy einsums run in the INPUT dtype with f32 scalar/decay math
    (the original all-f32 version materialized 4x the bytes), and the B/C
    group tensors broadcast to heads inside the einsums via a split
    (G, H/G) head axis instead of jnp.repeat (which materialized
    (B, S, H, N) copies) — §Perf hymba iterations.
    """
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Hg = H // G
    ct = xh.dtype
    pad = (-S) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    # reshape to chunks; head axis split (G, Hg) for repeat-free broadcast
    xc = xh.reshape(Bsz, nc, chunk, G, Hg, P)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, G, N)
    Cc = Cm.reshape(Bsz, nc, chunk, G, N)

    a = dtc * A  # (B, nc, Q, H) log-decay per step, f32
    a_hc = jnp.moveaxis(a, -1, 2).reshape(Bsz, nc, G, Hg, Sp // nc)
    L = jnp.exp(_segsum(a_hc)).astype(ct)  # (B, nc, G, Hg, Q, Q)

    xdt = xc * dtc.reshape(Bsz, nc, chunk, G, Hg)[..., None].astype(ct)

    # Intra-chunk (diagonal blocks): Y_d = (C B^T ∘ L) (dt x)
    cb = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)  # (B,nc,G,Q,Q)
    y_diag = jnp.einsum("bcgqk,bcghqk,bckghp->bcqghp", cb, L, xdt)

    # Chunk states: S_c = sum_j exp(cum_end - cum_j) * B_j (dt x)_j^T
    cum = jnp.cumsum(a_hc, -1)  # (B,nc,G,Hg,Q) f32
    decay_to_end = jnp.exp(cum[..., -1:] - cum).astype(ct)
    states = jnp.einsum("bcghq,bcqgn,bcqghp->bcghpn",
                        decay_to_end, Bc, xdt)  # (B,nc,G,Hg,P,N)

    # Inter-chunk recurrence over nc (sequential scan, nc is small); the
    # carried state stays f32 for stability across many chunks.
    chunk_decay = jnp.exp(cum[..., -1])  # (B, nc, G, Hg) f32

    def scan_body(s_prev, inp):
        st, dec = inp  # (B,G,Hg,P,N), (B,G,Hg)
        s_new = s_prev * dec[..., None, None] + st.astype(jnp.float32)
        return s_new, s_prev.astype(ct)

    if init_state is None:
        s0 = jnp.zeros((Bsz, G, Hg, P, N), jnp.float32)
    else:
        s0 = init_state.reshape(Bsz, G, Hg, P, N).astype(jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_body,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,G,Hg,P,N)

    # Off-diagonal contribution: Y_off = (C · S_prev) * exp(cum)
    state_decay = jnp.exp(cum).astype(ct)  # (B,nc,G,Hg,Q)
    y_off = jnp.einsum("bcqgn,bcghpn,bcghq->bcqghp",
                       Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, Sp, H, P)[:, :S]
    final_state = final_state.reshape(Bsz, H, P, N).astype(ct)
    if return_state:
        return y, final_state
    return y


def ssd_block(p, cfg: ArchConfig, x, *, chunk: int = 256):
    """Full mamba2 mixer block (train/prefill). x: (B, S, d)."""
    B, S, d = x.shape
    di = cfg.d_inner_ssm
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    dt_ = x.dtype

    zxbcdt = x @ p["in_proj"].astype(dt_)  # (B,S, 2di+2GN+H)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    xbc = jax.nn.silu(_causal_conv(p["conv_w"].astype(dt_), p["conv_b"].astype(dt_), xbc))
    xh, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)

    xh = shard(xh.reshape(B, S, H, P), "batch", "seq", "heads", None)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)

    y = ssd_mix(cfg, xh, dt, A, Bm, Cm, chunk=chunk)
    y = y + xh * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return shard(y @ p["out_proj"].astype(dt_), "batch", "seq", None)


def ssd_decode(p, cfg: ArchConfig, x, state):
    """Single-token SSD step. x: (B, 1, d);
    state: {"conv": (B, K-1, conv_ch), "ssm": (B, H, P, N)}."""
    B, _, d = x.shape
    di = cfg.d_inner_ssm
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    Kc = cfg.conv_kernel
    dt_ = x.dtype

    zxbcdt = x[:, 0] @ p["in_proj"].astype(dt_)  # (B, ...)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)

    conv_buf = jnp.concatenate([state["conv"], xbc[:, None, :]], 1)  # (B,K,C)
    w = p["conv_w"].astype(dt_)
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_buf, w) + p["conv_b"].astype(dt_))
    new_conv = conv_buf[:, 1:]

    xh, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    xh = xh.reshape(B, H, P).astype(jnp.float32)
    Bm = Bm.reshape(B, G, N).astype(jnp.float32)
    Cm = Cm.reshape(B, G, N).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)  # (B,H)

    ssm = state["ssm"].astype(jnp.float32)  # (B,H,P,N)
    ssm = ssm * decay[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, Bh, xh
    )
    y = jnp.einsum("bhpn,bhn->bhp", ssm, Ch) + xh * p["D"][None, :, None]
    y = y.reshape(B, di).astype(dt_)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ p["out_proj"].astype(dt_))[:, None, :]
    return out, {"conv": new_conv, "ssm": ssm.astype(state["ssm"].dtype)}


def ssd_init_state(cfg: ArchConfig, batch: int, dtype):
    di = cfg.d_inner_ssm
    G, N = cfg.ssm_groups, cfg.ssm_state
    conv_ch = di + 2 * G * N
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, N), dtype),
    }
