"""Unified LM model: one composable definition covering all five assigned
families (dense / moe / ssm / hybrid / audio / vlm).

Structure:
  * homogeneous decoder stacks are scanned over a stacked (L, ...) param
    tree (small HLO, O(1) compile in depth, remat-friendly);
  * llama-3.2-vision uses a nested scan: 8 groups x [1 cross-attn block +
    inner scan over 5 self-attn layers];
  * whisper is encoder stack + decoder stack with cross-attention over
    precomputed encoder K/V.

Entry points:
  param_specs(cfg)                      -> Spec pytree
  init(cfg, key)                        -> params
  forward(params, cfg, tokens, ...)     -> logits (train/prefill, causal)
  loss_fn(params, cfg, batch)           -> scalar CE loss
  prefill(params, cfg, batch)           -> (last logits, cache)
  decode_step(params, cfg, cache, tok)  -> (logits, cache)
  init_cache / abstract_cache           -> cache pytrees
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import get_mesh, logical_sharding, shard
from repro.models.lm import layers as L
from repro.models.lm.params import Spec, abstract, materialize

# ======================================================================
# Param specs
# ======================================================================


def _stack(specs, n: int):
    """Prepend a scanned 'layers' axis to every Spec in a subtree."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        specs,
        is_leaf=lambda x: isinstance(x, Spec),
    )


def _block_specs(cfg: ArchConfig) -> Dict[str, Any]:
    """One decoder block's params, per family."""
    fam = cfg.family
    if fam == "ssm":
        return {"norm": L.norm_specs(cfg), "ssd": L.ssd_specs(cfg)}
    s: Dict[str, Any] = {
        "norm1": L.norm_specs(cfg),
        "attn": L.attention_specs(cfg),
        "norm2": L.norm_specs(cfg),
    }
    if fam == "moe":
        s["moe"] = L.moe_specs(cfg)
    else:
        s["mlp"] = L.mlp_specs(cfg)
    if fam == "hybrid":
        s["ssd"] = L.ssd_specs(cfg)
        s["attn_norm"] = L.norm_specs(cfg)
        s["ssd_norm"] = L.norm_specs(cfg)
    return s


def _enc_block_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "norm1": L.norm_specs(cfg),
        "attn": L.attention_specs(cfg),
        "norm2": L.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg),
    }


def _cross_block_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "norm1": L.norm_specs(cfg),
        "xattn": L.attention_specs(cfg),
        "norm2": L.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg),
        "gate_attn": Spec((1,), (None,), "zeros"),
        "gate_mlp": Spec((1,), (None,), "zeros"),
    }


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab_size
    specs: Dict[str, Any] = {
        "embed": Spec((V, d), ("vocab", "embed_fsdp"), "normal", 0.02),
        "final_norm": L.norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["head"] = Spec((d, V), ("embed_fsdp", "vocab"), "fan_in")

    fam = cfg.family
    if fam == "vlm":
        g = cfg.cross_attn_every
        n_groups = cfg.num_layers // g
        specs["blocks"] = _stack(_stack(_block_specs(cfg), g), n_groups)
        specs["cross_blocks"] = _stack(_cross_block_specs(cfg), n_groups)
        specs["vision_proj"] = Spec((d, d), ("embed_fsdp", None), "fan_in")
    elif fam == "audio":
        specs["enc_blocks"] = _stack(_enc_block_specs(cfg), cfg.encoder_layers)
        specs["enc_norm"] = L.norm_specs(cfg)
        specs["enc_pos"] = Spec((cfg.frontend_seq, d), ("frames", None), "normal", 0.01)
        dec = {
            "norm1": L.norm_specs(cfg),
            "attn": L.attention_specs(cfg),
            "norm_x": L.norm_specs(cfg),
            "xattn": L.attention_specs(cfg),
            "norm2": L.norm_specs(cfg),
            "mlp": L.mlp_specs(cfg),
        }
        specs["blocks"] = _stack(dec, cfg.num_layers)
        specs["dec_pos"] = Spec((cfg.max_position_embeddings, d), (None, None),
                                "normal", 0.01)
    else:
        specs["blocks"] = _stack(_block_specs(cfg), cfg.num_layers)
    return specs


def init(cfg: ArchConfig, key):
    return materialize(param_specs(cfg), key, jnp.dtype(cfg.param_dtype))


def abstract_params(cfg: ArchConfig, mesh=None, rules=None):
    return abstract(param_specs(cfg), mesh, rules, jnp.dtype(cfg.param_dtype))


# ======================================================================
# Blocks (forward)
# ======================================================================


def _decoder_block(p, cfg: ArchConfig, x, positions, *, kv_block=1024):
    fam = cfg.family
    if fam == "ssm":
        return x + L.ssd_block(p["ssd"], cfg, L.norm(cfg, p["norm"], x))
    h = L.norm(cfg, p["norm1"], x)
    if fam == "hybrid":
        a, _ = L.self_attention(p["attn"], cfg, h, positions,
                                window=cfg.sliding_window, kv_block=kv_block)
        s = L.ssd_block(p["ssd"], cfg, h)
        mix = 0.5 * (L.norm(cfg, p["attn_norm"], a) + L.norm(cfg, p["ssd_norm"], s))
        x = x + mix
    else:
        a, _ = L.self_attention(p["attn"], cfg, h, positions,
                                window=cfg.sliding_window, kv_block=kv_block)
        x = x + a
    h2 = L.norm(cfg, p["norm2"], x)
    if fam == "moe":
        y, aux = L.moe_block(p["moe"], cfg, h2)
        return x + y, aux
    return x + L.mlp_block(p["mlp"], cfg, h2)


def _scan_blocks(blocks, cfg: ArchConfig, x, positions, *, kv_block=1024):
    """Scan the homogeneous decoder stack; accumulates MoE aux loss."""
    is_moe = cfg.family == "moe"

    def body(carry, layer_p):
        x, aux = carry
        layer_p = L.cast_tree(layer_p, x.dtype) if cfg.param_dtype != cfg.compute_dtype else layer_p
        if is_moe:
            x, a = _decoder_block(layer_p, cfg, x, positions, kv_block=kv_block)
            return (x, aux + a), None
        x = _decoder_block(layer_p, cfg, x, positions, kv_block=kv_block)
        return (x, aux), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    else:
        aux = jnp.zeros((), jnp.float32)
        nl = jax.tree.leaves(blocks)[0].shape[0]
        for i in range(nl):
            layer = jax.tree.map(lambda a: a[i], blocks)
            (x, aux), _ = body((x, aux), layer)
    return x, aux


def _cross_block(p, cfg: ArchConfig, x, enc_k, enc_v):
    h = L.norm(cfg, p["norm1"], x)
    a = L.cross_attention(p["xattn"], cfg, h, enc_k, enc_v)
    x = x + jnp.tanh(p["gate_attn"].astype(x.dtype)) * a
    h = L.norm(cfg, p["norm2"], x)
    x = x + jnp.tanh(p["gate_mlp"].astype(x.dtype)) * L.mlp_block(p["mlp"], cfg, h)
    return x


# ======================================================================
# Forward (train / prefill full-sequence)
# ======================================================================


def _embed_tokens(params, cfg: ArchConfig, tokens):
    emb = params["embed"]
    x = emb.astype(jnp.dtype(cfg.compute_dtype))[tokens]
    return shard(x, "batch", "seq", None)


def _lm_head(params, cfg: ArchConfig, x):
    x = L.norm(cfg, params["final_norm"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ w.astype(x.dtype)
    return shard(logits, "batch", "seq", "vocab")


def _encode_audio(params, cfg: ArchConfig, frames):
    """frames: (B, F, d) stub post-conv features."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + params["enc_pos"].astype(x.dtype)[None]

    def body(carry, layer_p):
        x = carry
        h = L.norm(cfg, layer_p["norm1"], x)
        a, _ = L.self_attention(layer_p["attn"], cfg, h,
                                jnp.arange(x.shape[1]), causal=False,
                                rope=False, kv_block=min(1024, x.shape[1]))
        x = x + a
        x = x + L.mlp_block(layer_p["mlp"], cfg, L.norm(cfg, layer_p["norm2"], x))
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:
        nl = jax.tree.leaves(params["enc_blocks"])[0].shape[0]
        for i in range(nl):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc_blocks"]))
    return L.norm(cfg, params["enc_norm"], x)


def _forward_hidden(params, cfg: ArchConfig, tokens, *, frontend=None,
                    kv_block=1024):
    """Causal forward up to (but excluding) the LM head -> (hidden, aux)."""
    fam = cfg.family
    x = _embed_tokens(params, cfg, tokens)
    positions = jnp.arange(tokens.shape[1])
    aux = jnp.zeros((), jnp.float32)

    if fam == "audio":
        enc = _encode_audio(params, cfg, frontend)
        x = x + params["dec_pos"].astype(x.dtype)[None, : x.shape[1]]

        def body(carry, layer_p):
            x = carry
            h = L.norm(cfg, layer_p["norm1"], x)
            a, _ = L.self_attention(layer_p["attn"], cfg, h, positions,
                                    rope=False, kv_block=kv_block)
            x = x + a
            h = L.norm(cfg, layer_p["norm_x"], x)
            ek, ev = L.encode_kv(layer_p["xattn"], cfg, enc)
            x = x + L.cross_attention(layer_p["xattn"], cfg, h, ek, ev)
            x = x + L.mlp_block(layer_p["mlp"], cfg, L.norm(cfg, layer_p["norm2"], x))
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, params["blocks"])
        else:
            nl = jax.tree.leaves(params["blocks"])[0].shape[0]
            for i in range(nl):
                x, _ = body(x, jax.tree.map(lambda a: a[i], params["blocks"]))

    elif fam == "vlm":
        enc = frontend.astype(x.dtype) @ params["vision_proj"].astype(x.dtype)

        def group_body(carry, grp):
            x = carry
            cross_p, self_p = grp
            x = _cross_block(cross_p, cfg, x, *L.encode_kv(cross_p["xattn"], cfg, enc))
            x, _ = _scan_blocks(self_p, cfg, x, positions, kv_block=kv_block)
            return x, None

        if cfg.scan_layers:
            x, _ = jax.lax.scan(group_body, x,
                                (params["cross_blocks"], params["blocks"]))
        else:
            ng = jax.tree.leaves(params["cross_blocks"])[0].shape[0]
            for i in range(ng):
                grp = jax.tree.map(lambda a: a[i],
                                   (params["cross_blocks"], params["blocks"]))
                x, _ = group_body(x, grp)
    else:
        x, aux = _scan_blocks(params["blocks"], cfg, x, positions, kv_block=kv_block)

    return x, aux


def forward(params, cfg: ArchConfig, tokens, *, frontend=None, kv_block=1024):
    """Causal forward over full sequences -> (logits (B, S, V), aux).

    ``frontend``: (B, F, d) stub embeddings for audio (mel frames) / vlm
    (vision patches); required for those families.
    """
    x, aux = _forward_hidden(params, cfg, tokens, frontend=frontend,
                             kv_block=kv_block)
    return _lm_head(params, cfg, x), aux


def _ce_sum(params, cfg: ArchConfig, x, labels):
    """CE sum from hidden states: logits stay in compute dtype; only the
    reductions run in f32 — no full f32 (B, S, V) materialization."""
    logits = _lm_head(params, cfg, x)
    m = jax.lax.stop_gradient(logits.max(-1))
    z = jnp.exp((logits - m[..., None]).astype(jnp.float32)).sum(-1)
    logz = m.astype(jnp.float32) + jnp.log(z)
    gold = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), -1)[..., 0]
    return (logz - gold.astype(jnp.float32)).sum()


def loss_fn(params, cfg: ArchConfig, batch, *, kv_block=1024,
            ce_chunks: int = 0):
    """Masked next-token cross-entropy (+ MoE aux).

    ``ce_chunks > 0``: compute the LM head + CE per sequence chunk under
    jax.checkpoint, so only (B, S/chunks, V) logits are ever live. The head
    weights are re-read per chunk (cheap) in exchange for not keeping the
    full logits tensor — the top HBM-traffic term of the train cells
    (EXPERIMENTS.md §Perf).
    """
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    n_tok = B * S

    if ce_chunks and S % ce_chunks == 0:
        x, aux = _forward_hidden(params, cfg, tokens,
                                 frontend=batch.get("frontend"),
                                 kv_block=kv_block)
        Sc = S // ce_chunks
        xs = jnp.moveaxis(x.reshape(B, ce_chunks, Sc, x.shape[-1]), 1, 0)
        ls = jnp.moveaxis(labels.reshape(B, ce_chunks, Sc), 1, 0)

        def chunk_ce(carry, inp):
            xc, lc = inp
            return carry + _ce_sum(params, cfg, xc, lc), None

        chunk_ce = jax.checkpoint(chunk_ce, prevent_cse=False)
        total, _ = jax.lax.scan(chunk_ce, jnp.zeros((), jnp.float32), (xs, ls))
        return total / n_tok + 0.01 * aux

    x, aux = _forward_hidden(params, cfg, tokens,
                             frontend=batch.get("frontend"), kv_block=kv_block)
    return _ce_sum(params, cfg, x, labels) / n_tok + 0.01 * aux


# ======================================================================
# KV / SSM caches
# ======================================================================


def _attn_cache_shapes(cfg: ArchConfig, batch: int, max_len: int):
    Hk, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shapes = {
        "k": ((batch, W, Hk, Dh), ("batch", "cache_seq", "kv_heads", None)),
        "v": ((batch, W, Hk, Dh), ("batch", "cache_seq", "kv_heads", None)),
        "idx": ((), ()),
    }
    if cfg.sliding_window:
        shapes["slot_pos"] = ((W,), (None,))
    return shapes


def _ssm_cache_shapes(cfg: ArchConfig, batch: int):
    di = cfg.d_inner_ssm
    G, N = cfg.ssm_groups, cfg.ssm_state
    conv_ch = di + 2 * G * N
    return {
        "conv": ((batch, cfg.conv_kernel - 1, conv_ch), ("batch", None, "heads")),
        "ssm": ((batch, cfg.ssm_heads, cfg.ssm_head_dim, N),
                ("batch", "heads", None, "state")),
    }


def _layer_cache_shapes(cfg: ArchConfig, batch: int, max_len: int):
    fam = cfg.family
    out: Dict[str, Any] = {}
    if fam == "ssm":
        out["ssd"] = _ssm_cache_shapes(cfg, batch)
    elif fam == "hybrid":
        out["attn"] = _attn_cache_shapes(cfg, batch, max_len)
        out["ssd"] = _ssm_cache_shapes(cfg, batch)
    else:
        out["attn"] = _attn_cache_shapes(cfg, batch, max_len)
    return out


def _cache_from_shapes(shapes, cfg: ArchConfig, stack_dims: Tuple[int, ...],
                       make_leaf):
    """shapes pytree of (shape, axes) -> pytree via make_leaf(shape, axes, name)."""

    def rec(node, name):
        if isinstance(node, tuple) and len(node) == 2 and isinstance(node[0], tuple):
            shape, axes = node
            if name in ("idx",):
                return make_leaf(shape, axes, name, jnp.int32, stack=True)
            if name in ("slot_pos",):
                return make_leaf(shape, axes, name, jnp.int32, stack=True)
            return make_leaf(shape, axes, name, jnp.dtype(cfg.compute_dtype), stack=True)
        return {k: rec(v, k) for k, v in node.items()}

    return rec(shapes, "")


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    shapes = _layer_cache_shapes(cfg, batch, max_len)
    nl = cfg.num_layers

    def make_leaf(shape, axes, name, dtype, stack: bool):
        s = (nl,) + shape if stack else shape
        if name == "slot_pos":
            return jnp.full(s, -1, dtype)
        return jnp.zeros(s, dtype)

    cache = _cache_from_shapes(shapes, cfg, (nl,), make_leaf)
    if cfg.family == "audio":
        # cross K/V per decoder layer, filled at prefill
        Hk, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
        F = cfg.frontend_seq
        cache["cross_k"] = jnp.zeros((nl, batch, F, Hk, Dh), jnp.dtype(cfg.compute_dtype))
        cache["cross_v"] = jnp.zeros((nl, batch, F, Hk, Dh), jnp.dtype(cfg.compute_dtype))
    if cfg.family == "vlm":
        cache["enc"] = jnp.zeros((batch, cfg.frontend_seq, cfg.d_model),
                                 jnp.dtype(cfg.compute_dtype))
    return cache


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int, mesh=None, rules=None):
    shapes = _layer_cache_shapes(cfg, batch, max_len)
    nl = cfg.num_layers

    def make_leaf(shape, axes, name, dtype, stack: bool):
        s = (nl,) + shape if stack else shape
        ax = (("layers",) + tuple(axes)) if stack else tuple(axes)
        sh = logical_sharding(ax, rules=rules, mesh=mesh, shape=s)
        return jax.ShapeDtypeStruct(s, dtype, sharding=sh)

    cache = _cache_from_shapes(shapes, cfg, (nl,), make_leaf)
    if cfg.family == "audio":
        Hk, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
        F = cfg.frontend_seq
        sh = logical_sharding(("layers", "batch", "frames", "kv_heads", None),
                              rules=rules, mesh=mesh, shape=(nl, batch, F, Hk, Dh))
        cdt = jnp.dtype(cfg.compute_dtype)
        cache["cross_k"] = jax.ShapeDtypeStruct((nl, batch, F, Hk, Dh), cdt, sharding=sh)
        cache["cross_v"] = jax.ShapeDtypeStruct((nl, batch, F, Hk, Dh), cdt, sharding=sh)
    if cfg.family == "vlm":
        cdt = jnp.dtype(cfg.compute_dtype)
        shp = (batch, cfg.frontend_seq, cfg.d_model)
        sh = logical_sharding(("batch", "frames", None), rules=rules, mesh=mesh,
                              shape=shp)
        cache["enc"] = jax.ShapeDtypeStruct(shp, cdt, sharding=sh)
    return cache


# ======================================================================
# Prefill + decode
# ======================================================================


def prefill(params, cfg: ArchConfig, batch, max_len: Optional[int] = None,
            *, kv_block=1024):
    """Run the full prompt, return (last-token logits, filled cache).

    For attention layers the cache is filled with the prefill K/V; for SSM
    layers the final state is computed by re-running the mixer with
    ``return_state=True``.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S + 1
    fam = cfg.family
    cdt = jnp.dtype(cfg.compute_dtype)

    x = _embed_tokens(params, cfg, tokens)
    positions = jnp.arange(S)
    cache = init_cache(cfg, B, max_len)

    def fill_attn(c, k, v):
        W = c["k"].shape[1]
        if cfg.sliding_window and W < S:
            # last W positions, ring-aligned so slot = pos % W
            take = jax.lax.dynamic_slice_in_dim(k, S - W, W, axis=1)
            vtake = jax.lax.dynamic_slice_in_dim(v, S - W, W, axis=1)
            pos = jnp.arange(S - W, S)
            slot = pos % W
            ck = jnp.zeros_like(c["k"]).at[:, slot].set(take.astype(c["k"].dtype))
            cv = jnp.zeros_like(c["v"]).at[:, slot].set(vtake.astype(c["v"].dtype))
            sp = jnp.full((W,), -1, jnp.int32).at[slot].set(pos)
            return {"k": ck, "v": cv, "slot_pos": sp, "idx": jnp.int32(S)}
        ck = jnp.zeros_like(c["k"]).at[:, :S].set(k.astype(c["k"].dtype))
        cv = jnp.zeros_like(c["v"]).at[:, :S].set(v.astype(c["v"].dtype))
        out = {"k": ck, "v": cv, "idx": jnp.int32(S)}
        if cfg.sliding_window:
            out["slot_pos"] = jnp.full((c["k"].shape[1],), -1, jnp.int32).at[
                jnp.arange(S)].set(jnp.arange(S))
        return out

    if fam == "audio":
        enc = _encode_audio(params, cfg, batch["frontend"])
        x = x + params["dec_pos"].astype(x.dtype)[None, :S]

        def body(x, layer_p, layer_c):
            h = L.norm(cfg, layer_p["norm1"], x)
            a, (k, v) = L.self_attention(layer_p["attn"], cfg, h, positions,
                                         rope=False, kv_block=kv_block)
            x = x + a
            h = L.norm(cfg, layer_p["norm_x"], x)
            ek, ev = L.encode_kv(layer_p["xattn"], cfg, enc)
            x = x + L.cross_attention(layer_p["xattn"], cfg, h, ek, ev)
            x = x + L.mlp_block(layer_p["mlp"], cfg, L.norm(cfg, layer_p["norm2"], x))
            new_c = dict(fill_attn(layer_c["attn"], k, v))
            return x, {"attn": new_c, "ek": ek.astype(cdt), "ev": ev.astype(cdt)}

        x, caches = _scan_prefill(params["blocks"], cfg, x, body, cache)
        cache = {"attn": caches["attn"], "cross_k": caches["ek"], "cross_v": caches["ev"]}

    elif fam == "vlm":
        enc = batch["frontend"].astype(x.dtype) @ params["vision_proj"].astype(x.dtype)
        g = cfg.cross_attn_every
        ng = cfg.num_layers // g

        def body(x, layer_p, layer_c):
            h = L.norm(cfg, layer_p["norm1"], x)
            a, (k, v) = L.self_attention(layer_p["attn"], cfg, h, positions,
                                         kv_block=kv_block)
            x = x + a
            x = x + L.mlp_block(layer_p["mlp"], cfg, L.norm(cfg, layer_p["norm2"], x))
            return x, {"attn": fill_attn(layer_c, k, v)}

        # flatten (ng, g, ...) blocks to (L, ...) for the cache pass
        flat_blocks = jax.tree.map(
            lambda a: a.reshape((ng * g,) + a.shape[2:]), params["blocks"]
        )
        new_attn = []
        xs = x
        for gi in range(ng):
            cross_p = jax.tree.map(lambda a: a[gi], params["cross_blocks"])
            cross_p = L.cast_tree(cross_p, cdt)
            xs = _cross_block(cross_p, cfg, xs, *L.encode_kv(cross_p["xattn"], cfg, enc))
            for li in range(g):
                lidx = gi * g + li
                layer_p = L.cast_tree(jax.tree.map(lambda a: a[lidx], flat_blocks), cdt)
                layer_c = jax.tree.map(lambda a: a[lidx], cache["attn"])
                xs, out = body(xs, layer_p, layer_c)
                new_attn.append(out["attn"])
        x = xs
        cache = {
            "attn": jax.tree.map(lambda *a: jnp.stack(a), *new_attn),
            "enc": enc.astype(cdt),
        }

    elif fam == "ssm":

        def body(x, layer_p, layer_c):
            h = L.norm(cfg, layer_p["norm"], x)
            y, st = _ssd_block_with_state(layer_p["ssd"], cfg, h)
            return x + y, {"ssd": st}

        x, caches = _scan_prefill(params["blocks"], cfg, x, body, cache)
        cache = caches

    elif fam == "hybrid":

        def body(x, layer_p, layer_c):
            h = L.norm(cfg, layer_p["norm1"], x)
            a, (k, v) = L.self_attention(layer_p["attn"], cfg, h, positions,
                                         window=cfg.sliding_window, kv_block=kv_block)
            s, st = _ssd_block_with_state(layer_p["ssd"], cfg, h)
            mix = 0.5 * (L.norm(cfg, layer_p["attn_norm"], a)
                         + L.norm(cfg, layer_p["ssd_norm"], s))
            x = x + mix
            x = x + L.mlp_block(layer_p["mlp"], cfg, L.norm(cfg, layer_p["norm2"], x))
            return x, {"attn": fill_attn(layer_c["attn"], k, v), "ssd": st}

        x, caches = _scan_prefill(params["blocks"], cfg, x, body, cache)
        cache = caches

    else:  # dense / moe

        def body(x, layer_p, layer_c):
            h = L.norm(cfg, layer_p["norm1"], x)
            a, (k, v) = L.self_attention(layer_p["attn"], cfg, h, positions,
                                         window=cfg.sliding_window, kv_block=kv_block)
            x = x + a
            h2 = L.norm(cfg, layer_p["norm2"], x)
            if cfg.family == "moe":
                y, _ = L.moe_block(layer_p["moe"], cfg, h2)
            else:
                y = L.mlp_block(layer_p["mlp"], cfg, h2)
            return x + y, {"attn": fill_attn(layer_c["attn"], k, v)}

        x, caches = _scan_prefill(params["blocks"], cfg, x, body, cache)
        cache = caches

    logits = _lm_head(params, cfg, x[:, -1:, :])
    return logits[:, 0], cache


def _ssd_block_with_state(p, cfg, h, chunk: int = 256):
    """ssd_block variant that also returns the final SSM + conv state."""
    B, S, d = h.shape
    di = cfg.d_inner_ssm
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    dt_ = h.dtype
    zxbcdt = h @ p["in_proj"].astype(dt_)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    conv_tail = xbc[:, -(cfg.conv_kernel - 1):, :]
    xbc = jax.nn.silu(L._causal_conv(p["conv_w"].astype(dt_), p["conv_b"].astype(dt_), xbc))
    xh, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xh.reshape(B, S, H, P)
    y, state = L.ssd_mix(cfg, xh, dt, A,
                         Bm.reshape(B, S, G, N), Cm.reshape(B, S, G, N),
                         chunk=chunk, return_state=True)
    y = y + xh * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = L.rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_)
    cdt = jnp.dtype(cfg.compute_dtype)
    return out, {"conv": conv_tail.astype(cdt), "ssm": state.astype(cdt)}


def _scan_prefill(blocks, cfg: ArchConfig, x, body, cache):
    """Scan the stack threading x; collects per-layer caches as scan outputs."""
    cdt = jnp.dtype(cfg.compute_dtype)

    def scan_body(x, inp):
        layer_p, layer_c = inp
        layer_p = L.cast_tree(layer_p, cdt)
        x, out = body(x, layer_p, layer_c)
        return x, out

    if cfg.remat:
        scan_body = jax.checkpoint(scan_body, prevent_cse=False)
    if cfg.scan_layers:
        x, caches = jax.lax.scan(scan_body, x, (blocks, cache))
    else:
        nl = jax.tree.leaves(blocks)[0].shape[0]
        outs = []
        for i in range(nl):
            x, out = scan_body(x, jax.tree.map(lambda a: a[i], (blocks, cache)))
            outs.append(out)
        caches = jax.tree.map(lambda *a: jnp.stack(a), *outs)
    return x, caches


def decode_step(params, cfg: ArchConfig, cache, tokens):
    """One decode step. tokens: (B,) int32. Returns (logits (B, V), cache)."""
    fam = cfg.family
    cdt = jnp.dtype(cfg.compute_dtype)
    x = _embed_tokens(params, cfg, tokens[:, None])

    def attn_step(p, x, c):
        if cfg.sliding_window:
            return L.cached_swa_attention(p["attn"], cfg, x, c, cfg.sliding_window)
        return L.cached_self_attention(p["attn"], cfg, x, c)

    if fam == "audio":
        idx0 = cache["attn"]["idx"]
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"].astype(cdt), idx0[0] if idx0.ndim else idx0, 1, 0
        )[None]

        def body(x, inp):
            layer_p, c, ek, ev = inp
            layer_p = L.cast_tree(layer_p, cdt)
            h = L.norm(cfg, layer_p["norm1"], x)
            # whisper decode: no rope; positions via learned dec_pos
            q = jnp.einsum("bsd,dhk->bshk", h, layer_p["attn"]["wq"].astype(cdt))
            if cfg.attn_bias:
                q = q + layer_p["attn"]["bq"].astype(cdt)
            idx = c["idx"]
            k_new = jnp.einsum("bsd,dhk->bshk", h, layer_p["attn"]["wk"].astype(cdt))
            v_new = jnp.einsum("bsd,dhk->bshk", h, layer_p["attn"]["wv"].astype(cdt))
            if cfg.attn_bias:
                k_new = k_new + layer_p["attn"]["bk"].astype(cdt)
                v_new = v_new + layer_p["attn"]["bv"].astype(cdt)
            ck = jax.lax.dynamic_update_slice_in_dim(c["k"], k_new.astype(c["k"].dtype), idx, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(c["v"], v_new.astype(c["v"].dtype), idx, 1)
            o = L.decode_attention(q, ck, cv, idx + 1)
            a = jnp.einsum("bshk,hkd->bsd", o, layer_p["attn"]["wo"].astype(cdt))
            if cfg.attn_bias:
                a = a + layer_p["attn"]["bo"].astype(cdt)
            x = x + a
            h = L.norm(cfg, layer_p["norm_x"], x)
            x = x + L.cross_attention(layer_p["xattn"], cfg, h, ek, ev)
            x = x + L.mlp_block(layer_p["mlp"], cfg, L.norm(cfg, layer_p["norm2"], x))
            return x, {"k": ck, "v": cv, "idx": idx + 1}

        def scan_body(x, inp):
            layer_p, c, ek, ev = inp
            return body(x, (layer_p, c, ek, ev))

        if cfg.scan_layers:
            x, new_attn = jax.lax.scan(
                scan_body, x,
                (params["blocks"], cache["attn"], cache["cross_k"], cache["cross_v"]),
            )
        else:
            nl = jax.tree.leaves(params["blocks"])[0].shape[0]
            outs = []
            for i in range(nl):
                x, o = scan_body(x, jax.tree.map(
                    lambda a: a[i],
                    (params["blocks"], cache["attn"], cache["cross_k"], cache["cross_v"])))
                outs.append(o)
            new_attn = jax.tree.map(lambda *a: jnp.stack(a), *outs)
        new_cache = {"attn": new_attn, "cross_k": cache["cross_k"],
                     "cross_v": cache["cross_v"]}

    elif fam == "vlm":
        enc = cache["enc"]
        g = cfg.cross_attn_every
        ng = cfg.num_layers // g
        flat_p = params["blocks"]
        new_attn = []
        for gi in range(ng):
            cross_p = L.cast_tree(jax.tree.map(lambda a: a[gi], params["cross_blocks"]), cdt)
            x = _cross_block(cross_p, cfg, x, *L.encode_kv(cross_p["xattn"], cfg, enc))
            for li in range(g):
                lidx = gi * g + li
                layer_p = L.cast_tree(
                    jax.tree.map(lambda a: a[gi][li], params["blocks"]), cdt)
                c = jax.tree.map(lambda a: a[lidx], cache["attn"])
                h = L.norm(cfg, layer_p["norm1"], x)
                a, c = attn_step(layer_p, h, c)
                x = x + a
                x = x + L.mlp_block(layer_p["mlp"], cfg, L.norm(cfg, layer_p["norm2"], x))
                new_attn.append(c)
        new_cache = {"attn": jax.tree.map(lambda *a: jnp.stack(a), *new_attn),
                     "enc": enc}

    else:

        def body(x, inp):
            layer_p, c = inp
            layer_p = L.cast_tree(layer_p, cdt)
            out_c = {}
            if fam == "ssm":
                h = L.norm(cfg, layer_p["norm"], x)
                y, st = L.ssd_decode(layer_p["ssd"], cfg, h, c["ssd"])
                x = x + y
                out_c["ssd"] = st
                return x, out_c
            h = L.norm(cfg, layer_p["norm1"], x)
            if fam == "hybrid":
                a, ac = attn_step(layer_p, h, c["attn"])
                s, st = L.ssd_decode(layer_p["ssd"], cfg, h, c["ssd"])
                mix = 0.5 * (L.norm(cfg, layer_p["attn_norm"], a)
                             + L.norm(cfg, layer_p["ssd_norm"], s))
                x = x + mix
                out_c = {"attn": ac, "ssd": st}
            else:
                a, ac = attn_step(layer_p, h, c["attn"])
                x = x + a
                out_c["attn"] = ac
            h2 = L.norm(cfg, layer_p["norm2"], x)
            if fam == "moe":
                y, _ = L.moe_block(layer_p["moe"], cfg, h2)
            else:
                y = L.mlp_block(layer_p["mlp"], cfg, h2)
            return x + y, out_c

        if cfg.scan_layers:
            x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        else:
            nl = jax.tree.leaves(params["blocks"])[0].shape[0]
            outs = []
            for i in range(nl):
                x, o = body(x, jax.tree.map(lambda a: a[i], (params["blocks"], cache)))
                outs.append(o)
            new_cache = jax.tree.map(lambda *a: jnp.stack(a), *outs)

    logits = _lm_head(params, cfg, x)
    return logits[:, 0], new_cache
