from repro.models.lm import layers, model, params

__all__ = ["layers", "model", "params"]
