"""Snapshot (DTDG) models: GCN, GCLSTM, T-GCN.

All operate on discretized snapshots — padded COO edge lists of a fixed
capacity (the ``SnapshotTensor`` rows built by
``core.loader.snapshot_tensor``) + a learned node embedding table. Each
model maps a snapshot (and its recurrent state, if any) to per-node
embeddings Z in R^{N x d}; link prediction on snapshot t+1 is decoded from
Z computed on snapshots <= t.

Every model exposes the same ``lax.scan``-compatible contract through the
``init_params`` / ``init_state`` / ``make_apply`` registry: the recurrent
state is a pytree carry (``()`` for the stateless GCN) and
``apply(params, src, dst, mask, state) -> (z, state)`` is pure, so a whole
epoch of snapshots runs as **one** scanned jitted call in
``train.loop.DTDGLinkPipeline`` instead of one dispatch per
snapshot. Neighbor aggregation inside every model routes through the
``kernels/segment_reduce`` op (``nn.graph_conv``). See ``docs/dtdg.md``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.tg.common import link_decoder_init
from repro.nn.graph_conv import gcn, gcn_init, gcn_layer, gcn_layer_init
from repro.nn.linear import dense, dense_init


@dataclasses.dataclass(frozen=True)
class SnapshotConfig:
    """Shared DTDG model hyperparameters (node count, widths, depth)."""

    num_nodes: int
    d_node: int = 256
    d_embed: int = 128
    num_layers: int = 2


# ----------------------------------------------------------------------
# GCN: snapshot-independent encoder
# ----------------------------------------------------------------------
def gcn_model_init(key, cfg: SnapshotConfig):
    """Init GCN params: embedding table + GCN stack + link decoder."""
    k1, k2, k3 = jax.random.split(key, 3)
    dims = [cfg.d_node] + [cfg.d_embed] * cfg.num_layers
    return {
        "emb": jax.random.normal(k1, (cfg.num_nodes, cfg.d_node)) * 0.02,
        "gcn": gcn_init(k2, dims),
        "decoder": link_decoder_init(k3, cfg.d_embed),
    }


def gcn_model_apply(params, cfg: SnapshotConfig, src, dst, edge_mask):
    """Per-node embeddings Z from one padded snapshot (stateless)."""
    return gcn(params["gcn"], params["emb"], src, dst, edge_mask, cfg.num_nodes)


# ----------------------------------------------------------------------
# GCLSTM (Chen et al., 2018): LSTM whose hidden transforms are GCNs
# ----------------------------------------------------------------------
def gclstm_init(key, cfg: SnapshotConfig):
    """Init GCLSTM params: embeddings, gate dense/GCN pairs, decoder."""
    keys = jax.random.split(key, 11)
    d_in, d_h = cfg.d_node, cfg.d_embed
    p = {
        "emb": jax.random.normal(keys[0], (cfg.num_nodes, d_in)) * 0.02,
        "decoder": link_decoder_init(keys[1], d_h),
    }
    for i, g in enumerate(("i", "f", "o", "g")):
        p[f"w{g}"] = dense_init(keys[2 + 2 * i], d_in, d_h)
        p[f"u{g}"] = gcn_layer_init(keys[3 + 2 * i], d_h, d_h)
    p["out"] = dense_init(keys[10], d_h, d_h)
    return p


def gclstm_state(cfg: SnapshotConfig):
    """Zero (h, c) recurrent state: two (N, d_embed) arrays."""
    z = jnp.zeros((cfg.num_nodes, cfg.d_embed))
    return (z, z)


def gclstm_apply(params, cfg: SnapshotConfig, src, dst, edge_mask, state):
    """One GCLSTM step over a padded snapshot: returns (z, (h, c))."""
    h, c = state
    x = params["emb"]
    n = cfg.num_nodes

    def gate(g, act):
        return act(
            dense(params[f"w{g}"], x)
            + gcn_layer(params[f"u{g}"], h, src, dst, edge_mask, n)
        )

    i = gate("i", jax.nn.sigmoid)
    f = gate("f", jax.nn.sigmoid)
    o = gate("o", jax.nn.sigmoid)
    g = gate("g", jnp.tanh)
    c = f * c + i * g
    h = o * jnp.tanh(c)
    z = dense(params["out"], h)
    return z, (h, c)


# ----------------------------------------------------------------------
# T-GCN (Zhao et al., 2019): GRU whose transforms are GCNs over [X || h]
# ----------------------------------------------------------------------
def tgcn_init(key, cfg: SnapshotConfig):
    """Init T-GCN params: embeddings, GRU-gate GCNs, decoder."""
    keys = jax.random.split(key, 5)
    d_in, d_h = cfg.d_node, cfg.d_embed
    return {
        "emb": jax.random.normal(keys[0], (cfg.num_nodes, d_in)) * 0.02,
        "gu": gcn_layer_init(keys[1], d_in + d_h, d_h),
        "gr": gcn_layer_init(keys[2], d_in + d_h, d_h),
        "gc": gcn_layer_init(keys[3], d_in + d_h, d_h),
        "decoder": link_decoder_init(keys[4], d_h),
    }


def tgcn_state(cfg: SnapshotConfig):
    """Zero hidden state: one (N, d_embed) array."""
    return jnp.zeros((cfg.num_nodes, cfg.d_embed))


def tgcn_apply(params, cfg: SnapshotConfig, src, dst, edge_mask, h):
    """One T-GCN (GRU-over-GCN) step: returns (z, h_new) with z = h_new."""
    x = params["emb"]
    n = cfg.num_nodes
    xh = jnp.concatenate([x, h], -1)
    u = jax.nn.sigmoid(gcn_layer(params["gu"], xh, src, dst, edge_mask, n))
    r = jax.nn.sigmoid(gcn_layer(params["gr"], xh, src, dst, edge_mask, n))
    xrh = jnp.concatenate([x, r * h], -1)
    c = jnp.tanh(gcn_layer(params["gc"], xrh, src, dst, edge_mask, n))
    h_new = u * h + (1.0 - u) * c
    return h_new, h_new


# ----------------------------------------------------------------------
# Uniform scan-compatible registry
# ----------------------------------------------------------------------
SNAPSHOT_MODELS = ("gcn", "gclstm", "tgcn")


def init_params(name: str, key, cfg: SnapshotConfig):
    """Initialize parameters for snapshot model ``name``."""
    if name == "gcn":
        return gcn_model_init(key, cfg)
    if name == "gclstm":
        return gclstm_init(key, cfg)
    if name == "tgcn":
        return tgcn_init(key, cfg)
    raise ValueError(f"unknown DTDG model {name!r}; have {SNAPSHOT_MODELS}")


def init_state(name: str, cfg: SnapshotConfig):
    """Initial recurrent state: a pytree usable as a ``lax.scan`` carry
    (``()`` for the stateless GCN)."""
    if name == "gcn":
        return ()
    if name == "gclstm":
        return gclstm_state(cfg)
    if name == "tgcn":
        return tgcn_state(cfg)
    raise ValueError(f"unknown DTDG model {name!r}; have {SNAPSHOT_MODELS}")


def make_apply(name: str, cfg: SnapshotConfig):
    """Pure per-snapshot apply fn with the uniform carry signature.

    Returns ``apply(params, src, dst, mask, state) -> (z, new_state)`` where
    ``src/dst/mask`` are one padded snapshot's (capacity,) arrays and
    ``state`` matches ``init_state``. The same function is the body of both
    the per-snapshot jitted step (loop mode) and the scanned epoch, which
    is what makes scan-vs-loop parity exact.
    """
    if name not in SNAPSHOT_MODELS:
        raise ValueError(f"unknown DTDG model {name!r}; have {SNAPSHOT_MODELS}")

    if name == "gcn":

        def apply(params, src, dst, mask, state):
            return gcn_model_apply(params, cfg, src, dst, mask), state

    elif name == "gclstm":

        def apply(params, src, dst, mask, state):
            return gclstm_apply(params, cfg, src, dst, mask, state)

    else:

        def apply(params, src, dst, mask, state):
            return tgcn_apply(params, cfg, src, dst, mask, state)

    return apply


# ----------------------------------------------------------------------
# Shared snapshot padding helper
# ----------------------------------------------------------------------
def pad_snapshot(src, dst, capacity: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pad a host snapshot edge list to ``capacity`` with a validity mask."""
    import numpy as np

    n = len(src)
    if n > capacity:  # sample down, deterministic
        sel = np.linspace(0, n - 1, capacity).astype(np.int64)
        src, dst, n = src[sel], dst[sel], capacity
    mask = np.zeros(capacity, dtype=bool)
    mask[:n] = True
    out_src = np.zeros(capacity, dtype=np.int32)
    out_dst = np.zeros(capacity, dtype=np.int32)
    out_src[:n] = src
    out_dst[:n] = dst
    return out_src, out_dst, mask
