"""Snapshot (DTDG) models: GCN, GCLSTM, T-GCN.

All operate on discretized snapshots produced by iterate-by-time loading
(paper Def. 3.4): a padded COO edge list per snapshot + a learned node
embedding table. Each model maps a snapshot (and its recurrent state, if
any) to per-node embeddings Z in R^{N x d}; link prediction on snapshot
t+1 is decoded from Z computed on snapshots <= t.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.tg.common import link_decoder_init
from repro.nn.graph_conv import gcn, gcn_init, gcn_layer, gcn_layer_init
from repro.nn.linear import dense, dense_init


@dataclasses.dataclass(frozen=True)
class SnapshotConfig:
    num_nodes: int
    d_node: int = 256
    d_embed: int = 128
    num_layers: int = 2


# ----------------------------------------------------------------------
# GCN: snapshot-independent encoder
# ----------------------------------------------------------------------
def gcn_model_init(key, cfg: SnapshotConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    dims = [cfg.d_node] + [cfg.d_embed] * cfg.num_layers
    return {
        "emb": jax.random.normal(k1, (cfg.num_nodes, cfg.d_node)) * 0.02,
        "gcn": gcn_init(k2, dims),
        "decoder": link_decoder_init(k3, cfg.d_embed),
    }


def gcn_model_apply(params, cfg: SnapshotConfig, src, dst, edge_mask):
    return gcn(params["gcn"], params["emb"], src, dst, edge_mask, cfg.num_nodes)


# ----------------------------------------------------------------------
# GCLSTM (Chen et al., 2018): LSTM whose hidden transforms are GCNs
# ----------------------------------------------------------------------
def gclstm_init(key, cfg: SnapshotConfig):
    keys = jax.random.split(key, 11)
    d_in, d_h = cfg.d_node, cfg.d_embed
    p = {
        "emb": jax.random.normal(keys[0], (cfg.num_nodes, d_in)) * 0.02,
        "decoder": link_decoder_init(keys[1], d_h),
    }
    for i, g in enumerate(("i", "f", "o", "g")):
        p[f"w{g}"] = dense_init(keys[2 + 2 * i], d_in, d_h)
        p[f"u{g}"] = gcn_layer_init(keys[3 + 2 * i], d_h, d_h)
    p["out"] = dense_init(keys[10], d_h, d_h)
    return p


def gclstm_state(cfg: SnapshotConfig):
    z = jnp.zeros((cfg.num_nodes, cfg.d_embed))
    return (z, z)


def gclstm_apply(params, cfg: SnapshotConfig, src, dst, edge_mask, state):
    h, c = state
    x = params["emb"]
    n = cfg.num_nodes

    def gate(g, act):
        return act(
            dense(params[f"w{g}"], x)
            + gcn_layer(params[f"u{g}"], h, src, dst, edge_mask, n)
        )

    i = gate("i", jax.nn.sigmoid)
    f = gate("f", jax.nn.sigmoid)
    o = gate("o", jax.nn.sigmoid)
    g = gate("g", jnp.tanh)
    c = f * c + i * g
    h = o * jnp.tanh(c)
    z = dense(params["out"], h)
    return z, (h, c)


# ----------------------------------------------------------------------
# T-GCN (Zhao et al., 2019): GRU whose transforms are GCNs over [X || h]
# ----------------------------------------------------------------------
def tgcn_init(key, cfg: SnapshotConfig):
    keys = jax.random.split(key, 5)
    d_in, d_h = cfg.d_node, cfg.d_embed
    return {
        "emb": jax.random.normal(keys[0], (cfg.num_nodes, d_in)) * 0.02,
        "gu": gcn_layer_init(keys[1], d_in + d_h, d_h),
        "gr": gcn_layer_init(keys[2], d_in + d_h, d_h),
        "gc": gcn_layer_init(keys[3], d_in + d_h, d_h),
        "decoder": link_decoder_init(keys[4], d_h),
    }


def tgcn_state(cfg: SnapshotConfig):
    return jnp.zeros((cfg.num_nodes, cfg.d_embed))


def tgcn_apply(params, cfg: SnapshotConfig, src, dst, edge_mask, h):
    x = params["emb"]
    n = cfg.num_nodes
    xh = jnp.concatenate([x, h], -1)
    u = jax.nn.sigmoid(gcn_layer(params["gu"], xh, src, dst, edge_mask, n))
    r = jax.nn.sigmoid(gcn_layer(params["gr"], xh, src, dst, edge_mask, n))
    xrh = jnp.concatenate([x, r * h], -1)
    c = jnp.tanh(gcn_layer(params["gc"], xrh, src, dst, edge_mask, n))
    h_new = u * h + (1.0 - u) * c
    return h_new, h_new


# ----------------------------------------------------------------------
# Shared snapshot padding helper
# ----------------------------------------------------------------------
def pad_snapshot(src, dst, capacity: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pad a host snapshot edge list to ``capacity`` with a validity mask."""
    import numpy as np

    n = len(src)
    if n > capacity:  # sample down, deterministic
        sel = np.linspace(0, n - 1, capacity).astype(np.int64)
        src, dst, n = src[sel], dst[sel], capacity
    mask = np.zeros(capacity, dtype=bool)
    mask[:n] = True
    out_src = np.zeros(capacity, dtype=np.int32)
    out_dst = np.zeros(capacity, dtype=np.int32)
    out_src[:n] = src
    out_dst[:n] = dst
    return out_src, out_dst, mask
