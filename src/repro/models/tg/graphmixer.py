"""GraphMixer (Cong et al. / Sarıgün 2023): MLP-Mixer over recent neighbors.

Per seed node: tokens are the K most recent interactions, each encoded as
[edge features || *fixed* (non-learnable) time encoding of dt]. Mixer layers
alternate token mixing (across the K axis) and channel mixing. The pooled
token plus a node encoder (mean of 1-hop features) feeds the link decoder.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.tg.common import link_decoder_init, link_logits, node_feature_init, node_features
from repro.nn.linear import dense, dense_init
from repro.nn.mlp import mlp, mlp_init
from repro.nn.norm import layer_norm, layer_norm_init
from repro.nn.time_encode import time_encode, time_encode_init


@dataclasses.dataclass(frozen=True)
class GraphMixerConfig:
    num_nodes: int
    d_edge: int = 0
    d_static: int = 0
    d_model: int = 128
    d_time: int = 100
    num_layers: int = 2
    k: int = 20
    token_expansion: float = 0.5
    channel_expansion: float = 4.0


def init(key, cfg: GraphMixerConfig):
    keys = jax.random.split(key, 4 + 4 * cfg.num_layers)
    d_tok = cfg.d_model
    params = {
        "nodes": node_feature_init(keys[0], cfg.num_nodes, cfg.d_static, cfg.d_model),
        "time": time_encode_init(keys[1], cfg.d_time, learnable=False),
        "tok_proj": dense_init(keys[2], cfg.d_edge + cfg.d_time, d_tok),
        "decoder": link_decoder_init(keys[3], cfg.d_model),
    }
    dt_hidden = max(4, int(cfg.k * cfg.token_expansion))
    dc_hidden = int(d_tok * cfg.channel_expansion)
    for l in range(cfg.num_layers):
        params[f"ln_tok_{l}"] = layer_norm_init(d_tok)
        params[f"mix_tok_{l}"] = mlp_init(keys[4 + 4 * l], [cfg.k, dt_hidden, cfg.k])
        params[f"ln_ch_{l}"] = layer_norm_init(d_tok)
        params[f"mix_ch_{l}"] = mlp_init(keys[5 + 4 * l], [d_tok, dc_hidden, d_tok])
    return params


def embed(params, cfg: GraphMixerConfig, batch, static_feats=None):
    seeds, seed_t = batch["seed_nodes"], batch["seed_times"]
    nbr_ids, nbr_t, nbr_mask = batch["nbr_ids"], batch["nbr_times"], batch["nbr_mask"]

    dt = (seed_t[:, None] - nbr_t).astype(jnp.float32)
    enc = time_encode(params["time"], dt)  # (S, K, d_time)
    if cfg.d_edge and "nbr_feats" in batch:
        tok_in = jnp.concatenate([batch["nbr_feats"], enc], -1)
    else:
        tok_in = enc
    tok = dense(params["tok_proj"], tok_in)  # (S, K, d)
    tok = tok * nbr_mask[..., None]

    for l in range(cfg.num_layers):
        t_ln = layer_norm(params[f"ln_tok_{l}"], tok)
        mixed = mlp(params[f"mix_tok_{l}"], jnp.swapaxes(t_ln, -1, -2),
                    act=jax.nn.gelu)
        tok = tok + jnp.swapaxes(mixed, -1, -2)
        c_ln = layer_norm(params[f"ln_ch_{l}"], tok)
        tok = tok + mlp(params[f"mix_ch_{l}"], c_ln, act=jax.nn.gelu)

    denom = jnp.maximum(nbr_mask.sum(-1, keepdims=True), 1.0)
    pooled = (tok * nbr_mask[..., None]).sum(-2) / denom  # (S, d)

    # Node encoder: own features + mean of neighbor features.
    h_self = node_features(params["nodes"], seeds, static_feats)
    h_nbrs = node_features(params["nodes"], nbr_ids, static_feats)
    h_nbrs = (h_nbrs * nbr_mask[..., None]).sum(-2) / denom
    return pooled + h_self + h_nbrs


def link_scores(params, cfg: GraphMixerConfig, batch, batch_size: int, static_feats=None):
    h = embed(params, cfg, batch, static_feats)
    return link_logits(params["decoder"], h, batch_size)
