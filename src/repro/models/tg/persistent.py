"""Persistent Forecast: predict the most recent observation, unchanged.

For node property prediction, the forecast for node u at time t is the last
observed label vector of u; for link prediction it reduces to EdgeBank with
unlimited memory. Strong baseline per the paper (Tables 4/12).
"""

from __future__ import annotations

import numpy as np


class PersistentForecast:
    def __init__(self, num_nodes: int, label_dim: int):
        self.num_nodes = int(num_nodes)
        self.label_dim = int(label_dim)
        self.reset_state()

    def reset_state(self) -> None:
        self._last = np.zeros((self.num_nodes, self.label_dim), dtype=np.float32)
        self._seen = np.zeros(self.num_nodes, dtype=bool)

    def update(self, nodes: np.ndarray, labels: np.ndarray) -> None:
        self._last[nodes] = labels
        self._seen[nodes] = True

    def predict(self, nodes: np.ndarray) -> np.ndarray:
        return self._last[nodes]
