"""EdgeBank (Poursafaei et al., 2022): non-parametric link-memory baseline.

Unlimited-memory mode: predict 1.0 for any (src, dst) pair observed before
the query time, else 0.0. Implemented with a hashed numpy set for O(1)
batch-vectorized membership tests.
"""

from __future__ import annotations

import numpy as np


class EdgeBank:
    def __init__(self, num_nodes: int, window: int | None = None):
        """``window``: time-window mode (only edges within the trailing
        window count); ``None`` = unlimited memory (paper default)."""
        self.num_nodes = int(num_nodes)
        self.window = window
        self.reset_state()

    def reset_state(self) -> None:
        self._seen: dict[int, int] = {}  # key -> last time seen

    def _key(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        return src.astype(np.int64) * self.num_nodes + dst.astype(np.int64)

    def update(self, src: np.ndarray, dst: np.ndarray, t: np.ndarray) -> None:
        src, dst, t = (np.atleast_1d(np.asarray(a)) for a in (src, dst, t))
        for k, tt in zip(self._key(src, dst).tolist(), t.tolist()):
            self._seen[k] = tt
        # undirected symmetrization (the standard protocol)
        for k, tt in zip(self._key(dst, src).tolist(), t.tolist()):
            self._seen[k] = tt

    # openDG-style online aliases: a live service interleaves single-edge
    # memory updates with link queries, so expose the streaming names too.
    update_memory = update

    def predict(self, src: np.ndarray, dst: np.ndarray, t: np.ndarray) -> np.ndarray:
        src, dst, t = (np.atleast_1d(np.asarray(a)) for a in (src, dst, t))
        keys = self._key(src, dst)
        out = np.zeros(len(keys), dtype=np.float32)
        for i, (k, tt) in enumerate(zip(keys.tolist(), t.tolist())):
            last = self._seen.get(k)
            if last is None:
                continue
            if self.window is None or tt - last <= self.window:
                out[i] = 1.0
        return out

    # Streaming alias of :meth:`predict` (openDG ``EdgeBankPredictor`` API).
    predict_link = predict

    def predict_many(self, src: np.ndarray, dst_many: np.ndarray, t: np.ndarray) -> np.ndarray:
        """One-vs-many scoring: dst_many (B, M) -> (B, M)."""
        B, M = dst_many.shape
        flat_src = np.repeat(src, M)
        flat_t = np.repeat(t, M)
        return self.predict(flat_src, dst_many.reshape(-1), flat_t).reshape(B, M)

    def state_dict(self) -> dict[str, np.ndarray]:
        """Canonical checkpoint payload: sorted (key, last-seen-time) arrays.

        Sorting by key makes the layout independent of insertion order, so
        two banks holding the same memory serialize bit-identically.
        """
        keys = np.fromiter(self._seen.keys(), dtype=np.int64, count=len(self._seen))
        times = np.fromiter(self._seen.values(), dtype=np.int64, count=len(self._seen))
        order = np.argsort(keys, kind="stable")
        return {"keys": keys[order], "times": times[order]}

    def load_state_dict(self, state: dict[str, np.ndarray]) -> None:
        """Inverse of :meth:`state_dict`; replaces the current memory."""
        keys = np.asarray(state["keys"], dtype=np.int64)
        times = np.asarray(state["times"], dtype=np.int64)
        self._seen = dict(zip(keys.tolist(), times.tolist()))
