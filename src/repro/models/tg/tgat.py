"""TGAT (da Xu et al., 2020): temporal graph attention.

Each layer computes a seed embedding by attending over the seed's temporal
neighborhood; keys/values are [neighbor embedding || edge features ||
Bochner time encoding of (t_seed - t_nbr)]. Two layers consume the 2-hop
block produced by the recency/uniform neighbor hook.

With ``device_sampling=True`` the batch additionally carries the resident
packed recency buffer (``nbr_buf``), and ``embed`` can compute the layer-1
attention with ``fused_temporal_layer`` — node-level k/v tables plus
in-kernel time/edge bias folds — so the ``(S, K, H, Dh)`` pre-gathered
neighbor tensors never materialize in HBM (see ``docs/kernels.md``). The
classic pre-gathered path stays the numerical oracle and the non-TPU
default.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.tg.common import (
    all_node_features,
    fused_mode,
    link_decoder_init,
    link_logits,
    node_feature_init,
    node_features,
)
from repro.nn.attention import (
    fused_final_hop_attention,
    fused_seed_neighbor_attention,
    mha_init,
    seed_neighbor_attention,
)
from repro.nn.mlp import mlp, mlp_init
from repro.nn.time_encode import time_encode, time_encode_init


@dataclasses.dataclass(frozen=True)
class TGATConfig:
    num_nodes: int
    d_edge: int = 0
    d_static: int = 0
    d_model: int = 100
    d_time: int = 100
    num_heads: int = 2
    num_layers: int = 2  # 1 or 2
    k: int = 20


def init(key, cfg: TGATConfig):
    keys = jax.random.split(key, 4 + cfg.num_layers * 2)
    d_kv = cfg.d_model + cfg.d_edge + cfg.d_time
    params = {
        "nodes": node_feature_init(keys[0], cfg.num_nodes, cfg.d_static, cfg.d_model),
        "time": time_encode_init(keys[1], cfg.d_time),
        "decoder": link_decoder_init(keys[2], cfg.d_model),
    }
    for l in range(cfg.num_layers):
        params[f"attn_{l}"] = mha_init(
            keys[3 + 2 * l], cfg.d_model + cfg.d_time, d_kv, cfg.d_model, cfg.num_heads
        )
        params[f"merge_{l}"] = mlp_init(
            keys[4 + 2 * l], [cfg.d_model + cfg.d_model, cfg.d_model, cfg.d_model]
        )
    return params


def _layer(params, l, cfg, h_seed, seed_t, h_nbr, nbr_t, nbr_feats, nbr_mask):
    """One TGAT layer. h_seed: (S,d); h_nbr: (S,K,d); returns (S,d)."""
    dt_seed = time_encode(params["time"], jnp.zeros_like(seed_t, jnp.float32))
    q = jnp.concatenate([h_seed, dt_seed], axis=-1)
    dt = (seed_t[:, None] - nbr_t).astype(jnp.float32)
    enc = time_encode(params["time"], dt)
    kv = [h_nbr, enc] if nbr_feats is None else [h_nbr, nbr_feats, enc]
    kv = jnp.concatenate(kv, axis=-1)
    att = seed_neighbor_attention(params[f"attn_{l}"], q, kv, nbr_mask,
                                  num_heads=cfg.num_heads)
    return mlp(params[f"merge_{l}"], jnp.concatenate([att, h_seed], axis=-1))


def _fused_layer0(params, cfg, h_all, h_seed, seeds, seed_t, buf, edge_table,
                  mode, node_axis=None, buf_rows=None):
    """Layer-0 attention for ``seeds`` straight off the packed buffer.

    The kv projection's node term comes from the (N, d_model) table; the
    time-encoding and edge-feature terms are folded in by the fused op, so
    no ``(S, K, ·)`` kv tensor is built here. With ``node_axis``/
    ``buf_rows`` (inside a shard_map over the mesh's node axis) the
    attention runs shard-aware over each shard's local buffer block.
    """
    dt0 = time_encode(params["time"], jnp.zeros_like(seed_t, jnp.float32))
    att = fused_seed_neighbor_attention(
        params["attn_0"], h_all, jnp.concatenate([h_seed, dt0], axis=-1),
        seeds, seed_t, buf, params["time"], d_edge=cfg.d_edge,
        edge_table=edge_table, num_heads=cfg.num_heads, mode=mode,
        node_axis=node_axis, buf_rows=buf_rows,
    )
    return mlp(params["merge_0"], jnp.concatenate([att, h_seed], axis=-1))


def _embed_fused(params, cfg: TGATConfig, batch, static_feats, mode,
                 node_axis=None, buf_rows=None):
    """Device-sampling embed: every attention via the fused kernel family.

    1-layer TGAT runs a single ``fused_temporal_layer`` over the resident
    buffer. 2-layer TGAT additionally embeds the hop-1 frontier through the
    hop-2-aware variant (frontier ids may be -1 padding; each frontier node
    queries the buffer at its own interaction time) and runs the final hop
    through ``fused_final_hop_attention`` — the seeds attend over their
    *computed* frontier embeddings via the per-seed-table variant, so no
    ``(S, K, ·)`` float tensor is built on any hop, forward or backward.
    """
    seeds, seed_t = batch["seed_nodes"], batch["seed_times"]
    buf = batch["nbr_buf"]
    edge_table = batch.get("edge_feat_table") if cfg.d_edge else None
    h_all = all_node_features(params["nodes"], static_feats)  # (N, d_model)
    h_seed = h_all[seeds]
    h1 = _fused_layer0(params, cfg, h_all, h_seed, seeds, seed_t, buf,
                       edge_table, mode, node_axis, buf_rows)
    if cfg.num_layers == 1:
        return h1

    # Hop-1 frontier through layer 0. Padded frontier slots (id -1) pass
    # straight to the hop-2-aware kernel, which emits zero rows for them;
    # only the query-side node features need a clamped gather.
    nbr_ids, nbr_t, nbr_mask = (batch["nbr_ids"], batch["nbr_times"],
                                batch["nbr_mask"])
    f_nodes = nbr_ids.reshape(-1)
    f_t = nbr_t.reshape(-1)
    h_f = jnp.where((f_nodes >= 0)[:, None],
                    h_all[jnp.maximum(f_nodes, 0)], 0.0)
    h_f1 = _fused_layer0(params, cfg, h_all, h_f, f_nodes, f_t, buf,
                         edge_table, mode, node_axis, buf_rows)
    # Final hop: seeds attend over their own K computed frontier rows.
    dt_seed = time_encode(params["time"], jnp.zeros_like(seed_t, jnp.float32))
    att = fused_final_hop_attention(
        params["attn_1"], h_f1, jnp.concatenate([h1, dt_seed], axis=-1),
        seed_t, nbr_t, batch["nbr_eids"], nbr_mask, params["time"],
        d_edge=cfg.d_edge, edge_table=edge_table, num_heads=cfg.num_heads,
        mode=mode,
    )
    return mlp(params["merge_1"], jnp.concatenate([att, h1], axis=-1))


def embed(params, cfg: TGATConfig, batch, static_feats=None, fused=None,
          node_axis=None, buf_rows=None):
    """Embed all S seeds. Uses hop-2 tensors when cfg.num_layers == 2.

    ``fused`` selects the device-sampling fused attention path (see
    ``models.tg.common.fused_mode``): ``None``/"auto" fuses on TPU when the
    batch carries ``nbr_buf``; ``False`` forces the classic pre-gathered
    path; "ref"/"kernel"/"interpret" force a specific fused implementation.
    ``node_axis``/``buf_rows`` engage the shard-aware fused layer when
    called inside a shard_map over a 2-D mesh (``nbr_buf`` then holds each
    shard's local buffer block; see ``docs/sharding.md``).
    """
    mode = fused_mode(fused, batch)
    if mode is not None:
        return _embed_fused(params, cfg, batch, static_feats, mode,
                            node_axis, buf_rows)

    seeds, seed_t = batch["seed_nodes"], batch["seed_times"]
    nbr_ids, nbr_t = batch["nbr_ids"], batch["nbr_times"]
    nbr_mask = batch["nbr_mask"]
    nbr_feats = batch.get("nbr_feats") if cfg.d_edge else None

    h_seed0 = node_features(params["nodes"], seeds, static_feats)
    h_nbr0 = node_features(params["nodes"], nbr_ids, static_feats)

    if cfg.num_layers == 1:
        return _layer(params, 0, cfg, h_seed0, seed_t, h_nbr0, nbr_t, nbr_feats, nbr_mask)

    # Layer 0 embeds the hop-1 frontier using hop-2 neighborhoods.
    S, K = nbr_ids.shape
    f_nodes = nbr_ids.reshape(-1)
    f_t = nbr_t.reshape(-1)
    h_f0 = node_features(params["nodes"], f_nodes, static_feats)
    h_f_nbr0 = node_features(params["nodes"], batch["nbr2_ids"], static_feats)
    f_feats = batch.get("nbr2_feats") if cfg.d_edge else None
    h_f1 = _layer(
        params, 0, cfg, h_f0, f_t, h_f_nbr0, batch["nbr2_times"], f_feats,
        batch["nbr2_mask"],
    )
    # Seeds at layer 0 too (their own hop-1 block).
    h_seed1 = _layer(params, 0, cfg, h_seed0, seed_t, h_nbr0, nbr_t, nbr_feats, nbr_mask)
    # Layer 1: seeds attend over layer-0 embeddings of their hop-1 frontier.
    h_nbr1 = h_f1.reshape(S, K, -1)
    return _layer(params, 1, cfg, h_seed1, seed_t, h_nbr1, nbr_t, nbr_feats, nbr_mask)


def link_scores(params, cfg: TGATConfig, batch, batch_size: int,
                static_feats=None, fused=None, node_axis=None,
                buf_rows=None):
    h = embed(params, cfg, batch, static_feats, fused=fused,
              node_axis=node_axis, buf_rows=buf_rows)
    return link_logits(params["decoder"], h, batch_size)
