from repro.models.tg import (
    common,
    dygformer,
    edgebank,
    graphmixer,
    persistent,
    snapshot,
    tgat,
    tgn,
    tpnet,
)

__all__ = [
    "common",
    "dygformer",
    "edgebank",
    "graphmixer",
    "persistent",
    "snapshot",
    "tgat",
    "tgn",
    "tpnet",
]
