"""Shared pieces for the TG model zoo: link decoders, seed bookkeeping.

Batch tensor convention (from the recency/uniform neighbor hooks), with B =
padded batch size and Nn = negatives per positive:

  seed_nodes : (S,) = [src (B) | dst (B) | neg (B*Nn)]
  nbr_*      : (S, K) neighbor blocks aligned with seed_nodes
  batch_mask : (B,) valid-event mask

Models embed all S seeds and ``split_seeds`` recovers (h_src, h_dst, h_neg).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import dense, dense_init
from repro.nn.mlp import mlp, mlp_init


def split_seeds(h, batch_size: int):
    """h: (S, d) -> (h_src (B,d), h_dst (B,d), h_neg (B,Nn,d) or None)."""
    B = batch_size
    h_src, h_dst = h[:B], h[B : 2 * B]
    rest = h[2 * B :]
    if rest.shape[0] == 0:
        return h_src, h_dst, None
    nn_ = rest.shape[0] // B
    return h_src, h_dst, rest.reshape(B, nn_, -1)


def link_decoder_init(key, d_model: int, hidden: int = 0):
    """Init the 2-layer MLP link decoder over [h_u ; h_v]."""
    hidden = hidden or d_model
    return {"mlp": mlp_init(key, [2 * d_model, hidden, 1])}


def link_decoder(params, h_u, h_v):
    """Pairwise link logit. Broadcasts h_u against extra leading dims of h_v."""
    if h_v.ndim == h_u.ndim + 1:
        h_u = jnp.broadcast_to(h_u[:, None, :], h_v.shape)
    x = jnp.concatenate([h_u, h_v], axis=-1)
    return mlp(params["mlp"], x)[..., 0]


def link_logits(params, h, batch_size: int):
    """Standard positive/negative logits from stacked seed embeddings."""
    h_src, h_dst, h_neg = split_seeds(h, batch_size)
    pos = link_decoder(params, h_src, h_dst)  # (B,)
    neg = None if h_neg is None else link_decoder(params, h_src, h_neg)  # (B, Nn)
    return pos, neg


def bce_link_loss_parts(pos_logits, neg_logits, batch_mask):
    """Masked BCE numerator/denominator before normalization.

    Returns ``(loss_sum, denom)`` so data-sharded training can psum the
    parts over the data axis and normalize by the *global* term count —
    every shard then optimizes ``local_sum / global_denom``, whose psum'd
    gradient is exactly the single-device gradient (the denominator does
    not depend on params)."""
    m = batch_mask.astype(jnp.float32)
    pos_ls = jax.nn.log_sigmoid(pos_logits)
    loss = -(pos_ls * m).sum()
    denom = m.sum()
    if neg_logits is not None:
        neg_ls = jax.nn.log_sigmoid(-neg_logits)
        loss = loss - (neg_ls * m[:, None]).sum()
        denom = denom + (m[:, None] * jnp.ones_like(neg_logits)).sum()
    return loss, denom


def bce_link_loss(pos_logits, neg_logits, batch_mask):
    """Masked binary cross-entropy over positives + negatives."""
    loss, denom = bce_link_loss_parts(pos_logits, neg_logits, batch_mask)
    return loss / jnp.maximum(denom, 1.0)


def node_feature_init(key, num_nodes: int, d_static: int, d_model: int):
    """Learnable node embedding + optional static-feature projection."""
    ke, kp = jax.random.split(key)
    p = {"emb": jax.random.normal(ke, (num_nodes, d_model)) * 0.02}
    if d_static:
        p["static_proj"] = dense_init(kp, d_static, d_model)
    return p


def node_features(params, ids, static_feats=None):
    """Gather per-id node features (learned embedding + optional static
    projection); rows with id < 0 (padding) are zeroed."""
    safe = jnp.maximum(ids, 0)
    h = params["emb"][safe]
    if static_feats is not None and "static_proj" in params:
        h = h + dense(params["static_proj"], static_feats[safe])
    return jnp.where((ids >= 0)[..., None], h, 0.0)


def all_node_features(params, static_feats=None):
    """Every node's feature row at once: (N, d_model).

    The node-level table the fused device-sampling attention gathers from
    (instead of materializing per-seed ``node_features`` copies)."""
    h = params["emb"]
    if static_feats is not None and "static_proj" in params:
        h = h + dense(params["static_proj"], static_feats)
    return h


def fused_mode(fused, batch):
    """Resolve a model's ``fused`` argument against the batch contents.

    Returns ``None`` (use the classic pre-gathered path) or a
    ``fused_temporal_layer`` mode string. ``fused=None``/``"auto"`` engages
    the fused path only when the batch carries the resident buffer
    (``nbr_buf``, produced by ``DeviceRecencyNeighborHook``) *and* the
    backend is TPU — on CPU/GPU the classic jnp path is both the oracle and
    the fastest option, keeping ``device_sampling=True`` bit-identical to
    the host-sampling pipeline there. Explicit values (``True``/"kernel"/
    "interpret"/"ref") force the fused math and require ``nbr_buf``.
    """
    if fused is False:
        return None
    if fused is None or fused == "auto":
        if "nbr_buf" in batch and jax.default_backend() == "tpu":
            return "auto"
        return None
    if "nbr_buf" not in batch:
        raise ValueError(
            "fused temporal attention requires the resident packed buffer "
            "(batch has no 'nbr_buf'): build RECIPE_TGB_LINK with "
            "device_sampling=True and make sure DeviceRecencyNeighborHook "
            "exposes it (expose_buffer=True — the auto default skips GPU, "
            "where the fused kernel has no implementation)"
        )
    return "auto" if fused is True else fused
