"""TPNet (Lu et al., 2024): temporal walk matrices via random feature
propagation with time decay.

Each node u maintains L+1 random-feature vectors R_l[u] approximating the
l-step temporal walk matrix row. On an edge event (u, v, t):

    R_0 is fixed (random gaussian features, never updated)
    for l in 1..L:
        R_l[u] <- exp(-lam * (t - last[u])) * R_l[u] + R_{l-1}[v]
        R_l[v] <- exp(-lam * (t - last[v])) * R_l[v] + R_{l-1}[u]
    last[u] = last[v] = t

The link likelihood for (u, v) is an MLP over the (L+1)^2 matrix of decayed
inner products <R_i[u], R_j[v]>, which approximates counts of temporal walks
of each (i, j) length pair — the paper's relative encoding.

State is functional ({"R": (L+1, N, d), "last": (N,)}) like TGN memory.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.mlp import mlp, mlp_init


@dataclasses.dataclass(frozen=True)
class TPNetConfig:
    num_nodes: int
    d_rp: int = 32  # random-feature dimension (paper: log(2E))
    num_rp_layers: int = 2
    time_decay: float = 1e-6
    d_hidden: int = 64


def init(key, cfg: TPNetConfig):
    k1, k2 = jax.random.split(key)
    L = cfg.num_rp_layers
    return {
        "r0": jax.random.normal(k1, (cfg.num_nodes, cfg.d_rp)) / jnp.sqrt(cfg.d_rp),
        "score": mlp_init(k2, [(L + 1) ** 2, cfg.d_hidden, cfg.d_hidden, 1]),
    }


def init_state(params, cfg: TPNetConfig):
    L = cfg.num_rp_layers
    R = jnp.zeros((L + 1, cfg.num_nodes, cfg.d_rp))
    R = R.at[0].set(params["r0"])
    return {"R": R, "last": jnp.zeros((cfg.num_nodes,), jnp.int32)}


def _decay(cfg, dt):
    return jnp.exp(-cfg.time_decay * jnp.maximum(dt.astype(jnp.float32), 0.0))


def scores_pairwise(params, cfg: TPNetConfig, state, u, v, t):
    """Link logits for node pairs at times t. u: (...,), v: (...,)."""
    R, last = state["R"], state["last"]
    du = _decay(cfg, t - last[u])[..., None]
    dv = _decay(cfg, t - last[v])[..., None]
    Ru = R[:, u, :] * du  # (L+1, ..., d)
    Rv = R[:, v, :] * dv
    inner = jnp.einsum("i...d,j...d->...ij", Ru, Rv)
    # Signed log compression keeps the walk-count features well-scaled
    # (counts grow with degree; raw products destabilize the MLP).
    inner = jnp.sign(inner) * jnp.log1p(jnp.abs(inner))
    feats = inner.reshape(*inner.shape[:-2], -1)
    return mlp(params["score"], feats, act=jax.nn.relu)[..., 0]


def update_state(params, cfg: TPNetConfig, state, src, dst, t, mask=None):
    """Sequential-within-batch approximation: one decay per node per batch
    (events in a batch update in parallel with last-write-wins on ties),
    matching TPNet's batched implementation."""
    R, last = state["R"], state["last"]
    if mask is None:
        mask = jnp.ones_like(src, dtype=bool)
    nodes = jnp.concatenate([src, dst])
    other = jnp.concatenate([dst, src])
    tt = jnp.concatenate([t, t])
    mm = jnp.concatenate([mask, mask]).astype(jnp.float32)

    d_node = _decay(cfg, tt - last[nodes]) * mm  # (2B,)
    new_R = R
    for l in range(1, cfg.num_rp_layers + 1):
        contrib = new_R[l - 1][other] * d_node[:, None] * mm[:, None]
        # scatter-add contributions; decay applied once per touched node
        decayed = new_R[l]
        touched = jax.ops.segment_sum(mm, nodes, cfg.num_nodes) > 0
        dt_node = tt - last[nodes]
        # per-node decay factor: use max dt (first event in batch dominates)
        dec = jax.ops.segment_max(
            jnp.where(mm > 0, _decay(cfg, dt_node), 0.0), nodes, cfg.num_nodes
        )
        base = jnp.where(touched[:, None], decayed * dec[:, None], decayed)
        add = jax.ops.segment_sum(contrib, nodes, cfg.num_nodes)
        new_R = new_R.at[l].set(base + add)

    new_last = last.at[nodes].max(jnp.where(mm > 0, tt, 0).astype(last.dtype))
    return {"R": new_R, "last": new_last}


def link_scores(params, cfg: TPNetConfig, state, batch, batch_size: int):
    """((pos, neg), new_state) from raw batch tensors (no sampling needed)."""
    B = batch_size
    src, dst, t = batch["src"], batch["dst"], batch["time"]
    pos = scores_pairwise(params, cfg, state, src, dst, t)
    neg = None
    if "neg" in batch:
        negs = batch["neg"]  # (B, Nn)
        t_b = jnp.broadcast_to(t[:, None], negs.shape)
        src_b = jnp.broadcast_to(src[:, None], negs.shape)
        neg = scores_pairwise(params, cfg, state, src_b, negs, t_b)
    new_state = update_state(params, cfg, state, src, dst, t, batch.get("batch_mask"))
    return (pos, neg), new_state
