"""DyGFormer (Yu et al., 2023): transformer over first-hop interaction
sequences with neighbor co-occurrence encoding.

For a candidate pair (u, v): take each endpoint's K most recent neighbors
(as ordered sequences), encode per-position features
[node emb || edge feat || time enc || co-occurrence emb], patch, and run a
transformer over the concatenated (2 * K / patch) token sequence; mean-pool
per side for (h_u, h_v).

The co-occurrence encoder counts, for every position in u's sequence, how
often that neighbor appears in u's and in v's sequences (and vice versa) —
computed batched with equality matrices.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.tg.common import link_decoder_init, node_feature_init, node_features
from repro.nn.attention import mha, mha_init
from repro.nn.linear import dense, dense_init
from repro.nn.mlp import mlp, mlp_init
from repro.nn.norm import layer_norm, layer_norm_init
from repro.nn.time_encode import time_encode, time_encode_init


@dataclasses.dataclass(frozen=True)
class DyGFormerConfig:
    num_nodes: int
    d_edge: int = 0
    d_static: int = 0
    d_model: int = 172
    d_time: int = 100
    d_cooc: int = 50
    num_heads: int = 2
    num_layers: int = 2
    k: int = 32
    patch_size: int = 1


def init(key, cfg: DyGFormerConfig):
    keys = jax.random.split(key, 6 + 4 * cfg.num_layers)
    d_feat = cfg.d_model + cfg.d_edge + cfg.d_time + cfg.d_cooc
    d_tok = d_feat * cfg.patch_size
    params = {
        "nodes": node_feature_init(keys[0], cfg.num_nodes, cfg.d_static, cfg.d_model),
        "time": time_encode_init(keys[1], cfg.d_time),
        "cooc": mlp_init(keys[2], [2, cfg.d_cooc, cfg.d_cooc]),
        "patch_proj": dense_init(keys[3], d_tok, cfg.d_model),
        "out_ln": layer_norm_init(cfg.d_model),
        "decoder": link_decoder_init(keys[4], cfg.d_model),
    }
    for l in range(cfg.num_layers):
        params[f"ln1_{l}"] = layer_norm_init(cfg.d_model)
        params[f"attn_{l}"] = mha_init(keys[5 + 4 * l], cfg.d_model, cfg.d_model,
                                       cfg.d_model, cfg.num_heads)
        params[f"ln2_{l}"] = layer_norm_init(cfg.d_model)
        params[f"mlp_{l}"] = mlp_init(keys[6 + 4 * l],
                                      [cfg.d_model, 4 * cfg.d_model, cfg.d_model])
    return params


def _cooc_counts(a_ids, b_ids, a_mask, b_mask):
    """For each position in a: (count in a, count in b). Shapes (P, K)."""
    eq_aa = (a_ids[:, :, None] == a_ids[:, None, :]) & a_mask[:, None, :]
    eq_ab = (a_ids[:, :, None] == b_ids[:, None, :]) & b_mask[:, None, :]
    ca = eq_aa.sum(-1).astype(jnp.float32) * a_mask
    cb = eq_ab.sum(-1).astype(jnp.float32) * a_mask
    return jnp.stack([ca, cb], -1)  # (P, K, 2)


def _side_features(params, cfg, ids, times, feats, mask, t_ref, cooc):
    h = node_features(params["nodes"], ids)  # (P, K, d_model)
    dt = (t_ref[:, None] - times).astype(jnp.float32)
    enc = time_encode(params["time"], dt)
    cooc_emb = mlp(params["cooc"], cooc, act=jax.nn.relu)
    parts = [h, enc, cooc_emb]
    if cfg.d_edge:
        parts.insert(1, feats)
    x = jnp.concatenate(parts, -1) * mask[..., None]
    # Patching: fold patch_size consecutive positions into one token.
    P, K, D = x.shape
    ps = cfg.patch_size
    x = x.reshape(P, K // ps, ps * D)
    return dense(params["patch_proj"], x)  # (P, K/ps, d_model)


def embed_pairs(params, cfg: DyGFormerConfig, u, v):
    """u, v: dicts with ids/times/feats/mask (P, K) + t_ref (P,).

    Returns (h_u, h_v): (P, d_model) each.
    """
    cu = _cooc_counts(u["ids"], v["ids"], u["mask"], v["mask"])
    cv = _cooc_counts(v["ids"], u["ids"], v["mask"], u["mask"])
    xu = _side_features(params, cfg, u["ids"], u["times"], u.get("feats"),
                        u["mask"], u["t_ref"], cu)
    xv = _side_features(params, cfg, v["ids"], v["times"], v.get("feats"),
                        v["mask"], v["t_ref"], cv)
    x = jnp.concatenate([xu, xv], 1)  # (P, 2K/ps, d)

    ps = cfg.patch_size
    tok_mask = jnp.concatenate(
        [u["mask"].reshape(x.shape[0], -1, ps).any(-1),
         v["mask"].reshape(x.shape[0], -1, ps).any(-1)], 1)
    attn_mask = tok_mask[:, None, :] & tok_mask[:, :, None]

    for l in range(cfg.num_layers):
        h = layer_norm(params[f"ln1_{l}"], x)
        x = x + mha(params[f"attn_{l}"], h, h, attn_mask, num_heads=cfg.num_heads)
        h = layer_norm(params[f"ln2_{l}"], x)
        x = x + mlp(params[f"mlp_{l}"], h, act=jax.nn.gelu)
    x = layer_norm(params["out_ln"], x)

    half = x.shape[1] // 2
    mu = tok_mask[:, :half, None].astype(x.dtype)
    mv = tok_mask[:, half:, None].astype(x.dtype)
    h_u = (x[:, :half] * mu).sum(1) / jnp.maximum(mu.sum(1), 1.0)
    h_v = (x[:, half:] * mv).sum(1) / jnp.maximum(mv.sum(1), 1.0)
    return h_u, h_v


def _gather_side(batch, sel, cfg):
    side = {
        "ids": batch["nbr_ids"][sel],
        "times": batch["nbr_times"][sel],
        "mask": batch["nbr_mask"][sel],
        "t_ref": batch["seed_times"][sel],
    }
    if cfg.d_edge and "nbr_feats" in batch:
        side["feats"] = batch["nbr_feats"][sel]
    return side


def link_scores(params, cfg: DyGFormerConfig, batch, batch_size: int):
    """Pos logits (B,) and neg logits (B, Nn) with pair-dependent encoding."""
    from repro.models.tg.common import link_decoder

    B = batch_size
    S = batch["seed_nodes"].shape[0]
    n_neg = (S - 2 * B) // B

    idx_src = jnp.arange(B)
    idx_dst = jnp.arange(B, 2 * B)
    u = _gather_side(batch, idx_src, cfg)
    v = _gather_side(batch, idx_dst, cfg)
    h_u, h_v = embed_pairs(params, cfg, u, v)
    pos = link_decoder(params["decoder"], h_u, h_v)

    neg = None
    if n_neg > 0:
        idx_neg = jnp.arange(2 * B, S)  # (B*Nn,) grouped by negative-column
        # seed layout: neg.reshape(-1) of (B, Nn) -> index (i*Nn + j)? The
        # hook flattens row-major: batch i, negative j at 2B + i*Nn + j.
        u_rep = {k: (jnp.repeat(val, n_neg, axis=0)) for k, val in u.items()}
        w = _gather_side(batch, idx_neg, cfg)
        h_ur, h_w = embed_pairs(params, cfg, u_rep, w)
        neg = link_decoder(params["decoder"], h_ur, h_w).reshape(B, n_neg)
    return pos, neg
