"""TGN (Rossi et al., 2020): memory-based temporal graph network.

Functional formulation: the evolving per-node memory is explicit state
``{"memory": (N, dm), "last_update": (N,)}`` threaded through training —
this makes whole-epoch jit/scan possible and, in the distributed trainer,
turns DistTGL-style memory synchronization into an explicit ``psum``.

Per batch (predict-then-update):
  1. embed seeds with temporal attention over neighbors, node features =
     memory (+ learned embedding),
  2. score links,
  3. build messages [mem_src || mem_dst || phi(dt) || edge_feat] for both
     endpoints, keep each node's *last* message, GRU-update the memory.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.tg.common import (
    all_node_features,
    fused_mode,
    link_decoder_init,
    link_logits,
    node_feature_init,
    node_features,
)
from repro.nn.attention import (
    fused_seed_neighbor_attention,
    mha_init,
    seed_neighbor_attention,
)
from repro.nn.mlp import mlp, mlp_init
from repro.nn.recurrent import gru, gru_init
from repro.nn.time_encode import time_encode, time_encode_init


@dataclasses.dataclass(frozen=True)
class TGNConfig:
    num_nodes: int
    d_edge: int = 0
    d_static: int = 0
    d_model: int = 100
    d_time: int = 100
    d_memory: int = 100
    num_heads: int = 2
    k: int = 10


def init(key, cfg: TGNConfig):
    keys = jax.random.split(key, 6)
    d_msg = 2 * cfg.d_memory + cfg.d_time + cfg.d_edge
    d_kv = cfg.d_memory + cfg.d_model + cfg.d_edge + cfg.d_time
    return {
        "nodes": node_feature_init(keys[0], cfg.num_nodes, cfg.d_static, cfg.d_model),
        "time": time_encode_init(keys[1], cfg.d_time),
        "attn": mha_init(keys[2], cfg.d_memory + cfg.d_model + cfg.d_time, d_kv,
                         cfg.d_model, cfg.num_heads),
        "merge": mlp_init(keys[3], [cfg.d_model + cfg.d_memory + cfg.d_model,
                                    cfg.d_model, cfg.d_model]),
        "gru": gru_init(keys[4], d_msg, cfg.d_memory),
        "decoder": link_decoder_init(keys[5], cfg.d_model),
    }


def init_state(cfg: TGNConfig):
    return {
        "memory": jnp.zeros((cfg.num_nodes, cfg.d_memory)),
        "last_update": jnp.zeros((cfg.num_nodes,), jnp.int32),
    }


def _embed_fused(params, cfg: TGNConfig, state, batch, static_feats, mode,
                 node_axis=None, buf_rows=None):
    """Device-sampling embed: attention over the resident packed buffer.

    The kv input's node-level slice is ``memory ‖ node features`` — both are
    (N, ·) tables — so the whole node term of the k/v projections becomes an
    (N, H, Dh) table; time/edge terms are folded in by the fused op and the
    per-seed (S, K, ·) kv tensors never materialize.
    """
    seeds, seed_t = batch["seed_nodes"], batch["seed_times"]
    buf = batch["nbr_buf"]
    edge_table = batch.get("edge_feat_table") if cfg.d_edge else None
    mem = state["memory"]
    h_all = all_node_features(params["nodes"], static_feats)
    node_kv = jnp.concatenate([mem, h_all], axis=-1)  # (N, d_mem + d_model)
    m_seed = mem[jnp.maximum(seeds, 0)]
    h_seed = h_all[jnp.maximum(seeds, 0)]
    q_in = jnp.concatenate(
        [m_seed, h_seed,
         time_encode(params["time"], jnp.zeros_like(seed_t, jnp.float32))],
        axis=-1)
    att = fused_seed_neighbor_attention(
        params["attn"], node_kv, q_in, seeds, seed_t, buf, params["time"],
        d_edge=cfg.d_edge, edge_table=edge_table, num_heads=cfg.num_heads,
        mode=mode, node_axis=node_axis, buf_rows=buf_rows,
    )
    return mlp(params["merge"], jnp.concatenate([att, m_seed, h_seed], -1))


def embed(params, cfg: TGNConfig, state, batch, static_feats=None, fused=None,
          node_axis=None, buf_rows=None):
    """Temporal-attention embedding of the batch seeds over node memory.

    ``fused`` behaves as in ``tgat.embed`` (see
    ``models.tg.common.fused_mode``); ``node_axis``/``buf_rows`` engage
    the shard-aware fused layer inside a 2-D-mesh shard_map (see
    ``tgat.embed`` / ``docs/sharding.md``).
    """
    mode = fused_mode(fused, batch)
    if mode is not None:
        return _embed_fused(params, cfg, state, batch, static_feats, mode,
                            node_axis, buf_rows)

    seeds, seed_t = batch["seed_nodes"], batch["seed_times"]
    nbr_ids, nbr_t, nbr_mask = batch["nbr_ids"], batch["nbr_times"], batch["nbr_mask"]

    mem = state["memory"]
    h_seed = node_features(params["nodes"], seeds, static_feats)
    m_seed = mem[jnp.maximum(seeds, 0)]
    h_nbr = node_features(params["nodes"], nbr_ids, static_feats)
    m_nbr = jnp.where((nbr_ids >= 0)[..., None], mem[jnp.maximum(nbr_ids, 0)], 0.0)

    q = jnp.concatenate(
        [m_seed, h_seed,
         time_encode(params["time"], jnp.zeros_like(seed_t, jnp.float32))], -1)
    dt = (seed_t[:, None] - nbr_t).astype(jnp.float32)
    kv = [m_nbr, h_nbr, time_encode(params["time"], dt)]
    if cfg.d_edge and "nbr_feats" in batch:
        kv.insert(2, batch["nbr_feats"])
    kv = jnp.concatenate(kv, -1)
    att = seed_neighbor_attention(params["attn"], q, kv, nbr_mask,
                                  num_heads=cfg.num_heads)
    return mlp(params["merge"], jnp.concatenate([att, m_seed, h_seed], -1))


def update_memory(params, cfg: TGNConfig, state, batch):
    """GRU memory update with last-message-per-node aggregation."""
    src, dst, t = batch["src"], batch["dst"], batch["time"]
    mask = batch.get("batch_mask")
    if mask is None:
        mask = jnp.ones_like(src, dtype=bool)
    edge_feats = batch.get("edge_feats")
    B = src.shape[0]
    mem, last = state["memory"], state["last_update"]

    nodes = jnp.concatenate([src, dst])  # (2B,)
    other = jnp.concatenate([dst, src])
    tt = jnp.concatenate([t, t])
    mm = jnp.concatenate([mask, mask])
    dt = (tt - last[nodes]).astype(jnp.float32)
    parts = [mem[nodes], mem[other], time_encode(params["time"], dt)]
    if cfg.d_edge:
        ef = (jnp.zeros((B, cfg.d_edge)) if edge_feats is None else edge_feats)
        parts.append(jnp.concatenate([ef, ef], 0))
    msgs = jnp.concatenate(parts, -1)  # (2B, d_msg)

    # Last message per node: segment_max over event index (later wins).
    idx = jnp.arange(2 * B)
    idx = jnp.where(mm, idx, -1)
    seg_last = jax.ops.segment_max(idx, nodes, cfg.num_nodes)  # (N,)
    touched = seg_last >= 0
    pick = jnp.maximum(seg_last, 0)

    msg_per_node = msgs[pick]  # (N, d_msg)
    new_mem_all = gru(params["gru"], msg_per_node, mem)
    new_mem = jnp.where(touched[:, None], new_mem_all, mem)
    new_last = jnp.where(touched, tt[pick].astype(last.dtype), last)
    return {"memory": new_mem, "last_update": new_last}


def link_scores(params, cfg: TGNConfig, state, batch, batch_size: int,
                static_feats=None, fused=None, node_axis=None,
                buf_rows=None):
    """Returns ((pos, neg), new_state)."""
    h = embed(params, cfg, state, batch, static_feats, fused=fused,
              node_axis=node_axis, buf_rows=buf_rows)
    logits = link_logits(params["decoder"], h, batch_size)
    new_state = update_memory(params, cfg, state, batch)
    return logits, new_state
