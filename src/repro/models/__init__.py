from repro.models import tg

__all__ = ["tg"]
