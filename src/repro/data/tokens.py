"""Deterministic synthetic token pipeline for LM training/smoke tests.

Generates structured (learnable) token streams: a mixture of a Markov chain
over a small state space projected into the vocabulary plus copy motifs, so
a model's loss decreases measurably within a few hundred steps — useful for
end-to-end training validation without external data.
"""

from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def synthetic_token_batches(
    vocab_size: int,
    batch_size: int,
    seq_len: int,
    num_batches: int,
    seed: int = 0,
    num_states: int = 64,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yields (tokens, labels) int32 arrays of shape (B, S); labels are the
    next-token shift of tokens (last label wraps to BOS=0)."""
    rng = np.random.default_rng(seed)
    k = min(num_states, vocab_size)
    # Sparse-ish row-stochastic transition matrix.
    trans = rng.dirichlet(np.full(k, 0.1), size=k)
    cdf = np.cumsum(trans, axis=1)
    proj = rng.integers(0, vocab_size, size=k)  # state -> token id

    for _ in range(num_batches):
        states = rng.integers(0, k, size=batch_size)
        seq = np.empty((batch_size, seq_len + 1), dtype=np.int64)
        u = rng.random((batch_size, seq_len + 1))
        for s in range(seq_len + 1):
            seq[:, s] = proj[states]
            # advance the chain (vectorized inverse-CDF draw)
            states = (cdf[states] < u[:, s : s + 1]).sum(axis=1)
            states = np.minimum(states, k - 1)
        tokens = seq[:, :-1].astype(np.int32)
        labels = seq[:, 1:].astype(np.int32)
        yield tokens, labels
