"""Synthetic temporal-graph generators, statistically matched to the paper's
datasets (Table 13).

The container is offline, so TGB's Wikipedia/Reddit/LastFM/Trade/Genre are
replaced with deterministic generators that match, at configurable scale:

  * bipartite structure (users x items) where applicable,
  * power-law (Zipf) degree distributions on both sides,
  * bursty inter-arrival times (log-normal gaps),
  * duplicate-edge "surprise" rates via per-user preference concentration,
  * per-edge feature dimension (Wikipedia/Reddit: 172-dim LIWC-like),
  * node-event streams (user activity features) to exercise node events.

All generators are seeded and pure (same spec -> same graph).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.graph import DGData


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    name: str
    num_src: int  # users
    num_dst: int  # items/pages (0 => unipartite)
    num_edges: int
    duration_ticks: int  # native-granularity span
    granularity: str = "s"
    edge_feat_dim: int = 0
    node_feat_dim: int = 0
    node_event_rate: float = 0.0  # node events per edge event
    zipf_src: float = 1.3
    zipf_dst: float = 1.5
    repeat_bias: float = 0.7  # prob. of re-drawing from a user's past items
    seed: int = 0


# Scaled-down analogues of Table 13 (full-size is a flag flip; defaults keep
# CPU benchmarks snappy while preserving the distributions).
DATASET_SPECS = {
    "wikipedia": SyntheticSpec(
        "wikipedia", num_src=6000, num_dst=3000, num_edges=157_474,
        duration_ticks=30 * 86400, edge_feat_dim=172, repeat_bias=0.89,
    ),
    "reddit": SyntheticSpec(
        "reddit", num_src=9000, num_dst=2000, num_edges=672_447,
        duration_ticks=30 * 86400, edge_feat_dim=172, repeat_bias=0.93,
    ),
    "lastfm": SyntheticSpec(
        "lastfm", num_src=980, num_dst=1000, num_edges=1_293_103,
        duration_ticks=30 * 86400, edge_feat_dim=0, repeat_bias=0.65,
    ),
    "trade": SyntheticSpec(
        "trade", num_src=255, num_dst=0, num_edges=468_245,
        duration_ticks=32, granularity="y", edge_feat_dim=1, repeat_bias=0.97,
    ),
    "genre": SyntheticSpec(
        "genre", num_src=1400, num_dst=105, num_edges=1_785_839,
        duration_ticks=30 * 86400, edge_feat_dim=1, repeat_bias=0.95,
    ),
    # Tiny spec for unit tests.
    "tiny": SyntheticSpec(
        "tiny", num_src=50, num_dst=30, num_edges=2000,
        duration_ticks=86400, edge_feat_dim=8, node_feat_dim=4,
        node_event_rate=0.1,
    ),
}


def _zipf_probs(n: int, alpha: float) -> np.ndarray:
    p = 1.0 / np.power(np.arange(1, n + 1, dtype=np.float64), alpha)
    return p / p.sum()


def generate(spec: SyntheticSpec | str, scale: float = 1.0,
             seed: Optional[int] = None) -> DGData:
    """Generate a synthetic temporal graph from a spec (or named spec)."""
    if isinstance(spec, str):
        spec = DATASET_SPECS[spec]
    if scale != 1.0:
        spec = dataclasses.replace(
            spec,
            num_edges=max(64, int(spec.num_edges * scale)),
            num_src=max(8, int(spec.num_src * min(1.0, scale * 2))),
            num_dst=max(4, int(spec.num_dst * min(1.0, scale * 2))) if spec.num_dst else 0,
        )
    rng = np.random.default_rng(spec.seed if seed is None else seed)
    E = spec.num_edges
    bipartite = spec.num_dst > 0
    n_src = spec.num_src
    n_dst = spec.num_dst if bipartite else spec.num_src

    # -- timestamps: bursty log-normal inter-arrivals, normalized to span ----
    gaps = rng.lognormal(mean=0.0, sigma=1.5, size=E)
    t = np.cumsum(gaps)
    t = (t / t[-1] * (spec.duration_ticks - 1)).astype(np.int64)

    # -- sources: Zipf over users --------------------------------------------
    src = rng.choice(n_src, size=E, p=_zipf_probs(n_src, spec.zipf_src))

    # -- destinations: mixture of (a) re-draw from the user's own past items
    #    (controls duplicate-edge rate / "surprise") and (b) global Zipf.
    dst_global = rng.choice(n_dst, size=E, p=_zipf_probs(n_dst, spec.zipf_dst))
    # Per-user sticky item: a cheap stand-in for preference concentration —
    # with prob repeat_bias, a user interacts within a small personal pool.
    pool_size = 4
    personal_pools = rng.integers(0, n_dst, size=(n_src, pool_size))
    pick = rng.integers(0, pool_size, size=E)
    dst_personal = personal_pools[src, pick]
    use_personal = rng.random(E) < spec.repeat_bias
    dst = np.where(use_personal, dst_personal, dst_global)

    if bipartite:
        dst = dst + n_src  # offset item ids after user ids
        num_nodes = n_src + n_dst
    else:
        # unipartite (trade-like): avoid self loops
        dst = np.where(dst == src, (dst + 1) % n_src, dst)
        num_nodes = n_src

    edge_feats = None
    if spec.edge_feat_dim:
        # Low-rank structured features + noise (LIWC-like correlation).
        basis = rng.standard_normal((16, spec.edge_feat_dim)).astype(np.float32)
        codes = rng.standard_normal((E, 16)).astype(np.float32) * 0.3
        edge_feats = codes @ basis + 0.05 * rng.standard_normal(
            (E, spec.edge_feat_dim)
        ).astype(np.float32)

    node_ids = node_t = node_feats = None
    if spec.node_event_rate > 0:
        M = int(E * spec.node_event_rate)
        node_ids = rng.integers(0, num_nodes, size=M)
        node_t = np.sort(rng.integers(0, spec.duration_ticks, size=M))
        if spec.node_feat_dim:
            node_feats = rng.standard_normal((M, spec.node_feat_dim)).astype(np.float32)

    static = None
    if spec.node_feat_dim:
        static = rng.standard_normal((num_nodes, spec.node_feat_dim)).astype(np.float32)

    return DGData.from_arrays(
        src, dst, t,
        edge_feats=edge_feats,
        node_ids=node_ids, node_t=node_t, node_feats=node_feats,
        static_node_feats=static,
        granularity=spec.granularity,
        num_nodes=num_nodes,
    )


def dst_pool_of(data: DGData) -> np.ndarray:
    """Destination pool for negative sampling (the observed dst set)."""
    return np.unique(data.dst)
