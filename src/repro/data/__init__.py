from repro.data.synthetic import SyntheticSpec, generate, DATASET_SPECS
from repro.data.tokens import synthetic_token_batches

__all__ = ["SyntheticSpec", "generate", "DATASET_SPECS", "synthetic_token_batches"]
