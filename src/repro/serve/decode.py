"""Serving steps: prefill + single-token decode (greedy/sampled), plus a
small batched generation driver for the examples.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.lm import model as M


def make_prefill_step(cfg: ArchConfig, max_len: Optional[int] = None,
                      kv_block: int = 1024):
    def prefill_step(params, batch):
        return M.prefill(params, cfg, batch, max_len=max_len, kv_block=kv_block)

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def serve_step(params, cache, tokens):
        """tokens: (B,) int32 — the most recent token per sequence."""
        return M.decode_step(params, cfg, cache, tokens)

    return serve_step


def generate(params, cfg: ArchConfig, batch, num_tokens: int,
             temperature: float = 0.0, seed: int = 0, kv_block: int = 256):
    """Greedy/temperature generation for examples + tests."""
    B = batch["tokens"].shape[0]
    S = batch["tokens"].shape[1]
    prefill = jax.jit(make_prefill_step(cfg, max_len=S + num_tokens + 1,
                                        kv_block=kv_block))
    step = jax.jit(make_decode_step(cfg))
    logits, cache = prefill(params, batch)
    key = jax.random.PRNGKey(seed)
    out = []
    tok = None
    for i in range(num_tokens):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature).astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
        logits, cache = step(params, cache, tok)
    return jnp.stack(out, axis=1)  # (B, num_tokens)
