from repro.serve.decode import make_prefill_step, make_decode_step, generate

__all__ = ["make_prefill_step", "make_decode_step", "generate"]
