from repro.serve.decode import make_prefill_step, make_decode_step, generate
from repro.serve.faults import FaultInjector, ModelFault, TransferFault
from repro.serve.graph_service import (OnlineGraphService, PendingResponse,
                                       Response, Status)

__all__ = [
    "make_prefill_step", "make_decode_step", "generate",
    "FaultInjector", "ModelFault", "TransferFault",
    "OnlineGraphService", "PendingResponse", "Response", "Status",
]
