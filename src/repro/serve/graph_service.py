"""Fault-tolerant online serving for temporal graphs.

:class:`OnlineGraphService` turns the training-side CTDG machinery into a
live inference service:

* **Event ingest** — live ``(src, dst, t, eid)`` edge events flow through a
  bounded queue (the ``PrefetchLoader`` backpressure idiom: blocking put,
  stop-aware worker) into the device-resident
  :class:`~repro.core.device_sampler.DeviceRecencySampler` *and* an
  :class:`~repro.models.tg.edgebank.EdgeBank` kept warm as the fallback
  tier. Duplicate events (same eid) are dropped; out-of-order events are
  applied and counted.
* **Deadline-aware microbatching** — ``predict_link`` / ``embed`` requests
  carry a deadline; a batcher thread flushes on size-or-timeout; requests
  already past their deadline at flush time are shed with an explicit
  :attr:`Status.REJECTED` (never silently dropped, never run).
* **Graceful degradation** — a count-based circuit breaker plus an EWMA
  latency estimate route traffic: healthy + under budget → learned model
  (:attr:`Status.OK`); unhealthy or over budget → EdgeBank answers link
  queries (:attr:`Status.DEGRADED`). Every ``probe_every``-th degraded
  flush probes the model so the breaker can close again. Embeddings have
  no non-parametric fallback and fail explicitly while degraded.
* **Crash safety** — :meth:`OnlineGraphService.snapshot` drains in-flight
  events and writes sampler buffers + EdgeBank memory + the event cursor
  through :mod:`repro.distributed.checkpoint`; :meth:`restore` brings a
  fresh process back bit-identical to an uninterrupted one.

All chaos behavior is injectable via
:class:`~repro.serve.faults.FaultInjector` so the failure paths are tested
deterministically, not hoped for.

Pass ``telemetry=`` (a :class:`repro.obs.Telemetry`) to make the service
observable (``docs/observability.md``): per-tier request-latency
histograms (``serve/latency/model`` / ``serve/latency/edgebank``), a
``serve/latency/model_call`` histogram of the raw model-tier call time
feeding the EWMA, ingest/flush/shed/degrade/probe counters, and a
``serve/model_latency_ewma`` gauge. The EWMA itself now lives in
:class:`repro.obs.EwmaGauge` with the exact coefficients
(``0.7 * prev + 0.3 * lat``) the private bookkeeping used, so breaker
decisions are bit-identical with telemetry on, off, or absent.
"""

from __future__ import annotations

import enum
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_sampler import DeviceRecencySampler
from repro.distributed import checkpoint as ckpt
from repro.obs import NULL, EwmaGauge
from repro.models.tg.common import link_decoder, link_decoder_init
from repro.models.tg.edgebank import EdgeBank
from repro.nn.linear import dense, dense_init
from repro.nn.time_encode import time_encode, time_encode_init


class Status(enum.Enum):
    """Outcome of a serving request.

    ``OK``: answered by the learned model. ``DEGRADED``: answered by the
    EdgeBank fallback tier. ``REJECTED``: shed because its deadline passed
    before execution. ``FAILED``: errored with no fallback (embedding while
    degraded, fault with EdgeBank also unavailable, or service shutdown).
    """

    OK = "ok"
    DEGRADED = "degraded"
    REJECTED = "rejected"
    FAILED = "failed"


@dataclass
class Response:
    """Result of a serving request.

    ``tier`` names who answered ("model" or "edgebank"); ``latency_s`` is
    enqueue-to-resolve wall time; ``detail`` carries the error message for
    REJECTED/FAILED responses.
    """

    status: Status
    score: Optional[float] = None
    embedding: Optional[np.ndarray] = None
    tier: Optional[str] = None
    latency_s: float = 0.0
    detail: str = ""


class PendingResponse:
    """Handle for an in-flight request; resolved by the batcher thread."""

    def __init__(self):
        self._ev = threading.Event()
        self._resp: Optional[Response] = None

    def done(self) -> bool:
        """True once a Response has been attached."""
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None) -> Response:
        """Block until resolved (raises TimeoutError after ``timeout``)."""
        if not self._ev.wait(timeout):
            raise TimeoutError("serving request not resolved in time")
        assert self._resp is not None
        return self._resp

    def _resolve(self, resp: Response) -> None:
        self._resp = resp
        self._ev.set()


@dataclass
class _Request:
    kind: str  # "link" | "embed"
    src: int
    dst: int  # unused for embed
    t: int
    deadline: float  # absolute monotonic time; inf = no deadline
    enqueue_t: float
    pending: PendingResponse = field(default_factory=PendingResponse)


def learned_link_params(key, num_nodes: int, d_model: int = 32,
                        time_dim: int = 8) -> dict:
    """Init params for the default learned tier: a node-embedding table, a
    Time2Vec encoder, a neighbor-aggregation projection, and the shared
    2-layer MLP link decoder."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": jax.random.normal(k1, (num_nodes + 1, d_model), jnp.float32) * 0.1,
        "time": time_encode_init(k2, time_dim),
        "proj": dense_init(k3, d_model + time_dim, d_model),
        "dec": link_decoder_init(k4, d_model),
    }


def learned_embed(params, seeds, t, nbr_ids, nbr_times, mask):
    """Embed seeds at query times from their recency neighbor block:
    node embedding + tanh-projected mean of [neighbor embedding ; Time2Vec
    of the time gap], masked to valid neighbors. Row-wise (batch-size
    independent), which is what makes serving results reproducible across
    different microbatch compositions."""
    base = params["embed"][seeds]
    ids = jnp.where(mask, nbr_ids, 0)
    dt = jnp.where(mask, t[:, None] - nbr_times, 0)
    nh = jnp.concatenate(
        [params["embed"][ids], time_encode(params["time"], dt)], axis=-1)
    nh = nh * mask[:, :, None].astype(nh.dtype)
    agg = nh.sum(axis=1) / jnp.maximum(mask.sum(axis=1, keepdims=True), 1)
    return base + jnp.tanh(dense(params["proj"], agg))


@jax.jit
def _link_scores(params, seeds, t, nbr_ids, nbr_times, mask):
    h = learned_embed(params, seeds, t, nbr_ids, nbr_times, mask)
    B = seeds.shape[0] // 2
    logit = link_decoder(params["dec"], h[:B], h[B:])
    return jax.nn.sigmoid(logit)


_embed_jit = jax.jit(learned_embed)

_STOP = object()


class OnlineGraphService:
    """Live temporal-graph inference with deadline-aware microbatching,
    EdgeBank graceful degradation, and crash-safe snapshots.

    Two daemon threads run per service: an ingest worker applying events
    from a bounded queue to the sampler + EdgeBank, and a batcher flushing
    the request queue on size-or-timeout. ``stop()`` (or exiting the
    context manager) shuts both down and fails outstanding requests rather
    than leaving callers blocked.
    """

    def __init__(self, num_nodes: int, k: int = 8, *,
                 seed: int = 0,
                 model_fn: Optional[Callable] = None,
                 embed_fn: Optional[Callable] = None,
                 max_batch: int = 32,
                 flush_interval: float = 0.005,
                 queue_depth: int = 256,
                 latency_budget: Optional[float] = None,
                 fail_threshold: int = 3,
                 probe_every: int = 8,
                 edgebank_window: Optional[int] = None,
                 fault_injector=None,
                 telemetry=None):
        """``model_fn``/``embed_fn`` override the learned tier (signature of
        :func:`_link_scores` / :func:`learned_embed` minus ``params``);
        ``latency_budget`` (seconds) bounds the EWMA model latency before
        degrading; ``fail_threshold`` consecutive model faults open the
        circuit breaker; every ``probe_every``-th degraded flush probes the
        model to let it close. ``telemetry`` (a ``repro.obs.Telemetry``)
        enables the counters/histograms in the module docstring — the
        no-sink default records nothing and changes no behavior."""
        self.num_nodes = int(num_nodes)
        self.k = int(k)
        self.max_batch = int(max_batch)
        self.flush_interval = float(flush_interval)
        self.latency_budget = latency_budget
        self.fail_threshold = int(fail_threshold)
        self.probe_every = max(1, int(probe_every))
        self.telemetry = telemetry if telemetry is not None else NULL

        self.sampler = DeviceRecencySampler(self.num_nodes, self.k)
        self.edgebank = EdgeBank(self.num_nodes, window=edgebank_window)
        self.params = learned_link_params(jax.random.PRNGKey(seed),
                                          self.num_nodes)
        score = model_fn or (lambda *a: _link_scores(self.params, *a))
        embed = embed_fn or (lambda *a: _embed_jit(self.params, *a))
        transfer = lambda x: np.ascontiguousarray(x)  # noqa: E731
        if fault_injector is not None:
            score = fault_injector.wrap_model(score)
            embed = fault_injector.wrap_model(embed)
            transfer = fault_injector.wrap_transfer(transfer)
        self._score_fn, self._embed_fn, self._transfer = score, embed, transfer

        self._state_lock = threading.Lock()
        self._applied: set[int] = set()
        self._last_t = -(2 ** 62)
        self._event_cursor = 0  # events applied (post-dedup)
        self.stats = {"ok": 0, "degraded": 0, "rejected": 0, "failed": 0,
                      "events_applied": 0, "events_deduped": 0,
                      "events_out_of_order": 0, "model_errors": 0,
                      "probes": 0}

        # Model-tier latency EWMA: the same float sequence the private
        # bookkeeping produced (decay/alpha = 0.7/0.3, first sample passes
        # through), now readable as a telemetry gauge too.
        self._lat = EwmaGauge(alpha=0.3, decay=0.7)
        self._failures = 0
        self._degraded_flushes = 0

        self._evq: queue.Queue = queue.Queue(maxsize=int(queue_depth))
        self._reqq: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._ingest_thread = threading.Thread(
            target=self._ingest_loop, daemon=True, name="ogs-ingest")
        self._batch_thread = threading.Thread(
            target=self._batch_loop, daemon=True, name="ogs-batch")
        self._ingest_thread.start()
        self._batch_thread.start()

    # ------------------------------------------------------------- ingest

    def ingest(self, src: int, dst: int, t: int, eid: int = -1) -> None:
        """Enqueue one live edge event (blocking put = backpressure: a
        producer outrunning the ingest worker stalls instead of ballooning
        memory, mirroring ``PrefetchLoader``)."""
        self._check_alive()
        self._evq.put(("ev", (int(src), int(dst), int(t), int(eid))))

    def ingest_many(self, events: Iterable[Sequence[int]]) -> None:
        """Enqueue a sequence of ``(src, dst, t, eid)`` events in order."""
        for ev in events:
            self.ingest(*ev)

    def drain(self) -> None:
        """Block until every event enqueued so far has been applied.

        The sequencing barrier for read-your-writes tests and for
        :meth:`snapshot` (the event cursor must be quiescent to be
        meaningful)."""
        self._check_alive()
        barrier = threading.Event()
        self._evq.put(("barrier", barrier))
        if not barrier.wait(timeout=60):
            raise RuntimeError("ingest drain timed out")

    def _ingest_loop(self) -> None:
        while True:
            item = self._evq.get()
            if item is _STOP:
                return
            kind, payload = item
            if kind == "barrier":
                payload.set()
                continue
            src, dst, t, eid = payload
            if eid >= 0 and eid in self._applied:
                self.stats["events_deduped"] += 1
                self.telemetry.count("serve/events_deduped")
                continue
            if t < self._last_t:
                self.stats["events_out_of_order"] += 1
                self.telemetry.count("serve/events_out_of_order")
            self._last_t = max(self._last_t, t)
            if eid >= 0:
                self._applied.add(eid)
            with self._state_lock:
                self.sampler.update(np.array([src]), np.array([dst]),
                                    np.array([t]), np.array([eid]))
                self.edgebank.update_memory(src, dst, t)
            self._event_cursor += 1
            self.stats["events_applied"] += 1
            self.telemetry.count("serve/events_applied")

    # ------------------------------------------------------------ serving

    def submit_link(self, src: int, dst: int, t: int,
                    timeout: Optional[float] = None) -> PendingResponse:
        """Queue a link prediction; ``timeout`` (seconds) sets the deadline
        after which the request is shed as REJECTED instead of executed."""
        return self._submit("link", src, dst, t, timeout)

    def submit_embed(self, node: int, t: int,
                     timeout: Optional[float] = None) -> PendingResponse:
        """Queue an embedding request (learned tier only — no fallback)."""
        return self._submit("embed", node, node, t, timeout)

    def predict_link(self, src: int, dst: int, t: int,
                     timeout: Optional[float] = None) -> Response:
        """Synchronous :meth:`submit_link`: blocks until resolved."""
        return self.submit_link(src, dst, t, timeout).result(
            None if timeout is None else timeout + 10.0)

    def embed(self, node: int, t: int,
              timeout: Optional[float] = None) -> Response:
        """Synchronous :meth:`submit_embed`: blocks until resolved."""
        return self.submit_embed(node, t, timeout).result(
            None if timeout is None else timeout + 10.0)

    def _submit(self, kind, src, dst, t, timeout) -> PendingResponse:
        self._check_alive()
        now = time.monotonic()
        deadline = float("inf") if timeout is None else now + timeout
        req = _Request(kind, int(src), int(dst), int(t), deadline, now)
        self._reqq.put(req)
        return req.pending

    def _batch_loop(self) -> None:
        pending: list[_Request] = []
        while True:
            if pending:
                wait = (pending[0].enqueue_t + self.flush_interval
                        - time.monotonic())
            else:
                wait = 0.05
            item = None
            if wait > 0:
                try:
                    item = self._reqq.get(timeout=wait)
                except queue.Empty:
                    pass
            else:
                try:
                    item = self._reqq.get_nowait()
                except queue.Empty:
                    pass
            if item is _STOP:
                break
            if item is not None:
                pending.append(item)
            if pending and (len(pending) >= self.max_batch
                            or time.monotonic() - pending[0].enqueue_t
                            >= self.flush_interval):
                batch, pending = pending[:self.max_batch], pending[self.max_batch:]
                try:
                    self._flush(batch)
                except BaseException as e:  # never let the batcher die
                    for r in batch:
                        if not r.pending.done():
                            self._resolve(r, Response(
                                Status.FAILED, detail=f"flush error: {e!r}"))
        # shutdown: fail everything still queued or held
        leftovers = pending
        while True:
            try:
                item = self._reqq.get_nowait()
            except queue.Empty:
                break
            if item is not _STOP:
                leftovers.append(item)
        for r in leftovers:
            self._resolve(r, Response(Status.FAILED, detail="service stopped"))

    def _resolve(self, req: _Request, resp: Response) -> None:
        resp.latency_s = time.monotonic() - req.enqueue_t
        self.stats[resp.status.value] += 1
        tel = self.telemetry
        if tel.enabled:
            tel.count(f"serve/requests_{resp.status.value}")
            if resp.tier is not None:
                # Per-tier enqueue-to-resolve latency distribution.
                tel.observe(f"serve/latency/{resp.tier}", resp.latency_s)
        req.pending._resolve(resp)

    def _choose_tier(self) -> str:
        if self._failures >= self.fail_threshold or self._over_budget():
            self._degraded_flushes += 1
            self.telemetry.count("serve/degraded_flushes")
            if self._degraded_flushes % self.probe_every == 0:
                self.stats["probes"] += 1
                self.telemetry.count("serve/probes")
                return "model"  # probe so the breaker can close
            return "edgebank"
        return "model"

    def _over_budget(self) -> bool:
        return (self.latency_budget is not None
                and self._lat.value is not None
                and self._lat.value > self.latency_budget)

    def _flush(self, batch: list[_Request]) -> None:
        self.telemetry.count("serve/flushes")
        now = time.monotonic()
        live = []
        for r in batch:
            if now > r.deadline:
                self.telemetry.count("serve/shed")
                self._resolve(r, Response(Status.REJECTED,
                                          detail="deadline exceeded"))
            else:
                live.append(r)
        if not live:
            return
        links = [r for r in live if r.kind == "link"]
        embeds = [r for r in live if r.kind == "embed"]
        tier = self._choose_tier()

        if embeds:
            if tier == "model":
                try:
                    embs = self._run_embeds(embeds)
                    for r, e in zip(embeds, embs):
                        self._resolve(r, Response(Status.OK, embedding=e,
                                                  tier="model"))
                    self._failures = 0
                except Exception as e:
                    self._record_failure()
                    for r in embeds:
                        self._resolve(r, Response(
                            Status.FAILED, detail=f"model error: {e!r}"))
            else:
                for r in embeds:
                    self._resolve(r, Response(
                        Status.FAILED,
                        detail="degraded: no fallback tier for embeddings"))
        if not links:
            return

        if tier == "model":
            try:
                scores = self._run_links(links)
                for r, s in zip(links, scores):
                    self._resolve(r, Response(Status.OK, score=float(s),
                                              tier="model"))
                self._failures = 0
                return
            except Exception:
                self._record_failure()
                tier = "edgebank"  # fall through to the warm tier
        src = np.array([r.src for r in links], np.int64)
        dst = np.array([r.dst for r in links], np.int64)
        t = np.array([r.t for r in links], np.int64)
        with self._state_lock:
            scores = self.edgebank.predict_link(src, dst, t)
        for r, s in zip(links, scores):
            self._resolve(r, Response(Status.DEGRADED, score=float(s),
                                      tier="edgebank"))

    def _record_failure(self) -> None:
        self._failures += 1
        self.stats["model_errors"] += 1
        self.telemetry.count("serve/model_errors")

    def _run_links(self, links: list[_Request]) -> np.ndarray:
        B = len(links)
        seeds = self._transfer(np.array(
            [r.src for r in links] + [r.dst for r in links], np.int32))
        t = self._transfer(np.array([r.t for r in links] * 2, np.int32))
        t0 = time.perf_counter()
        with self._state_lock:
            blk = self.sampler.sample(seeds, query_t=t)
        scores = np.asarray(jax.device_get(self._score_fn(
            seeds, jnp.asarray(t), blk.nbr_ids, blk.nbr_times, blk.mask)))
        assert scores.shape == (B,)
        self._observe_latency(time.perf_counter() - t0)
        return scores

    def _run_embeds(self, embeds: list[_Request]) -> list[np.ndarray]:
        seeds = self._transfer(np.array([r.src for r in embeds], np.int32))
        t = self._transfer(np.array([r.t for r in embeds], np.int32))
        t0 = time.perf_counter()
        with self._state_lock:
            blk = self.sampler.sample(seeds, query_t=t)
        h = np.asarray(jax.device_get(self._embed_fn(
            seeds, jnp.asarray(t), blk.nbr_ids, blk.nbr_times, blk.mask)))
        self._observe_latency(time.perf_counter() - t0)
        return [h[i] for i in range(h.shape[0])]

    def _observe_latency(self, lat: float) -> None:
        ewma = self._lat.update(lat)
        tel = self.telemetry
        if tel.enabled:
            tel.observe("serve/latency/model_call", lat)
            tel.gauge("serve/model_latency_ewma", ewma)

    # --------------------------------------------------------- durability

    def snapshot(self, ckpt_dir: str, step: int = 0) -> None:
        """Crash-safe snapshot: drain in-flight events, then write sampler
        buffers + EdgeBank memory + the event cursor atomically through
        :mod:`repro.distributed.checkpoint`."""
        self.drain()
        with self._state_lock:
            applied = np.fromiter(sorted(self._applied), dtype=np.int64,
                                  count=len(self._applied))
            payload = {
                "sampler": self.sampler.state_dict(),
                "edgebank": self.edgebank.state_dict(),
                "cursor": {
                    "applied_eids": applied,
                    "last_t": np.asarray(self._last_t, np.int64),
                    "event_cursor": np.asarray(self._event_cursor, np.int64),
                },
            }
        ckpt.save(ckpt_dir, step, payload)

    def restore(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        """Load a :meth:`snapshot` back into this service (inverse of
        snapshot; returns the restored step). The learned tier's params are
        re-derived from ``seed``, so sampler + EdgeBank + cursor are the
        full mutable state and a restored service answers bit-identically
        to one that never died."""
        flat, got_step, _ = ckpt.restore(ckpt_dir, target=None, step=step)
        groups: dict[str, dict] = {}
        for k, v in flat.items():
            g, name = k.split("/", 1)
            groups.setdefault(g, {})[name] = v
        with self._state_lock:
            self.sampler.load_state_dict(groups["sampler"])
            self.edgebank.load_state_dict(groups["edgebank"])
            cur = groups["cursor"]
            self._applied = set(np.asarray(cur["applied_eids"]).tolist())
            self._last_t = int(cur["last_t"])
            self._event_cursor = int(cur["event_cursor"])
        return got_step

    # ---------------------------------------------------------- lifecycle

    def _check_alive(self) -> None:
        if self._stop.is_set():
            raise RuntimeError("OnlineGraphService is stopped")

    def stop(self) -> None:
        """Idempotent shutdown: stop both workers and fail any outstanding
        requests (callers blocked in ``result()`` wake with FAILED rather
        than deadlocking)."""
        if self._stop.is_set():
            return
        self._stop.set()
        self._evq.put(_STOP)
        self._reqq.put(_STOP)
        self._ingest_thread.join(timeout=10)
        self._batch_thread.join(timeout=10)

    def __enter__(self):
        """Context-manager entry (service threads already run)."""
        return self

    def __exit__(self, *exc):
        """Context-manager exit: :meth:`stop`."""
        self.stop()
        return False
