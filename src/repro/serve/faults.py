"""Deterministic fault injection for the online serving path.

A :class:`FaultInjector` perturbs the two places a live temporal-graph
service actually fails in production:

* the **event stream** — dropped, duplicated, and out-of-order updates
  (:meth:`FaultInjector.perturb_events`), and
* the **model path** — slow steps, raised model errors, and host<->device
  transfer errors (:meth:`FaultInjector.wrap_model` /
  :meth:`FaultInjector.wrap_transfer`).

Everything is driven by a seeded ``np.random.default_rng`` so chaos tests
are reproducible: the same seed yields the same fault schedule, which lets
tests assert exact shed/degrade behavior instead of flaky approximations.
"""

from __future__ import annotations

import time
from typing import Callable, Sequence

import numpy as np


class ModelFault(RuntimeError):
    """Raised by a fault-wrapped model step to simulate a model failure."""


class TransferFault(RuntimeError):
    """Raised by a fault-wrapped transfer to simulate a host<->device error."""


class FaultInjector:
    """Seeded chaos source for :class:`~repro.serve.graph_service.OnlineGraphService`.

    Probabilities are per-event (stream faults) or per-call (model faults);
    all default to 0 so an injector with no arguments is a no-op.
    """

    def __init__(self, seed: int = 0, *, drop_p: float = 0.0, dup_p: float = 0.0,
                 reorder_p: float = 0.0, reorder_span: int = 4,
                 slow_p: float = 0.0, slow_s: float = 0.05,
                 fail_p: float = 0.0, transfer_fail_p: float = 0.0):
        self.rng = np.random.default_rng(seed)
        self.drop_p = drop_p
        self.dup_p = dup_p
        self.reorder_p = reorder_p
        self.reorder_span = max(1, int(reorder_span))
        self.slow_p = slow_p
        self.slow_s = slow_s
        self.fail_p = fail_p
        self.transfer_fail_p = transfer_fail_p
        self.stats = {"dropped": 0, "duplicated": 0, "reordered": 0,
                      "slow_steps": 0, "model_faults": 0, "transfer_faults": 0}

    def perturb_events(self, events: Sequence[tuple]) -> list[tuple]:
        """Apply drop/duplicate/reorder faults to an event sequence.

        Events are opaque tuples (the service uses ``(src, dst, t, eid)``).
        Duplicates re-emit the same tuple (same eid — a retry, not a new
        edge); reordering swaps an event with one up to ``reorder_span``
        positions later.
        """
        out: list[tuple] = []
        for ev in events:
            if self.drop_p and self.rng.random() < self.drop_p:
                self.stats["dropped"] += 1
                continue
            out.append(ev)
            if self.dup_p and self.rng.random() < self.dup_p:
                self.stats["duplicated"] += 1
                out.append(ev)
        if self.reorder_p:
            i = 0
            while i < len(out) - 1:
                if self.rng.random() < self.reorder_p:
                    j = min(len(out) - 1,
                            i + 1 + int(self.rng.integers(self.reorder_span)))
                    out[i], out[j] = out[j], out[i]
                    self.stats["reordered"] += 1
                i += 1
        return out

    def wrap_model(self, fn: Callable) -> Callable:
        """Wrap a model step: sleeps ``slow_s`` with prob ``slow_p``, raises
        :class:`ModelFault` with prob ``fail_p``, else calls through."""

        def wrapped(*args, **kwargs):
            if self.slow_p and self.rng.random() < self.slow_p:
                self.stats["slow_steps"] += 1
                time.sleep(self.slow_s)
            if self.fail_p and self.rng.random() < self.fail_p:
                self.stats["model_faults"] += 1
                raise ModelFault("injected model fault")
            return fn(*args, **kwargs)

        return wrapped

    def wrap_transfer(self, fn: Callable) -> Callable:
        """Wrap a host<->device transfer: raises :class:`TransferFault` with
        prob ``transfer_fail_p``, else calls through."""

        def wrapped(*args, **kwargs):
            if self.transfer_fail_p and self.rng.random() < self.transfer_fail_p:
                self.stats["transfer_faults"] += 1
                raise TransferFault("injected transfer fault")
            return fn(*args, **kwargs)

        return wrapped
