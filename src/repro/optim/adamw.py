"""AdamW in pure JAX (pytree-structured, shardable).

The optimizer state mirrors the parameter pytree, so GSPMD shards moments
exactly like parameters (ZeRO-style when params are FSDP-sharded). Moments
are stored in f32 even for bf16 params (mixed-precision master statistics).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


def adamw_init(params):
    def zeros_f32(p):
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "mu": jax.tree.map(zeros_f32, params),
        "nu": jax.tree.map(zeros_f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params,
    grads,
    state,
    cfg: AdamWConfig,
    lr_scale=1.0,
) -> Tuple[Any, Any]:
    """Returns (new_params, new_state). ``lr_scale`` multiplies cfg.lr (use a
    schedule value)."""
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1.0 - b1) * g32
        nu = b2 * nu + (1.0 - b2) * (g32 * g32)
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}
