"""Learning-rate schedules (return multiplicative scales for AdamWConfig.lr)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(step):
    return jnp.ones_like(jnp.asarray(step, jnp.float32))


def warmup_cosine(step, warmup_steps: int, total_steps: int, min_scale: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = s / jnp.maximum(warmup_steps, 1)
    frac = (s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = min_scale + (1.0 - min_scale) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(s < warmup_steps, warm, cos)
