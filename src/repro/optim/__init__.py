from repro.optim.adamw import adamw_init, adamw_update, AdamWConfig
from repro.optim.schedule import warmup_cosine, constant
from repro.optim.clip import clip_by_global_norm, global_norm

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "warmup_cosine",
    "constant",
    "clip_by_global_norm",
    "global_norm",
]
