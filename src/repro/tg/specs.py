"""Typed, serializable experiment specs — the declarative half of
``repro.tg`` (paper §4: "a single library that unifies CTDG and DTDG
methods with native link-, node-, and graph-level task support").

Each spec is a frozen dataclass answering one question:

  ``DataSpec``    — *what stream*: dataset + chronological splits + the
                    optional ``TimeDelta`` discretization axis. The axis is
                    the CTDG/DTDG switch: ``None`` keeps the event stream
                    (event-iterated pipelines), a granularity tensorizes it
                    into snapshots (scan-compiled pipelines).
  ``SamplerSpec`` — *what neighborhoods*: recency/uniform × host/device ×
                    hops × checkpoint policy. Replaces the kwarg sprawl
                    that used to ride the trainers and recipe factories
                    (``device_sampling=``, ``sampler=``, ``expose_buffer=``,
                    ``checkpoint_adjacency=`` …).
  ``ModelSpec``   — *what model*: a zoo name plus its config kwargs.
  ``TrainSpec``   — *how to train*: optimizer, epochs, eval cadence,
                    checkpoint cadence, scan-vs-loop mode.

Every spec round-trips through ``to_dict``/``from_dict`` with plain-JSON
leaves, so a whole experiment is reproducible from a single JSON blob
(``tg.Experiment.to_json``). See ``docs/experiment.md`` for the full
reference and the migration table from legacy trainer kwargs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional

from repro.core.granularity import TimeDelta


def timedelta_to_dict(td: Optional[TimeDelta]) -> Optional[Dict[str, Any]]:
    """JSON-serializable form of a ``TimeDelta`` (``None`` passes through)."""
    if td is None:
        return None
    return {"unit": td.unit, "value": td.value}


def timedelta_from_dict(d) -> Optional[TimeDelta]:
    """Inverse of ``timedelta_to_dict``; also accepts unit strings like
    ``"h"`` (the ``TimeDelta.coerce`` shorthand) and ``TimeDelta`` values."""
    if d is None or isinstance(d, TimeDelta):
        return d
    if isinstance(d, str):
        return TimeDelta.coerce(d)
    return TimeDelta(d["unit"], int(d.get("value", 1)))


class _SpecBase:
    """Shared ``to_dict``/``from_dict`` plumbing for flat spec dataclasses
    (fields with plain-JSON values; subclasses override for special
    fields)."""

    def to_dict(self) -> Dict[str, Any]:
        """Plain-JSON dict of this spec's fields."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]):
        """Rebuild a spec from ``to_dict`` output (unknown keys rejected)."""
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(f"{cls.__name__}: unknown spec keys {sorted(unknown)}")
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class DataSpec(_SpecBase):
    """Dataset + chronological splits + the discretization axis.

    ``dataset``/``scale`` name a ``repro.data.generate`` stream (ignored
    when a pre-built ``DGData`` is passed to ``Experiment.compile``).
    ``discretization`` is the CTDG/DTDG switch: ``None`` keeps the native
    event stream; a ``TimeDelta`` (or unit string like ``"h"``) tensorizes
    it into fixed-capacity snapshots (``capacity`` overrides the automatic
    max-row power-of-two sizing). ``val_ratio``/``test_ratio`` are the
    ``DGData.split`` chronological boundaries shared by every task.

    ``storage`` points at an on-disk ``repro.storage.MmapStore`` directory
    (``docs/storage.md``). When set, ``Experiment.compile`` opens the store
    instead of generating ``dataset``, backs the event stream with its
    memory-mapped columns, and runs the pipelines out-of-core: uniform
    adjacency built by the streaming two-pass CSR, loader pages released
    after every batch. Results are bit-identical to the in-memory run.
    """

    dataset: str = "wikipedia"
    scale: float = 1.0
    val_ratio: float = 0.15
    test_ratio: float = 0.15
    discretization: Optional[TimeDelta] = None
    capacity: Optional[int] = None
    storage: Optional[str] = None

    def __post_init__(self):
        if self.discretization is not None and not isinstance(
            self.discretization, TimeDelta
        ):
            object.__setattr__(
                self, "discretization", TimeDelta.coerce(self.discretization)
            )

    def to_dict(self) -> Dict[str, Any]:
        """Plain-JSON dict (the ``TimeDelta`` axis as ``{unit, value}``)."""
        d = dataclasses.asdict(self)
        d["discretization"] = timedelta_to_dict(self.discretization)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "DataSpec":
        """Rebuild from ``to_dict`` output (axis dict/str/None accepted)."""
        d = dict(d)
        d["discretization"] = timedelta_from_dict(d.get("discretization"))
        return super().from_dict(d)


@dataclasses.dataclass(frozen=True)
class SamplerSpec(_SpecBase):
    """Temporal-neighbor sampling strategy for event-stream pipelines.

    ``kind``: ``"recency"`` (K most recent, circular buffers) or
    ``"uniform"`` (K uniform draws from the strict past, CSR-by-time).
    ``device=True`` selects the device-resident twin of either sampler
    (state on the accelerator, jitted update/sample — same outputs and
    checkpoint contract). ``num_hops=None`` lets the pipeline derive the
    hop count from the model depth. ``checkpoint_adjacency=False`` keeps
    the uniform samplers' O(E) CSR out of checkpoints (counter-only;
    rebuilt from storage on restore). ``expose_buffer`` forwards to
    ``DeviceRecencyNeighborHook`` (``None`` = backend auto) and
    ``prefetch`` is the ``PrefetchLoader`` queue depth used when
    ``device=True``. DTDG scan pipelines need no sampler — snapshots are
    consumed whole — so link/node snapshot experiments ignore this spec.

    ``shards`` is the node sharding axis (``docs/sharding.md``): ``None``
    keeps today's single-device state; an integer N shards the device
    samplers' state row-wise by node id over the mesh's node axis (a 1-D
    mesh of the first N devices by default, or the node axis of the 2-D
    ``(data, nodes)`` mesh when ``TrainSpec.data_shards > 1``), with
    batches placed mesh-replicated and update/sample routed through
    ``shard_map`` — same outputs, state scales past one device's HBM.
    Requires ``device=True``; checkpoints stay canonical, so runs reshard
    freely across different ``shards``. ``expose_buffer=True`` with
    ``shards`` carries each shard's local buffer block on the batch for
    the shard-aware fused attention path. ``partition`` picks the uniform
    sampler's CSR node-boundary split: ``"rows"`` (equal node counts, the
    default) or ``"degree"`` (cumulative-degree quantile cuts — smaller
    per-shard CSR padding on skewed graphs; draws are identical either
    way).
    """

    kind: str = "recency"
    k: int = 20
    num_hops: Optional[int] = None
    device: bool = False
    checkpoint_adjacency: bool = True
    expose_buffer: Optional[bool] = None
    prefetch: int = 2
    shards: Optional[int] = None
    mesh_axis: str = "data"
    partition: str = "rows"

    def __post_init__(self):
        if self.kind not in ("recency", "uniform"):
            raise ValueError(
                f"unknown sampler kind {self.kind!r}; use 'recency' or 'uniform'"
            )
        if self.num_hops not in (None, 1, 2):
            raise ValueError("num_hops must be None (auto), 1 or 2")
        if self.partition not in ("rows", "degree"):
            raise ValueError(
                f"partition must be 'rows' or 'degree', got {self.partition!r}"
            )
        if self.shards is not None:
            if self.shards < 1:
                raise ValueError("shards must be a positive integer or None")
            if not self.device:
                raise ValueError(
                    "shards requires device=True (only the device-resident "
                    "samplers have mesh-sharded state)"
                )


@dataclasses.dataclass(frozen=True)
class ModelSpec(_SpecBase):
    """A model-zoo name plus its config kwargs.

    CTDG link models: ``tgat``, ``tgn``, ``graphmixer``, ``dygformer``,
    ``tpnet``. DTDG snapshot models: ``gcn``, ``gclstm``, ``tgcn``. Node
    task adds the host baselines ``pf`` (persistent forecast) and the
    windowed ``tgn``. ``kwargs`` feed the model config (e.g.
    ``{"num_layers": 1}`` for TGAT, ``{"d_embed": 64}`` for snapshot
    models) and must stay JSON-serializable.
    """

    name: str = "tgat"
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        """Plain-JSON dict (kwargs copied, not aliased)."""
        return {"name": self.name, "kwargs": dict(self.kwargs)}


@dataclasses.dataclass(frozen=True)
class TrainSpec(_SpecBase):
    """Optimizer, epochs, eval cadence, and checkpoint policy.

    ``lr=None`` keeps each pipeline's historical default (1e-4 for CTDG
    link, 1e-3 for snapshot pipelines). ``eval_every=N`` evaluates
    ``eval_split`` every N epochs during ``fit`` (0 = only on demand);
    ``ckpt_every=N`` with ``ckpt_dir`` writes a checkpoint every N epochs.
    ``compiled``/``chunk_size`` control the DTDG scan (``compiled=False``
    is the per-snapshot jitted loop, the bit-parity oracle).

    ``data_shards`` is the event-stream data-parallel axis
    (``docs/sharding.md``): > 1 builds the 2-D ``(data, nodes)`` mesh —
    ``data_shards × SamplerSpec.shards`` devices — and each CTDG link
    train step shards the batch into contiguous time-ordered sub-streams
    over the data axis (gradients psum-summed; TGN memory synchronized by
    the DistTGL masked psum). Requires ``SamplerSpec.device=True`` and a
    ``batch_size`` divisible by ``data_shards``.

    ``telemetry`` is a JSONL path: when set, ``Experiment.compile`` builds
    a ``repro.obs.Telemetry`` with a ``FileSink`` at that path and threads
    it through the pipeline, loader, storage, and train loop — every span,
    counter, gauge, and histogram of the run lands in one
    schema-validated file (``docs/observability.md``). ``None`` (default)
    keeps telemetry disabled at near-zero overhead.
    """

    lr: Optional[float] = None
    epochs: int = 1
    batch_size: int = 200
    num_negatives: int = 1
    eval_negatives: int = 20
    seed: int = 0
    eval_every: int = 0
    eval_split: str = "val"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    compiled: bool = True
    chunk_size: Optional[int] = None
    data_shards: int = 1
    telemetry: Optional[str] = None

    def __post_init__(self):
        if self.data_shards < 1:
            raise ValueError("data_shards must be a positive integer")
        if self.data_shards > 1 and self.batch_size % self.data_shards:
            raise ValueError(
                f"batch_size {self.batch_size} must be divisible by "
                f"data_shards {self.data_shards} (each data shard takes a "
                f"contiguous time-ordered sub-stream of the batch)"
            )
