"""``repro.tg`` — the declarative experiment API (one front door).

Compose typed, serializable specs into a :class:`~repro.tg.Experiment`:
``DataSpec`` (dataset + splits + the ``TimeDelta`` discretization axis),
``SamplerSpec`` (recency/uniform × host/device × hops × checkpoint
policy), ``ModelSpec`` and ``TrainSpec``. ``Experiment.compile()``
inspects the axis and task to assemble the matching pipeline —
event-stream CTDG or scan-compiled DTDG, for link and node tasks — and
``Experiment.run()`` drives it through the shared ``TrainLoop`` engine.
Every spec round-trips through ``to_dict``/``from_dict``, so experiments
reproduce from a single JSON blob. See ``docs/experiment.md``.
"""

from repro.tg.experiment import Experiment
from repro.tg.specs import DataSpec, ModelSpec, SamplerSpec, TrainSpec

__all__ = ["DataSpec", "Experiment", "ModelSpec", "SamplerSpec", "TrainSpec"]
