"""``tg.Experiment`` — the declarative front door to every TG pipeline.

One object composes the four specs (:class:`~repro.tg.specs.DataSpec`,
:class:`~repro.tg.specs.SamplerSpec`, :class:`~repro.tg.specs.ModelSpec`,
:class:`~repro.tg.specs.TrainSpec`) with a task, and ``compile()`` inspects
the ``TimeDelta`` discretization axis and the task to assemble the right
pipeline — covering all four quadrants with one entry point:

  =========  =======================  ========================================
  task       discretization           pipeline
  =========  =======================  ========================================
  ``link``   ``None`` (event stream)  ``CTDGLinkPipeline`` (hooks + prefetch
                                      loader + jitted steps)
  ``link``   a ``TimeDelta``          ``DTDGLinkPipeline`` (``SnapshotTensor``
                                      + ``lax.scan``)
  ``node``   a ``TimeDelta``          ``DTDGNodePipeline`` for snapshot models
                                      (scan-compiled); ``EventNodePipeline``
                                      for ``pf``/``tgn`` (event windows)
  =========  =======================  ========================================

``run()`` drives the compiled pipeline through the shared
``repro.train.loop.TrainLoop`` engine (epochs, eval cadence, checkpoint
cadence from ``TrainSpec``) and returns the history plus final metrics.
Experiments round-trip through ``to_dict``/``from_dict`` (and
``to_json``/``from_json``) with plain-JSON leaves, so a run is reproducible
from a single blob. See ``docs/experiment.md``.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Mapping, Optional, Tuple

from repro.tg.specs import DataSpec, ModelSpec, SamplerSpec, TrainSpec

CTDG_LINK_MODELS = ("tgat", "tgn", "graphmixer", "dygformer", "tpnet")
DTDG_MODELS = ("gcn", "gclstm", "tgcn")
EVENT_NODE_MODELS = ("pf", "tgn")

TASKS = ("link", "node")


@dataclasses.dataclass(frozen=True)
class Experiment:
    """A fully-specified, serializable TG experiment.

    ``data``/``model``/``train`` are always meaningful; ``sampler`` only
    drives event-stream (CTDG link) pipelines — snapshot pipelines consume
    whole padded snapshots and ignore it. ``task`` selects link vs node
    property prediction. The object is immutable; derive variants with
    ``dataclasses.replace``.
    """

    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    train: TrainSpec = dataclasses.field(default_factory=TrainSpec)
    sampler: SamplerSpec = dataclasses.field(default_factory=SamplerSpec)
    task: str = "link"

    def __post_init__(self):
        if self.task not in TASKS:
            raise ValueError(f"unknown task {self.task!r}; have {TASKS}")

    # -- serialization ---------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """Plain-JSON dict capturing the whole experiment."""
        return {
            "task": self.task,
            "data": self.data.to_dict(),
            "model": self.model.to_dict(),
            "train": self.train.to_dict(),
            "sampler": self.sampler.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Experiment":
        """Rebuild an experiment from ``to_dict`` output."""
        return cls(
            task=d.get("task", "link"),
            data=DataSpec.from_dict(d.get("data", {})),
            model=ModelSpec.from_dict(d.get("model", {})),
            train=TrainSpec.from_dict(d.get("train", {})),
            sampler=SamplerSpec.from_dict(d.get("sampler", {})),
        )

    def to_json(self, **kwargs) -> str:
        """The experiment as a JSON blob (``json.dumps`` kwargs forwarded)."""
        return json.dumps(self.to_dict(), **kwargs)

    @classmethod
    def from_json(cls, blob: str) -> "Experiment":
        """Rebuild an experiment from ``to_json`` output."""
        return cls.from_dict(json.loads(blob))

    # -- compilation -----------------------------------------------------
    def _store(self, data=None):
        """The out-of-core ``EventStore`` handle, if this experiment has
        one: an ``EventStore`` passed as ``data``, else the ``MmapStore``
        at ``DataSpec.storage`` (``None`` otherwise)."""
        from repro.storage import EventStore

        if isinstance(data, EventStore):
            return data
        if data is None and self.data.storage is not None:
            from repro.storage import MmapStore

            return MmapStore(self.data.storage)
        return None

    def _dataset(self, data=None):
        """The concrete ``DGData``: the given one (an ``EventStore`` is
        viewed through ``DGData.from_store``), else the ``MmapStore`` at
        ``DataSpec.storage``, else ``DataSpec``'s generated stream."""
        store = self._store(data)
        if store is not None:
            return store.to_data()
        if data is not None:
            return data
        from repro.data import generate

        return generate(self.data.dataset, scale=self.data.scale)

    def _telemetry(self, telemetry=None):
        """The run's ``repro.obs.Telemetry``: the explicit override, else a
        ``FileSink`` writer at ``TrainSpec.telemetry``, else ``None`` (the
        pipelines then default to their own disabled instance)."""
        if telemetry is not None:
            return telemetry
        if self.train.telemetry is not None:
            from repro.obs import FileSink, Telemetry

            return Telemetry(FileSink(self.train.telemetry))
        return None

    def compile(self, data=None, telemetry=None):
        """Assemble the pipeline this experiment describes.

        Inspects the ``TimeDelta`` discretization axis and the task (see
        the module table) and returns a pipeline exposing the shared
        surface (``train_epoch`` / ``evaluate`` / ``save_checkpoint`` /
        ``restore_checkpoint``). ``data`` overrides ``DataSpec``'s
        generated dataset with a pre-built ``DGData`` — or an
        ``EventStore``, which (like ``DataSpec.storage``) backs the stream
        with the store's columns and runs event pipelines out-of-core
        (``docs/storage.md``). ``telemetry`` (a ``repro.obs.Telemetry``)
        overrides the ``TrainSpec.telemetry`` JSONL writer; either way the
        instance lands on ``pipeline.telemetry`` and instruments the whole
        run (``docs/observability.md``).
        """
        d, m, t = self.data, self.model, self.train
        tel = self._telemetry(telemetry)
        store = self._store(data)
        stream = store.to_data() if store is not None else self._dataset(data)

        if self.task == "link":
            if d.discretization is None:
                if m.name not in CTDG_LINK_MODELS:
                    raise ValueError(
                        f"model {m.name!r} is not an event-stream (CTDG) link "
                        f"model; have {CTDG_LINK_MODELS} — or set "
                        f"DataSpec.discretization for the snapshot pipeline"
                    )
                from repro.train.loop import CTDGLinkPipeline

                return CTDGLinkPipeline(
                    m.name, stream,
                    batch_size=t.batch_size, lr=t.lr,
                    eval_negatives=t.eval_negatives, seed=t.seed,
                    model_kwargs=dict(m.kwargs), sampler_spec=self.sampler,
                    val_ratio=d.val_ratio, test_ratio=d.test_ratio,
                    data_shards=t.data_shards, store=store,
                    telemetry=tel,
                )
            if m.name not in DTDG_MODELS:
                raise ValueError(
                    f"model {m.name!r} is not a snapshot (DTDG) model; have "
                    f"{DTDG_MODELS} — or drop DataSpec.discretization for the "
                    f"event-stream pipeline"
                )
            from repro.train.loop import DTDGLinkPipeline

            return DTDGLinkPipeline(
                m.name, stream,
                snapshot_unit=d.discretization,
                edge_capacity=d.capacity,
                lr=t.lr, num_negatives=t.num_negatives,
                eval_negatives=t.eval_negatives, seed=t.seed,
                val_ratio=d.val_ratio, test_ratio=d.test_ratio,
                compiled=t.compiled, chunk_size=t.chunk_size,
                telemetry=tel,
                **dict(m.kwargs),
            )

        # task == "node": the TimeDelta axis is the label-window unit.
        if d.discretization is None:
            raise ValueError(
                "task='node' needs DataSpec.discretization — it is the "
                "prediction-window axis for both pipeline families"
            )
        from repro.train.nodeprop import DTDGNodePipeline, EventNodePipeline

        if m.name in DTDG_MODELS:
            return DTDGNodePipeline(
                m.name, stream, unit=d.discretization,
                lr=t.lr, seed=t.seed, capacity=d.capacity,
                val_ratio=d.val_ratio, test_ratio=d.test_ratio,
                compiled=t.compiled, **dict(m.kwargs),
            )
        if m.name in EVENT_NODE_MODELS:
            return EventNodePipeline(
                m.name, stream, unit=d.discretization,
                lr=t.lr, seed=t.seed,
                val_ratio=d.val_ratio, test_ratio=d.test_ratio,
                **dict(m.kwargs),
            )
        raise ValueError(
            f"model {m.name!r} is not a node-task model; have "
            f"{DTDG_MODELS + EVENT_NODE_MODELS}"
        )

    # -- execution -------------------------------------------------------
    def run(self, data=None, splits: Tuple[str, ...] = ("test",),
            log=None) -> Dict[str, Any]:
        """Compile, fit, and evaluate in one call.

        Runs ``TrainSpec.epochs`` epochs through the shared ``TrainLoop``
        engine (eval cadence ``eval_every`` on ``eval_split``, checkpoint
        cadence ``ckpt_every`` into ``ckpt_dir``), then evaluates each of
        ``splits``. Returns ``{"pipeline", "history", "metrics"}`` —
        ``metrics`` maps split name to the task metric (link: MRR, node:
        NDCG@10).
        """
        from repro.train.loop import TrainLoop

        tel = self._telemetry()
        pipeline = self.compile(data, telemetry=tel)
        t = self.train
        history = TrainLoop(pipeline, telemetry=tel).fit(
            epochs=t.epochs, eval_every=t.eval_every, eval_split=t.eval_split,
            ckpt_dir=t.ckpt_dir, ckpt_every=t.ckpt_every, log=log,
        )
        metrics = {s: pipeline.evaluate(s)[0] for s in splits}
        return {"pipeline": pipeline, "history": history, "metrics": metrics}
