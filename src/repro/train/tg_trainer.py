"""Training/evaluation drivers for the TG model zoo.

``LinkPredictionTrainer`` — CTDG models (TGAT, TGN, GraphMixer, DyGFormer,
TPNet) over event-iterated batches with the TGB link recipe (random train
negatives, one-vs-many eval negatives, recency neighbors, padding, device
transfer).

With ``device_sampling=True`` the trainer switches to the device-resident
pipeline: the recency buffers live on the accelerator as a JAX pytree
(``core.device_sampler.DeviceRecencySampler``, jit-compiled update/sample
inside ``DeviceRecencyNeighborHook``) and the loader is wrapped in a
``PrefetchLoader`` that stages the *next* batch's host arrays onto the
device from a background thread while the current jitted step runs. The
default (``device_sampling=False``) keeps the host-numpy sampler, which
doubles as the parity oracle in tests.

``SnapshotLinkTrainer`` — DTDG models (GCN, GCLSTM, TGCN) over
time-iterated snapshots: embeddings from snapshots <= t predict the edges of
snapshot t+1.
"""

from __future__ import annotations

import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DGData,
    DGraph,
    DGDataLoader,
    PrefetchLoader,
    RECIPE_TGB_LINK,
    RecipeRegistry,
    TimeDelta,
    TRAIN_KEY,
    EVAL_KEY,
)
from repro.distributed import checkpoint as ckpt
from repro.models.tg import dygformer, graphmixer, snapshot, tgat, tgn, tpnet
from repro.models.tg.common import bce_link_loss, link_decoder, link_logits
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train.metrics import mrr

_STATELESS = {"tgat", "graphmixer", "dygformer"}
_STATEFUL = {"tgn", "tpnet"}


class LinkPredictionTrainer:
    def __init__(
        self,
        model_name: str,
        data: DGData,
        batch_size: int = 200,
        k: int = 20,
        lr: float = 1e-4,
        eval_negatives: int = 20,
        seed: int = 0,
        model_kwargs: Optional[Dict[str, Any]] = None,
        device_sampling: bool = False,
        prefetch: int = 2,
        sampler: str = "recency",
    ):
        if model_name not in _STATELESS | _STATEFUL:
            raise ValueError(f"unknown CTDG model {model_name!r}")
        self.model_name = model_name
        self.data = data
        self.batch_size = batch_size
        self.device_sampling = device_sampling
        self.prefetch = prefetch
        self.train_data, self.val_data, self.test_data = data.split()
        kwargs = dict(model_kwargs or {})

        d_edge = data.edge_feat_dim
        n = data.num_nodes
        key = jax.random.PRNGKey(seed)

        num_hops = 1
        if model_name == "tgat":
            self.cfg = tgat.TGATConfig(num_nodes=n, d_edge=d_edge, k=k, **kwargs)
            num_hops = min(2, self.cfg.num_layers)
            self.params = tgat.init(key, self.cfg)
            self._scores = partial(tgat.link_scores, cfg=self.cfg)
        elif model_name == "graphmixer":
            self.cfg = graphmixer.GraphMixerConfig(num_nodes=n, d_edge=d_edge, k=k, **kwargs)
            self.params = graphmixer.init(key, self.cfg)
            self._scores = partial(graphmixer.link_scores, cfg=self.cfg)
        elif model_name == "dygformer":
            self.cfg = dygformer.DyGFormerConfig(num_nodes=n, d_edge=d_edge, k=k, **kwargs)
            self.params = dygformer.init(key, self.cfg)
            self._scores = partial(dygformer.link_scores, cfg=self.cfg)
        elif model_name == "tgn":
            self.cfg = tgn.TGNConfig(num_nodes=n, d_edge=d_edge, k=k, **kwargs)
            self.params = tgn.init(key, self.cfg)
            self.model_state = tgn.init_state(self.cfg)
        elif model_name == "tpnet":
            self.cfg = tpnet.TPNetConfig(num_nodes=n, **kwargs)
            self.params = tpnet.init(key, self.cfg)
            self.model_state = tpnet.init_state(self.params, self.cfg)

        needs_nbrs = model_name != "tpnet"
        self.manager = RecipeRegistry.build(
            RECIPE_TGB_LINK,
            num_nodes=n,
            k=self.cfg.k if needs_nbrs else 1,
            num_hops=num_hops,
            batch_size=batch_size,
            eval_negatives=eval_negatives,
            # Full-stream features: sampled nbr_eids are global event
            # indices (the loader offsets sliced splits by their
            # ``eid_offset``), so the lookup table must cover val/test
            # warm-up too (the train rows are the identical prefix).
            edge_feats=data.edge_feats if d_edge else None,
            edge_feat_dim=d_edge,
            seed=seed,
            device_sampling=device_sampling,
            sampler=sampler,
            # Only TGAT/TGN have a fused attention path consuming the
            # exposed packed buffer; other models skip the snapshot so the
            # device sampler's buffer update can donate in place.
            expose_buffer=None if model_name in ("tgat", "tgn") else False,
        )
        if sampler == "uniform":
            # The uniform samplers draw from a static CSR-by-time adjacency;
            # build it once over the full stream — the strict t < query_t
            # filter at sample time keeps it leak-free.
            from repro.core.tg_hooks import (
                DeviceUniformNeighborHook,
                UniformNeighborHook,
            )

            for hook in self.manager.hooks():
                if isinstance(hook, (UniformNeighborHook,
                                     DeviceUniformNeighborHook)):
                    hook.build(data.src, data.dst, data.edge_t,
                               np.arange(len(data.src), dtype=np.int64))

        self.opt_cfg = AdamWConfig(lr=lr)
        self.opt_state = adamw_init(self.params)
        self._build_steps()

    # ------------------------------------------------------------------
    def _build_steps(self):
        name, B = self.model_name, self.batch_size

        if name in _STATELESS:

            def loss_fn(params, batch):
                pos, neg = self._scores(params, batch=batch, batch_size=B)
                return bce_link_loss(pos, neg, batch["batch_mask"])

            @jax.jit
            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                params, opt_state = adamw_update(params, grads, opt_state, self.opt_cfg)
                return params, opt_state, loss

            @jax.jit
            def eval_step(params, batch):
                return self._scores(params, batch=batch, batch_size=B)

            self._train_step, self._eval_step = train_step, eval_step

        elif name == "tgn":
            cfg = self.cfg

            def loss_fn(params, state, batch):
                (pos, neg), new_state = tgn.link_scores(params, cfg, state, batch, B)
                return bce_link_loss(pos, neg, batch["batch_mask"]), new_state

            @jax.jit
            def train_step(params, opt_state, state, batch):
                (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, state, batch
                )
                params, opt_state = adamw_update(params, grads, opt_state, self.opt_cfg)
                return params, opt_state, new_state, loss

            @jax.jit
            def eval_step(params, state, batch):
                return tgn.link_scores(params, cfg, state, batch, B)

            self._train_step, self._eval_step = train_step, eval_step

        elif name == "tpnet":
            cfg = self.cfg

            def loss_fn(params, state, batch):
                (pos, neg), new_state = tpnet.link_scores(params, cfg, state, batch, B)
                return bce_link_loss(pos, neg, batch["batch_mask"]), new_state

            @jax.jit
            def train_step(params, opt_state, state, batch):
                (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, state, batch
                )
                params, opt_state = adamw_update(params, grads, opt_state, self.opt_cfg)
                return params, opt_state, new_state, loss

            @jax.jit
            def eval_step(params, state, batch):
                return tpnet.link_scores(params, cfg, state, batch, B)

            self._train_step, self._eval_step = train_step, eval_step

    # ------------------------------------------------------------------
    def _loader(self, data: DGData):
        loader = DGDataLoader(DGraph(data), self.manager, batch_size=self.batch_size)
        if self.device_sampling:
            # Overlap hook pipeline + host->device staging of batch i+1 with
            # the jitted step on batch i (double-buffered by default).
            return PrefetchLoader(loader, prefetch=self.prefetch)
        return loader

    def _batch_tensors(self, batch) -> Dict[str, Any]:
        return {k: batch[k] for k in batch.keys()}

    def reset_epoch_state(self):
        self.manager.reset_state()
        if self.model_name == "tgn":
            self.model_state = tgn.init_state(self.cfg)
        elif self.model_name == "tpnet":
            self.model_state = tpnet.init_state(self.params, self.cfg)

    # -- checkpointing ---------------------------------------------------
    # The hook/sampler buffers (host numpy or device JAX pytree — both
    # expose the same state_dict contract) ride along with params/optimizer
    # state, so a restored run resumes mid-stream with warm neighbor state.
    def save_checkpoint(self, ckpt_dir: str, step: int) -> str:
        tree = {
            "params": self.params,
            "opt_state": self.opt_state,
            "hooks": self.manager.state_dict(),
        }
        if self.model_name in _STATEFUL:
            tree["model_state"] = self.model_state
        return ckpt.save(ckpt_dir, step, tree,
                         extra_meta={"model_name": self.model_name})

    def restore_checkpoint(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        target = {
            "params": self.params,
            "opt_state": self.opt_state,
            "hooks": self.manager.state_dict(),
        }
        if self.model_name in _STATEFUL:
            target["model_state"] = self.model_state
        tree, step, meta = ckpt.restore(ckpt_dir, step, target=target)
        if meta.get("model_name") not in (None, self.model_name):
            raise ValueError(
                f"checkpoint is for model {meta['model_name']!r}, "
                f"trainer is {self.model_name!r}"
            )
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.manager.load_state_dict(tree["hooks"])
        if self.model_name in _STATEFUL:
            self.model_state = tree["model_state"]
        return step

    def train_epoch(self) -> Tuple[float, float]:
        """One epoch over the train split. Returns (mean loss, seconds)."""
        self.reset_epoch_state()
        t0 = time.perf_counter()
        losses = []
        with self.manager.activate(TRAIN_KEY):
            for batch in self._loader(self.train_data):
                bt = self._batch_tensors(batch)
                if self.model_name in _STATELESS:
                    self.params, self.opt_state, loss = self._train_step(
                        self.params, self.opt_state, bt
                    )
                else:
                    self.params, self.opt_state, self.model_state, loss = self._train_step(
                        self.params, self.opt_state, self.model_state, bt
                    )
                losses.append(loss)
        losses = [float(l) for l in losses]
        return float(np.mean(losses)), time.perf_counter() - t0

    def evaluate(self, split: str = "val") -> Tuple[float, float]:
        """One-vs-many MRR on val/test (warm state from train[, val])."""
        self.reset_epoch_state()
        # Warm the samplers/state through earlier splits without predicting.
        with self.manager.activate(TRAIN_KEY):
            warm = [self.train_data] + ([self.val_data] if split == "test" else [])
            for d in warm:
                for batch in self._loader(d):
                    bt = self._batch_tensors(batch)
                    if self.model_name in _STATEFUL:
                        _, self.model_state = self._eval_step(
                            self.params, self.model_state, bt
                        )
        data = self.val_data if split == "val" else self.test_data
        t0 = time.perf_counter()
        rrs, masks = [], []
        with self.manager.activate(EVAL_KEY):
            for batch in self._loader(data):
                bt = self._batch_tensors(batch)
                if self.model_name in _STATELESS:
                    pos, neg = self._eval_step(self.params, bt)
                else:
                    (pos, neg), self.model_state = self._eval_step(
                        self.params, self.model_state, bt
                    )
                rrs.append(mrr(pos, neg, bt["batch_mask"]) * float(bt["batch_mask"].sum()))
                masks.append(float(bt["batch_mask"].sum()))
        return float(np.sum(rrs) / max(np.sum(masks), 1.0)), time.perf_counter() - t0


class SnapshotLinkTrainer:
    """DTDG link prediction: process snapshot t, predict snapshot t+1."""

    def __init__(
        self,
        model_name: str,
        data: DGData,
        snapshot_unit: TimeDelta | str = "h",
        d_embed: int = 128,
        lr: float = 1e-3,
        num_negatives: int = 1,
        eval_negatives: int = 20,
        edge_capacity: Optional[int] = None,
        seed: int = 0,
    ):
        if model_name not in ("gcn", "gclstm", "tgcn"):
            raise ValueError(f"unknown DTDG model {model_name!r}")
        self.model_name = model_name
        self.data = data
        self.unit = TimeDelta.coerce(snapshot_unit)
        self.num_negatives = num_negatives
        self.eval_negatives = eval_negatives
        self._rng = np.random.default_rng(seed)
        self._seed = seed

        self.cfg = snapshot.SnapshotConfig(num_nodes=data.num_nodes, d_embed=d_embed)
        key = jax.random.PRNGKey(seed)
        if model_name == "gcn":
            self.params = snapshot.gcn_model_init(key, self.cfg)
        elif model_name == "gclstm":
            self.params = snapshot.gclstm_init(key, self.cfg)
        else:
            self.params = snapshot.tgcn_init(key, self.cfg)

        # Snapshot capacity: max discretized snapshot size (power-of-2 pad).
        disc = data.discretize(self.unit, reduce="count")
        self.disc = disc
        loader = DGDataLoader(DGraph(disc), None, batch_size=None, batch_unit=self.unit)
        sizes = [b.num_events for b in loader]
        cap = edge_capacity or int(2 ** np.ceil(np.log2(max(max(sizes), 1))))
        self.capacity = cap
        self.opt_cfg = AdamWConfig(lr=lr)
        self.opt_state = adamw_init(self.params)
        self._build_steps()

    def _init_state(self):
        if self.model_name == "gcn":
            return ()
        if self.model_name == "gclstm":
            return snapshot.gclstm_state(self.cfg)
        return snapshot.tgcn_state(self.cfg)

    def _apply(self, params, src, dst, mask, state):
        if self.model_name == "gcn":
            z = snapshot.gcn_model_apply(params, self.cfg, src, dst, mask)
            return z, state
        if self.model_name == "gclstm":
            return snapshot.gclstm_apply(params, self.cfg, src, dst, mask, state)
        return snapshot.tgcn_apply(params, self.cfg, src, dst, mask, state)

    def _build_steps(self):
        apply = self._apply

        def loss_fn(params, state, cur, nxt):
            z, new_state = apply(params, cur["src"], cur["dst"], cur["mask"], state)
            h_src, h_dst = z[nxt["src"]], z[nxt["dst"]]
            pos = link_decoder(params["decoder"], h_src, h_dst)
            h_neg = z[nxt["neg"]]
            neg = link_decoder(params["decoder"], h_src, h_neg)
            return bce_link_loss(pos, neg, nxt["mask"]), new_state

        @jax.jit
        def train_step(params, opt_state, state, cur, nxt):
            (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, state, cur, nxt
            )
            params, opt_state = adamw_update(params, grads, opt_state, self.opt_cfg)
            return params, opt_state, new_state, loss

        @jax.jit
        def eval_step(params, state, cur, nxt):
            z, new_state = apply(params, cur["src"], cur["dst"], cur["mask"], state)
            h_src, h_dst = z[nxt["src"]], z[nxt["dst"]]
            pos = link_decoder(params["decoder"], h_src, h_dst)
            neg = link_decoder(params["decoder"], h_src, z[nxt["neg"]])
            return pos, neg, new_state

        self._train_step, self._eval_step = train_step, eval_step

    # ------------------------------------------------------------------
    def _snapshots(self):
        loader = DGDataLoader(
            DGraph(self.disc), None, batch_size=None,
            batch_unit=self.unit, emit_empty=True,
        )
        for b in loader:
            src, dst, mask = snapshot.pad_snapshot(b["src"], b["dst"], self.capacity)
            yield {
                "src": jnp.asarray(src), "dst": jnp.asarray(dst),
                "mask": jnp.asarray(mask),
            }

    def _with_negatives(self, snap, m: int):
        neg = self._rng.integers(0, self.cfg.num_nodes, size=(self.capacity, m))
        return {**snap, "neg": jnp.asarray(neg, jnp.int32)}

    def run_epoch(self, train_frac: float = 0.7, train: bool = True) -> Tuple[float, float]:
        """Returns (mean metric, seconds). metric = loss if train else MRR."""
        self._rng = np.random.default_rng(self._seed)
        snaps = list(self._snapshots())
        n_train = max(1, int(len(snaps) * train_frac))
        state = self._init_state()
        t0 = time.perf_counter()
        out, weights = [], []
        for i in range(len(snaps) - 1):
            cur = snaps[i]
            is_train = i + 1 < n_train
            if train and is_train:
                nxt = self._with_negatives(snaps[i + 1], self.num_negatives)
                self.params, self.opt_state, state, loss = self._train_step(
                    self.params, self.opt_state, state, cur, nxt
                )
                out.append(float(loss))
                weights.append(1.0)
            elif not train and not is_train:
                nxt = self._with_negatives(snaps[i + 1], self.eval_negatives)
                pos, neg, state = self._eval_step(self.params, state, cur, nxt)
                w = float(np.asarray(nxt["mask"]).sum())
                out.append(mrr(pos, neg, nxt["mask"]) * w)
                weights.append(w)
            else:
                # advance recurrent state through non-scored snapshots
                _, state = self._advance(state, cur)
        t1 = time.perf_counter()
        denom = max(sum(weights), 1.0)
        return float(np.sum(out) / denom if not train else np.mean(out)), t1 - t0

    def _advance(self, state, cur):
        z, state = self._apply(self.params, cur["src"], cur["dst"], cur["mask"], state)
        return z, state
