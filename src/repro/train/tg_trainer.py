"""Legacy trainer names for the TG model zoo — thin shims over the shared
engine in ``repro.train.loop``.

``LinkPredictionTrainer`` and ``SnapshotLinkTrainer`` are kept for
backwards compatibility: they are the same classes as
``repro.train.loop.CTDGLinkPipeline`` / ``DTDGLinkPipeline`` (every
attribute, method, and checkpoint produced by either name is
interchangeable with the other). New code should declare experiments
through ``repro.tg.Experiment`` instead, which assembles these pipelines
from serializable specs and runs them through the shared ``TrainLoop``
engine — see ``docs/experiment.md`` for the migration table from the
legacy trainer kwargs (``device_sampling=``, ``sampler=``,
``uniform_checkpoint_adjacency=`` …) to ``SamplerSpec``/``TrainSpec``
fields.
"""

from __future__ import annotations

from repro.train.loop import (
    CTDGLinkPipeline,
    DTDGLinkPipeline,
    restore_with_saved_hooks as _restore_with_saved_hooks,  # noqa: F401 (compat)
    weighted_mrr as _weighted_mrr,  # noqa: F401 (compat)
)

_STATELESS = {"tgat", "graphmixer", "dygformer"}
_STATEFUL = {"tgn", "tpnet"}


class LinkPredictionTrainer(CTDGLinkPipeline):
    """Deprecated alias of ``repro.train.loop.CTDGLinkPipeline``.

    Prefer ``repro.tg.Experiment`` with ``DataSpec(discretization=None)``
    and a ``SamplerSpec`` — the legacy kwargs map as: ``sampler=`` ->
    ``SamplerSpec.kind``, ``device_sampling=`` -> ``SamplerSpec.device``,
    ``k=`` -> ``SamplerSpec.k``, ``prefetch=`` -> ``SamplerSpec.prefetch``,
    ``uniform_checkpoint_adjacency=`` -> ``SamplerSpec.checkpoint_adjacency``.
    """


class SnapshotLinkTrainer(DTDGLinkPipeline):
    """Deprecated alias of ``repro.train.loop.DTDGLinkPipeline``.

    Prefer ``repro.tg.Experiment`` with ``DataSpec(discretization="h")``
    (the ``TimeDelta`` axis selects the scan-compiled snapshot pipeline);
    ``compiled=``/``chunk_size=`` live on ``TrainSpec``, ``d_embed=`` on
    ``ModelSpec.kwargs``.
    """
