"""The shared training engine behind every TG task quadrant.

This module owns the machinery that used to be duplicated (or hand-rolled
per example) across ``LinkPredictionTrainer`` and ``SnapshotLinkTrainer``:

  * ``CTDGLinkPipeline``  — event-stream link prediction (TGB link recipe,
    optional device-resident sampling + ``PrefetchLoader``, jitted steps);
  * ``DTDGLinkPipeline``  — scan-compiled snapshot link prediction
    (``SnapshotTensor`` + ``lax.scan``; ``compiled=False`` keeps the
    per-snapshot jitted loop as the bit-parity oracle);
  * ``TrainLoop``         — the epoch engine: runs ``train_epoch`` /
    ``evaluate`` / ``save_checkpoint`` on any pipeline with the standard
    surface, applying eval and checkpoint cadences and recording history;
  * the checkpoint bundle helpers (``save_bundle`` / ``restore_bundle`` /
    ``restore_with_saved_hooks``) and ``weighted_mrr`` shared by all
    pipelines.

``repro.tg.Experiment`` is the declarative front door that assembles these
pipelines from specs; ``repro.train.tg_trainer`` keeps the legacy trainer
names as thin deprecated shims over the same classes. The node-property
pipelines live in ``repro.train.nodeprop`` and run through the same
``TrainLoop`` surface. See ``docs/experiment.md``.

Pipeline surface (duck-typed, consumed by ``TrainLoop``):

  ``train_epoch() -> (mean_loss, seconds)``
  ``evaluate(split) -> (metric, seconds)``      # split in {train,val,test}
  ``save_checkpoint(ckpt_dir, step) -> path``
  ``restore_checkpoint(ckpt_dir, step=None) -> step``
"""

from __future__ import annotations

import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DGData,
    DGraph,
    DGDataLoader,
    PrefetchLoader,
    RECIPE_DTDG_SNAPSHOT,
    RECIPE_TGB_LINK,
    RecipeRegistry,
    TimeDelta,
    TRAIN_KEY,
    EVAL_KEY,
    snapshot_tensor,
)
from repro.distributed import checkpoint as ckpt
from repro.models.tg import dygformer, graphmixer, snapshot, tgat, tgn, tpnet
from repro.obs import MemorySink, Telemetry
from repro.models.tg.common import bce_link_loss, link_decoder
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.tg.specs import SamplerSpec
from repro.train.metrics import mrr

CTDG_STATELESS = {"tgat", "graphmixer", "dygformer"}
CTDG_STATEFUL = {"tgn", "tpnet"}
CTDG_LINK_MODELS = CTDG_STATELESS | CTDG_STATEFUL


# ----------------------------------------------------------------------
# Shared checkpoint machinery
# ----------------------------------------------------------------------
def restore_with_saved_hooks(ckpt_dir, step, target):
    """Two-phase checkpoint restore with a checkpoint-shaped hooks subtree.

    The hooks state is checkpoint-dependent (e.g. the uniform samplers'
    counter-only mode drops the CSR leaves), so a target prototype built
    from the *current* hook state can demand leaves the checkpoint never
    saved. Read the flat checkpoint once, reassemble the hooks subtree
    that was actually written (``<group>/<idx>/<state_key>`` keys with flat
    array leaves — the shared contract), and assemble the rest structurally
    from the already-loaded leaves; the samplers' ``load_state_dict``
    accepts either form.
    """
    flat, step, meta = ckpt.restore(ckpt_dir, step, target=None)
    hooks: Dict[str, Dict] = {}
    for k, v in flat.items():
        if k.startswith("hooks/"):
            group, leaf = k[len("hooks/"):].rsplit("/", 1)
            hooks.setdefault(group, {})[leaf] = v
    target = dict(target)
    target["hooks"] = hooks
    return ckpt.assemble(flat, target), step, meta


def save_bundle(ckpt_dir: str, step: int, tree: Dict[str, Any],
                model_name: str, **extra_meta) -> str:
    """Write a pipeline checkpoint bundle (atomic step directory).

    ``tree`` is the composable ``{params, opt_state[, model_state],
    hooks[, pipeline]}`` contract every pipeline shares; ``model_name``
    (plus any ``extra_meta``) rides the sidecar metadata so restores can
    refuse mismatched models. Returns the written path.
    """
    return ckpt.save(ckpt_dir, step, tree,
                     extra_meta={"model_name": model_name, **extra_meta})


def restore_bundle(ckpt_dir: str, step: Optional[int], target: Dict[str, Any],
                   model_name: str):
    """Restore a bundle written by ``save_bundle`` into ``target``'s
    structure (hooks subtree checkpoint-shaped; see
    ``restore_with_saved_hooks``), validating the model name. Returns
    ``(tree, step)``.
    """
    tree, step, meta = restore_with_saved_hooks(ckpt_dir, step, target)
    if meta.get("model_name") not in (None, model_name):
        raise ValueError(
            f"checkpoint is for model {meta['model_name']!r}, "
            f"pipeline is {model_name!r}"
        )
    return tree, step


def weighted_mrr(pos_rows, neg_rows, mask_rows) -> float:
    """Per-row MRR weighted by valid predictions — shared by the scanned
    and loop DTDG paths so their aggregation is bit-identical."""
    out, wsum = 0.0, 0.0
    for pos, neg, m in zip(pos_rows, neg_rows, mask_rows):
        w = float(np.asarray(m).sum())
        if w:
            out += mrr(pos, neg, m) * w
            wsum += w
    return float(out / max(wsum, 1.0))


# ----------------------------------------------------------------------
# The epoch engine
# ----------------------------------------------------------------------
def history_from_records(records) -> Dict[str, Any]:
    """Rebuild a ``TrainLoop.fit`` history dict from telemetry records.

    Consumes the ``train/epoch`` / ``train/eval`` / ``train/ckpt`` span
    records one ``fit`` emits (in order) and returns the exact history
    contract — ``{"loss", "train_secs", "eval", "ckpts"}`` with the same
    values the pipeline produced (they ride the span attrs verbatim; span
    durations are *not* used, so the numbers are bit-identical to the
    pre-telemetry hand-rolled dict). Non-span and unrelated records are
    ignored, so a shared sink's full stream can be passed unfiltered.
    """
    history: Dict[str, Any] = {"loss": [], "train_secs": [], "eval": [],
                               "ckpts": []}
    for r in records:
        if r.get("kind") != "span":
            continue
        attrs = r.get("attrs", {})
        if r["name"] == "train/epoch":
            history["loss"].append(attrs["loss"])
            history["train_secs"].append(attrs["secs"])
        elif r["name"] == "train/eval":
            history["eval"].append((attrs["epoch"], attrs["metric"]))
        elif r["name"] == "train/ckpt":
            history["ckpts"].append(attrs["path"])
    return history


class TrainLoop:
    """Multi-epoch driver over any pipeline with the standard surface.

    ``fit`` runs ``epochs`` training epochs, evaluating ``eval_split``
    every ``eval_every`` epochs (0 = never) and writing a checkpoint to
    ``ckpt_dir`` every ``ckpt_every`` epochs (0 = never), and returns a
    history dict::

        {"loss": [...], "train_secs": [...],
         "eval": [(epoch, metric), ...], "ckpts": [path, ...]}

    The loop is deliberately dumb — all task/pipeline intelligence lives in
    the pipeline object — which is what lets the CTDG/DTDG × link/node
    quadrants share one engine.

    Every ``fit`` emits ``train/epoch`` / ``train/eval`` / ``train/ckpt``
    spans through ``telemetry`` (defaulting to the pipeline's own
    ``Telemetry``, so one spec-configured sink sees the whole run), and
    the returned history is itself rebuilt from those records
    (:func:`history_from_records`) — the records are the source of truth,
    not a parallel bookkeeping path.
    """

    def __init__(self, pipeline, telemetry: Optional[Telemetry] = None):
        self.pipeline = pipeline
        if telemetry is None:
            telemetry = getattr(pipeline, "telemetry", None)
        # A private instance when neither the caller nor the pipeline has
        # one: fit() attaches its history sink here, which must never
        # mutate a shared singleton.
        self.telemetry = telemetry if telemetry is not None else Telemetry()

    def fit(self, epochs: int = 1, eval_every: int = 0,
            eval_split: str = "val", ckpt_dir: Optional[str] = None,
            ckpt_every: int = 0, log=None) -> Dict[str, Any]:
        """Run the epoch loop; see the class docstring for the contract."""
        tel = self.telemetry
        mem = tel.attach(MemorySink())  # tee: history comes from records
        try:
            for epoch in range(epochs):
                with tel.span("train/epoch", epoch=epoch) as sp:
                    loss, secs = self.pipeline.train_epoch()
                    sp["loss"], sp["secs"] = loss, secs
                if log is not None:
                    log(f"epoch {epoch}: loss={loss:.4f} ({secs:.1f}s)")
                if eval_every and (epoch + 1) % eval_every == 0:
                    with tel.span("train/eval", epoch=epoch,
                                  split=eval_split) as sp:
                        metric, _ = self.pipeline.evaluate(eval_split)
                        sp["metric"] = metric
                    if log is not None:
                        log(f"epoch {epoch}: {eval_split} "
                            f"metric={metric:.4f}")
                if ckpt_dir and ckpt_every and (epoch + 1) % ckpt_every == 0:
                    with tel.span("train/ckpt", epoch=epoch) as sp:
                        sp["path"] = self.pipeline.save_checkpoint(
                            ckpt_dir, epoch)
        finally:
            tel.detach(mem)
        return history_from_records(mem.records)


# ----------------------------------------------------------------------
# CTDG link prediction: event-stream pipeline
# ----------------------------------------------------------------------
class CTDGLinkPipeline:
    """CTDG link-prediction over the TGB link recipe.

    Event-iterated batches feed jitted train/eval steps for the CTDG model
    zoo (TGAT, TGN, GraphMixer, DyGFormer, TPNet): random train negatives,
    one-vs-many eval negatives, recency/uniform temporal neighbors,
    padding, device transfer.

    The sampling strategy comes from a ``repro.tg.SamplerSpec``:
    ``device=True`` switches to the device-resident pipeline (accelerator-
    resident sampler state with jit-compiled update/sample inside the
    hooks, and the loader wrapped in a ``PrefetchLoader`` that stages the
    *next* batch while the current jitted step runs). The host-numpy
    default doubles as the parity oracle in tests.

    ``SamplerSpec.shards`` additionally shards the device sampler state
    row-wise by node id over a 1-D mesh (``shard_map`` update/sample;
    bit-identical outputs), stages batches mesh-replicated, and runs the
    jitted steps replicated over the same mesh — see ``docs/sharding.md``.

    ``data_shards > 1`` composes the data and node axes into one 2-D
    ``("data", "nodes")`` mesh of ``data_shards × (SamplerSpec.shards or
    1)`` devices: each train step slices the event batch into contiguous
    time-ordered sub-streams over the data axis (gradients psum'd, the
    loss normalized by the global term count, TGN memory synchronized by
    the DistTGL masked psum) while sampler buffers/CSR stay partitioned
    over the node axis. With ``fused`` enabled the per-shard attention
    runs shard-aware (``fused_temporal_layer_sharded``) over each node
    shard's local buffer block, assembled exactly by a psum over the node
    axis — so one step scales FLOPs (data axis) and sampler HBM (node
    axis) together. ``fused`` forwards to the TGAT/TGN ``link_scores``
    (e.g. ``"ref"`` forces the fused math on CPU for parity tests).
    """

    def __init__(
        self,
        model_name: str,
        data: DGData,
        batch_size: int = 200,
        k: int = 20,
        lr: Optional[float] = None,
        eval_negatives: int = 20,
        seed: int = 0,
        model_kwargs: Optional[Dict[str, Any]] = None,
        device_sampling: bool = False,
        prefetch: int = 2,
        sampler: str = "recency",
        uniform_checkpoint_adjacency: bool = True,
        sampler_spec: Optional[SamplerSpec] = None,
        val_ratio: float = 0.15,
        test_ratio: float = 0.15,
        data_shards: int = 1,
        fused=None,
        store=None,
        telemetry: Optional[Telemetry] = None,
    ):
        if model_name not in CTDG_LINK_MODELS:
            raise ValueError(f"unknown CTDG model {model_name!r}")
        # Per-pipeline telemetry (docs/observability.md): a fresh disabled
        # instance by default so TrainLoop can tee sinks onto it safely.
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        spec = sampler_spec or SamplerSpec(
            kind=sampler, k=k, device=device_sampling, prefetch=prefetch,
            checkpoint_adjacency=uniform_checkpoint_adjacency,
        )
        self.model_name = model_name
        self.data = data
        # Out-of-core handle (repro.storage.EventStore). When set, the
        # uniform adjacency is built by the streaming two-pass CSR (O(chunk)
        # resident) and loaders release memmap pages after every batch.
        self._store = store
        self.batch_size = batch_size
        self.sampler_spec = spec
        self.device_sampling = spec.device
        self.prefetch = spec.prefetch
        self.data_shards = int(data_shards)
        self.fused = fused
        if self.data_shards < 1:
            raise ValueError("data_shards must be a positive integer")
        if fused is not None and model_name not in ("tgat", "tgn"):
            raise ValueError(
                f"fused= applies to the TGAT/TGN fused attention path; "
                f"{model_name!r} has no fused twin"
            )
        if self.data_shards > 1:
            if not spec.device:
                raise ValueError(
                    "data_shards > 1 requires SamplerSpec(device=True) — "
                    "the 2-D mesh step assumes device-staged batches and "
                    "mesh-placed sampler state (docs/sharding.md)"
                )
            if batch_size % self.data_shards:
                raise ValueError(
                    f"batch_size {batch_size} must be divisible by "
                    f"data_shards {self.data_shards} (each data shard takes "
                    f"a contiguous time-ordered sub-stream of the batch)"
                )
            if model_name == "tpnet":
                raise ValueError(
                    "data_shards > 1 supports tgat/tgn/graphmixer/dygformer;"
                    " tpnet's sketch state has no masked-psum sync recipe"
                )
        # Resolve expose_buffer early: it decides whether the sharded fused
        # path (and hence the 2-D shard_map step) is in play. Only TGAT/TGN
        # consume the exposed packed buffer; under a mesh, exposure is an
        # opt-in for the shard-aware fused layer, so auto-enable it exactly
        # when the fused path can engage (explicit fused= or TPU backend).
        expose = spec.expose_buffer
        if expose is None and model_name not in ("tgat", "tgn"):
            expose = False
        if expose is None and (spec.shards or self.data_shards > 1):
            expose = bool(self.fused) or jax.default_backend() == "tpu"
        self._expose_buffer = expose
        # Multi-device meshes (docs/sharding.md): data_shards composes the
        # 2-D ("data", "nodes") mesh — event sub-streams over the data
        # axis, sampler state over the node axis; SamplerSpec.shards alone
        # keeps the 1-D node mesh with replicated jitted steps. The 2-D
        # shard_map step is also required whenever a *sharded* packed
        # buffer rides the batch (expose_buffer with shards), since only
        # ``fused_temporal_layer_sharded`` inside a shard_map can read it.
        self._mesh = None
        self._replicated = None
        self._data_axis = None
        self._node_axis = None
        self._use_2d = self.data_shards > 1 or bool(
            spec.shards and expose and spec.kind == "recency"
            and model_name in ("tgat", "tgn")
        )
        recipe_axis = spec.mesh_axis
        if self._use_2d:
            from repro.distributed.sharding import (
                make_2d_mesh,
                replicated_sharding,
            )

            self._mesh = make_2d_mesh(self.data_shards, spec.shards or 1)
            self._replicated = replicated_sharding(self._mesh)
            self._data_axis, self._node_axis = "data", "nodes"
            recipe_axis = "nodes"
        elif spec.shards:
            from repro.distributed.sharding import (
                make_node_mesh,
                replicated_sharding,
            )

            self._mesh = make_node_mesh(spec.shards, spec.mesh_axis)
            self._replicated = replicated_sharding(self._mesh)
        self.train_data, self.val_data, self.test_data = data.split(
            val_ratio, test_ratio
        )
        kwargs = dict(model_kwargs or {})
        k = spec.k

        d_edge = data.edge_feat_dim
        n = data.num_nodes
        key = jax.random.PRNGKey(seed)

        num_hops = 1
        if model_name == "tgat":
            self.cfg = tgat.TGATConfig(num_nodes=n, d_edge=d_edge, k=k, **kwargs)
            num_hops = min(2, self.cfg.num_layers)
            self.params = tgat.init(key, self.cfg)
            self._scores = partial(tgat.link_scores, cfg=self.cfg)
        elif model_name == "graphmixer":
            self.cfg = graphmixer.GraphMixerConfig(num_nodes=n, d_edge=d_edge, k=k, **kwargs)
            self.params = graphmixer.init(key, self.cfg)
            self._scores = partial(graphmixer.link_scores, cfg=self.cfg)
        elif model_name == "dygformer":
            self.cfg = dygformer.DyGFormerConfig(num_nodes=n, d_edge=d_edge, k=k, **kwargs)
            self.params = dygformer.init(key, self.cfg)
            self._scores = partial(dygformer.link_scores, cfg=self.cfg)
        elif model_name == "tgn":
            self.cfg = tgn.TGNConfig(num_nodes=n, d_edge=d_edge, k=k, **kwargs)
            self.params = tgn.init(key, self.cfg)
            self.model_state = tgn.init_state(self.cfg)
        elif model_name == "tpnet":
            self.cfg = tpnet.TPNetConfig(num_nodes=n, **kwargs)
            self.params = tpnet.init(key, self.cfg)
            self.model_state = tpnet.init_state(self.params, self.cfg)
        if spec.num_hops is not None:
            num_hops = spec.num_hops

        needs_nbrs = model_name != "tpnet"
        self.manager = RecipeRegistry.build(
            RECIPE_TGB_LINK,
            num_nodes=n,
            spec=SamplerSpec(
                kind=spec.kind, k=self.cfg.k if needs_nbrs else 1,
                num_hops=num_hops, device=spec.device,
                checkpoint_adjacency=spec.checkpoint_adjacency,
                expose_buffer=self._expose_buffer, prefetch=spec.prefetch,
                shards=spec.shards, mesh_axis=recipe_axis,
                partition=spec.partition,
            ),
            mesh=self._mesh,
            mesh_axis=recipe_axis,
            batch_size=batch_size,
            eval_negatives=eval_negatives,
            # Full-stream features: sampled nbr_eids are global event
            # indices (the loader offsets sliced splits by their
            # ``eid_offset``), so the lookup table must cover val/test
            # warm-up too (the train rows are the identical prefix).
            edge_feats=data.edge_feats if d_edge else None,
            edge_feat_dim=d_edge,
            seed=seed,
        )
        if spec.kind == "uniform":
            # The uniform samplers draw from a static CSR-by-time adjacency;
            # build it once over the full stream — the strict t < query_t
            # filter at sample time keeps it leak-free.
            from repro.core.tg_hooks import (
                DeviceUniformNeighborHook,
                UniformNeighborHook,
            )

            for hook in self.manager.hooks():
                if isinstance(hook, (UniformNeighborHook,
                                     DeviceUniformNeighborHook)):
                    if self._store is not None:
                        hook.build_from_store(self._store)
                    else:
                        hook.build(data.src, data.dst, data.edge_t,
                                   np.arange(len(data.src), dtype=np.int64))

        # Node rows owned per shard of the sharded packed buffer — the
        # ``rows_per_shard`` handed to ``fused_temporal_layer_sharded`` by
        # the 2-D step (None without a node-sharded recency sampler).
        self._buf_rows = None
        if self._node_axis is not None:
            from repro.core.tg_hooks import DeviceRecencyNeighborHook

            for hook in self.manager.hooks():
                if isinstance(hook, DeviceRecencyNeighborHook):
                    self._buf_rows = hook.sampler.rows_per_shard

        self.opt_cfg = AdamWConfig(lr=1e-4 if lr is None else lr)
        self.opt_state = adamw_init(self.params)
        self._place_replicated()
        self._build_steps()

    # ------------------------------------------------------------------
    def _place_replicated(self):
        """Commit params/optimizer (and recurrent model) state replicated
        onto the sampler mesh, so the jitted steps see one device set
        (sharded-sampling pipelines only; no-op without a mesh)."""
        if self._mesh is None:
            return
        self.params = jax.device_put(self.params, self._replicated)
        self.opt_state = jax.device_put(self.opt_state, self._replicated)
        if self.model_name in CTDG_STATEFUL:
            self.model_state = jax.device_put(self.model_state,
                                              self._replicated)

    def _build_steps(self):
        if self._use_2d:
            self._build_steps_2d()
            return
        name, B = self.model_name, self.batch_size
        skw = {} if self.fused is None else {"fused": self.fused}

        if name in CTDG_STATELESS:

            def loss_fn(params, batch):
                pos, neg = self._scores(params, batch=batch, batch_size=B,
                                        **skw)
                return bce_link_loss(pos, neg, batch["batch_mask"])

            @jax.jit
            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                params, opt_state = adamw_update(params, grads, opt_state, self.opt_cfg)
                return params, opt_state, loss

            @jax.jit
            def eval_step(params, batch):
                return self._scores(params, batch=batch, batch_size=B, **skw)

            self._train_step, self._eval_step = train_step, eval_step

        else:
            score_fn = tgn.link_scores if name == "tgn" else tpnet.link_scores
            cfg = self.cfg

            def loss_fn(params, state, batch):
                (pos, neg), new_state = score_fn(params, cfg, state, batch, B,
                                                 **skw)
                return bce_link_loss(pos, neg, batch["batch_mask"]), new_state

            @jax.jit
            def train_step(params, opt_state, state, batch):
                (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, state, batch
                )
                params, opt_state = adamw_update(params, grads, opt_state, self.opt_cfg)
                return params, opt_state, new_state, loss

            @jax.jit
            def eval_step(params, state, batch):
                return score_fn(params, cfg, state, batch, B, **skw)

            self._train_step, self._eval_step = train_step, eval_step

    # -- 2-D mesh steps (docs/sharding.md) ------------------------------
    def _seed_perm(self, S: int) -> np.ndarray:
        """Shard-major permutation of the stacked seed axis.

        Seed-aligned tensors are stacked ``[src (B) | dst (B) | neg
        (B*Nn)]``; slicing that layout over the data axis would hand shard
        0 nothing but src rows. This (static) permutation reorders rows
        shard-major so each contiguous ``1/data_shards`` slice is that
        shard's own ``[src_l | dst_l | neg_l]`` stack — exactly the seed
        layout the models expect at batch size ``B/data_shards``.
        """
        B, ds = self.batch_size, self.data_shards
        nn = (S - 2 * B) // B
        bl = B // ds
        parts = []
        for s in range(ds):
            lo, hi = s * bl, (s + 1) * bl
            parts.append(np.arange(lo, hi))
            parts.append(B + np.arange(lo, hi))
            if nn:
                parts.append(2 * B + np.arange(lo * nn, hi * nn))
        return np.concatenate(parts).astype(np.int32)

    def _make_2d_step(self, kind: str, bt: Dict[str, Any]):
        """Build one jitted 2-D ``shard_map`` step for this batch signature.

        Batch tensors are routed by leading dimension: event-aligned
        ``(B, ...)`` tensors slice directly over the data axis (the batch
        is time-ordered, so equal slices are contiguous time-ordered
        sub-streams); seed-aligned ``(S, ...)`` and frontier-aligned
        ``(S*K, ...)`` tensors are permuted shard-major first
        (``_seed_perm``); ``nbr_buf`` splits over the node axis; the edge
        table, params, optimizer and model state stay replicated. Each
        shard optimizes ``local_loss_sum / global_denominator`` so the
        psum'd gradient equals the single-device gradient; the optimizer
        update runs replicated inside the shard_map.
        """
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import (
            SHARD_MAP_KW,
            shard_map,
            sync_state_masked_psum,
        )
        from repro.models.tg.common import bce_link_loss_parts

        mesh = self._mesh
        daxis, naxis = self._data_axis, self._node_axis
        ds, B = self.data_shards, self.batch_size
        Bl = B // ds
        S = int(np.shape(bt["seed_nodes"])[0]) if "seed_nodes" in bt else -1
        perm = self._seed_perm(S) if (S > 0 and ds > 1) else None

        perms: Dict[str, Optional[np.ndarray]] = {}
        specs: Dict[str, P] = {}
        for key, v in bt.items():
            shp = tuple(np.shape(v))
            perms[key] = None
            if key == "nbr_buf":
                specs[key] = P(naxis)
            elif key == "edge_feat_table" or not shp:
                specs[key] = P()
            elif shp[0] == B:
                specs[key] = P(daxis)
            elif S > 0 and shp[0] % S == 0:
                if perm is not None:
                    m = shp[0] // S
                    perms[key] = perm if m == 1 else (
                        perm[:, None] * m + np.arange(m, dtype=np.int32)
                    ).reshape(-1)
                specs[key] = P(daxis)
            else:
                specs[key] = P()

        def prep(batch):
            return {k: (v if perms[k] is None else v[perms[k]])
                    for k, v in batch.items()}

        kw = {}
        if self.model_name in ("tgat", "tgn"):
            kw["fused"] = self.fused
            if "nbr_buf" in bt and self._buf_rows is not None:
                kw["node_axis"] = naxis
                kw["buf_rows"] = self._buf_rows
        opt_cfg = self.opt_cfg
        rep = P()

        if self.model_name in CTDG_STATELESS:
            scores = self._scores

            def train_body(params, opt_state, pb):
                def objective(p):
                    pos, neg = scores(p, batch=pb, batch_size=Bl, **kw)
                    num, den = bce_link_loss_parts(pos, neg,
                                                   pb["batch_mask"])
                    D = jnp.maximum(jax.lax.psum(den, daxis), 1.0)
                    return num / D, (num, den)

                (_, (num, den)), grads = jax.value_and_grad(
                    objective, has_aux=True)(params)
                grads = jax.tree.map(
                    lambda g: jax.lax.psum(g, daxis), grads)
                loss = jax.lax.psum(num, daxis) / jnp.maximum(
                    jax.lax.psum(den, daxis), 1.0)
                params, opt_state = adamw_update(params, grads, opt_state,
                                                 opt_cfg)
                return params, opt_state, loss

            def eval_body(params, pb):
                return scores(params, batch=pb, batch_size=Bl, **kw)

            if kind == "train":
                smapped = shard_map(
                    train_body, mesh=mesh, in_specs=(rep, rep, specs),
                    out_specs=(rep, rep, rep), **SHARD_MAP_KW)
                return jax.jit(lambda p, o, b: smapped(p, o, prep(b)))
            smapped = shard_map(
                eval_body, mesh=mesh, in_specs=(rep, specs),
                out_specs=(P(daxis), P(daxis)), **SHARD_MAP_KW)
            return jax.jit(lambda p, b: smapped(p, prep(b)))

        score_fn = tgn.link_scores
        cfg = self.cfg

        def touched_rows(pb):
            # Node rows this data shard's events update — the masked-psum
            # sync mask (padded rows excluded via batch_mask).
            nodes = jnp.concatenate([pb["src"], pb["dst"]])
            mm = jnp.concatenate([pb["batch_mask"], pb["batch_mask"]])
            return jnp.zeros(cfg.num_nodes, bool).at[nodes].max(mm)

        def train_body(params, opt_state, state, pb):
            def objective(p):
                (pos, neg), new_state = score_fn(p, cfg, state, pb, Bl, **kw)
                num, den = bce_link_loss_parts(pos, neg, pb["batch_mask"])
                D = jnp.maximum(jax.lax.psum(den, daxis), 1.0)
                return num / D, (num, den, new_state)

            (_, (num, den, new_state)), grads = jax.value_and_grad(
                objective, has_aux=True)(params)
            grads = jax.tree.map(lambda g: jax.lax.psum(g, daxis), grads)
            loss = jax.lax.psum(num, daxis) / jnp.maximum(
                jax.lax.psum(den, daxis), 1.0)
            new_state = sync_state_masked_psum(
                new_state, touched_rows(pb), daxis)
            params, opt_state = adamw_update(params, grads, opt_state,
                                             opt_cfg)
            return params, opt_state, new_state, loss

        def eval_body(params, state, pb):
            (pos, neg), new_state = score_fn(params, cfg, state, pb, Bl,
                                             **kw)
            new_state = sync_state_masked_psum(
                new_state, touched_rows(pb), daxis)
            return (pos, neg), new_state

        if kind == "train":
            smapped = shard_map(
                train_body, mesh=mesh, in_specs=(rep, rep, rep, specs),
                out_specs=(rep, rep, rep, rep), **SHARD_MAP_KW)
            return jax.jit(lambda p, o, s, b: smapped(p, o, s, prep(b)))
        smapped = shard_map(
            eval_body, mesh=mesh, in_specs=(rep, rep, specs),
            out_specs=((P(daxis), P(daxis)), rep), **SHARD_MAP_KW)
        return jax.jit(lambda p, s, b: smapped(p, s, prep(b)))

    def _build_steps_2d(self):
        """Install 2-D dispatchers with the standard step signatures.

        Steps are built lazily per batch signature (train and eval batches
        differ in the negatives width, hence in every seed-aligned shape)
        and memoized, so each shape still compiles exactly once.
        """
        cache: Dict[Any, Any] = {}

        def get(kind, bt):
            sig = (kind, tuple(sorted(
                (k, tuple(np.shape(v))) for k, v in bt.items())))
            if sig not in cache:
                cache[sig] = self._make_2d_step(kind, bt)
            return cache[sig]

        if self.model_name in CTDG_STATELESS:
            self._train_step = lambda p, o, bt: get("train", bt)(p, o, bt)
            self._eval_step = lambda p, bt: get("eval", bt)(p, bt)
        else:
            self._train_step = (
                lambda p, o, s, bt: get("train", bt)(p, o, s, bt))
            self._eval_step = lambda p, s, bt: get("eval", bt)(p, s, bt)

    # ------------------------------------------------------------------
    def _loader(self, data: DGData):
        # With an out-of-core store, drop its resident pages after each
        # batch is handed off — hooks copy what they keep, so the epoch's
        # peak RSS stays near one window of the stream.
        on_batch = None
        if self._store is not None:
            store, tel = self._store, self.telemetry

            def on_batch():
                store.release()
                tel.count("storage/windows_released")

        loader = DGDataLoader(DGraph(data), self.manager,
                              batch_size=self.batch_size, on_batch=on_batch)
        if self.device_sampling:
            # Overlap hook pipeline + host->device staging of batch i+1 with
            # the jitted step on batch i (double-buffered by default). With
            # a sampler mesh, batches are staged with the mesh-replicated
            # NamedSharding so they land on the sharded state's device set.
            return PrefetchLoader(loader, device=self._replicated,
                                  prefetch=self.prefetch,
                                  telemetry=self.telemetry)
        return loader

    def _batch_tensors(self, batch) -> Dict[str, Any]:
        return {k: batch[k] for k in batch.keys()}

    def reset_epoch_state(self):
        """Clear hook/sampler state (+ recurrent model state) for an epoch."""
        self.manager.reset_state()
        if self.model_name == "tgn":
            self.model_state = tgn.init_state(self.cfg)
        elif self.model_name == "tpnet":
            self.model_state = tpnet.init_state(self.params, self.cfg)
        if self._mesh is not None and self.model_name in CTDG_STATEFUL:
            self.model_state = jax.device_put(self.model_state,
                                              self._replicated)

    # -- checkpointing ---------------------------------------------------
    # The hook/sampler buffers (host numpy or device JAX pytree — both
    # expose the same state_dict contract) ride along with params/optimizer
    # state, so a restored run resumes mid-stream with warm neighbor state.
    def save_checkpoint(self, ckpt_dir: str, step: int) -> str:
        """Write a checkpoint (atomic step directory). Returns its path."""
        tree = {
            "params": self.params,
            "opt_state": self.opt_state,
            "hooks": self.manager.state_dict(),
        }
        if self.model_name in CTDG_STATEFUL:
            tree["model_state"] = self.model_state
        return save_bundle(ckpt_dir, step, tree, self.model_name)

    def restore_checkpoint(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        """Restore params/opt/hook (+ model) state; returns the step."""
        target = {
            "params": self.params,
            "opt_state": self.opt_state,
        }
        if self.model_name in CTDG_STATEFUL:
            target["model_state"] = self.model_state
        tree, step = restore_bundle(ckpt_dir, step, target, self.model_name)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.manager.load_state_dict(tree["hooks"])
        if self.model_name in CTDG_STATEFUL:
            self.model_state = tree["model_state"]
        # Checkpoints are mesh-agnostic (canonical host layouts); re-commit
        # the restored trees onto this pipeline's mesh, whatever mesh (or
        # none) wrote them.
        self._place_replicated()
        return step

    def train_epoch(self) -> Tuple[float, float]:
        """One epoch over the train split. Returns (mean loss, seconds)."""
        tel = self.telemetry
        with tel.span("ctdg/epoch", model=self.model_name) as sp:
            self.reset_epoch_state()
            t0 = time.perf_counter()
            losses = []
            with self.manager.activate(TRAIN_KEY):
                for batch in self._loader(self.train_data):
                    bt = self._batch_tensors(batch)
                    # Dispatch time only: the jitted step is async, so the
                    # span bounds Python+dispatch; device time shows up as
                    # the next batch's wait (see docs/observability.md).
                    with tel.span("ctdg/step"):
                        if self.model_name in CTDG_STATELESS:
                            self.params, self.opt_state, loss = \
                                self._train_step(
                                    self.params, self.opt_state, bt)
                        else:
                            (self.params, self.opt_state, self.model_state,
                             loss) = self._train_step(
                                self.params, self.opt_state,
                                self.model_state, bt)
                    losses.append(loss)
            losses = [float(l) for l in losses]
            mean, secs = float(np.mean(losses)), time.perf_counter() - t0
            sp["loss"], sp["steps"] = mean, len(losses)
        return mean, secs

    def evaluate(self, split: str = "val") -> Tuple[float, float]:
        """One-vs-many MRR on val/test (warm state from train[, val])."""
        tel = self.telemetry
        with tel.span("ctdg/eval", split=split) as sp:
            self.reset_epoch_state()
            # Warm samplers/state through earlier splits w/o predicting.
            with tel.span("ctdg/warm"), self.manager.activate(TRAIN_KEY):
                warm = [self.train_data] + (
                    [self.val_data] if split == "test" else [])
                for d in warm:
                    for batch in self._loader(d):
                        bt = self._batch_tensors(batch)
                        if self.model_name in CTDG_STATEFUL:
                            _, self.model_state = self._eval_step(
                                self.params, self.model_state, bt
                            )
            data = self.val_data if split == "val" else self.test_data
            t0 = time.perf_counter()
            rrs, masks = [], []
            with self.manager.activate(EVAL_KEY):
                for batch in self._loader(data):
                    bt = self._batch_tensors(batch)
                    with tel.span("ctdg/eval_step"):
                        if self.model_name in CTDG_STATELESS:
                            pos, neg = self._eval_step(self.params, bt)
                        else:
                            (pos, neg), self.model_state = self._eval_step(
                                self.params, self.model_state, bt
                            )
                    w = float(bt["batch_mask"].sum())
                    rrs.append(mrr(pos, neg, bt["batch_mask"]) * w)
                    masks.append(w)
            out = float(np.sum(rrs) / max(np.sum(masks), 1.0))
            sp["mrr"] = out
        return out, time.perf_counter() - t0


# ----------------------------------------------------------------------
# Shared snapshot-pair plumbing (DTDG link + node pipelines)
# ----------------------------------------------------------------------
class SnapshotPairPipeline:
    """Shared base of the scan-compiled snapshot pipelines.

    Owns the plumbing every snapshot-pair task repeats: tensorizing the
    stream into a ``SnapshotTensor``, mapping chronological ``DGData.split``
    boundaries onto snapshot rows (a prediction pair ``p -> p+1`` belongs
    to the split containing its *predicted* snapshot ``p+1``), the
    ``_split_pairs`` ranges, and the FIFO-bounded scan-input cache.
    Subclasses (``DTDGLinkPipeline``, ``train.nodeprop.DTDGNodePipeline``)
    add their task's extra scan inputs and bodies on top.
    """

    # Scan inputs are pure functions of (snapshot tensor, task inputs);
    # cache the few ranges an epoch reuses, FIFO-evicting beyond this bound
    # so long-lived pipelines don't accumulate per-chunk device copies.
    _XS_CACHE_MAX = 8

    def _init_snapshots(self, data: DGData, unit, capacity, device,
                        val_ratio: float, test_ratio: float) -> None:
        """Tensorize ``data`` once and map split times to snapshot rows."""
        self.snapshots = snapshot_tensor(data, unit, capacity=capacity,
                                         device=device)
        self.capacity = self.snapshots.capacity
        T = self.snapshots.num_snapshots
        train_d, val_d, test_d = data.split(val_ratio, test_ratio)
        test_row = (
            self.snapshots.row_of_time(int(test_d.edge_t[0]))
            if test_d.num_edge_events else T
        )
        # An empty val split collapses onto the test boundary (val pairs
        # empty, test pairs intact) rather than swallowing the test split.
        val_row = (
            self.snapshots.row_of_time(int(val_d.edge_t[0]))
            if val_d.num_edge_events else test_row
        )
        self.set_split_rows(val_row, test_row)
        self._xs_cache: Dict[Tuple, Dict[str, Any]] = {}

    def set_split_rows(self, val_row: int, test_row: int) -> None:
        """Install (clamped) snapshot-row split boundaries — the first val
        row and the first test row. ``val_row == test_row`` means no val
        pairs (e.g. the legacy ``train_frac`` mapping)."""
        T = self.snapshots.num_snapshots
        self._val_row = min(max(val_row, 1), T)
        self._test_row = min(max(test_row, self._val_row), T)

    def _split_pairs(self, split: str) -> Tuple[int, int]:
        """Prediction-pair range ``[lo, hi)`` for a split."""
        T = self.snapshots.num_snapshots
        if split == "train":
            return 0, max(self._val_row - 1, 0)
        if split == "val":
            return max(self._val_row - 1, 0), max(self._test_row - 1, 0)
        if split == "test":
            return max(self._test_row - 1, 0), max(T - 1, 0)
        raise ValueError(f"unknown split {split!r}")

    def _pair_slices(self, lo: int, hi: int) -> Dict[str, Any]:
        """The stacked current/predicted snapshot arrays for pairs
        ``[lo, hi)`` (pair p = snapshot p -> p+1) — the scan inputs every
        snapshot-pair task shares."""
        st = self.snapshots
        return {
            "src": st.src[lo:hi], "dst": st.dst[lo:hi],
            "mask": st.mask[lo:hi],
            "nsrc": st.src[lo + 1:hi + 1], "ndst": st.dst[lo + 1:hi + 1],
            "nmask": st.mask[lo + 1:hi + 1],
        }

    def _xs_cached(self, key: Tuple, build) -> Dict[str, Any]:
        """FIFO-bounded memoization of a scan-input dict keyed by ``key``."""
        if key not in self._xs_cache:
            if len(self._xs_cache) >= self._XS_CACHE_MAX:
                self._xs_cache.pop(next(iter(self._xs_cache)))
            self._xs_cache[key] = build()
        return self._xs_cache[key]


# ----------------------------------------------------------------------
# DTDG link prediction: scan-compiled snapshot pipeline
# ----------------------------------------------------------------------
class DTDGLinkPipeline(SnapshotPairPipeline):
    """DTDG link prediction over the scan-compiled snapshot pipeline.

    Snapshot t's embeddings predict the edges of snapshot t+1. The stream is
    tensorized once into a device-resident ``SnapshotTensor``; with
    ``compiled=True`` (default) each split's epoch is one scanned jitted
    call (optionally chunked via ``chunk_size``), with ``compiled=False``
    the same body runs as a per-snapshot jitted loop through the
    ``RECIPE_DTDG_SNAPSHOT`` hook pipeline — the scan-vs-loop parity oracle.

    Splits are chronological ``DGData.split`` boundaries mapped to snapshot
    rows; a prediction pair belongs to the split that contains its
    *predicted* snapshot, and the recurrent state is carried across split
    boundaries by advance-only scans. Checkpoints bundle
    ``{params, opt_state[, model_state], hooks, pipeline}`` where
    ``pipeline`` holds the mid-epoch snapshot-pair cursor. See
    ``docs/dtdg.md`` for the full pipeline.
    """

    def __init__(
        self,
        model_name: str,
        data: DGData,
        snapshot_unit: TimeDelta | str = "h",
        d_embed: int = 128,
        lr: Optional[float] = None,
        num_negatives: int = 1,
        eval_negatives: int = 20,
        edge_capacity: Optional[int] = None,
        seed: int = 0,
        val_ratio: float = 0.15,
        test_ratio: float = 0.15,
        compiled: bool = True,
        chunk_size: Optional[int] = None,
        device=None,
        telemetry: Optional[Telemetry] = None,
    ):
        if model_name not in snapshot.SNAPSHOT_MODELS:
            raise ValueError(f"unknown DTDG model {model_name!r}")
        self.model_name = model_name
        self.data = data
        # Fresh instance (not the NULL singleton) so TrainLoop's history
        # sink never leaks onto unrelated pipelines.
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.unit = TimeDelta.coerce(snapshot_unit)
        self.num_negatives = num_negatives
        self.eval_negatives = eval_negatives
        self._seed = seed
        self.compiled = compiled
        self.chunk_size = chunk_size

        # Tensorize once (jitted discretize + scatter; core/loader.py) and
        # map the chronological split boundaries to snapshot rows.
        self._init_snapshots(data, self.unit, edge_capacity, device,
                             val_ratio, test_ratio)

        self.cfg = snapshot.SnapshotConfig(num_nodes=data.num_nodes, d_embed=d_embed)
        self.params = snapshot.init_params(
            model_name, jax.random.PRNGKey(seed), self.cfg
        )
        self._apply = snapshot.make_apply(model_name, self.cfg)
        self._has_state = model_name != "gcn"
        self.model_state = snapshot.init_state(model_name, self.cfg)

        self.manager = RecipeRegistry.build(
            RECIPE_DTDG_SNAPSHOT,
            num_nodes=data.num_nodes,
            capacity=self.capacity,
            num_negatives=num_negatives,
            eval_negatives=eval_negatives,
            seed=seed,
            device=device,
        )

        self.opt_cfg = AdamWConfig(lr=1e-3 if lr is None else lr)
        self.opt_state = adamw_init(self.params)
        self._cursor = 0  # next train pair (mid-epoch checkpoint resume)
        self._build_steps()

    # ------------------------------------------------------------------
    def _build_steps(self):
        apply = self._apply
        opt_cfg = self.opt_cfg

        def loss_fn(params, state, x):
            z, new_state = apply(params, x["src"], x["dst"], x["mask"], state)
            h_src = z[x["nsrc"]]
            pos = link_decoder(params["decoder"], h_src, z[x["ndst"]])
            neg = link_decoder(params["decoder"], h_src, z[x["neg"]])
            return bce_link_loss(pos, neg, x["nmask"]), new_state

        def train_body(carry, x):
            params, opt_state, state = carry
            (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, state, x
            )
            params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
            return (params, opt_state, new_state), loss

        def eval_body(params, state, x):
            z, new_state = apply(params, x["src"], x["dst"], x["mask"], state)
            h_src = z[x["nsrc"]]
            pos = link_decoder(params["decoder"], h_src, z[x["ndst"]])
            neg = link_decoder(params["decoder"], h_src, z[x["neg"]])
            return new_state, (pos, neg)

        def advance_body(params, state, x):
            _, new_state = apply(params, x["src"], x["dst"], x["mask"], state)
            return new_state

        # One jitted scan per split chunk (the compiled pipeline) and the
        # same bodies as standalone jitted per-snapshot steps (loop mode).
        self._train_scan = jax.jit(
            lambda p, o, s, xs: jax.lax.scan(train_body, (p, o, s), xs)
        )
        self._train_step = jax.jit(lambda p, o, s, x: train_body((p, o, s), x))
        self._eval_scan = jax.jit(
            lambda p, s, xs: jax.lax.scan(
                lambda st, x: eval_body(p, st, x), s, xs
            )
        )
        self._eval_step = jax.jit(eval_body)
        self._advance_scan = jax.jit(
            lambda p, s, xs: jax.lax.scan(
                lambda st, x: (advance_body(p, st, x), None), s, xs
            )[0]
        )
        self._advance_step = jax.jit(advance_body)

    # ------------------------------------------------------------------
    def _pair_xs(self, lo: int, hi: int, m: int) -> Dict[str, Any]:
        """Stacked scan inputs for prediction pairs ``[lo, hi)`` (pair p =
        snapshot p -> p+1) with ``m`` negatives per predicted edge."""
        def build():
            rows = np.arange(lo + 1, hi + 1)
            return {**self._pair_slices(lo, hi),
                    "neg": self.snapshots.negatives(self._seed, m, rows)}

        return self._xs_cached((lo, hi, m), build)

    def _pair_x(self, p: int, neg) -> Dict[str, Any]:
        """One pair's arrays (loop mode), with hook-produced negatives."""
        st = self.snapshots
        return {
            "src": st.src[p], "dst": st.dst[p], "mask": st.mask[p],
            "nsrc": st.src[p + 1], "ndst": st.dst[p + 1],
            "nmask": st.mask[p + 1], "neg": neg,
        }

    def _hook_negatives(self, p: int):
        """Run the predicted snapshot through the active hook pipeline and
        return its ``neg`` draws (identical to the scan path's bulk draw)."""
        from repro.core.batch import Batch

        st = self.snapshots
        batch = Batch(
            {"src": st.src[p + 1], "dst": st.dst[p + 1],
             "time": np.full(st.capacity, (st.t0 + p + 1) * st.ticks,
                             dtype=np.int64),
             "snap_mask": st.mask[p + 1]},
            meta={"snapshot_row": p + 1},
        )
        return self.manager.execute(batch)["neg"]

    def _chunks(self, lo: int, hi: int):
        step = self.chunk_size or max(hi - lo, 1)
        for start in range(lo, hi, step):
            yield start, min(start + step, hi)

    def reset_epoch_state(self):
        """Reset hook cursors and the recurrent state (start of an epoch)."""
        self.manager.reset_state()
        self.model_state = snapshot.init_state(self.model_name, self.cfg)

    @property
    def snapshot_cursor(self) -> int:
        """Next train snapshot pair to run — the mid-epoch resume cursor
        carried in checkpoints as ``pipeline/snapshot_cursor``."""
        return self._cursor

    # ------------------------------------------------------------------
    def train_chunk(self) -> Optional[list]:
        """Run ONE compiled chunk from the current snapshot cursor.

        The kill/resume granule of the scan pipeline: each call scans the
        next ``chunk_size`` snapshot pairs, advances ``_cursor`` (the value
        checkpointed as ``pipeline.snapshot_cursor``), and returns the
        chunk's per-pair losses. Returns ``None`` once the train split is
        exhausted (and zeroes the cursor so the next call starts a fresh
        epoch). A checkpoint written between calls restores to exactly this
        boundary, which is what makes mid-epoch kill + resume bit-identical
        to an uninterrupted run. Compiled mode only."""
        if not self.compiled:
            raise RuntimeError("train_chunk requires compiled=True")
        lo, hi = self._split_pairs("train")
        start = max(self._cursor, lo)
        if start >= hi:
            self._cursor = 0
            return None
        if self._cursor == 0:
            self.reset_epoch_state()
        chi = min(start + (self.chunk_size or max(hi - lo, 1)), hi)
        with self.telemetry.span("dtdg/chunk", lo=start, hi=chi):
            xs = self._pair_xs(start, chi, self.num_negatives)
            (self.params, self.opt_state, self.model_state), ls = \
                self._train_scan(self.params, self.opt_state,
                                 self.model_state, xs)
        self._cursor = chi
        return [float(l) for l in np.asarray(ls)]

    def train_epoch(self) -> Tuple[float, float]:
        """One epoch over the train split. Returns (mean loss, seconds).

        ``compiled=True``: one scanned jitted call per chunk (default: the
        whole split in one call). A restored mid-epoch snapshot cursor
        resumes from where the checkpoint left off.
        """
        tel = self.telemetry
        with tel.span("dtdg/epoch", model=self.model_name,
                      compiled=self.compiled) as sp:
            lo, hi = self._split_pairs("train")
            if self._cursor == 0:
                self.reset_epoch_state()
            start = max(self._cursor, lo)
            t0 = time.perf_counter()
            losses = []
            if self.compiled:
                while True:
                    chunk_losses = self.train_chunk()
                    if chunk_losses is None:
                        break
                    losses.extend(chunk_losses)
            else:
                with self.manager.activate(TRAIN_KEY):
                    for p in range(start, hi):
                        x = self._pair_x(p, self._hook_negatives(p))
                        with tel.span("dtdg/step"):
                            (self.params, self.opt_state,
                             self.model_state), loss = self._train_step(
                                self.params, self.opt_state,
                                self.model_state, x)
                        losses.append(float(loss))
                        self._cursor = p + 1
            self._cursor = 0
            secs = time.perf_counter() - t0
            mean = float(np.mean(losses)) if losses else 0.0
            sp["loss"], sp["pairs"] = mean, len(losses)
        return mean, secs

    def evaluate(self, split: str = "val") -> Tuple[float, float]:
        """One-vs-many MRR on val/test. Returns (MRR, seconds).

        The recurrent state is warmed through all earlier snapshots with an
        advance-only scan (carried across the split boundary), then the
        split's pairs are scored in one scanned call per chunk.
        """
        tel = self.telemetry
        with tel.span("dtdg/eval", split=split) as sp:
            lo, hi = self._split_pairs(split)
            self.manager.reset_state()
            t0 = time.perf_counter()
            # Local state: evaluation re-warms from scratch and must not
            # clobber a mid-epoch training state (checkpoint-resume safety).
            state = snapshot.init_state(self.model_name, self.cfg)
            if self._has_state and lo > 0:
                if self.compiled:
                    st = self.snapshots
                    warm = {"src": st.src[:lo], "dst": st.dst[:lo],
                            "mask": st.mask[:lo]}
                    state = self._advance_scan(self.params, state, warm)
                else:
                    st = self.snapshots
                    for p in range(lo):
                        state = self._advance_step(
                            self.params, state,
                            {"src": st.src[p], "dst": st.dst[p],
                             "mask": st.mask[p]},
                        )
            pos_rows, neg_rows, mask_rows = [], [], []
            if self.compiled:
                for clo, chi in self._chunks(lo, hi):
                    xs = self._pair_xs(clo, chi, self.eval_negatives)
                    state, (pos, neg) = self._eval_scan(self.params, state,
                                                        xs)
                    pos_rows.extend(np.asarray(pos))
                    neg_rows.extend(np.asarray(neg))
                    mask_rows.extend(np.asarray(xs["nmask"]))
            else:
                with self.manager.activate(EVAL_KEY):
                    for p in range(lo, hi):
                        x = self._pair_x(p, self._hook_negatives(p))
                        state, (pos, neg) = self._eval_step(self.params,
                                                            state, x)
                        pos_rows.append(np.asarray(pos))
                        neg_rows.append(np.asarray(neg))
                        mask_rows.append(np.asarray(x["nmask"]))
            out = weighted_mrr(pos_rows, neg_rows, mask_rows)
            sp["mrr"] = out
        return out, time.perf_counter() - t0

    # -- checkpointing ---------------------------------------------------
    # Same composable contract as CTDGLinkPipeline: params + optimizer
    # state + recurrent model state + hook cursors + the snapshot-pair
    # cursor, so a restored run resumes mid-epoch at the right snapshot
    # with the right negative draws.
    def _ckpt_tree(self) -> Dict[str, Any]:
        tree = {
            "params": self.params,
            "opt_state": self.opt_state,
            "hooks": self.manager.state_dict(),
            "pipeline": {"snapshot_cursor": np.int64(self._cursor)},
        }
        if self._has_state:
            tree["model_state"] = self.model_state
        return tree

    def save_checkpoint(self, ckpt_dir: str, step: int) -> str:
        """Write a checkpoint (atomic step directory). Returns its path."""
        return save_bundle(ckpt_dir, step, self._ckpt_tree(), self.model_name,
                           trainer="snapshot")

    def restore_checkpoint(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        """Restore params/opt/model state, hook cursors and the snapshot
        cursor; returns the checkpoint step."""
        target = {k: v for k, v in self._ckpt_tree().items() if k != "hooks"}
        tree, step = restore_bundle(ckpt_dir, step, target, self.model_name)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.manager.load_state_dict(tree["hooks"])
        self._cursor = int(np.asarray(tree["pipeline"]["snapshot_cursor"]))
        if self._has_state:
            self.model_state = tree["model_state"]
        return step

    def run_epoch(self, train_frac: Optional[float] = None,
                  train: bool = True) -> Tuple[float, float]:
        """Legacy shim: ``train=True`` -> ``train_epoch()``; otherwise
        ``evaluate('val')``. ``train_frac`` is ignored — splits now come
        from ``DGData.split`` (chronological val/test ratios) — so an
        explicitly passed value warns loudly instead of silently changing
        which snapshots are scored."""
        if train_frac is not None:
            import warnings

            warnings.warn(
                "run_epoch(train_frac=...) is ignored; splits come from "
                "DGData.split — pass val_ratio/test_ratio to the pipeline "
                "and use train_epoch()/evaluate() instead",
                DeprecationWarning,
                stacklevel=2,
            )
        if train:
            return self.train_epoch()
        return self.evaluate("val")
