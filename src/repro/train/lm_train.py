"""LM training step: loss + grad + clip + AdamW, GSPMD-shardable.

The same ``train_step`` serves real (small-scale) training and the
multi-pod dry-run: parameters, optimizer state, and batch arrive either as
real arrays or as ShapeDtypeStructs with NamedShardings.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.lm import model as M
from repro.models.lm.params import Spec, abstract, tree_shardings
from repro.optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig = AdamWConfig(lr=3e-4),
                    clip_norm: float = 1.0, kv_block: int = 1024,
                    ce_chunks: int = 0, accum_steps: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum_steps > 1``: gradient-accumulation microbatching — the global
    batch splits into ``accum_steps`` microbatches scanned sequentially;
    live activation memory scales 1/accum_steps at identical roofline
    terms, and each microbatch's gradient reduce-scatter overlaps the next
    microbatch's backward (XLA latency hiding).
    """

    def loss_of(params, batch):
        return M.loss_fn(params, cfg, batch, kv_block=kv_block,
                         ce_chunks=ce_chunks)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]),
                batch,
            )

            def body(carry, mb):
                grads_acc, loss_acc = carry
                loss, grads = jax.value_and_grad(loss_of)(params, mb)
                return (jax.tree.map(jnp.add, grads_acc, grads),
                        loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def abstract_opt_state(cfg: ArchConfig, mesh=None, rules=None):
    """ShapeDtypeStructs for AdamW state, sharded like the parameters
    (ZeRO: moments inherit the FSDP/TP param sharding)."""
    specs = M.param_specs(cfg)

    def f32(spec: Spec):
        return Spec(spec.shape, spec.axes, spec.init, spec.scale)

    f32_specs = jax.tree.map(f32, specs, is_leaf=lambda x: isinstance(x, Spec))
    mom = abstract(f32_specs, mesh, rules, jnp.float32)
    return {
        "mu": mom,
        "nu": jax.tree.map(lambda s: s, mom),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_opt_state(params):
    return adamw_init(params)
