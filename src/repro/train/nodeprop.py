"""Dynamic node property prediction (TGB nodeprop-style, paper Table 4).

Task (genre-like): for each user node, predict the distribution of its
interactions over destination categories in the *next* time window, scored
with NDCG@10 against the realized distribution.

Models:
  * ``pf``  — Persistent Forecast (previous window's distribution);
  * ``tgn`` — TGN memory embeddings + linear head, trained online with a
              soft cross-entropy on next-window distributions;
  * ``gcn`` — snapshot GCN embeddings + linear head.
"""

from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DGData, DGraph, DGDataLoader, TimeDelta
from repro.models.tg import snapshot, tgn
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train.metrics import ndcg_at_k


def _window_labels(data: DGData, unit: TimeDelta, num_nodes: int,
                   num_cats: int, cat_of_dst: np.ndarray):
    """Per (window, user) -> category distribution; yields consecutive
    (window_events, next_window_user_dist) pairs."""
    loader = DGDataLoader(DGraph(data), None, batch_size=None, batch_unit=unit,
                          emit_empty=True)
    windows = []
    for b in loader:
        counts = np.zeros((num_nodes, num_cats), np.float32)
        if b.num_events:
            np.add.at(counts, (b["src"], cat_of_dst[b["dst"]]), 1.0)
        windows.append((b, counts))
    return windows


class NodePropertyTrainer:
    def __init__(self, model_name: str, data: DGData, unit: TimeDelta | str = "d",
                 num_cats: Optional[int] = None, d_embed: int = 32, lr: float = 1e-3,
                 seed: int = 0):
        if model_name not in ("pf", "tgn", "gcn"):
            raise ValueError(model_name)
        self.model_name = model_name
        self.data = data
        self.unit = TimeDelta.coerce(unit)
        self.n = data.num_nodes
        # categories = hashed destination buckets (genre-like)
        dsts = np.unique(data.dst)
        self.num_cats = num_cats or min(32, len(dsts))
        self.cat_of_dst = np.zeros(self.n, np.int64)
        self.cat_of_dst[dsts] = np.arange(len(dsts)) % self.num_cats
        self._rng = np.random.default_rng(seed)

        key = jax.random.PRNGKey(seed)
        if model_name == "tgn":
            self.cfg = tgn.TGNConfig(num_nodes=self.n, d_edge=0, d_model=d_embed,
                                     d_time=16, d_memory=d_embed, k=4)
            self.params = {
                "tgn": tgn.init(key, self.cfg),
                "head": jax.random.normal(key, (d_embed, self.num_cats)) * 0.05,
            }
        elif model_name == "gcn":
            self.cfg = snapshot.SnapshotConfig(num_nodes=self.n, d_node=d_embed,
                                               d_embed=d_embed)
            self.params = {
                "gcn": snapshot.gcn_model_init(key, self.cfg),
                "head": jax.random.normal(key, (d_embed, self.num_cats)) * 0.05,
            }
        else:
            self.params = None
        if self.params is not None:
            self.opt_cfg = AdamWConfig(lr=lr)
            self.opt = adamw_init(self.params)
        self._build()

    def _build(self):
        if self.model_name == "tgn":
            cfg = self.cfg

            def loss_fn(params, state, batch, labels, active):
                h = tgn.embed(params["tgn"], cfg, state, batch)
                logits = h @ params["head"]  # (S, C)
                logp = jax.nn.log_softmax(logits, -1)
                tgt = labels / jnp.maximum(labels.sum(-1, keepdims=True), 1.0)
                loss = -(tgt * logp).sum(-1)
                loss = (loss * active).sum() / jnp.maximum(active.sum(), 1.0)
                new_state = tgn.update_memory(params["tgn"], cfg, state, batch)
                return loss, new_state

            @jax.jit
            def train_step(params, opt, state, batch, labels, active):
                (loss, new_state), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, state, batch, labels, active)
                params, opt = adamw_update(params, g, opt, self.opt_cfg)
                return params, opt, new_state, loss

            @jax.jit
            def predict(params, state, batch):
                h = tgn.embed(params["tgn"], cfg, state, batch)
                new_state = tgn.update_memory(params["tgn"], cfg, state, batch)
                return jax.nn.softmax(h @ params["head"], -1), new_state

            self._train_step, self._predict = train_step, predict

        elif self.model_name == "gcn":
            cfg = self.cfg

            def loss_fn(params, snap, labels, active):
                z = snapshot.gcn_model_apply(params["gcn"], cfg, snap["src"],
                                             snap["dst"], snap["mask"])
                logp = jax.nn.log_softmax(z @ params["head"], -1)
                tgt = labels / jnp.maximum(labels.sum(-1, keepdims=True), 1.0)
                loss = -(tgt * logp).sum(-1)
                return (loss * active).sum() / jnp.maximum(active.sum(), 1.0)

            @jax.jit
            def train_step(params, opt, snap, labels, active):
                loss, g = jax.value_and_grad(loss_fn)(params, snap, labels, active)
                params, opt = adamw_update(params, g, opt, self.opt_cfg)
                return params, opt, loss

            @jax.jit
            def predict(params, snap):
                z = snapshot.gcn_model_apply(params["gcn"], cfg, snap["src"],
                                             snap["dst"], snap["mask"])
                return jax.nn.softmax(z @ params["head"], -1)

            self._train_step, self._predict = train_step, predict

    # ------------------------------------------------------------------
    def run(self, train_frac: float = 0.7, k_eval: int = 10) -> Tuple[float, float]:
        """Returns (test NDCG@10, seconds)."""
        windows = _window_labels(self.data, self.unit, self.n, self.num_cats,
                                 self.cat_of_dst)
        n_train = max(1, int(len(windows) * train_frac))
        t0 = time.perf_counter()

        if self.model_name == "pf":
            last = np.zeros((self.n, self.num_cats), np.float32)
            scores = []
            for i in range(len(windows) - 1):
                _, counts = windows[i]
                nxt = windows[i + 1][1]
                if i + 1 >= n_train:
                    active = nxt.sum(-1) > 0
                    if active.any():
                        scores.append(ndcg_at_k(last[active], nxt[active], k_eval))
                last = np.where(counts.sum(-1, keepdims=True) > 0, counts, last)
            return float(np.mean(scores)) if scores else 0.0, time.perf_counter() - t0

        if self.model_name == "tgn":
            state = tgn.init_state(self.cfg)
            scores = []
            for i in range(len(windows) - 1):
                b, _ = windows[i]
                nxt = windows[i + 1][1]
                if b.num_events == 0:
                    continue
                batch = self._tgn_batch(b)
                labels = jnp.asarray(nxt[np.asarray(batch["seed_user"])])
                active = (labels.sum(-1) > 0).astype(jnp.float32)
                if i + 1 < n_train:
                    self.params, self.opt, state, _ = self._train_step(
                        self.params, self.opt, state, batch, labels, active)
                else:
                    probs, state = self._predict(self.params, state, batch)
                    a = np.asarray(active, bool)
                    if a.any():
                        scores.append(ndcg_at_k(np.asarray(probs)[a],
                                                np.asarray(labels)[a], k_eval))
            return float(np.mean(scores)) if scores else 0.0, time.perf_counter() - t0

        # gcn
        scores = []
        for i in range(len(windows) - 1):
            b, _ = windows[i]
            nxt = jnp.asarray(windows[i + 1][1])
            src, dst, mask = snapshot.pad_snapshot(b.get("src", np.zeros(0, np.int64)),
                                                   b.get("dst", np.zeros(0, np.int64)),
                                                   1 << int(np.ceil(np.log2(max(b.num_events, 2)))))
            snap = {"src": jnp.asarray(src), "dst": jnp.asarray(dst),
                    "mask": jnp.asarray(mask)}
            active = (nxt.sum(-1) > 0).astype(jnp.float32)
            if i + 1 < n_train:
                self.params, self.opt, _ = self._train_step(
                    self.params, self.opt, snap, nxt, active)
            else:
                probs = self._predict(self.params, snap)
                a = np.asarray(active, bool)
                if a.any():
                    scores.append(ndcg_at_k(np.asarray(probs)[a],
                                            np.asarray(nxt)[a], k_eval))
        return float(np.mean(scores)) if scores else 0.0, time.perf_counter() - t0

    def _tgn_batch(self, b) -> Dict:
        """Materialize a TGN batch for node prediction: seeds = the window's
        active users; neighbors from a host-side recency buffer. Shapes are
        power-of-two bucketed so XLA compiles a handful of variants."""
        if not hasattr(self, "_sampler"):
            from repro.core import RecencySampler

            self._sampler = RecencySampler(self.n, self.cfg.k)
        users = np.unique(b["src"])
        blk = self._sampler.sample(users)
        t_ref = np.full(len(users), int(b["time"].max()), np.int64)
        self._sampler.update(b["src"], b["dst"], b["time"])

        def p2(n):
            return 1 << int(np.ceil(np.log2(max(n, 2))))

        ucap, ecap = p2(len(users)), p2(b.num_events)
        upad, epad = ucap - len(users), ecap - b.num_events
        emask = np.zeros(ecap, bool)
        emask[: b.num_events] = True
        return {
            "src": jnp.asarray(np.pad(b["src"], (0, epad))),
            "dst": jnp.asarray(np.pad(b["dst"], (0, epad))),
            "time": jnp.asarray(np.pad(b["time"], (0, epad))),
            "batch_mask": jnp.asarray(emask),
            "seed_nodes": jnp.asarray(np.pad(users, (0, upad))),
            "seed_times": jnp.asarray(np.pad(t_ref, (0, upad))),
            "nbr_ids": jnp.asarray(np.pad(blk.nbr_ids, ((0, upad), (0, 0)),
                                          constant_values=-1)),
            "nbr_times": jnp.asarray(np.pad(blk.nbr_times, ((0, upad), (0, 0)))),
            "nbr_mask": jnp.asarray(np.pad(blk.mask, ((0, upad), (0, 0)))),
            "seed_user": jnp.asarray(np.pad(users, (0, upad))),
        }
