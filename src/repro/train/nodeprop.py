"""Dynamic node property prediction (TGB nodeprop-style, paper Table 4).

Task (genre-like): for each user node, predict the distribution of its
interactions over destination categories in the *next* time window, scored
with NDCG@10 against the realized distribution.

Two pipeline families share the ``TrainLoop`` surface
(``train_epoch``/``evaluate``/checkpointing):

  * ``DTDGNodePipeline``  — snapshot models (GCN, GCLSTM, T-GCN) + linear
    head over the device-resident ``SnapshotTensor`` view: the stream is
    tensorized once and a training epoch is ONE ``lax.scan`` jitted call
    (labels are scattered from the *next* snapshot's edges inside the scan
    body, so no host label materialization at all). ``compiled=False``
    runs the same body as a per-snapshot jitted loop — the scan-vs-loop
    bit-parity oracle. This closes the ROADMAP item "scan-compiled
    NodePropertyTrainer".
  * ``EventNodePipeline`` — the host window-loop baselines: ``pf``
    (persistent forecast) and ``tgn`` (memory embeddings + linear head
    over event windows with recency neighbors).

``NodePropertyTrainer`` is the legacy shim: it dispatches on the model
name (``pf``/``tgn`` -> event windows, snapshot models -> the scanned
pipeline) and keeps the historical ``run(train_frac)`` one-shot API. New
code should use ``repro.tg.Experiment`` with ``task="node"``.

Note the snapshot family's labels count *unique* ``(window, src, dst)``
interactions (the ``SnapshotTensor`` view collapses duplicate event
classes at the window granularity, paper Def. 3.5), while the event-window
family counts raw event multiplicity.
"""

from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DGData, DGraph, DGDataLoader, TimeDelta
from repro.models.tg import snapshot, tgn
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train.loop import (
    SnapshotPairPipeline,
    restore_bundle,
    save_bundle,
)
from repro.train.metrics import ndcg_at_k


def _window_labels(data: DGData, unit: TimeDelta, num_nodes: int,
                   num_cats: int, cat_of_dst: np.ndarray):
    """Per (window, user) -> category distribution; yields consecutive
    (window_events, next_window_user_dist) pairs."""
    loader = DGDataLoader(DGraph(data), None, batch_size=None, batch_unit=unit,
                          emit_empty=True)
    windows = []
    for b in loader:
        counts = np.zeros((num_nodes, num_cats), np.float32)
        if b.num_events:
            np.add.at(counts, (b["src"], cat_of_dst[b["dst"]]), 1.0)
        windows.append((b, counts))
    return windows


def _category_map(data: DGData, num_cats: Optional[int]) -> Tuple[int, np.ndarray]:
    """Hashed destination buckets (genre-like): ``(num_cats, cat_of_dst)``."""
    dsts = np.unique(data.dst)
    c = num_cats or min(32, len(dsts))
    cat = np.zeros(data.num_nodes, np.int64)
    cat[dsts] = np.arange(len(dsts)) % c
    return c, cat


# ----------------------------------------------------------------------
# DTDG: scan-compiled snapshot node property pipeline
# ----------------------------------------------------------------------
class DTDGNodePipeline(SnapshotPairPipeline):
    """Scan-compiled node property prediction over ``SnapshotTensor``.

    Snapshot t's per-node embeddings (any ``models.tg.snapshot`` registry
    model + a linear category head) predict each active user's category
    distribution in snapshot t+1, trained with a soft cross-entropy and
    scored with NDCG@10. With ``compiled=True`` an epoch over the train
    rows is one ``lax.scan`` jitted call (AdamW update inside the body;
    labels scattered from the next row's edges in-scan); with
    ``compiled=False`` the same body runs as a per-snapshot jitted loop —
    bit-identical, the parity oracle.

    Splits map ``DGData.split`` boundaries to snapshot rows through the
    shared ``SnapshotPairPipeline`` base (a prediction pair belongs to the
    split holding its *predicted* snapshot); recurrent state is warmed
    across split boundaries by advance-only scans.
    """

    def __init__(
        self,
        model_name: str,
        data: DGData,
        unit: TimeDelta | str = "d",
        num_cats: Optional[int] = None,
        d_embed: int = 32,
        lr: Optional[float] = None,
        seed: int = 0,
        val_ratio: float = 0.15,
        test_ratio: float = 0.15,
        capacity: Optional[int] = None,
        compiled: bool = True,
        device=None,
    ):
        if model_name not in snapshot.SNAPSHOT_MODELS:
            raise ValueError(
                f"unknown snapshot model {model_name!r}; "
                f"have {snapshot.SNAPSHOT_MODELS}"
            )
        self.model_name = model_name
        self.data = data
        self.unit = TimeDelta.coerce(unit)
        self.n = data.num_nodes
        self.compiled = compiled
        self.num_cats, self.cat_of_dst = _category_map(data, num_cats)
        self._cat_dev = jnp.asarray(self.cat_of_dst, jnp.int32)

        self._init_snapshots(data, self.unit, capacity, device,
                             val_ratio, test_ratio)

        key = jax.random.PRNGKey(seed)
        self.cfg = snapshot.SnapshotConfig(num_nodes=self.n, d_node=d_embed,
                                           d_embed=d_embed)
        self.params = {
            "m": snapshot.init_params(model_name, key, self.cfg),
            "head": jax.random.normal(key, (d_embed, self.num_cats)) * 0.05,
        }
        self._apply = snapshot.make_apply(model_name, self.cfg)
        self._has_state = model_name != "gcn"
        self.model_state = snapshot.init_state(model_name, self.cfg)

        self.opt_cfg = AdamWConfig(lr=1e-3 if lr is None else lr)
        self.opt_state = adamw_init(self.params)
        self._build_steps()

    # ------------------------------------------------------------------
    def _build_steps(self):
        apply = self._apply
        opt_cfg = self.opt_cfg
        n, c = self.n, self.num_cats
        cat = self._cat_dev

        def labels_of(x):
            # Next-window category counts, scattered on device from the
            # predicted snapshot's (deduplicated) edges.
            lab = jnp.zeros((n, c), jnp.float32)
            return lab.at[x["nsrc"], cat[x["ndst"]]].add(
                x["nmask"].astype(jnp.float32)
            )

        def forward(params, state, x):
            z, new_state = apply(params["m"], x["src"], x["dst"], x["mask"],
                                 state)
            return z @ params["head"], new_state

        def loss_fn(params, state, x):
            logits, new_state = forward(params, state, x)
            labels = labels_of(x)
            active = (labels.sum(-1) > 0).astype(jnp.float32)
            logp = jax.nn.log_softmax(logits, -1)
            tgt = labels / jnp.maximum(labels.sum(-1, keepdims=True), 1.0)
            loss = -(tgt * logp).sum(-1)
            loss = (loss * active).sum() / jnp.maximum(active.sum(), 1.0)
            return loss, new_state

        def train_body(carry, x):
            params, opt_state, state = carry
            (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, state, x
            )
            params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
            return (params, opt_state, new_state), loss

        def eval_body(params, state, x):
            logits, new_state = forward(params, state, x)
            return new_state, (jax.nn.softmax(logits, -1), labels_of(x))

        def advance_body(params, state, x):
            _, new_state = apply(params["m"], x["src"], x["dst"], x["mask"],
                                 state)
            return new_state

        self._train_scan = jax.jit(
            lambda p, o, s, xs: jax.lax.scan(train_body, (p, o, s), xs)
        )
        self._train_step = jax.jit(lambda p, o, s, x: train_body((p, o, s), x))
        self._eval_scan = jax.jit(
            lambda p, s, xs: jax.lax.scan(lambda st, x: eval_body(p, st, x), s, xs)
        )
        self._eval_step = jax.jit(eval_body)
        self._advance_scan = jax.jit(
            lambda p, s, xs: jax.lax.scan(
                lambda st, x: (advance_body(p, st, x), None), s, xs
            )[0]
        )

    # ------------------------------------------------------------------
    def _pair_xs(self, lo: int, hi: int) -> Dict[str, Any]:
        """Stacked scan inputs for prediction pairs ``[lo, hi)``."""
        return self._xs_cached((lo, hi), lambda: self._pair_slices(lo, hi))

    def _pair_x(self, p: int) -> Dict[str, Any]:
        """One pair's arrays (loop mode)."""
        st = self.snapshots
        return {
            "src": st.src[p], "dst": st.dst[p], "mask": st.mask[p],
            "nsrc": st.src[p + 1], "ndst": st.dst[p + 1],
            "nmask": st.mask[p + 1],
        }

    def reset_epoch_state(self):
        """Reset the recurrent state (start of an epoch)."""
        self.model_state = snapshot.init_state(self.model_name, self.cfg)

    # ------------------------------------------------------------------
    def train_epoch(self) -> Tuple[float, float]:
        """One epoch over the train rows. Returns (mean loss, seconds).

        ``compiled=True``: the whole epoch is one scanned jitted call.
        """
        lo, hi = self._split_pairs("train")
        self.reset_epoch_state()
        t0 = time.perf_counter()
        if hi <= lo:
            return 0.0, time.perf_counter() - t0
        if self.compiled:
            xs = self._pair_xs(lo, hi)
            (self.params, self.opt_state, self.model_state), ls = \
                self._train_scan(self.params, self.opt_state,
                                 self.model_state, xs)
            losses = [float(l) for l in np.asarray(ls)]
        else:
            losses = []
            for p in range(lo, hi):
                (self.params, self.opt_state, self.model_state), loss = \
                    self._train_step(self.params, self.opt_state,
                                     self.model_state, self._pair_x(p))
                losses.append(float(loss))
        return float(np.mean(losses)), time.perf_counter() - t0

    def evaluate(self, split: str = "test", k_eval: int = 10) -> Tuple[float, float]:
        """NDCG@``k_eval`` over a split's prediction pairs.

        Recurrent state is warmed through all earlier snapshots with an
        advance-only scan; each pair's probabilities and realized next-
        window distributions come back from one scanned call, and NDCG is
        averaged over the windows with at least one active user (matching
        the historical host trainer's aggregation).
        """
        lo, hi = self._split_pairs(split)
        t0 = time.perf_counter()
        state = snapshot.init_state(self.model_name, self.cfg)
        if self._has_state and lo > 0:
            st = self.snapshots
            state = self._advance_scan(
                self.params, state,
                {"src": st.src[:lo], "dst": st.dst[:lo], "mask": st.mask[:lo]},
            )
        rows = []
        if hi > lo:
            if self.compiled:
                _, (probs, labels) = self._eval_scan(self.params, state,
                                                     self._pair_xs(lo, hi))
                probs, labels = np.asarray(probs), np.asarray(labels)
                rows = list(zip(probs, labels))
            else:
                for p in range(lo, hi):
                    state, (pr, lab) = self._eval_step(self.params, state,
                                                       self._pair_x(p))
                    rows.append((np.asarray(pr), np.asarray(lab)))
        scores = []
        for pr, lab in rows:
            active = lab.sum(-1) > 0
            if active.any():
                scores.append(ndcg_at_k(pr[active], lab[active], k_eval))
        out = float(np.mean(scores)) if scores else 0.0
        return out, time.perf_counter() - t0

    # -- checkpointing ---------------------------------------------------
    def _ckpt_tree(self) -> Dict[str, Any]:
        tree = {"params": self.params, "opt_state": self.opt_state,
                "hooks": {}}
        if self._has_state:
            tree["model_state"] = self.model_state
        return tree

    def save_checkpoint(self, ckpt_dir: str, step: int) -> str:
        """Write a checkpoint (atomic step directory). Returns its path."""
        return save_bundle(ckpt_dir, step, self._ckpt_tree(), self.model_name,
                           trainer="nodeprop")

    def restore_checkpoint(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        """Restore params/opt (+ recurrent) state; returns the step."""
        target = {k: v for k, v in self._ckpt_tree().items() if k != "hooks"}
        tree, step = restore_bundle(ckpt_dir, step, target, self.model_name)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        if self._has_state:
            self.model_state = tree["model_state"]
        return step


# ----------------------------------------------------------------------
# CTDG: host window-loop baselines (persistent forecast, windowed TGN)
# ----------------------------------------------------------------------
class EventNodePipeline:
    """Host window-loop node property prediction (``pf`` / windowed TGN).

    Iterates the event stream by time windows (``DGDataLoader`` iterate-by-
    time with empty windows emitted); ``tgn`` embeds each window's active
    users with memory + recency neighbors and trains a linear category head
    online, ``pf`` forecasts each user's previous window distribution.
    ``train_epoch``/``evaluate`` expose the shared pipeline surface;
    ``run_online`` keeps the historical single-pass train-then-score
    behavior bit-for-bit.
    """

    def __init__(self, model_name: str, data: DGData,
                 unit: TimeDelta | str = "d", num_cats: Optional[int] = None,
                 d_embed: int = 32, lr: Optional[float] = None, seed: int = 0,
                 val_ratio: float = 0.15, test_ratio: float = 0.15):
        if model_name not in ("pf", "tgn"):
            raise ValueError(f"unknown event node model {model_name!r}")
        self.model_name = model_name
        self.data = data
        self.unit = TimeDelta.coerce(unit)
        self.n = data.num_nodes
        self.num_cats, self.cat_of_dst = _category_map(data, num_cats)
        self._train_frac = max(1.0 - val_ratio - test_ratio, 0.0)
        self._val_frac = max(1.0 - test_ratio, 0.0)
        self._windows = None

        key = jax.random.PRNGKey(seed)
        if model_name == "tgn":
            self.cfg = tgn.TGNConfig(num_nodes=self.n, d_edge=0, d_model=d_embed,
                                     d_time=16, d_memory=d_embed, k=4)
            self.params = {
                "tgn": tgn.init(key, self.cfg),
                "head": jax.random.normal(key, (d_embed, self.num_cats)) * 0.05,
            }
            self.opt_cfg = AdamWConfig(lr=1e-3 if lr is None else lr)
            self.opt = adamw_init(self.params)
            self._build()
        else:
            self.params = None

    def _build(self):
        cfg = self.cfg

        def loss_fn(params, state, batch, labels, active):
            h = tgn.embed(params["tgn"], cfg, state, batch)
            logits = h @ params["head"]  # (S, C)
            logp = jax.nn.log_softmax(logits, -1)
            tgt = labels / jnp.maximum(labels.sum(-1, keepdims=True), 1.0)
            loss = -(tgt * logp).sum(-1)
            loss = (loss * active).sum() / jnp.maximum(active.sum(), 1.0)
            new_state = tgn.update_memory(params["tgn"], cfg, state, batch)
            return loss, new_state

        @jax.jit
        def train_step(params, opt, state, batch, labels, active):
            (loss, new_state), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, state, batch, labels, active)
            params, opt = adamw_update(params, g, opt, self.opt_cfg)
            return params, opt, new_state, loss

        @jax.jit
        def predict(params, state, batch):
            h = tgn.embed(params["tgn"], cfg, state, batch)
            new_state = tgn.update_memory(params["tgn"], cfg, state, batch)
            return jax.nn.softmax(h @ params["head"], -1), new_state

        self._train_step, self._predict = train_step, predict

    # ------------------------------------------------------------------
    def windows(self):
        """Materialized (window batch, label counts) pairs, cached."""
        if self._windows is None:
            self._windows = _window_labels(self.data, self.unit, self.n,
                                           self.num_cats, self.cat_of_dst)
        return self._windows

    def _bounds(self) -> Tuple[int, int]:
        """(first val window, first test window) indices."""
        w = len(self.windows())
        return max(1, int(w * self._train_frac)), max(1, int(w * self._val_frac))

    def reset_epoch_state(self) -> None:
        """Drop the recency-neighbor buffer so the next pass re-warms
        chronologically from the stream head (each train/eval pass walks
        the windows from window 0; a buffer left warm by a previous pass
        would leak future neighbors into the walk)."""
        if hasattr(self, "_sampler"):
            del self._sampler

    def train_epoch(self) -> Tuple[float, float]:
        """One online pass over the train windows (no-op for ``pf``)."""
        t0 = time.perf_counter()
        if self.model_name == "pf":
            return 0.0, time.perf_counter() - t0
        self.reset_epoch_state()
        n_val, _ = self._bounds()
        windows = self.windows()
        state = tgn.init_state(self.cfg)
        losses = []
        for i in range(min(n_val, len(windows)) - 1):
            b, _ = windows[i]
            if b.num_events == 0:
                continue
            batch = self._tgn_batch(b)
            labels = jnp.asarray(windows[i + 1][1][np.asarray(batch["seed_user"])])
            active = (labels.sum(-1) > 0).astype(jnp.float32)
            self.params, self.opt, state, loss = self._train_step(
                self.params, self.opt, state, batch, labels, active)
            losses.append(float(loss))
        return float(np.mean(losses)) if losses else 0.0, time.perf_counter() - t0

    def evaluate(self, split: str = "test", k_eval: int = 10) -> Tuple[float, float]:
        """NDCG@``k_eval`` over a split's windows (state warmed through all
        earlier windows without parameter updates)."""
        n_val, n_test = self._bounds()
        windows = self.windows()
        lo, hi = ((n_val, n_test) if split == "val"
                  else (n_test, len(windows)) if split == "test"
                  else (1, n_val))
        self.reset_epoch_state()
        t0 = time.perf_counter()
        scores = []
        if self.model_name == "pf":
            last = np.zeros((self.n, self.num_cats), np.float32)
            for i in range(len(windows) - 1):
                _, counts = windows[i]
                nxt = windows[i + 1][1]
                if lo <= i + 1 < hi:
                    active = nxt.sum(-1) > 0
                    if active.any():
                        scores.append(ndcg_at_k(last[active], nxt[active], k_eval))
                last = np.where(counts.sum(-1, keepdims=True) > 0, counts, last)
        else:
            state = tgn.init_state(self.cfg)
            for i in range(len(windows) - 1):
                b, _ = windows[i]
                if b.num_events == 0 or i + 1 >= hi:
                    continue
                batch = self._tgn_batch(b)
                probs, state = self._predict(self.params, state, batch)
                if lo <= i + 1:
                    nxt = windows[i + 1][1]
                    labels = nxt[np.asarray(batch["seed_user"])]
                    a = labels.sum(-1) > 0
                    if a.any():
                        scores.append(ndcg_at_k(np.asarray(probs)[a],
                                                labels[a], k_eval))
        out = float(np.mean(scores)) if scores else 0.0
        return out, time.perf_counter() - t0

    # -- checkpointing ---------------------------------------------------
    def _ckpt_tree(self) -> Dict[str, Any]:
        if self.model_name == "pf":
            # Persistent forecast is parameter-free; checkpoint a marker so
            # the bundle round-trips through the shared contract.
            return {"pipeline": {"stateless": np.int64(1)}, "hooks": {}}
        return {"params": self.params, "opt_state": self.opt, "hooks": {}}

    def save_checkpoint(self, ckpt_dir: str, step: int) -> str:
        """Write a checkpoint (atomic step directory). Returns its path."""
        return save_bundle(ckpt_dir, step, self._ckpt_tree(), self.model_name,
                           trainer="nodeprop")

    def restore_checkpoint(self, ckpt_dir: str, step: Optional[int] = None) -> int:
        """Restore params/opt state (no-op payload for ``pf``); returns the
        checkpoint step."""
        target = {k: v for k, v in self._ckpt_tree().items() if k != "hooks"}
        tree, step = restore_bundle(ckpt_dir, step, target, self.model_name)
        if self.model_name != "pf":
            self.params = tree["params"]
            self.opt = tree["opt_state"]
        return step

    # ------------------------------------------------------------------
    def run_online(self, train_frac: float = 0.7, k_eval: int = 10) -> Tuple[float, float]:
        """Historical single-pass behavior: train online through the first
        ``train_frac`` windows, score NDCG@k on the rest. Returns
        (test NDCG@k, seconds)."""
        windows = self.windows()
        n_train = max(1, int(len(windows) * train_frac))
        self.reset_epoch_state()
        t0 = time.perf_counter()

        if self.model_name == "pf":
            last = np.zeros((self.n, self.num_cats), np.float32)
            scores = []
            for i in range(len(windows) - 1):
                _, counts = windows[i]
                nxt = windows[i + 1][1]
                if i + 1 >= n_train:
                    active = nxt.sum(-1) > 0
                    if active.any():
                        scores.append(ndcg_at_k(last[active], nxt[active], k_eval))
                last = np.where(counts.sum(-1, keepdims=True) > 0, counts, last)
            return float(np.mean(scores)) if scores else 0.0, time.perf_counter() - t0

        state = tgn.init_state(self.cfg)
        scores = []
        for i in range(len(windows) - 1):
            b, _ = windows[i]
            nxt = windows[i + 1][1]
            if b.num_events == 0:
                continue
            batch = self._tgn_batch(b)
            labels = jnp.asarray(nxt[np.asarray(batch["seed_user"])])
            active = (labels.sum(-1) > 0).astype(jnp.float32)
            if i + 1 < n_train:
                self.params, self.opt, state, _ = self._train_step(
                    self.params, self.opt, state, batch, labels, active)
            else:
                probs, state = self._predict(self.params, state, batch)
                a = np.asarray(active, bool)
                if a.any():
                    scores.append(ndcg_at_k(np.asarray(probs)[a],
                                            np.asarray(labels)[a], k_eval))
        return float(np.mean(scores)) if scores else 0.0, time.perf_counter() - t0

    def _tgn_batch(self, b) -> Dict:
        """Materialize a TGN batch for node prediction: seeds = the window's
        active users; neighbors from a host-side recency buffer. Shapes are
        power-of-two bucketed so XLA compiles a handful of variants."""
        if not hasattr(self, "_sampler"):
            from repro.core import RecencySampler

            self._sampler = RecencySampler(self.n, self.cfg.k)
        users = np.unique(b["src"])
        blk = self._sampler.sample(users)
        t_ref = np.full(len(users), int(b["time"].max()), np.int64)
        self._sampler.update(b["src"], b["dst"], b["time"])

        def p2(n):
            return 1 << int(np.ceil(np.log2(max(n, 2))))

        ucap, ecap = p2(len(users)), p2(b.num_events)
        upad, epad = ucap - len(users), ecap - b.num_events
        emask = np.zeros(ecap, bool)
        emask[: b.num_events] = True
        return {
            "src": jnp.asarray(np.pad(b["src"], (0, epad))),
            "dst": jnp.asarray(np.pad(b["dst"], (0, epad))),
            "time": jnp.asarray(np.pad(b["time"], (0, epad))),
            "batch_mask": jnp.asarray(emask),
            "seed_nodes": jnp.asarray(np.pad(users, (0, upad))),
            "seed_times": jnp.asarray(np.pad(t_ref, (0, upad))),
            "nbr_ids": jnp.asarray(np.pad(blk.nbr_ids, ((0, upad), (0, 0)),
                                          constant_values=-1)),
            "nbr_times": jnp.asarray(np.pad(blk.nbr_times, ((0, upad), (0, 0)))),
            "nbr_mask": jnp.asarray(np.pad(blk.mask, ((0, upad), (0, 0)))),
            "seed_user": jnp.asarray(np.pad(users, (0, upad))),
        }


class NodePropertyTrainer:
    """Legacy one-shot node-property driver (prefer ``repro.tg.Experiment``
    with ``task="node"``).

    Dispatches on the model name: ``pf``/``tgn`` keep the historical host
    window loop (``EventNodePipeline.run_online``); snapshot models
    (``gcn``, ``gclstm``, ``tgcn``) now run through the scan-compiled
    ``DTDGNodePipeline``, so a training epoch is one ``lax.scan`` jitted
    call (the ROADMAP "scan-compiled NodePropertyTrainer" item).
    """

    def __init__(self, model_name: str, data: DGData, unit: TimeDelta | str = "d",
                 num_cats: Optional[int] = None, d_embed: int = 32,
                 lr: float = 1e-3, seed: int = 0, compiled: bool = True):
        if model_name in ("pf", "tgn"):
            self._impl = EventNodePipeline(model_name, data, unit=unit,
                                           num_cats=num_cats, d_embed=d_embed,
                                           lr=lr, seed=seed)
        else:
            self._impl = DTDGNodePipeline(model_name, data, unit=unit,
                                          num_cats=num_cats, d_embed=d_embed,
                                          lr=lr, seed=seed, compiled=compiled)
        self.model_name = model_name

    @property
    def pipeline(self):
        """The underlying pipeline (event windows or scanned snapshots)."""
        return self._impl

    def run(self, train_frac: float = 0.7, k_eval: int = 10) -> Tuple[float, float]:
        """Train on the first ``train_frac`` windows, return
        (test NDCG@k, seconds) — the historical one-shot API."""
        if isinstance(self._impl, EventNodePipeline):
            return self._impl.run_online(train_frac, k_eval)
        # Scan pipeline: map train_frac to a snapshot-row boundary (no val
        # split), train one scanned epoch, score the remaining rows.
        impl = self._impl
        n_train = max(1, int(impl.snapshots.num_snapshots * train_frac))
        impl.set_split_rows(n_train, n_train)
        t0 = time.perf_counter()
        impl.train_epoch()
        ndcg, _ = impl.evaluate("test", k_eval)
        return ndcg, time.perf_counter() - t0
