from repro.train.loop import (
    CTDGLinkPipeline,
    DTDGLinkPipeline,
    TrainLoop,
)
from repro.train.metrics import auc, mrr, ndcg_at_k
from repro.train.nodeprop import (
    DTDGNodePipeline,
    EventNodePipeline,
    NodePropertyTrainer,
)
from repro.train.tg_trainer import LinkPredictionTrainer, SnapshotLinkTrainer

__all__ = [
    "auc",
    "mrr",
    "ndcg_at_k",
    "CTDGLinkPipeline",
    "DTDGLinkPipeline",
    "DTDGNodePipeline",
    "EventNodePipeline",
    "NodePropertyTrainer",
    "TrainLoop",
    "LinkPredictionTrainer",
    "SnapshotLinkTrainer",
]
