from repro.train.metrics import auc, mrr, ndcg_at_k
from repro.train.tg_trainer import LinkPredictionTrainer, SnapshotLinkTrainer

__all__ = [
    "auc",
    "mrr",
    "ndcg_at_k",
    "LinkPredictionTrainer",
    "SnapshotLinkTrainer",
]
