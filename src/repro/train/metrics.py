"""Evaluation metrics: MRR (one-vs-many), AUC, NDCG@k."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mrr(pos_scores, neg_scores, mask=None):
    """Mean reciprocal rank of each positive against its negatives.

    pos_scores: (B,); neg_scores: (B, M); mask: (B,) valid rows.
    Optimistic-tie handling follows TGB: rank = 1 + #(neg > pos) +
    0.5 * #(neg == pos).
    """
    pos = jnp.asarray(pos_scores)
    neg = jnp.asarray(neg_scores)
    greater = (neg > pos[:, None]).sum(-1)
    ties = (neg == pos[:, None]).sum(-1)
    rank = 1.0 + greater + 0.5 * ties
    rr = 1.0 / rank
    if mask is None:
        return float(rr.mean())
    m = jnp.asarray(mask, jnp.float32)
    return float((rr * m).sum() / jnp.maximum(m.sum(), 1.0))


def auc(scores, labels) -> float:
    """Area under the ROC curve (rank statistic, ties handled)."""
    s = np.asarray(scores, dtype=np.float64)
    y = np.asarray(labels, dtype=np.int64)
    n_pos, n_neg = int(y.sum()), int((1 - y).sum())
    if n_pos == 0 or n_neg == 0:
        return 0.5
    order = np.argsort(s, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(s) + 1)
    # midrank correction for ties
    uniq, inv, cnt = np.unique(s, return_inverse=True, return_counts=True)
    cum = np.cumsum(cnt)
    mid = cum - (cnt - 1) / 2.0
    ranks = mid[inv]
    r_pos = ranks[y == 1].sum()
    return float((r_pos - n_pos * (n_pos + 1) / 2.0) / (n_pos * n_neg))


def ndcg_at_k(pred, target, k: int = 10) -> float:
    """NDCG@k averaged over rows. pred/target: (B, M) relevance scores."""
    pred = np.asarray(pred, dtype=np.float64)
    target = np.asarray(target, dtype=np.float64)
    B, M = pred.shape
    k = min(k, M)
    top = np.argsort(-pred, axis=1)[:, :k]
    ideal = -np.sort(-target, axis=1)[:, :k]
    discounts = 1.0 / np.log2(np.arange(2, k + 2))
    dcg = (np.take_along_axis(target, top, axis=1) * discounts).sum(1)
    idcg = (ideal * discounts).sum(1)
    ok = idcg > 0
    out = np.zeros(B)
    out[ok] = dcg[ok] / idcg[ok]
    return float(out.mean())
