"""Assigned architecture config: yi-9b."""

from repro.configs.base import ArchConfig

# [dense] llama-arch GQA [arXiv:2403.04652]
CONFIG = ArchConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11_008,
    vocab_size=64_000,
    rope_theta=10_000.0,
)
