"""Assigned architecture config: phi3-mini-3-8b."""

from repro.configs.base import ArchConfig

# [dense] RoPE SwiGLU GQA(kv=32 -> MHA) [arXiv:2404.14219]
CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
)
