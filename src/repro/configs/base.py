"""Architecture + shape configuration system (``--arch`` / ``--shape``)."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned LM shapes.
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads

    # attention details
    qk_norm: bool = False
    attn_bias: bool = False
    sliding_window: int = 0  # 0 => full attention
    rope_theta: float = 10_000.0

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_kernel: int = 4

    # structure
    cross_attn_every: int = 0  # vlm: insert cross-attn before every n-th layer
    max_position_embeddings: int = 32_770  # learned-positional archs (whisper)
    encoder_layers: int = 0  # audio: encoder depth (enc-dec)
    frontend_seq: int = 0  # audio/vlm stub frontend length
    tie_embeddings: bool = False

    # numerics / compilation
    norm_eps: float = 1e-6
    act: str = "silu"  # silu | gelu
    param_dtype: str = "bfloat16"  # bf16 params + f32 optimizer moments (mixed precision)
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    # which shapes apply (long_500k only for sub-quadratic archs)
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        if self.act == "silu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        per_layer = 0
        if self.family == "ssm":
            di, N = self.d_inner_ssm, self.ssm_state
            H = self.ssm_heads
            in_proj = d * (2 * di + 2 * self.ssm_groups * N + H)
            per_layer = in_proj + di * d + di * self.conv_kernel
        elif self.family == "moe":
            e_mlp = 3 * d * self.d_ff * self.num_experts
            shared = 3 * d * self.d_ff * self.num_shared_experts
            router = d * self.num_experts
            per_layer = attn + e_mlp + shared + router
        elif self.family == "hybrid":
            di, N = self.d_inner_ssm, self.ssm_state
            H = self.ssm_heads
            ssm = d * (2 * di + 2 * self.ssm_groups * N + H) + di * d
            per_layer = attn + ssm + mlp
        elif self.family == "audio":
            per_layer = 2 * attn + mlp  # decoder: self-attn + cross-attn
        else:
            per_layer = attn + mlp
        total = emb + L * per_layer
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp)
        if self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            total += n_cross * (attn + mlp)
        return int(total)

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE uses top-k + shared experts)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        active_mlp = 3 * d * self.d_ff * (self.num_experts_per_tok + self.num_shared_experts)
        router = d * self.num_experts
        return int(emb + L * (attn + active_mlp + router))

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2)
            if self.num_experts_per_tok else 0,
            num_shared_experts=min(self.num_shared_experts, 1)
            if self.num_shared_experts else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_seq=16 if self.frontend_seq else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            max_position_embeddings=128,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
            scan_layers=False,
        )
