"""Assigned architecture config: qwen3-0-6b."""

from repro.configs.base import ArchConfig

# [dense] qk_norm, GQA [hf:Qwen/Qwen3-8B family, 0.6B config]
CONFIG = ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,  # qwen3 uses 128 regardless of d_model/heads
    d_ff=3072,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
