"""The 40 (architecture x shape) dry-run cells and applicability rules."""

from __future__ import annotations

from typing import Iterator, List, Tuple

from repro.configs.archs import ARCHS
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason). long_500k needs sub-quadratic sequence mixing."""
    if shape.name == "long_500k" and not arch.supports_long_context:
        return False, "pure full-attention arch; 500k decode needs sub-quadratic mixing"
    return True, ""


def cells(include_skipped: bool = False) -> List[Tuple[ArchConfig, ShapeConfig]]:
    out = []
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, _ = shape_applicable(arch, shape)
            if ok or include_skipped:
                out.append((arch, shape))
    return out


def skipped_cells() -> List[Tuple[str, str, str]]:
    out = []
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, reason = shape_applicable(arch, shape)
            if not ok:
                out.append((arch.name, shape.name, reason))
    return out
