"""Assigned architecture config: mamba2-780m."""

from repro.configs.base import ArchConfig

# [ssm] SSD (state-space duality) [arXiv:2405.21060]
CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,  # attention-free, no MLP (mamba2 blocks only)
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    supports_long_context=True,
)
