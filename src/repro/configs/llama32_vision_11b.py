"""Assigned architecture config: llama32-vision-11b."""

from repro.configs.base import ArchConfig

# [vlm] cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision]
CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=128_256,
    cross_attn_every=5,  # 8 cross-attention blocks
    frontend_seq=1601,  # vision patch tokens (stub input)
    rope_theta=500_000.0,
)
