from repro.configs.base import ArchConfig, ShapeConfig, SHAPES

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ARCHS", "get_arch", "cells"]


def __getattr__(name):  # lazy to avoid import cycles with per-arch modules
    if name in ("ARCHS", "get_arch"):
        from repro.configs import archs

        return getattr(archs, name)
    if name == "cells":
        from repro.configs.cells import cells

        return cells
    raise AttributeError(name)
