"""Registry of the 10 assigned architectures (one module per arch).

Select with ``--arch <id>``; ids use the assignment spelling (dots/dashes).
"""

from __future__ import annotations

from repro.configs import (
    dbrx_132b,
    hymba_1_5b,
    llama32_vision_11b,
    mamba2_780m,
    phi3_mini_3_8b,
    qwen2_moe_a2_7b,
    qwen3_0_6b,
    stablelm_12b,
    whisper_large_v3,
    yi_9b,
)
from repro.configs.base import ArchConfig

ARCHS = {
    cfg.name: cfg
    for cfg in [
        mamba2_780m.CONFIG,
        qwen3_0_6b.CONFIG,
        yi_9b.CONFIG,
        stablelm_12b.CONFIG,
        phi3_mini_3_8b.CONFIG,
        whisper_large_v3.CONFIG,
        llama32_vision_11b.CONFIG,
        hymba_1_5b.CONFIG,
        dbrx_132b.CONFIG,
        qwen2_moe_a2_7b.CONFIG,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
