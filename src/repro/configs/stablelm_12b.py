"""Assigned architecture config: stablelm-12b."""

from repro.configs.base import ArchConfig

# [dense] [hf:stabilityai/stablelm-2-12b]
CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13_824,
    vocab_size=100_352,
)
