"""Assigned architecture config: hymba-1-5b."""

from repro.configs.base import ArchConfig

# [hybrid] parallel attn+mamba heads [arXiv:2411.13676]
CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    sliding_window=1024,  # hymba uses SWA on most layers -> sub-quadratic
    supports_long_context=True,
)
