"""Assigned architecture config: whisper-large-v3."""

from repro.configs.base import ArchConfig

# [audio] enc-dec, conv frontend (stub) [arXiv:2212.04356]
CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,  # decoder layers
    encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    frontend_seq=1500,  # post-conv mel frames (stub input)
    act="gelu",
    attn_bias=True,
)
