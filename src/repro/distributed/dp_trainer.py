"""Explicit shard_map data-parallel trainer (DistTGL-style) for the TG
models — the distributed runtime for the paper's workload.

Temporal-graph training state is small (params ~1-10M) but *stateful*
(TGN memory, TPNet random features), so the scaling axis is data
parallelism over event streams with periodic state synchronization — the
DistTGL recipe. Here:

  * the global event batch is sharded over the 'data' mesh axis (each
    shard is a contiguous sub-stream, preserving per-shard time order);
  * gradients are psum-averaged inside shard_map, optionally compressed
    (bf16 / int8 + error feedback, see compression.py);
  * model state (e.g. TGN memory) is synchronized by a masked psum: nodes
    touched on exactly one shard take that shard's value; nodes touched on
    several take the mean (staleness is bounded by one batch — the
    DistTGL trade-off);
  * the optimizer update runs replicated (params are replicated in DP).

Gradient-accumulation microbatching overlaps the per-microbatch
reduce-scatter with the next microbatch's backward (XLA latency hiding
does the interleaving once both are in the same program).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import compression as comp
from repro.optim import AdamWConfig, adamw_init, adamw_update

# The version-compat shard_map shim is shared with the sharded samplers
# and lives with the other mesh helpers in distributed/sharding.py.
from repro.distributed.sharding import SHARD_MAP_KW as _SHARD_MAP_KW
from repro.distributed.sharding import shard_map as _shard_map
from repro.distributed.sharding import sync_state_masked_psum


class DataParallelTrainer:
    """shard_map DP wrapper around a per-shard loss function.

    loss_fn(params, state, batch_shard) -> (loss, (new_state, touched))
      ``touched``: bool mask (num_nodes,) of state rows this shard updated
      (None for stateless models — pass state={} and touched=None).
    """

    def __init__(
        self,
        loss_fn: Callable,
        mesh: Mesh,
        opt_cfg: AdamWConfig = AdamWConfig(lr=1e-4),
        axis: str = "data",
        compression: str = "none",
        accum_steps: int = 1,
    ):
        self.loss_fn = loss_fn
        self.mesh = mesh
        self.axis = axis
        self.opt_cfg = opt_cfg
        self.compression = compression
        self.accum_steps = accum_steps
        self._step = None

    def init(self, params):
        opt_state = adamw_init(params)
        err = comp.zeros_like_error(params) if self.compression == "int8_ef" else None
        return opt_state, err

    def build_step(self, stateful: bool):
        axis = self.axis
        scheme = self.compression
        opt_cfg = self.opt_cfg
        loss_fn = self.loss_fn
        accum = self.accum_steps

        def shard_step(params, opt_state, err, state, batch):
            # batch leaves: (accum, per_shard_B, ...) inside shard_map
            def one_micro(carry, micro):
                grads_acc, loss_acc, state = carry
                (loss, (state, touched)), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, state, micro)
                grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
                return (grads_acc, loss_acc + loss, state), touched

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, state), touched = jax.lax.scan(
                one_micro, (zeros, 0.0, state), batch)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum

            # compressed gradient all-reduce
            wire, err, _ = comp.compress_grads(grads, err, scheme)
            grads = comp.psum_compressed(wire, scheme, axis)
            loss = jax.lax.pmean(loss, axis)

            # DistTGL-style state sync: mean over shards that touched a row
            if stateful and touched is not None:
                touched_any = touched.any(0)  # over accum steps
                state = sync_state_masked_psum(state, touched_any, axis)

            params_new, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
            return params_new, opt_state, err, state, loss

        pspec = P()  # replicated params/opt/err/state
        bspec = jax.tree.map(lambda _: P(None, self.axis), {"x": 0})["x"]

        smapped = _shard_map(
            shard_step,
            mesh=self.mesh,
            in_specs=(pspec, pspec, pspec, pspec, P(None, self.axis)),
            out_specs=(pspec, pspec, pspec, pspec, P()),
            **_SHARD_MAP_KW,
        )
        self._step = jax.jit(smapped)
        return self._step

    def step(self, params, opt_state, err, state, batch):
        """batch leaves: (accum, global_B, ...) — sharded over axis 1."""
        if self._step is None:
            raise RuntimeError("call build_step() first")
        if err is None:
            err = jax.tree.map(lambda p: jnp.zeros((1,), jnp.float32), {})
        return self._step(params, opt_state, err, state, batch)
