"""Logical-axis sharding (MaxText-style rules) and mesh helpers.

Every parameter and key activation is annotated with *logical* axis names
("batch", "embed", "heads", ...). A rule table maps logical names to mesh
axes; GSPMD derives the collectives. Rules differ per parallelism profile
(pure TP, FSDP+TP, ...) and per mesh (single-pod vs multi-pod).

The active (mesh, rules) pair is process-global context set by the launcher;
model code calls ``shard(x, "batch", "seq", "embed")`` which is a no-op when
no mesh is active (CPU tests).

This module is also the home of the *node-partitioned sampler state* layout
shared by the device-resident temporal samplers (see ``docs/sharding.md``):

  * ``shard_map`` — the version-compat resolved ``jax.shard_map`` (used by
    both the DP trainer and the sharded samplers);
  * ``make_node_mesh`` — a 1-D mesh over the first N devices, axis "data";
  * ``node_rows_per_shard`` / ``row_sharding`` / ``replicated_sharding`` —
    the row-wise node-id partition arithmetic and the ``NamedSharding``s
    the samplers, hooks, and ``PrefetchLoader`` all agree on. The logical
    axis name for node-partitioned state is ``"nodes"`` (see
    ``DEFAULT_RULES``).
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# shard_map moved to the jax namespace (and check_rep became check_vma)
# across JAX releases; resolve whichever the installed version exposes once,
# here, for every shard_map consumer in the repo (DP trainer, sharded
# samplers).
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
    SHARD_MAP_KW = {"check_vma": False}
else:  # pragma: no cover - older JAX
    from jax.experimental.shard_map import shard_map  # type: ignore

    SHARD_MAP_KW = {"check_rep": False}

AxisVal = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, AxisVal]

# Default rules: DP over (pod, data); TP over model for heads/mlp/vocab/
# experts; FSDP (ZeRO-3) shards the embed axis of params over data.
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "embed_fsdp": "data",  # param-only embed axis for FSDP sharding
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qkv": "model",
    "mlp": "model",
    "moe_mlp": "model",
    "experts": None,
    "expert_cap": None,  # capacity axis of (E, C, d) expert batches
    "vocab": "model",
    "layers": None,
    "state": None,
    "conv": None,
    "frames": None,
    "patches": None,
    "cache_seq": None,
    "seq_shard": ("pod", "data"),  # sequence parallelism for long-context
    "nodes": "data",  # node-id row partition of device sampler state
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Rules = dict(DEFAULT_RULES)


_CTX = _Ctx()


def set_sharding_context(mesh: Optional[Mesh], rules: Optional[Rules] = None) -> None:
    """Install the process-global (mesh, rules) pair used by ``shard``."""
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES if rules is None else rules)


def get_mesh() -> Optional[Mesh]:
    """The active mesh set by ``set_sharding_context`` (None = no mesh)."""
    return _CTX.mesh


def get_rules() -> Rules:
    """The active logical-axis rule table."""
    return _CTX.rules


class sharding_context:
    """``with sharding_context(mesh, rules): ...``"""

    def __init__(self, mesh: Optional[Mesh], rules: Optional[Rules] = None):
        self._new = (mesh, rules)
        self._old: Tuple[Optional[Mesh], Rules] = (None, {})

    def __enter__(self):
        self._old = (_CTX.mesh, _CTX.rules)
        set_sharding_context(*self._new)
        return self

    def __exit__(self, *exc):
        _CTX.mesh, _CTX.rules = self._old


def _axis_size(mesh: Mesh, ax: str) -> int:
    return mesh.shape[ax]


def _mesh_axes_for(logical: Sequence[Optional[str]], rules: Rules, mesh: Mesh,
                   shape: Optional[Sequence[int]] = None):
    """Map logical axis names to mesh axes.

    Rules whose mesh axis does not exist on this mesh (e.g. 'pod' on the
    single-pod mesh) are dropped. When ``shape`` is given, mappings that do
    not evenly divide the dimension are reduced (dropping axes from the
    front of a tuple mapping) or dropped — JAX/GSPMD requires even tiling.
    """
    out = []
    for i, name in enumerate(logical):
        if name is None:
            out.append(None)
            continue
        ax = rules.get(name)
        if ax is None:
            out.append(None)
            continue
        if isinstance(ax, str):
            ax = (ax,)
        live = tuple(a for a in ax if a in mesh.axis_names)
        if shape is not None:
            dim = shape[i]
            # reduce the mapping until its product divides the dim
            while live:
                prod = int(np.prod([_axis_size(mesh, a) for a in live]))
                if prod and dim % prod == 0:
                    break
                live = live[1:]
        out.append(live if len(live) > 1 else (live[0] if live else None))
    return out


def logical_spec(logical: Sequence[Optional[str]],
                 rules: Optional[Rules] = None,
                 mesh: Optional[Mesh] = None,
                 shape: Optional[Sequence[int]] = None) -> P:
    """``PartitionSpec`` for logical axis names under (rules, mesh);
    divisibility-reduced against ``shape`` when given."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None:
        return P()
    return P(*_mesh_axes_for(logical, rules, mesh, shape))


def logical_sharding(logical: Sequence[Optional[str]],
                     rules: Optional[Rules] = None,
                     mesh: Optional[Mesh] = None,
                     shape: Optional[Sequence[int]] = None) -> Optional[NamedSharding]:
    """``NamedSharding`` for logical axis names (None without a mesh)."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_spec(logical, rules, mesh, shape))


def shard(x, *logical: Optional[str]):
    """Activation sharding constraint by logical axis names. No-op without
    an active mesh; divisibility-checked against ``x.shape``."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, logical_spec(logical, shape=x.shape))
    )


# ----------------------------------------------------------------------
# Node-partitioned sampler state (the ``docs/sharding.md`` layout)
# ----------------------------------------------------------------------
def make_node_mesh(shards: int, axis: str = "data",
                   devices: Optional[Sequence] = None) -> Mesh:
    """A 1-D mesh over the first ``shards`` devices.

    This is the mesh the device-resident samplers shard their node-row
    state over (``SamplerSpec.shards`` resolves through here). ``axis``
    defaults to ``"data"`` — the same axis the DP trainer shards event
    batches over, so sampler state and batch shards can share one mesh.
    """
    devices = list(devices if devices is not None else jax.devices())
    if shards < 1:
        raise ValueError("shards must be >= 1")
    if shards > len(devices):
        raise ValueError(
            f"requested {shards} sampler shards but only {len(devices)} "
            f"devices are visible (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N to emulate more)"
        )
    return Mesh(np.asarray(devices[:shards]), (axis,))


def make_2d_mesh(data_shards: int, node_shards: int,
                 axes: Tuple[str, str] = ("data", "nodes"),
                 devices: Optional[Sequence] = None) -> Mesh:
    """A 2-D ``(data, nodes)`` mesh over the first ``data*nodes`` devices.

    The data axis shards event batches (contiguous time-ordered
    sub-streams, DistTGL-style); the node axis shards sampler buffers /
    CSR adjacency row-wise by node id. Sampler state uses
    ``P(axes[1])`` placements (sharded over nodes, replicated over data);
    batch tensors inside the 2-D train step use ``P(axes[0])``.
    """
    if data_shards < 1 or node_shards < 1:
        raise ValueError("mesh axis sizes must be >= 1")
    devices = list(devices if devices is not None else jax.devices())
    need = data_shards * node_shards
    if need > len(devices):
        raise ValueError(
            f"requested a {data_shards}x{node_shards} mesh but only "
            f"{len(devices)} devices are visible (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N to emulate more)"
        )
    grid = np.asarray(devices[:need]).reshape(data_shards, node_shards)
    return Mesh(grid, axes)


def sync_state_masked_psum(state: Dict, touched, axis: str) -> Dict:
    """DistTGL-style masked-psum model-state sync inside ``shard_map``.

    ``touched`` is a bool mask over state rows (leading dim of every value
    in ``state``): rows touched on exactly one shard of ``axis`` take that
    shard's value; rows touched on several take the mean; untouched rows
    keep their (replicated) local value. Staleness is bounded by one batch
    — the DistTGL trade-off documented in ``distributed/dp_trainer.py``.
    """
    cnt = jax.lax.psum(touched.astype(jnp.float32), axis)
    out = {}
    for key, val in state.items():
        m = touched
        while m.ndim < val.ndim:
            m = m[..., None]
        contrib = jnp.where(m, val, 0.0).astype(jnp.float32)
        summed = jax.lax.psum(contrib, axis)
        c = jnp.maximum(cnt, 1.0)
        while c.ndim < val.ndim:
            c = c[..., None]
        mean = summed / c
        keep = cnt > 0
        while keep.ndim < val.ndim:
            keep = keep[..., None]
        out[key] = jnp.where(keep, mean, val.astype(jnp.float32)).astype(val.dtype)
    return out


def node_rows_per_shard(num_nodes: int, shards: int) -> int:
    """Node rows owned by each shard under the row-wise node-id partition:
    ``ceil(num_nodes / shards)`` (the last shard may own padding rows)."""
    return max(-(-int(num_nodes) // int(shards)), 1)


def row_sharding(mesh: Mesh, axis: str = "data") -> NamedSharding:
    """``NamedSharding`` splitting an array's leading (row) dimension over
    ``axis`` — the placement of node-partitioned sampler state."""
    return NamedSharding(mesh, P(axis))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully-replicated ``NamedSharding`` over ``mesh`` — the placement of
    per-batch tensors feeding sharded sampler computations."""
    return NamedSharding(mesh, P())
