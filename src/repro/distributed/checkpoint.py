"""Fault-tolerant checkpointing (no orbax dependency).

Design for 1000+ node runs:
  * step-numbered directories ``ckpt_<step>/`` with a msgpack manifest
    (tree structure, shapes, dtypes, logical axes) + one .npy per leaf;
  * writes go to ``<dir>.tmp`` then a single atomic rename — a crash
    mid-write never corrupts the latest checkpoint;
  * an async writer thread keeps the train loop running during serialization
    (the arrays are snapshotted to host first);
  * restore is *elastic*: leaves are loaded host-side and re-sharded onto
    whatever mesh/rules are active now via the recorded logical axes —
    restarting on a different topology (e.g. after losing a pod) re-shards
    transparently;
  * retention: keep the last N checkpoints (default 3).

On a real multi-host cluster each host writes only the shards it owns
(process-local slices via ``addressable_shards``); in this single-process
container that degenerates to full arrays, but the layout and manifest are
the same.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree, is_leaf=None) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def _axes_leaf(x) -> bool:
    """Logical-axes trees have tuple/list/None leaves (one per array)."""
    return x is None or (
        isinstance(x, (tuple, list))
        and all(a is None or isinstance(a, str) for a in x)
    )


def _fsync_path(path: str) -> None:
    """fsync a file or directory so it survives a crash after rename."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(
    ckpt_dir: str,
    step: int,
    tree,
    *,
    logical_axes=None,
    extra_meta: Optional[Dict[str, Any]] = None,
    keep: int = 3,
) -> str:
    """Synchronous checkpoint write. Returns the checkpoint path.

    Durability contract: every leaf + the manifest are fsynced inside the
    tmp dir, the tmp dir itself is fsynced, then a single ``os.rename``
    publishes it and the parent dir is fsynced — a crash at any point
    leaves either the previous checkpoint or the new one, never a torn
    directory that parses as valid."""
    path = os.path.join(ckpt_dir, f"ckpt_{step}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten_with_paths(tree)
    axes_map = {}
    if logical_axes is not None:
        axes_map = {k: list(v) if v is not None else None
                    for k, v in _flatten_with_paths(logical_axes,
                                                    is_leaf=_axes_leaf)}

    manifest = {"step": step, "leaves": [], "extra": extra_meta or {}}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        dtype_str = str(arr.dtype)
        if arr.dtype.kind == "V" or not arr.dtype.isbuiltin:
            # numpy can't serialize ml_dtypes (bfloat16, fp8, ...) natively:
            # store the raw bits; the true dtype lives in the manifest.
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        fname = f"leaf_{i}.npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        _fsync_path(fpath)
        manifest["leaves"].append({
            "key": key,
            "file": fname,
            "shape": list(arr.shape),
            "dtype": dtype_str,
            "bytes": os.path.getsize(fpath),  # torn-write detection
            "axes": axes_map.get(key),
        })
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_path(tmp)

    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)  # atomic publish
    _fsync_path(ckpt_dir)
    _retain(ckpt_dir, keep)
    return path


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"ckpt_{s}"), ignore_errors=True)


def is_intact(path: str) -> bool:
    """True iff a checkpoint dir's manifest parses and every leaf file it
    names exists with the recorded byte size (legacy manifests without a
    recorded size fall back to an existence check). A dir failing this is
    *torn* — e.g. a crash mid-write on a filesystem without atomic rename
    semantics, or post-publish corruption — and is skipped by
    :func:`latest_step` / default :func:`restore`."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError):
        return False
    for leaf in manifest.get("leaves", []):
        fpath = os.path.join(path, leaf["file"])
        try:
            size = os.path.getsize(fpath)
        except OSError:
            return False
        if leaf.get("bytes") is not None and size != leaf["bytes"]:
            return False
    return True


def all_steps(ckpt_dir: str, intact_only: bool = False) -> List[int]:
    """Step numbers of checkpoints under ``ckpt_dir`` (``intact_only``
    filters through :func:`is_intact`)."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("ckpt_") and not name.endswith(".tmp"):
            try:
                s = int(name.split("_", 1)[1])
            except ValueError:
                continue
            if intact_only and not is_intact(os.path.join(ckpt_dir, name)):
                continue
            out.append(s)
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest *intact* step (torn checkpoints never win the resume race)."""
    steps = all_steps(ckpt_dir, intact_only=True)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None, *, target=None,
            mesh=None, rules=None):
    """Load a checkpoint; returns (tree, step, extra_meta).

    ``target``: optional pytree prototype — the restored tree adopts its
    structure (required to rebuild dicts/dataclasses ordering). Without it,
    a flat {key: array} dict is returned.

    Elastic resharding: if ``mesh`` is given, each leaf with recorded
    logical axes is device_put with the sharding those axes resolve to on
    the *current* mesh (which may differ from the mesh at save time).
    """
    from repro.distributed.sharding import logical_sharding

    if step is None:
        step = latest_step(ckpt_dir)  # newest intact — skips torn dirs
        if step is None:
            raise FileNotFoundError(f"no intact checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"ckpt_{step}")
    if not is_intact(path):
        raise RuntimeError(
            f"checkpoint {path} is torn/corrupt (manifest or leaf files "
            "missing/truncated); omit `step` to fall back to the newest "
            "intact checkpoint")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat: Dict[str, Any] = {}
    for leaf in manifest["leaves"]:
        arr = np.load(os.path.join(path, leaf["file"]))
        want = leaf["dtype"]
        if str(arr.dtype) != want:
            import ml_dtypes

            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        if mesh is not None and leaf.get("axes") is not None:
            sh = logical_sharding(tuple(leaf["axes"]), rules=rules, mesh=mesh,
                                  shape=arr.shape)
            arr = jax.device_put(arr, sh)
        flat[leaf["key"]] = arr

    if target is None:
        return flat, step, manifest["extra"]
    return assemble(flat, target), step, manifest["extra"]


def assemble(flat: Dict[str, Any], target):
    """Reassemble a flat ``{path-key: array}`` dict (as returned by
    ``restore(target=None)``) into ``target``'s pytree structure — the
    structural half of ``restore``, usable without re-reading leaves from
    disk. Raises ``KeyError`` on leaves the flat dict is missing."""
    keys_in_order = [k for k, _ in _flatten_with_paths(target)]
    missing = [k for k in keys_in_order if k not in flat]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
    leaves = [flat[k] for k in keys_in_order]
    treedef = jax.tree_util.tree_structure(target)
    return treedef.unflatten(leaves)


class AsyncCheckpointer:
    """Background checkpoint writer: snapshot to host, enqueue, train on.

    ``wait()`` drains the queue (call before exit / evaluation barriers).
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._closed = False
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, axes, extra = item
            try:
                save(self.ckpt_dir, step, host_tree, logical_axes=axes,
                     extra_meta=extra, keep=self.keep)
            except BaseException as e:  # surfaced on next save/wait
                self._err = e
            finally:
                self._q.task_done()

    def _check_worker(self):
        """Surface a buffered worker failure (or a dead worker thread) on
        the *caller's* thread — errors are never silently dropped."""
        if self._err:
            raise RuntimeError("async checkpoint write failed") from self._err
        if not self._thread.is_alive() and not self._closed:
            raise RuntimeError("async checkpoint worker thread died")

    def save(self, step: int, tree, *, logical_axes=None, extra_meta=None):
        self._check_worker()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree, logical_axes, extra_meta))

    def wait(self):
        # A bare q.join() deadlocks forever if the worker dies hard (its
        # task_done never comes), so poll with a liveness check instead.
        with self._q.all_tasks_done:
            while self._q.unfinished_tasks:
                if not self._thread.is_alive():
                    break
                self._q.all_tasks_done.wait(timeout=0.1)
        self._check_worker()

    def close(self):
        self.wait()
        self._closed = True
        self._q.put(None)
        self._thread.join(timeout=10)
