from repro.distributed import checkpoint, compression
from repro.distributed.dp_trainer import DataParallelTrainer
from repro.distributed.sharding import (
    DEFAULT_RULES,
    Rules,
    get_mesh,
    get_rules,
    logical_sharding,
    logical_spec,
    set_sharding_context,
    shard,
    sharding_context,
)

__all__ = [
    "DEFAULT_RULES",
    "DataParallelTrainer",
    "Rules",
    "checkpoint",
    "compression",
    "get_mesh",
    "get_rules",
    "logical_sharding",
    "logical_spec",
    "set_sharding_context",
    "shard",
    "sharding_context",
]
