"""Gradient compression for data-parallel all-reduce.

Two schemes, both drop-in around a ``psum``:
  * bf16: cast grads to bf16 before the all-reduce (2x wire reduction,
    no state);
  * int8 + error feedback: per-tensor symmetric int8 quantization of
    (grad + error); the quantization residual is carried to the next step
    (Seide et al. 2014 / 1-bit SGD lineage), keeping SGD unbiased in the
    long run. 4x wire reduction.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def zeros_like_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x) -> Tuple[Any, Any]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, error, scheme: str):
    """Returns (wire_tree, new_error, aux) — wire_tree is what gets
    psum'd; call ``decompress_grads`` on the reduced result."""
    if scheme == "none":
        return grads, error, None
    if scheme == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), error, None
    if scheme == "int8_ef":
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(error)
        qs, scales, new_e = [], [], []
        for g, e in zip(flat_g, flat_e):
            target = g.astype(jnp.float32) + e
            q, s = quantize_int8(target)
            qs.append(q)
            scales.append(s)
            new_e.append(target - dequantize_int8(q, s))
        return (tdef.unflatten(qs), tdef.unflatten(scales)), tdef.unflatten(new_e), None
    raise ValueError(f"unknown compression scheme {scheme!r}")


def psum_compressed(wire, scheme: str, axis_name: str):
    """All-reduce the compressed representation and decompress to f32 mean."""
    n = jax.lax.psum(1, axis_name)
    if scheme == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, wire)
    if scheme == "bf16":
        return jax.tree.map(
            lambda g: (jax.lax.psum(g.astype(jnp.float32), axis_name) / n),
            wire,
        )
    if scheme == "int8_ef":
        qs, scales = wire
        # int8 payloads summed in int32 (wire dtype stays 8-bit per hop on
        # TPU reduction trees); scales averaged.
        red_q = jax.tree.map(
            lambda q: jax.lax.psum(q.astype(jnp.int32), axis_name), qs)
        red_s = jax.tree.map(lambda s: jax.lax.psum(s, axis_name) / n, scales)
        return jax.tree.map(
            lambda q, s: q.astype(jnp.float32) * s / n, red_q, red_s)
    raise ValueError(f"unknown compression scheme {scheme!r}")
