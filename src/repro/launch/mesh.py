"""Production meshes.

Single-pod: 16 x 16 = 256 chips (one v5e pod), axes (data, model).
Multi-pod: 2 x 16 x 16 = 512 chips, axes (pod, data, model); the pod axis
extends data parallelism (and sequence sharding for long-context decode).

``make_production_mesh`` is a function — importing this module never touches
jax device state.
"""

from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: Optional[int] = None):
    """Small mesh over whatever devices exist (CI / unit tests)."""
    n = n_devices or len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
