"""Batched serving driver: prefill a prompt batch, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def main(argv: Optional[list] = None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-0.6b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.8)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    from repro.configs import get_arch
    from repro.models.lm import model as M
    from repro.serve import generate

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.family in ("audio", "vlm"):
        batch["frontend"] = jnp.zeros(
            (args.batch, cfg.frontend_seq, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))

    t0 = time.perf_counter()
    out = generate(params, cfg, batch, num_tokens=args.new_tokens,
                   temperature=args.temperature, seed=args.seed,
                   kv_block=min(256, args.prompt_len))
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {out.shape} tokens in {dt:.1f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
