"""Production training driver with checkpoint/restart fault tolerance.

Three workload kinds, selected by ``--workload``:
  * ``tg``   — the paper's workload: CTDG link prediction (TGAT/TGN/...)
               on a synthetic TGB-like stream, optionally data-parallel via
               the shard_map DP trainer;
  * ``dtdg`` — DTDG snapshot link prediction through ``tg.Experiment``
               (scan-compiled pipeline) with per-chunk checkpoints and
               mid-epoch ``snapshot_cursor`` resume;
  * ``lm``   — small-scale LM training (any ``--arch``, reduced or scaled
               config) with the GSPMD train step.

Fault tolerance: async sharded checkpoints every ``--ckpt-every`` steps;
on startup the driver resumes from the newest checkpoint (``--resume``),
and data order is a pure function of (seed, step) so restarts are
deterministic. ``--simulate-failure N`` kills the process at step N to
exercise the restart path (used by tests/test_fault_tolerance.py).

Straggler mitigation at scale comes from fixed-shape steps (no ragged
work), host-side prefetch, and the elastic restore path (a lost pod =>
resume on the smaller mesh; shardings are re-derived from logical axes).
"""

from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def train_tg(args) -> int:
    from repro.data import generate
    from repro.train import LinkPredictionTrainer
    from repro.distributed import checkpoint as ckpt

    data = generate(args.dataset, scale=args.data_scale)
    tr = LinkPredictionTrainer(
        args.model, data, batch_size=args.batch_size, k=args.k,
        eval_negatives=args.eval_negatives, seed=args.seed,
    )

    start_epoch = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        tree, step, extra = ckpt.restore(
            args.ckpt_dir,
            target={"params": tr.params, "opt": tr.opt_state},
        )
        tr.params, tr.opt_state = tree["params"], tree["opt"]
        start_epoch = extra.get("epoch", step) + 1
        print(f"[resume] restored epoch {start_epoch - 1} from {args.ckpt_dir}")

    writer = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=3)
    for epoch in range(start_epoch, args.epochs):
        loss, secs = tr.train_epoch()
        mrr, _ = tr.evaluate("val") if args.eval_every and (
            epoch % args.eval_every == 0) else (float("nan"), 0)
        print(f"epoch {epoch}: loss={loss:.4f} mrr={mrr:.4f} ({secs:.1f}s)",
              flush=True)
        writer.save(epoch, {"params": tr.params, "opt": tr.opt_state},
                    extra_meta={"epoch": epoch, "loss": float(loss)})
        if args.simulate_failure is not None and epoch == args.simulate_failure:
            writer.wait()
            print("[failure-injection] exiting mid-run", flush=True)
            os._exit(42)
    writer.close()
    mrr, _ = tr.evaluate("test")
    print(f"final test MRR: {mrr:.4f}")
    return 0


def train_dtdg(args) -> int:
    """DTDG link workload through the ``tg.Experiment`` front door with
    per-chunk checkpoints: the scan pipeline's ``snapshot_cursor`` is
    written after every compiled chunk, ``--simulate-failure N`` kills the
    process after N chunks (mid-epoch), and ``--resume`` restores to that
    exact chunk boundary — final metrics are bit-identical to an
    uninterrupted run (tests/test_fault_tolerance.py)."""
    from repro import tg
    from repro.distributed import checkpoint as ckpt

    exp = tg.Experiment(
        task="link",
        data=tg.DataSpec(dataset=args.dataset, scale=args.data_scale,
                         discretization=args.discretization),
        model=tg.ModelSpec(name=args.model),
        train=tg.TrainSpec(epochs=args.epochs, seed=args.seed,
                           compiled=True, chunk_size=args.chunk_size),
    )
    pipe = exp.compile()

    start_epoch = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        step = pipe.restore_checkpoint(args.ckpt_dir)
        start_epoch = step // 100000
        print(f"[resume] restored step {step} "
              f"(epoch {start_epoch}, cursor {pipe.snapshot_cursor})",
              flush=True)

    chunks_done = 0
    for epoch in range(start_epoch, args.epochs):
        t0 = time.perf_counter()
        losses: list = []
        while True:
            chunk_losses = pipe.train_chunk()
            if chunk_losses is None:
                break
            losses.extend(chunk_losses)
            chunks_done += 1
            # Step encodes (epoch, cursor): unique, monotonic, and enough
            # to place a resume at the exact chunk boundary.
            pipe.save_checkpoint(args.ckpt_dir,
                                 epoch * 100000 + pipe.snapshot_cursor)
            if (args.simulate_failure is not None
                    and chunks_done == args.simulate_failure):
                print("[failure-injection] exiting mid-run", flush=True)
                os._exit(42)
        loss = float(np.mean(losses)) if losses else 0.0
        print(f"epoch {epoch}: loss={loss:.4f} "
              f"({time.perf_counter() - t0:.1f}s)", flush=True)
    mrr, _ = pipe.evaluate("test")
    print(f"final test MRR: {mrr:.4f}")
    return 0


def train_lm(args) -> int:
    from repro.configs import get_arch
    from repro.data import synthetic_token_batches
    from repro.distributed import checkpoint as ckpt
    from repro.models.lm import model as M
    from repro.optim import AdamWConfig
    from repro.train.lm_train import init_opt_state, make_train_step

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = M.init(cfg, key)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=args.lr),
                                      kv_block=min(1024, args.seq_len)))

    start = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        tree, start_step, _ = ckpt.restore(
            args.ckpt_dir, target={"params": params, "opt": opt_state})
        params, opt_state = tree["params"], tree["opt"]
        start = start_step + 1
        print(f"[resume] restored step {start - 1}")

    writer = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=3)
    gen = synthetic_token_batches(cfg.vocab_size, args.batch_size,
                                  args.seq_len, args.steps, seed=args.seed)
    t0 = time.perf_counter()
    for step, (tokens, labels) in enumerate(gen):
        if step < start:
            continue  # deterministic replay: skip consumed batches
        batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if cfg.family in ("audio", "vlm"):
            batch["frontend"] = jnp.zeros(
                (args.batch_size, cfg.frontend_seq, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0:
            print(f"step {step}: loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({time.perf_counter() - t0:.1f}s)", flush=True)
        if args.ckpt_every and step % args.ckpt_every == 0:
            writer.save(step, {"params": params, "opt": opt_state})
        if args.simulate_failure is not None and step == args.simulate_failure:
            writer.wait()
            print("[failure-injection] exiting mid-run", flush=True)
            os._exit(42)
    writer.close()
    print(f"done: final loss {float(metrics['loss']):.4f}")
    return 0


def main(argv: Optional[list] = None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--workload", choices=["tg", "dtdg", "lm"], default="tg")
    p.add_argument("--ckpt-dir", default="checkpoints")
    p.add_argument("--resume", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--simulate-failure", type=int, default=None)
    # tg
    p.add_argument("--model", default="tgat")
    p.add_argument("--dataset", default="tiny")
    p.add_argument("--data-scale", type=float, default=1.0)
    p.add_argument("--epochs", type=int, default=3)
    p.add_argument("--batch-size", type=int, default=200)
    p.add_argument("--k", type=int, default=20)
    p.add_argument("--eval-negatives", type=int, default=20)
    p.add_argument("--eval-every", type=int, default=0)
    # dtdg
    p.add_argument("--discretization", default="h")
    p.add_argument("--chunk-size", type=int, default=4)
    # lm
    p.add_argument("--arch", default="qwen3-0.6b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--ckpt-every", type=int, default=20)
    args = p.parse_args(argv)
    if args.workload == "tg":
        return train_tg(args)
    if args.workload == "dtdg":
        return train_dtdg(args)
    return train_lm(args)


if __name__ == "__main__":
    raise SystemExit(main())
