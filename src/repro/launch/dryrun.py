import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers, SPMD-partitions, and compiles.

For each cell, ``jax.jit(step).lower(*abstract_args).compile()`` must
succeed on both the single-pod (16, 16) mesh and the multi-pod (2, 16, 16)
mesh; memory_analysis() proves per-device fit and cost_analysis() feeds the
roofline table (single-pod).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod-only
  PYTHONPATH=src python -m repro.launch.dryrun --out results/dryrun.json
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs.archs import ARCHS, get_arch
from repro.configs.base import SHAPES
from repro.configs.cells import cells, shape_applicable, skipped_cells
from repro.distributed.sharding import sharding_context
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import donate_argnums, rules_for, step_and_args


def _compile_cell(cfg, shape, mesh, rules, kv_block, *, ce_chunks=0,
                  donate=(), accum_steps=1):
    with sharding_context(mesh, rules):
        step, args, _ = step_and_args(cfg, shape, mesh, rules,
                                      kv_block=kv_block, ce_chunks=ce_chunks,
                                      accum_steps=accum_steps)
        with mesh:
            lowered = jax.jit(step, donate_argnums=donate).lower(*args)
            compiled = lowered.compile()
    return compiled


def _depth_variant(cfg, units: int):
    """Same config at ``units`` stacked units, unrolled (so XLA cost
    analysis counts every layer — a lax.scan body is costed ONCE regardless
    of trip count, which silently underreports FLOPs by ~L x)."""
    import dataclasses as dc

    kw = dict(scan_layers=False, name=f"{cfg.name}@{units}u")
    if cfg.family == "vlm":
        kw["num_layers"] = cfg.cross_attn_every * units
    elif cfg.family == "audio":
        kw["num_layers"] = units
        kw["encoder_layers"] = units
    else:
        kw["num_layers"] = units
    return dc.replace(cfg, **kw), _num_units(cfg)


def _num_units(cfg) -> int:
    if cfg.family == "vlm":
        return cfg.num_layers // cfg.cross_attn_every
    return cfg.num_layers


def _extrapolated_roofline(cfg, shape, mesh, rules, kv_block, *,
                           ce_chunks=0, donate=()):
    """Exact-in-depth roofline stats: compile unrolled 1- and 2-unit
    variants, take the per-unit delta, extrapolate to full depth."""
    c1_cfg, n_units = _depth_variant(cfg, 1)
    c2_cfg, _ = _depth_variant(cfg, 2)
    kw = dict(ce_chunks=ce_chunks, donate=donate)
    r1 = hlo_analysis.analyze(
        _compile_cell(c1_cfg, shape, mesh, rules, kv_block, **kw), mesh.size)
    r2 = hlo_analysis.analyze(
        _compile_cell(c2_cfg, shape, mesh, rules, kv_block, **kw), mesh.size)
    return hlo_analysis.extrapolate(r1, r2, n_units)


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool = False,
             kv_block: int = 1024, verbose: bool = True,
             variant: str = "baseline") -> dict:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name, "status": "skipped",
                "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(shape, arch=cfg, variant=variant)
    opt = variant == "opt"
    ce_chunks = 8 if (opt and shape.kind == "train"
                      and shape.seq_len % 8 == 0) else 0
    donate = donate_argnums(shape) if opt else ()

    # 1) Full-depth scanned compile: THE dry-run proof (sharding coherence,
    #    per-device memory fit, collective schedule compiles).
    t0 = time.perf_counter()
    compiled = _compile_cell(cfg, shape, mesh, rules, kv_block,
                             ce_chunks=ce_chunks, donate=donate)
    t_compile = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    roof_once = hlo_analysis.analyze(compiled, mesh.size)

    # 2) Depth-exact roofline stats via 1-/2-unit unrolled extrapolation.
    roof = _extrapolated_roofline(cfg, shape, mesh, rules, kv_block,
                                  ce_chunks=ce_chunks, donate=donate)
    if shape.kind == "train":
        # AdamW moments are genuinely f32 on TPU as well: 2 moments x
        # (read + write) x 4B per param, sharded across devices.
        roof.legit_f32_bytes = 16.0 * cfg.param_count() / mesh.size

    mf = hlo_analysis.model_flops(cfg, shape)
    rec = {
        "arch": arch_name,
        "shape": shape_name,
        "variant": variant,
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "devices": mesh.size,
        "status": "ok",
        "compile_s": round(t_compile, 2),
        "model_flops": mf,
        "useful_flops_ratio": mf / max(roof.flops_global, 1.0),
        "mem_arg_gib": round(mem.argument_size_in_bytes / 2**30, 3),
        "mem_temp_gib": round(mem.temp_size_in_bytes / 2**30, 3),
        "fits_16g_hbm": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                         + mem.output_size_in_bytes) < 16 * 2**30,
        "collective_kinds_full": roof_once.collective.op_bytes,
        **roof.as_dict(),
    }
    if verbose:
        print(
            f"[{rec['mesh']}] {arch_name:22s} {shape_name:12s} "
            f"compile={t_compile:6.1f}s "
            f"mem(arg={mem.argument_size_in_bytes/2**30:6.2f}G "
            f"tmp={mem.temp_size_in_bytes/2**30:6.2f}G)/dev "
            f"flops/dev={roof.flops_per_device:.3e} "
            f"coll={roof.collective.wire_bytes/2**20:8.1f}MiB "
            f"dominant={roof.dominant} "
            f"useful={rec['useful_flops_ratio']:.2f}",
            flush=True,
        )
    return rec


def main(argv: Optional[list] = None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="single arch id (default: all)")
    p.add_argument("--shape", default=None, help="single shape (default: all)")
    p.add_argument("--single-pod-only", action="store_true")
    p.add_argument("--multi-pod-only", action="store_true")
    p.add_argument("--kv-block", type=int, default=1024)
    p.add_argument("--variant", default="baseline", choices=["baseline", "opt"])
    p.add_argument("--out", default="results/dryrun.json")
    p.add_argument("--append", action="store_true",
                   help="merge with existing results file")
    args = p.parse_args(argv)

    todo = []
    for cfg, shape in cells():
        if args.arch and cfg.name != args.arch:
            continue
        if args.shape and shape.name != args.shape:
            continue
        todo.append((cfg.name, shape.name))

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    results = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r.get("mesh")) for r in results}

    failures = 0
    for arch_name, shape_name in todo:
        for mp in meshes:
            key = (arch_name, shape_name, "multi_pod" if mp else "single_pod")
            if key in done:
                continue
            try:
                rec = run_cell(arch_name, shape_name, multi_pod=mp,
                               kv_block=args.kv_block, variant=args.variant)
            except Exception as e:  # a dry-run failure is a bug in the system
                traceback.print_exc()
                rec = {"arch": arch_name, "shape": shape_name,
                       "mesh": "multi_pod" if mp else "single_pod",
                       "status": "failed", "error": f"{type(e).__name__}: {e}"}
                failures += 1
            results.append(rec)
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    for arch, shape, reason in skipped_cells():
        if args.arch and arch != args.arch:
            continue
        print(f"[skip] {arch:22s} {shape:12s} {reason}")

    print(f"\n{len(results)} cells recorded, {failures} failures -> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
