"""Input ShapeDtypeStructs for every (arch x shape) dry-run cell.

Everything is a ShapeDtypeStruct with a NamedSharding — weak-type correct,
shardable, and never allocated. ``step_and_args`` returns the jittable step
function plus its abstract arguments for a cell.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import DEFAULT_RULES, Rules, logical_sharding
from repro.models.lm import model as M
from repro.optim import AdamWConfig
from repro.train.lm_train import abstract_opt_state, make_train_step


def rules_for(shape: ShapeConfig, base: Optional[Rules] = None,
              arch: Optional[ArchConfig] = None,
              variant: str = "baseline") -> Rules:
    """Per-shape sharding rules.

    decode shapes shard the KV-cache sequence axis over 'model' (kv heads
    are often not divisible by 16) and keep batch on (pod, data); for
    long_500k (batch=1) the batch rule is dropped automatically by the
    divisibility check and state lives on heads/model.

    ``variant="opt"`` applies the hillclimbed rules (EXPERIMENTS.md §Perf):
    MoE experts go expert-parallel on the 'model' axis (each device owns
    E/16 experts; activations move via all-to-all instead of every expert
    weight being gathered + activation all-reduced).
    """
    rules = dict(base or DEFAULT_RULES)
    if shape.kind == "decode":
        rules["cache_seq"] = "model"
        rules["kv_heads"] = None
    if (variant == "opt" and arch is not None and arch.family == "moe"
            and shape.kind != "decode"):
        # Expert parallelism: each model-shard owns E/16 experts; the
        # capacity axis shards over (pod, data) so expert matmuls are not
        # replicated across data shards (§Perf dbrx iteration 3).
        # Decode keeps the baseline (f-sharded) expert layout: with one
        # token per step, per-layer EP weight gathers would dominate —
        # weights must stay resident (§Perf cross-cell check).
        rules["experts"] = "model"
        rules["moe_mlp"] = None
        rules["expert_cap"] = ("pod", "data")
    return rules


def _sds(shape, dtype, axes, mesh, rules):
    sh = logical_sharding(axes, rules=rules, mesh=mesh, shape=shape)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh=None,
                rules: Optional[Rules] = None) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    rules = rules or rules_for(shape)
    cdt = jnp.dtype(cfg.compute_dtype)
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = _sds((B, S), jnp.int32, ("batch", "seq"), mesh, rules)
        specs["labels"] = _sds((B, S), jnp.int32, ("batch", "seq"), mesh, rules)
    elif shape.kind == "prefill":
        specs["tokens"] = _sds((B, S), jnp.int32, ("batch", "seq"), mesh, rules)
    if shape.kind in ("train", "prefill") and cfg.family in ("audio", "vlm"):
        F = cfg.frontend_seq
        specs["frontend"] = _sds((B, F, cfg.d_model), cdt,
                                 ("batch", "frames", None), mesh, rules)
    return specs


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh=None,
                rules: Optional[Rules] = None) -> Tuple[Any, ...]:
    """Full abstract argument tuple for the cell's step function."""
    rules = rules or rules_for(shape)
    params = M.abstract_params(cfg, mesh, rules)
    if shape.kind == "train":
        opt = abstract_opt_state(cfg, mesh, rules)
        return (params, opt, batch_specs(cfg, shape, mesh, rules))
    if shape.kind == "prefill":
        return (params, batch_specs(cfg, shape, mesh, rules))
    # decode: params, cache at fill level seq_len, one new token per
    # sequence. Cache length rounds up to a 512 multiple so the cache_seq
    # axis stays shardable (S+1 = 32769 is coprime with the mesh and would
    # silently drop the sharding rule -> 16x cache blow-up; §Perf iter 1).
    B, S = shape.global_batch, shape.seq_len
    cache = M.abstract_cache(cfg, B, _round_up(S + 1, 512), mesh, rules)
    tokens = _sds((B,), jnp.int32, ("batch",), mesh, rules)
    return (params, cache, tokens)


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def step_fn(cfg: ArchConfig, shape: ShapeConfig,
            kv_block: int = 1024, ce_chunks: int = 0,
            accum_steps: int = 1) -> Callable:
    if shape.kind == "train":
        return make_train_step(cfg, AdamWConfig(lr=3e-4), kv_block=kv_block,
                               ce_chunks=ce_chunks, accum_steps=accum_steps)
    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return M.prefill(params, cfg, batch,
                             max_len=_round_up(shape.seq_len + 1, 512),
                             kv_block=kv_block)
        return prefill_step

    def serve_step(params, cache, tokens):
        return M.decode_step(params, cfg, cache, tokens)

    return serve_step


def step_and_args(cfg: ArchConfig, shape: ShapeConfig, mesh=None,
                  rules: Optional[Rules] = None, kv_block: int = 1024,
                  ce_chunks: int = 0, accum_steps: int = 1):
    rules = rules or rules_for(shape, arch=cfg)
    return (step_fn(cfg, shape, kv_block, ce_chunks, accum_steps),
            input_specs(cfg, shape, mesh, rules), rules)


def donate_argnums(shape: ShapeConfig):
    """Buffer donation per step kind: train donates (params, opt_state);
    decode donates the cache (in-place dynamic-update-slice instead of a
    full cache copy per step). Prefill donates nothing (prompt reused)."""
    if shape.kind == "train":
        return (0, 1)
    if shape.kind == "decode":
        return (1,)
    return ()
