"""Roofline-term extraction from compiled XLA artifacts.

``cost_analysis()`` on an SPMD-partitioned module reports *per-device*
FLOPs/bytes (verified empirically: a (M,K)x(K,N) matmul sharded data=2
reports 2*(M/2)*K*N). Collective traffic is not in cost_analysis, so we
parse the optimized HLO: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op's operand bytes (per-device shapes),
plus a wire-byte estimate using standard ring-algorithm factors.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

import numpy as np

# v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"%([\w.\-]+)\s*=\s*(\([^)]*\)|[^\s]+)\s")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota form: [num_groups,group_size]<=...
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


@dataclasses.dataclass
class CollectiveStats:
    op_bytes: Dict[str, int]  # per collective kind: sum of result bytes
    operand_bytes: int  # per-device operand bytes, summed over ops
    wire_bytes: int  # ring-algorithm wire-byte estimate per device
    count: int
    wire_bytes_raw: int = 0  # before the CPU f32-normalization correction

    @property
    def total_result_bytes(self) -> int:
        return sum(self.op_bytes.values())


def parse_collectives(hlo_text: str, bf16_model: bool = True) -> CollectiveStats:
    """Sum collective traffic from the partitioned HLO.

    CPU-backend caveat: XLA:CPU float-normalization promotes bf16 dots (and
    the collectives fed by them) to f32, doubling measured bytes relative to
    the TPU program. With ``bf16_model=True`` (params + activations are
    bf16; only scalar/moment reductions are truly f32) f32 collective bytes
    are halved to recover the TPU-dtype traffic. Raw bytes are kept too.
    """
    op_bytes: Dict[str, int] = {}
    operand_total = 0
    wire_total = 0.0
    wire_raw = 0.0
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, result_type, kind = m.groups()
        rb = _shape_bytes(result_type)
        if rb == 0:
            continue
        n = max(_group_size(line), 1)
        count += 1
        # dtype correction: f32 tensors above scalar size are normalization
        # artifacts of a bf16 model (TPU would run them in bf16).
        corr = 1.0
        if bf16_model and re.search(r"\bf32\[\d", result_type) and rb > 4096:
            corr = 0.5
        op_bytes[kind] = op_bytes.get(kind, 0) + int(rb * corr)
        if kind == "all-gather":
            operand = rb // n
            wire = rb * (n - 1) / n
        elif kind == "all-reduce":
            operand = rb
            wire = 2 * rb * (n - 1) / n
        elif kind == "reduce-scatter":
            operand = rb * n
            wire = rb * (n - 1)
        elif kind == "all-to-all":
            operand = rb
            wire = rb * (n - 1) / n
        else:  # collective-permute
            operand = rb
            wire = rb
        operand_total += int(operand * corr)
        wire_total += wire * corr
        wire_raw += wire
    return CollectiveStats(op_bytes, operand_total, int(wire_total), count,
                           int(wire_raw))


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective: CollectiveStats
    num_devices: int
    # memory_analysis
    arg_bytes: int = 0
    temp_bytes: int = 0
    out_bytes: int = 0
    # bytes that are genuinely f32 on TPU too (e.g. optimizer moments);
    # everything else bf16 -> CPU float-normalization doubled it.
    legit_f32_bytes: float = 0.0

    @property
    def flops_global(self) -> float:
        return self.flops_per_device * self.num_devices

    @property
    def compute_s(self) -> float:
        # == flops_global / (chips * peak)
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def bytes_corrected(self) -> float:
        """TPU-dtype HBM traffic estimate: measured CPU bytes halve for the
        bf16 share; genuinely-f32 traffic (moments) is added back at full."""
        return self.bytes_per_device / 2.0 + self.legit_f32_bytes / 2.0

    @property
    def memory_s(self) -> float:
        return self.bytes_corrected / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective.wire_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline lower bound on step time (max of the three terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "flops_global": self.flops_global,
            "bytes_per_device": self.bytes_per_device,
            "bytes_per_device_corrected": self.bytes_corrected,
            "collective_operand_bytes": self.collective.operand_bytes,
            "collective_wire_bytes": self.collective.wire_bytes,
            "collective_wire_bytes_raw": self.collective.wire_bytes_raw,
            "collective_count": self.collective.count,
            "collective_by_kind": self.collective.op_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "arg_bytes_per_device": self.arg_bytes,
            "temp_bytes_per_device": self.temp_bytes,
            "out_bytes_per_device": self.out_bytes,
        }


def extrapolate(r1: "Roofline", r2: "Roofline", n_units: int) -> "Roofline":
    """Depth-exact stats from unrolled 1-unit and 2-unit compiles:
    total = cost(1) + (n_units - 1) * (cost(2) - cost(1)).

    The delta isolates one stacked unit; cost(1) carries the fixed parts
    (embedding, head, loss/optimizer or cache plumbing)."""

    def ext(a, b):
        return a + (n_units - 1) * max(b - a, 0.0)

    coll_kinds = {}
    for k in set(r1.collective.op_bytes) | set(r2.collective.op_bytes):
        a = r1.collective.op_bytes.get(k, 0)
        b = r2.collective.op_bytes.get(k, 0)
        coll_kinds[k] = int(ext(a, b))
    coll = CollectiveStats(
        coll_kinds,
        int(ext(r1.collective.operand_bytes, r2.collective.operand_bytes)),
        int(ext(r1.collective.wire_bytes, r2.collective.wire_bytes)),
        int(ext(r1.collective.count, r2.collective.count)),
        int(ext(r1.collective.wire_bytes_raw, r2.collective.wire_bytes_raw)),
    )
    out = Roofline(
        ext(r1.flops_per_device, r2.flops_per_device),
        ext(r1.bytes_per_device, r2.bytes_per_device),
        coll,
        r1.num_devices,
        legit_f32_bytes=max(r1.legit_f32_bytes, r2.legit_f32_bytes),
    )
    out.arg_bytes = int(ext(r1.arg_bytes, r2.arg_bytes))
    out.temp_bytes = max(r1.temp_bytes, r2.temp_bytes)
    out.out_bytes = int(ext(r1.out_bytes, r2.out_bytes))
    return out


def analyze(compiled, num_devices: int, legit_f32_bytes: float = 0.0) -> Roofline:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older JAX: one dict per computation
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = parse_collectives(compiled.as_text())
    r = Roofline(flops, byts, coll, num_devices, legit_f32_bytes=legit_f32_bytes)
    try:
        mem = compiled.memory_analysis()
        r.arg_bytes = int(mem.argument_size_in_bytes)
        r.temp_bytes = int(mem.temp_size_in_bytes)
        r.out_bytes = int(mem.output_size_in_bytes)
    except Exception:
        pass
    return r


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D for training (fwd+bwd), 2*N*D for inference,
    using active params for MoE."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
