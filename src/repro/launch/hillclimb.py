import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing driver: re-run a dry-run cell under a named
optimization configuration and append (hypothesis, before, after) records
to results/perf_iters.json.

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --cell yi-9b:decode_32k \
      --label donate+bf16attn --variant opt
"""

import argparse
import json
from typing import Optional

from repro.launch.dryrun import run_cell


def main(argv: Optional[list] = None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--cell", required=True, help="arch:shape")
    p.add_argument("--label", required=True)
    p.add_argument("--variant", default="opt")
    p.add_argument("--kv-block", type=int, default=1024)
    p.add_argument("--out", default="results/perf_iters.json")
    args = p.parse_args(argv)

    arch, shape = args.cell.split(":")
    rec = run_cell(arch, shape, multi_pod=False, kv_block=args.kv_block,
                   variant=args.variant)
    rec["label"] = args.label

    history = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            history = json.load(f)
    history.append(rec)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(history, f, indent=1)
    print(json.dumps({k: rec[k] for k in
                      ("arch", "shape", "label", "compute_s", "memory_s",
                       "collective_s", "dominant", "useful_flops_ratio",
                       "mem_temp_gib")}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
