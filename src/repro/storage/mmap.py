"""``MmapStore`` — the memory-mapped columnar ``EventStore`` backend.

On-disk layout (``docs/storage.md``): a directory holding **one ``.npy``
file per column** (``src.npy``/``dst.npy``/``edge_t.npy`` int64, optional
``edge_feats.npy`` float32, optional node-event and static-feature
columns) plus a fsync'd ``manifest.json`` recording dtype/shape/byte-size
per column. Opening a store memory-maps each column read-only
(``np.lib.format.open_memmap``), so every ``DGData``/loader/sampler path
downstream reads O(touched pages) instead of O(stream) — and
:meth:`MmapStore.release` hands the pages back (``madvise(MADV_DONTNEED)``)
so a windowed epoch's resident set stays bounded by the window.

Writes follow the ``distributed/checkpoint`` atomic-publish idiom: the
converter streams columns into ``<path>.tmp`` (fixed-size ``.npy`` headers
rewritten with the final row count at close), fsyncs every file, writes +
fsyncs the manifest, fsyncs the tmp directory, then ``os.rename``s it into
place and fsyncs the parent — a crash mid-convert can never publish a torn
store, and :meth:`MmapStore.is_intact` cross-checks byte sizes against the
manifest. The converters (:meth:`from_chunks` / :meth:`from_csv` /
:meth:`from_arrays`) are **chunked**: nothing ever materializes the full
stream, so a host can convert streams much larger than its RAM.
"""

from __future__ import annotations

import json
import mmap as _mmap_mod
import os
import struct
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core.granularity import TimeDelta
from repro.storage.base import EventStore

MANIFEST = "manifest.json"
FORMAT = "repro-eventstore"
VERSION = 1

# Fixed total .npy header size (magic + version + HEADER_LEN + dict + pad).
# Writing a placeholder header first and rewriting it with the final shape
# at close keeps the data stream append-only; 128 bytes fits any row count
# that fits an int64 and keeps data 64-byte aligned.
_NPY_HEADER_BYTES = 128

EDGE_COLUMNS = ("src", "dst", "edge_t")
OPTIONAL_COLUMNS = ("edge_feats", "eid", "node_ids", "node_t", "node_feats",
                    "static_node_feats")


def _fsync_path(path: str) -> None:
    """fsync a file or directory so the write survives power loss."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _npy_header(dtype: np.dtype, shape) -> bytes:
    """A v1.0 ``.npy`` header padded to exactly ``_NPY_HEADER_BYTES``."""
    descr = {"descr": np.lib.format.dtype_to_descr(np.dtype(dtype)),
             "fortran_order": False, "shape": tuple(int(s) for s in shape)}
    body = repr(descr).encode("latin1")
    magic = b"\x93NUMPY\x01\x00"
    hlen = _NPY_HEADER_BYTES - len(magic) - 2
    if len(body) > hlen - 1:
        raise ValueError(f"npy header too large for shape {shape}")
    return (magic + struct.pack("<H", hlen) + body
            + b" " * (hlen - 1 - len(body)) + b"\n")


class _ColumnWriter:
    """Append-only ``.npy`` column writer with a rewritten final header."""

    def __init__(self, path: str, dtype, width: Optional[int] = None):
        self.path = path
        self.dtype = np.dtype(dtype)
        self.width = width
        self.rows = 0
        self._f = open(path, "wb")
        self._f.write(_npy_header(self.dtype, self._shape(0)))

    def _shape(self, rows: int):
        return (rows,) if self.width is None else (rows, self.width)

    def append(self, arr: np.ndarray) -> None:
        arr = np.ascontiguousarray(arr, dtype=self.dtype)
        if arr.shape[1:] != self._shape(0)[1:]:
            raise ValueError(
                f"column {os.path.basename(self.path)}: chunk shape "
                f"{arr.shape} does not match {self._shape('N')}")
        self._f.write(arr.tobytes())
        self.rows += len(arr)

    def close(self) -> dict:
        """Rewrite the header with the final shape, fsync, and return the
        manifest entry for this column."""
        self._f.flush()
        self._f.seek(0)
        self._f.write(_npy_header(self.dtype, self._shape(self.rows)))
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        return {
            "dtype": np.lib.format.dtype_to_descr(self.dtype),
            "shape": list(self._shape(self.rows)),
            "bytes": os.path.getsize(self.path),
        }


class MmapStore(EventStore):
    """Memory-mapped columnar event storage (read side).

    ``MmapStore(path)`` validates the manifest and maps each column
    read-only; all ``EventStore`` queries then run on the mapped arrays.
    Build stores with the chunked converters: :meth:`from_arrays`,
    :meth:`from_chunks` (any iterable of column-dict chunks — the
    out-of-core entry point), :meth:`from_csv`, or :meth:`from_data`.
    """

    def __init__(self, path: str):
        self.path = str(path)
        man_path = os.path.join(self.path, MANIFEST)
        if not os.path.isfile(man_path):
            raise FileNotFoundError(
                f"{self.path!r} is not an event store (no {MANIFEST}); "
                f"build one with MmapStore.from_arrays/from_csv")
        with open(man_path) as f:
            man = json.load(f)
        if man.get("format") != FORMAT:
            raise ValueError(f"{man_path}: not a {FORMAT} manifest")
        if int(man.get("version", 0)) > VERSION:
            raise ValueError(
                f"{man_path}: version {man['version']} is newer than "
                f"supported {VERSION}")
        self.manifest = man
        self.num_nodes = int(man["num_nodes"])
        g = man["granularity"]
        self.granularity = TimeDelta(g["unit"], int(g.get("value", 1)))
        cols = {}
        for name, meta in man["columns"].items():
            fpath = os.path.join(self.path, name + ".npy")
            size = os.path.getsize(fpath) if os.path.isfile(fpath) else -1
            if size != meta["bytes"]:
                raise ValueError(
                    f"torn store: {fpath} has {size} bytes, manifest says "
                    f"{meta['bytes']} — rebuild the store")
            cols[name] = np.lib.format.open_memmap(fpath, mode="r")
            if list(cols[name].shape) != list(meta["shape"]):
                raise ValueError(
                    f"torn store: {fpath} shape {cols[name].shape} != "
                    f"manifest {meta['shape']}")
        self.src = cols["src"]
        self.dst = cols["dst"]
        self.edge_t = cols["edge_t"]
        self.edge_feats = cols.get("edge_feats")
        self._eids = cols.get("eid")
        self.node_ids = cols.get("node_ids")
        self.node_t = cols.get("node_t")
        self.node_feats = cols.get("node_feats")
        self.static_node_feats = cols.get("static_node_feats")
        self._columns = cols

    # -- residency -------------------------------------------------------
    def release(self) -> None:
        """Advise the kernel to reclaim every mapped page
        (``MADV_DONTNEED``): resident set drops to ~0 for the store,
        touched pages fault back in on next access. Called per-window by
        ``iter_windows(release=True)`` / the store-aware loaders, this
        bounds an epoch's RSS by the window size instead of the stream."""
        advise = getattr(_mmap_mod, "MADV_DONTNEED", None)
        if advise is None:  # pragma: no cover - non-Linux hosts
            return
        for arr in self._columns.values():
            mm = getattr(arr, "_mmap", None)
            if mm is not None:
                try:
                    mm.madvise(advise)
                except (ValueError, OSError):  # pragma: no cover
                    pass

    def __repr__(self) -> str:  # pragma: no cover
        return (f"MmapStore({self.path!r}, edges={self.num_edge_events}, "
                f"nodes={self.num_nodes}, d_edge={self.edge_feat_dim})")

    # -- integrity -------------------------------------------------------
    @staticmethod
    def is_intact(path: str) -> bool:
        """True iff ``path`` holds a manifest whose per-column byte sizes
        all match the files on disk (the torn-write check)."""
        try:
            man_path = os.path.join(path, MANIFEST)
            with open(man_path) as f:
                man = json.load(f)
            if man.get("format") != FORMAT:
                return False
            for name, meta in man["columns"].items():
                if os.path.getsize(
                        os.path.join(path, name + ".npy")) != meta["bytes"]:
                    return False
            return True
        except (OSError, ValueError, KeyError):
            return False

    # -- converters ------------------------------------------------------
    @classmethod
    def from_chunks(cls, path: str, chunks: Iterable[dict], *,
                    granularity: TimeDelta | str = "s",
                    num_nodes: Optional[int] = None,
                    node_events: Optional[dict] = None,
                    static_node_feats=None,
                    overwrite: bool = False) -> "MmapStore":
        """Stream column-dict chunks into a new store — the out-of-core
        converter every other ``from_*`` delegates to.

        Each chunk is ``{"src", "dst", "t"[, "edge_feats"][, "eid"]}``;
        chunks must arrive **time-sorted** (within and across chunks —
        validated; unsorted streams must be sorted upstream, e.g. via
        ``from_arrays``). Only one chunk is resident at a time. Publication
        is atomic: the store appears at ``path`` complete or not at all.
        ``node_events`` (``{"ids", "t"[, "feats"]}``, assumed small) and
        ``static_node_feats`` are written alongside when given.
        """
        path = str(path)
        granularity = TimeDelta.coerce(granularity)
        if os.path.exists(path):
            if not overwrite:
                raise FileExistsError(
                    f"{path} exists; pass overwrite=True to replace it")
            import shutil

            shutil.rmtree(path)
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            import shutil

            shutil.rmtree(tmp)
        os.makedirs(tmp)

        writers = {name: _ColumnWriter(os.path.join(tmp, name + ".npy"),
                                       np.int64)
                   for name in EDGE_COLUMNS}
        max_node = -1
        last_t = None
        try:
            for chunk in chunks:
                src = np.ascontiguousarray(chunk["src"], dtype=np.int64)
                dst = np.ascontiguousarray(chunk["dst"], dtype=np.int64)
                t = np.ascontiguousarray(chunk["t"], dtype=np.int64)
                if not (len(src) == len(dst) == len(t)):
                    raise ValueError("chunk src/dst/t length mismatch")
                if len(t) == 0:
                    continue
                if (last_t is not None and t[0] < last_t) or np.any(
                        np.diff(t) < 0):
                    raise ValueError(
                        "from_chunks requires a time-sorted stream (sort "
                        "upstream, or use from_arrays for in-RAM input)")
                last_t = int(t[-1])
                writers["src"].append(src)
                writers["dst"].append(dst)
                writers["edge_t"].append(t)
                if len(src):
                    max_node = max(max_node, int(src.max()), int(dst.max()))
                # Optional columns must be present from the first chunk on
                # (or never): the column files are append-only.
                first = writers["src"].rows == len(src)
                feats = chunk.get("edge_feats")
                if feats is None:
                    if "edge_feats" in writers:
                        raise ValueError(
                            "edge_feats missing from a chunk after being "
                            "present earlier")
                else:
                    feats = np.ascontiguousarray(feats, dtype=np.float32)
                    if feats.ndim != 2 or len(feats) != len(src):
                        raise ValueError("edge_feats must be (chunk, d)")
                    if "edge_feats" not in writers:
                        if not first:
                            raise ValueError(
                                "edge_feats appeared after the first chunk")
                        writers["edge_feats"] = _ColumnWriter(
                            os.path.join(tmp, "edge_feats.npy"), np.float32,
                            width=feats.shape[1])
                    writers["edge_feats"].append(feats)
                eid = chunk.get("eid")
                if eid is None:
                    if "eid" in writers:
                        raise ValueError(
                            "eid missing from a chunk after being present "
                            "earlier")
                else:
                    if "eid" not in writers:
                        if not first:
                            raise ValueError(
                                "eid appeared after the first chunk")
                        writers["eid"] = _ColumnWriter(
                            os.path.join(tmp, "eid.npy"), np.int64)
                    writers["eid"].append(
                        np.ascontiguousarray(eid, dtype=np.int64))

            if node_events is not None:
                ids = np.ascontiguousarray(node_events["ids"], np.int64)
                nt = np.ascontiguousarray(node_events["t"], np.int64)
                order = np.argsort(nt, kind="stable")
                writers["node_ids"] = _ColumnWriter(
                    os.path.join(tmp, "node_ids.npy"), np.int64)
                writers["node_ids"].append(ids[order])
                writers["node_t"] = _ColumnWriter(
                    os.path.join(tmp, "node_t.npy"), np.int64)
                writers["node_t"].append(nt[order])
                if len(ids):
                    max_node = max(max_node, int(ids.max()))
                nf = node_events.get("feats")
                if nf is not None:
                    nf = np.ascontiguousarray(nf, np.float32)
                    writers["node_feats"] = _ColumnWriter(
                        os.path.join(tmp, "node_feats.npy"), np.float32,
                        width=nf.shape[1])
                    writers["node_feats"].append(nf[order])
            if static_node_feats is not None:
                sf = np.ascontiguousarray(static_node_feats, np.float32)
                writers["static_node_feats"] = _ColumnWriter(
                    os.path.join(tmp, "static_node_feats.npy"), np.float32,
                    width=sf.shape[1])
                writers["static_node_feats"].append(sf)

            columns = {name: w.close() for name, w in writers.items()}
        except Exception:
            for w in writers.values():
                try:
                    w._f.close()
                except Exception:  # pragma: no cover
                    pass
            import shutil

            shutil.rmtree(tmp, ignore_errors=True)
            raise

        manifest = {
            "format": FORMAT,
            "version": VERSION,
            "num_nodes": int(num_nodes if num_nodes is not None
                             else max_node + 1),
            "granularity": {"unit": granularity.unit,
                            "value": granularity.value},
            "num_edge_events": columns["src"]["shape"][0],
            "num_node_events": columns.get("node_ids",
                                           {"shape": [0]})["shape"][0],
            "columns": columns,
        }
        man_path = os.path.join(tmp, MANIFEST)
        with open(man_path, "w") as f:
            json.dump(manifest, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        _fsync_path(tmp)
        os.rename(tmp, path)
        _fsync_path(os.path.dirname(os.path.abspath(path)) or ".")
        return cls(path)

    @classmethod
    def from_arrays(cls, path: str, src, dst, t, *, edge_feats=None,
                    eids=None, node_ids=None, node_t=None, node_feats=None,
                    static_node_feats=None,
                    granularity: TimeDelta | str = "s",
                    num_nodes: Optional[int] = None,
                    chunk_rows: int = 1 << 18,
                    overwrite: bool = False) -> "MmapStore":
        """Convert in-RAM arrays (sorted here if needed — they already fit)
        by streaming fixed-size slices through :meth:`from_chunks`."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        t = np.asarray(t, dtype=np.int64)
        if not (len(src) == len(dst) == len(t)):
            raise ValueError("src/dst/t length mismatch")
        if len(t) and np.any(np.diff(t) < 0):
            order = np.argsort(t, kind="stable")
            src, dst, t = src[order], dst[order], t[order]
            if edge_feats is not None:
                edge_feats = np.asarray(edge_feats, np.float32)[order]
            if eids is not None:
                eids = np.asarray(eids, np.int64)[order]

        def chunks():
            for lo in range(0, max(len(src), 1), chunk_rows):
                hi = min(lo + chunk_rows, len(src))
                if hi <= lo:
                    break
                c = {"src": src[lo:hi], "dst": dst[lo:hi], "t": t[lo:hi]}
                if edge_feats is not None:
                    c["edge_feats"] = edge_feats[lo:hi]
                if eids is not None:
                    c["eid"] = eids[lo:hi]
                yield c

        node_events = None
        if node_ids is not None:
            node_events = {"ids": node_ids, "t": node_t}
            if node_feats is not None:
                node_events["feats"] = node_feats
        return cls.from_chunks(
            path, chunks(), granularity=granularity, num_nodes=num_nodes,
            node_events=node_events, static_node_feats=static_node_feats,
            overwrite=overwrite)

    @classmethod
    def from_data(cls, path: str, data, *, chunk_rows: int = 1 << 18,
                  overwrite: bool = False) -> "MmapStore":
        """Convert an existing ``DGData`` (columns already sorted)."""
        return cls.from_arrays(
            path, data.src, data.dst, data.edge_t,
            edge_feats=data.edge_feats, node_ids=data.node_ids,
            node_t=data.node_t, node_feats=data.node_feats,
            static_node_feats=data.static_node_feats,
            granularity=data.granularity, num_nodes=data.num_nodes,
            chunk_rows=chunk_rows, overwrite=overwrite)

    @classmethod
    def from_csv(cls, path: str, csv_path: str, *, src_col: int = 0,
                 dst_col: int = 1, t_col: int = 2,
                 feat_cols: Optional[Sequence[int]] = None,
                 delimiter: str = ",", skip_header: int = 1,
                 granularity: TimeDelta | str = "s",
                 num_nodes: Optional[int] = None,
                 chunk_rows: int = 1 << 16,
                 overwrite: bool = False) -> "MmapStore":
        """Chunked CSV converter: parse ``chunk_rows`` lines at a time
        (int64 id/time columns parsed exactly — no float round-trip) and
        stream them through :meth:`from_chunks`. The CSV must be
        time-sorted; the full file is never resident."""
        from repro.core.graph import iter_csv_chunks

        return cls.from_chunks(
            path,
            iter_csv_chunks(csv_path, src_col=src_col, dst_col=dst_col,
                            t_col=t_col, feat_cols=feat_cols,
                            delimiter=delimiter, skip_header=skip_header,
                            chunk_rows=chunk_rows),
            granularity=granularity, num_nodes=num_nodes,
            overwrite=overwrite)
