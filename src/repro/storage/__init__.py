"""Pluggable out-of-core event storage (``docs/storage.md``).

``EventStore`` is the backend contract (sorted columnar event arrays +
range queries + resumable windowed iteration); ``InMemoryStore`` is the
bit-identical host-numpy default, ``MmapStore`` the memory-mapped columnar
backend for streams larger than host RAM. ``streaming_csr`` builds the
uniform samplers' adjacency in O(chunk) resident memory, and
``StoreEventLoader`` feeds store windows through the hook pipeline into
``PrefetchLoader``.
"""

from repro.storage.base import EventStore, EventWindow, WindowIterator
from repro.storage.csr import streaming_csr
from repro.storage.memory import InMemoryStore
from repro.storage.mmap import MmapStore
from repro.storage.windows import StoreEventLoader

__all__ = [
    "EventStore",
    "EventWindow",
    "WindowIterator",
    "InMemoryStore",
    "MmapStore",
    "StoreEventLoader",
    "streaming_csr",
]
