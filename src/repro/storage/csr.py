"""Streaming (two-pass, O(chunk)-resident) CSR-by-time adjacency build.

The uniform samplers' adjacency is the doubled edge list — each event
contributes ``(src -> dst)`` and ``(dst -> src)`` — laid out node-major
with times ascending per node. The in-RAM builders
(``UniformSampler.build`` / ``DeviceUniformSampler._host_csr``) get there
with one global ``lexsort`` over ``2E`` materialized arrays;
:func:`streaming_csr` produces the same layout from any ``EventStore`` in
two windowed passes over the stream:

  1. **degree count** — accumulate per-node degrees (``bincount`` per
     window) into the global ``indptr``, and collect the unique-time table
     ``tvals`` (the stream is time-sorted, so per-window uniques merge at
     boundaries in O(#distinct) memory);
  2. **chunked fill** — for each window, double its events in *event
     order* (src entry then dst entry per event), stable-sort the chunk by
     node, and scatter each node's run at its write cursor. Because the
     stream is time-sorted, per-node runs land time-ascending — the CSR
     invariant — without ever sorting (or holding) the full edge list.

Only one window is resident at a time; the output arrays are plain RAM by
default or disk-backed memmaps under ``scratch_dir`` (for adjacencies that
exceed host RAM — the sharded device sampler then slices them per shard
without any full-size host copy). The layout is **bit-identical** to the
in-RAM builders whenever no two *distinct* events share a ``(node,
timestamp)`` pair (always true for streams with unique timestamps;
self-loops are fine). On colliding pairs the builders break ties
differently — streaming keeps event order per entry-pair, ``lexsort``
keeps all src-side entries first — both are valid time-respecting layouts
and sampling distributions are identical; pipelines that need bit-exact
backend parity build both backends through this function (see
``train.loop.CTDGLinkPipeline``).
"""

from __future__ import annotations

import os
from typing import Optional

import numpy as np


def _alloc(scratch_dir: Optional[str], name: str, shape, dtype):
    """RAM array, or a disk-backed memmap under ``scratch_dir``."""
    if scratch_dir is None:
        return np.empty(shape, dtype)
    os.makedirs(scratch_dir, exist_ok=True)
    return np.lib.format.open_memmap(
        os.path.join(scratch_dir, name + ".npy"), mode="w+", dtype=dtype,
        shape=tuple(shape))


def streaming_csr(store, *, num_nodes: Optional[int] = None,
                  chunk_size: int = 1 << 20,
                  scratch_dir: Optional[str] = None,
                  with_keys: bool = True,
                  release: bool = True,
                  telemetry=None) -> dict:
    """Build the node-major/time-ascending doubled-edge CSR from a store.

    Returns ``{"adj_nbr", "adj_t", "adj_e", "indptr"}`` int64 (the shared
    uniform-sampler checkpoint contract) plus — when ``with_keys`` — the
    derived search structures ``{"adj_key", "tvals", "base"}`` that
    ``DeviceUniformSampler``'s sharded path consumes directly. Peak
    residency is O(chunk) beyond the outputs; pass ``scratch_dir`` to park
    the O(E) outputs on disk too. ``release=True`` drops the store's
    mapped pages after each window (memmap backends). ``telemetry`` (a
    ``repro.obs.Telemetry``) times each pass as a ``storage/csr_pass1`` /
    ``storage/csr_pass2`` span and counts windows per pass
    (``storage/csr_windows``, on top of the window iterator's own
    read/release counters).
    """
    from repro.obs import NULL

    tel = telemetry if telemetry is not None else NULL
    n = int(num_nodes if num_nodes is not None else store.num_nodes)
    E = store.num_edge_events

    # -- pass 1: degrees + unique-time table ----------------------------
    deg = np.zeros(n, dtype=np.int64)
    tvals_parts = []
    last_t = None
    with tel.span("storage/csr_pass1", events=E):
        for w in store.iter_windows(batch_size=chunk_size, release=release,
                                    telemetry=tel):
            tel.count("storage/csr_windows")
            deg += np.bincount(w.src, minlength=n)
            deg += np.bincount(w.dst, minlength=n)
            if with_keys and len(w):
                u = np.unique(np.asarray(w.t, dtype=np.int64))
                if last_t is not None and len(u) and u[0] == last_t:
                    u = u[1:]
                if len(u):
                    tvals_parts.append(u)
                    last_t = int(u[-1])
    indptr = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
    m = int(indptr[-1])
    assert m == 2 * E, "degree pass disagrees with the event count"

    tvals = base = None
    if with_keys:
        tvals = (np.concatenate(tvals_parts) if tvals_parts
                 else np.empty(0, np.int64))
        base = len(tvals) + 1

    # -- pass 2: chunked fill at per-node write cursors ------------------
    adj_nbr = _alloc(scratch_dir, "adj_nbr", (m,), np.int64)
    adj_t = _alloc(scratch_dir, "adj_t", (m,), np.int64)
    adj_e = _alloc(scratch_dir, "adj_e", (m,), np.int64)
    adj_key = (_alloc(scratch_dir, "adj_key", (m,), np.int64)
               if with_keys else None)
    cursor = indptr[:-1].copy()
    with tel.span("storage/csr_pass2", entries=m):
        for w in store.iter_windows(batch_size=chunk_size, release=release,
                                    telemetry=tel):
            tel.count("storage/csr_windows")
            c = len(w)
            if c == 0:
                continue
            # Doubled entries in event order: (src->dst) then (dst->src).
            nodes = np.empty(2 * c, np.int64)
            nodes[0::2], nodes[1::2] = w.src, w.dst
            nbrs = np.empty(2 * c, np.int64)
            nbrs[0::2], nbrs[1::2] = w.dst, w.src
            times = np.repeat(np.asarray(w.t, np.int64), 2)
            es = np.repeat(np.asarray(w.eids, np.int64), 2)
            order = np.argsort(nodes, kind="stable")
            snodes = nodes[order]
            uniq, starts, counts = np.unique(snodes, return_index=True,
                                             return_counts=True)
            pos = cursor[snodes] + (
                np.arange(2 * c) - np.repeat(starts, counts))
            adj_nbr[pos] = nbrs[order]
            st = times[order]
            adj_t[pos] = st
            adj_e[pos] = es[order]
            if with_keys:
                adj_key[pos] = snodes * base + np.searchsorted(tvals, st)
            cursor[uniq] += counts
    out = {"adj_nbr": adj_nbr, "adj_t": adj_t, "adj_e": adj_e,
           "indptr": indptr}
    if with_keys:
        out.update(adj_key=adj_key, tvals=tvals, base=base)
    return out
