"""Store-driven batch loading: ``EventStore.iter_windows`` into the hook
pipeline and ``PrefetchLoader``.

``StoreEventLoader`` is the storage-native sibling of
``core.loader.DGDataLoader``: it iterates a store's windows (by event
count or by time), materializes each as a hook-compatible ``Batch``
(``src``/``dst``/``time``[/``edge_feats``] + global ``eids`` meta), runs
the ``HookManager`` pipeline, and yields — so it drops into every place a
``DGDataLoader`` fits, including as the inner loader of a
``PrefetchLoader`` (the background thread prepares window ``i+1`` while
the jitted step consumes window ``i``, exactly as with the in-RAM
loader). ``release=True`` returns the backend's mapped pages after each
batch, bounding a whole epoch's resident set by the window size. The
iterator's resume cursor (``state_dict``) checkpoints mid-epoch positions
— see ``docs/storage.md``.
"""

from __future__ import annotations

from typing import Iterator, Optional, Union

from repro.core.batch import Batch
from repro.storage.base import EventStore


class StoreEventLoader:
    """Iterate an ``EventStore`` as hook-processed ``Batch``es.

    Exactly one of ``batch_size`` / ``time_window`` selects the iteration
    mode (``DGDataLoader``'s CTDG/DTDG split). ``start`` resumes from a
    row or a ``WindowIterator.state_dict`` cursor; the live cursor is
    exposed via :meth:`state_dict` for mid-epoch checkpointing.
    ``telemetry`` (a ``repro.obs.Telemetry``) forwards to
    ``iter_windows`` for the window read/release counters.
    """

    def __init__(self, store: EventStore, hook_manager=None,
                 batch_size: Optional[int] = None,
                 time_window: Optional[int] = None, *,
                 start: Union[None, int, dict] = None,
                 emit_empty: bool = False, release: bool = False,
                 telemetry=None):
        self.store = store
        self.manager = hook_manager
        self._kw = dict(batch_size=batch_size, time_window=time_window,
                        emit_empty=emit_empty, release=release,
                        telemetry=telemetry)
        # Validate eagerly (and fix the resume point even if iteration
        # starts later).
        self._windows = store.iter_windows(start=start, **self._kw)

    def state_dict(self) -> dict:
        """The underlying window iterator's resume cursor."""
        return self._windows.state_dict()

    def __len__(self) -> int:
        return len(self._windows)

    def __iter__(self) -> Iterator[Batch]:
        for w in self._windows:
            batch = w.to_batch()
            batch.meta["granularity"] = self.store.granularity
            if self.manager is not None:
                batch = self.manager.execute(batch)
            yield batch
