"""Pluggable event-storage backends: the ``EventStore`` contract.

Everything upstream of this package (``DGData``, loaders, samplers, the
``tg.Experiment`` front door) consumes a temporal event stream as sorted
columnar arrays — ``src``/``dst``/``edge_t`` plus optional edge/node
features. ``EventStore`` makes the *residence* of those columns pluggable:

  * :class:`~repro.storage.memory.InMemoryStore` wraps host numpy arrays —
    the bit-identical default, zero behavior change vs. raw ``DGData``;
  * :class:`~repro.storage.mmap.MmapStore` memory-maps one ``.npy`` file
    per column from an on-disk directory with a fsync'd JSON manifest, so
    TGB-scale streams iterate with O(window) resident memory.

The contract (``docs/storage.md``) is deliberately small: column
attributes (any ``np.ndarray``-compatible type — ``np.memmap`` included),
``edge_range``/``node_event_range`` binary-search range queries with the
exact ``DGData`` semantics, bounds-checked row windows (``edge_window``),
and resumable windowed iteration (``iter_windows``) whose host batches
feed ``PrefetchLoader`` via :class:`~repro.storage.windows.StoreEventLoader`.
``DGData.from_store`` lifts any backend into the existing array-of-struct
API without copying, which is how the rest of the stack becomes
backend-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple, Union

import numpy as np

from repro.core.granularity import TimeDelta


@dataclasses.dataclass(frozen=True)
class EventWindow:
    """One contiguous slice ``[lo, hi)`` of a store's edge-event stream.

    Arrays are host views into the backend's columns (numpy views for
    ``InMemoryStore``, memmap views for ``MmapStore`` — nothing is copied
    until a consumer writes or stages to device). ``eids`` are *global*
    event ids (row indices, int64 end-to-end until device staging).
    ``window`` is the ``(t_lo, t_hi)`` wall-clock bound for time-windowed
    iteration, ``None`` for event-count windows.
    """

    lo: int
    hi: int
    src: np.ndarray
    dst: np.ndarray
    t: np.ndarray
    eids: np.ndarray
    edge_feats: Optional[np.ndarray] = None
    window: Optional[Tuple[int, int]] = None

    def __len__(self) -> int:
        return self.hi - self.lo

    def to_batch(self):
        """This window as a loader-compatible ``core.Batch`` (``src``/
        ``dst``/``time``[/``edge_feats``] data keys; ``eids``/``window``
        meta) — the shape every hook in ``RECIPE_TGB_LINK`` expects."""
        from repro.core.batch import Batch

        raw = {"src": self.src, "dst": self.dst, "time": self.t}
        if self.edge_feats is not None:
            raw["edge_feats"] = self.edge_feats
        return Batch(raw, {"eids": self.eids, "window": self.window})


class WindowIterator:
    """Resumable iterator over a store's event windows.

    Produced by :meth:`EventStore.iter_windows`. The cursor —
    ``state_dict()`` → ``{"row", "tick"}`` — is plain int64 numpy, so it
    rides any checkpoint tree (``distributed/checkpoint``) and resuming
    mid-stream (``iter_windows(..., start=state)``) replays the remaining
    windows bit-identically (see ``tests/test_storage.py``).
    """

    def __init__(self, store: "EventStore", batch_size: Optional[int],
                 time_window: Optional[int], start: Union[None, int, dict],
                 emit_empty: bool, release: bool, telemetry=None):
        if (batch_size is None) == (time_window is None):
            raise ValueError("set exactly one of batch_size / time_window")
        if batch_size is not None and batch_size <= 0:
            raise ValueError(f"batch_size must be positive, got {batch_size}")
        if time_window is not None:
            if store.granularity.is_event_ordered:
                raise ValueError(
                    "time_window iteration requires a real-time granularity; "
                    "this store is event-ordered — use batch_size"
                )
            if time_window <= 0:
                raise ValueError(
                    f"time_window must be positive, got {time_window}")
        from repro.obs import NULL

        self._store = store
        self._batch_size = batch_size
        self._ticks = time_window
        self._emit_empty = emit_empty
        self._release = release
        self._telemetry = telemetry if telemetry is not None else NULL
        span = store.time_span
        self._t0, self._t_end = span[0], span[1] + 1
        if isinstance(start, dict):
            self._row = int(start["row"])
            self._tick = int(start["tick"])
        else:
            self._tick = 0
            self._row = 0 if start is None else int(start)
            if self._row:
                if batch_size is None:
                    raise ValueError(
                        "start= as a bare row only applies to batch_size "
                        "iteration; resume time windows from a state_dict")
                if self._row < 0 or self._row > store.num_edge_events:
                    raise ValueError(
                        f"start row {self._row} out of range "
                        f"[0, {store.num_edge_events}]")

    # -- checkpoint contract -------------------------------------------
    def state_dict(self) -> dict:
        """The resume cursor: next unread row (+ next tick for time
        windows), as int64 leaves for checkpoint trees."""
        return {"row": np.int64(self._row), "tick": np.int64(self._tick)}

    def __len__(self) -> int:
        if self._batch_size is not None:
            left = self._store.num_edge_events - self._row
            return -(-left // self._batch_size) if left > 0 else 0
        span = self._t_end - (self._t0 + self._tick * self._ticks)
        return max(int(np.ceil(span / self._ticks)), 0)

    def __iter__(self) -> Iterator[EventWindow]:
        if self._batch_size is not None:
            yield from self._iter_events()
        else:
            yield from self._iter_time()

    def _iter_events(self) -> Iterator[EventWindow]:
        n = self._store.num_edge_events
        while self._row < n:
            lo = self._row
            hi = min(lo + self._batch_size, n)
            w = self._store.edge_window(lo, hi)
            self._row = hi
            self._telemetry.count("storage/windows_read")
            yield w
            if self._release:
                self._store.release()
                self._telemetry.count("storage/windows_released")

    def _iter_time(self) -> Iterator[EventWindow]:
        while True:
            t = self._t0 + self._tick * self._ticks
            if t >= self._t_end:
                return
            t_next = min(t + self._ticks, self._t_end)
            lo, hi = self._store.edge_range(t, t_next)
            self._tick += 1
            self._row = hi
            if hi > lo or self._emit_empty:
                self._telemetry.count("storage/windows_read")
                yield self._store.edge_window(lo, hi, window=(t, t_next))
                if self._release:
                    self._store.release()
                    self._telemetry.count("storage/windows_released")


class EventStore:
    """Base class of the pluggable event-storage backends.

    Subclasses populate the column attributes (``src``/``dst``/``edge_t``
    int64 sorted by time, optional ``edge_feats`` float32, the optional
    node-event columns, ``static_node_feats``) plus ``num_nodes`` and
    ``granularity``; everything else — range queries, bounds-checked
    windows, resumable iteration — is implemented here against the
    contract. Columns may be any ndarray-compatible type; ``np.memmap``
    keeps the backend out-of-core. ``eids`` are implicit row indices
    (``[0, num_edge_events)``, int64) unless the backend stores an
    explicit ``eid`` column — see ``docs/storage.md``.
    """

    src: np.ndarray
    dst: np.ndarray
    edge_t: np.ndarray
    edge_feats: Optional[np.ndarray] = None
    node_ids: Optional[np.ndarray] = None
    node_t: Optional[np.ndarray] = None
    node_feats: Optional[np.ndarray] = None
    static_node_feats: Optional[np.ndarray] = None
    num_nodes: int = 0
    granularity: TimeDelta = TimeDelta.event()
    _eids: Optional[np.ndarray] = None

    # -- derived sizes --------------------------------------------------
    @property
    def num_edge_events(self) -> int:
        """Number of edge events (rows) in the store."""
        return len(self.src)

    @property
    def num_node_events(self) -> int:
        """Number of node events (0 when the backend has none)."""
        return 0 if self.node_ids is None else len(self.node_ids)

    @property
    def edge_feat_dim(self) -> int:
        """Edge-feature width (0 when the store has no edge features)."""
        return 0 if self.edge_feats is None else int(self.edge_feats.shape[1])

    @property
    def node_feat_dim(self) -> int:
        """Node-event feature width (0 when absent)."""
        return 0 if self.node_feats is None else int(self.node_feats.shape[1])

    @property
    def time_span(self) -> Tuple[int, int]:
        """``[min_t, max_t]`` over all events — ``DGData.time_span``
        semantics (O(1): the columns are time-sorted)."""
        ts = [self.edge_t] if len(self.edge_t) else []
        if self.node_t is not None and len(self.node_t):
            ts.append(self.node_t)
        if not ts:
            return (0, 0)
        return (int(min(int(t[0]) for t in ts)),
                int(max(int(t[-1]) for t in ts)))

    # -- range queries (DGData semantics) --------------------------------
    def edge_range(self, t_lo: Optional[int],
                   t_hi: Optional[int]) -> Tuple[int, int]:
        """Edge rows with ``t in [t_lo, t_hi)`` — O(log E) binary search
        over the sorted timestamp column (O(log E) *pages* touched for a
        memmap backend)."""
        lo = 0 if t_lo is None else int(
            np.searchsorted(self.edge_t, t_lo, "left"))
        hi = (self.num_edge_events if t_hi is None
              else int(np.searchsorted(self.edge_t, t_hi, "left")))
        return lo, hi

    def node_event_range(self, t_lo, t_hi) -> Tuple[int, int]:
        """Node-event rows with ``t in [t_lo, t_hi)`` (``(0, 0)`` when the
        backend holds no node events)."""
        if self.node_t is None:
            return 0, 0
        lo = 0 if t_lo is None else int(
            np.searchsorted(self.node_t, t_lo, "left"))
        hi = (len(self.node_t) if t_hi is None
              else int(np.searchsorted(self.node_t, t_hi, "left")))
        return lo, hi

    # -- windows ---------------------------------------------------------
    def edge_window(self, lo: int, hi: int, window=None) -> EventWindow:
        """The bounds-checked row window ``[lo, hi)`` as an
        :class:`EventWindow` (empty windows — ``lo == hi`` — are valid;
        ``lo > hi`` or out-of-range rows raise ``ValueError``)."""
        n = self.num_edge_events
        if lo > hi:
            raise ValueError(f"edge window lo {lo} > hi {hi}")
        if lo < 0 or hi > n:
            raise ValueError(
                f"edge window [{lo}, {hi}) out of range [0, {n})")
        eids = (np.arange(lo, hi, dtype=np.int64) if self._eids is None
                else np.asarray(self._eids[lo:hi], dtype=np.int64))
        return EventWindow(
            lo=int(lo), hi=int(hi),
            src=self.src[lo:hi], dst=self.dst[lo:hi], t=self.edge_t[lo:hi],
            eids=eids,
            edge_feats=(None if self.edge_feats is None
                        else self.edge_feats[lo:hi]),
            window=window,
        )

    def iter_windows(self, batch_size: Optional[int] = None,
                     time_window: Optional[int] = None, *,
                     start: Union[None, int, dict] = None,
                     emit_empty: bool = False,
                     release: bool = False,
                     telemetry=None) -> WindowIterator:
        """Iterate the stream as :class:`EventWindow` host batches.

        Exactly one of ``batch_size`` (fixed event count, CTDG-style) or
        ``time_window`` (fixed span in native granularity ticks,
        DTDG-style; empty windows skipped unless ``emit_empty``) selects
        the mode — the same split ``DGDataLoader`` draws. ``start``
        resumes: a row index, or a :meth:`WindowIterator.state_dict`
        cursor restored from a checkpoint. ``release=True`` calls
        :meth:`release` after each yielded window, bounding a memmap
        backend's resident set by O(window) instead of O(touched stream).
        ``telemetry`` (a ``repro.obs.Telemetry``) counts
        ``storage/windows_read`` / ``storage/windows_released`` per
        window yielded/released (``docs/observability.md``).
        """
        return WindowIterator(self, batch_size, time_window, start,
                              emit_empty, release, telemetry)

    # -- residency -------------------------------------------------------
    def release(self) -> None:
        """Drop any reclaimable residency (no-op for in-memory backends;
        ``MmapStore`` advises the kernel to evict its mapped pages)."""

    # -- bridges ---------------------------------------------------------
    def to_data(self):
        """This store as a zero-copy ``DGData`` view (columns aliased, not
        copied) — the bridge into every existing loader/sampler/pipeline."""
        from repro.core.graph import DGData

        return DGData.from_store(self)
