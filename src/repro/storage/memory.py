"""``InMemoryStore`` — the host-numpy ``EventStore`` backend.

Wraps today's in-RAM columnar arrays behind the storage contract with zero
behavior change: construction applies the exact ``DGData.from_arrays``
normalization (int64/float32 casts, stable sort by timestamp), and
``InMemoryStore.from_data`` aliases an existing ``DGData``'s columns
without copying — so a pipeline run off this backend is bit-identical to
one run off the raw arrays. It doubles as the parity oracle for
``MmapStore`` in ``tests/test_storage.py``.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.granularity import TimeDelta
from repro.storage.base import EventStore


class InMemoryStore(EventStore):
    """Host-numpy event storage (the bit-identical default backend)."""

    def __init__(self, src, dst, t, edge_feats=None, node_ids=None,
                 node_t=None, node_feats=None, static_node_feats=None,
                 granularity: TimeDelta | str = "s",
                 num_nodes: Optional[int] = None):
        from repro.core.graph import DGData

        data = DGData.from_arrays(
            src, dst, t, edge_feats=edge_feats, node_ids=node_ids,
            node_t=node_t, node_feats=node_feats,
            static_node_feats=static_node_feats, granularity=granularity,
            num_nodes=num_nodes,
        )
        self._init_from(data)

    def _init_from(self, data) -> None:
        self.src = data.src
        self.dst = data.dst
        self.edge_t = data.edge_t
        self.edge_feats = data.edge_feats
        self.node_ids = data.node_ids
        self.node_t = data.node_t
        self.node_feats = data.node_feats
        self.static_node_feats = data.static_node_feats
        self.num_nodes = int(data.num_nodes)
        self.granularity = data.granularity
        self._eids = None

    @classmethod
    def from_data(cls, data) -> "InMemoryStore":
        """Alias a ``DGData``'s (already sorted) columns — no copy."""
        self = cls.__new__(cls)
        self._init_from(data)
        return self

    def __repr__(self) -> str:  # pragma: no cover
        return (f"InMemoryStore(edges={self.num_edge_events}, "
                f"nodes={self.num_nodes}, d_edge={self.edge_feat_dim})")
