"""JAX-level observability: profiler trace capture and device-memory gauges.

The span/counter layer (``repro.obs.telemetry``) sees host wall-clock
only; the two hooks here reach into the JAX runtime for the rest:

  * :func:`trace_capture` wraps a code region in ``jax.profiler.trace``,
    writing a TensorBoard/XProf trace (per-op device timelines, HLO) to
    a log directory — the "zoom in" tool once a span points at a slow
    phase (capture recipe in ``docs/observability.md``);
  * :func:`device_memory_gauges` snapshots every visible device's
    ``memory_stats()`` into gauges (``device{i}/bytes_in_use`` etc.).
    CPU devices report no stats (``memory_stats()`` is ``None``) and are
    skipped, so the call is safe on any backend.
"""

from __future__ import annotations

import contextlib
from typing import Dict, Optional

from repro.obs.telemetry import Telemetry

# memory_stats keys worth exporting when present (backend-dependent).
_MEM_KEYS = ("bytes_in_use", "peak_bytes_in_use", "bytes_limit",
             "num_allocs", "bytes_reserved")


@contextlib.contextmanager
def trace_capture(logdir: str, telemetry: Optional[Telemetry] = None):
    """Capture a ``jax.profiler`` trace of the enclosed region.

    Writes the trace under ``logdir`` (view with TensorBoard's profile
    plugin or XProf). When ``telemetry`` is given, the region also emits
    a ``profiler/trace`` span whose attrs carry the log directory, so the
    JSONL stream records that (and where) a trace was taken. The context
    degrades to a no-op if the installed JAX has no profiler (some
    minimal builds), rather than failing the run being profiled.
    """
    import jax

    trace = getattr(getattr(jax, "profiler", None), "trace", None)
    tel = telemetry if telemetry is not None else Telemetry()
    with tel.span("profiler/trace", logdir=str(logdir)):
        if trace is None:  # pragma: no cover - full jax always has it
            yield
        else:
            with trace(str(logdir)):
                yield


def device_memory_gauges(telemetry: Telemetry,
                         prefix: str = "device") -> Dict[str, float]:
    """Snapshot per-device memory stats into ``telemetry`` gauges.

    For each visible device with ``memory_stats()`` support (GPU/TPU;
    CPU returns ``None`` and is skipped) sets gauges named
    ``{prefix}{i}/{key}`` for the well-known keys present. Returns the
    gauges set (empty on CPU-only hosts), so callers can log or assert
    on them directly.
    """
    import jax

    out: Dict[str, float] = {}
    for i, dev in enumerate(jax.devices()):
        stats = None
        try:
            stats = dev.memory_stats()
        except Exception:  # pragma: no cover - backend-specific
            continue
        if not stats:
            continue
        for key in _MEM_KEYS:
            if key in stats:
                name = f"{prefix}{i}/{key}"
                out[name] = float(stats[key])
                telemetry.gauge(name, stats[key])
    return out
