"""``Telemetry`` — spans, counters, gauges, and latency histograms.

One ``Telemetry`` object is the write-side API the instrumented paths
(``train/loop.py``, ``core/loader.py``, ``serve/graph_service.py``,
``repro.storage``) share:

  * ``span(name)``    — a timed section on the monotonic clock. Spans
    nest per thread (a thread-local stack turns ``name`` into the dotted
    ``path``) and emit one ``span`` record at exit; the context manager
    yields a mutable attrs dict so callers can attach results (loss,
    metric, sizes) measured inside the span.
  * ``count(name)``   — monotone counters (queue stalls, shed requests,
    windows read), snapshotted as ``counter`` records by ``flush()``.
  * ``gauge(name)``   — last-value gauges (queue depth, EWMA latency,
    device memory), snapshotted as ``gauge`` records by ``flush()``.
  * ``observe(name)`` — fixed-bucket latency histograms (log-spaced
    edges, ~33% resolution) with p50/p99 read-out, snapshotted as
    ``hist`` records by ``flush()``.

**Disabled is free.** A ``Telemetry`` with no sinks (the default) keeps
``enabled`` False: ``span`` returns a cached ``nullcontext`` and the
other calls return after one attribute check, so instrumented hot loops
pay ~no overhead until someone attaches a sink (bounded by
``tests/test_obs.py``; numbers in ``docs/observability.md``). Sinks can
be attached/detached mid-run — ``TrainLoop`` tees a ``MemorySink``
through whatever the pipeline already has to rebuild its history from
the records it just emitted.

All aggregate state is lock-guarded and spans use thread-local nesting,
so the serving and prefetch daemon threads emit safely into the same
object. ``EwmaGauge`` is the standalone exponentially-weighted average
used by the serving latency breaker (kept bit-identical to the formula
it replaced).
"""

from __future__ import annotations

import bisect
import contextlib
import math
import threading
import time
from typing import Any, Dict, Iterable, List, Optional

from repro.obs.sinks import MemorySink, NullSink, Sink

# Histogram bucket geometry: 8 log-spaced buckets per decade from 100ns
# to 1000s (every latency this codebase can produce), ~33% resolution.
_H_LO, _H_DECADES, _H_PER_DECADE = 1e-7, 10, 8
_H_GROWTH = 10.0 ** (1.0 / _H_PER_DECADE)
_H_EDGES = [_H_LO * _H_GROWTH ** i
            for i in range(1, _H_DECADES * _H_PER_DECADE + 1)]


class Histogram:
    """Fixed-bucket latency histogram with quantile read-out.

    Buckets are log-spaced (8 per decade over ``[1e-7, 1e3]`` seconds,
    upper-edge ratio ~1.33) plus an underflow and an overflow bucket, so
    ``observe`` is O(log #buckets) with zero allocation and a snapshot is
    a short list — the Prometheus histogram idiom. ``quantile`` returns
    the upper edge of the bucket holding the requested rank: an upper
    bound on the true quantile, tight to one bucket ratio (verified
    against ``numpy.quantile`` in ``tests/test_obs.py``).
    """

    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self):
        self.counts = [0] * (len(_H_EDGES) + 1)  # [under..., buckets, over]
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, seconds: float) -> None:
        """Record one value (seconds; any nonnegative float works)."""
        x = float(seconds)
        self.counts[bisect.bisect_left(_H_EDGES, x)] += 1
        self.count += 1
        self.sum += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)

    def quantile(self, q: float) -> float:
        """Upper-edge estimate of the ``q`` quantile (0 for empty)."""
        if self.count == 0:
            return 0.0
        target = min(max(int(math.ceil(q * self.count)), 1), self.count)
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target:
                edge = _H_EDGES[i] if i < len(_H_EDGES) else self.max
                return min(edge, self.max)
        return self.max  # pragma: no cover - cum always reaches count

    def snapshot(self, name: str) -> Dict[str, Any]:
        """This histogram as a schema-valid ``hist`` record.

        ``buckets`` lists only the occupied buckets as ``[upper_edge,
        count]`` pairs (overflow keeps the last real edge scaled once
        more), which keeps records short on sparse histograms.
        """
        buckets = [
            [_H_EDGES[i] if i < len(_H_EDGES) else _H_EDGES[-1] * _H_GROWTH,
             c]
            for i, c in enumerate(self.counts) if c
        ]
        return {"kind": "hist", "name": name, "count": self.count,
                "sum": self.sum, "p50": self.quantile(0.5),
                "p99": self.quantile(0.99), "buckets": buckets}


class EwmaGauge:
    """Exponentially-weighted moving average with explicit coefficients.

    ``update`` computes ``decay * prev + alpha * x`` (first sample passes
    through). ``decay`` defaults to ``1 - alpha`` but is an explicit
    parameter so call sites replacing a hand-rolled EWMA (the serving
    latency breaker's ``0.7 * prev + 0.3 * lat``) reproduce their exact
    float sequence, keeping threshold semantics bit-identical.
    """

    __slots__ = ("alpha", "decay", "value")

    def __init__(self, alpha: float = 0.3, decay: Optional[float] = None):
        self.alpha = float(alpha)
        self.decay = (1.0 - self.alpha) if decay is None else float(decay)
        self.value: Optional[float] = None

    def update(self, x: float) -> float:
        """Fold one sample in; returns the new average."""
        self.value = (x if self.value is None
                      else self.decay * self.value + self.alpha * x)
        return self.value


class Telemetry:
    """The write-side telemetry API (see the module docstring).

    ``sink`` seeds the attached-sink list (``None`` or a ``NullSink``
    means disabled); more sinks can be attached/detached at any time and
    every record is fanned out to all of them. One instance is intended
    per pipeline/service; the module-level ``NULL`` singleton is the
    shared disabled default for call sites that only read.
    """

    def __init__(self, sink: Optional[Sink] = None):
        self._sinks: List[Sink] = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Histogram] = {}
        # Reusable no-op span: one shared scratch dict (callers may write
        # attrs into it; nothing ever reads it back).
        self._null_span = contextlib.nullcontext({})
        if sink is not None:
            self.attach(sink)

    # -- sink management -------------------------------------------------
    @property
    def enabled(self) -> bool:
        """True when at least one (non-null) sink is attached."""
        return bool(self._sinks)

    def attach(self, sink: Sink) -> Sink:
        """Attach a sink (``NullSink`` is ignored); returns it."""
        if not isinstance(sink, NullSink):
            with self._lock:
                self._sinks.append(sink)
        return sink

    def detach(self, sink: Sink) -> None:
        """Detach a previously attached sink (missing sinks are ignored)."""
        with self._lock:
            self._sinks = [s for s in self._sinks if s is not sink]

    def _emit(self, record: Dict[str, Any]) -> None:
        for s in list(self._sinks):
            s.emit(record)

    # -- spans -----------------------------------------------------------
    def span(self, name: str, **attrs):
        """Context manager timing one section on the monotonic clock.

        Yields a mutable attrs dict (seeded with ``**attrs``) that rides
        the emitted ``span`` record; nesting within a thread builds the
        dotted ``path``. Disabled telemetry returns a cached null context
        (yields a scratch dict, records nothing).
        """
        if not self._sinks:
            return self._null_span
        return self._span(name, attrs)

    @contextlib.contextmanager
    def _span(self, name: str, attrs: Dict[str, Any]):
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        path = ".".join([*stack, name])
        stack.append(name)
        t0 = time.monotonic()
        try:
            yield attrs
        finally:
            dur = time.monotonic() - t0
            stack.pop()
            self._emit({"kind": "span", "name": name, "path": path,
                        "t0": t0, "dur_s": dur, "attrs": attrs})

    # -- aggregates ------------------------------------------------------
    def count(self, name: str, n: float = 1) -> None:
        """Add ``n`` to a counter (snapshotted by ``flush``)."""
        if not self._sinks:
            return
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        """Set a gauge to its latest value (snapshotted by ``flush``)."""
        if not self._sinks:
            return
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, seconds: float) -> None:
        """Record one latency sample into a histogram."""
        if not self._sinks:
            return
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            h.observe(seconds)

    def flush(self) -> None:
        """Emit one snapshot record per counter/gauge/histogram.

        Aggregates keep accumulating after a flush (records are
        cumulative snapshots, not deltas); ``reset`` clears them.
        """
        if not self._sinks:
            return
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = [h.snapshot(k) for k, h in self._hists.items()]
        for name, v in sorted(counters.items()):
            self._emit({"kind": "counter", "name": name, "value": v})
        for name, v in sorted(gauges.items()):
            self._emit({"kind": "gauge", "name": name, "value": v})
        for rec in hists:
            self._emit(rec)

    def reset(self) -> None:
        """Clear all counter/gauge/histogram state (sinks stay attached)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    # -- read-side conveniences (tests, reports) -------------------------
    def counter_value(self, name: str, default: float = 0) -> float:
        """Current value of a counter (``default`` when never counted)."""
        with self._lock:
            return self._counters.get(name, default)

    def gauge_value(self, name: str, default: float = 0.0) -> float:
        """Current value of a gauge (``default`` when never set)."""
        with self._lock:
            return self._gauges.get(name, default)

    def histogram(self, name: str) -> Optional[Histogram]:
        """The live histogram for ``name`` (``None`` when never observed)."""
        with self._lock:
            return self._hists.get(name)


#: Shared disabled instance — the default for instrumented call sites
#: that never attach sinks themselves (do not attach sinks to it).
NULL = Telemetry()


def span_report(records: Iterable[Dict[str, Any]], min_pct: float = 0.5,
                markdown: bool = False) -> str:
    """Aggregate ``span`` records into a per-path timing table.

    Sums duration and call count per dotted span path and renders the
    Table-11-style breakdown the old ``utils.prof.Profiler.report``
    printed (percentages against the top-level total; sub-``min_pct``
    rows dropped). ``markdown=True`` renders a GitHub-flavored table for
    ``$GITHUB_STEP_SUMMARY``. Non-span records are ignored, so a whole
    JSONL file can be piped through unfiltered.
    """
    times: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for r in records:
        if r.get("kind") != "span":
            continue
        p = r["path"]
        times[p] = times.get(p, 0.0) + float(r["dur_s"])
        counts[p] = counts.get(p, 0) + 1
    total = max(sum(v for k, v in times.items() if "." not in k), 1e-12)
    rows = []
    for path in sorted(times, key=lambda p: (p.count("."), -times[p])):
        pct = 100.0 * times[path] / total
        if pct < min_pct:
            continue
        depth = path.count(".")
        label = ("&nbsp;&nbsp;" if markdown else "  ") * depth \
            + path.split(".")[-1]
        rows.append((label, counts[path], times[path], pct))
    if markdown:
        lines = ["| section | calls | seconds | % |",
                 "| --- | ---: | ---: | ---: |"]
        lines += [f"| {n} | {c} | {t:.3f} | {p:.1f}% |"
                  for n, c, t, p in rows]
        return "\n".join(lines)
    lines = [f"{'section':<40s}{'calls':>8s}{'seconds':>10s}{'%':>7s}"]
    lines += [f"{n:<40s}{c:>8d}{t:>10.3f}{p:>6.1f}%" for n, c, t, p in rows]
    return "\n".join(lines)


def history_sink() -> MemorySink:
    """A fresh ``MemorySink`` for history/tee use (tiny convenience so
    callers outside ``repro.obs`` don't need two imports)."""
    return MemorySink()
