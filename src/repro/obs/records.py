"""The typed telemetry record schema shared by every ``repro.obs`` sink.

Every record is one flat JSON object with a ``kind`` discriminator; the
five kinds cover the whole observability surface (``docs/observability.md``
has the field-by-field reference):

  ``span``    — one timed section: dotted ``path`` (nesting), monotonic
                start ``t0``, duration ``dur_s``, free-form ``attrs``;
  ``counter`` — a monotonically accumulated count, snapshotted at flush;
  ``gauge``   — a point-in-time value (queue depth, EWMA latency,
                device memory);
  ``hist``    — a fixed-bucket latency histogram snapshot: ``count``,
                ``sum``, derived ``p50``/``p99``, and the per-bucket
                counts (``buckets``) for offline re-aggregation;
  ``bench``   — a benchmark measurement. Field-compatible with the
                legacy BENCH_JSON rows (``name``/``us``/``derived``/
                ``ts``/``rev``/``backend``/``device_count``), which is
                what lets ``benchmarks/common.py`` emit through this
                layer without touching ``scripts/check_bench_regression``.

``validate`` is the single source of truth for the schema: tests assert
every record a run emits passes it, and ``FileSink`` output round-trips
through it line by line.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

KINDS = ("span", "counter", "gauge", "hist", "bench")

# Required fields (beyond "kind") per record kind, with the accepted types.
_NUM = (int, float)
_REQUIRED: Dict[str, Dict[str, tuple]] = {
    "span": {"name": (str,), "path": (str,), "t0": _NUM, "dur_s": _NUM,
             "attrs": (dict,)},
    "counter": {"name": (str,), "value": _NUM},
    "gauge": {"name": (str,), "value": _NUM},
    "hist": {"name": (str,), "count": (int,), "sum": _NUM, "p50": _NUM,
             "p99": _NUM, "buckets": (list,)},
    "bench": {"name": (str,), "us": _NUM, "derived": (str,), "ts": _NUM},
}


def validate(record: Any) -> Dict[str, Any]:
    """Check one record against the schema; returns it, raises ``ValueError``.

    A valid record is a dict with a known ``kind`` and every
    kind-required field present with the right type. Extra fields are
    allowed (``bench`` records carry ``rev``/``backend``/``device_count``;
    spans may carry anything in ``attrs``) — the schema is a floor, not a
    ceiling, so sinks stay forward-compatible.
    """
    if not isinstance(record, dict):
        raise ValueError(f"record must be a dict, got {type(record).__name__}")
    kind = record.get("kind")
    if kind not in KINDS:
        raise ValueError(f"unknown record kind {kind!r}; have {KINDS}")
    for field, types in _REQUIRED[kind].items():
        if field not in record:
            raise ValueError(f"{kind} record missing field {field!r}: {record}")
        v = record[field]
        if not isinstance(v, types) or isinstance(v, bool):
            raise ValueError(
                f"{kind} record field {field!r} has type "
                f"{type(v).__name__}, expected one of "
                f"{[t.__name__ for t in types]}")
    return record


def bench_record(name: str, value: float, derived: str = "", *,
                 ts: float, rev: Optional[str], backend: Optional[str],
                 device_count: Optional[int]) -> Dict[str, Any]:
    """Build a ``bench`` record with the exact legacy BENCH_JSON fields.

    ``benchmarks/common.py`` routes every ``emit``/``emit_value`` through
    here, so bench rows and telemetry records share one schema; the field
    names and rounding match the pre-obs writer bit-for-bit (only the
    ``kind`` discriminator is new, which the regression gate ignores).
    """
    return validate({
        "kind": "bench",
        "name": name,
        "us": round(float(value), 1),
        "derived": derived,
        "ts": round(float(ts), 3),
        "rev": rev,
        "backend": backend,
        "device_count": device_count,
    })
