"""Pluggable telemetry sinks — where ``repro.obs`` records go.

The sink contract is one method: ``emit(record)`` takes a schema-valid
plain-JSON dict (``repro.obs.records``) and must be safe to call from any
thread (the serving and prefetch paths emit from daemon threads). Three
implementations cover every deployment:

  * :class:`NullSink`   — drops everything; the explicit no-op. A
    ``Telemetry`` with no sinks (the default) never even builds records,
    so the disabled path costs one attribute check per call site.
  * :class:`MemorySink` — appends to an in-process list; the test sink
    (and what ``TrainLoop`` tees through to rebuild its history).
  * :class:`FileSink`   — appends one JSON line per record to a file
    (JSONL), flushed per record so a crashed run keeps everything it
    emitted. This is what ``TrainSpec.telemetry`` wires up.
"""

from __future__ import annotations

import json
import threading
from typing import Any, Dict, List


class Sink:
    """Base sink: ``emit`` receives schema-valid records, ``close`` is
    called (idempotently) when the owner is done with the sink."""

    def emit(self, record: Dict[str, Any]) -> None:
        """Consume one record (thread-safe in every subclass)."""
        raise NotImplementedError

    def close(self) -> None:
        """Release resources; further ``emit`` calls are undefined."""


class NullSink(Sink):
    """The explicit no-op sink: every record is dropped."""

    def emit(self, record: Dict[str, Any]) -> None:
        """Drop the record."""


class MemorySink(Sink):
    """In-memory sink for tests and history reconstruction.

    ``records`` is the emitted list in arrival order; it is safe to read
    concurrently with emits (appends are atomic under the GIL, and a lock
    guards against torn iteration in ``drain``).
    """

    def __init__(self):
        self.records: List[Dict[str, Any]] = []
        self._lock = threading.Lock()

    def emit(self, record: Dict[str, Any]) -> None:
        """Append the record."""
        with self._lock:
            self.records.append(record)

    def drain(self) -> List[Dict[str, Any]]:
        """Return all records so far and clear the sink."""
        with self._lock:
            out, self.records = self.records, []
            return out


class FileSink(Sink):
    """Append-mode JSONL sink: one JSON object per line.

    The file is opened lazily on first emit (so building a ``Telemetry``
    from a spec never touches the filesystem until something is actually
    recorded), written under a lock, and flushed per record — a killed
    process keeps every line it wrote. Append mode means several runs (or
    the bench writer and a telemetry writer) can share one trajectory
    file, same as the BENCH_JSON convention.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._lock = threading.Lock()
        self._f = None

    def emit(self, record: Dict[str, Any]) -> None:
        """Append ``record`` as one JSON line (flushed immediately)."""
        with self._lock:
            if self._f is None:
                self._f = open(self.path, "a")
            self._f.write(json.dumps(record) + "\n")
            self._f.flush()

    def close(self) -> None:
        """Close the underlying file (idempotent)."""
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None

    def __del__(self):  # pragma: no cover - GC-order dependent
        try:
            self.close()
        except Exception:
            pass
