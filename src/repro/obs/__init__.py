"""Structured telemetry for the whole stack (``docs/observability.md``).

One write-side API — :class:`~repro.obs.telemetry.Telemetry` spans,
counters, gauges, and latency histograms — emits typed JSONL records
(``repro.obs.records``) through pluggable sinks (``repro.obs.sinks``):
no-op by default, in-memory for tests, append-JSONL for runs. The train
pipelines (``TrainSpec.telemetry``), ``PrefetchLoader``, the serving
tiers, and the storage layer all instrument through this package, and
``benchmarks/common.py`` emits BENCH_JSON rows as the same schema's
``bench`` records. ``repro.obs.profiler`` adds the JAX runtime hooks
(``jax.profiler`` trace capture, device-memory gauges).
"""

from repro.obs.profiler import device_memory_gauges, trace_capture
from repro.obs.records import bench_record, validate
from repro.obs.sinks import FileSink, MemorySink, NullSink, Sink
from repro.obs.telemetry import (
    NULL,
    EwmaGauge,
    Histogram,
    Telemetry,
    span_report,
)

__all__ = [
    "Telemetry",
    "NULL",
    "EwmaGauge",
    "Histogram",
    "span_report",
    "Sink",
    "NullSink",
    "MemorySink",
    "FileSink",
    "validate",
    "bench_record",
    "trace_capture",
    "device_memory_gauges",
]
