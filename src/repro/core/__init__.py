"""TGM core: the paper's primary contribution in JAX.

Unified CTDG/DTDG temporal graphs (event storage + views + granularity),
vectorized discretization, the hook/recipe formalism, and vectorized
temporal neighbor sampling.
"""

from repro.core.batch import Batch
from repro.core.device_sampler import DeviceRecencySampler
from repro.core.device_uniform import DeviceUniformSampler
from repro.core.discretize import (
    discretize,
    discretize_edges_padded,
    discretize_jax,
    discretize_naive,
)
from repro.core.events import EdgeEvent, NodeEvent
from repro.core.granularity import EventOrderedError, TimeDelta
from repro.core.graph import DGData, DGraph, SnapshotTensor
from repro.core.hooks import BASE_ATTRS, Hook, HookManager, LambdaHook, RecipeError, resolve_order
from repro.core.loader import DGDataLoader, PrefetchLoader, snapshot_tensor
from repro.core.negatives import NegativeEdgeSampler, snapshot_negatives
from repro.core.recipes import (
    EVAL_KEY,
    RECIPE_ANALYTICS_DOS,
    RECIPE_DTDG_SNAPSHOT,
    RECIPE_TGB_LINK,
    RECIPE_TGB_NODE,
    TRAIN_KEY,
    RecipeRegistry,
)
from repro.core.sampler import (
    NeighborBlock,
    RecencySampler,
    SequentialRecencySampler,
    UniformSampler,
)

__all__ = [
    "Batch",
    "BASE_ATTRS",
    "DeviceRecencySampler",
    "DeviceUniformSampler",
    "DGData",
    "DGraph",
    "DGDataLoader",
    "PrefetchLoader",
    "EdgeEvent",
    "EventOrderedError",
    "Hook",
    "HookManager",
    "LambdaHook",
    "NegativeEdgeSampler",
    "NeighborBlock",
    "NodeEvent",
    "RecencySampler",
    "RecipeError",
    "RecipeRegistry",
    "SequentialRecencySampler",
    "SnapshotTensor",
    "TimeDelta",
    "UniformSampler",
    "discretize",
    "discretize_edges_padded",
    "discretize_jax",
    "discretize_naive",
    "resolve_order",
    "snapshot_negatives",
    "snapshot_tensor",
    "RECIPE_TGB_LINK",
    "RECIPE_TGB_NODE",
    "RECIPE_DTDG_SNAPSHOT",
    "RECIPE_ANALYTICS_DOS",
    "TRAIN_KEY",
    "EVAL_KEY",
]
