"""Materialized batches ``B|_{T,A}`` (paper Def. 3.6).

A batch is a temporal slice of the graph enriched with a set of *attributes*
``A`` (tensors a model consumes). Hooks transform batches by producing new
attributes; the batch tracks which attributes are present so hook contracts
(requires ⊂ A) can be validated at runtime as well as at recipe-build time.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, KeysView, Set


class Batch:
    """Attribute-tracked batch container.

    Behaves like a dict of named tensors; ``attrs`` is the paper's ``A``.
    Base attributes after materialization: ``src, dst, time`` (+``edge_feats``
    etc. when present). ``meta`` carries non-tensor info (time window, sizes).
    """

    __slots__ = ("_data", "meta")

    def __init__(self, data: Dict[str, Any] | None = None, meta: Dict[str, Any] | None = None):
        self._data: Dict[str, Any] = dict(data or {})
        self.meta: Dict[str, Any] = dict(meta or {})

    # -- attribute set (paper's A) -----------------------------------------
    @property
    def attrs(self) -> Set[str]:
        return set(self._data.keys())

    def require(self, *names: str) -> None:
        missing = [n for n in names if n not in self._data]
        if missing:
            raise KeyError(
                f"batch is missing required attributes {missing}; "
                f"present: {sorted(self._data)}"
            )

    # -- mapping protocol ----------------------------------------------------
    def __getitem__(self, name: str) -> Any:
        if name not in self._data:
            raise KeyError(
                f"batch attribute {name!r} not present; available: {sorted(self._data)}"
            )
        return self._data[name]

    def __setitem__(self, name: str, value: Any) -> None:
        self._data[name] = value

    def __contains__(self, name: str) -> bool:
        return name in self._data

    def __iter__(self) -> Iterator[str]:
        return iter(self._data)

    def keys(self) -> KeysView[str]:
        return self._data.keys()

    def get(self, name: str, default: Any = None) -> Any:
        return self._data.get(name, default)

    def update(self, other: Dict[str, Any]) -> None:
        self._data.update(other)

    def as_dict(self) -> Dict[str, Any]:
        return dict(self._data)

    @property
    def num_events(self) -> int:
        src = self._data.get("src")
        return 0 if src is None else len(src)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Batch(attrs={sorted(self._data)}, meta={self.meta})"
