"""Time granularity: first-class time units for temporal graphs (paper §3).

A temporal graph has a *native* granularity ``tau``: the coarsest unit that
still discriminates all event timestamps. If real time is unavailable, the
special event-ordered granularity ``TimeDelta.event()`` preserves only order
and is excluded from arithmetic time operations.

Granularities are partially ordered: ``a <= b`` iff ``b`` is coarser, i.e.
one tick of ``b`` spans an integral (>=1) number of ticks of ``a``.

See ``docs/architecture.md`` for how granularity carries the CTDG/DTDG
split through the loader and discretization.
"""

from __future__ import annotations

import dataclasses
from typing import Union

# Seconds per unit. 'r' is the event-ordered pseudo-unit (no real-time span).
_UNIT_SECONDS = {
    "ns": 1e-9,
    "us": 1e-6,
    "ms": 1e-3,
    "s": 1.0,
    "m": 60.0,
    "h": 3600.0,
    "d": 86400.0,
    "w": 7 * 86400.0,
    "y": 365 * 86400.0,
}

_ORDERED_UNIT = "r"


class EventOrderedError(TypeError):
    """Raised when a real-time operation is applied to event-ordered time."""


@dataclasses.dataclass(frozen=True, order=False)
class TimeDelta:
    """A time granularity: ``value`` ticks of ``unit``.

    ``TimeDelta('h')`` is hourly; ``TimeDelta('s', 30)`` is 30-second;
    ``TimeDelta.event()`` is the event-ordered granularity ``tau_event``.
    """

    unit: str
    value: int = 1

    def __post_init__(self) -> None:
        if self.unit != _ORDERED_UNIT and self.unit not in _UNIT_SECONDS:
            raise ValueError(
                f"unknown time unit {self.unit!r}; "
                f"expected one of {sorted(_UNIT_SECONDS)} or {_ORDERED_UNIT!r}"
            )
        if self.value <= 0:
            raise ValueError(f"granularity value must be positive, got {self.value}")
        if self.unit == _ORDERED_UNIT and self.value != 1:
            raise ValueError("event-ordered granularity has no multiple")

    # -- constructors ------------------------------------------------------
    @classmethod
    def event(cls) -> "TimeDelta":
        """The event-ordered pseudo-granularity ``tau_event``."""
        return cls(_ORDERED_UNIT, 1)

    @classmethod
    def coerce(cls, value: Union["TimeDelta", str]) -> "TimeDelta":
        if isinstance(value, TimeDelta):
            return value
        return cls(value)

    # -- properties --------------------------------------------------------
    @property
    def is_event_ordered(self) -> bool:
        return self.unit == _ORDERED_UNIT

    @property
    def seconds(self) -> float:
        """Real-time span of one tick, in seconds."""
        if self.is_event_ordered:
            raise EventOrderedError(
                "event-ordered granularity has no real-time span; "
                "it is excluded from time operations (paper §3)"
            )
        return _UNIT_SECONDS[self.unit] * self.value

    def ticks_per(self, finer: "TimeDelta") -> int:
        """Number of ``finer`` ticks per tick of ``self`` (must be integral)."""
        ratio = self.seconds / finer.seconds
        n = round(ratio)
        if n < 1 or abs(ratio - n) > 1e-9 * max(1.0, n):
            raise ValueError(
                f"{self} is not an integral multiple of {finer} (ratio={ratio})"
            )
        return n

    def is_coarser_or_equal(self, other: "TimeDelta") -> bool:
        """True iff self >= other in the coarseness order (paper: tau_hat >= tau)."""
        if self.is_event_ordered or other.is_event_ordered:
            raise EventOrderedError(
                "event-ordered granularity is not comparable in coarseness"
            )
        return self.seconds >= other.seconds - 1e-12

    # -- comparisons: a <= b  <=>  b is coarser ----------------------------
    def __le__(self, other: "TimeDelta") -> bool:
        return other.is_coarser_or_equal(self)

    def __lt__(self, other: "TimeDelta") -> bool:
        return self <= other and self.seconds < other.seconds

    def __ge__(self, other: "TimeDelta") -> bool:
        return self.is_coarser_or_equal(other)

    def __gt__(self, other: "TimeDelta") -> bool:
        return self >= other and self.seconds > other.seconds

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        if self.is_event_ordered:
            return "TimeDelta(event-ordered)"
        return f"TimeDelta({self.value}{self.unit})"
