"""Device-resident uniform temporal neighbor sampling.

``DeviceUniformSampler`` is the JAX twin of ``UniformSampler``: the
CSR-by-time adjacency lives on the accelerator, built with JAX segment ops
(one ``segment_sum`` for the per-node degree counts + a stable composite-key
sort), and sampling is a single jitted global ``searchsorted`` over the
fused ``(node, time-rank)`` key — the same vectorization trick the device
recency sampler's update uses (see ``core/device_sampler.py``), ported to
the static-adjacency case:

  * ``rank(t)`` maps raw timestamps through the unique-time table, so the
    composite key ``node * (num_times + 1) + rank(t)`` is immune to raw
    timestamp magnitude and globally sorted (the adjacency is node-major
    with times ascending within each node);
  * per query, the count of neighbors strictly before ``query_t`` is
    ``searchsorted(keys, seed * base + rank(query_t)) - indptr[seed]`` —
    one vectorized search for the whole (B,) seed batch, no per-seed loop;
  * K draws per seed are taken uniformly (with replacement) from that
    prefix with a counter-derived ``jax.random`` key, so epochs are
    reproducible and ``reset_state`` replays them.

``state_dict``/``load_state_dict`` speak the same canonical host-numpy
contract as the host sampler (``adj_nbr/adj_t/adj_e/indptr/counter``), so
checkpoints are interchangeable between the two — mirroring the
``RecencySampler``/``DeviceRecencySampler`` pairing, which makes the two
sampler families drop-in swappable inside ``RECIPE_TGB_LINK``.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_sampler import as_int32
from repro.core.sampler import NeighborBlock, csr_from_state

_I32_MAX = np.int32(2**31 - 1)


@partial(jax.jit, static_argnames=("num_nodes",))
def _build(nodes, nbrs, times, eids, *, num_nodes: int):
    """Sort the doubled edge list into node-major/time-ascending CSR order
    and compute per-node extents with segment ops. Pure/jit."""
    m = nodes.shape[0]
    # Unique-time table (padded to fixed size with int32 max so searchsorted
    # stays correct for any in-range query).
    tvals = jnp.unique(times, size=m, fill_value=_I32_MAX)
    tranks = jnp.searchsorted(tvals, times).astype(jnp.int32)
    num_t = jnp.searchsorted(tvals, _I32_MAX).astype(jnp.int32)
    base = num_t + 1
    # Stable sort on the (node, time-rank) composite key: groups by node,
    # time-ascending within the node, original order on exact ties — the
    # same layout numpy's lexsort((times, nodes)) produces on the host.
    key = nodes * base + tranks
    order = jnp.argsort(key, stable=True)
    counts = jax.ops.segment_sum(jnp.ones(m, jnp.int32), nodes,
                                 num_segments=num_nodes)
    indptr = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts, dtype=jnp.int32)])
    return {
        "adj_nbr": nbrs[order],
        "adj_t": times[order],
        "adj_e": eids[order],
        "adj_key": key[order],
        "indptr": indptr,
        "tvals": tvals,
        "base": base,
    }


@partial(jax.jit, static_argnames=("k",))
def _sample(adj, seeds, query_t, rng_key, *, k: int):
    """Uniform K-with-replacement draws from each seed's strict-past prefix.

    One global ``searchsorted`` on the composite key yields every seed's
    valid-prefix length at once; seeds with an empty prefix come back fully
    masked.
    """
    qranks = jnp.searchsorted(adj["tvals"], query_t, side="left")
    qranks = qranks.astype(jnp.int32)
    starts = adj["indptr"][seeds]
    ends = jnp.searchsorted(adj["adj_key"], seeds * adj["base"] + qranks,
                            side="left").astype(jnp.int32)
    n_valid = ends - starts
    has = n_valid > 0
    B = seeds.shape[0]
    draw = jax.random.randint(rng_key, (B, k), 0,
                              jnp.maximum(n_valid, 1)[:, None], jnp.int32)
    idx = jnp.minimum(starts[:, None] + draw, adj["adj_nbr"].shape[0] - 1)
    ids = jnp.where(has[:, None], adj["adj_nbr"][idx], -1)
    times = jnp.where(has[:, None], adj["adj_t"][idx], 0)
    eids = jnp.where(has[:, None], adj["adj_e"][idx], -1)
    mask = jnp.broadcast_to(has[:, None], (B, k))
    return ids, times, eids, mask


class DeviceUniformSampler:
    """JAX device-resident uniform temporal neighbor sampler.

    Drop-in twin of ``UniformSampler``: ``build`` once per storage slice,
    then ``sample(seeds, query_t)`` draws K past neighbors per seed
    uniformly with replacement, entirely on ``device`` (default: first JAX
    device). Sampling uses a counter-derived PRNG key per call, so runs are
    reproducible and ``reset_state`` rewinds an epoch exactly.
    """

    def __init__(self, num_nodes: int, k: int, seed: int = 0, device=None,
                 checkpoint_adjacency: bool = True):
        if k <= 0:
            raise ValueError("k must be positive")
        self.num_nodes = int(num_nodes)
        self.k = int(k)
        self._seed = int(seed)
        self._counter = 0
        self._device = device or jax.devices()[0]
        self._adj = None
        self.checkpoint_adjacency = bool(checkpoint_adjacency)

    # ------------------------------------------------------------------
    _as_i32 = staticmethod(as_int32)

    def build(self, src, dst, t, eids: Optional[np.ndarray] = None) -> None:
        """Build the device CSR-by-time adjacency for an edge storage slice.

        Each undirected event contributes both (src -> dst) and
        (dst -> src) entries. ``eids`` defaults to the event index, matching
        the ``EdgeFeatureLookupHook`` convention.
        """
        if eids is None:
            eids = np.arange(len(np.asarray(src)), dtype=np.int64)
        nodes = jnp.concatenate([self._as_i32(src, "src"),
                                 self._as_i32(dst, "dst")])
        nbrs = jnp.concatenate([self._as_i32(dst, "dst"),
                                self._as_i32(src, "src")])
        times = jnp.concatenate([self._as_i32(t, "t")] * 2)
        es = jnp.concatenate([self._as_i32(eids, "eids")] * 2)
        adj = _build(nodes, nbrs, times, eids=es, num_nodes=self.num_nodes)
        # One host sync at build time (once per split) to verify the fused
        # int32 key cannot have overflowed: num_nodes * base must fit.
        base = int(adj["base"])
        if self.num_nodes * base >= 2**31:
            raise ValueError(
                f"composite key range num_nodes*({base}) exceeds int32; use "
                f"the host UniformSampler for this graph"
            )
        self._adj = jax.device_put(adj, self._device)

    @property
    def _built(self) -> bool:
        return self._adj is not None

    def reset_state(self) -> None:
        """Rewind the draw counter (start of an epoch); keeps the built
        adjacency — it is a pure function of the storage slice."""
        self._counter = 0

    def sample(self, seeds, query_t) -> NeighborBlock:
        """Draw K uniform past neighbors per seed, strictly before
        ``query_t``. Returns a fixed-shape device ``NeighborBlock``."""
        if not self._built:
            raise RuntimeError("DeviceUniformSampler.build() must be called first")
        seeds = jnp.asarray(seeds, jnp.int32)
        query_t = self._as_i32(query_t, "query_t")
        rng_key = jax.random.fold_in(jax.random.PRNGKey(self._seed),
                                     self._counter)
        self._counter += 1
        ids, times, eids, mask = _sample(self._adj, seeds, query_t, rng_key,
                                         k=self.k)
        return NeighborBlock(ids, times, eids, mask)

    # -- checkpoint contract (shared with UniformSampler) ----------------
    def state_dict(self) -> dict:
        """Canonical host-numpy state: the CSR arrays plus the draw counter.
        Loads into either uniform sampler (self-contained restore at an
        O(E) checkpoint cost — see ``UniformSampler.state_dict``). With
        ``checkpoint_adjacency=False``, counter-only: the restoring side
        rebuilds the CSR from storage via ``build(...)``."""
        if not self._built or not self.checkpoint_adjacency:
            return {"counter": np.int64(self._counter)}
        host = jax.device_get(self._adj)
        return {
            "adj_nbr": host["adj_nbr"].astype(np.int64),
            "adj_t": host["adj_t"].astype(np.int64),
            "adj_e": host["adj_e"].astype(np.int64),
            "indptr": host["indptr"].astype(np.int64),
            "counter": np.int64(self._counter),
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore from either sampler's ``state_dict``; the derived
        composite-key/time-rank arrays are rebuilt on device."""
        self._counter = int(state["counter"])
        if "adj_nbr" not in state:
            return
        nodes, nbrs, times, eids = csr_from_state(state, self.num_nodes)
        adj = _build(
            self._as_i32(nodes, "nodes"),
            self._as_i32(nbrs, "adj_nbr"),
            self._as_i32(times, "adj_t"),
            eids=self._as_i32(eids, "adj_e"),
            num_nodes=self.num_nodes,
        )
        self._adj = jax.device_put(adj, self._device)
